"""L2: KERMIT's ML compute graphs, built on the L1 pallas kernels.

Each public function here is a pure jax function lowered once by aot.py to
an HLO-text artifact that the rust runtime executes via PJRT. Parameters
are passed as explicit leading arguments (no closures) so the rust side
owns all state; train steps return updated parameters (functional SGD).

Graphs:
  * lstm_predictor_fwd  — WorkloadPredictor inference (paper §7.2): one-hot
    label history -> next-label logits at horizons t+1 (the rust side rolls
    the sequence forward to get t+5 / t+10).
  * lstm_train_step     — BPTT + SGD over a minibatch of label sequences.
  * mlp_classifier_fwd  — NN variant of the WorkloadClassifier (Fig 6).
  * mlp_train_step      — fwd + bwd + SGD for the MLP.
  * pairwise_dist_graph — DBSCAN distance-matrix batch (Algorithm 2).
  * welch_stats_graph   — per-window mean/var for the ChangeDetector.
"""

import jax
import jax.numpy as jnp

from . import shapes
from .kernels import lstm_cell as k_lstm
from .kernels import mlp as k_mlp
from .kernels import pairwise_dist as k_dist
from .kernels import ref
from .kernels import window_stats as k_wstats

# Pallas interpret-mode has no reverse-mode autodiff rule, so the *train*
# graphs run the pure-jnp oracles from kernels/ref.py — bit-compatible with
# the pallas kernels (enforced by python/tests/test_kernels.py) — while
# every *inference* graph (the on-line hot path) runs the pallas kernels.


# --------------------------------------------------------------------------
# LSTM workload predictor
# --------------------------------------------------------------------------

def lstm_apply(wx, wh, b, wo, bo, seq, cell=k_lstm.lstm_cell):
    """Run the LSTM over seq [b, t, c] one-hot labels; return logits [b, c].

    lax.scan keeps the lowered HLO compact (a While loop) instead of
    unrolling LSTM_SEQ copies of the cell.
    """
    bsz = seq.shape[0]
    h0 = jnp.zeros((bsz, shapes.LSTM_HIDDEN), jnp.float32)
    c0 = jnp.zeros((bsz, shapes.LSTM_HIDDEN), jnp.float32)

    def step(carry, x_t):
        h, c = carry
        h, c = cell(x_t, h, c, wx, wh, b)
        return (h, c), None

    (h, _), _ = jax.lax.scan(step, (h0, c0), jnp.swapaxes(seq, 0, 1))
    return h @ wo + bo


def lstm_predictor_fwd(wx, wh, b, wo, bo, seq):
    """Inference entry point: seq [1, t, c] -> logits [1, c]."""
    return (lstm_apply(wx, wh, b, wo, bo, seq),)


def _lstm_loss(params, seq, labels):
    wx, wh, b, wo, bo = params
    logits = lstm_apply(wx, wh, b, wo, bo, seq, cell=ref.lstm_cell)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1)
    return jnp.mean(nll)


def lstm_train_step(wx, wh, b, wo, bo, seq, labels, lr):
    """One SGD step over a minibatch. Returns (loss, *updated params)."""
    params = (wx, wh, b, wo, bo)
    loss, grads = jax.value_and_grad(_lstm_loss)(params, seq, labels)
    new = tuple(p - lr * g for p, g in zip(params, grads))
    return (loss.reshape(1),) + new


# --------------------------------------------------------------------------
# MLP workload classifier (NN comparator in Fig 6)
# --------------------------------------------------------------------------

def mlp_apply(w1, b1, w2, b2, x, layer=k_mlp.mlp_layer):
    h = layer(x, w1, b1, relu=True)
    return layer(h, w2, b2, relu=False)


def mlp_classifier_fwd(w1, b1, w2, b2, x):
    """x [n, f] -> logits [n, c]."""
    return (mlp_apply(w1, b1, w2, b2, x),)


def _mlp_loss(params, x, labels):
    w1, b1, w2, b2 = params
    logits = mlp_apply(w1, b1, w2, b2, x, layer=ref.mlp_layer)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1)
    return jnp.mean(nll)


def mlp_train_step(w1, b1, w2, b2, x, labels, lr):
    params = (w1, b1, w2, b2)
    loss, grads = jax.value_and_grad(_mlp_loss)(params, x, labels)
    new = tuple(p - lr * g for p, g in zip(params, grads))
    return (loss.reshape(1),) + new


# --------------------------------------------------------------------------
# DBSCAN distance batch + Welch window statistics
# --------------------------------------------------------------------------

def pairwise_dist_graph(x, y):
    """[n, f] x [m, f] -> squared distances [n, m]."""
    return (k_dist.pairwise_sq_dist(x, y, block=shapes.DIST_BLOCK),)


def welch_stats_graph(windows):
    """[w, s, f] -> (mean [w, f], var [w, f])."""
    mean, var = k_wstats.window_stats(windows)
    return (mean, var)
