"""AOT exporter: lower every L2 graph to HLO text + write a manifest.

HLO *text* (never `.serialize()`): jax >= 0.5 emits HloModuleProto with
64-bit instruction ids that the rust side's xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Run as `python -m compile.aot --out-dir ../artifacts` from python/ (the
Makefile does this). Python runs ONCE at build time; the rust binary is
self-contained afterwards.
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model, shapes


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*dims):
    return jax.ShapeDtypeStruct(dims, jnp.float32)


def i32(*dims):
    return jax.ShapeDtypeStruct(dims, jnp.int32)


def lstm_param_specs():
    c, h = shapes.MAX_CLASSES, shapes.LSTM_HIDDEN
    return [f32(c, 4 * h), f32(h, 4 * h), f32(4 * h), f32(h, c), f32(c)]


def mlp_param_specs():
    f, h, c = shapes.MLP_FEATURES, shapes.MLP_HIDDEN, shapes.MAX_CLASSES
    return [f32(f, h), f32(h), f32(h, c), f32(c)]


def graph_specs():
    """(name, fn, arg_specs) for every artifact."""
    c = shapes.MAX_CLASSES
    return [
        (
            "pairwise_dist",
            model.pairwise_dist_graph,
            [f32(shapes.DIST_N, shapes.DIST_F),
             f32(shapes.DIST_N, shapes.DIST_F)],
        ),
        (
            "welch_stats",
            model.welch_stats_graph,
            [f32(shapes.WELCH_WINDOWS, shapes.WELCH_SAMPLES,
                 shapes.NUM_FEATURES)],
        ),
        (
            "lstm_fwd",
            model.lstm_predictor_fwd,
            lstm_param_specs() + [f32(1, shapes.LSTM_SEQ, c)],
        ),
        (
            "lstm_train",
            model.lstm_train_step,
            lstm_param_specs()
            + [f32(shapes.LSTM_BATCH, shapes.LSTM_SEQ, c),
               i32(shapes.LSTM_BATCH), f32()],
        ),
        (
            "mlp_fwd",
            model.mlp_classifier_fwd,
            mlp_param_specs() + [f32(shapes.MLP_BATCH, shapes.MLP_FEATURES)],
        ),
        (
            "mlp_train",
            model.mlp_train_step,
            mlp_param_specs()
            + [f32(shapes.MLP_BATCH, shapes.MLP_FEATURES),
               i32(shapes.MLP_BATCH), f32()],
        ),
    ]


def spec_json(s):
    return {"dtype": str(s.dtype), "shape": list(s.shape)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {
        "format": "hlo-text/return-tuple",
        "constants": {
            "num_features": shapes.NUM_FEATURES,
            "analytic_features": shapes.ANALYTIC_FEATURES,
            "dist_f": shapes.DIST_F,
            "mlp_features": shapes.MLP_FEATURES,
            "max_classes": shapes.MAX_CLASSES,
            "dist_n": shapes.DIST_N,
            "dist_block": shapes.DIST_BLOCK,
            "lstm_hidden": shapes.LSTM_HIDDEN,
            "lstm_seq": shapes.LSTM_SEQ,
            "lstm_batch": shapes.LSTM_BATCH,
            "mlp_hidden": shapes.MLP_HIDDEN,
            "mlp_batch": shapes.MLP_BATCH,
            "welch_windows": shapes.WELCH_WINDOWS,
            "welch_samples": shapes.WELCH_SAMPLES,
        },
        "artifacts": {},
    }

    for name, fn, specs in graph_specs():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            "inputs": [spec_json(s) for s in specs],
        }
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
