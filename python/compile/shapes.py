"""Fixed AOT shapes shared between the L1/L2 python layer and the rust runtime.

Every artifact is lowered at these exact shapes; the rust side reads them
back from artifacts/manifest.json (written by aot.py) so the two layers can
never drift apart silently.
"""

# Observation-window feature vector width (see DESIGN.md §3: 16 container
# performance counters — cpu user/sys/iowait, mem used/cache, disk r/w,
# net rx/tx, ctx switches, page faults, gc time, task queue, shuffle bytes,
# hdfs read/write).
NUM_FEATURES = 16

# Workload label space. Labels are generated integers (paper §7.1); the
# one-hot width bounds how many distinct workload classes the NN components
# can track. 32 pure+hybrid classes is ample for the paper's workloads.
MAX_CLASSES = 32

# Analytic-window width: window mean concatenated with window std (the
# representation the classifiers and DBSCAN operate on — see
# rust/src/features/mod.rs::AnalyticWindow).
ANALYTIC_FEATURES = 2 * NUM_FEATURES

# --- pairwise_dist artifact (DBSCAN distance matrix over analytic rows) ---
DIST_N = 256          # rows per batch tile (rust tiles larger sets over this)
DIST_F = ANALYTIC_FEATURES
DIST_BLOCK = 128      # pallas block edge: 2 tiles per grid axis

# --- LSTM workload predictor ---
LSTM_HIDDEN = 64
LSTM_SEQ = 16         # label-history length fed to the predictor
LSTM_BATCH = 32       # training minibatch (sequences)

# --- MLP workload classifier (NN variant benchmarked in Fig 6) ---
MLP_FEATURES = ANALYTIC_FEATURES
MLP_HIDDEN = 64
MLP_BATCH = 256       # inference/training batch (rust pads short batches)

# --- Welch window statistics ---
WELCH_WINDOWS = 64    # observation windows per batch
WELCH_SAMPLES = 32    # raw samples aggregated per window
