"""L1 Pallas kernel: fused LSTM cell.

The WorkloadPredictor (paper §7.2) is an LSTM over the recent label
sequence. The TPU-friendly formulation computes all four gates with a
single pair of matmuls against concatenated weights —

    gates = x @ Wx + h @ Wh + b        # [b, 4h], one MXU pass per operand

— then applies the elementwise gate math fused in the same kernel, so the
intermediate `gates` tensor never round-trips to HBM. Gate order along the
4H axis is (i, f, g, o), matching ref.lstm_cell.

Shapes here are small (b<=32, h=64): a single grid step with everything
resident in VMEM (< 200 KiB).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, h_ref, c_ref, wx_ref, wh_ref, b_ref, hn_ref, cn_ref):
    x = x_ref[...]
    h = h_ref[...]
    c = c_ref[...]
    gates = (
        jax.lax.dot_general(x, wx_ref[...], (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
        + jax.lax.dot_general(h, wh_ref[...], (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
        + b_ref[...]
    )
    hd = h.shape[1]
    i = gates[:, 0 * hd:1 * hd]
    f = gates[:, 1 * hd:2 * hd]
    g = gates[:, 2 * hd:3 * hd]
    o = gates[:, 3 * hd:4 * hd]
    c_new = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    hn_ref[...] = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    cn_ref[...] = c_new


@jax.jit
def lstm_cell(x, h, c, wx, wh, b):
    """One LSTM step: x [b, f], h/c [b, hd], wx [f, 4hd], wh [hd, 4hd],
    b [4hd] -> (h', c')."""
    bsz, hd = h.shape
    return pl.pallas_call(
        _kernel,
        out_shape=(
            jax.ShapeDtypeStruct((bsz, hd), jnp.float32),
            jax.ShapeDtypeStruct((bsz, hd), jnp.float32),
        ),
        interpret=True,
    )(x, h, c, wx, wh, b)
