"""Pure-jnp oracles for every Pallas kernel.

These are the correctness ground truth: python/tests/test_kernels.py asserts
allclose between each pallas kernel (interpret=True) and the function here,
across hypothesis-generated shapes and values. They are also used directly
by model.py when a non-pallas reference lowering is wanted.
"""

import jax
import jax.numpy as jnp


def pairwise_sq_dist(x, y):
    """Squared euclidean distance matrix: out[i, j] = ||x_i - y_j||^2.

    Formulated as ||x||^2 + ||y||^2 - 2 x.y^T — the matmul form the pallas
    kernel tiles for the MXU. Clamped at zero (the subtraction can go
    slightly negative in f32).
    """
    xn = jnp.sum(x * x, axis=1, keepdims=True)          # [n, 1]
    yn = jnp.sum(y * y, axis=1, keepdims=True).T        # [1, m]
    d = xn + yn - 2.0 * (x @ y.T)
    return jnp.maximum(d, 0.0)


def lstm_cell(x, h, c, wx, wh, b):
    """One fused LSTM step. Gate order along the 4H axis: i, f, g, o."""
    gates = x @ wx + h @ wh + b                         # [b, 4h]
    hd = h.shape[1]
    i, f, g, o = (gates[:, k * hd:(k + 1) * hd] for k in range(4))
    c_new = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    return h_new, c_new


def window_stats(windows):
    """Per-window mean and (population) variance over the sample axis.

    windows: [w, s, f] -> (mean [w, f], var [w, f]).
    """
    mean = jnp.mean(windows, axis=1)
    var = jnp.mean(windows * windows, axis=1) - mean * mean
    return mean, jnp.maximum(var, 0.0)


def mlp_layer(x, w, b, relu=True):
    """Fused dense (+ optional relu)."""
    y = x @ w + b
    return jnp.maximum(y, 0.0) if relu else y
