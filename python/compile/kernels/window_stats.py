"""L1 Pallas kernel: per-observation-window feature statistics.

The ChangeDetector (paper §7.2) runs Welch's t-test between neighbouring
observation windows; its inputs are the per-window mean and variance of
each feature. In batch mode (off-line Algorithm 2) KERMIT re-scans the full
landed time-series, so the reduction is worth a kernel: each grid step
stages one window [s, f] in VMEM and emits its mean and population variance
in one pass using the E[x^2] - E[x]^2 identity (single read of the data).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(w_ref, mean_ref, var_ref):
    w = w_ref[...]                                       # [1, s, f]
    s = w.shape[1]
    sum1 = jnp.sum(w, axis=1)                            # [1, f]
    sum2 = jnp.sum(w * w, axis=1)                        # [1, f]
    mean = sum1 / s
    mean_ref[...] = mean
    var_ref[...] = jnp.maximum(sum2 / s - mean * mean, 0.0)


@jax.jit
def window_stats(windows):
    """windows [w, s, f] -> (mean [w, f], var [w, f]); one grid step per
    window."""
    w, s, f = windows.shape
    return pl.pallas_call(
        _kernel,
        grid=(w,),
        in_specs=[pl.BlockSpec((1, s, f), lambda i: (i, 0, 0))],
        out_specs=(
            pl.BlockSpec((1, f), lambda i: (i, 0)),
            pl.BlockSpec((1, f), lambda i: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((w, f), jnp.float32),
            jax.ShapeDtypeStruct((w, f), jnp.float32),
        ),
        interpret=True,
    )(windows)
