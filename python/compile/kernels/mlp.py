"""L1 Pallas kernel: fused dense layer (matmul + bias + optional relu).

Building block for the MLP variant of the WorkloadClassifier (the NN
comparator in Fig 6). Fusing bias-add and relu into the matmul kernel keeps
the activation tensor in VMEM instead of bouncing through HBM between ops.
Batch is tiled over the grid so large inference batches stream through a
fixed VMEM footprint.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _make_kernel(relu):
    def kernel(x_ref, w_ref, b_ref, o_ref):
        y = jax.lax.dot_general(
            x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) + b_ref[...]
        o_ref[...] = jnp.maximum(y, 0.0) if relu else y
    return kernel


@functools.partial(jax.jit, static_argnames=("relu", "block"))
def mlp_layer(x, w, b, *, relu=True, block=None):
    """x [n, f] @ w [f, h] + b [h], optionally relu'd. `block` tiles the
    batch axis (must divide n); defaults to the whole batch."""
    n, f = x.shape
    h = w.shape[1]
    blk = block or n
    assert n % blk == 0, (n, blk)
    return pl.pallas_call(
        _make_kernel(relu),
        grid=(n // blk,),
        in_specs=[
            pl.BlockSpec((blk, f), lambda i: (i, 0)),
            pl.BlockSpec((f, h), lambda i: (0, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((blk, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h), jnp.float32),
        interpret=True,
    )(x, w, b)
