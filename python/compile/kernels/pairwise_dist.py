"""L1 Pallas kernel: tiled pairwise squared-L2 distance matrix.

The DBSCAN region query in the rust off-line sub-system needs the full
distance matrix over a batch of observation-window feature vectors. On TPU
the natural formulation is the matmul identity

    d[i, j] = ||x_i||^2 + ||y_j||^2 - 2 * (x @ y^T)[i, j]

so the dominant term runs on the MXU. The grid tiles the [n, m] output into
BLOCK x BLOCK panels; each kernel invocation stages one x-row panel and one
y-row panel through VMEM and emits one output tile. With BLOCK=128 and
F<=64 the working set is 2*128*F*4 + 128*128*4 ≈ 130 KiB — far inside the
16 MiB VMEM budget, leaving headroom for double buffering (see
EXPERIMENTS.md §Perf for the block-size sweep).

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO which both the pytest
oracle check and the rust runtime consume.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, y_ref, o_ref):
    x = x_ref[...]                                      # [bx, f]
    y = y_ref[...]                                      # [by, f]
    xn = jnp.sum(x * x, axis=1, keepdims=True)          # [bx, 1]
    yn = jnp.sum(y * y, axis=1, keepdims=True).T        # [1, by]
    # MXU term: contract over the feature axis in f32.
    prod = jax.lax.dot_general(
        x, y, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    o_ref[...] = jnp.maximum(xn + yn - 2.0 * prod, 0.0)


@functools.partial(jax.jit, static_argnames=("block",))
def pairwise_sq_dist(x, y, *, block=128):
    """Pairwise squared distances via a blocked pallas kernel.

    x: [n, f], y: [m, f] with n, m divisible by `block` -> [n, m].
    """
    n, f = x.shape
    m, _ = y.shape
    assert n % block == 0 and m % block == 0, (n, m, block)
    grid = (n // block, m // block)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, f), lambda i, j: (i, 0)),
            pl.BlockSpec((block, f), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block, block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=True,
    )(x, y)
