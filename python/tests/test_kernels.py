"""L1 correctness: every pallas kernel vs its pure-jnp oracle.

hypothesis sweeps shapes and values; assert_allclose is the gate. These
tests are the CORE numeric signal for the whole stack — the rust runtime
executes exactly the HLO these kernels lower to.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import lstm_cell as k_lstm
from compile.kernels import mlp as k_mlp
from compile.kernels import pairwise_dist as k_dist
from compile.kernels import window_stats as k_wstats
from compile.kernels import ref

ATOL = 2e-5
RTOL = 2e-5


def rand(rng, *shape, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32) * scale)


# --------------------------------------------------------------------------
# pairwise_dist
# --------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    st.integers(1, 3),            # n blocks
    st.integers(1, 3),            # m blocks
    st.sampled_from([4, 16, 17]), # feature dim (incl. non-power-of-2)
    st.sampled_from([8, 32]),     # block edge
    st.integers(0, 2**31 - 1),
)
def test_pairwise_dist_matches_ref(nb, mb, f, block, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, nb * block, f, scale=2.0)
    y = rand(rng, mb * block, f, scale=2.0)
    got = k_dist.pairwise_sq_dist(x, y, block=block)
    want = ref.pairwise_sq_dist(x, y)
    np.testing.assert_allclose(got, want, atol=1e-3, rtol=1e-3)


def test_pairwise_dist_self_zero_diagonal():
    rng = np.random.default_rng(0)
    x = rand(rng, 64, 16)
    d = k_dist.pairwise_sq_dist(x, x, block=32)
    np.testing.assert_allclose(jnp.diag(d), jnp.zeros(64), atol=1e-4)


def test_pairwise_dist_symmetry():
    rng = np.random.default_rng(1)
    x = rand(rng, 32, 8)
    d = k_dist.pairwise_sq_dist(x, x, block=16)
    np.testing.assert_allclose(d, d.T, atol=1e-4, rtol=1e-4)


def test_pairwise_dist_nonnegative():
    rng = np.random.default_rng(2)
    # near-duplicate rows provoke negative values in the naive formula
    x = rand(rng, 32, 4, scale=1e-3)
    d = k_dist.pairwise_sq_dist(x, x + 1e-7, block=16)
    assert bool(jnp.all(d >= 0.0))


def test_pairwise_dist_known_values():
    x = jnp.asarray([[0.0, 0.0], [3.0, 4.0]] * 4, jnp.float32)  # 8 rows
    d = k_dist.pairwise_sq_dist(x, x, block=8)
    assert pytest.approx(float(d[0, 1]), abs=1e-5) == 25.0
    assert pytest.approx(float(d[1, 0]), abs=1e-5) == 25.0


# --------------------------------------------------------------------------
# lstm_cell
# --------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(
    st.integers(1, 8),                 # batch
    st.sampled_from([3, 8, 32]),       # input feature dim
    st.sampled_from([4, 16, 64]),      # hidden
    st.integers(0, 2**31 - 1),
)
def test_lstm_cell_matches_ref(bsz, f, hd, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, bsz, f)
    h = rand(rng, bsz, hd)
    c = rand(rng, bsz, hd)
    wx = rand(rng, f, 4 * hd, scale=0.5)
    wh = rand(rng, hd, 4 * hd, scale=0.5)
    b = rand(rng, 4 * hd, scale=0.1)
    gh, gc = k_lstm.lstm_cell(x, h, c, wx, wh, b)
    wh_, wc_ = ref.lstm_cell(x, h, c, wx, wh, b)
    np.testing.assert_allclose(gh, wh_, atol=ATOL, rtol=RTOL)
    np.testing.assert_allclose(gc, wc_, atol=ATOL, rtol=RTOL)


def test_lstm_cell_bounded_h():
    rng = np.random.default_rng(3)
    h, _ = k_lstm.lstm_cell(
        rand(rng, 4, 8, scale=10.0), rand(rng, 4, 16, scale=10.0),
        rand(rng, 4, 16, scale=10.0), rand(rng, 8, 64, scale=10.0),
        rand(rng, 16, 64, scale=10.0), rand(rng, 64, scale=10.0),
    )
    assert bool(jnp.all(jnp.abs(h) <= 1.0 + 1e-6))  # |sigmoid*tanh| <= 1


def test_lstm_cell_zero_forget_drops_state():
    # f gate driven to ~0 via a huge negative bias -> c' ~= sigmoid(i)tanh(g)
    bsz, f, hd = 2, 4, 8
    rng = np.random.default_rng(4)
    x, h = rand(rng, bsz, f), rand(rng, bsz, hd)
    c = rand(rng, bsz, hd, scale=100.0)
    wx = jnp.zeros((f, 4 * hd), jnp.float32)
    wh = jnp.zeros((hd, 4 * hd), jnp.float32)
    b = jnp.concatenate([
        jnp.zeros(hd), jnp.full((hd,), -50.0), jnp.zeros(hd), jnp.zeros(hd)
    ]).astype(jnp.float32)
    _, c_new = k_lstm.lstm_cell(x, h, c, wx, wh, b)
    assert bool(jnp.all(jnp.abs(c_new) <= 0.51))


# --------------------------------------------------------------------------
# window_stats
# --------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(
    st.integers(1, 6),              # windows
    st.sampled_from([2, 8, 32]),    # samples per window
    st.sampled_from([1, 5, 16]),    # features
    st.integers(0, 2**31 - 1),
)
def test_window_stats_matches_ref(w, s, f, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, w, s, f, scale=3.0)
    gm, gv = k_wstats.window_stats(x)
    wm, wv = ref.window_stats(x)
    np.testing.assert_allclose(gm, wm, atol=ATOL, rtol=RTOL)
    np.testing.assert_allclose(gv, wv, atol=1e-4, rtol=1e-4)


def test_window_stats_constant_window():
    x = jnp.full((2, 16, 4), 7.5, jnp.float32)
    m, v = k_wstats.window_stats(x)
    np.testing.assert_allclose(m, jnp.full((2, 4), 7.5), atol=1e-6)
    np.testing.assert_allclose(v, jnp.zeros((2, 4)), atol=1e-6)


def test_window_stats_variance_nonnegative():
    rng = np.random.default_rng(5)
    x = rand(rng, 4, 8, 3, scale=1e-4) + 1e4  # catastrophic-cancellation bait
    _, v = k_wstats.window_stats(x)
    assert bool(jnp.all(v >= 0.0))


# --------------------------------------------------------------------------
# mlp_layer
# --------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(
    st.integers(1, 4),              # batch blocks
    st.sampled_from([4, 16]),       # block
    st.sampled_from([3, 16]),       # in features
    st.sampled_from([2, 32]),       # out features
    st.booleans(),
    st.integers(0, 2**31 - 1),
)
def test_mlp_layer_matches_ref(nb, blk, f, h, relu, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, nb * blk, f)
    w = rand(rng, f, h, scale=0.5)
    b = rand(rng, h, scale=0.1)
    got = k_mlp.mlp_layer(x, w, b, relu=relu, block=blk)
    want = ref.mlp_layer(x, w, b, relu=relu)
    np.testing.assert_allclose(got, want, atol=ATOL, rtol=RTOL)


def test_mlp_layer_relu_clamps():
    x = jnp.asarray([[-1.0, -2.0]], jnp.float32)
    w = jnp.eye(2, dtype=jnp.float32)
    b = jnp.zeros(2, jnp.float32)
    out = k_mlp.mlp_layer(x, w, b, relu=True)
    np.testing.assert_allclose(out, jnp.zeros((1, 2)), atol=0)
