"""L2 model graph tests: shapes, loss-decrease sanity, AOT lowering."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, shapes
from compile.aot import graph_specs, to_hlo_text


def lstm_params(rng, scale=0.3):
    c, h = shapes.MAX_CLASSES, shapes.LSTM_HIDDEN
    return (
        jnp.asarray(rng.standard_normal((c, 4 * h), dtype=np.float32) * scale),
        jnp.asarray(rng.standard_normal((h, 4 * h), dtype=np.float32) * scale),
        jnp.zeros(4 * h, jnp.float32),
        jnp.asarray(rng.standard_normal((h, c), dtype=np.float32) * scale),
        jnp.zeros(c, jnp.float32),
    )


def mlp_params(rng, scale=0.3):
    f, h, c = shapes.MLP_FEATURES, shapes.MLP_HIDDEN, shapes.MAX_CLASSES
    return (
        jnp.asarray(rng.standard_normal((f, h), dtype=np.float32) * scale),
        jnp.zeros(h, jnp.float32),
        jnp.asarray(rng.standard_normal((h, c), dtype=np.float32) * scale),
        jnp.zeros(c, jnp.float32),
    )


def test_lstm_fwd_shape():
    rng = np.random.default_rng(0)
    params = lstm_params(rng)
    seq = jnp.zeros((1, shapes.LSTM_SEQ, shapes.MAX_CLASSES), jnp.float32)
    (logits,) = model.lstm_predictor_fwd(*params, seq)
    assert logits.shape == (1, shapes.MAX_CLASSES)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_lstm_train_reduces_loss_on_fixed_pattern():
    rng = np.random.default_rng(1)
    params = lstm_params(rng)
    b, t, c = shapes.LSTM_BATCH, shapes.LSTM_SEQ, shapes.MAX_CLASSES
    # deterministic cyclic pattern: label follows (last + 1) % 5
    seqs = np.zeros((b, t, c), np.float32)
    labels = np.zeros(b, np.int32)
    for i in range(b):
        start = i % 5
        lab = [(start + j) % 5 for j in range(t + 1)]
        for j in range(t):
            seqs[i, j, lab[j]] = 1.0
        labels[i] = lab[t]
    seqs, labels = jnp.asarray(seqs), jnp.asarray(labels)
    lr = jnp.float32(0.5)

    loss0 = None
    for step in range(30):
        out = model.lstm_train_step(*params, seqs, labels, lr)
        loss = float(out[0][0])
        if loss0 is None:
            loss0 = loss
        params = out[1:]
    assert loss < loss0 * 0.5, (loss0, loss)


def test_mlp_fwd_shape():
    rng = np.random.default_rng(2)
    params = mlp_params(rng)
    x = jnp.zeros((shapes.MLP_BATCH, shapes.MLP_FEATURES), jnp.float32)
    (logits,) = model.mlp_classifier_fwd(*params, x)
    assert logits.shape == (shapes.MLP_BATCH, shapes.MAX_CLASSES)


def test_mlp_train_learns_separable_data():
    rng = np.random.default_rng(3)
    params = mlp_params(rng)
    b, f = shapes.MLP_BATCH, shapes.MLP_FEATURES
    labels = np.asarray([i % 4 for i in range(b)], np.int32)
    x = rng.standard_normal((b, f)).astype(np.float32) * 0.05
    for i in range(b):
        x[i, labels[i]] += 3.0  # one strong indicator feature per class
    x, jlabels = jnp.asarray(x), jnp.asarray(labels)
    lr = jnp.float32(0.2)
    for _ in range(60):
        out = model.mlp_train_step(*params, x, jlabels, lr)
        params = out[1:]
    (logits,) = model.mlp_classifier_fwd(*params, x)
    acc = float(jnp.mean((jnp.argmax(logits, axis=1) == jlabels)))
    assert acc > 0.95, acc


def test_pairwise_dist_graph_matches_bruteforce():
    rng = np.random.default_rng(4)
    n, f = shapes.DIST_N, shapes.DIST_F
    x = jnp.asarray(rng.standard_normal((n, f), dtype=np.float32))
    (d,) = model.pairwise_dist_graph(x, x)
    brute = np.sum((np.asarray(x)[:, None, :] - np.asarray(x)[None, :, :]) ** 2, axis=2)
    np.testing.assert_allclose(d, brute, atol=1e-2, rtol=1e-3)


def test_welch_stats_graph():
    rng = np.random.default_rng(5)
    w, s, f = shapes.WELCH_WINDOWS, shapes.WELCH_SAMPLES, shapes.NUM_FEATURES
    x = jnp.asarray(rng.standard_normal((w, s, f), dtype=np.float32))
    mean, var = model.welch_stats_graph(x)
    np.testing.assert_allclose(mean, np.asarray(x).mean(axis=1), atol=1e-5)
    np.testing.assert_allclose(var, np.asarray(x).var(axis=1), atol=1e-4)


@pytest.mark.parametrize("name", [g[0] for g in graph_specs()])
def test_all_graphs_lower_to_hlo_text(name):
    """Every artifact graph must lower to parseable HLO text (the exact
    bytes the rust runtime loads)."""
    spec = {g[0]: g for g in graph_specs()}[name]
    _, fn, args = spec
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    assert "ENTRY" in text and len(text) > 100
