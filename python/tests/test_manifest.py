"""Artifact bundle integrity: the manifest the rust runtime validates
against must match shapes.py, and every artifact file must be present
with its recorded hash (a stale artifacts/ dir is the classic cross-layer
failure mode)."""

import hashlib
import json
import os

import pytest

from compile import shapes

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART_DIR, "manifest.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(MANIFEST),
    reason="artifacts not built (run `make artifacts`)",
)


def load():
    with open(MANIFEST) as f:
        return json.load(f)


def test_constants_match_shapes():
    c = load()["constants"]
    assert c["num_features"] == shapes.NUM_FEATURES
    assert c["analytic_features"] == shapes.ANALYTIC_FEATURES
    assert c["max_classes"] == shapes.MAX_CLASSES
    assert c["dist_n"] == shapes.DIST_N
    assert c["dist_f"] == shapes.DIST_F
    assert c["lstm_hidden"] == shapes.LSTM_HIDDEN
    assert c["lstm_seq"] == shapes.LSTM_SEQ
    assert c["mlp_features"] == shapes.MLP_FEATURES
    assert c["mlp_batch"] == shapes.MLP_BATCH
    assert c["welch_windows"] == shapes.WELCH_WINDOWS
    assert c["welch_samples"] == shapes.WELCH_SAMPLES


def test_all_artifacts_present_with_matching_hash():
    m = load()
    assert set(m["artifacts"]) == {
        "pairwise_dist", "welch_stats", "lstm_fwd", "lstm_train",
        "mlp_fwd", "mlp_train",
    }
    for name, entry in m["artifacts"].items():
        path = os.path.join(ART_DIR, entry["file"])
        assert os.path.exists(path), f"{name} file missing"
        text = open(path).read()
        assert "ENTRY" in text, f"{name} is not HLO text"
        digest = hashlib.sha256(text.encode()).hexdigest()
        assert digest == entry["sha256"], f"{name} hash mismatch (stale?)"


def test_input_shapes_recorded():
    m = load()
    pd = m["artifacts"]["pairwise_dist"]["inputs"]
    assert pd[0]["shape"] == [shapes.DIST_N, shapes.DIST_F]
    lstm = m["artifacts"]["lstm_fwd"]["inputs"]
    # last input is the sequence
    assert lstm[-1]["shape"] == [1, shapes.LSTM_SEQ, shapes.MAX_CLASSES]
    for entry in m["artifacts"].values():
        for spec in entry["inputs"]:
            assert spec["dtype"] in ("float32", "int32")
