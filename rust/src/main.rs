//! KERMIT command-line launcher.
//!
//! Subcommands:
//!   run       — full autonomic loop on a recurring-job schedule,
//!               vs. default / rule-of-thumb / oracle baselines
//!   discover  — off-line discovery (Algorithm 2) on a generated trace
//!   artifacts — load + verify the AOT artifact bundle (PJRT smoke test)
//!   tune      — one-shot Explorer search for a single workload class

use kermit::clustering::NativeDistance;
use kermit::coordinator::{
    run_fixed_config, run_oracle, Coordinator, CoordinatorConfig,
};
use kermit::explorer::baselines::{exhaustive, rule_of_thumb};
use kermit::explorer::Explorer;
use kermit::monitor::{aggregate_trace, MonitorConfig};
use kermit::offline::{discover, DiscoveryConfig};
use kermit::simcluster::config_space::ConfigIndex;
use kermit::simcluster::perfmodel::job_duration;
use kermit::simcluster::{default_config_index, JobSpec};
use kermit::knowledge::WorkloadDb;
use kermit::util::cli::Args;
use kermit::workloadgen::{tour_schedule, Generator, Mix};

const USAGE: &str = "\
kermit — autonomic big-data performance optimization (KERMIT reproduction)

USAGE:
  kermit run [--cycles N] [--classes 0,3,5] [--seed S] [--budget B]
  kermit discover [--classes 0,2,5] [--duration D] [--seed S]
  kermit artifacts [--dir artifacts]
  kermit tune --class C [--budget B]
  kermit help
";

fn parse_classes(s: &str) -> Vec<u32> {
    s.split(',')
        .filter(|t| !t.is_empty())
        .map(|t| t.trim().parse().expect("bad class id"))
        .collect()
}

fn cmd_run(args: &Args) -> kermit::util::error::Result<()> {
    let cycles = args.get_usize("cycles", 40)?;
    let classes = parse_classes(args.get_or("classes", "0,3,5"));
    let seed = args.get_u64("seed", 1)?;
    let budget = args.get_usize("budget", 60)?;

    let mut jobs = Vec::new();
    for _ in 0..cycles {
        for &c in &classes {
            jobs.push(JobSpec { mix: Mix::Pure(c) });
        }
    }
    let mut cfg = CoordinatorConfig::default();
    cfg.seed = seed;
    cfg.offline_interval_windows = 12;
    let mut coord = Coordinator::new(cfg.clone());
    coord.plugin.explorer_config.global_budget = budget;

    println!("running {} jobs through the autonomic loop...", jobs.len());
    let kermit = coord.run_schedule(&jobs);
    let default =
        run_fixed_config(&jobs, default_config_index(), &cfg.engine, seed);
    let rot = run_fixed_config(&jobs, rule_of_thumb(), &cfg.engine, seed);
    let oracle = run_oracle(&jobs, &cfg.engine, seed);

    println!("\n== makespan (s, lower is better) ==");
    println!("  kermit          {:>12.0}", kermit.makespan);
    println!("  default config  {:>12.0}", default.makespan);
    println!("  rule of thumb   {:>12.0}", rot.makespan);
    println!("  oracle          {:>12.0}", oracle.makespan);
    println!("\n== steady state (mean of last 20 jobs) ==");
    println!("  kermit          {:>12.1}", kermit.tail_mean_duration(20));
    println!("  rule of thumb   {:>12.1}", rot.tail_mean_duration(20));
    println!("  oracle          {:>12.1}", oracle.tail_mean_duration(20));
    println!("\n== plugin ==\n  {:?}", kermit.plugin_stats);
    println!(
        "  workloads known: {}   label consistency: {:.3}",
        kermit.workloads_known,
        kermit.classification_consistency()
    );
    Ok(())
}

fn cmd_discover(args: &Args) -> kermit::util::error::Result<()> {
    let classes = parse_classes(args.get_or("classes", "0,2,5"));
    let duration = args.get_usize("duration", 500)?;
    let seed = args.get_u64("seed", 0)?;

    let mut g = Generator::with_default_config(seed);
    let trace = g.generate(&tour_schedule(duration, &classes));
    let windows =
        aggregate_trace(&trace, &MonitorConfig { window_size: 30 });
    let mut db = WorkloadDb::new();
    let report = discover(
        &windows,
        &mut db,
        &DiscoveryConfig::default(),
        &NativeDistance,
    );
    println!(
        "trace: {} samples, {} windows ({} transition, {} noise)",
        trace.len(),
        windows.len(),
        report.transition_windows,
        report.noise_windows
    );
    println!("clusters:");
    for o in &report.outcomes {
        println!("  {o:?}");
    }
    println!("workloads in DB: {}", db.len());
    Ok(())
}

fn cmd_artifacts(args: &Args) -> kermit::util::error::Result<()> {
    let dir = std::path::PathBuf::from(
        args.get_or("dir", "artifacts").to_string(),
    );
    let rt = kermit::runtime::Runtime::load(&dir)?;
    println!("PJRT platform: cpu; artifacts loaded from {}:", dir.display());
    for name in rt.names() {
        let a = rt.get(name)?;
        let shapes: Vec<String> = a
            .inputs
            .iter()
            .map(|i| format!("{:?}", i.shape))
            .collect();
        println!("  {name:<14} inputs: {}", shapes.join(", "));
    }
    println!("artifact smoke test OK");
    Ok(())
}

fn cmd_tune(args: &Args) -> kermit::util::error::Result<()> {
    let class = args.get_u64("class", 0)? as u32;
    let budget = args.get_usize("budget", 140)?;
    let mut cfg = kermit::explorer::ExplorerConfig::default();
    cfg.global_budget = budget;
    let ex = Explorer::new(cfg);
    let mut eval = |c: ConfigIndex| job_duration(class, &c.to_config());
    let found = ex.global_search(&mut eval);
    let oracle = exhaustive(&mut eval);
    println!("class {class}:");
    println!(
        "  explorer: {:?} -> {:.1}s in {} probes",
        found.best.0, found.best_duration, found.probes
    );
    println!(
        "  oracle:   {:?} -> {:.1}s in {} probes",
        oracle.best.0, oracle.best_duration, oracle.probes
    );
    println!(
        "  tuning efficiency: {:.1}%",
        100.0 * oracle.best_duration / found.best_duration
    );
    Ok(())
}

fn main() -> kermit::util::error::Result<()> {
    let args = Args::from_env(&[
        "cycles", "classes", "seed", "budget", "duration", "dir", "class",
    ])?;
    if args.help_requested() {
        print!("{USAGE}");
        return Ok(());
    }
    match args.subcommand.as_deref() {
        Some("run") => cmd_run(&args),
        Some("discover") => cmd_discover(&args),
        Some("artifacts") => cmd_artifacts(&args),
        Some("tune") => cmd_tune(&args),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}
