//! DBSCAN over a precomputed distance matrix — KERMIT's workload
//! discovery algorithm (Algorithm 2: "run DBSCAN on {O_t} to get a set
//! of clusters"; each cluster is a distinct workload type).
//!
//! The matrix-based formulation lets discovery batches route the O(n^2)
//! distance computation through the `pairwise_dist` PJRT artifact (the
//! L1 pallas kernel) — see `offline::discovery`.

use super::DistanceProvider;
use crate::linalg::engine::Engine;
use crate::linalg::Matrix;

/// Cluster id assigned to noise points.
pub const NOISE: i32 = -1;

#[derive(Debug, Clone)]
pub struct DbscanConfig {
    /// Neighbourhood radius (on *distances*, not squared — config is in
    /// the same units as the feature space).
    pub eps: f64,
    /// Minimum neighbourhood size (including the point itself) for a
    /// core point. The paper's µ hyper-parameter.
    pub min_pts: usize,
}

impl Default for DbscanConfig {
    fn default() -> Self {
        // µ default per the paper's "well-documented defaults" remark:
        // min_pts ≈ 2 * dim is the literature rule; eps is data-scale
        // dependent and set by callers.
        DbscanConfig { eps: 10.0, min_pts: 5 }
    }
}

#[derive(Debug, Clone)]
pub struct DbscanResult {
    /// Cluster id per row; NOISE (-1) for outliers, else 0..n_clusters.
    pub labels: Vec<i32>,
    pub n_clusters: usize,
}

impl DbscanResult {
    /// Row indices of cluster `c`.
    pub fn members(&self, c: i32) -> Vec<usize> {
        self.labels
            .iter()
            .enumerate()
            .filter(|(_, &l)| l == c)
            .map(|(i, _)| i)
            .collect()
    }
}

/// Classic DBSCAN (Ester et al.) with BFS cluster expansion.
pub fn dbscan(
    rows: &Matrix,
    config: &DbscanConfig,
    dist: &dyn DistanceProvider,
) -> DbscanResult {
    dbscan_with(Engine::sequential(), rows, config, dist)
}

/// Engine-parallel [`dbscan`]: the O(n²) neighbourhood queries fan out
/// over the engine's persistent worker pool (each row's neighbour list
/// is an independent scan of its distance-matrix row, written to a
/// disjoint slot). The BFS expansion is inherently sequential and
/// untouched, so labels are bit-identical to the sequential path for
/// any thread count. Pair with [`super::EngineDistance`] to also
/// parallelise the distance-matrix construction itself.
pub fn dbscan_with(
    engine: Engine,
    rows: &Matrix,
    config: &DbscanConfig,
    dist: &dyn DistanceProvider,
) -> DbscanResult {
    let n = rows.n_rows();
    if n == 0 {
        return DbscanResult { labels: vec![], n_clusters: 0 };
    }
    let d = dist.pairwise_sq(rows);
    let eps_sq = config.eps * config.eps;

    // neighbour lists (row-parallel)
    let mut neighbours: Vec<Vec<usize>> = vec![Vec::new(); n];
    engine.for_rows(&mut neighbours, 1, |start, chunk| {
        for (off, nb) in chunk.iter_mut().enumerate() {
            let drow = &d[(start + off) * n..(start + off + 1) * n];
            *nb = (0..n).filter(|&j| drow[j] <= eps_sq).collect();
        }
    });
    let is_core: Vec<bool> =
        neighbours.iter().map(|nb| nb.len() >= config.min_pts).collect();

    const UNVISITED: i32 = -2;
    let mut labels = vec![UNVISITED; n];
    let mut cluster = 0i32;

    for i in 0..n {
        if labels[i] != UNVISITED || !is_core[i] {
            continue;
        }
        // expand new cluster from core point i
        labels[i] = cluster;
        let mut queue: Vec<usize> = neighbours[i].clone();
        while let Some(j) = queue.pop() {
            if labels[j] == NOISE {
                labels[j] = cluster; // border point adopted
            }
            if labels[j] != UNVISITED {
                continue;
            }
            labels[j] = cluster;
            if is_core[j] {
                queue.extend(neighbours[j].iter().copied());
            }
        }
        cluster += 1;
    }
    // remaining unvisited points are noise
    for l in labels.iter_mut() {
        if *l == UNVISITED {
            *l = NOISE;
        }
    }
    DbscanResult { labels, n_clusters: cluster as usize }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clustering::NativeDistance;
    use crate::util::rng::Rng;

    fn blob(rng: &mut Rng, rows: &mut Matrix, cx: f64, cy: f64, n: usize, s: f64) {
        for _ in 0..n {
            rows.push_row(&[rng.normal_ms(cx, s), rng.normal_ms(cy, s)]);
        }
    }

    #[test]
    fn finds_two_blobs_and_noise() {
        let mut rng = Rng::new(0);
        let mut rows = Matrix::with_width(2);
        blob(&mut rng, &mut rows, 0.0, 0.0, 40, 0.3);
        blob(&mut rng, &mut rows, 10.0, 10.0, 40, 0.3);
        rows.push_row(&[5.0, 5.0]); // isolated noise point
        let r = dbscan(
            &rows,
            &DbscanConfig { eps: 1.2, min_pts: 4 },
            &NativeDistance,
        );
        assert_eq!(r.n_clusters, 2);
        assert_eq!(r.labels[80], NOISE);
        // first blob one cluster, second blob another
        let c0 = r.labels[0];
        assert!(r.labels[..40].iter().all(|&l| l == c0));
        let c1 = r.labels[40];
        assert_ne!(c0, c1);
        assert!(r.labels[40..80].iter().all(|&l| l == c1));
    }

    #[test]
    fn all_noise_when_eps_tiny() {
        let mut rng = Rng::new(1);
        let mut rows = Matrix::with_width(2);
        blob(&mut rng, &mut rows, 0.0, 0.0, 20, 1.0);
        let r = dbscan(
            &rows,
            &DbscanConfig { eps: 1e-6, min_pts: 3 },
            &NativeDistance,
        );
        assert_eq!(r.n_clusters, 0);
        assert!(r.labels.iter().all(|&l| l == NOISE));
    }

    #[test]
    fn one_cluster_when_eps_huge() {
        let mut rng = Rng::new(2);
        let mut rows = Matrix::with_width(2);
        blob(&mut rng, &mut rows, 0.0, 0.0, 20, 1.0);
        blob(&mut rng, &mut rows, 5.0, 0.0, 20, 1.0);
        let r = dbscan(
            &rows,
            &DbscanConfig { eps: 1e3, min_pts: 3 },
            &NativeDistance,
        );
        assert_eq!(r.n_clusters, 1);
        assert!(r.labels.iter().all(|&l| l == 0));
    }

    #[test]
    fn chain_connectivity() {
        // points in a line spaced 1.0 apart: single cluster at eps=1.5
        let mut rows = Matrix::with_width(2);
        for i in 0..30 {
            rows.push_row(&[i as f64, 0.0]);
        }
        let r = dbscan(
            &rows,
            &DbscanConfig { eps: 1.5, min_pts: 2 },
            &NativeDistance,
        );
        assert_eq!(r.n_clusters, 1);
    }

    #[test]
    fn empty_input() {
        let r =
            dbscan(&Matrix::new(), &DbscanConfig::default(), &NativeDistance);
        assert_eq!(r.n_clusters, 0);
        assert!(r.labels.is_empty());
    }

    #[test]
    fn parallel_labels_bit_identical_to_sequential() {
        use crate::clustering::EngineDistance;
        let mut rng = Rng::new(5);
        let mut rows = Matrix::with_width(2);
        blob(&mut rng, &mut rows, 0.0, 0.0, 60, 0.4);
        blob(&mut rng, &mut rows, 7.0, 7.0, 60, 0.4);
        rows.push_row(&[3.5, 3.5]);
        let cfg = DbscanConfig { eps: 1.2, min_pts: 4 };
        let a = dbscan(&rows, &cfg, &NativeDistance);
        for threads in [2, 4] {
            let engine = Engine::with_threads(threads).with_min_items(1);
            let b = dbscan_with(engine, &rows, &cfg, &EngineDistance::new(engine));
            assert_eq!(a.labels, b.labels, "threads {threads}");
            assert_eq!(a.n_clusters, b.n_clusters);
        }
    }

    #[test]
    fn labels_are_contiguous() {
        let mut rng = Rng::new(3);
        let mut rows = Matrix::with_width(2);
        for k in 0..4 {
            blob(&mut rng, &mut rows, 8.0 * k as f64, 0.0, 25, 0.4);
        }
        let r = dbscan(
            &rows,
            &DbscanConfig { eps: 1.5, min_pts: 4 },
            &NativeDistance,
        );
        assert_eq!(r.n_clusters, 4);
        let mut seen: Vec<i32> = r.labels.iter().copied().filter(|&l| l >= 0).collect();
        seen.sort();
        seen.dedup();
        assert_eq!(seen, vec![0, 1, 2, 3]);
    }
}
