//! Fig 10 clustering quality metrics: Purity and Awt.
//!
//! Paper §7.1: "Purity indicates how many of the observation windows were
//! classified correctly … The Awt metric … measures how accurately the
//! algorithm was able to identify different workload types. For example,
//! if the benchmark executed 3 different workload types and the algorithm
//! detected 3 clusters whose centroids fall within the observation window
//! range of each workload type, then the Awt metric for this algorithm
//! would be 100%."

use std::collections::{BTreeMap, BTreeSet};

/// Purity: each cluster votes for its dominant ground-truth class; purity
/// is the fraction of points whose cluster's dominant class matches their
/// own. Noise points (label < 0) count as singleton mistakes (they have
/// no cluster to be pure in), which penalises over-aggressive noise
/// flagging.
pub fn purity(truth: &[u32], cluster: &[i32]) -> f64 {
    assert_eq!(truth.len(), cluster.len());
    if truth.is_empty() {
        return 0.0;
    }
    let mut per_cluster: BTreeMap<i32, BTreeMap<u32, usize>> = BTreeMap::new();
    for (&t, &c) in truth.iter().zip(cluster) {
        if c >= 0 {
            *per_cluster.entry(c).or_default().entry(t).or_insert(0) += 1;
        }
    }
    let correct: usize = per_cluster
        .values()
        .map(|counts| counts.values().copied().max().unwrap_or(0))
        .sum();
    correct as f64 / truth.len() as f64
}

/// Awt ("accuracy of workload types"): fraction of ground-truth workload
/// types that are *identified* — i.e. some cluster's dominant class is
/// that type and that cluster's majority mass lies within the type's
/// windows. A type matched by more than one cluster counts once;
/// spurious extra clusters reduce the score via the denominator
/// max(#types, #clusters).
pub fn awt(truth: &[u32], cluster: &[i32]) -> f64 {
    assert_eq!(truth.len(), cluster.len());
    let types: BTreeSet<u32> = truth.iter().copied().collect();
    if types.is_empty() {
        return 0.0;
    }
    let mut per_cluster: BTreeMap<i32, BTreeMap<u32, usize>> = BTreeMap::new();
    for (&t, &c) in truth.iter().zip(cluster) {
        if c >= 0 {
            *per_cluster.entry(c).or_default().entry(t).or_insert(0) += 1;
        }
    }
    // dominant type of each cluster
    let mut matched: BTreeSet<u32> = BTreeSet::new();
    for counts in per_cluster.values() {
        let total: usize = counts.values().sum();
        if let Some((&dom, &n)) = counts.iter().max_by_key(|(_, &n)| n) {
            if n * 2 >= total {
                matched.insert(dom);
            }
        }
    }
    let denom = types.len().max(per_cluster.len());
    matched.len() as f64 / denom as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_clustering() {
        let truth = [0, 0, 1, 1, 2, 2];
        let cl = [0, 0, 1, 1, 2, 2];
        assert_eq!(purity(&truth, &cl), 1.0);
        assert_eq!(awt(&truth, &cl), 1.0);
    }

    #[test]
    fn merged_clusters_hurt_both() {
        let truth = [0, 0, 1, 1];
        let cl = [0, 0, 0, 0];
        assert_eq!(purity(&truth, &cl), 0.5);
        assert_eq!(awt(&truth, &cl), 0.5); // 1 of 2 types identified
    }

    #[test]
    fn split_cluster_keeps_purity_hurts_awt() {
        let truth = [0, 0, 0, 0, 1, 1];
        let cl = [0, 0, 1, 1, 2, 2]; // class 0 split into two clusters
        assert_eq!(purity(&truth, &cl), 1.0);
        // 2 types matched, but 3 clusters -> 2/3
        assert!((awt(&truth, &cl) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn noise_penalises_purity() {
        let truth = [0, 0, 0, 0];
        let cl = [0, 0, -1, -1];
        assert_eq!(purity(&truth, &cl), 0.5);
        assert_eq!(awt(&truth, &cl), 1.0); // the type itself was found
    }

    #[test]
    fn label_permutation_invariant() {
        let truth = [0, 0, 1, 1];
        let a = [0, 0, 1, 1];
        let b = [7, 7, 3, 3];
        assert_eq!(purity(&truth, &a), purity(&truth, &b));
        assert_eq!(awt(&truth, &a), awt(&truth, &b));
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(purity(&[], &[]), 0.0);
        assert_eq!(awt(&[], &[]), 0.0);
    }
}
