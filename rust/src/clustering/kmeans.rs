//! k-means baseline for Fig 10 (k-means++ init, Lloyd iterations).

use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct KmeansResult {
    pub labels: Vec<i32>,
    pub centroids: Vec<Vec<f64>>,
    pub inertia: f64,
    pub iterations: usize,
}

fn sq_dist(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// Lloyd's algorithm with k-means++ seeding.
pub fn kmeans(
    rows: &[Vec<f64>],
    k: usize,
    max_iter: usize,
    rng: &mut Rng,
) -> KmeansResult {
    assert!(k >= 1);
    assert!(rows.len() >= k, "need at least k rows");
    let n = rows.len();

    // k-means++ init
    let mut centroids: Vec<Vec<f64>> = Vec::with_capacity(k);
    centroids.push(rows[rng.range_usize(0, n)].clone());
    let mut d2: Vec<f64> =
        rows.iter().map(|r| sq_dist(r, &centroids[0])).collect();
    while centroids.len() < k {
        let total: f64 = d2.iter().sum();
        let next = if total <= 1e-18 {
            // all points coincide with existing centroids: pick any
            rng.range_usize(0, n)
        } else {
            let mut target = rng.f64() * total;
            let mut pick = n - 1;
            for (i, &w) in d2.iter().enumerate() {
                if target < w {
                    pick = i;
                    break;
                }
                target -= w;
            }
            pick
        };
        centroids.push(rows[next].clone());
        for (i, r) in rows.iter().enumerate() {
            let d = sq_dist(r, centroids.last().unwrap());
            if d < d2[i] {
                d2[i] = d;
            }
        }
    }

    let mut labels = vec![0i32; n];
    let mut iterations = 0;
    for it in 0..max_iter {
        iterations = it + 1;
        // assign
        let mut changed = false;
        for (i, r) in rows.iter().enumerate() {
            let best = centroids
                .iter()
                .enumerate()
                .map(|(c, cen)| (c, sq_dist(r, cen)))
                .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                .unwrap()
                .0 as i32;
            if labels[i] != best {
                labels[i] = best;
                changed = true;
            }
        }
        // update
        let w = rows[0].len();
        let mut sums = vec![vec![0.0; w]; k];
        let mut counts = vec![0usize; k];
        for (i, r) in rows.iter().enumerate() {
            let c = labels[i] as usize;
            counts[c] += 1;
            for j in 0..w {
                sums[c][j] += r[j];
            }
        }
        for c in 0..k {
            if counts[c] > 0 {
                for j in 0..w {
                    centroids[c][j] = sums[c][j] / counts[c] as f64;
                }
            } else {
                // empty cluster: reseed at the farthest point
                let far = (0..n)
                    .max_by(|&a, &b| {
                        let da = sq_dist(&rows[a], &centroids[labels[a] as usize]);
                        let db = sq_dist(&rows[b], &centroids[labels[b] as usize]);
                        da.partial_cmp(&db).unwrap()
                    })
                    .unwrap();
                centroids[c] = rows[far].clone();
            }
        }
        if !changed && it > 0 {
            break;
        }
    }
    let inertia = rows
        .iter()
        .zip(&labels)
        .map(|(r, &l)| sq_dist(r, &centroids[l as usize]))
        .sum();
    KmeansResult { labels, centroids, inertia, iterations }
}

/// Pick k by the elbow criterion over a k-range: the smallest k whose
/// relative inertia improvement drops below `threshold`. This is how the
/// Fig 10 harness gives k-means a fair shot without the true class count.
pub fn kmeans_elbow(
    rows: &[Vec<f64>],
    k_max: usize,
    threshold: f64,
    max_iter: usize,
    rng: &mut Rng,
) -> KmeansResult {
    assert!(k_max >= 1);
    let mut prev = kmeans(rows, 1, max_iter, rng);
    for k in 2..=k_max.min(rows.len()) {
        let cur = kmeans(rows, k, max_iter, rng);
        let denom = prev.inertia.max(1e-12);
        let improve = (prev.inertia - cur.inertia) / denom;
        if improve < threshold {
            return prev;
        }
        prev = cur;
    }
    prev
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blobs(rng: &mut Rng, centers: &[(f64, f64)], n: usize, s: f64) -> Vec<Vec<f64>> {
        let mut rows = vec![];
        for &(cx, cy) in centers {
            for _ in 0..n {
                rows.push(vec![rng.normal_ms(cx, s), rng.normal_ms(cy, s)]);
            }
        }
        rows
    }

    #[test]
    fn recovers_three_blobs() {
        let mut rng = Rng::new(0);
        let rows = blobs(&mut rng, &[(0.0, 0.0), (10.0, 0.0), (0.0, 10.0)], 50, 0.5);
        let r = kmeans(&rows, 3, 100, &mut rng);
        // each ground-truth blob maps to exactly one cluster
        for g in 0..3 {
            let ls = &r.labels[g * 50..(g + 1) * 50];
            assert!(ls.iter().all(|&l| l == ls[0]), "blob {g} split");
        }
        assert!(r.inertia < 150.0 * 2.0);
    }

    #[test]
    fn k_one_centroid_is_mean() {
        let rows = vec![vec![0.0], vec![2.0], vec![4.0]];
        let mut rng = Rng::new(1);
        let r = kmeans(&rows, 1, 10, &mut rng);
        assert!((r.centroids[0][0] - 2.0).abs() < 1e-9);
    }

    #[test]
    fn elbow_finds_reasonable_k() {
        let mut rng = Rng::new(2);
        let rows = blobs(
            &mut rng,
            &[(0.0, 0.0), (12.0, 0.0), (0.0, 12.0), (12.0, 12.0)],
            40,
            0.5,
        );
        let r = kmeans_elbow(&rows, 8, 0.25, 100, &mut rng);
        let k = r.centroids.len();
        assert!((3..=5).contains(&k), "k = {k}");
    }

    #[test]
    fn duplicate_points_dont_crash() {
        let rows = vec![vec![1.0, 1.0]; 10];
        let mut rng = Rng::new(3);
        let r = kmeans(&rows, 3, 10, &mut rng);
        assert_eq!(r.labels.len(), 10);
        assert!(r.inertia < 1e-9);
    }
}
