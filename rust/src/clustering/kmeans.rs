//! k-means baseline for Fig 10 (k-means++ init, Lloyd iterations) over
//! the contiguous `Matrix` row store.

use crate::linalg::{add_assign, sq_dist, Matrix};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct KmeansResult {
    pub labels: Vec<i32>,
    /// k x width centroid matrix.
    pub centroids: Matrix,
    pub inertia: f64,
    pub iterations: usize,
}

/// Lloyd's algorithm with k-means++ seeding.
///
/// Convergence check runs *before* the update step: once an assign pass
/// changes nothing (after at least one update has made the centroids
/// actual means), the loop exits without the redundant extra update the
/// classic "break at loop end" formulation pays. Empty clusters reseed
/// at the point farthest from its assigned centroid, reusing the
/// distances computed during the assign pass instead of recomputing
/// `sq_dist` per candidate.
pub fn kmeans(
    rows: &Matrix,
    k: usize,
    max_iter: usize,
    rng: &mut Rng,
) -> KmeansResult {
    assert!(k >= 1);
    let n = rows.n_rows();
    assert!(n >= k, "need at least k rows");
    let w = rows.n_cols();

    // k-means++ init (same probe sequence as the classic formulation)
    let mut centroids = Matrix::zeros(k, w);
    let first = rng.range_usize(0, n);
    centroids.row_mut(0).copy_from_slice(rows.row(first));
    let mut d2: Vec<f64> =
        rows.iter_rows().map(|r| sq_dist(r, centroids.row(0))).collect();
    let mut seeded = 1;
    while seeded < k {
        let total: f64 = d2.iter().sum();
        let next = if total <= 1e-18 {
            // all points coincide with existing centroids: pick any
            rng.range_usize(0, n)
        } else {
            let mut target = rng.f64() * total;
            let mut pick = n - 1;
            for (i, &weight) in d2.iter().enumerate() {
                if target < weight {
                    pick = i;
                    break;
                }
                target -= weight;
            }
            pick
        };
        centroids.row_mut(seeded).copy_from_slice(rows.row(next));
        for (i, r) in rows.iter_rows().enumerate() {
            let d = sq_dist(r, centroids.row(seeded));
            if d < d2[i] {
                d2[i] = d;
            }
        }
        seeded += 1;
    }

    let mut labels = vec![0i32; n];
    // distance of each point to its assigned centroid (assign-pass
    // byproduct; feeds inertia and empty-cluster reseeding for free)
    let mut assigned_d2 = vec![0.0f64; n];
    let mut sums = vec![0.0f64; k * w];
    let mut counts = vec![0usize; k];
    let mut iterations = 0;
    for it in 0..max_iter {
        iterations = it + 1;
        // assign
        let mut changed = false;
        for (i, r) in rows.iter_rows().enumerate() {
            let mut best = 0usize;
            let mut best_d = f64::INFINITY;
            for c in 0..k {
                let d = sq_dist(r, centroids.row(c));
                if d < best_d {
                    best_d = d;
                    best = c;
                }
            }
            assigned_d2[i] = best_d;
            if labels[i] != best as i32 {
                labels[i] = best as i32;
                changed = true;
            }
        }
        // converged: centroids are already the means of this assignment
        // (it == 0 is excluded because the initial all-zero labels may
        // coincidentally match before any update has run)
        if !changed && it > 0 {
            break;
        }
        // update
        sums.fill(0.0);
        counts.fill(0);
        for (i, r) in rows.iter_rows().enumerate() {
            let c = labels[i] as usize;
            counts[c] += 1;
            add_assign(&mut sums[c * w..(c + 1) * w], r);
        }
        for c in 0..k {
            if counts[c] > 0 {
                let cnt = counts[c] as f64;
                for (dst, s) in centroids
                    .row_mut(c)
                    .iter_mut()
                    .zip(&sums[c * w..(c + 1) * w])
                {
                    *dst = s / cnt;
                }
            } else {
                // empty cluster: reseed at the farthest point, using the
                // assign-pass distances
                let far = assigned_d2
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap();
                centroids.row_mut(c).copy_from_slice(rows.row(far));
            }
        }
    }
    let inertia = rows
        .iter_rows()
        .zip(&labels)
        .map(|(r, &l)| sq_dist(r, centroids.row(l as usize)))
        .sum();
    KmeansResult { labels, centroids, inertia, iterations }
}

/// Pick k by the elbow criterion over a k-range: the smallest k whose
/// relative inertia improvement drops below `threshold`. This is how the
/// Fig 10 harness gives k-means a fair shot without the true class count.
pub fn kmeans_elbow(
    rows: &Matrix,
    k_max: usize,
    threshold: f64,
    max_iter: usize,
    rng: &mut Rng,
) -> KmeansResult {
    assert!(k_max >= 1);
    let mut prev = kmeans(rows, 1, max_iter, rng);
    for k in 2..=k_max.min(rows.n_rows()) {
        let cur = kmeans(rows, k, max_iter, rng);
        let denom = prev.inertia.max(1e-12);
        let improve = (prev.inertia - cur.inertia) / denom;
        if improve < threshold {
            return prev;
        }
        prev = cur;
    }
    prev
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blobs(rng: &mut Rng, centers: &[(f64, f64)], n: usize, s: f64) -> Matrix {
        let mut rows = Matrix::with_width(2);
        for &(cx, cy) in centers {
            for _ in 0..n {
                rows.push_row(&[rng.normal_ms(cx, s), rng.normal_ms(cy, s)]);
            }
        }
        rows
    }

    #[test]
    fn recovers_three_blobs() {
        let mut rng = Rng::new(0);
        let rows = blobs(&mut rng, &[(0.0, 0.0), (10.0, 0.0), (0.0, 10.0)], 50, 0.5);
        let r = kmeans(&rows, 3, 100, &mut rng);
        // each ground-truth blob maps to exactly one cluster
        for g in 0..3 {
            let ls = &r.labels[g * 50..(g + 1) * 50];
            assert!(ls.iter().all(|&l| l == ls[0]), "blob {g} split");
        }
        assert!(r.inertia < 150.0 * 2.0);
    }

    #[test]
    fn k_one_centroid_is_mean() {
        let rows = Matrix::from_rows(&[vec![0.0], vec![2.0], vec![4.0]]);
        let mut rng = Rng::new(1);
        let r = kmeans(&rows, 1, 10, &mut rng);
        assert!((r.centroids.row(0)[0] - 2.0).abs() < 1e-9);
    }

    #[test]
    fn converged_init_stops_after_one_update() {
        // k=1: the initial all-zero labels already match; exactly one
        // update pass must run, then the next assign pass breaks
        let rows = Matrix::from_rows(&[vec![0.0], vec![2.0], vec![4.0]]);
        let mut rng = Rng::new(1);
        let r = kmeans(&rows, 1, 10, &mut rng);
        assert_eq!(r.iterations, 2, "expected assign+update then break");
    }

    #[test]
    fn elbow_finds_reasonable_k() {
        let mut rng = Rng::new(2);
        let rows = blobs(
            &mut rng,
            &[(0.0, 0.0), (12.0, 0.0), (0.0, 12.0), (12.0, 12.0)],
            40,
            0.5,
        );
        let r = kmeans_elbow(&rows, 8, 0.25, 100, &mut rng);
        let k = r.centroids.n_rows();
        assert!((3..=5).contains(&k), "k = {k}");
    }

    #[test]
    fn duplicate_points_dont_crash() {
        let rows = Matrix::from_rows(&vec![vec![1.0, 1.0]; 10]);
        let mut rng = Rng::new(3);
        let r = kmeans(&rows, 3, 10, &mut rng);
        assert_eq!(r.labels.len(), 10);
        assert!(r.inertia < 1e-9);
    }
}
