//! k-means baseline for Fig 10 (k-means++ init, Lloyd iterations) over
//! the contiguous `Matrix` row store.
//!
//! The assign pass (and the k-means++ seeding distance refresh) is
//! row-parallel through [`Engine`]; the update pass stays sequential so
//! centroid accumulation keeps one floating-point summation order.
//! Together with chunk-ordered tie-breaking in the empty-cluster reseed
//! scan, [`kmeans_with`] is **bit-identical** to the sequential
//! [`kmeans`] for any thread count (pinned by tests).

use crate::linalg::engine::Engine;
use crate::linalg::{add_assign, sq_dist, Matrix};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct KmeansResult {
    pub labels: Vec<i32>,
    /// k x width centroid matrix.
    pub centroids: Matrix,
    pub inertia: f64,
    pub iterations: usize,
}

/// Lloyd's algorithm with k-means++ seeding.
///
/// Convergence check runs *before* the update step: once an assign pass
/// changes nothing (after at least one update has made the centroids
/// actual means), the loop exits without the redundant extra update the
/// classic "break at loop end" formulation pays. Empty clusters reseed
/// at the point farthest from its assigned centroid, reusing the
/// distances computed during the assign pass instead of recomputing
/// `sq_dist` per candidate.
pub fn kmeans(
    rows: &Matrix,
    k: usize,
    max_iter: usize,
    rng: &mut Rng,
) -> KmeansResult {
    kmeans_with(Engine::sequential(), rows, k, max_iter, rng)
}

/// Engine-parallel [`kmeans`]: the assign pass fans its row loop out
/// over the engine's persistent worker pool. Per-row work has no
/// cross-row dependency, the update pass stays sequential, and the
/// empty-cluster reseed reduces chunk winners in chunk order with
/// `max_by`'s last-index tie-breaking, so labels, centroids, inertia,
/// and iteration count are bit-identical to the sequential path for any
/// thread count — and for any chunk alignment, which lets the scratch
/// loops use cache-line-aligned chunk boundaries (false sharing on the
/// `d2` / `assign` buffers bounded to at most the one line straddling
/// each boundary between adjacent workers).
pub fn kmeans_with(
    engine: Engine,
    rows: &Matrix,
    k: usize,
    max_iter: usize,
    rng: &mut Rng,
) -> KmeansResult {
    assert!(k >= 1);
    let n = rows.n_rows();
    assert!(n >= k, "need at least k rows");
    let w = rows.n_cols();
    // alignment only moves chunk boundaries, never what is computed
    // (the reseed reduction below is chunk-boundary-invariant)
    let d2_engine = engine.with_chunk_align(Engine::cache_align_for::<f64>(1));
    let assign_engine = engine.with_chunk_align(Engine::cache_align_for::<(i32, f64)>(1));

    // k-means++ init (same probe sequence as the classic formulation)
    let mut centroids = Matrix::zeros(k, w);
    let first = rng.range_usize(0, n);
    centroids.row_mut(0).copy_from_slice(rows.row(first));
    let mut d2: Vec<f64> =
        rows.iter_rows().map(|r| sq_dist(r, centroids.row(0))).collect();
    let mut seeded = 1;
    while seeded < k {
        let total: f64 = d2.iter().sum();
        let next = if total <= 1e-18 {
            // all points coincide with existing centroids: pick any
            rng.range_usize(0, n)
        } else {
            let mut target = rng.f64() * total;
            let mut pick = n - 1;
            for (i, &weight) in d2.iter().enumerate() {
                if target < weight {
                    pick = i;
                    break;
                }
                target -= weight;
            }
            pick
        };
        centroids.row_mut(seeded).copy_from_slice(rows.row(next));
        let seeded_row = centroids.row(seeded);
        d2_engine.for_rows(&mut d2, 1, |start, chunk| {
            for (off, dv) in chunk.iter_mut().enumerate() {
                let d = sq_dist(rows.row(start + off), seeded_row);
                if d < *dv {
                    *dv = d;
                }
            }
        });
        seeded += 1;
    }

    // per row: (assigned label, distance to its centroid). The distance
    // is an assign-pass byproduct that feeds inertia and empty-cluster
    // reseeding for free; fusing both into one buffer lets the parallel
    // assign write each row's results through a single chunked slice.
    let mut assign = vec![(0i32, 0.0f64); n];
    let mut sums = vec![0.0f64; k * w];
    let mut counts = vec![0usize; k];
    let mut iterations = 0;
    for it in 0..max_iter {
        iterations = it + 1;
        // assign (row-parallel; the per-chunk changed flags are
        // order-insensitive so any reduction order is fine)
        let changed = assign_engine
            .for_rows_map(&mut assign, 1, |start, chunk| {
                let mut changed = false;
                for (off, cell) in chunk.iter_mut().enumerate() {
                    let r = rows.row(start + off);
                    let mut best = 0usize;
                    let mut best_d = f64::INFINITY;
                    for c in 0..k {
                        let d = sq_dist(r, centroids.row(c));
                        if d < best_d {
                            best_d = d;
                            best = c;
                        }
                    }
                    cell.1 = best_d;
                    if cell.0 != best as i32 {
                        cell.0 = best as i32;
                        changed = true;
                    }
                }
                changed
            })
            .into_iter()
            .any(|c| c);
        // converged: centroids are already the means of this assignment
        // (it == 0 is excluded because the initial all-zero labels may
        // coincidentally match before any update has run)
        if !changed && it > 0 {
            break;
        }
        // update (sequential: keeps one summation order, so centroids
        // stay bit-identical to the single-threaded run)
        sums.fill(0.0);
        counts.fill(0);
        for (i, r) in rows.iter_rows().enumerate() {
            let c = assign[i].0 as usize;
            counts[c] += 1;
            add_assign(&mut sums[c * w..(c + 1) * w], r);
        }
        for c in 0..k {
            if counts[c] > 0 {
                let cnt = counts[c] as f64;
                for (dst, s) in centroids
                    .row_mut(c)
                    .iter_mut()
                    .zip(&sums[c * w..(c + 1) * w])
                {
                    *dst = s / cnt;
                }
            } else {
                // empty cluster: reseed at the farthest point, using the
                // assign-pass distances. `>=` in both the chunk-local
                // scan and the chunk-order reduction reproduces
                // `Iterator::max_by`'s last-maximum tie-breaking exactly.
                let far = assign_engine
                    .map_chunks(n, |range| {
                        let mut best_i = range.start;
                        let mut best_v = f64::NEG_INFINITY;
                        for i in range {
                            let v = assign[i].1;
                            if v >= best_v {
                                best_v = v;
                                best_i = i;
                            }
                        }
                        (best_i, best_v)
                    })
                    .into_iter()
                    .reduce(|a, b| if b.1 >= a.1 { b } else { a })
                    .map(|(i, _)| i)
                    .unwrap();
                centroids.row_mut(c).copy_from_slice(rows.row(far));
            }
        }
    }
    let labels: Vec<i32> = assign.iter().map(|a| a.0).collect();
    let inertia = rows
        .iter_rows()
        .zip(&labels)
        .map(|(r, &l)| sq_dist(r, centroids.row(l as usize)))
        .sum();
    KmeansResult { labels, centroids, inertia, iterations }
}

/// Pick k by the elbow criterion over a k-range: the smallest k whose
/// relative inertia improvement drops below `threshold`. This is how the
/// Fig 10 harness gives k-means a fair shot without the true class count.
pub fn kmeans_elbow(
    rows: &Matrix,
    k_max: usize,
    threshold: f64,
    max_iter: usize,
    rng: &mut Rng,
) -> KmeansResult {
    kmeans_elbow_with(Engine::sequential(), rows, k_max, threshold, max_iter, rng)
}

/// Engine-parallel [`kmeans_elbow`]: the k sweep itself stays sequential
/// (each step consumes the shared RNG stream and compares against the
/// previous inertia), but every inner [`kmeans_with`] fans its assign
/// passes out over the engine — same elbow decisions, same result,
/// multi-threaded inner loops.
pub fn kmeans_elbow_with(
    engine: Engine,
    rows: &Matrix,
    k_max: usize,
    threshold: f64,
    max_iter: usize,
    rng: &mut Rng,
) -> KmeansResult {
    assert!(k_max >= 1);
    let mut prev = kmeans_with(engine, rows, 1, max_iter, rng);
    for k in 2..=k_max.min(rows.n_rows()) {
        let cur = kmeans_with(engine, rows, k, max_iter, rng);
        let denom = prev.inertia.max(1e-12);
        let improve = (prev.inertia - cur.inertia) / denom;
        if improve < threshold {
            return prev;
        }
        prev = cur;
    }
    prev
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blobs(rng: &mut Rng, centers: &[(f64, f64)], n: usize, s: f64) -> Matrix {
        let mut rows = Matrix::with_width(2);
        for &(cx, cy) in centers {
            for _ in 0..n {
                rows.push_row(&[rng.normal_ms(cx, s), rng.normal_ms(cy, s)]);
            }
        }
        rows
    }

    #[test]
    fn recovers_three_blobs() {
        let mut rng = Rng::new(0);
        let rows = blobs(&mut rng, &[(0.0, 0.0), (10.0, 0.0), (0.0, 10.0)], 50, 0.5);
        let r = kmeans(&rows, 3, 100, &mut rng);
        // each ground-truth blob maps to exactly one cluster
        for g in 0..3 {
            let ls = &r.labels[g * 50..(g + 1) * 50];
            assert!(ls.iter().all(|&l| l == ls[0]), "blob {g} split");
        }
        assert!(r.inertia < 150.0 * 2.0);
    }

    #[test]
    fn k_one_centroid_is_mean() {
        let rows = Matrix::from_rows(&[vec![0.0], vec![2.0], vec![4.0]]);
        let mut rng = Rng::new(1);
        let r = kmeans(&rows, 1, 10, &mut rng);
        assert!((r.centroids.row(0)[0] - 2.0).abs() < 1e-9);
    }

    #[test]
    fn converged_init_stops_after_one_update() {
        // k=1: the initial all-zero labels already match; exactly one
        // update pass must run, then the next assign pass breaks
        let rows = Matrix::from_rows(&[vec![0.0], vec![2.0], vec![4.0]]);
        let mut rng = Rng::new(1);
        let r = kmeans(&rows, 1, 10, &mut rng);
        assert_eq!(r.iterations, 2, "expected assign+update then break");
    }

    #[test]
    fn elbow_finds_reasonable_k() {
        let mut rng = Rng::new(2);
        let rows = blobs(
            &mut rng,
            &[(0.0, 0.0), (12.0, 0.0), (0.0, 12.0), (12.0, 12.0)],
            40,
            0.5,
        );
        let r = kmeans_elbow(&rows, 8, 0.25, 100, &mut rng);
        let k = r.centroids.n_rows();
        assert!((3..=5).contains(&k), "k = {k}");
    }

    #[test]
    fn duplicate_points_dont_crash() {
        let rows = Matrix::from_rows(&vec![vec![1.0, 1.0]; 10]);
        let mut rng = Rng::new(3);
        let r = kmeans(&rows, 3, 10, &mut rng);
        assert_eq!(r.labels.len(), 10);
        assert!(r.inertia < 1e-9);
    }

    #[test]
    fn parallel_is_bit_identical_to_sequential() {
        for seed in 0..4u64 {
            let mut drng = Rng::new(seed);
            let mut rows = blobs(&mut drng, &[(0.0, 0.0), (6.0, 0.0), (0.0, 6.0)], 70, 0.6);
            // duplicate block: distance ties in assign and (when a
            // cluster empties) in the reseed argmax
            for _ in 0..80 {
                rows.push_row(&[3.0, 3.0]);
            }
            let mut ra = Rng::new(seed ^ 0x5eed);
            let a = kmeans(&rows, 4, 60, &mut ra);
            for threads in [2, 3, 8] {
                let engine = Engine::with_threads(threads).with_min_items(1);
                let mut rb = Rng::new(seed ^ 0x5eed);
                let b = kmeans_with(engine, &rows, 4, 60, &mut rb);
                assert_eq!(a.labels, b.labels, "threads {threads}");
                assert_eq!(a.centroids, b.centroids, "threads {threads}");
                assert_eq!(a.iterations, b.iterations);
                assert_eq!(a.inertia, b.inertia);
            }
        }
    }

    #[test]
    fn reseed_tie_break_matches_sequential_under_parallelism() {
        // 200 identical points with k=3: clusters empty on every update
        // and all reseed candidates tie at distance zero, so this pins
        // the chunk-order last-index tie-breaking of the parallel argmax
        let rows = Matrix::from_rows(&vec![vec![1.0, 2.0]; 200]);
        let mut ra = Rng::new(11);
        let a = kmeans(&rows, 3, 10, &mut ra);
        for threads in [2, 5] {
            let engine = Engine::with_threads(threads).with_min_items(1);
            let mut rb = Rng::new(11);
            let b = kmeans_with(engine, &rows, 3, 10, &mut rb);
            assert_eq!(a.labels, b.labels, "threads {threads}");
            assert_eq!(a.centroids, b.centroids, "threads {threads}");
        }
    }

    #[test]
    fn parallel_elbow_matches_sequential() {
        let mut drng = Rng::new(9);
        let rows = blobs(&mut drng, &[(0.0, 0.0), (12.0, 0.0), (0.0, 12.0)], 60, 0.5);
        let mut ra = Rng::new(21);
        let a = kmeans_elbow(&rows, 8, 0.25, 100, &mut ra);
        let engine = Engine::with_threads(4).with_min_items(1);
        let mut rb = Rng::new(21);
        let b = kmeans_elbow_with(engine, &rows, 8, 0.25, 100, &mut rb);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.centroids, b.centroids);
    }
}
