//! Agglomerative (hierarchical) clustering baseline for Fig 10:
//! average-linkage bottom-up merging with a distance cut-off, via the
//! Lance–Williams update on a dense distance matrix. O(n^3) worst case —
//! fine for discovery-batch sizes (hundreds of windows).

use super::DistanceProvider;
use crate::linalg::engine::Engine;
use crate::linalg::Matrix;

#[derive(Debug, Clone)]
pub struct AggloResult {
    pub labels: Vec<i32>,
    pub n_clusters: usize,
}

/// Average-linkage agglomerative clustering; merging stops when the
/// closest pair of clusters is farther than `cut_distance` apart.
pub fn agglomerative(
    rows: &Matrix,
    cut_distance: f64,
    dist: &dyn DistanceProvider,
) -> AggloResult {
    agglomerative_with(Engine::sequential(), rows, cut_distance, dist)
}

/// Engine-parallel [`agglomerative`]: each merge step's closest-pair
/// scan (the O(n²) inner loop of the O(n³) algorithm) fans out over the
/// engine's persistent worker pool — the per-merge dispatch is exactly
/// the many-small-calls pattern the pool amortises (a scoped spawn per
/// merge used to dominate small-n runs). Pass an
/// [`super::EngineDistance`] to also parallelise the initial
/// distance-matrix construction.
///
/// The scan is a *triangular* loop — row `i` visits `n-1-i` pairs — so
/// equal-count row chunks would give the first chunk ~2x its share of
/// the area. Work items are therefore the `i ↔ n-1-i` row *pairings*:
/// pairing `p` covers rows `p` and `n-1-p`, whose combined pair count
/// is a constant `n-2` (the middle row of an odd `n` stands alone), so
/// every chunk carries an equal share of the area and the speedup
/// tracks the thread count.
///
/// Balancing reorders the visit sequence, so winners can no longer rely
/// on first-encounter tie-breaking. Instead every comparison uses the
/// total order "smaller distance, then lexicographically smaller
/// `(i, j)`" — whose unique minimum is exactly the pair the sequential
/// row-major strict-`<` scan selects — keeping the merge sequence and
/// labels bit-identical for any thread count (pinned by the
/// equivalence test below).
pub fn agglomerative_with(
    engine: Engine,
    rows: &Matrix,
    cut_distance: f64,
    dist: &dyn DistanceProvider,
) -> AggloResult {
    let n = rows.n_rows();
    if n == 0 {
        return AggloResult { labels: vec![], n_clusters: 0 };
    }
    // working matrix of *distances* (not squared) between live clusters
    let sq = dist.pairwise_sq(rows);
    let mut d: Vec<f64> = sq.iter().map(|&x| x.sqrt()).collect();
    let mut alive: Vec<bool> = vec![true; n];
    let mut size: Vec<f64> = vec![1.0; n];
    // union-find style parent chain for final labelling
    let mut merged_into: Vec<usize> = (0..n).collect();

    // "is y a better closest-pair candidate than x": the total order
    // described in the doc comment (distance, then (i, j) lex) — its
    // minimum is the sequential scan's first strictly-smallest pair
    fn better(
        x: (usize, usize, f64),
        y: (usize, usize, f64),
    ) -> bool {
        y.2 < x.2 || (y.2 == x.2 && (y.0, y.1) < (x.0, x.1))
    }

    let mut live = n;
    let half = n.div_ceil(2);
    while live > 1 {
        // find closest live pair: area-balanced chunks over the i ↔
        // n-1-i row pairings (each pairing scans a constant n-2 pairs)
        let best = engine
            .map_chunks(half, |range| {
                let mut local = (usize::MAX, usize::MAX, f64::INFINITY);
                for p in range {
                    let lo = p;
                    let hi = n - 1 - p;
                    // odd-n middle row pairs with itself: scan it once
                    let pair = [lo, hi];
                    let rows: &[usize] =
                        if lo == hi { &pair[..1] } else { &pair };
                    for &i in rows {
                        if !alive[i] {
                            continue;
                        }
                        for j in (i + 1)..n {
                            if !alive[j] {
                                continue;
                            }
                            let dij = d[i * n + j];
                            if better(local, (i, j, dij)) {
                                local = (i, j, dij);
                            }
                        }
                    }
                }
                local
            })
            .into_iter()
            .reduce(|x, y| if better(x, y) { y } else { x })
            .unwrap();
        let (a, b, dab) = best;
        if dab > cut_distance {
            break;
        }
        // merge b into a; average linkage Lance-Williams:
        // d(a∪b, k) = (|a| d(a,k) + |b| d(b,k)) / (|a|+|b|)
        for k in 0..n {
            if !alive[k] || k == a || k == b {
                continue;
            }
            let dak = d[a * n + k];
            let dbk = d[b * n + k];
            let new = (size[a] * dak + size[b] * dbk) / (size[a] + size[b]);
            d[a * n + k] = new;
            d[k * n + a] = new;
        }
        size[a] += size[b];
        alive[b] = false;
        merged_into[b] = a;
        live -= 1;
    }

    // resolve roots and compact labels
    fn root(m: &[usize], mut i: usize) -> usize {
        while m[i] != i {
            i = m[i];
        }
        i
    }
    let mut label_of_root = std::collections::BTreeMap::new();
    let mut labels = vec![0i32; n];
    let mut next = 0i32;
    for i in 0..n {
        let r = root(&merged_into, i);
        let l = *label_of_root.entry(r).or_insert_with(|| {
            let l = next;
            next += 1;
            l
        });
        labels[i] = l;
    }
    AggloResult { labels, n_clusters: next as usize }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clustering::NativeDistance;
    use crate::util::rng::Rng;

    #[test]
    fn merges_tight_blobs_keeps_far_ones_apart() {
        let mut rng = Rng::new(0);
        let mut rows = Matrix::with_width(2);
        for &(cx, cy) in &[(0.0, 0.0), (20.0, 0.0), (0.0, 20.0)] {
            for _ in 0..20 {
                rows.push_row(&[rng.normal_ms(cx, 0.4), rng.normal_ms(cy, 0.4)]);
            }
        }
        let r = agglomerative(&rows, 6.0, &NativeDistance);
        assert_eq!(r.n_clusters, 3);
        for g in 0..3 {
            let ls = &r.labels[g * 20..(g + 1) * 20];
            assert!(ls.iter().all(|&l| l == ls[0]));
        }
    }

    #[test]
    fn cut_zero_keeps_singletons() {
        let rows = Matrix::from_rows(&[vec![0.0], vec![1.0], vec![2.0]]);
        let r = agglomerative(&rows, 0.5, &NativeDistance);
        assert_eq!(r.n_clusters, 3);
    }

    #[test]
    fn cut_infinite_merges_all() {
        let rows = Matrix::from_rows(&[vec![0.0], vec![100.0], vec![200.0]]);
        let r = agglomerative(&rows, f64::INFINITY, &NativeDistance);
        assert_eq!(r.n_clusters, 1);
    }

    #[test]
    fn empty_input() {
        let r = agglomerative(&Matrix::new(), 1.0, &NativeDistance);
        assert_eq!(r.n_clusters, 0);
    }

    #[test]
    fn balanced_scan_identical_on_odd_counts_and_duplicate_ties() {
        use crate::clustering::EngineDistance;
        // odd row count exercises the self-paired middle row; duplicate
        // rows create exact distance ties, exercising the (i, j) lex
        // tie-break that replaces first-encounter order
        let mut rng = Rng::new(9);
        let mut rows = Matrix::with_width(3);
        for i in 0..77 {
            if i % 5 == 0 {
                rows.push_row(&[1.0, 2.0, 3.0]); // exact duplicates
            } else {
                let c = (i % 3) as f64 * 12.0;
                rows.push_row(&[
                    rng.normal_ms(c, 0.3),
                    rng.normal_ms(c, 0.3),
                    rng.normal_ms(-c, 0.3),
                ]);
            }
        }
        let a = agglomerative(&rows, 5.0, &NativeDistance);
        for threads in [2, 3, 8] {
            let engine = Engine::with_threads(threads).with_min_items(1);
            let b = agglomerative_with(
                engine,
                &rows,
                5.0,
                &EngineDistance::new(engine),
            );
            assert_eq!(a.labels, b.labels, "threads {threads}");
            assert_eq!(a.n_clusters, b.n_clusters);
        }
    }

    #[test]
    fn parallel_labels_bit_identical_to_sequential() {
        use crate::clustering::EngineDistance;
        let mut rng = Rng::new(4);
        let mut rows = Matrix::with_width(2);
        for &(cx, cy) in &[(0.0, 0.0), (15.0, 0.0), (0.0, 15.0)] {
            for _ in 0..30 {
                rows.push_row(&[rng.normal_ms(cx, 0.5), rng.normal_ms(cy, 0.5)]);
            }
        }
        let a = agglomerative(&rows, 6.0, &NativeDistance);
        for threads in [2, 4] {
            let engine = Engine::with_threads(threads).with_min_items(1);
            let b = agglomerative_with(engine, &rows, 6.0, &EngineDistance::new(engine));
            assert_eq!(a.labels, b.labels, "threads {threads}");
            assert_eq!(a.n_clusters, b.n_clusters);
        }
    }
}
