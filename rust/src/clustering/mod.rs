//! Clustering for workload discovery (paper §7.1, Algorithm 2, Fig 10).
//!
//! DBSCAN is KERMIT's discovery algorithm; k-means and agglomerative are
//! the Fig 10 baselines. All of them operate on the contiguous
//! [`Matrix`] row store (`linalg`), and the distance matrix is computed
//! through a pluggable provider so the off-line pipeline can route it
//! through the `pairwise_dist` PJRT artifact (L1 pallas kernel) while
//! unit tests use the native implementation.

pub mod agglomerative;
pub mod dbscan;
pub mod kmeans;
pub mod metrics;

use crate::linalg::{sq_dist, Matrix};

pub use dbscan::{dbscan, DbscanConfig, DbscanResult, NOISE};
pub use metrics::{awt, purity};

/// Pluggable pairwise squared-distance provider. `rows` is the feature
/// matrix (one observation per row); the result is the dense n x n
/// matrix (row-major).
pub trait DistanceProvider {
    fn pairwise_sq(&self, rows: &Matrix) -> Vec<f64>;
}

/// Native O(n^2 d) implementation over contiguous rows.
pub struct NativeDistance;

impl DistanceProvider for NativeDistance {
    fn pairwise_sq(&self, rows: &Matrix) -> Vec<f64> {
        let n = rows.n_rows();
        let mut out = vec![0.0; n * n];
        for i in 0..n {
            let ri = rows.row(i);
            for j in (i + 1)..n {
                let d = sq_dist(ri, rows.row(j));
                out[i * n + j] = d;
                out[j * n + i] = d;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_distance_symmetric_zero_diag() {
        let rows = Matrix::from_rows(&[
            vec![0.0, 0.0],
            vec![3.0, 4.0],
            vec![1.0, 1.0],
        ]);
        let d = NativeDistance.pairwise_sq(&rows);
        assert_eq!(d[0], 0.0);
        assert_eq!(d[4], 0.0);
        assert_eq!(d[8], 0.0);
        assert!((d[1] - 25.0).abs() < 1e-12);
        assert_eq!(d[1], d[3]);
        assert!((d[2] - 2.0).abs() < 1e-12);
    }
}
