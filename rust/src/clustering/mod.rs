//! Clustering for workload discovery (paper §7.1, Algorithm 2, Fig 10).
//!
//! DBSCAN is KERMIT's discovery algorithm; k-means and agglomerative are
//! the Fig 10 baselines. All of them operate on the contiguous
//! [`Matrix`] row store (`linalg`), and the distance matrix is computed
//! through a pluggable provider so the off-line pipeline can route it
//! through the `pairwise_dist` PJRT artifact (L1 pallas kernel) while
//! unit tests use the native implementation.

pub mod agglomerative;
pub mod dbscan;
pub mod kmeans;
pub mod metrics;

use crate::linalg::engine::Engine;
use crate::linalg::{sq_dist, Matrix};

pub use dbscan::{dbscan, dbscan_with, DbscanConfig, DbscanResult, NOISE};
pub use metrics::{awt, purity};

/// Pluggable pairwise squared-distance provider. `rows` is the feature
/// matrix (one observation per row); the result is the dense n x n
/// matrix (row-major).
pub trait DistanceProvider {
    fn pairwise_sq(&self, rows: &Matrix) -> Vec<f64>;
}

/// Native O(n^2 d) implementation over contiguous rows.
pub struct NativeDistance;

impl DistanceProvider for NativeDistance {
    fn pairwise_sq(&self, rows: &Matrix) -> Vec<f64> {
        pairwise_sq_with(Engine::sequential(), rows)
    }
}

/// Engine-parallel native provider: same distances as [`NativeDistance`]
/// bit-for-bit, with the O(n^2 d) matrix construction row-chunked across
/// the engine's worker pool. The coordinator's "artifact if available"
/// constructor falls back to this when the PJRT `pairwise_dist` kernel
/// is not loadable (see `runtime::nn::distance_provider`).
pub struct EngineDistance {
    pub engine: Engine,
}

impl EngineDistance {
    pub fn new(engine: Engine) -> EngineDistance {
        EngineDistance { engine }
    }
}

impl DistanceProvider for EngineDistance {
    fn pairwise_sq(&self, rows: &Matrix) -> Vec<f64> {
        pairwise_sq_with(self.engine, rows)
    }
}

/// Dense pairwise squared-distance matrix, row-parallel over `engine`.
///
/// The sequential path computes the upper triangle and mirrors it. The
/// parallel path computes full rows instead (each worker owns a disjoint
/// band of output rows, so no mirror write crosses a chunk boundary);
/// that doubles the kernel invocations but removes all write sharing,
/// and because `sq_dist(a, b)` is bitwise-symmetric — in every SIMD
/// tier, including the FMA ones — the two paths produce identical
/// matrices. Chunk boundaries are rounded to cache-line-sized
/// multiples of the n-wide output rows, bounding cross-worker sharing
/// to at most the one line straddling each boundary.
pub fn pairwise_sq_with(engine: Engine, rows: &Matrix) -> Vec<f64> {
    let n = rows.n_rows();
    let engine = engine.with_chunk_align(Engine::cache_align_for::<f64>(n));
    if !engine.is_parallel_for(n) {
        let mut out = vec![0.0; n * n];
        for i in 0..n {
            let ri = rows.row(i);
            for j in (i + 1)..n {
                let d = sq_dist(ri, rows.row(j));
                out[i * n + j] = d;
                out[j * n + i] = d;
            }
        }
        return out;
    }
    let mut out = vec![0.0; n * n];
    engine.for_rows(&mut out, n, |first_row, chunk| {
        for (off, orow) in chunk.chunks_mut(n).enumerate() {
            let i = first_row + off;
            let ri = rows.row(i);
            for (j, cell) in orow.iter_mut().enumerate() {
                if i != j {
                    *cell = sq_dist(ri, rows.row(j));
                }
            }
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_distance_symmetric_zero_diag() {
        let rows = Matrix::from_rows(&[
            vec![0.0, 0.0],
            vec![3.0, 4.0],
            vec![1.0, 1.0],
        ]);
        let d = NativeDistance.pairwise_sq(&rows);
        assert_eq!(d[0], 0.0);
        assert_eq!(d[4], 0.0);
        assert_eq!(d[8], 0.0);
        assert!((d[1] - 25.0).abs() < 1e-12);
        assert_eq!(d[1], d[3]);
        assert!((d[2] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn engine_distance_bit_identical_to_native() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0);
        let mut rows = Matrix::with_width(5);
        for _ in 0..130 {
            let r: Vec<f64> =
                (0..5).map(|_| rng.range_f64(-10.0, 10.0)).collect();
            rows.push_row(&r);
        }
        let want = NativeDistance.pairwise_sq(&rows);
        for threads in [2, 4] {
            let engine = Engine::with_threads(threads).with_min_items(1);
            let got = EngineDistance::new(engine).pairwise_sq(&rows);
            assert_eq!(got, want, "threads {threads}");
        }
    }
}
