//! Exposition: Prometheus text format, a deterministic JSON snapshot,
//! and a strict parser used by the CI smoke to validate what we render.

use std::collections::BTreeMap;

use crate::util::json::Json;

use super::registry::{MetricKind, Registry, SeriesValue};

/// Format a sample value the way Prometheus text format expects:
/// integers without a decimal point, everything else via shortest
/// round-trip `Display`, and the special values spelled `+Inf`,
/// `-Inf`, `NaN`.
fn fmt_value(v: f64) -> String {
    if v.is_nan() {
        return "NaN".to_string();
    }
    if v.is_infinite() {
        return if v > 0.0 { "+Inf" } else { "-Inf" }.to_string();
    }
    if v == v.trunc() && v.abs() < 1e15 {
        return format!("{}", v as i64);
    }
    format!("{v}")
}

/// Escape a label value: backslash, double quote, newline.
fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Escape a HELP text: backslash and newline (quotes are fine there).
fn escape_help(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

fn label_block(labels: &[(String, String)], extra: Option<(&str, &str)>) -> String {
    if labels.is_empty() && extra.is_none() {
        return String::new();
    }
    let mut parts: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape_label(v)))
        .collect();
    if let Some((k, v)) = extra {
        parts.push(format!("{k}=\"{}\"", escape_label(v)));
    }
    format!("{{{}}}", parts.join(","))
}

/// Render the registry in Prometheus text exposition format. Families
/// appear in name order, series in sorted-label order, so the output
/// is byte-for-byte deterministic for a given registry state.
pub fn render_prometheus(reg: &Registry) -> String {
    let mut out = String::new();
    for fam in reg.gather() {
        out.push_str(&format!(
            "# HELP {} {}\n",
            fam.name,
            escape_help(&fam.help)
        ));
        out.push_str(&format!("# TYPE {} {}\n", fam.name, fam.kind.as_str()));
        for (labels, value) in &fam.series {
            match value {
                SeriesValue::Counter(v) => {
                    out.push_str(&format!(
                        "{}{} {}\n",
                        fam.name,
                        label_block(labels, None),
                        fmt_value(*v as f64)
                    ));
                }
                SeriesValue::Gauge(v) => {
                    out.push_str(&format!(
                        "{}{} {}\n",
                        fam.name,
                        label_block(labels, None),
                        fmt_value(*v)
                    ));
                }
                SeriesValue::Histogram {
                    bounds,
                    buckets,
                    count,
                    sum,
                } => {
                    for (b, cum) in bounds.iter().zip(buckets.iter()) {
                        out.push_str(&format!(
                            "{}_bucket{} {}\n",
                            fam.name,
                            label_block(labels, Some(("le", &fmt_value(*b)))),
                            cum
                        ));
                    }
                    out.push_str(&format!(
                        "{}_bucket{} {}\n",
                        fam.name,
                        label_block(labels, Some(("le", "+Inf"))),
                        count
                    ));
                    out.push_str(&format!(
                        "{}_sum{} {}\n",
                        fam.name,
                        label_block(labels, None),
                        fmt_value(*sum)
                    ));
                    out.push_str(&format!(
                        "{}_count{} {}\n",
                        fam.name,
                        label_block(labels, None),
                        count
                    ));
                }
            }
        }
    }
    out
}

/// Deterministic JSON snapshot of the registry, for test pinning and
/// the `OBS_snapshot.json` CI artifact. Families and series keep the
/// registry's canonical order (the codec sorts object keys anyway).
pub fn snapshot_json(reg: &Registry) -> Json {
    let mut root = Json::obj();
    for fam in reg.gather() {
        let mut f = Json::obj();
        f.set("kind", Json::Str(fam.kind.as_str().to_string()))
            .set("help", Json::Str(fam.help.clone()));
        let series = fam
            .series
            .iter()
            .map(|(labels, value)| {
                let mut s = Json::obj();
                let mut lj = Json::obj();
                for (k, v) in labels {
                    lj.set(k, Json::Str(v.clone()));
                }
                s.set("labels", lj);
                match value {
                    SeriesValue::Counter(v) => {
                        s.set("value", Json::Num(*v as f64));
                    }
                    SeriesValue::Gauge(v) => {
                        s.set("value", Json::Num(*v));
                    }
                    SeriesValue::Histogram {
                        bounds,
                        buckets,
                        count,
                        sum,
                    } => {
                        let bs = bounds
                            .iter()
                            .zip(buckets.iter())
                            .map(|(b, c)| {
                                let mut e = Json::obj();
                                e.set("le", Json::Num(*b))
                                    .set("n", Json::Num(*c as f64));
                                e
                            })
                            .collect();
                        s.set("buckets", Json::Arr(bs))
                            .set("count", Json::Num(*count as f64))
                            .set("sum", Json::Num(*sum));
                    }
                }
                s
            })
            .collect();
        f.set("series", Json::Arr(series));
        root.set(&fam.name, f);
    }
    root
}

/// One family as seen by the strict parser.
#[derive(Debug, Clone)]
pub struct ParsedFamily {
    pub name: String,
    pub kind: String,
    pub samples: usize,
}

fn valid_metric_name(s: &str) -> bool {
    !s.is_empty()
        && s.chars().next().map_or(false, |c| {
            c.is_ascii_alphabetic() || c == '_' || c == ':'
        })
        && s.chars()
            .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

fn valid_label_name(s: &str) -> bool {
    !s.is_empty()
        && s.chars()
            .next()
            .map_or(false, |c| c.is_ascii_alphabetic() || c == '_')
        && s.chars().all(|c| c.is_ascii_alphanumeric() || c == '_')
}

fn parse_sample_value(s: &str) -> Result<f64, String> {
    match s {
        "+Inf" => Ok(f64::INFINITY),
        "-Inf" => Ok(f64::NEG_INFINITY),
        "NaN" => Ok(f64::NAN),
        other => other
            .parse::<f64>()
            .map_err(|_| format!("bad sample value {other:?}")),
    }
}

/// Split `name{labels} value` into parts, validating label syntax and
/// unescaping values. Returns (metric_name, labels, value).
fn parse_sample_line(
    line: &str,
) -> Result<(String, Vec<(String, String)>, f64), String> {
    let (name_part, rest) = match line.find('{') {
        Some(i) => {
            let close = line
                .rfind('}')
                .ok_or_else(|| format!("unclosed label block: {line:?}"))?;
            if close < i {
                return Err(format!("malformed label block: {line:?}"));
            }
            (&line[..i], Some((&line[i + 1..close], &line[close + 1..])))
        }
        None => ("", None),
    };
    let (name, labels, value_part) = match rest {
        Some((label_src, tail)) => {
            let mut labels = Vec::new();
            let mut src = label_src;
            while !src.is_empty() {
                let eq = src
                    .find('=')
                    .ok_or_else(|| format!("label missing '=': {src:?}"))?;
                let key = &src[..eq];
                if !valid_label_name(key) {
                    return Err(format!("bad label name {key:?}"));
                }
                let after = &src[eq + 1..];
                if !after.starts_with('"') {
                    return Err(format!("label value not quoted: {src:?}"));
                }
                // walk the quoted value honoring escapes
                let mut val = String::new();
                let mut chars = after[1..].char_indices();
                let mut end = None;
                while let Some((i, c)) = chars.next() {
                    match c {
                        '\\' => match chars.next() {
                            Some((_, 'n')) => val.push('\n'),
                            Some((_, '\\')) => val.push('\\'),
                            Some((_, '"')) => val.push('"'),
                            other => {
                                return Err(format!(
                                    "bad escape {other:?} in label value"
                                ))
                            }
                        },
                        '"' => {
                            end = Some(i);
                            break;
                        }
                        c => val.push(c),
                    }
                }
                let end = end
                    .ok_or_else(|| format!("unterminated label value: {src:?}"))?;
                labels.push((key.to_string(), val));
                src = &after[1 + end + 1..];
                if let Some(stripped) = src.strip_prefix(',') {
                    src = stripped;
                } else if !src.is_empty() {
                    return Err(format!("junk after label value: {src:?}"));
                }
            }
            (name_part.to_string(), labels, tail.trim())
        }
        None => {
            let mut it = line.splitn(2, ' ');
            let name = it.next().unwrap_or("").to_string();
            let tail = it.next().unwrap_or("").trim();
            (name, Vec::new(), tail)
        }
    };
    if !valid_metric_name(&name) {
        return Err(format!("bad metric name {name:?}"));
    }
    let value = parse_sample_value(value_part)?;
    Ok((name, labels, value))
}

/// Strict parser over Prometheus text exposition. Beyond syntax, it
/// enforces what the renderer promises: a TYPE line precedes every
/// sample of its family, no sample belongs to an undeclared family,
/// no duplicate series, histogram buckets are cumulative and end with
/// an `+Inf` bucket equal to `_count`. Used by the CI smoke to keep
/// the renderer honest.
pub fn parse_prometheus(text: &str) -> Result<Vec<ParsedFamily>, String> {
    struct FamState {
        kind: String,
        samples: usize,
        // histogram per-series accounting keyed by non-le labels
        hist: BTreeMap<String, HistState>,
    }
    #[derive(Default)]
    struct HistState {
        last_le: Option<f64>,
        last_cum: Option<f64>,
        saw_inf: bool,
        inf_value: f64,
        count: Option<f64>,
        saw_sum: bool,
    }

    let mut fams: BTreeMap<String, FamState> = BTreeMap::new();
    let mut order: Vec<String> = Vec::new();
    let mut seen_series: BTreeMap<String, ()> = BTreeMap::new();

    let owner_of = |name: &str, fams: &BTreeMap<String, FamState>| -> Option<String> {
        if fams.contains_key(name) {
            return Some(name.to_string());
        }
        for suffix in ["_bucket", "_sum", "_count"] {
            if let Some(base) = name.strip_suffix(suffix) {
                if let Some(f) = fams.get(base) {
                    if f.kind == "histogram" {
                        return Some(base.to_string());
                    }
                }
            }
        }
        None
    };

    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim_end();
        if line.is_empty() {
            continue;
        }
        let err = |msg: String| format!("line {}: {}", lineno + 1, msg);
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.splitn(2, ' ');
            let name = it.next().unwrap_or("");
            let kind = it.next().unwrap_or("").trim();
            if !valid_metric_name(name) {
                return Err(err(format!("bad metric name {name:?}")));
            }
            if !matches!(kind, "counter" | "gauge" | "histogram") {
                return Err(err(format!("bad TYPE {kind:?}")));
            }
            if fams.contains_key(name) {
                return Err(err(format!("duplicate TYPE for {name}")));
            }
            fams.insert(
                name.to_string(),
                FamState {
                    kind: kind.to_string(),
                    samples: 0,
                    hist: BTreeMap::new(),
                },
            );
            order.push(name.to_string());
            continue;
        }
        if line.starts_with('#') {
            continue; // HELP and comments
        }
        let (name, labels, value) =
            parse_sample_line(line).map_err(err)?;
        let owner = owner_of(&name, &fams).ok_or_else(|| {
            err(format!("sample {name} has no preceding TYPE"))
        })?;
        let series_key = format!("{name}|{labels:?}");
        if seen_series.insert(series_key, ()).is_some() {
            return Err(err(format!("duplicate series for {name}")));
        }
        let fam = fams.get_mut(&owner).expect("owner resolved above");
        fam.samples += 1;
        if fam.kind == "histogram" {
            let base_labels: Vec<&(String, String)> =
                labels.iter().filter(|(k, _)| k != "le").collect();
            let hist_key = format!("{base_labels:?}");
            let st = fam.hist.entry(hist_key).or_default();
            if name.ends_with("_bucket") {
                let le = labels
                    .iter()
                    .find(|(k, _)| k == "le")
                    .map(|(_, v)| v.as_str())
                    .ok_or_else(|| err("bucket without le".to_string()))?;
                let le = parse_sample_value(le).map_err(err)?;
                if le.is_infinite() {
                    st.saw_inf = true;
                    st.inf_value = value;
                } else if st.saw_inf {
                    return Err(err("bucket after +Inf".to_string()));
                }
                if let Some(prev) = st.last_le {
                    if le <= prev {
                        return Err(err("le not increasing".to_string()));
                    }
                }
                if let Some(prev) = st.last_cum {
                    if value < prev {
                        return Err(err(
                            "bucket counts not cumulative".to_string()
                        ));
                    }
                }
                st.last_le = Some(le);
                st.last_cum = Some(value);
            } else if name.ends_with("_count") {
                st.count = Some(value);
            } else if name.ends_with("_sum") {
                st.saw_sum = true;
            } else {
                return Err(err(format!(
                    "bare sample {name} on histogram family"
                )));
            }
        }
    }

    for (name, fam) in &fams {
        if fam.kind == "histogram" {
            for st in fam.hist.values() {
                if !st.saw_inf {
                    return Err(format!("{name}: missing +Inf bucket"));
                }
                if !st.saw_sum {
                    return Err(format!("{name}: missing _sum"));
                }
                match st.count {
                    Some(c) if c == st.inf_value => {}
                    Some(_) => {
                        return Err(format!(
                            "{name}: +Inf bucket != _count"
                        ))
                    }
                    None => return Err(format!("{name}: missing _count")),
                }
            }
        }
    }

    Ok(order
        .into_iter()
        .map(|name| {
            let fam = &fams[&name];
            ParsedFamily {
                name: name.clone(),
                kind: fam.kind.clone(),
                samples: fam.samples,
            }
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_registry() -> Registry {
        let reg = Registry::new();
        reg.counter("kermit_demo_requests_total", "Requests served.", &[("tenant", "0")])
            .add(3);
        reg.counter("kermit_demo_requests_total", "Requests served.", &[("tenant", "1")])
            .add(5);
        reg.gauge("kermit_demo_pending", "Pending items.", &[]).set(2.5);
        let h = reg.histogram(
            "kermit_demo_latency_seconds",
            "Latency.",
            &[],
            &[1.0, 5.0, 25.0],
        );
        h.observe(0.5);
        h.observe(3.0);
        h.observe(50.0);
        reg
    }

    #[test]
    fn render_is_deterministic_and_ordered() {
        let a = render_prometheus(&demo_registry());
        let b = render_prometheus(&demo_registry());
        assert_eq!(a, b);
        let pending = a.find("kermit_demo_pending").unwrap();
        let latency = a.find("kermit_demo_latency_seconds").unwrap();
        let requests = a.find("kermit_demo_requests_total").unwrap();
        assert!(latency < pending && pending < requests);
    }

    #[test]
    fn label_values_are_escaped() {
        let reg = Registry::new();
        reg.counter("kermit_esc_total", "e", &[("path", "a\"b\\c\nd")])
            .inc();
        let text = render_prometheus(&reg);
        assert!(text.contains("path=\"a\\\"b\\\\c\\nd\""), "{text}");
        // and the strict parser round-trips it
        parse_prometheus(&text).expect("escaped output parses");
    }

    #[test]
    fn parser_accepts_renderer_output() {
        let text = render_prometheus(&demo_registry());
        let fams = parse_prometheus(&text).expect("valid exposition");
        assert_eq!(fams.len(), 3);
        let hist = fams.iter().find(|f| f.kind == "histogram").unwrap();
        assert_eq!(hist.name, "kermit_demo_latency_seconds");
        // 4 buckets + sum + count
        assert_eq!(hist.samples, 6);
    }

    #[test]
    fn parser_rejects_non_cumulative_buckets() {
        let bad = "# TYPE h histogram\n\
                   h_bucket{le=\"1\"} 5\n\
                   h_bucket{le=\"2\"} 3\n\
                   h_bucket{le=\"+Inf\"} 5\n\
                   h_sum 1\n\
                   h_count 5\n";
        assert!(parse_prometheus(bad)
            .unwrap_err()
            .contains("cumulative"));
    }

    #[test]
    fn parser_rejects_samples_without_type() {
        assert!(parse_prometheus("orphan_total 3\n")
            .unwrap_err()
            .contains("no preceding TYPE"));
    }

    #[test]
    fn parser_rejects_inf_count_mismatch() {
        let bad = "# TYPE h histogram\n\
                   h_bucket{le=\"+Inf\"} 4\n\
                   h_sum 1\n\
                   h_count 5\n";
        assert!(parse_prometheus(bad).unwrap_err().contains("+Inf"));
    }

    #[test]
    fn snapshot_json_is_deterministic() {
        let a = snapshot_json(&demo_registry()).encode_pretty();
        let b = snapshot_json(&demo_registry()).encode_pretty();
        assert_eq!(a, b);
        assert!(a.contains("kermit_demo_requests_total"));
    }
}
