//! The telemetry plane: the loop that monitors workloads finally
//! monitors *itself*.
//!
//! KERMIT's MAPE-K loop produces plenty of numbers — `PoolStats`,
//! `PluginStats`, `TenantIngestStats`, `MultiTenantReport` — but until
//! this module they were report-scoped: polled ad hoc at run end and
//! invisible between reports, so UNKNOWN-rate spikes, abandoned-search
//! storms and executor queue buildup were only discoverable after the
//! fact. The `obs` plane closes that gap with four std-only pieces:
//!
//! * [`registry`] — a lock-light metrics registry (`Counter` / `Gauge`
//!   / `Histogram` on atomics). Handles are registered once and held;
//!   a hot-path increment is a single relaxed atomic op. Label sets
//!   are sorted, families live in a `BTreeMap`, so every export is
//!   deterministic.
//! * [`expo`] — Prometheus text exposition ([`render_prometheus`]),
//!   a deterministic JSON snapshot for test pinning, and a *strict*
//!   parser ([`parse_prometheus`]) the CI smoke validates the
//!   exposition with.
//! * [`alerts`] — threshold / rate-of-change rules evaluated on a
//!   cadence over registry samples, producing deterministic
//!   [`AlertEvent`]s the chaos scenarios assert on (fire while
//!   faulted, clear after heal).
//! * [`trace`] — ring-buffered spans for the decide → probe → measure
//!   → persist path per tenant, exportable as JSON timelines.
//!
//! Instrumentation follows two idioms, both driven by the layer that
//! owns the numbers:
//!
//! 1. **direct handles** where the hot path is concurrent — the
//!    [`ObserveMetrics`] counters the stream router installs on every
//!    pipeline shard (incremented from pool workers during a fanned-out
//!    tick);
//! 2. **scrape exporters** (`export_metrics` methods on the owning
//!    stats types, orchestrated by `TuningPlane::scrape`) where
//!    counters already exist — bridged into the registry as monotone
//!    totals on every scrape.
//!
//! Telemetry must never change results: every hook is `Option`-gated
//! and the parallel==sequential equivalence suites run with and
//! without it unchanged.

pub mod alerts;
pub mod expo;
pub mod registry;
pub mod trace;

pub use alerts::{
    chaos_rules, standard_rules, AlertEngine, AlertEvent, AlertRule,
    AlertState, RuleExpr,
};
pub use expo::{parse_prometheus, render_prometheus, snapshot_json};
pub use registry::{
    Counter, Gauge, Histogram, MetricKind, Registry, SeriesValue,
};
pub use trace::{DecisionTrace, TraceSpan};

use registry::Registry as Reg;

/// The one NaN-safe ratio helper every layer shares (`cache_hit_ratio`,
/// `known_fraction`, tail-hit ratios, alert-rule delta ratios): returns
/// `num / den` when the denominator is positive and both sides are
/// finite, `0.0` otherwise — never NaN, never ±Inf.
pub fn ratio(num: f64, den: f64) -> f64 {
    if num.is_finite() && den.is_finite() && den > 0.0 {
        num / den
    } else {
        0.0
    }
}

/// Static-registration handles for the on-line observe hot path: one
/// set per pipeline shard, registered once when telemetry is enabled,
/// then incremented with single relaxed atomic ops from whichever pool
/// worker drains the shard that tick.
#[derive(Clone)]
pub struct ObserveMetrics {
    /// Windows observed (`kermit_stream_windows_observed_total`).
    pub windows: Counter,
    /// Windows published as UNKNOWN
    /// (`kermit_stream_unknown_windows_total`).
    pub unknown: Counter,
    /// Windows the change detector flagged as transitions
    /// (`kermit_stream_transition_windows_total`).
    pub transitions: Counter,
}

impl ObserveMetrics {
    /// Register the observe-path counters for one tenant.
    pub fn register(reg: &Reg, tenant: &str) -> ObserveMetrics {
        let labels = [("tenant", tenant)];
        ObserveMetrics {
            windows: reg.counter(
                "kermit_stream_windows_observed_total",
                "Observation windows the on-line pipeline observed.",
                &labels,
            ),
            unknown: reg.counter(
                "kermit_stream_unknown_windows_total",
                "Observed windows published with the UNKNOWN label.",
                &labels,
            ),
            transitions: reg.counter(
                "kermit_stream_transition_windows_total",
                "Observed windows the change detector flagged as \
                 transitions.",
                &labels,
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratio_is_nan_safe() {
        assert_eq!(ratio(1.0, 2.0), 0.5);
        assert_eq!(ratio(0.0, 2.0), 0.0);
        assert_eq!(ratio(1.0, 0.0), 0.0);
        assert_eq!(ratio(1.0, -3.0), 0.0);
        assert_eq!(ratio(f64::NAN, 2.0), 0.0);
        assert_eq!(ratio(1.0, f64::NAN), 0.0);
        assert_eq!(ratio(f64::INFINITY, 2.0), 0.0);
        assert_eq!(ratio(1.0, f64::INFINITY), 0.0);
    }

    #[test]
    fn observe_metrics_register_per_tenant_series() {
        let reg = Registry::new();
        let m0 = ObserveMetrics::register(&reg, "0");
        let m1 = ObserveMetrics::register(&reg, "1");
        m0.windows.inc();
        m0.windows.inc();
        m1.windows.inc();
        m0.unknown.inc();
        assert_eq!(
            reg.total("kermit_stream_windows_observed_total"),
            Some(3.0)
        );
        assert_eq!(
            reg.total("kermit_stream_unknown_windows_total"),
            Some(1.0)
        );
        // re-registering the same tenant returns the same cell
        let again = ObserveMetrics::register(&reg, "0");
        again.windows.inc();
        assert_eq!(
            reg.total("kermit_stream_windows_observed_total"),
            Some(4.0)
        );
    }
}
