//! Decision tracing: ring-buffered spans for the decide → probe →
//! measure → persist path, per tenant.
//!
//! The tuning plane opens a span when it makes a decision for an app
//! (kind + label + sim time), closes it when the measurement lands
//! (`measured`), dies (`failed`) or times out (`timed_out`), and
//! appends persist notes when the knowledge plane flushes. Each tenant
//! gets a bounded ring, so a long-running plane keeps the most recent
//! `cap` spans per tenant and the memory bill stays flat.
//!
//! [`DecisionTrace::timeline_json`] exports the rings as deterministic
//! JSON timelines — the per-tenant dashboard of label transitions,
//! cache hits and probe spend.

use std::collections::BTreeMap;
use std::collections::VecDeque;

use crate::util::json::Json;

/// One decide→outcome span.
#[derive(Debug, Clone)]
pub struct TraceSpan {
    pub tenant: u32,
    pub app_id: u64,
    /// Sim time the decision was made.
    pub decided_at: f64,
    /// Decision kind (`default` / `cache_hit` / `global_probe` /
    /// `local_probe` / `degraded`).
    pub kind: String,
    /// Workload label the decision was made under.
    pub label: String,
    /// Sim time the span closed; `None` while in flight.
    pub closed_at: Option<f64>,
    /// `measured` / `failed` / `timed_out`; `None` while in flight.
    pub outcome: Option<String>,
    /// Measured duration, when one landed.
    pub measured: Option<f64>,
}

/// Persist-side note (WAL flush, snapshot rotation), global to the
/// plane rather than per tenant.
#[derive(Debug, Clone)]
pub struct PersistNote {
    pub at: f64,
    pub kind: String,
    pub records: u64,
}

/// Per-tenant span rings plus a persist-note ring.
pub struct DecisionTrace {
    cap: usize,
    tenants: BTreeMap<u32, VecDeque<TraceSpan>>,
    persist: VecDeque<PersistNote>,
}

impl DecisionTrace {
    /// `cap` bounds spans kept per tenant (and persist notes kept
    /// overall); clamped to at least 1.
    pub fn new(cap: usize) -> DecisionTrace {
        DecisionTrace {
            cap: cap.max(1),
            tenants: BTreeMap::new(),
            persist: VecDeque::new(),
        }
    }

    /// Open a span for `(tenant, app_id)`. If the ring is full the
    /// oldest span falls off.
    pub fn open(
        &mut self,
        tenant: u32,
        app_id: u64,
        at: f64,
        kind: &str,
        label: &str,
    ) {
        let ring = self.tenants.entry(tenant).or_default();
        if ring.len() == self.cap {
            ring.pop_front();
        }
        ring.push_back(TraceSpan {
            tenant,
            app_id,
            decided_at: at,
            kind: kind.to_string(),
            label: label.to_string(),
            closed_at: None,
            outcome: None,
            measured: None,
        });
    }

    /// Close the most recent open span for `(tenant, app_id)`. Spans
    /// that already fell off the ring close silently — tracing never
    /// errors into the decision path.
    pub fn close(
        &mut self,
        tenant: u32,
        app_id: u64,
        at: f64,
        outcome: &str,
        measured: Option<f64>,
    ) {
        if let Some(ring) = self.tenants.get_mut(&tenant) {
            if let Some(span) = ring
                .iter_mut()
                .rev()
                .find(|s| s.app_id == app_id && s.outcome.is_none())
            {
                span.closed_at = Some(at);
                span.outcome = Some(outcome.to_string());
                span.measured = measured;
            }
        }
    }

    /// Record a persist-side event (WAL flush, snapshot rotation).
    pub fn note_persist(&mut self, at: f64, kind: &str, records: u64) {
        if self.persist.len() == self.cap {
            self.persist.pop_front();
        }
        self.persist.push_back(PersistNote {
            at,
            kind: kind.to_string(),
            records,
        });
    }

    /// Spans currently held for one tenant, oldest first.
    pub fn spans(&self, tenant: u32) -> Vec<&TraceSpan> {
        self.tenants
            .get(&tenant)
            .map(|r| r.iter().collect())
            .unwrap_or_default()
    }

    /// Count of open (unclosed) spans across all tenants.
    pub fn open_spans(&self) -> usize {
        self.tenants
            .values()
            .flat_map(|r| r.iter())
            .filter(|s| s.outcome.is_none())
            .count()
    }

    /// Export every ring as a deterministic JSON timeline:
    /// `{"tenants": {"0": [span...]}, "persist": [note...]}`.
    pub fn timeline_json(&self) -> Json {
        let mut tenants = Json::obj();
        for (t, ring) in &self.tenants {
            let spans = ring
                .iter()
                .map(|s| {
                    let mut j = Json::obj();
                    j.set("app_id", Json::Num(s.app_id as f64))
                        .set("decided_at", Json::Num(s.decided_at))
                        .set("kind", Json::Str(s.kind.clone()))
                        .set("label", Json::Str(s.label.clone()));
                    if let Some(at) = s.closed_at {
                        j.set("closed_at", Json::Num(at));
                    }
                    if let Some(o) = &s.outcome {
                        j.set("outcome", Json::Str(o.clone()));
                    }
                    if let Some(m) = s.measured {
                        j.set("measured", Json::Num(m));
                    }
                    j
                })
                .collect();
            tenants.set(&t.to_string(), Json::Arr(spans));
        }
        let persist = self
            .persist
            .iter()
            .map(|n| {
                let mut j = Json::obj();
                j.set("at", Json::Num(n.at))
                    .set("kind", Json::Str(n.kind.clone()))
                    .set("records", Json::Num(n.records as f64));
                j
            })
            .collect();
        let mut root = Json::obj();
        root.set("tenants", tenants).set("persist", Json::Arr(persist));
        root
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_open_close_and_export() {
        let mut tr = DecisionTrace::new(8);
        tr.open(0, 1, 10.0, "global_probe", "w3");
        tr.open(0, 2, 11.0, "cache_hit", "w3");
        tr.close(0, 1, 25.0, "measured", Some(15.0));
        tr.close(0, 2, 26.0, "failed", None);
        let spans = tr.spans(0);
        assert_eq!(spans.len(), 2);
        assert_eq!(spans[0].outcome.as_deref(), Some("measured"));
        assert_eq!(spans[0].measured, Some(15.0));
        assert_eq!(spans[1].outcome.as_deref(), Some("failed"));
        assert_eq!(tr.open_spans(), 0);
        let j = tr.timeline_json().encode_pretty();
        assert!(j.contains("global_probe"));
        assert!(j.contains("\"w3\""));
    }

    #[test]
    fn ring_is_bounded_per_tenant() {
        let mut tr = DecisionTrace::new(3);
        for app in 0..10u64 {
            tr.open(1, app, app as f64, "default", "w0");
        }
        let spans = tr.spans(1);
        assert_eq!(spans.len(), 3);
        assert_eq!(spans[0].app_id, 7);
        // closing an evicted span is a no-op, not an error
        tr.close(1, 0, 99.0, "measured", None);
        assert_eq!(tr.open_spans(), 3);
    }

    #[test]
    fn close_matches_latest_open_span_for_app() {
        let mut tr = DecisionTrace::new(8);
        tr.open(2, 7, 1.0, "global_probe", "w1");
        tr.open(2, 7, 2.0, "local_probe", "w1"); // re-decided
        tr.close(2, 7, 3.0, "measured", Some(1.5));
        let spans = tr.spans(2);
        assert!(spans[0].outcome.is_none(), "older span stays open");
        assert_eq!(spans[1].outcome.as_deref(), Some("measured"));
    }

    #[test]
    fn persist_notes_are_bounded_and_exported() {
        let mut tr = DecisionTrace::new(2);
        tr.note_persist(1.0, "wal_flush", 4);
        tr.note_persist(2.0, "snapshot", 9);
        tr.note_persist(3.0, "wal_flush", 2);
        let j = tr.timeline_json().encode();
        assert!(!j.contains("\"records\": 4") && !j.contains("\"records\":4"));
        assert!(j.contains("snapshot"));
    }

    #[test]
    fn timeline_is_deterministic() {
        let build = || {
            let mut tr = DecisionTrace::new(4);
            tr.open(0, 1, 1.0, "default", "w0");
            tr.open(3, 2, 2.0, "cache_hit", "w1");
            tr.close(3, 2, 4.0, "measured", Some(2.0));
            tr.timeline_json().encode_pretty()
        };
        assert_eq!(build(), build());
    }
}
