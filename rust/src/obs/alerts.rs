//! Loop-health alert rules evaluated on a cadence over registry
//! samples.
//!
//! Rules come in three shapes:
//!
//! * [`RuleExpr::GaugeAbove`] — instantaneous threshold on a family
//!   total (queue depth, degraded-tenant count);
//! * [`RuleExpr::DeltaAbove`] — per-evaluation-interval increase of a
//!   counter total (persist-error burst, probe-failure burst);
//! * [`RuleExpr::DeltaRatioAbove`] — ratio of two counter deltas with
//!   a minimum-denominator guard (UNKNOWN-rate spike).
//!
//! Delta rules self-baseline: the first evaluation only records the
//! current totals and can never fire, so attaching the engine to a
//! registry mid-run is safe. A rule fires after `fire_after`
//! consecutive breaching evaluations and clears on the first clean
//! one, emitting deterministic [`AlertEvent`]s either way — that
//! fire-then-clear sequence is exactly what the chaos scenarios
//! assert on (and the fault-free oracle must stay silent).
//!
//! An optional `guard` suppresses a rule until some other family
//! total reaches a floor — e.g. the UNKNOWN-rate rule stays quiet
//! until the knowledge base knows at least one workload, so cold
//! starts (where *everything* is UNKNOWN by construction) don't page.

use super::registry::Registry;

/// The comparison a rule applies each evaluation.
#[derive(Debug, Clone)]
pub enum RuleExpr {
    /// Family total is above `threshold` right now.
    GaugeAbove { metric: String, threshold: f64 },
    /// Family total grew by more than `threshold` since the previous
    /// evaluation.
    DeltaAbove { metric: String, threshold: f64 },
    /// `delta(num) / delta(den)` exceeds `threshold`, evaluated only
    /// when `delta(den) >= min_den`.
    DeltaRatioAbove {
        num: String,
        den: String,
        threshold: f64,
        min_den: f64,
    },
}

/// One alert rule.
#[derive(Debug, Clone)]
pub struct AlertRule {
    pub name: String,
    pub expr: RuleExpr,
    /// Consecutive breaching evaluations required before firing.
    pub fire_after: u32,
    /// Suppress the rule until `metric`'s family total is at least
    /// this floor.
    pub guard: Option<(String, f64)>,
}

impl AlertRule {
    pub fn new(name: &str, expr: RuleExpr) -> AlertRule {
        AlertRule {
            name: name.to_string(),
            expr,
            fire_after: 1,
            guard: None,
        }
    }

    pub fn fire_after(mut self, n: u32) -> AlertRule {
        self.fire_after = n.max(1);
        self
    }

    pub fn guarded_by(mut self, metric: &str, floor: f64) -> AlertRule {
        self.guard = Some((metric.to_string(), floor));
        self
    }
}

/// Fired or cleared.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AlertState {
    Fired,
    Cleared,
}

/// A deterministic alert transition.
#[derive(Debug, Clone)]
pub struct AlertEvent {
    /// Evaluation timestamp (sim seconds in chaos runs).
    pub at: f64,
    pub rule: String,
    pub state: AlertState,
    /// The value that breached (or the value at clear time).
    pub value: f64,
}

#[derive(Default)]
struct RuleState {
    prev_num: Option<f64>,
    prev_den: f64,
    breaches: u32,
    active: bool,
}

/// Evaluates a rule set against a registry on whatever cadence the
/// caller drives it at.
pub struct AlertEngine {
    rules: Vec<AlertRule>,
    states: Vec<RuleState>,
}

impl AlertEngine {
    pub fn new(rules: Vec<AlertRule>) -> AlertEngine {
        let states = rules.iter().map(|_| RuleState::default()).collect();
        AlertEngine { rules, states }
    }

    pub fn rules(&self) -> &[AlertRule] {
        &self.rules
    }

    /// Names of rules currently in the fired state.
    pub fn active(&self) -> Vec<String> {
        self.rules
            .iter()
            .zip(&self.states)
            .filter(|(_, s)| s.active)
            .map(|(r, _)| r.name.clone())
            .collect()
    }

    /// Run one evaluation pass; returns the transitions it produced,
    /// in rule order.
    pub fn eval(&mut self, reg: &Registry, now: f64) -> Vec<AlertEvent> {
        let mut events = Vec::new();
        for (rule, state) in self.rules.iter().zip(self.states.iter_mut()) {
            let guard_ok = match &rule.guard {
                Some((metric, floor)) => {
                    reg.total(metric).unwrap_or(0.0) >= *floor
                }
                None => true,
            };
            let (breach, value) = match &rule.expr {
                RuleExpr::GaugeAbove { metric, threshold } => {
                    let v = reg.total(metric).unwrap_or(0.0);
                    (v > *threshold, v)
                }
                RuleExpr::DeltaAbove { metric, threshold } => {
                    let v = reg.total(metric).unwrap_or(0.0);
                    let out = match state.prev_num {
                        Some(prev) => {
                            let d = v - prev;
                            (d > *threshold, d)
                        }
                        None => (false, 0.0),
                    };
                    state.prev_num = Some(v);
                    out
                }
                RuleExpr::DeltaRatioAbove {
                    num,
                    den,
                    threshold,
                    min_den,
                } => {
                    let nv = reg.total(num).unwrap_or(0.0);
                    let dv = reg.total(den).unwrap_or(0.0);
                    let out = match state.prev_num {
                        Some(prev_n) => {
                            let dn = nv - prev_n;
                            let dd = dv - state.prev_den;
                            let r = super::ratio(dn, dd);
                            (dd >= *min_den && r > *threshold, r)
                        }
                        None => (false, 0.0),
                    };
                    state.prev_num = Some(nv);
                    state.prev_den = dv;
                    out
                }
            };
            let breach = breach && guard_ok;
            if breach {
                state.breaches += 1;
                if !state.active && state.breaches >= rule.fire_after {
                    state.active = true;
                    events.push(AlertEvent {
                        at: now,
                        rule: rule.name.clone(),
                        state: AlertState::Fired,
                        value,
                    });
                }
            } else {
                state.breaches = 0;
                if state.active {
                    state.active = false;
                    events.push(AlertEvent {
                        at: now,
                        rule: rule.name.clone(),
                        state: AlertState::Cleared,
                        value,
                    });
                }
            }
        }
        events
    }
}

/// The rules chaos scenarios evaluate. Every input here is driven by
/// the deterministic sim (plugin/tuning/knowledge counters scraped
/// from the plane), so oracle runs are reproducibly silent.
pub fn chaos_rules() -> Vec<AlertRule> {
    vec![
        // Sustained UNKNOWN-rate spike on the observe path. Guarded
        // by the knowledge base knowing at least one workload so the
        // all-UNKNOWN cold start can't page; needs two consecutive
        // breaching evaluations with a real window flow.
        AlertRule::new(
            "unknown_rate_spike",
            RuleExpr::DeltaRatioAbove {
                num: "kermit_stream_unknown_windows_total".to_string(),
                den: "kermit_stream_windows_observed_total".to_string(),
                threshold: 0.8,
                min_den: 8.0,
            },
        )
        .fire_after(2)
        .guarded_by("kermit_knowledge_workloads_known", 1.0),
        // Probe measurements dying (preempted jobs, timeouts).
        AlertRule::new(
            "probe_failure_burst",
            RuleExpr::DeltaAbove {
                metric: "kermit_plugin_probes_failed_total".to_string(),
                threshold: 0.5,
            },
        ),
        // The poison detector or offline audit quarantining entries.
        AlertRule::new(
            "knowledge_quarantine",
            RuleExpr::DeltaAbove {
                metric: "kermit_knowledge_quarantines_total".to_string(),
                threshold: 0.5,
            },
        ),
        // Durable-store writes failing.
        AlertRule::new(
            "persist_error_burst",
            RuleExpr::DeltaAbove {
                metric: "kermit_persist_errors_total".to_string(),
                threshold: 0.5,
            },
        ),
        // Ingest supervisor holding tenants in Degraded/Healing.
        AlertRule::new(
            "tenant_degraded",
            RuleExpr::GaugeAbove {
                metric: "kermit_stream_tenants_degraded".to_string(),
                threshold: 0.5,
            },
        ),
    ]
}

/// The full catalog: the chaos rules plus rules whose inputs are not
/// sim-deterministic (process-global pool gauges, scale-sensitive
/// abandon counts) — fine for a live scrape loop, excluded from chaos
/// assertions.
pub fn standard_rules() -> Vec<AlertRule> {
    let mut rules = chaos_rules();
    rules.push(AlertRule::new(
        "abandoned_search_storm",
        RuleExpr::DeltaAbove {
            metric: "kermit_plugin_searches_abandoned_total".to_string(),
            threshold: 7.5,
        },
    ));
    rules.push(AlertRule::new(
        "pool_queue_depth",
        RuleExpr::GaugeAbove {
            metric: "kermit_pool_pending_tasks".to_string(),
            threshold: 1024.0,
        },
    ));
    rules
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reg_with_counter(name: &str, v: u64) -> Registry {
        let reg = Registry::new();
        reg.counter(name, "t", &[]).add(v);
        reg
    }

    #[test]
    fn delta_rule_baselines_then_fires_then_clears() {
        let reg = Registry::new();
        let c = reg.counter("kermit_errs_total", "t", &[]);
        let mut eng = AlertEngine::new(vec![AlertRule::new(
            "err_burst",
            RuleExpr::DeltaAbove {
                metric: "kermit_errs_total".to_string(),
                threshold: 0.5,
            },
        )]);
        c.add(100); // pre-existing total must not fire on first eval
        assert!(eng.eval(&reg, 1.0).is_empty(), "first eval baselines");
        c.add(3);
        let ev = eng.eval(&reg, 2.0);
        assert_eq!(ev.len(), 1);
        assert_eq!(ev[0].state, AlertState::Fired);
        assert_eq!(ev[0].value, 3.0);
        assert_eq!(eng.active(), vec!["err_burst".to_string()]);
        // still breaching: no duplicate fire
        c.add(2);
        assert!(eng.eval(&reg, 3.0).is_empty());
        // quiet interval clears
        let ev = eng.eval(&reg, 4.0);
        assert_eq!(ev.len(), 1);
        assert_eq!(ev[0].state, AlertState::Cleared);
        assert!(eng.active().is_empty());
    }

    #[test]
    fn gauge_rule_fires_and_clears_immediately() {
        let reg = Registry::new();
        let g = reg.gauge("kermit_depth", "t", &[]);
        let mut eng = AlertEngine::new(vec![AlertRule::new(
            "deep",
            RuleExpr::GaugeAbove {
                metric: "kermit_depth".to_string(),
                threshold: 10.0,
            },
        )]);
        g.set(5.0);
        assert!(eng.eval(&reg, 1.0).is_empty());
        g.set(11.0);
        assert_eq!(eng.eval(&reg, 2.0)[0].state, AlertState::Fired);
        g.set(0.0);
        assert_eq!(eng.eval(&reg, 3.0)[0].state, AlertState::Cleared);
    }

    #[test]
    fn ratio_rule_respects_min_denominator_and_fire_after() {
        let reg = Registry::new();
        let num = reg.counter("kermit_u_total", "t", &[]);
        let den = reg.counter("kermit_w_total", "t", &[]);
        let mut eng = AlertEngine::new(vec![AlertRule::new(
            "spike",
            RuleExpr::DeltaRatioAbove {
                num: "kermit_u_total".to_string(),
                den: "kermit_w_total".to_string(),
                threshold: 0.8,
                min_den: 8.0,
            },
        )
        .fire_after(2)]);
        assert!(eng.eval(&reg, 0.0).is_empty()); // baseline
        num.add(5);
        den.add(5); // ratio 1.0 but den delta below min
        assert!(eng.eval(&reg, 1.0).is_empty());
        num.add(10);
        den.add(10); // first breach — fire_after 2 holds it
        assert!(eng.eval(&reg, 2.0).is_empty());
        num.add(10);
        den.add(10); // second consecutive breach fires
        let ev = eng.eval(&reg, 3.0);
        assert_eq!(ev.len(), 1);
        assert_eq!(ev[0].state, AlertState::Fired);
        assert_eq!(ev[0].value, 1.0);
    }

    #[test]
    fn guard_suppresses_until_floor() {
        let reg = reg_with_counter("kermit_bad_total", 0);
        let bad = reg.counter("kermit_bad_total", "t", &[]);
        let mut eng = AlertEngine::new(vec![AlertRule::new(
            "g",
            RuleExpr::DeltaAbove {
                metric: "kermit_bad_total".to_string(),
                threshold: 0.5,
            },
        )
        .guarded_by("kermit_ready", 1.0)]);
        eng.eval(&reg, 0.0);
        bad.add(5);
        assert!(eng.eval(&reg, 1.0).is_empty(), "guard metric absent");
        reg.gauge("kermit_ready", "t", &[]).set(1.0);
        bad.add(5);
        assert_eq!(eng.eval(&reg, 2.0)[0].state, AlertState::Fired);
    }

    #[test]
    fn missing_metric_is_zero_not_error() {
        let reg = Registry::new();
        let mut eng = AlertEngine::new(standard_rules());
        assert!(eng.eval(&reg, 0.0).is_empty());
        assert!(eng.eval(&reg, 1.0).is_empty());
    }

    #[test]
    fn catalogs_are_consistent() {
        let chaos = chaos_rules();
        let standard = standard_rules();
        assert!(standard.len() > chaos.len());
        for r in &chaos {
            assert!(standard.iter().any(|s| s.name == r.name));
        }
        // chaos rules never watch the process-global pool
        for r in &chaos {
            let metric_names: Vec<&str> = match &r.expr {
                RuleExpr::GaugeAbove { metric, .. }
                | RuleExpr::DeltaAbove { metric, .. } => vec![metric],
                RuleExpr::DeltaRatioAbove { num, den, .. } => {
                    vec![num, den]
                }
            }
            .into_iter()
            .map(|s| s.as_str())
            .collect();
            assert!(
                metric_names.iter().all(|m| !m.contains("pool")),
                "{} watches a pool metric",
                r.name
            );
        }
    }
}
