//! Lock-light metrics registry.
//!
//! Design: registration is the slow path (one mutex, two `BTreeMap`
//! lookups) and happens once per series; the returned handle wraps an
//! `Arc<AtomicU64>` (or a small bundle of them for histograms), so the
//! hot path — `Counter::inc`, `Gauge::set`, `Histogram::observe` — is
//! lock-free and a handle clone is just an `Arc` clone. Handles stay
//! valid for the registry's lifetime; scrapers re-enter through the
//! same mutex and read everything with relaxed loads.
//!
//! Determinism: families are keyed by name in a `BTreeMap`, series by
//! their sorted label set in a nested `BTreeMap`, so [`Registry::gather`]
//! (and everything layered on it — exposition, JSON snapshot, alert
//! evaluation) walks samples in one canonical order.
//!
//! Two write idioms coexist:
//!
//! * **owned counters** incremented on the hot path (`inc`/`add`);
//! * **bridged counters** mirroring a plain `u64` an existing layer
//!   already maintains — [`Counter::set_total`] uses `fetch_max`, so
//!   repeated scrapes keep the series monotone even if exporters race.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// What a metric family is, for TYPE lines and snapshot kinds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricKind {
    Counter,
    Gauge,
    Histogram,
}

impl MetricKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            MetricKind::Counter => "counter",
            MetricKind::Gauge => "gauge",
            MetricKind::Histogram => "histogram",
        }
    }
}

/// A monotone counter. `inc`/`add` are single relaxed atomic ops.
#[derive(Clone)]
pub struct Counter {
    cell: Arc<AtomicU64>,
}

impl Counter {
    pub fn inc(&self) {
        self.cell.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.cell.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.cell.load(Ordering::Relaxed)
    }

    /// Bridge-exporter entry point: mirror an externally maintained
    /// total into this series. Uses `fetch_max`, so the series never
    /// goes backwards even if two scrapers race or the source resets.
    pub fn set_total(&self, total: u64) {
        self.cell.fetch_max(total, Ordering::Relaxed);
    }
}

/// A gauge: an `f64` stored as bits in an `AtomicU64`.
#[derive(Clone)]
pub struct Gauge {
    cell: Arc<AtomicU64>,
}

impl Gauge {
    pub fn set(&self, v: f64) {
        self.cell.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.cell.load(Ordering::Relaxed))
    }
}

struct HistogramCore {
    /// Upper bounds (exclusive of +Inf, which is implicit).
    bounds: Vec<f64>,
    /// Per-bucket (non-cumulative) observation counts; one per bound.
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    /// Sum of observed values as f64 bits, updated by CAS.
    sum_bits: AtomicU64,
}

/// A fixed-bound histogram. `observe` is a linear bucket scan plus
/// three atomic ops — fine for the latency-class bucket counts we use
/// (≤ 8 bounds).
#[derive(Clone)]
pub struct Histogram {
    core: Arc<HistogramCore>,
}

impl Histogram {
    pub fn observe(&self, v: f64) {
        for (i, b) in self.core.bounds.iter().enumerate() {
            if v <= *b {
                self.core.buckets[i].fetch_add(1, Ordering::Relaxed);
                break;
            }
        }
        self.core.count.fetch_add(1, Ordering::Relaxed);
        let mut cur = self.core.sum_bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match self.core.sum_bits.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Bridge-exporter entry point: overwrite the histogram with
    /// externally maintained totals. `per_bucket` is non-cumulative,
    /// one entry per bound; anything beyond the last bound only shows
    /// up in `count`. Don't mix with `observe` on the same series.
    pub fn set_totals(&self, per_bucket: &[u64], count: u64, sum: f64) {
        for (i, cell) in self.core.buckets.iter().enumerate() {
            let v = per_bucket.get(i).copied().unwrap_or(0);
            cell.store(v, Ordering::Relaxed);
        }
        self.core.count.store(count, Ordering::Relaxed);
        self.core.sum_bits.store(sum.to_bits(), Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.core.count.load(Ordering::Relaxed)
    }
}

/// A point-in-time reading of one series, as produced by
/// [`Registry::gather`].
#[derive(Debug, Clone, PartialEq)]
pub enum SeriesValue {
    Counter(u64),
    Gauge(f64),
    /// `buckets` are cumulative counts per bound (the +Inf bucket is
    /// `count`); `sum` is the running sum of observations.
    Histogram {
        bounds: Vec<f64>,
        buckets: Vec<u64>,
        count: u64,
        sum: f64,
    },
}

/// A gathered family: name, help, kind and every series in canonical
/// (sorted-label) order.
#[derive(Debug, Clone)]
pub struct FamilySnapshot {
    pub name: String,
    pub help: String,
    pub kind: MetricKind,
    pub series: Vec<(Vec<(String, String)>, SeriesValue)>,
}

enum Cell {
    Counter(Arc<AtomicU64>),
    Gauge(Arc<AtomicU64>),
    Histogram(Arc<HistogramCore>),
}

struct Family {
    help: String,
    kind: MetricKind,
    /// Histogram families share one bound vector, fixed at first
    /// registration.
    bounds: Vec<f64>,
    series: BTreeMap<Vec<(String, String)>, Cell>,
}

/// The registry. Cheap to clone (shared interior); every layer that
/// exports metrics takes `&Registry`.
#[derive(Clone)]
pub struct Registry {
    inner: Arc<Mutex<BTreeMap<String, Family>>>,
}

impl Default for Registry {
    fn default() -> Self {
        Registry::new()
    }
}

fn canonical_labels(labels: &[(&str, &str)]) -> Vec<(String, String)> {
    let mut out: BTreeMap<String, String> = BTreeMap::new();
    for (k, v) in labels {
        out.insert((*k).to_string(), (*v).to_string());
    }
    out.into_iter().collect()
}

impl Registry {
    pub fn new() -> Registry {
        Registry {
            inner: Arc::new(Mutex::new(BTreeMap::new())),
        }
    }

    fn family_cell(
        &self,
        name: &str,
        help: &str,
        kind: MetricKind,
        labels: &[(&str, &str)],
        bounds: &[f64],
    ) -> Cell {
        let mut fams = self.inner.lock().expect("obs registry poisoned");
        let fam = fams.entry(name.to_string()).or_insert_with(|| Family {
            help: help.to_string(),
            kind,
            bounds: bounds.to_vec(),
            series: BTreeMap::new(),
        });
        assert!(
            fam.kind == kind,
            "metric {name} registered as {} and {}",
            fam.kind.as_str(),
            kind.as_str()
        );
        let key = canonical_labels(labels);
        let cell = fam.series.entry(key).or_insert_with(|| match kind {
            MetricKind::Counter => Cell::Counter(Arc::new(AtomicU64::new(0))),
            MetricKind::Gauge => {
                Cell::Gauge(Arc::new(AtomicU64::new(0f64.to_bits())))
            }
            MetricKind::Histogram => {
                let n = fam.bounds.len();
                Cell::Histogram(Arc::new(HistogramCore {
                    bounds: fam.bounds.clone(),
                    buckets: (0..n).map(|_| AtomicU64::new(0)).collect(),
                    count: AtomicU64::new(0),
                    sum_bits: AtomicU64::new(0f64.to_bits()),
                }))
            }
        });
        match cell {
            Cell::Counter(c) => Cell::Counter(Arc::clone(c)),
            Cell::Gauge(g) => Cell::Gauge(Arc::clone(g)),
            Cell::Histogram(h) => Cell::Histogram(Arc::clone(h)),
        }
    }

    /// Register (or look up) a counter series. Idempotent: the same
    /// `(name, labels)` always returns a handle onto the same cell.
    pub fn counter(
        &self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
    ) -> Counter {
        match self.family_cell(name, help, MetricKind::Counter, labels, &[]) {
            Cell::Counter(cell) => Counter { cell },
            _ => unreachable!(),
        }
    }

    /// Register (or look up) a gauge series.
    pub fn gauge(
        &self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
    ) -> Gauge {
        match self.family_cell(name, help, MetricKind::Gauge, labels, &[]) {
            Cell::Gauge(cell) => Gauge { cell },
            _ => unreachable!(),
        }
    }

    /// Register (or look up) a histogram series. `bounds` must be
    /// strictly increasing; the family's bounds are fixed by the first
    /// registration.
    pub fn histogram(
        &self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
        bounds: &[f64],
    ) -> Histogram {
        assert!(
            bounds.windows(2).all(|w| w[0] < w[1]),
            "histogram {name} bounds must be strictly increasing"
        );
        match self.family_cell(name, help, MetricKind::Histogram, labels, bounds)
        {
            Cell::Histogram(core) => Histogram { core },
            _ => unreachable!(),
        }
    }

    /// Snapshot every family in canonical order.
    pub fn gather(&self) -> Vec<FamilySnapshot> {
        let fams = self.inner.lock().expect("obs registry poisoned");
        fams.iter()
            .map(|(name, fam)| FamilySnapshot {
                name: name.clone(),
                help: fam.help.clone(),
                kind: fam.kind,
                series: fam
                    .series
                    .iter()
                    .map(|(labels, cell)| {
                        (labels.clone(), read_cell(cell))
                    })
                    .collect(),
            })
            .collect()
    }

    /// Sum a family across all its series, as the alert engine sees
    /// it: counters and gauges sum their values, histograms sum their
    /// observation counts. `None` if the family was never registered.
    pub fn total(&self, name: &str) -> Option<f64> {
        let fams = self.inner.lock().expect("obs registry poisoned");
        let fam = fams.get(name)?;
        let mut sum = 0.0;
        for cell in fam.series.values() {
            sum += match read_cell(cell) {
                SeriesValue::Counter(v) => v as f64,
                SeriesValue::Gauge(v) => v,
                SeriesValue::Histogram { count, .. } => count as f64,
            };
        }
        Some(sum)
    }
}

fn read_cell(cell: &Cell) -> SeriesValue {
    match cell {
        Cell::Counter(c) => SeriesValue::Counter(c.load(Ordering::Relaxed)),
        Cell::Gauge(g) => {
            SeriesValue::Gauge(f64::from_bits(g.load(Ordering::Relaxed)))
        }
        Cell::Histogram(h) => {
            let mut cum = 0u64;
            let buckets = h
                .buckets
                .iter()
                .map(|b| {
                    cum += b.load(Ordering::Relaxed);
                    cum
                })
                .collect();
            SeriesValue::Histogram {
                bounds: h.bounds.clone(),
                buckets,
                count: h.count.load(Ordering::Relaxed),
                sum: f64::from_bits(h.sum_bits.load(Ordering::Relaxed)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_handles_share_one_cell() {
        let reg = Registry::new();
        let a = reg.counter("kermit_t_total", "t", &[("tenant", "0")]);
        let b = reg.counter("kermit_t_total", "t", &[("tenant", "0")]);
        a.inc();
        b.add(2);
        assert_eq!(a.get(), 3);
        assert_eq!(reg.total("kermit_t_total"), Some(3.0));
    }

    #[test]
    fn set_total_is_monotone() {
        let reg = Registry::new();
        let c = reg.counter("kermit_bridge_total", "b", &[]);
        c.set_total(10);
        c.set_total(7); // stale writer loses
        assert_eq!(c.get(), 10);
        c.set_total(12);
        assert_eq!(c.get(), 12);
    }

    #[test]
    fn labels_canonicalize_regardless_of_order() {
        let reg = Registry::new();
        let a = reg.counter("kermit_l_total", "l", &[("b", "2"), ("a", "1")]);
        let b = reg.counter("kermit_l_total", "l", &[("a", "1"), ("b", "2")]);
        a.inc();
        b.inc();
        let fams = reg.gather();
        assert_eq!(fams.len(), 1);
        assert_eq!(fams[0].series.len(), 1);
        assert_eq!(
            fams[0].series[0].0,
            vec![
                ("a".to_string(), "1".to_string()),
                ("b".to_string(), "2".to_string())
            ]
        );
        assert_eq!(fams[0].series[0].1, SeriesValue::Counter(2));
    }

    #[test]
    fn histogram_buckets_are_cumulative_in_gather() {
        let reg = Registry::new();
        let h = reg.histogram("kermit_h", "h", &[], &[1.0, 5.0, 25.0]);
        h.observe(0.5);
        h.observe(3.0);
        h.observe(50.0); // beyond last bound: only count/sum
        match &reg.gather()[0].series[0].1 {
            SeriesValue::Histogram {
                buckets,
                count,
                sum,
                ..
            } => {
                assert_eq!(buckets, &vec![1, 2, 2]);
                assert_eq!(*count, 3);
                assert_eq!(*sum, 53.5);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn gauge_round_trips_f64() {
        let reg = Registry::new();
        let g = reg.gauge("kermit_g", "g", &[]);
        g.set(-2.25);
        assert_eq!(g.get(), -2.25);
        assert_eq!(reg.total("kermit_g"), Some(-2.25));
    }

    #[test]
    #[should_panic(expected = "registered as")]
    fn kind_conflict_panics() {
        let reg = Registry::new();
        reg.counter("kermit_k", "k", &[]);
        reg.gauge("kermit_k", "k", &[]);
    }
}
