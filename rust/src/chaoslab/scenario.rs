//! Scenario scripting: a named, seeded description of one chaos-lab
//! experiment — the fault plan for the simcluster plus scripted
//! knowledge-plane attacks, and the degradation bounds the run must
//! hold (the scoreboard of `super::runner`).

use crate::simcluster::fault::{
    ChurnEvent, DriftStorm, FaultPlan, NoisyNeighborFault, PreemptionFault,
    StragglerFault,
};
use crate::stream::TenantId;

/// A scripted knowledge-plane / workload attack fired mid-run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StepAction {
    /// Overwrite the lowest trusted stored optimum with a pessimal
    /// config (cache poisoning: the semantic corruption the integrity
    /// audit cannot see — only the poison detector can).
    PoisonOptimum,
    /// Corrupt the highest label's centroid to NaN (structural
    /// corruption: the off-line audit must quarantine it).
    CorruptEntry,
    /// A flash crowd: `tenants` brand-new tenants each submit `jobs`
    /// jobs at the step time. Part of the *workload*, so it is staged
    /// in the oracle run too — the faults are what differs.
    FlashCrowd { tenants: usize, jobs: usize },
}

/// One scripted step of a scenario.
#[derive(Debug, Clone)]
pub struct ScenarioStep {
    pub name: &'static str,
    /// Sim time the step fires at (first engine callback at/after it).
    pub at: f64,
    pub action: StepAction,
}

/// A full chaos scenario: workload scale, fault plan, scripted steps,
/// and the graceful-degradation bounds the faulted run must satisfy
/// against its fault-free oracle.
#[derive(Debug, Clone)]
pub struct ScenarioSpec {
    pub name: &'static str,
    pub seed: u64,
    pub tenants: usize,
    pub jobs_per_tenant: usize,
    pub classes: Vec<u32>,
    /// Explorer global budget (local budget derives from it).
    pub budget: usize,
    pub faults: FaultPlan,
    pub steps: Vec<ScenarioStep>,
    /// Max allowed per-completed-job makespan regret vs the oracle:
    /// `faulted_per_job / oracle_per_job - 1 <= regret_bound`.
    pub regret_bound: f64,
    /// Tail window (decisions per tenant) the recovery check pools.
    pub recovery_window: usize,
    /// The faulted run's tail cache-hit ratio must be at least this
    /// fraction of the oracle's (0 disables the check for scenarios
    /// whose guarantee is containment, not cache recovery).
    pub recovery_floor: f64,
    /// Alert rules (`crate::obs::chaos_rules` names) the faulted run
    /// must both FIRE while the fault is active and CLEAR by the end
    /// of the settle evaluations. The oracle run must fire none,
    /// regardless of this list.
    pub expect_alerts: Vec<&'static str>,
}

impl ScenarioSpec {
    /// Baseline spec at the standard scale: smoke (CI) runs 3 tenants x
    /// 8 jobs with a small search budget, full runs 4 x 14.
    pub fn base(name: &'static str, seed: u64, smoke: bool) -> ScenarioSpec {
        let (tenants, jobs, budget) =
            if smoke { (3, 8, 10) } else { (4, 14, 14) };
        ScenarioSpec {
            name,
            seed,
            tenants,
            jobs_per_tenant: jobs,
            classes: vec![0, 5],
            budget,
            faults: FaultPlan::default(),
            steps: Vec::new(),
            regret_bound: 2.5,
            recovery_window: 6,
            recovery_floor: 0.0,
            expect_alerts: Vec::new(),
        }
    }

    /// Apply `KERMIT_CHAOS_SEED` / `KERMIT_CHAOS_TENANTS` /
    /// `KERMIT_CHAOS_JOBS` env overrides (unset or unparsable values
    /// leave the spec untouched) — the reproduce-my-CI-failure knob.
    pub fn apply_env(&mut self) {
        fn env_parse<T: std::str::FromStr>(key: &str) -> Option<T> {
            std::env::var(key).ok()?.parse().ok()
        }
        if let Some(s) = env_parse::<u64>("KERMIT_CHAOS_SEED") {
            self.seed = s;
        }
        if let Some(t) = env_parse::<usize>("KERMIT_CHAOS_TENANTS") {
            self.tenants = t.max(1);
        }
        if let Some(j) = env_parse::<usize>("KERMIT_CHAOS_JOBS") {
            self.jobs_per_tenant = j.max(1);
        }
    }
}

/// The standard scenario sweep — one scenario per fault family in the
/// taxonomy (docs/ARCHITECTURE.md "Chaos lab"). Bounds are the
/// documented graceful-degradation guarantees; every scenario must hold
/// them on every seed.
pub fn standard_scenarios(smoke: bool) -> Vec<ScenarioSpec> {
    let mut scenarios = Vec::new();

    // Straggler executors: durations stretch, nothing fails — tuning
    // keeps converging on noisy measurements and the cache must keep
    // serving (the only scenario with a real cache-recovery floor).
    let mut s = ScenarioSpec::base("stragglers", 101, smoke);
    s.faults.stragglers =
        Some(StragglerFault { prob: 0.25, slowdown: 2.5 });
    s.regret_bound = 2.5;
    s.recovery_floor = 0.3;
    scenarios.push(s);

    // Preemption storm: containers die mid-job, some jobs fail outright
    // and re-queue on a bounded budget. Probe jobs that die must feed
    // failure (not silence) to the search sessions.
    let mut s = ScenarioSpec::base("preemption_storm", 202, smoke);
    s.faults.preemption = Some(PreemptionFault {
        prob: 0.35,
        kill_frac: 0.5,
        restart_penalty: 1.3,
        regrant_denied_prob: 0.3,
    });
    s.faults.max_requeues = 2;
    s.regret_bound = 3.0;
    s.expect_alerts = vec!["probe_failure_burst"];
    scenarios.push(s);

    // Noisy neighbor: a mid-run interference window shrinks every
    // effective fleet; the poison detector must NOT blame stored
    // optima for degraded-fleet runs (full-fleet gating).
    let mut s = ScenarioSpec::base("noisy_neighbor", 303, smoke);
    s.faults.noisy_neighbor = Some(NoisyNeighborFault {
        from: 300.0,
        until: 1800.0,
        intensity: 0.4,
    });
    s.regret_bound = 3.0;
    scenarios.push(s);

    // Flash crowd + churn: new tenants burst in mid-run (staged in the
    // oracle too — it is workload), while an existing tenant churns
    // away with its queue and running job.
    let mut s = ScenarioSpec::base("flash_crowd", 404, smoke);
    s.faults.churn = vec![ChurnEvent { tenant: TenantId(0), at: 900.0 }];
    s.steps.push(ScenarioStep {
        name: "crowd_arrives",
        at: 600.0,
        action: StepAction::FlashCrowd {
            tenants: 2,
            jobs: if smoke { 3 } else { 5 },
        },
    });
    s.regret_bound = 3.0;
    scenarios.push(s);

    // Coordinated drift storm: every tenant's features slide off their
    // learned centroids on phase-shifted schedules — classification
    // degrades to UNKNOWN/drift, decisions degrade to defaults, and
    // the loop must neither wedge nor poison the DB.
    let mut s = ScenarioSpec::base("drift_storm", 505, smoke);
    s.faults.drift_storm = Some(DriftStorm {
        from: 500.0,
        rate: 0.004,
        phase_shift: 150.0,
    });
    s.regret_bound = 3.0;
    s.expect_alerts = vec!["unknown_rate_spike"];
    scenarios.push(s);

    // Poisoned DB: no engine faults at all — the attack is on the
    // knowledge plane itself (one semantic poisoning, one structural
    // corruption). Guarantee: the poison is served at most
    // `poison_strikes` times before quarantine, and the corrupt entry
    // never survives an audit.
    let mut s = ScenarioSpec::base("poisoned_db", 606, smoke);
    s.steps.push(ScenarioStep {
        name: "poison_optimum",
        at: 400.0,
        action: StepAction::PoisonOptimum,
    });
    s.steps.push(ScenarioStep {
        name: "corrupt_entry",
        at: 700.0,
        action: StepAction::CorruptEntry,
    });
    s.regret_bound = 3.0;
    s.expect_alerts = vec!["knowledge_quarantine"];
    scenarios.push(s);

    for s in &mut scenarios {
        s.apply_env();
    }
    scenarios
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standard_sweep_covers_the_taxonomy() {
        let sweep = standard_scenarios(true);
        let names: Vec<&str> = sweep.iter().map(|s| s.name).collect();
        assert_eq!(
            names,
            vec![
                "stragglers",
                "preemption_storm",
                "noisy_neighbor",
                "flash_crowd",
                "drift_storm",
                "poisoned_db"
            ]
        );
        // every scenario actually injects something (faults or steps)
        for s in &sweep {
            assert!(
                !s.faults.is_inert() || !s.steps.is_empty(),
                "{} injects nothing",
                s.name
            );
            assert!(s.regret_bound > 0.0);
        }
        // smoke is strictly smaller than full
        let full = standard_scenarios(false);
        assert!(sweep[0].jobs_per_tenant < full[0].jobs_per_tenant);
    }

    #[test]
    fn expected_alerts_name_real_chaos_rules() {
        let known: Vec<String> = crate::obs::chaos_rules()
            .iter()
            .map(|r| r.name.clone())
            .collect();
        let sweep = standard_scenarios(true);
        let expecting: Vec<&ScenarioSpec> = sweep
            .iter()
            .filter(|s| !s.expect_alerts.is_empty())
            .collect();
        // the fire-and-clear guarantee is exercised by at least three
        // distinct fault families
        assert!(expecting.len() >= 3, "only {} expect alerts", expecting.len());
        for s in expecting {
            for a in &s.expect_alerts {
                assert!(
                    known.iter().any(|k| k == a),
                    "{}: unknown alert rule {a}",
                    s.name
                );
            }
        }
    }
}
