//! Persistence chaos: fault-injected crash/recovery scenarios for the
//! durable knowledge plane (`knowledge::persist`), proving the
//! crash-consistency guarantees end to end on the real tuning plane:
//!
//! * **`crash_restart`** — a full tuning run learns optima, snapshots,
//!   quarantines an entry, flushes, and is killed. Guarantees: the
//!   recovered durable state is byte-identical to the pre-crash digest
//!   (zero learned-optimum loss up to the WAL tail), the quarantine
//!   survives the restart, at least one tenant serves a CacheHit with
//!   zero probes paid immediately after recovery (warm from job one),
//!   and the restarted run's makespan holds a bounded cold-start
//!   regret against a never-crashed oracle.
//! * **`corrupt_snapshot`** — the newest snapshot generation is
//!   bit-flipped on disk and the active WAL's tail is torn by the
//!   crash. Guarantees: recovery rejects the corrupt generation (never
//!   serving a checksum-corrupt entry), falls back one generation,
//!   replays the surviving WAL records, truncates the torn tail, and
//!   lands exactly on the last durable state.
//!
//! These scenarios are NOT part of [`super::standard_scenarios`] (that
//! name list is pinned); `benches/persist.rs` and the
//! `rust-persist-smoke` CI job drive them via
//! [`persistence_scenarios`] + [`run_persistence_scenario`].

use crate::experiments::tuning_plane::{
    plane_config, schedules, sim_config,
};
use crate::knowledge::persist::{durable_digest, BinaryCodec};
use crate::knowledge::Characterization;
use crate::simcluster::config_space::ConfigIndex;
use crate::tuning::TuningPlane;
use crate::util::json::Json;
use std::collections::BTreeSet;
use std::path::PathBuf;

/// Which fault script a persistence scenario runs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PersistFault {
    /// Kill after a snapshot + flushed WAL tail; recover; rerun.
    CrashRestart,
    /// Bit-flip the newest snapshot and tear the WAL tail; recover.
    CorruptSnapshot,
}

/// A seeded persistence scenario.
#[derive(Debug, Clone)]
pub struct PersistSpec {
    pub name: &'static str,
    pub seed: u64,
    pub fault: PersistFault,
    pub tenants: usize,
    pub jobs_per_tenant: usize,
    pub classes: Vec<u32>,
    /// Explorer global budget (as in `ScenarioSpec`).
    pub budget: usize,
    /// Max allowed post-restart makespan regret vs the never-crashed
    /// oracle (`crash_restart` only).
    pub regret_bound: f64,
}

impl PersistSpec {
    fn base(
        name: &'static str,
        seed: u64,
        fault: PersistFault,
        smoke: bool,
    ) -> PersistSpec {
        let (tenants, jobs, budget) =
            if smoke { (3, 8, 10) } else { (4, 14, 14) };
        PersistSpec {
            name,
            seed,
            fault,
            tenants,
            jobs_per_tenant: jobs,
            classes: vec![0, 5],
            budget,
            regret_bound: 2.0,
        }
    }

    /// Same env overrides as `ScenarioSpec::apply_env` — reproduce a
    /// CI failure locally from the artifact's seed.
    pub fn apply_env(&mut self) {
        fn env_parse<T: std::str::FromStr>(key: &str) -> Option<T> {
            std::env::var(key).ok()?.parse().ok()
        }
        if let Some(s) = env_parse::<u64>("KERMIT_CHAOS_SEED") {
            self.seed = s;
        }
        if let Some(t) = env_parse::<usize>("KERMIT_CHAOS_TENANTS") {
            self.tenants = t.max(1);
        }
        if let Some(j) = env_parse::<usize>("KERMIT_CHAOS_JOBS") {
            self.jobs_per_tenant = j.max(1);
        }
    }
}

/// The persistence sweep (one scenario per crash family).
pub fn persistence_scenarios(smoke: bool) -> Vec<PersistSpec> {
    let mut sweep = vec![
        PersistSpec::base(
            "crash_restart",
            808,
            PersistFault::CrashRestart,
            smoke,
        ),
        PersistSpec::base(
            "corrupt_snapshot",
            909,
            PersistFault::CorruptSnapshot,
            smoke,
        ),
    ];
    for s in &mut sweep {
        s.apply_env();
    }
    sweep
}

/// The recovery scoreboard for one persistence scenario —
/// deterministic JSON (same seed → same bytes), like
/// `ScenarioOutcome`.
#[derive(Debug, Clone, Default)]
pub struct RecoveryOutcome {
    pub name: String,
    pub seed: u64,

    // ---- what recovery reported ---------------------------------------
    pub generation_loaded: Option<u64>,
    pub snapshots_rejected: u64,
    pub wal_records_replayed: u64,
    pub wal_torn_tail: bool,

    // ---- zero-loss guarantee ------------------------------------------
    /// Trusted optima in the last durable state before the crash.
    pub optima_at_crash: usize,
    /// Trusted optima after recovery.
    pub optima_recovered: usize,
    /// Durable optima missing (or with a different config) after
    /// recovery — MUST be zero.
    pub lost_optima: usize,
    /// Recovered durable state is byte-identical to the pre-crash
    /// durable digest.
    pub digest_match: bool,

    // ---- quarantine survival ------------------------------------------
    pub quarantined_at_crash: usize,
    pub quarantined_recovered: usize,
    pub quarantine_preserved: bool,

    // ---- warm restart (crash_restart only) ----------------------------
    /// Tenants that served at least one CacheHit with ZERO probes paid
    /// in the post-restart run.
    pub warm_tenants: usize,
    /// Post-restart makespan vs the never-crashed oracle's, minus one.
    pub cold_regret: f64,
    pub regret_bound: f64,

    // ---- hygiene ------------------------------------------------------
    pub persist_errors: usize,

    // ---- verdict ------------------------------------------------------
    pub pass: bool,
    pub failures: Vec<String>,
}

impl RecoveryOutcome {
    pub fn to_json(&self) -> Json {
        let n = |v: usize| Json::Num(v as f64);
        let mut j = Json::obj();
        j.set("name", Json::Str(self.name.clone()))
            .set("seed", Json::Num(self.seed as f64))
            .set(
                "generation_loaded",
                match self.generation_loaded {
                    Some(g) => Json::Num(g as f64),
                    None => Json::Null,
                },
            )
            .set(
                "snapshots_rejected",
                Json::Num(self.snapshots_rejected as f64),
            )
            .set(
                "wal_records_replayed",
                Json::Num(self.wal_records_replayed as f64),
            )
            .set("wal_torn_tail", Json::Bool(self.wal_torn_tail))
            .set("optima_at_crash", n(self.optima_at_crash))
            .set("optima_recovered", n(self.optima_recovered))
            .set("lost_optima", n(self.lost_optima))
            .set("digest_match", Json::Bool(self.digest_match))
            .set("quarantined_at_crash", n(self.quarantined_at_crash))
            .set("quarantined_recovered", n(self.quarantined_recovered))
            .set(
                "quarantine_preserved",
                Json::Bool(self.quarantine_preserved),
            )
            .set("warm_tenants", n(self.warm_tenants))
            .set("cold_regret", Json::Num(self.cold_regret))
            .set("regret_bound", Json::Num(self.regret_bound))
            .set("persist_errors", n(self.persist_errors))
            .set("pass", Json::Bool(self.pass))
            .set(
                "failures",
                Json::Arr(
                    self.failures
                        .iter()
                        .map(|f| Json::Str(f.clone()))
                        .collect(),
                ),
            );
        j
    }
}

fn store_dir(spec: &PersistSpec) -> PathBuf {
    std::env::temp_dir().join(format!(
        "kermit_chaos_persist_{}_{}",
        spec.name, spec.seed
    ))
}

/// Durable-state summary: (digest bytes, trusted-optimum labels with
/// configs, quarantined labels).
fn durable_state(
    plane: &TuningPlane,
) -> (String, Vec<(u32, ConfigIndex)>, BTreeSet<u32>) {
    let db = plane.coord.db.read().unwrap();
    let digest = durable_digest(&db).encode();
    let optima = db
        .entries()
        .filter(|e| e.optimal_config_found)
        .map(|e| (e.label, e.config.expect("optimal entry has config")))
        .collect();
    let quarantined = db.quarantined_labels().into_iter().collect();
    (digest, optima, quarantined)
}

/// Execute one persistence scenario and score its guarantees.
pub fn run_persistence_scenario(spec: &PersistSpec) -> RecoveryOutcome {
    let dir = store_dir(spec);
    std::fs::remove_dir_all(&dir).ok();
    let mut o = RecoveryOutcome {
        name: spec.name.to_string(),
        seed: spec.seed,
        regret_bound: spec.regret_bound,
        ..RecoveryOutcome::default()
    };
    let fail = |o: &mut RecoveryOutcome, msg: String| {
        o.failures.push(msg);
    };

    let phase1 = schedules(
        spec.seed,
        spec.tenants,
        spec.jobs_per_tenant,
        &spec.classes,
    );
    let phase2 = schedules(
        spec.seed ^ 0xF00D,
        spec.tenants,
        spec.jobs_per_tenant,
        &spec.classes,
    );

    // ---- phase 1: learn on a durable plane ----------------------------
    let (mut plane, _) = TuningPlane::open_durable(
        plane_config(spec.seed, spec.budget),
        &dir,
        Box::new(BinaryCodec),
    )
    .expect("fresh store opens");
    plane.run_schedules(&phase1, sim_config(), spec.seed);
    plane.persist_snapshot(); // generation 1 on disk

    match spec.fault {
        PersistFault::CrashRestart => {
            // quarantine a *traffic-orphan* entry so the restart must
            // carry the flag. Quarantining a label live jobs classify
            // to would send every tenant on a fresh global search (a
            // poisoned optimum is never served, and in-flight dedup
            // only kicks in once some peer's re-search completes) —
            // the warm-start guarantee below needs at least one tenant
            // that pays zero probes, so the quarantined entry must be
            // one phase 2 never routes to.
            let target = {
                let mut db = plane.coord.db.write().unwrap();
                let dim = db
                    .entries()
                    .next()
                    .map(|e| e.characterization.per_feature.len());
                dim.map(|w| {
                    // far enough that no live characterization ever
                    // wins a nearest() match against a real entry;
                    // synthetic, so offline discovery ignores it too
                    let row = vec![1.0e6; w];
                    let c = Characterization::from_vec_rows(&[row.clone()]);
                    let l = db.insert_new(c, row, 1, true);
                    // order matters: a completed search lifts
                    // quarantine, so the optimum lands first
                    db.set_optimal_measured(
                        l,
                        ConfigIndex([2, 1, 0, 2, 1, 0]),
                        123.0,
                    );
                    db.quarantine(l);
                    l
                })
            };
            if target.is_none() {
                fail(
                    &mut o,
                    "phase 1 discovered no entry to clone dims from".into(),
                );
            }
            plane.persist_flush(); // the quarantine reaches the WAL tail
        }
        PersistFault::CorruptSnapshot => {
            // a second learning phase lands records in the rotated WAL,
            // then the snapshot that folds them is corrupted on disk
            plane.run_schedules(&phase2, sim_config(), spec.seed ^ 1);
            let flip = 11 + (spec.seed as usize % 97);
            plane.store_mut().unwrap().faults.snapshot_bit_flip_at =
                Some(flip);
            plane.persist_snapshot(); // generation 2: corrupt payload
        }
    }

    // the last DURABLE state: everything after this point is allowed to
    // be lost to the torn WAL tail, nothing before it may be
    o.persist_errors = plane.persist_errors;
    let (digest, optima, quarantined) = durable_state(&plane);
    o.optima_at_crash = optima.len();
    o.quarantined_at_crash = quarantined.len();

    if spec.fault == PersistFault::CorruptSnapshot {
        // one last mutation whose WAL frame the crash tears mid-write:
        // recovery must truncate it and land on the digest above
        let victim = optima.first().map(|(l, _)| *l);
        if let Some(l) = victim {
            plane
                .coord
                .db
                .write()
                .unwrap()
                .set_optimal_measured(l, ConfigIndex([0, 0, 0, 0, 0, 0]), 1.0);
            plane.persist_flush();
            plane.store_mut().unwrap().faults.wal_torn_tail_bytes =
                Some(spec.seed % 8 + 1);
        } else {
            fail(&mut o, "no optimum to mutate for the torn tail".into());
        }
    }
    plane.crash();

    // ---- recovery -----------------------------------------------------
    let (mut plane2, report) = TuningPlane::open_durable(
        plane_config(spec.seed, spec.budget),
        &dir,
        Box::new(BinaryCodec),
    )
    .expect("recovery opens");
    o.generation_loaded = report.generation_loaded;
    o.snapshots_rejected = report.snapshots_rejected;
    o.wal_records_replayed = report.wal_records_replayed;
    o.wal_torn_tail = report.wal_torn_tail;

    let (digest2, optima2, quarantined2) = durable_state(&plane2);
    o.optima_recovered = optima2.len();
    o.quarantined_recovered = quarantined2.len();
    o.digest_match = digest2 == digest;
    o.quarantine_preserved = quarantined2 == quarantined;
    let recovered: std::collections::BTreeMap<u32, ConfigIndex> =
        optima2.iter().copied().collect();
    o.lost_optima = optima
        .iter()
        .filter(|(l, c)| recovered.get(l) != Some(c))
        .count();

    if !o.digest_match {
        fail(&mut o, "durable digest changed across the crash".into());
    }
    if o.lost_optima > 0 {
        fail(&mut o, format!("{} learned optima lost", o.lost_optima));
    }
    if !o.quarantine_preserved {
        fail(&mut o, "quarantine set changed across the crash".into());
    }

    match spec.fault {
        PersistFault::CrashRestart => {
            if o.quarantined_at_crash == 0 {
                fail(&mut o, "nothing was quarantined pre-crash".into());
            }
            if o.generation_loaded != Some(1) {
                fail(
                    &mut o,
                    format!(
                        "expected generation 1, loaded {:?}",
                        o.generation_loaded
                    ),
                );
            }
            // ---- phase 2 on the recovered plane: warm from job one --
            let report2 =
                plane2.run_schedules(&phase2, sim_config(), spec.seed ^ 1);
            o.warm_tenants = report2
                .multi
                .tenant_stats
                .iter()
                .filter(|(_, s)| s.cache_hits >= 1 && s.probes_paid() == 0)
                .count();
            if o.warm_tenants == 0 {
                fail(
                    &mut o,
                    "no tenant served a zero-probe cache hit post-restart"
                        .into(),
                );
            }
            // ---- bounded cold-start regret vs a never-crashed oracle
            let mut oracle = TuningPlane::new(plane_config(
                spec.seed,
                spec.budget,
            ));
            oracle.run_schedules(&phase1, sim_config(), spec.seed);
            let oracle2 =
                oracle.run_schedules(&phase2, sim_config(), spec.seed ^ 1);
            o.cold_regret = if oracle2.sim.makespan > 0.0 {
                report2.sim.makespan / oracle2.sim.makespan - 1.0
            } else {
                0.0
            };
            if o.cold_regret > o.regret_bound {
                fail(
                    &mut o,
                    format!(
                        "cold regret {:.3} over bound {:.3}",
                        o.cold_regret, o.regret_bound
                    ),
                );
            }
        }
        PersistFault::CorruptSnapshot => {
            if o.snapshots_rejected < 1 {
                fail(&mut o, "corrupt snapshot was not rejected".into());
            }
            if o.generation_loaded != Some(1) {
                fail(
                    &mut o,
                    format!(
                        "expected fallback to generation 1, loaded {:?}",
                        o.generation_loaded
                    ),
                );
            }
            if !o.wal_torn_tail {
                fail(&mut o, "torn WAL tail was not detected".into());
            }
        }
    }
    if o.optima_at_crash == 0 {
        fail(&mut o, "phase 1 learned no optima (nothing proven)".into());
    }
    if o.persist_errors > 0 {
        fail(&mut o, format!("{} persistence errors", o.persist_errors));
    }

    o.pass = o.failures.is_empty();
    std::fs::remove_dir_all(&dir).ok();
    o
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_covers_both_crash_families() {
        let sweep = persistence_scenarios(true);
        let names: Vec<&str> = sweep.iter().map(|s| s.name).collect();
        assert_eq!(names, vec!["crash_restart", "corrupt_snapshot"]);
        let full = persistence_scenarios(false);
        assert!(sweep[0].jobs_per_tenant < full[0].jobs_per_tenant);
    }

    #[test]
    fn crash_restart_recovers_everything_and_is_deterministic() {
        let spec = persistence_scenarios(true)
            .into_iter()
            .find(|s| s.fault == PersistFault::CrashRestart)
            .unwrap();
        let a = run_persistence_scenario(&spec);
        assert!(a.pass, "failures: {:?}", a.failures);
        assert_eq!(a.lost_optima, 0);
        assert!(a.digest_match && a.quarantine_preserved);
        assert!(a.warm_tenants >= 1, "{a:?}");
        // same seed → byte-identical artifact
        let b = run_persistence_scenario(&spec);
        assert_eq!(a.to_json().encode(), b.to_json().encode());
    }

    #[test]
    fn corrupt_snapshot_falls_back_and_truncates_the_tail() {
        let spec = persistence_scenarios(true)
            .into_iter()
            .find(|s| s.fault == PersistFault::CorruptSnapshot)
            .unwrap();
        let o = run_persistence_scenario(&spec);
        assert!(o.pass, "failures: {:?}", o.failures);
        assert!(o.snapshots_rejected >= 1);
        assert_eq!(o.generation_loaded, Some(1));
        assert!(o.wal_torn_tail);
        assert_eq!(o.lost_optima, 0);
        assert!(o.digest_match);
    }
}
