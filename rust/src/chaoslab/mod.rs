//! Chaos lab: scripted fault-injection scenarios over the multi-tenant
//! simcluster, scored against a fault-free oracle for
//! graceful-degradation guarantees.
//!
//! The KERMIT MAPE-K loop of PRs 3–5 was built and scored on a healthy
//! cluster. Real shared clusters are not healthy: executors straggle,
//! containers get preempted, tenants churn away mid-queue, workloads
//! drift in coordinated storms, and the knowledge plane itself can rot
//! (stale optima that went pessimal, corrupt entries). The chaos lab
//! makes those failure modes first-class and *repeatable*:
//!
//! * [`scenario`] — [`ScenarioSpec`]: a named, seeded fault plan plus
//!   scripted knowledge-plane attacks and the degradation bounds the
//!   run must hold ([`standard_scenarios`] is the taxonomy sweep);
//! * [`runner`] — [`run_scenario`]: executes the spec twice over
//!   identical workloads (oracle, then faulted) and scores bounded
//!   regret, zero livelocked sessions, poison containment, and cache
//!   recovery;
//! * [`outcome`] — [`ScenarioOutcome`]: the scoreboard, serializable
//!   to deterministic JSON (same seed → same bytes) for CI artifacts;
//! * [`persistence`] — [`RecoveryOutcome`]: crash/recovery scenarios
//!   for the durable knowledge plane (kill-and-restart, corrupt
//!   snapshot + torn WAL tail), proving zero learned-optimum loss up
//!   to the WAL tail and warm restarts;
//! * [`transport`] — [`TransportOutcome`]: transport-chaos scenarios
//!   for the ingest path (lossy/laggy/duplicating link, per-tenant
//!   partitions with heal times, stalled pump, wedged lanes), proving
//!   exactly-once window accounting, bounded regret, and full
//!   supervisor re-arm after heal + reconcile.
//!
//! Everything is seeded through `util::rng::Rng` — a CI failure
//! reproduces locally from the JSON snapshot's seed via
//! `KERMIT_CHAOS_SEED` (see `ScenarioSpec::apply_env`).

pub mod outcome;
pub mod persistence;
pub mod runner;
pub mod scenario;
pub mod transport;

pub use outcome::{diff_outcome_sets, OutcomeDiff, ScenarioOutcome};
pub use persistence::{
    persistence_scenarios, run_persistence_scenario, PersistFault,
    PersistSpec, RecoveryOutcome,
};
pub use runner::run_scenario;
pub use scenario::{
    standard_scenarios, ScenarioSpec, ScenarioStep, StepAction,
};
pub use transport::{
    run_transport_scenario, transport_scenarios, TransportOutcome,
    TransportScenarioSpec,
};
