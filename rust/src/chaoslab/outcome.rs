//! Scenario outcome: the scored comparison of one faulted run against
//! its fault-free oracle, serializable to deterministic JSON (`Json`
//! objects are `BTreeMap`-backed, so same outcome → same bytes — the
//! chaos smoke's reproducibility artifact).

use crate::util::json::{Json, JsonError};

/// The chaos scoreboard for one scenario.
#[derive(Debug, Clone, Default)]
pub struct ScenarioOutcome {
    pub name: String,
    pub seed: u64,

    // ---- workload + makespans -----------------------------------------
    pub oracle_makespan: f64,
    pub faulted_makespan: f64,
    /// Jobs the oracle run completed.
    pub oracle_jobs: usize,
    /// Jobs the faulted run completed (churn/requeue-exhaustion drop
    /// the rest — regret is scored per *completed* job).
    pub faulted_jobs: usize,

    // ---- graceful-degradation score -----------------------------------
    /// `faulted_per_job / oracle_per_job - 1`.
    pub regret: f64,
    pub regret_bound: f64,

    // ---- no-livelock guarantee ----------------------------------------
    pub livelocked_sessions: usize,
    pub pending_decisions: usize,

    // ---- hardening telemetry ------------------------------------------
    pub searches_failed: usize,
    pub probes_timed_out: usize,
    pub probe_jobs_failed: usize,
    pub labels_quarantined: usize,

    // ---- poisoning containment ----------------------------------------
    /// Optima the scenario script poisoned.
    pub db_poisoned: usize,
    /// Entries the scenario script structurally corrupted.
    pub db_corrupted: usize,
    /// Cache hits that served a poisoned optimum.
    pub poison_servings: usize,
    /// Served-poison labels still trusted at run end — must be zero.
    pub unquarantined_poison: usize,
    /// Corrupt entries the integrity audit quarantined.
    pub audit_quarantined: usize,

    // ---- cache recovery -----------------------------------------------
    pub oracle_tail_hit_ratio: f64,
    pub faulted_tail_hit_ratio: f64,
    pub recovery_floor: f64,

    // ---- fault-layer ground truth (faulted run) -----------------------
    pub straggler_jobs: usize,
    pub interference_jobs: usize,
    pub preemptions: usize,
    pub containers_preempted: usize,
    pub regrants: usize,
    pub jobs_failed: usize,
    pub jobs_requeued: usize,
    pub jobs_dropped: usize,
    pub tenants_churned: usize,
    pub drifted_samples: usize,
    pub windows_dropped: u64,

    // ---- loop-health alerts (faulted run) -----------------------------
    /// Alert rules that fired during the faulted run (sorted names).
    pub alerts_fired: Vec<String>,
    /// Alert rules that cleared by the end of the settle evaluations.
    pub alerts_cleared: Vec<String>,
    /// Alerts the fault-free oracle fired — must be zero to pass.
    pub oracle_alerts: usize,

    // ---- verdict ------------------------------------------------------
    pub pass: bool,
    pub failures: Vec<String>,
}

impl ScenarioOutcome {
    /// Deterministic JSON snapshot (same scenario + same seed → byte
    /// identical output; the CI artifact and the determinism test both
    /// rely on this).
    pub fn to_json(&self) -> Json {
        let n = |v: usize| Json::Num(v as f64);
        let mut j = Json::obj();
        j.set("name", Json::Str(self.name.clone()))
            .set("seed", Json::Num(self.seed as f64))
            .set("oracle_makespan", Json::Num(self.oracle_makespan))
            .set("faulted_makespan", Json::Num(self.faulted_makespan))
            .set("oracle_jobs", n(self.oracle_jobs))
            .set("faulted_jobs", n(self.faulted_jobs))
            .set("regret", Json::Num(self.regret))
            .set("regret_bound", Json::Num(self.regret_bound))
            .set("livelocked_sessions", n(self.livelocked_sessions))
            .set("pending_decisions", n(self.pending_decisions))
            .set("searches_failed", n(self.searches_failed))
            .set("probes_timed_out", n(self.probes_timed_out))
            .set("probe_jobs_failed", n(self.probe_jobs_failed))
            .set("labels_quarantined", n(self.labels_quarantined))
            .set("db_poisoned", n(self.db_poisoned))
            .set("db_corrupted", n(self.db_corrupted))
            .set("poison_servings", n(self.poison_servings))
            .set("unquarantined_poison", n(self.unquarantined_poison))
            .set("audit_quarantined", n(self.audit_quarantined))
            .set(
                "oracle_tail_hit_ratio",
                Json::Num(self.oracle_tail_hit_ratio),
            )
            .set(
                "faulted_tail_hit_ratio",
                Json::Num(self.faulted_tail_hit_ratio),
            )
            .set("recovery_floor", Json::Num(self.recovery_floor))
            .set("straggler_jobs", n(self.straggler_jobs))
            .set("interference_jobs", n(self.interference_jobs))
            .set("preemptions", n(self.preemptions))
            .set("containers_preempted", n(self.containers_preempted))
            .set("regrants", n(self.regrants))
            .set("jobs_failed", n(self.jobs_failed))
            .set("jobs_requeued", n(self.jobs_requeued))
            .set("jobs_dropped", n(self.jobs_dropped))
            .set("tenants_churned", n(self.tenants_churned))
            .set("drifted_samples", n(self.drifted_samples))
            .set("windows_dropped", Json::Num(self.windows_dropped as f64))
            .set(
                "alerts_fired",
                Json::Arr(
                    self.alerts_fired
                        .iter()
                        .map(|a| Json::Str(a.clone()))
                        .collect(),
                ),
            )
            .set(
                "alerts_cleared",
                Json::Arr(
                    self.alerts_cleared
                        .iter()
                        .map(|a| Json::Str(a.clone()))
                        .collect(),
                ),
            )
            .set("oracle_alerts", n(self.oracle_alerts))
            .set("pass", Json::Bool(self.pass))
            .set(
                "failures",
                Json::Arr(
                    self.failures
                        .iter()
                        .map(|f| Json::Str(f.clone()))
                        .collect(),
                ),
            );
        j
    }
}

/// Result of diffing two outcome snapshots (the same contract as
/// `benchkit::BaselineDiff`: compare only under a matching sweep,
/// skip cleanly otherwise).
#[derive(Debug, Clone, PartialEq)]
pub enum OutcomeDiff {
    /// The two snapshots ran different sweeps (scenario names or seeds
    /// differ — e.g. a `KERMIT_CHAOS_SEED` override, or a smoke run
    /// diffed against a full-scale baseline). Comparing them would be
    /// noise, so the differ skips.
    MetaMismatch {
        /// `(name, baseline seed, current seed)`; a missing side is
        /// `u64::MAX`.
        scenarios: Vec<(String, u64, u64)>,
    },
    /// Same sweep: per-scenario field-level comparison ran.
    Compared {
        /// Scenarios whose snapshots are byte-identical.
        unchanged: usize,
        /// `(scenario, field, baseline value, current value)` for every
        /// field that drifted.
        drifted: Vec<(String, String, String, String)>,
    },
}

/// Diff two `CHAOS_outcomes.json`-shaped snapshots (arrays of
/// [`ScenarioOutcome::to_json`] objects — `PERSIST_outcomes.json` has
/// the same shape and diffs with the same function).
///
/// Mirrors `benchkit::diff_baselines`' skip-on-meta-mismatch idiom:
/// the sweep identity (scenario name + seed set) plays the role of
/// `meta`, and only matching sweeps are compared field by field. The
/// outcomes are fully deterministic (same seed → same bytes), so ANY
/// drift under a matching sweep is a real behaviour change and the
/// differ reports every drifted field.
pub fn diff_outcome_sets(
    baseline: &Json,
    current: &Json,
) -> Result<OutcomeDiff, JsonError> {
    fn index(
        snapshot: &Json,
    ) -> Result<Vec<(String, u64, &Json)>, JsonError> {
        let mut out = Vec::new();
        for o in snapshot.as_arr()? {
            let name = o.get("name")?.as_str()?.to_string();
            let seed = o.get("seed")?.as_f64()? as u64;
            out.push((name, seed, o));
        }
        Ok(out)
    }
    let base = index(baseline)?;
    let cur = index(current)?;

    // sweep identity: same scenario names with the same seeds
    let base_ids: Vec<(String, u64)> =
        base.iter().map(|(n, s, _)| (n.clone(), *s)).collect();
    let cur_ids: Vec<(String, u64)> =
        cur.iter().map(|(n, s, _)| (n.clone(), *s)).collect();
    if base_ids != cur_ids {
        let names: std::collections::BTreeSet<String> = base_ids
            .iter()
            .chain(cur_ids.iter())
            .map(|(n, _)| n.clone())
            .collect();
        let side = |ids: &[(String, u64)], n: &str| {
            ids.iter()
                .find(|(name, _)| name == n)
                .map(|(_, s)| *s)
                .unwrap_or(u64::MAX)
        };
        return Ok(OutcomeDiff::MetaMismatch {
            scenarios: names
                .into_iter()
                .map(|n| {
                    let b = side(&base_ids, &n);
                    let c = side(&cur_ids, &n);
                    (n, b, c)
                })
                .collect(),
        });
    }

    let mut unchanged = 0usize;
    let mut drifted = Vec::new();
    for ((name, _, b), (_, _, c)) in base.iter().zip(cur.iter()) {
        if b.encode() == c.encode() {
            unchanged += 1;
            continue;
        }
        let bo = b.as_obj()?;
        let co = c.as_obj()?;
        let keys: std::collections::BTreeSet<&String> =
            bo.keys().chain(co.keys()).collect();
        for k in keys {
            let bv = bo.get(k).map(Json::encode);
            let cv = co.get(k).map(Json::encode);
            if bv != cv {
                drifted.push((
                    name.clone(),
                    k.clone(),
                    bv.unwrap_or_else(|| "<absent>".into()),
                    cv.unwrap_or_else(|| "<absent>".into()),
                ));
            }
        }
    }
    Ok(OutcomeDiff::Compared { unchanged, drifted })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outcome_json_is_deterministic_and_complete() {
        let mut o = ScenarioOutcome::default();
        o.name = "demo".into();
        o.seed = 7;
        o.regret = 0.25;
        o.pass = true;
        o.failures = vec!["x".into()];
        let a = o.to_json().encode();
        let b = o.to_json().encode();
        assert_eq!(a, b);
        // BTreeMap ordering: keys come out sorted, so the verdict and
        // the score are both present and stable
        assert!(a.contains("\"name\":\"demo\""), "{a}");
        assert!(a.contains("\"regret\":0.25"), "{a}");
        assert!(a.contains("\"pass\":true"), "{a}");
        assert!(a.contains("\"failures\":[\"x\"]"), "{a}");
    }

    fn snapshot(pairs: &[(&str, u64, f64)]) -> Json {
        Json::Arr(
            pairs
                .iter()
                .map(|(n, s, r)| {
                    let mut o = ScenarioOutcome::default();
                    o.name = n.to_string();
                    o.seed = *s;
                    o.regret = *r;
                    o.to_json()
                })
                .collect(),
        )
    }

    #[test]
    fn matching_sweeps_diff_field_by_field() {
        let base = snapshot(&[("a", 1, 0.1), ("b", 2, 0.2)]);
        let same = snapshot(&[("a", 1, 0.1), ("b", 2, 0.2)]);
        assert_eq!(
            diff_outcome_sets(&base, &same).unwrap(),
            OutcomeDiff::Compared { unchanged: 2, drifted: vec![] }
        );
        let moved = snapshot(&[("a", 1, 0.1), ("b", 2, 0.5)]);
        let Ok(OutcomeDiff::Compared { unchanged, drifted }) =
            diff_outcome_sets(&base, &moved)
        else {
            panic!("expected a comparison");
        };
        assert_eq!(unchanged, 1);
        assert_eq!(drifted.len(), 1);
        let (scenario, field, was, now) = &drifted[0];
        assert_eq!((scenario.as_str(), field.as_str()), ("b", "regret"));
        assert_eq!((was.as_str(), now.as_str()), ("0.2", "0.5"));
    }

    #[test]
    fn different_sweeps_skip_as_meta_mismatch() {
        let base = snapshot(&[("a", 1, 0.1)]);
        // seed override: same scenario, different seed
        let reseeded = snapshot(&[("a", 9, 0.1)]);
        assert!(matches!(
            diff_outcome_sets(&base, &reseeded).unwrap(),
            OutcomeDiff::MetaMismatch { .. }
        ));
        // different scenario set entirely
        let other = snapshot(&[("z", 1, 0.1)]);
        let Ok(OutcomeDiff::MetaMismatch { scenarios }) =
            diff_outcome_sets(&base, &other)
        else {
            panic!("expected a meta mismatch");
        };
        assert_eq!(scenarios.len(), 2);
        assert!(scenarios.contains(&("a".into(), 1, u64::MAX)));
        assert!(scenarios.contains(&("z".into(), u64::MAX, 1)));
    }

    #[test]
    fn malformed_snapshots_are_an_error_not_a_panic() {
        assert!(
            diff_outcome_sets(&Json::Num(3.0), &Json::Arr(vec![])).is_err()
        );
        let missing_name = Json::Arr(vec![Json::obj()]);
        assert!(
            diff_outcome_sets(&missing_name, &missing_name).is_err()
        );
    }
}
