//! Scenario outcome: the scored comparison of one faulted run against
//! its fault-free oracle, serializable to deterministic JSON (`Json`
//! objects are `BTreeMap`-backed, so same outcome → same bytes — the
//! chaos smoke's reproducibility artifact).

use crate::util::json::Json;

/// The chaos scoreboard for one scenario.
#[derive(Debug, Clone, Default)]
pub struct ScenarioOutcome {
    pub name: String,
    pub seed: u64,

    // ---- workload + makespans -----------------------------------------
    pub oracle_makespan: f64,
    pub faulted_makespan: f64,
    /// Jobs the oracle run completed.
    pub oracle_jobs: usize,
    /// Jobs the faulted run completed (churn/requeue-exhaustion drop
    /// the rest — regret is scored per *completed* job).
    pub faulted_jobs: usize,

    // ---- graceful-degradation score -----------------------------------
    /// `faulted_per_job / oracle_per_job - 1`.
    pub regret: f64,
    pub regret_bound: f64,

    // ---- no-livelock guarantee ----------------------------------------
    pub livelocked_sessions: usize,
    pub pending_decisions: usize,

    // ---- hardening telemetry ------------------------------------------
    pub searches_failed: usize,
    pub probes_timed_out: usize,
    pub probe_jobs_failed: usize,
    pub labels_quarantined: usize,

    // ---- poisoning containment ----------------------------------------
    /// Optima the scenario script poisoned.
    pub db_poisoned: usize,
    /// Entries the scenario script structurally corrupted.
    pub db_corrupted: usize,
    /// Cache hits that served a poisoned optimum.
    pub poison_servings: usize,
    /// Served-poison labels still trusted at run end — must be zero.
    pub unquarantined_poison: usize,
    /// Corrupt entries the integrity audit quarantined.
    pub audit_quarantined: usize,

    // ---- cache recovery -----------------------------------------------
    pub oracle_tail_hit_ratio: f64,
    pub faulted_tail_hit_ratio: f64,
    pub recovery_floor: f64,

    // ---- fault-layer ground truth (faulted run) -----------------------
    pub straggler_jobs: usize,
    pub interference_jobs: usize,
    pub preemptions: usize,
    pub containers_preempted: usize,
    pub regrants: usize,
    pub jobs_failed: usize,
    pub jobs_requeued: usize,
    pub jobs_dropped: usize,
    pub tenants_churned: usize,
    pub drifted_samples: usize,
    pub windows_dropped: u64,

    // ---- verdict ------------------------------------------------------
    pub pass: bool,
    pub failures: Vec<String>,
}

impl ScenarioOutcome {
    /// Deterministic JSON snapshot (same scenario + same seed → byte
    /// identical output; the CI artifact and the determinism test both
    /// rely on this).
    pub fn to_json(&self) -> Json {
        let n = |v: usize| Json::Num(v as f64);
        let mut j = Json::obj();
        j.set("name", Json::Str(self.name.clone()))
            .set("seed", Json::Num(self.seed as f64))
            .set("oracle_makespan", Json::Num(self.oracle_makespan))
            .set("faulted_makespan", Json::Num(self.faulted_makespan))
            .set("oracle_jobs", n(self.oracle_jobs))
            .set("faulted_jobs", n(self.faulted_jobs))
            .set("regret", Json::Num(self.regret))
            .set("regret_bound", Json::Num(self.regret_bound))
            .set("livelocked_sessions", n(self.livelocked_sessions))
            .set("pending_decisions", n(self.pending_decisions))
            .set("searches_failed", n(self.searches_failed))
            .set("probes_timed_out", n(self.probes_timed_out))
            .set("probe_jobs_failed", n(self.probe_jobs_failed))
            .set("labels_quarantined", n(self.labels_quarantined))
            .set("db_poisoned", n(self.db_poisoned))
            .set("db_corrupted", n(self.db_corrupted))
            .set("poison_servings", n(self.poison_servings))
            .set("unquarantined_poison", n(self.unquarantined_poison))
            .set("audit_quarantined", n(self.audit_quarantined))
            .set(
                "oracle_tail_hit_ratio",
                Json::Num(self.oracle_tail_hit_ratio),
            )
            .set(
                "faulted_tail_hit_ratio",
                Json::Num(self.faulted_tail_hit_ratio),
            )
            .set("recovery_floor", Json::Num(self.recovery_floor))
            .set("straggler_jobs", n(self.straggler_jobs))
            .set("interference_jobs", n(self.interference_jobs))
            .set("preemptions", n(self.preemptions))
            .set("containers_preempted", n(self.containers_preempted))
            .set("regrants", n(self.regrants))
            .set("jobs_failed", n(self.jobs_failed))
            .set("jobs_requeued", n(self.jobs_requeued))
            .set("jobs_dropped", n(self.jobs_dropped))
            .set("tenants_churned", n(self.tenants_churned))
            .set("drifted_samples", n(self.drifted_samples))
            .set("windows_dropped", Json::Num(self.windows_dropped as f64))
            .set("pass", Json::Bool(self.pass))
            .set(
                "failures",
                Json::Arr(
                    self.failures
                        .iter()
                        .map(|f| Json::Str(f.clone()))
                        .collect(),
                ),
            );
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outcome_json_is_deterministic_and_complete() {
        let mut o = ScenarioOutcome::default();
        o.name = "demo".into();
        o.seed = 7;
        o.regret = 0.25;
        o.pass = true;
        o.failures = vec!["x".into()];
        let a = o.to_json().encode();
        let b = o.to_json().encode();
        assert_eq!(a, b);
        // BTreeMap ordering: keys come out sorted, so the verdict and
        // the score are both present and stable
        assert!(a.contains("\"name\":\"demo\""), "{a}");
        assert!(a.contains("\"regret\":0.25"), "{a}");
        assert!(a.contains("\"pass\":true"), "{a}");
        assert!(a.contains("\"failures\":[\"x\"]"), "{a}");
    }
}
