//! Scenario runner: executes a [`ScenarioSpec`] twice over identical
//! workloads — once fault-free (the oracle), once with the fault plan
//! and scripted knowledge-plane attacks — and scores the faulted run's
//! graceful degradation against the oracle:
//!
//! * **bounded regret** — per-completed-job makespan within the spec's
//!   `regret_bound` of the oracle;
//! * **no livelock** — zero plug-ins still waiting on a probe after the
//!   run drains (the decision-timeout / failure-edge hardening);
//! * **poison containment** — a poisoned optimum that was actually
//!   served ends the run quarantined or re-searched, never still
//!   trusted; structurally corrupt entries never survive the audit;
//! * **cache recovery** — the tail cache-hit ratio holds the spec's
//!   floor relative to the oracle (where the scenario asserts one).

use super::outcome::ScenarioOutcome;
use super::scenario::{ScenarioSpec, ScenarioStep, StepAction};
use crate::experiments::tuning_plane::{plane_config, schedules, sim_config};
use crate::obs::{chaos_rules, AlertEngine, AlertEvent, AlertState, Registry};
use crate::online::ChoiceKind;
use crate::simcluster::config_space::{ConfigIndex, TuningConfig};
use crate::simcluster::fault::FaultReport;
use crate::simcluster::multi::{MultiClusterEngine, TenantRmPlugin};
use crate::simcluster::rm::{ResourceManager, ResourceRequest};
use crate::stream::TenantId;
use crate::tuning::{TuningPlane, TuningRunReport};
use crate::workloadgen::Sample;

/// The pessimal config the `PoisonOptimum` step plants: minimum
/// everything — in-grid and structurally valid, so only the *semantic*
/// poison detector can catch it.
fn poison_config() -> ConfigIndex {
    ConfigIndex([0, 0, 0, 0, 0, 0])
}

/// First alert evaluation (sim seconds). Late enough that the oracle
/// is past its all-UNKNOWN cold start and the knowledge guard on the
/// UNKNOWN-rate rule has real data behind it.
const ALERT_EVAL_START: f64 = 600.0;
/// Evaluation cadence (sim seconds) after the first evaluation.
const ALERT_EVAL_CADENCE: f64 = 200.0;

/// Wraps the tuning plane as the engine's plug-in hub and fires the
/// scenario's scripted knowledge-plane steps once sim time crosses
/// their `at` (checked on every callback edge).
struct ChaosHub {
    plane: TuningPlane,
    steps: Vec<ScenarioStep>,
    next_step: usize,
    /// Labels `PoisonOptimum` overwrote.
    poisoned: Vec<u32>,
    /// Labels `CorruptEntry` broke.
    corrupted: Vec<u32>,
    /// Cache hits that served a poisoned optimum after planting.
    poison_servings: usize,
    /// Scrape target for the loop-health alert rules.
    telemetry: Registry,
    /// The chaos rule set, evaluated on the sim-time cadence.
    alerts: AlertEngine,
    /// Every fire/clear transition the run produced, in order.
    alert_events: Vec<AlertEvent>,
    /// Next sim time an alert evaluation is due.
    next_eval: f64,
}

impl ChaosHub {
    fn new(
        plane: TuningPlane,
        steps: Vec<ScenarioStep>,
        telemetry: Registry,
    ) -> ChaosHub {
        ChaosHub {
            plane,
            steps,
            next_step: 0,
            poisoned: Vec::new(),
            corrupted: Vec::new(),
            poison_servings: 0,
            telemetry,
            alerts: AlertEngine::new(chaos_rules()),
            alert_events: Vec::new(),
            next_eval: ALERT_EVAL_START,
        }
    }

    /// Run ONE alert evaluation if a grid point has been crossed, then
    /// skip the grid past `now` — a long gap between callbacks must
    /// not replay stale evaluations (zero-delta catch-ups would reset
    /// breach streaks and clear alerts spuriously).
    fn eval_alerts_due(&mut self, now: f64) {
        if !now.is_finite() || self.next_eval > now {
            return;
        }
        let at = self.next_eval;
        while self.next_eval <= now {
            self.next_eval += ALERT_EVAL_CADENCE;
        }
        self.plane.scrape(&self.telemetry);
        self.alert_events.extend(self.alerts.eval(&self.telemetry, at));
    }

    /// Forced post-run evaluation (the settle passes after drain /
    /// reconcile / audit): scrape and evaluate unconditionally.
    fn settle_eval(&mut self, at: f64) {
        self.plane.scrape(&self.telemetry);
        self.alert_events.extend(self.alerts.eval(&self.telemetry, at));
    }

    /// Fire every scripted step whose time has come.
    fn fire_due(&mut self, now: f64) {
        self.eval_alerts_due(now);
        while self.next_step < self.steps.len()
            && self.steps[self.next_step].at <= now
        {
            let action = self.steps[self.next_step].action;
            self.next_step += 1;
            match action {
                StepAction::PoisonOptimum => {
                    // overwrite the lowest trusted optimum with the
                    // pessimal config and a wildly optimistic measured
                    // duration — the worst case for cache reuse. If no
                    // search has converged yet, plant the poison on the
                    // lowest unquarantined label instead (a rotted
                    // entry that *claims* a trusted optimum is exactly
                    // what a stale knowledge plane looks like).
                    let mut db = self.plane.coord.db.write().unwrap();
                    let labels = db.labels();
                    let target = labels
                        .iter()
                        .copied()
                        .filter(|&l| {
                            db.get(l).is_some_and(|e| {
                                e.optimal_config_found && !e.quarantined
                            })
                        })
                        .min()
                        .or_else(|| {
                            labels
                                .iter()
                                .copied()
                                .filter(|&l| {
                                    db.get(l)
                                        .is_some_and(|e| !e.quarantined)
                                })
                                .min()
                        });
                    if let Some(label) = target {
                        let e = db.get_mut(label).unwrap();
                        e.config = Some(poison_config());
                        e.best_duration = Some(1.0);
                        e.optimal_config_found = true;
                        self.poisoned.push(label);
                    }
                }
                StepAction::CorruptEntry => {
                    // break the highest label's centroid — structural
                    // corruption the integrity audit must quarantine
                    let mut db = self.plane.coord.db.write().unwrap();
                    let target = db
                        .labels()
                        .into_iter()
                        .filter(|&l| {
                            db.get(l).is_some_and(|e| !e.quarantined)
                        })
                        .max();
                    if let Some(label) = target {
                        let e = db.get_mut(label).unwrap();
                        if !e.centroid.is_empty() {
                            e.centroid[0] = f64::NAN;
                        }
                        self.corrupted.push(label);
                    }
                }
                // flash crowds are workload, staged pre-run in BOTH
                // the oracle and the faulted run — nothing to do here
                StepAction::FlashCrowd { .. } => {}
            }
        }
    }
}

impl TenantRmPlugin for ChaosHub {
    fn on_samples(&mut self, t: TenantId, samples: &[Sample]) {
        if let Some(s) = samples.last() {
            self.fire_due(s.time);
        }
        self.plane.on_samples(t, samples);
    }

    fn on_resource_request(
        &mut self,
        t: TenantId,
        req: &ResourceRequest,
    ) -> TuningConfig {
        self.fire_due(req.time);
        let (config, kind) = self.plane.decide(t, req.app_id, req.time);
        if kind == ChoiceKind::CacheHit
            && !self.poisoned.is_empty()
            && config == poison_config()
        {
            self.poison_servings += 1;
        }
        config.to_config()
    }

    fn on_app_complete(
        &mut self,
        t: TenantId,
        app_id: u64,
        duration: f64,
        now: f64,
    ) {
        self.fire_due(now);
        self.plane.complete(t, app_id, duration);
    }

    fn on_grant(&mut self, t: TenantId, app_id: u64, granted: u32) {
        self.plane.on_grant(t, app_id, granted);
    }

    fn on_app_fail(&mut self, t: TenantId, app_id: u64, now: f64) {
        self.fire_due(now);
        self.plane.on_app_fail(t, app_id, now);
    }
}

/// Everything one run (oracle or faulted) contributes to the score.
struct RunArtifacts {
    report: TuningRunReport,
    fault_report: FaultReport,
    jobs_completed: usize,
    pending_decisions: usize,
    tail_hit_ratio: f64,
    poisoned: usize,
    corrupted: usize,
    poison_servings: usize,
    unquarantined_poison: usize,
    unquarantined_corrupt: usize,
    audit_quarantined: usize,
    /// Alert rules that fired at least once (sorted, deduped).
    alerts_fired: Vec<String>,
    /// Alert rules that cleared at least once (sorted, deduped).
    alerts_cleared: Vec<String>,
}

/// Pooled cache-hit ratio over the last `window` decisions of every
/// tenant — the recovery observable (did the loop get back to serving
/// optima after the faults, or is it still flailing on defaults?).
pub(crate) fn tail_hit_ratio(plane: &TuningPlane, window: usize) -> f64 {
    let mut hits = 0usize;
    let mut total = 0usize;
    for t in plane.tenant_ids() {
        if let Some(choices) = plane.choices(t) {
            let tail = &choices[choices.len().saturating_sub(window)..];
            total += tail.len();
            hits += tail
                .iter()
                .filter(|k| **k == ChoiceKind::CacheHit)
                .count();
        }
    }
    crate::obs::ratio(hits as f64, total as f64)
}

fn run_one(spec: &ScenarioSpec, with_faults: bool) -> RunArtifacts {
    let mut plane = TuningPlane::new(plane_config(spec.seed, spec.budget));
    // the containment guarantee under test is "a poisoned optimum is
    // served at most `poison_strikes` times": the lab pins one strike
    // so a single bad full-fleet serving must quarantine the label
    plane.resilience.poison_strikes = 1;
    let scheds = schedules(
        spec.seed,
        spec.tenants,
        spec.jobs_per_tenant,
        &spec.classes,
    );
    let mut engine = MultiClusterEngine::new(
        ResourceManager::default_cluster(),
        sim_config(),
        spec.seed,
    );
    if with_faults {
        engine.set_faults(spec.faults.clone());
    }
    for (t, jobs) in &scheds {
        plane.ensure_tenant(*t);
        engine.push_jobs(*t, jobs);
    }
    // flash crowds are part of the workload, so both runs stage them —
    // the fault plan is the only thing that differs between runs
    let mut crowd_base = spec.tenants as u32;
    for step in &spec.steps {
        if let StepAction::FlashCrowd { tenants, jobs } = step.action {
            let crowd = schedules(
                spec.seed ^ 0xF1A5_C0DE,
                tenants,
                jobs,
                &spec.classes,
            );
            for (k, (_, jobs)) in crowd.iter().enumerate() {
                let t = TenantId(crowd_base + k as u32);
                plane.ensure_tenant(t);
                engine.push_jobs_at(t, jobs, step.at);
            }
            crowd_base += tenants as u32;
        }
    }
    // knowledge-plane attacks only fire in the faulted run; the alert
    // engine runs in BOTH runs — the oracle must stay silent, which is
    // exactly what makes a faulted-run alert a signal
    let telemetry = Registry::default();
    plane.enable_telemetry(&telemetry);
    let steps = if with_faults { spec.steps.clone() } else { Vec::new() };
    let mut hub = ChaosHub::new(plane, steps, telemetry);
    let sim = engine.run(&mut hub);
    let fault_report = *engine.fault_report();

    // force any step the run ended before (a corrupt entry must always
    // be planted so the audit is always on the hook for it), then
    // settle: drain the shards, write off dangling decisions, audit
    hub.fire_due(f64::INFINITY);
    hub.plane.drain();
    let timeout = hub.plane.resilience.decision_timeout;
    hub.plane.reconcile(sim.makespan + timeout + 1.0);
    let audit_quarantined = hub.plane.audit_knowledge().len();
    // settle evaluations: the first lands every post-run delta (the
    // final audit's quarantines, late probe write-offs) so burst rules
    // get their last chance to fire; the second sees a quiescent
    // registry, so everything still active must clear
    let settle_at = sim.makespan.max(hub.next_eval);
    hub.settle_eval(settle_at);
    hub.settle_eval(settle_at + ALERT_EVAL_CADENCE);

    let jobs_completed =
        sim.per_tenant.values().map(|l| l.jobs.len()).sum();
    let pending_decisions = hub.plane.pending_decisions();
    let tail = tail_hit_ratio(&hub.plane, spec.recovery_window);
    // containment: a poisoned label that was actually served must end
    // the run quarantined or re-searched — never still trusted with
    // the planted config (a never-served poison did no harm and waits
    // for its first serving to be caught)
    let (unquarantined_poison, unquarantined_corrupt) = {
        let db = hub.plane.coord.db.read().unwrap();
        let poison = if hub.poison_servings == 0 {
            0
        } else {
            hub.poisoned
                .iter()
                .filter(|&&l| {
                    db.get(l).is_some_and(|e| {
                        !e.quarantined
                            && e.optimal_config_found
                            && e.config == Some(poison_config())
                    })
                })
                .count()
        };
        // a structurally corrupt entry must be quarantined by SOME
        // audit (mid-run off-line cycle or the final sweep) — checked
        // against the db directly, not against sweep counters
        let corrupt = hub
            .corrupted
            .iter()
            .filter(|&&l| db.get(l).is_some_and(|e| !e.quarantined))
            .count();
        (poison, corrupt)
    };
    let collect_alerts = |want: AlertState| {
        let mut names: Vec<String> = hub
            .alert_events
            .iter()
            .filter(|e| e.state == want)
            .map(|e| e.rule.clone())
            .collect();
        names.sort();
        names.dedup();
        names
    };
    let alerts_fired = collect_alerts(AlertState::Fired);
    let alerts_cleared = collect_alerts(AlertState::Cleared);
    RunArtifacts {
        report: hub.plane.report(sim),
        fault_report,
        jobs_completed,
        pending_decisions,
        tail_hit_ratio: tail,
        poisoned: hub.poisoned.len(),
        corrupted: hub.corrupted.len(),
        poison_servings: hub.poison_servings,
        unquarantined_poison,
        unquarantined_corrupt,
        audit_quarantined,
        alerts_fired,
        alerts_cleared,
    }
}

/// Run one scenario: oracle first, then the faulted run, then score.
pub fn run_scenario(spec: &ScenarioSpec) -> ScenarioOutcome {
    let oracle = run_one(spec, false);
    let faulted = run_one(spec, true);

    let per_job = |makespan: f64, jobs: usize| makespan / jobs.max(1) as f64;
    let oracle_per_job =
        per_job(oracle.report.makespan(), oracle.jobs_completed).max(1e-9);
    let faulted_per_job =
        per_job(faulted.report.makespan(), faulted.jobs_completed);
    let regret = faulted_per_job / oracle_per_job - 1.0;

    let mut failures = Vec::new();
    if !(regret <= spec.regret_bound) {
        failures.push(format!(
            "regret {regret:.3} exceeds bound {:.3}",
            spec.regret_bound
        ));
    }
    if faulted.report.livelocked_sessions != 0 {
        failures.push(format!(
            "{} sessions livelocked after drain",
            faulted.report.livelocked_sessions
        ));
    }
    if faulted.pending_decisions != 0 {
        failures.push(format!(
            "{} decisions still pending after reconcile",
            faulted.pending_decisions
        ));
    }
    if faulted.unquarantined_poison != 0 {
        failures.push(format!(
            "{} served poisoned optima still trusted at run end",
            faulted.unquarantined_poison
        ));
    }
    if faulted.unquarantined_corrupt != 0 {
        failures.push(format!(
            "{} corrupt entries survived the audit",
            faulted.unquarantined_corrupt
        ));
    }
    // loop-health alerts: the fault-free oracle must never page, and
    // every alert the spec expects must both fire while faulted and
    // clear by the end of the settle evaluations
    if !oracle.alerts_fired.is_empty() {
        failures.push(format!(
            "oracle fired alerts: {}",
            oracle.alerts_fired.join(", ")
        ));
    }
    for a in &spec.expect_alerts {
        if !faulted.alerts_fired.iter().any(|f| f == a) {
            failures.push(format!("expected alert {a} never fired"));
        }
        if !faulted.alerts_cleared.iter().any(|f| f == a) {
            failures.push(format!("alert {a} did not clear by run end"));
        }
    }
    if spec.recovery_floor > 0.0
        && faulted.tail_hit_ratio + 1e-9
            < spec.recovery_floor * oracle.tail_hit_ratio
    {
        failures.push(format!(
            "tail cache-hit ratio {:.3} below {:.2}x oracle ({:.3})",
            faulted.tail_hit_ratio,
            spec.recovery_floor,
            oracle.tail_hit_ratio
        ));
    }

    let fr = faulted.fault_report;
    ScenarioOutcome {
        name: spec.name.to_string(),
        seed: spec.seed,
        oracle_makespan: oracle.report.makespan(),
        faulted_makespan: faulted.report.makespan(),
        oracle_jobs: oracle.jobs_completed,
        faulted_jobs: faulted.jobs_completed,
        regret,
        regret_bound: spec.regret_bound,
        livelocked_sessions: faulted.report.livelocked_sessions,
        pending_decisions: faulted.pending_decisions,
        searches_failed: faulted.report.searches_failed,
        probes_timed_out: faulted.report.probes_timed_out,
        probe_jobs_failed: faulted.report.probe_jobs_failed,
        labels_quarantined: faulted.report.labels_quarantined,
        db_poisoned: faulted.poisoned,
        db_corrupted: faulted.corrupted,
        poison_servings: faulted.poison_servings,
        unquarantined_poison: faulted.unquarantined_poison,
        audit_quarantined: faulted.audit_quarantined,
        oracle_tail_hit_ratio: oracle.tail_hit_ratio,
        faulted_tail_hit_ratio: faulted.tail_hit_ratio,
        recovery_floor: spec.recovery_floor,
        straggler_jobs: fr.straggler_jobs,
        interference_jobs: fr.interference_jobs,
        preemptions: fr.preemptions,
        containers_preempted: fr.containers_preempted,
        regrants: fr.regrants,
        jobs_failed: fr.jobs_failed,
        jobs_requeued: fr.jobs_requeued,
        jobs_dropped: fr.jobs_dropped,
        tenants_churned: fr.tenants_churned,
        drifted_samples: fr.drifted_samples,
        windows_dropped: faulted.report.multi.windows_dropped,
        alerts_fired: faulted.alerts_fired,
        alerts_cleared: faulted.alerts_cleared,
        oracle_alerts: oracle.alerts_fired.len(),
        pass: failures.is_empty(),
        failures,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simcluster::fault::StragglerFault;

    /// Tiny spec so unit tests stay fast; experiments::chaos runs the
    /// standard sweep.
    fn tiny(name: &'static str, seed: u64) -> ScenarioSpec {
        let mut s = ScenarioSpec::base(name, seed, true);
        s.tenants = 2;
        s.jobs_per_tenant = 5;
        s.budget = 8;
        s
    }

    #[test]
    fn oracle_equals_inert_faulted_run() {
        // a spec with no faults and no steps: the "faulted" run IS the
        // oracle (the fault layer draws zero RNG), so regret is ~0 and
        // every guarantee holds trivially
        let spec = tiny("inert", 31);
        let o = run_scenario(&spec);
        assert!(o.pass, "failures: {:?}", o.failures);
        assert!(o.regret.abs() < 1e-9, "regret {}", o.regret);
        assert_eq!(o.oracle_makespan, o.faulted_makespan);
        assert_eq!(o.oracle_jobs, o.faulted_jobs);
        assert_eq!(o.livelocked_sessions, 0);
        assert_eq!(o.preemptions, 0);
        assert_eq!(o.straggler_jobs, 0);
    }

    #[test]
    fn straggler_run_degrades_but_stays_bounded() {
        let mut spec = tiny("mini_stragglers", 32);
        spec.faults.stragglers =
            Some(StragglerFault { prob: 0.3, slowdown: 2.0 });
        spec.regret_bound = 3.0;
        let o = run_scenario(&spec);
        // the fault layer actually did something, and the faulted run
        // is not the oracle
        assert!(o.straggler_jobs > 0, "{o:?}");
        assert!(o.faulted_makespan > o.oracle_makespan, "{o:?}");
        // ...yet degradation stayed within the documented guarantees
        assert!(o.pass, "failures: {:?}", o.failures);
        assert_eq!(o.livelocked_sessions, 0);
        assert_eq!(o.pending_decisions, 0);
    }
}
