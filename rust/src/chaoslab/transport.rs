//! Transport-chaos scenarios: the ingest path under a faulty link.
//!
//! `runner` chaos-tests the *executor* side of the MAPE-K loop (jobs
//! straggle, containers die); this module chaos-tests the *transport*
//! between tenant producers and the tuning plane's ingest front-end —
//! samples dropped, delayed/reordered, duplicated, or cut off by a
//! per-tenant partition, plus consumer-side faults (a stalled pump, a
//! wedged lane worker). Every run drives the full closed loop through
//! a [`TransportLayer`] into an attached [`IngestFrontEnd`] with the
//! supervision stack live (sequence-numbered dedup/reorder, per-tenant
//! watchdogs, retry backoff, degraded mode), and is scored against a
//! fault-free oracle:
//!
//! * **bounded regret** — per-completed-job makespan within the spec's
//!   bound of the oracle, despite the lossy/laggy link;
//! * **zero double-counted windows** — at-least-once delivery never
//!   inflates the label timeline: per tenant, published windows never
//!   exceed `accepted / window_size`, and the sequence-fate accounting
//!   (`accepted + gaps_skipped + shed + closed_rejects ≤ sent`, exact
//!   for lossless plans) proves no sequence was delivered twice;
//! * **injected ≥ observed** — the transport's ground-truth fault
//!   report reconciles with the consumer-side counters (dedup hits
//!   bounded by duplicates + late releases, write-offs bounded by
//!   drops + partitions + delays, delivery totals exact);
//! * **no wedged lanes, no permanently-degraded tenants** — after heal
//!   + `reconcile_ingest`, every queue is empty and every tenant is
//!   back to `TenantHealth::Healthy`;
//! * **label-timeline convergence** — where the spec asserts a
//!   recovery floor, the faulted run's tail cache-hit ratio holds it
//!   relative to the oracle (label-renaming-agnostic: ratios, never
//!   label ids, which are per-run discovery order).

use super::runner::tail_hit_ratio;
use crate::experiments::tuning_plane::{plane_config, schedules, sim_config};
use crate::simcluster::config_space::TuningConfig;
use crate::simcluster::multi::{MultiClusterEngine, TenantRmPlugin};
use crate::simcluster::rm::{ResourceManager, ResourceRequest};
use crate::stream::{
    IngestConfig, IngestHandle, ShedPolicy, TenantId, TenantIngestStats,
    TransportFaultPlan, TransportFaultReport, TransportLayer,
};
use crate::tuning::{TuningPlane, TuningRunReport};
use crate::util::json::Json;
use crate::workloadgen::Sample;

/// One transport-chaos scenario: workload scale, the transport fault
/// plan, the watchdog deadline, and the degradation bounds the faulted
/// run must satisfy against its fault-free oracle.
#[derive(Debug, Clone)]
pub struct TransportScenarioSpec {
    pub name: &'static str,
    pub seed: u64,
    pub tenants: usize,
    pub jobs_per_tenant: usize,
    pub classes: Vec<u32>,
    /// Explorer global budget (local budget derives from it).
    pub budget: usize,
    pub transport: TransportFaultPlan,
    /// Watchdog no-progress deadline (sim time a tenant's delivery
    /// watermark may lag the cluster frontier before the supervisor
    /// demotes it). Finite here — the scenarios opt in to the silence
    /// watchdog that production defaults leave off.
    pub silence_after: f64,
    /// Max allowed per-completed-job makespan regret vs the oracle.
    pub regret_bound: f64,
    /// Tail window (decisions per tenant) the recovery check pools.
    pub recovery_window: usize,
    /// Faulted tail cache-hit ratio must be ≥ this fraction of the
    /// oracle's (0 disables — containment-only scenarios).
    pub recovery_floor: f64,
}

impl TransportScenarioSpec {
    /// Baseline spec at the standard chaos scale (same as
    /// [`super::ScenarioSpec::base`]): smoke runs 3 tenants x 8 jobs,
    /// full runs 4 x 14.
    pub fn base(
        name: &'static str,
        seed: u64,
        smoke: bool,
    ) -> TransportScenarioSpec {
        let (tenants, jobs, budget) =
            if smoke { (3, 8, 10) } else { (4, 14, 14) };
        TransportScenarioSpec {
            name,
            seed,
            tenants,
            jobs_per_tenant: jobs,
            classes: vec![0, 5],
            budget,
            transport: TransportFaultPlan::default(),
            silence_after: 450.0,
            regret_bound: 2.5,
            recovery_window: 6,
            recovery_floor: 0.0,
        }
    }

    /// Same env overrides as `ScenarioSpec::apply_env` — the
    /// reproduce-my-CI-failure knob.
    pub fn apply_env(&mut self) {
        fn env_parse<T: std::str::FromStr>(key: &str) -> Option<T> {
            std::env::var(key).ok()?.parse().ok()
        }
        if let Some(s) = env_parse::<u64>("KERMIT_CHAOS_SEED") {
            self.seed = s;
        }
        if let Some(t) = env_parse::<usize>("KERMIT_CHAOS_TENANTS") {
            self.tenants = t.max(1);
        }
        if let Some(j) = env_parse::<usize>("KERMIT_CHAOS_JOBS") {
            self.jobs_per_tenant = j.max(1);
        }
    }

    /// A plan with no loss channel at all (no drops, no partitions):
    /// after the end-of-run flush every sent sequence must be accounted
    /// for *exactly* — duplication, delay, stalls and wedges shuffle
    /// samples around but never destroy them.
    fn lossless(&self) -> bool {
        self.transport.loss.is_none() && self.transport.partitions.is_empty()
    }
}

/// The transport-chaos scoreboard for one scenario, serializable to
/// deterministic JSON. Carries the same `name` + `seed` identity keys
/// as [`super::ScenarioOutcome`], so `super::outcome::diff_outcome_sets`
/// diffs `TRANSPORT_outcomes.json` snapshots unchanged.
#[derive(Debug, Clone, Default)]
pub struct TransportOutcome {
    pub name: String,
    pub seed: u64,

    // ---- workload + makespans -----------------------------------------
    pub oracle_makespan: f64,
    pub faulted_makespan: f64,
    pub oracle_jobs: usize,
    pub faulted_jobs: usize,
    pub regret: f64,
    pub regret_bound: f64,

    // ---- no-livelock guarantee ----------------------------------------
    pub livelocked_sessions: usize,
    pub pending_decisions: usize,

    // ---- transport ground truth (faulted run) -------------------------
    pub samples_sent: u64,
    pub samples_dropped: usize,
    pub samples_partitioned: usize,
    pub samples_delayed: usize,
    pub samples_duplicated: usize,
    pub pump_stalls: usize,
    pub lane_wedges: usize,
    pub partitions_healed: usize,

    // ---- consumer-side observation (faulted run) ----------------------
    pub submitted: u64,
    pub accepted: u64,
    pub shed: u64,
    pub deduped: u64,
    pub gaps_skipped: u64,
    pub closed_rejects: u64,
    /// Samples still queued/parked after reconcile — must be zero.
    pub resident_after: u64,

    // ---- exactly-once window accounting -------------------------------
    pub oracle_windows: u64,
    pub faulted_windows: u64,
    /// Σ per tenant `published - accepted/window_size` overshoot — any
    /// nonzero value means a duplicate reached the label timeline.
    pub double_counted_windows: u64,
    /// Σ per tenant overshoot of
    /// `accepted + gaps_skipped + shed + closed_rejects` beyond `sent`
    /// (plus, for lossless plans, any deficit) — must be zero.
    pub seq_accounting_violation: u64,

    // ---- supervision / degraded mode (faulted run) --------------------
    pub delivery_retries: u64,
    pub degraded_events: u64,
    pub degraded_decisions: usize,
    pub healed: u64,
    /// Tenants not back to Healthy after heal + reconcile — must be 0.
    pub degraded_final: usize,

    // ---- label-timeline convergence -----------------------------------
    pub oracle_tail_hit_ratio: f64,
    pub faulted_tail_hit_ratio: f64,
    pub recovery_floor: f64,
    pub oracle_known_fraction: f64,
    pub faulted_known_fraction: f64,

    // ---- verdict ------------------------------------------------------
    pub pass: bool,
    pub failures: Vec<String>,
}

impl TransportOutcome {
    /// Deterministic JSON snapshot (same scenario + seed → same bytes).
    pub fn to_json(&self) -> Json {
        let n = |v: usize| Json::Num(v as f64);
        let u = |v: u64| Json::Num(v as f64);
        let mut j = Json::obj();
        j.set("name", Json::Str(self.name.clone()))
            .set("seed", Json::Num(self.seed as f64))
            .set("oracle_makespan", Json::Num(self.oracle_makespan))
            .set("faulted_makespan", Json::Num(self.faulted_makespan))
            .set("oracle_jobs", n(self.oracle_jobs))
            .set("faulted_jobs", n(self.faulted_jobs))
            .set("regret", Json::Num(self.regret))
            .set("regret_bound", Json::Num(self.regret_bound))
            .set("livelocked_sessions", n(self.livelocked_sessions))
            .set("pending_decisions", n(self.pending_decisions))
            .set("samples_sent", u(self.samples_sent))
            .set("samples_dropped", n(self.samples_dropped))
            .set("samples_partitioned", n(self.samples_partitioned))
            .set("samples_delayed", n(self.samples_delayed))
            .set("samples_duplicated", n(self.samples_duplicated))
            .set("pump_stalls", n(self.pump_stalls))
            .set("lane_wedges", n(self.lane_wedges))
            .set("partitions_healed", n(self.partitions_healed))
            .set("submitted", u(self.submitted))
            .set("accepted", u(self.accepted))
            .set("shed", u(self.shed))
            .set("deduped", u(self.deduped))
            .set("gaps_skipped", u(self.gaps_skipped))
            .set("closed_rejects", u(self.closed_rejects))
            .set("resident_after", u(self.resident_after))
            .set("oracle_windows", u(self.oracle_windows))
            .set("faulted_windows", u(self.faulted_windows))
            .set(
                "double_counted_windows",
                u(self.double_counted_windows),
            )
            .set(
                "seq_accounting_violation",
                u(self.seq_accounting_violation),
            )
            .set("delivery_retries", u(self.delivery_retries))
            .set("degraded_events", u(self.degraded_events))
            .set("degraded_decisions", n(self.degraded_decisions))
            .set("healed", u(self.healed))
            .set("degraded_final", n(self.degraded_final))
            .set(
                "oracle_tail_hit_ratio",
                Json::Num(self.oracle_tail_hit_ratio),
            )
            .set(
                "faulted_tail_hit_ratio",
                Json::Num(self.faulted_tail_hit_ratio),
            )
            .set("recovery_floor", Json::Num(self.recovery_floor))
            .set(
                "oracle_known_fraction",
                Json::Num(self.oracle_known_fraction),
            )
            .set(
                "faulted_known_fraction",
                Json::Num(self.faulted_known_fraction),
            )
            .set("pass", Json::Bool(self.pass))
            .set(
                "failures",
                Json::Arr(
                    self.failures
                        .iter()
                        .map(|f| Json::Str(f.clone()))
                        .collect(),
                ),
            );
        j
    }
}

/// Wraps the tuning plane as the engine's plug-in hub, with every
/// emitted sample routed through the (possibly faulty) transport into
/// the attached ingest front-end, and the pump gated by the
/// consumer-side faults (stall windows skip the pump entirely, wedged
/// lanes are skipped inside it).
struct TransportHub {
    plane: TuningPlane,
    handle: IngestHandle,
    transport: TransportLayer,
}

impl TransportHub {
    /// One supervised pump at sim time `now`, honouring the scripted
    /// consumer faults. Skipped entirely while the pump is stalled —
    /// the bounded queues (and the shed policy) are what protect the
    /// producers in that window.
    fn pump(&mut self, now: f64) {
        if self.transport.pump_stalled(now) {
            return;
        }
        let wedged = self.transport.wedged_tenants(now);
        self.plane.pump_ingest_wedged(&wedged);
    }
}

impl TenantRmPlugin for TransportHub {
    fn on_samples(&mut self, t: TenantId, samples: &[Sample]) {
        let Some(last) = samples.last() else { return };
        let now = last.time;
        for s in samples {
            self.transport.send(&self.handle, t, s.clone());
        }
        self.pump(now);
    }

    fn on_resource_request(
        &mut self,
        t: TenantId,
        req: &ResourceRequest,
    ) -> TuningConfig {
        // pump first so the decision sees the freshest labels the
        // transport let through (degraded tenants short-circuit to
        // their last known label inside `decide`)
        self.pump(req.time);
        let (config, _kind) = self.plane.decide(t, req.app_id, req.time);
        config.to_config()
    }

    fn on_app_complete(
        &mut self,
        t: TenantId,
        app_id: u64,
        duration: f64,
        now: f64,
    ) {
        self.pump(now);
        self.plane.complete(t, app_id, duration);
    }

    fn on_grant(&mut self, t: TenantId, app_id: u64, granted: u32) {
        self.plane.on_grant(t, app_id, granted);
    }

    fn on_app_fail(&mut self, t: TenantId, app_id: u64, now: f64) {
        self.pump(now);
        self.plane.on_app_fail(t, app_id, now);
    }
}

/// Everything one run (oracle or faulted) contributes to the score.
struct RunArtifacts {
    report: TuningRunReport,
    jobs_completed: usize,
    pending_decisions: usize,
    tail_hit_ratio: f64,
    transport_report: TransportFaultReport,
    samples_sent: u64,
    totals: TenantIngestStats,
    windows_published: u64,
    double_counted: u64,
    seq_violation: u64,
    degraded_final: usize,
    degraded_decisions: usize,
    healed: u64,
}

fn run_one_transport(
    spec: &TransportScenarioSpec,
    with_faults: bool,
) -> RunArtifacts {
    let mut plane = TuningPlane::new(plane_config(spec.seed, spec.budget));
    // Producer and pump share the engine thread here, so Block would
    // deadlock on a full queue — shed-oldest with a deep queue keeps
    // the stall windows lossless at this scale while staying safe.
    let handle = plane.attach_ingest(IngestConfig {
        queue_cap: 1 << 15,
        policy: ShedPolicy::ShedOldest,
        // generous write-off patience: a held sample is released within
        // `max_hold` sends, well inside 8 pumps — gaps written off are
        // real losses, not still-in-flight delays
        gap_patience: 8,
        reorder_cap: 256,
        ..Default::default()
    });
    // the scenarios opt in to the silence watchdog (off by default —
    // benign idleness is indistinguishable from a partition without a
    // deadline tuned to the workload)
    plane.coord.supervisor.config.silence_after = spec.silence_after;

    let scheds = schedules(
        spec.seed,
        spec.tenants,
        spec.jobs_per_tenant,
        &spec.classes,
    );
    let mut engine = MultiClusterEngine::new(
        ResourceManager::default_cluster(),
        sim_config(),
        spec.seed,
    );
    for (t, jobs) in &scheds {
        plane.ensure_tenant(*t);
        engine.push_jobs(*t, jobs);
    }
    let transport = if with_faults {
        TransportLayer::new(spec.transport.clone(), spec.seed)
    } else {
        TransportLayer::inert()
    };
    let mut hub = TransportHub { plane, handle, transport };
    let sim = engine.run(&mut hub);

    // settle: deliver everything the link still holds, pump it through,
    // write off the true losses + re-arm demoted tenants, then drain
    // the shards and expire dangling decisions
    hub.transport.flush(&hub.handle);
    hub.plane.pump_ingest_wedged(&[]);
    hub.plane.reconcile_ingest();
    hub.plane.drain();
    let timeout = hub.plane.resilience.decision_timeout;
    hub.plane.reconcile(sim.makespan + timeout + 1.0);
    hub.plane.audit_knowledge();

    let jobs_completed =
        sim.per_tenant.values().map(|l| l.jobs.len()).sum();
    let pending_decisions = hub.plane.pending_decisions();
    let tail = tail_hit_ratio(&hub.plane, spec.recovery_window);

    // per-tenant sequence-fate + window accounting (the zero-double-
    // count observables — all within-run, so they stay sound even
    // though fault-induced decision divergence changes how many
    // samples the two runs emit)
    let window_size =
        hub.plane.coord.config.monitor.window_size.max(1) as u64;
    let stats = hub.handle.stats();
    let mut windows_published = 0u64;
    let mut double_counted = 0u64;
    let mut seq_violation = 0u64;
    for t in hub.plane.tenant_ids() {
        let st = stats.get(&t).copied().unwrap_or_default();
        let sent = hub.transport.sent(t);
        let fates =
            st.accepted + st.gaps_skipped + st.shed + st.closed_rejects;
        // every sequence lands in at most one fate bucket; a second
        // delivery of the same sequence would overshoot `sent`
        seq_violation += fates.saturating_sub(sent);
        if spec.lossless() {
            // nothing can destroy a sequence: exact accounting
            seq_violation += sent.saturating_sub(fates);
        }
        let published = hub
            .plane
            .coord
            .router()
            .shard(t)
            .map(|s| s.contexts_published)
            .unwrap_or(0);
        windows_published += published;
        double_counted +=
            published.saturating_sub(st.accepted / window_size);
    }
    let degraded_final = hub.plane.coord.supervisor.impaired().len();
    let healed = hub.plane.coord.supervisor.healed;
    let degraded_decisions = hub.plane.degraded_decisions;
    let totals = hub.handle.totals();
    let samples_sent = hub.transport.sent_total();
    let transport_report = hub.transport.report;
    RunArtifacts {
        report: hub.plane.report(sim),
        jobs_completed,
        pending_decisions,
        tail_hit_ratio: tail,
        transport_report,
        samples_sent,
        totals,
        windows_published,
        double_counted,
        seq_violation,
        degraded_final,
        degraded_decisions,
        healed,
    }
}

/// Run one transport scenario: oracle first (inert link, identical
/// workload and supervision), then the faulted run, then score.
pub fn run_transport_scenario(
    spec: &TransportScenarioSpec,
) -> TransportOutcome {
    let oracle = run_one_transport(spec, false);
    let faulted = run_one_transport(spec, true);

    let per_job = |makespan: f64, jobs: usize| makespan / jobs.max(1) as f64;
    let oracle_per_job =
        per_job(oracle.report.makespan(), oracle.jobs_completed).max(1e-9);
    let faulted_per_job =
        per_job(faulted.report.makespan(), faulted.jobs_completed);
    let regret = faulted_per_job / oracle_per_job - 1.0;

    let fr = faulted.transport_report;
    let ft = faulted.totals;
    let mut failures = Vec::new();
    if !(regret <= spec.regret_bound) {
        failures.push(format!(
            "regret {regret:.3} exceeds bound {:.3}",
            spec.regret_bound
        ));
    }
    if faulted.report.livelocked_sessions != 0 {
        failures.push(format!(
            "{} sessions livelocked after drain",
            faulted.report.livelocked_sessions
        ));
    }
    if faulted.pending_decisions != 0 {
        failures.push(format!(
            "{} decisions still pending after reconcile",
            faulted.pending_decisions
        ));
    }
    if ft.resident != 0 {
        failures.push(format!(
            "{} samples still queued/parked after reconcile",
            ft.resident
        ));
    }
    // conservation: every submitted sample is accounted for
    let conserved =
        ft.accepted + ft.shed + ft.deduped + ft.closed_rejects + ft.resident;
    if conserved != ft.submitted {
        failures.push(format!(
            "conservation broken: {} accounted vs {} submitted",
            conserved, ft.submitted
        ));
    }
    // ground-truth delivery accounting: every sent sample is submitted
    // exactly once unless the link destroyed it, plus one per duplicate
    let expect_submitted = faulted.samples_sent
        - fr.samples_dropped as u64
        - fr.samples_partitioned as u64
        + fr.samples_duplicated as u64;
    if ft.submitted != expect_submitted {
        failures.push(format!(
            "delivery accounting drift: {} submitted vs {} expected",
            ft.submitted, expect_submitted
        ));
    }
    // injected ≥ observed: the consumer never reports more faults than
    // the transport injected
    if ft.deduped
        > (fr.samples_duplicated + fr.samples_delayed) as u64
    {
        failures.push(format!(
            "dedup hits {} exceed injected duplicates {} + delays {}",
            ft.deduped, fr.samples_duplicated, fr.samples_delayed
        ));
    }
    if ft.gaps_skipped
        > (fr.samples_dropped
            + fr.samples_partitioned
            + fr.samples_delayed) as u64
    {
        failures.push(format!(
            "gap write-offs {} exceed injected losses {}",
            ft.gaps_skipped,
            fr.samples_dropped + fr.samples_partitioned + fr.samples_delayed
        ));
    }
    if faulted.seq_accounting_violation != 0 {
        failures.push(format!(
            "sequence-fate accounting violated for {} sequences",
            faulted.seq_accounting_violation
        ));
    }
    if faulted.double_counted != 0 {
        failures.push(format!(
            "{} windows double-counted",
            faulted.double_counted
        ));
    }
    if faulted.degraded_final != 0 {
        failures.push(format!(
            "{} tenants still degraded after heal + reconcile",
            faulted.degraded_final
        ));
    }
    if fr.samples_partitioned > 0 && fr.partitions_healed == 0 {
        failures.push(
            "partition swallowed samples but never healed".to_string(),
        );
    }
    if spec.recovery_floor > 0.0
        && faulted.tail_hit_ratio + 1e-9
            < spec.recovery_floor * oracle.tail_hit_ratio
    {
        failures.push(format!(
            "tail cache-hit ratio {:.3} below {:.2}x oracle ({:.3})",
            faulted.tail_hit_ratio,
            spec.recovery_floor,
            oracle.tail_hit_ratio
        ));
    }

    TransportOutcome {
        name: spec.name.to_string(),
        seed: spec.seed,
        oracle_makespan: oracle.report.makespan(),
        faulted_makespan: faulted.report.makespan(),
        oracle_jobs: oracle.jobs_completed,
        faulted_jobs: faulted.jobs_completed,
        regret,
        regret_bound: spec.regret_bound,
        livelocked_sessions: faulted.report.livelocked_sessions,
        pending_decisions: faulted.pending_decisions,
        samples_sent: faulted.samples_sent,
        samples_dropped: fr.samples_dropped,
        samples_partitioned: fr.samples_partitioned,
        samples_delayed: fr.samples_delayed,
        samples_duplicated: fr.samples_duplicated,
        pump_stalls: fr.pump_stalls,
        lane_wedges: fr.lane_wedges,
        partitions_healed: fr.partitions_healed,
        submitted: ft.submitted,
        accepted: ft.accepted,
        shed: ft.shed,
        deduped: ft.deduped,
        gaps_skipped: ft.gaps_skipped,
        closed_rejects: ft.closed_rejects,
        resident_after: ft.resident,
        oracle_windows: oracle.windows_published,
        faulted_windows: faulted.windows_published,
        double_counted_windows: faulted.double_counted,
        seq_accounting_violation: faulted.seq_accounting_violation,
        delivery_retries: faulted.report.multi.delivery_retries,
        degraded_events: faulted.report.multi.degraded_events,
        degraded_decisions: faulted.degraded_decisions,
        healed: faulted.healed,
        degraded_final: faulted.degraded_final,
        oracle_tail_hit_ratio: oracle.tail_hit_ratio,
        faulted_tail_hit_ratio: faulted.tail_hit_ratio,
        recovery_floor: spec.recovery_floor,
        oracle_known_fraction: oracle.report.multi.known_fraction(),
        faulted_known_fraction: faulted.report.multi.known_fraction(),
        pass: failures.is_empty(),
        failures,
    }
}

/// The standard transport-chaos sweep — one scenario per transport
/// fault family in the taxonomy (docs/ARCHITECTURE.md "Chaos lab").
pub fn transport_scenarios(smoke: bool) -> Vec<TransportScenarioSpec> {
    use crate::stream::fault::{
        Partition, PumpStall, SampleDelay, SampleDup, SampleLoss,
        WedgedLane,
    };
    let mut scenarios = Vec::new();

    // Full partition with a heal time: tenant 0 goes silent mid-run,
    // the watchdog demotes it (degraded mode: last-known label, probes
    // suspended), traffic returns, and the label timeline must
    // converge back — the only scenario with a real recovery floor.
    let mut s = TransportScenarioSpec::base("partition_heal", 707, smoke);
    s.transport.partitions = vec![Partition {
        tenant: TenantId(0),
        from: 200.0,
        until: 1000.0,
    }];
    s.recovery_floor = 0.3;
    scenarios.push(s);

    // Lossy + laggy link: independent drops leave sequence gaps the
    // reorder buffer must write off; delays genuinely reorder.
    let mut s = TransportScenarioSpec::base("lossy_transport", 808, smoke);
    s.transport.loss = Some(SampleLoss { prob: 0.15 });
    s.transport.delay = Some(SampleDelay { prob: 0.25, max_hold: 3 });
    scenarios.push(s);

    // At-least-once storm: half of everything arrives twice, a fifth
    // arrives late and out of order — and the window accounting must
    // stay *exactly* once (lossless plan → exact sequence fates).
    let mut s = TransportScenarioSpec::base("duplicate_storm", 909, smoke);
    s.transport.duplication = Some(SampleDup { prob: 0.5 });
    s.transport.delay = Some(SampleDelay { prob: 0.2, max_hold: 2 });
    scenarios.push(s);

    // Consumer-side faults: the whole pump stalls for a window (queues
    // absorb the burst), then one tenant's lane wedges for a long
    // stretch (watchdog → retry backoff → degraded → heal).
    let mut s = TransportScenarioSpec::base("stalled_consumer", 1010, smoke);
    s.transport.stalls = vec![PumpStall { from: 300.0, until: 900.0 }];
    s.transport.wedges = vec![WedgedLane {
        tenant: TenantId(1),
        from: 600.0,
        until: 1600.0,
    }];
    scenarios.push(s);

    for s in &mut scenarios {
        s.apply_env();
    }
    scenarios
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stream::fault::{Partition, SampleDelay, SampleDup};

    /// Tiny spec so unit tests stay fast; experiments::chaos runs the
    /// standard sweep.
    fn tiny(name: &'static str, seed: u64) -> TransportScenarioSpec {
        let mut s = TransportScenarioSpec::base(name, seed, true);
        s.tenants = 2;
        s.jobs_per_tenant = 5;
        s.budget = 8;
        s
    }

    #[test]
    fn oracle_equals_inert_transport_run() {
        // no faults: the "faulted" run IS the oracle (the transport
        // layer draws zero RNG), so regret is ~0 and every transport
        // guarantee holds trivially
        let spec = tiny("inert", 41);
        let o = run_transport_scenario(&spec);
        assert!(o.pass, "failures: {:?}", o.failures);
        assert!(o.regret.abs() < 1e-9, "regret {}", o.regret);
        assert_eq!(o.oracle_makespan, o.faulted_makespan);
        assert_eq!(o.oracle_windows, o.faulted_windows);
        assert_eq!(o.samples_dropped + o.samples_duplicated, 0);
        assert_eq!(o.deduped + o.gaps_skipped, 0);
        assert_eq!(o.double_counted_windows, 0);
        assert_eq!(o.resident_after, 0);
    }

    #[test]
    fn duplicate_storm_never_double_counts() {
        let mut spec = tiny("mini_dup_storm", 42);
        spec.transport.duplication = Some(SampleDup { prob: 0.5 });
        spec.transport.delay =
            Some(SampleDelay { prob: 0.2, max_hold: 2 });
        let o = run_transport_scenario(&spec);
        // the link really duplicated traffic...
        assert!(o.samples_duplicated > 0, "{o:?}");
        assert!(o.deduped > 0, "dedup never fired: {o:?}");
        // ...and not one duplicate reached the label timeline
        assert_eq!(o.double_counted_windows, 0, "{o:?}");
        assert_eq!(o.seq_accounting_violation, 0, "{o:?}");
        assert!(o.pass, "failures: {:?}", o.failures);
    }

    #[test]
    fn partitioned_tenant_degrades_heals_and_reconverges() {
        let mut spec = tiny("mini_partition", 43);
        // early, short window so even the tiny run extends well past
        // the heal time
        spec.transport.partitions = vec![Partition {
            tenant: TenantId(0),
            from: 30.0,
            until: 120.0,
        }];
        spec.silence_after = 40.0;
        let o = run_transport_scenario(&spec);
        assert!(o.samples_partitioned > 0, "{o:?}");
        // whatever the watchdog did mid-run, nobody stays degraded and
        // nothing stays parked after heal + reconcile
        assert_eq!(o.degraded_final, 0, "{o:?}");
        assert_eq!(o.resident_after, 0, "{o:?}");
        assert!(o.pass, "failures: {:?}", o.failures);
    }

    #[test]
    fn transport_outcomes_are_deterministic() {
        let mut spec = tiny("mini_det", 44);
        spec.transport.duplication = Some(SampleDup { prob: 0.3 });
        let a = run_transport_scenario(&spec);
        let b = run_transport_scenario(&spec);
        assert_eq!(a.to_json().encode(), b.to_json().encode());
    }

    #[test]
    fn sweep_covers_the_transport_taxonomy() {
        let sweep = transport_scenarios(true);
        let names: Vec<&str> = sweep.iter().map(|s| s.name).collect();
        assert_eq!(
            names,
            vec![
                "partition_heal",
                "lossy_transport",
                "duplicate_storm",
                "stalled_consumer"
            ]
        );
        for s in &sweep {
            assert!(!s.transport.is_inert(), "{} injects nothing", s.name);
            assert!(s.regret_bound > 0.0);
            assert!(s.silence_after.is_finite());
        }
        let full = transport_scenarios(false);
        assert!(sweep[0].jobs_per_tenant < full[0].jobs_per_tenant);
    }
}
