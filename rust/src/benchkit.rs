//! Minimal benchmark harness (the offline crate set has no criterion).
//!
//! Used by every `rust/benches/*.rs` target (all `harness = false`):
//! wall-clock timing with warmup + repeated samples, median/MAD
//! statistics, and aligned table printing for the paper-figure outputs.

use std::time::Instant;

/// Timing summary over n samples.
#[derive(Debug, Clone, Copy)]
pub struct Timing {
    pub median_ns: f64,
    pub mad_ns: f64,
    pub samples: usize,
}

impl Timing {
    pub fn per_iter_str(&self) -> String {
        fmt_ns(self.median_ns) + " ± " + &fmt_ns(self.mad_ns)
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Time `f` (whole-call), `samples` times after `warmup` calls; returns
/// median/MAD per call.
pub fn bench<F: FnMut()>(warmup: usize, samples: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<f64> = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_nanos() as f64);
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = times[times.len() / 2];
    let mut devs: Vec<f64> =
        times.iter().map(|t| (t - median).abs()).collect();
    devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Timing {
        median_ns: median,
        mad_ns: devs[devs.len() / 2],
        samples,
    }
}

/// Aligned table printer for figure/table reproduction output, with an
/// optional machine-readable side channel: rows recorded through
/// [`Table::timed_row`] (or [`Table::metric`]) carry their median
/// latency in nanoseconds, and [`Table::write_json`] dumps the whole
/// table plus the `stage -> median_ns` map so the perf trajectory can
/// be tracked across PRs.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
    /// (stage, median_ns) points recorded alongside the display rows.
    metrics: Vec<(String, f64)>,
    /// Environment metadata (thread count, feature flags, …) emitted
    /// into the JSON so baselines diff apples-to-apples across PRs.
    meta: Vec<(String, String)>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            metrics: Vec::new(),
            meta: Vec::new(),
        }
    }

    /// Record a metadata key/value for [`Table::write_json`] (e.g. the
    /// engine thread count or whether the simd kernel was active), so a
    /// future PR diffing two baseline files can tell matching
    /// configurations apart.
    pub fn meta(&mut self, key: &str, value: &str) {
        self.meta.push((key.to_string(), value.to_string()));
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    /// Record a numeric data point for [`Table::write_json`] without
    /// adding a display row.
    pub fn metric(&mut self, stage: &str, median_ns: f64) {
        self.metrics.push((stage.to_string(), median_ns));
    }

    /// Add a display row whose first cell names the stage, recording the
    /// timing's median alongside for the JSON output.
    pub fn timed_row(&mut self, cells: &[String], t: Timing) {
        assert!(!cells.is_empty());
        self.metric(&cells[0], t.median_ns);
        self.row(cells);
    }

    /// Write the table (headers + rows) and the recorded
    /// `stage -> median_ns` map as pretty-printed JSON.
    pub fn write_json(
        &self,
        path: &std::path::Path,
    ) -> crate::util::error::Result<()> {
        use crate::util::json::Json;
        let mut root = Json::obj();
        root.set(
            "headers",
            Json::Arr(
                self.headers.iter().map(|h| Json::Str(h.clone())).collect(),
            ),
        )
        .set(
            "rows",
            Json::Arr(
                self.rows
                    .iter()
                    .map(|r| {
                        Json::Arr(
                            r.iter().map(|c| Json::Str(c.clone())).collect(),
                        )
                    })
                    .collect(),
            ),
        );
        let mut m = Json::obj();
        for (stage, ns) in &self.metrics {
            m.set(stage, Json::Num(*ns));
        }
        root.set("median_ns", m);
        let mut meta = Json::obj();
        for (k, v) in &self.meta {
            meta.set(k, Json::Str(v.clone()));
        }
        root.set("meta", meta);
        std::fs::write(path, root.encode_pretty()).map_err(|e| {
            crate::util::error::Error::io(format!(
                "writing bench table {}: {e}",
                path.display()
            ))
        })
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let cols: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect();
            println!("  {}", cols.join("  "));
        };
        line(&self.headers);
        let total: usize =
            widths.iter().sum::<usize>() + 2 * (widths.len() - 1);
        println!("  {}", "-".repeat(total));
        for row in &self.rows {
            line(row);
        }
    }
}

/// Percent formatter.
pub fn pct(x: f64) -> String {
    format!("{:.1}%", 100.0 * x)
}

// ---------------------------------------------------------------------------
// baseline diffing
// ---------------------------------------------------------------------------

/// One stage that got slower than the baseline beyond the threshold.
#[derive(Debug, Clone, PartialEq)]
pub struct StageRegression {
    pub stage: String,
    pub baseline_ns: f64,
    pub current_ns: f64,
    /// current / baseline (always > 1 for a regression).
    pub ratio: f64,
}

/// Outcome of diffing a current hotpath table against a baseline one.
#[derive(Debug, Clone, PartialEq)]
pub enum BaselineDiff {
    /// The two files were measured under different environments
    /// (thread count, feature flags, …): latencies are not comparable,
    /// the diff is skipped — this must NOT fail a build.
    MetaMismatch {
        key: String,
        baseline: String,
        current: String,
    },
    /// Environments match: per-stage comparison ran.
    Compared {
        /// Stages beyond `threshold`, sorted worst-first.
        regressions: Vec<StageRegression>,
        /// Stages within threshold (or improved).
        ok: usize,
        /// Stages present in only one of the files (new/retired
        /// benchmarks — informational, never a failure).
        unmatched: usize,
    },
}

/// Compare two `Table::write_json` documents (the `meta` and
/// `median_ns` sections). A stage regresses when
/// `current > baseline * (1 + threshold)`. Meta keys present in either
/// document must match exactly in the other, otherwise the comparison
/// is skipped as [`BaselineDiff::MetaMismatch`].
pub fn diff_baselines(
    baseline: &crate::util::json::Json,
    current: &crate::util::json::Json,
    threshold: f64,
) -> Result<BaselineDiff, crate::util::json::JsonError> {
    let empty = crate::util::json::Json::obj();
    let meta_of = |j: &crate::util::json::Json| {
        j.get_opt("meta").cloned().unwrap_or_else(|| empty.clone())
    };
    let bm = meta_of(baseline);
    let cm = meta_of(current);
    let mut keys: Vec<String> = Vec::new();
    for m in [&bm, &cm] {
        for k in m.as_obj()?.keys() {
            if !keys.contains(k) {
                keys.push(k.clone());
            }
        }
    }
    for key in keys {
        let b = bm
            .get_opt(&key)
            .map(|v| v.as_str().map(str::to_string))
            .transpose()?;
        let c = cm
            .get_opt(&key)
            .map(|v| v.as_str().map(str::to_string))
            .transpose()?;
        if b != c {
            return Ok(BaselineDiff::MetaMismatch {
                key,
                baseline: b.unwrap_or_else(|| "<absent>".into()),
                current: c.unwrap_or_else(|| "<absent>".into()),
            });
        }
    }

    let bs = baseline.get("median_ns")?.as_obj()?;
    let cs = current.get("median_ns")?.as_obj()?;
    let mut regressions = Vec::new();
    let mut ok = 0usize;
    let mut unmatched = 0usize;
    for (stage, bns) in bs {
        match cs.get(stage) {
            Some(cns) => {
                let (b, c) = (bns.as_f64()?, cns.as_f64()?);
                if b > 0.0 && c > b * (1.0 + threshold) {
                    regressions.push(StageRegression {
                        stage: stage.clone(),
                        baseline_ns: b,
                        current_ns: c,
                        ratio: c / b,
                    });
                } else {
                    ok += 1;
                }
            }
            None => unmatched += 1,
        }
    }
    unmatched += cs.keys().filter(|k| !bs.contains_key(*k)).count();
    regressions.sort_by(|a, b| b.ratio.partial_cmp(&a.ratio).unwrap());
    Ok(BaselineDiff::Compared { regressions, ok, unmatched })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let t = bench(1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(t.median_ns > 0.0);
        assert_eq!(t.samples, 5);
    }

    #[test]
    fn fmt_ns_ranges() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5e4).contains("µs"));
        assert!(fmt_ns(5e7).contains("ms"));
        assert!(fmt_ns(5e9).contains('s'));
    }

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(&["1".into(), "2".into()]);
        t.print(); // just must not panic
    }

    fn doc(meta: &[(&str, &str)], stages: &[(&str, f64)]) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut m = Json::obj();
        for (k, v) in meta {
            m.set(k, Json::Str(v.to_string()));
        }
        let mut s = Json::obj();
        for (k, ns) in stages {
            s.set(k, Json::Num(*ns));
        }
        let mut root = Json::obj();
        root.set("meta", m).set("median_ns", s);
        root
    }

    #[test]
    fn diff_flags_regressions_beyond_threshold_worst_first() {
        let base = doc(
            &[("engine_threads", "8"), ("simd_feature", "on")],
            &[("observe", 1000.0), ("kmeans", 2000.0), ("dbscan", 500.0)],
        );
        let cur = doc(
            &[("engine_threads", "8"), ("simd_feature", "on")],
            &[("observe", 1400.0), ("kmeans", 2100.0), ("dbscan", 2500.0)],
        );
        match diff_baselines(&base, &cur, 0.25).unwrap() {
            BaselineDiff::Compared { regressions, ok, unmatched } => {
                assert_eq!(regressions.len(), 2);
                // worst ratio first: dbscan 5x, then observe 1.4x
                assert_eq!(regressions[0].stage, "dbscan");
                assert!((regressions[0].ratio - 5.0).abs() < 1e-9);
                assert_eq!(regressions[1].stage, "observe");
                // kmeans +5% is inside the 25% threshold
                assert_eq!(ok, 1);
                assert_eq!(unmatched, 0);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn diff_improvements_and_new_stages_never_fail() {
        let base = doc(&[("t", "4")], &[("a", 1000.0), ("gone", 9.0)]);
        let cur = doc(&[("t", "4")], &[("a", 400.0), ("new", 5.0)]);
        match diff_baselines(&base, &cur, 0.1).unwrap() {
            BaselineDiff::Compared { regressions, ok, unmatched } => {
                assert!(regressions.is_empty());
                assert_eq!(ok, 1);
                assert_eq!(unmatched, 2); // one retired + one new
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn diff_skips_on_meta_mismatch_including_absent_keys() {
        let base = doc(&[("engine_threads", "8")], &[("a", 1000.0)]);
        let cur = doc(&[("engine_threads", "2")], &[("a", 9000.0)]);
        match diff_baselines(&base, &cur, 0.1).unwrap() {
            BaselineDiff::MetaMismatch { key, baseline, current } => {
                assert_eq!(key, "engine_threads");
                assert_eq!((baseline.as_str(), current.as_str()), ("8", "2"));
            }
            other => panic!("unexpected {other:?}"),
        }
        // a key present on one side only is a mismatch too (a feature
        // flag added later must not silently compare)
        let cur2 = doc(
            &[("engine_threads", "8"), ("simd_feature", "on")],
            &[("a", 1.0)],
        );
        assert!(matches!(
            diff_baselines(&base, &cur2, 0.1).unwrap(),
            BaselineDiff::MetaMismatch { .. }
        ));
    }

    #[test]
    fn diff_roundtrips_through_real_table_json() {
        use crate::util::json::Json;
        let mut t = Table::new(&["stage", "latency"]);
        t.timed_row(
            &["observe".into(), "1.00 µs".into()],
            Timing { median_ns: 1000.0, mad_ns: 10.0, samples: 5 },
        );
        t.meta("engine_threads", "4");
        let path = std::env::temp_dir().join("kermit_diff_roundtrip.json");
        t.write_json(&path).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        match diff_baselines(&j, &j, 0.05).unwrap() {
            BaselineDiff::Compared { regressions, ok, unmatched } => {
                assert!(regressions.is_empty());
                assert_eq!((ok, unmatched), (1, 0));
            }
            other => panic!("unexpected {other:?}"),
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn write_json_emits_stage_medians() {
        use crate::util::json::Json;
        let mut t = Table::new(&["stage", "latency"]);
        t.timed_row(
            &["observe".into(), "1.00 µs".into()],
            Timing { median_ns: 1000.0, mad_ns: 10.0, samples: 5 },
        );
        t.metric("extra_stage", 42.0);
        t.meta("threads", "4");
        t.meta("simd", "off");
        let path = std::env::temp_dir().join("kermit_benchkit_json_test.json");
        t.write_json(&path).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(
            j.get("median_ns").unwrap().get("observe").unwrap().as_f64().unwrap(),
            1000.0
        );
        assert_eq!(
            j.get("median_ns")
                .unwrap()
                .get("extra_stage")
                .unwrap()
                .as_f64()
                .unwrap(),
            42.0
        );
        assert_eq!(j.get("rows").unwrap().as_arr().unwrap().len(), 1);
        let meta = j.get("meta").unwrap();
        assert_eq!(meta.get("threads").unwrap().as_str().unwrap(), "4");
        assert_eq!(meta.get("simd").unwrap().as_str().unwrap(), "off");
        std::fs::remove_file(&path).ok();
    }
}
