//! Minimal benchmark harness (the offline crate set has no criterion).
//!
//! Used by every `rust/benches/*.rs` target (all `harness = false`):
//! wall-clock timing with warmup + repeated samples, median/MAD
//! statistics, and aligned table printing for the paper-figure outputs.

use std::time::Instant;

/// Timing summary over n samples.
#[derive(Debug, Clone, Copy)]
pub struct Timing {
    pub median_ns: f64,
    pub mad_ns: f64,
    pub samples: usize,
}

impl Timing {
    pub fn per_iter_str(&self) -> String {
        fmt_ns(self.median_ns) + " ± " + &fmt_ns(self.mad_ns)
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Time `f` (whole-call), `samples` times after `warmup` calls; returns
/// median/MAD per call.
pub fn bench<F: FnMut()>(warmup: usize, samples: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<f64> = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_nanos() as f64);
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = times[times.len() / 2];
    let mut devs: Vec<f64> =
        times.iter().map(|t| (t - median).abs()).collect();
    devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Timing {
        median_ns: median,
        mad_ns: devs[devs.len() / 2],
        samples,
    }
}

/// Aligned table printer for figure/table reproduction output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let cols: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect();
            println!("  {}", cols.join("  "));
        };
        line(&self.headers);
        let total: usize =
            widths.iter().sum::<usize>() + 2 * (widths.len() - 1);
        println!("  {}", "-".repeat(total));
        for row in &self.rows {
            line(row);
        }
    }
}

/// Percent formatter.
pub fn pct(x: f64) -> String {
    format!("{:.1}%", 100.0 * x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let t = bench(1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(t.median_ns > 0.0);
        assert_eq!(t.samples, 5);
    }

    #[test]
    fn fmt_ns_ranges() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5e4).contains("µs"));
        assert!(fmt_ns(5e7).contains("ms"));
        assert!(fmt_ns(5e9).contains('s'));
    }

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(&["1".into(), "2".into()]);
        t.print(); // just must not panic
    }
}
