//! Property-based testing harness (the offline crate set has no
//! proptest): deterministic random-case generation with shrinking-free
//! failure reporting (the failing seed + case index are printed, which
//! is enough to reproduce exactly).

use crate::util::rng::Rng;

/// Run `check` on `cases` generated inputs. On failure, panics with the
/// case index and root seed so the exact case can be replayed.
pub fn forall<T, G, C>(seed: u64, cases: usize, mut generate: G, mut check: C)
where
    G: FnMut(&mut Rng) -> T,
    C: FnMut(&T) -> Result<(), String>,
    T: std::fmt::Debug,
{
    let mut root = Rng::new(seed);
    for i in 0..cases {
        let mut case_rng = root.fork(i as u64);
        let input = generate(&mut case_rng);
        if let Err(msg) = check(&input) {
            panic!(
                "property failed at case {i} (seed {seed}): {msg}\ninput: {input:?}"
            );
        }
    }
}

/// Generator helpers.
pub mod gen {
    use crate::util::rng::Rng;

    pub fn vec_f64(rng: &mut Rng, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| rng.range_f64(lo, hi)).collect()
    }

    pub fn rows(
        rng: &mut Rng,
        n: usize,
        width: usize,
        lo: f64,
        hi: f64,
    ) -> Vec<Vec<f64>> {
        (0..n).map(|_| vec_f64(rng, width, lo, hi)).collect()
    }

    pub fn labels(rng: &mut Rng, n: usize, classes: u32) -> Vec<u32> {
        (0..n).map(|_| rng.below(classes as u64) as u32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall(
            0,
            50,
            |rng| rng.range_f64(0.0, 1.0),
            |x| {
                if (0.0..1.0).contains(x) {
                    Ok(())
                } else {
                    Err(format!("{x} out of range"))
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed at case")]
    fn forall_reports_failures() {
        forall(
            1,
            50,
            |rng| rng.below(10),
            |&x| if x < 5 { Ok(()) } else { Err("too big".into()) },
        );
    }

    #[test]
    fn deterministic_cases() {
        let mut seen_a = Vec::new();
        forall(7, 10, |rng| rng.next_u64(), |&x| {
            seen_a.push(x);
            Ok(())
        });
        let mut seen_b = Vec::new();
        forall(7, 10, |rng| rng.next_u64(), |&x| {
            seen_b.push(x);
            Ok(())
        });
        assert_eq!(seen_a, seen_b);
    }
}
