//! Tenant identity and tenant-tagged samples: the vocabulary the
//! multi-tenant stream layer shares with the monitor.

use crate::workloadgen::{Sample, Trace};

/// Tenant identity — defined in [`crate::features`] (the shared
/// vocabulary layer, beneath monitor/online) and re-exported here as
/// the stream layer's routing key.
pub use crate::features::TenantId;

/// One raw metric sample tagged with the tenant that produced it — what
/// a multi-tenant agent fleet actually emits on the wire (the single
/// shared transport carries every tenant's samples interleaved).
#[derive(Debug, Clone)]
pub struct TenantSample {
    pub tenant: TenantId,
    pub sample: Sample,
}

/// Multiplex per-tenant traces into one interleaved stream: bursts of
/// `burst` samples are taken from each tenant in round-robin order until
/// every trace is exhausted. This models the arrival pattern the router
/// sees on a shared cluster — no tenant's samples are reordered, but
/// tenants' samples interleave arbitrarily relative to each other.
pub fn interleave_round_robin(
    traces: &[Trace],
    burst: usize,
) -> Vec<TenantSample> {
    assert!(burst > 0, "burst must be positive");
    let mut cursors = vec![0usize; traces.len()];
    let total: usize = traces.iter().map(|t| t.len()).sum();
    let mut out = Vec::with_capacity(total);
    while out.len() < total {
        for (k, trace) in traces.iter().enumerate() {
            let start = cursors[k];
            let end = (start + burst).min(trace.len());
            for s in &trace.samples[start..end] {
                out.push(TenantSample {
                    tenant: TenantId(k as u32),
                    sample: s.clone(),
                });
            }
            cursors[k] = end;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloadgen::{tour_schedule, Generator};

    #[test]
    fn tenant_id_orders_and_displays() {
        assert!(TenantId(0) < TenantId(3));
        assert_eq!(TenantId::default(), TenantId(0));
        assert_eq!(format!("{}", TenantId(7)), "tenant-7");
    }

    #[test]
    fn interleave_preserves_per_tenant_order_and_loses_nothing() {
        let mut g = Generator::with_default_config(1);
        let a = g.generate(&tour_schedule(40, &[0]));
        let b = g.generate(&tour_schedule(25, &[1, 2]));
        let lens = [a.len(), b.len()];
        let mixed = interleave_round_robin(&[a.clone(), b.clone()], 7);
        assert_eq!(mixed.len(), lens[0] + lens[1]);
        // per tenant, the sample sequence is exactly the original trace
        for (k, trace) in [a, b].iter().enumerate() {
            let got: Vec<f64> = mixed
                .iter()
                .filter(|ts| ts.tenant == TenantId(k as u32))
                .map(|ts| ts.sample.time)
                .collect();
            let want: Vec<f64> =
                trace.samples.iter().map(|s| s.time).collect();
            assert_eq!(got, want, "tenant {k}");
        }
        // and the interleaving actually alternates tenants
        let first_burst: Vec<u32> =
            mixed[..14].iter().map(|ts| ts.tenant.0).collect();
        assert!(first_burst.contains(&0) && first_burst.contains(&1));
    }
}
