//! Event-driven ingest front-end: bounded per-tenant sample queues, an
//! off-caller-thread batcher, and explicit backpressure — the entry
//! point for "heavy traffic from millions of users".
//!
//! # Why not call `StreamRouter::ingest` directly?
//!
//! The router is a *consumer-side* structure: ingesting into it takes
//! `&mut self`, so every producer serializes on the owner of the
//! router, and a slow tick stalls the producers themselves. This module
//! splits the two roles:
//!
//! * **Producers** hold a cheap, cloneable [`IngestHandle`] and call
//!   [`IngestHandle::submit`] — one short per-tenant mutex hold, no
//!   aggregation, no router access. Any number of producer threads can
//!   submit concurrently.
//! * The **consumer** owns the [`IngestFrontEnd`] (and the router) and
//!   drives [`IngestFrontEnd::pump`]: drain every tenant queue, coalesce
//!   samples into `ObservationWindow`s through per-tenant
//!   [`WindowAggregator`]s (fanned across the engine's work-stealing
//!   executor — the same executor router ticks, offline cycles, and
//!   tuning probes run on), enqueue the windows on the router, and
//!   tick it.
//!
//! The front-end is **event-driven**: producers signal the consumer's
//! condvar on the empty→non-empty edge, so an idle consumer sleeps in
//! [`IngestFrontEnd::wait_for_samples`] instead of spinning, and a busy
//! one never pays more than one atomic check per pump.
//!
//! # Backpressure is explicit, shedding is never silent
//!
//! Every queue is bounded at `queue_cap`. What happens on overflow is
//! the [`ShedPolicy`] picked at construction:
//!
//! | policy | producer sees | queue keeps | counted in |
//! |--------|---------------|-------------|------------|
//! | [`ShedPolicy::Block`] | blocks until space | everything | `blocked` (waits), never sheds |
//! | [`ShedPolicy::ShedOldest`] | returns immediately | newest `queue_cap` | `shed` (the evicted oldest) |
//! | [`ShedPolicy::ShedNewest`] | returns immediately | oldest `queue_cap` | `shed` (the rejected newcomer) |
//!
//! Per tenant, at every quiesce point (queue drained, reorder buffer
//! empty): `accepted + shed + deduped + closed_rejects == submitted` —
//! and at any instant
//! `accepted + shed + deduped + closed_rejects + resident == submitted`,
//! where `accepted` counts samples handed to the batcher and `resident`
//! counts samples still queued or parked in the reorder buffer.
//! `tests/ingest.rs` pins the invariant under every policy and under
//! concurrent producers. Fault-free the new terms are identically zero
//! and the PR 8 form `accepted + shed + resident == submitted` holds
//! unchanged.
//!
//! Shedding decisions are **deterministic**: they are a pure function
//! of the queue state at submit time, so a seeded single-threaded
//! replay produces the identical outcome sequence (also pinned).
//!
//! # Sequence numbers: surviving at-least-once, out-of-order transport
//!
//! Every submitted sample carries a per-tenant sequence number —
//! assigned under the queue lock for plain [`IngestHandle::submit`], or
//! supplied by the transport for
//! [`IngestHandle::submit_sequenced`] (see `stream::fault`, which
//! numbers samples *before* dropping/delaying/duplicating them). On
//! drain, each lane runs its samples through a per-tenant
//! [`ReorderBuffer`] that releases them to the batcher in sequence
//! order, collapses duplicates (`deduped`), and writes off sequence
//! numbers that will never arrive: shed samples are marked known-lost
//! at shed time, unknown transport gaps are skipped after
//! `gap_patience` pumps or when more than `reorder_cap` samples are
//! parked behind the gap (`gaps_skipped`). Fault-free the buffer is
//! pure pass-through — sequences arrive contiguous, nothing is parked,
//! windows are bit-identical to PR 8.
//!
//! # Close is loud, never a hang
//!
//! [`IngestFrontEnd::close`] marks the front-end closed and wakes every
//! producer parked in a [`ShedPolicy::Block`] wait; they return
//! [`SubmitOutcome::Closed`] (counted in `closed_rejects`) instead of
//! hanging on a consumer that will never drain again.

use super::router::StreamRouter;
use super::tenant::TenantId;
use crate::features::ObservationWindow;
use crate::linalg::engine::Engine;
use crate::monitor::{MonitorConfig, WindowAggregator};
use crate::workloadgen::Sample;
use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::{Duration, Instant};

/// What a full per-tenant queue does with the next sample.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedPolicy {
    /// Block the producer until the consumer drains space. Lossless;
    /// couples producer latency to consumer health. A blocked producer
    /// relies on a live consumer — only use where one is guaranteed.
    /// If the front-end closes while a producer is parked here, the
    /// wait ends with [`SubmitOutcome::Closed`] — never a hang.
    Block,
    /// Evict the oldest queued sample to admit the new one (keep the
    /// freshest data — right for monitoring, where stale samples decay
    /// in value). The evicted sample is counted, never silently lost.
    ShedOldest,
    /// Reject the incoming sample (keep the oldest — right when windows
    /// must stay contiguous from their start). Counted, never silent.
    ShedNewest,
}

/// Front-end configuration.
#[derive(Debug, Clone)]
pub struct IngestConfig {
    /// Per-tenant queue bound (clamped to ≥ 1).
    pub queue_cap: usize,
    /// Overflow behaviour for every queue.
    pub policy: ShedPolicy,
    /// Window aggregation config for the batchers. Must match the
    /// router's monitor config for windows to be bit-identical to
    /// direct `StreamRouter::ingest` (the coordinator's
    /// `attach_ingest` enforces this).
    pub monitor: MonitorConfig,
    /// Max samples drained per tenant per pump (0 = drain everything).
    /// A bound smooths one bursty tenant's latency impact on the rest.
    pub drain_max: usize,
    /// Engine the batching fans out on — share the coordinator's so
    /// batching, ticks, and offline cycles use one executor.
    pub engine: Engine,
    /// Max samples the reorder buffer parks behind a sequence gap
    /// before writing the gap off (clamped to ≥ 1).
    pub reorder_cap: usize,
    /// Pumps a sequence gap may stay open (waiting for a late sample)
    /// before it is written off as lost in transit (clamped to ≥ 1).
    pub gap_patience: u32,
}

impl Default for IngestConfig {
    fn default() -> Self {
        IngestConfig {
            queue_cap: 1024,
            policy: ShedPolicy::Block,
            monitor: MonitorConfig::default(),
            drain_max: 0,
            engine: Engine::sequential(),
            reorder_cap: 64,
            gap_patience: 2,
        }
    }
}

/// What happened to one submitted sample.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitOutcome {
    /// Queued with space to spare.
    Accepted,
    /// Queued after blocking for the consumer to drain space
    /// ([`ShedPolicy::Block`] only).
    AcceptedAfterBlock,
    /// Queued; the oldest resident sample was evicted and counted shed.
    ShedOldest,
    /// Rejected and counted shed; the queue is unchanged.
    ShedNewest,
    /// Rejected because the front-end closed (possibly while this
    /// producer was blocked waiting for space). Counted in
    /// `closed_rejects`; the queue is unchanged.
    Closed,
}

/// Per-tenant accounting snapshot. Invariant (always):
/// `accepted + shed + deduped + closed_rejects + resident == submitted`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TenantIngestStats {
    /// Samples ever submitted for this tenant.
    pub submitted: u64,
    /// Samples drained into the batcher (on their way to windows).
    pub accepted: u64,
    /// Samples shed by the overflow policy — every one counted here.
    pub shed: u64,
    /// Samples currently queued or parked in the reorder buffer.
    pub resident: u64,
    /// Times a producer blocked on this queue ([`ShedPolicy::Block`]).
    pub blocked: u64,
    /// High-water mark of queued samples.
    pub peak_resident: u64,
    /// Duplicate deliveries collapsed by the reorder buffer (same
    /// sequence number seen more than once — at-least-once transport).
    pub deduped: u64,
    /// Sequence numbers written off as lost in transit (never
    /// submitted, never shed — a transport drop or partition ate them).
    pub gaps_skipped: u64,
    /// Samples rejected because the front-end was closed.
    pub closed_rejects: u64,
}

impl TenantIngestStats {
    /// Bridge this tenant's ingest counters into a telemetry registry
    /// under `kermit_ingest_*{tenant=...}` (`resident` and
    /// `peak_resident` export as gauges, the rest as counters).
    pub fn export_metrics(&self, reg: &crate::obs::Registry, tenant: &str) {
        let labels = [("tenant", tenant)];
        let c = |name: &str, help: &str, v: u64| {
            reg.counter(name, help, &labels).set_total(v);
        };
        c(
            "kermit_ingest_submitted_total",
            "Samples submitted to the ingest front-end.",
            self.submitted,
        );
        c(
            "kermit_ingest_accepted_total",
            "Samples drained into the batcher.",
            self.accepted,
        );
        c(
            "kermit_ingest_shed_total",
            "Samples shed by the overflow policy.",
            self.shed,
        );
        c(
            "kermit_ingest_blocked_total",
            "Times a producer blocked on a full queue.",
            self.blocked,
        );
        c(
            "kermit_ingest_deduped_total",
            "Duplicate deliveries collapsed by the reorder buffer.",
            self.deduped,
        );
        c(
            "kermit_ingest_gaps_skipped_total",
            "Sequence numbers written off as lost in transit.",
            self.gaps_skipped,
        );
        c(
            "kermit_ingest_closed_rejects_total",
            "Samples rejected because the front-end was closed.",
            self.closed_rejects,
        );
        reg.gauge(
            "kermit_ingest_resident",
            "Samples currently queued or parked in the reorder buffer.",
            &labels,
        )
        .set(self.resident as f64);
        reg.gauge(
            "kermit_ingest_peak_resident",
            "High-water mark of queued samples.",
            &labels,
        )
        .set(self.peak_resident as f64);
    }

    fn absorb(&mut self, o: &TenantIngestStats) {
        self.submitted += o.submitted;
        self.accepted += o.accepted;
        self.shed += o.shed;
        self.resident += o.resident;
        self.blocked += o.blocked;
        self.peak_resident = self.peak_resident.max(o.peak_resident);
        self.deduped += o.deduped;
        self.gaps_skipped += o.gaps_skipped;
        self.closed_rejects += o.closed_rejects;
    }
}

/// One pump's work. `observed` is what the router tick processed —
/// windows enqueued by *this* pump plus any backlog from earlier ones.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PumpStats {
    /// Samples drained out of the queues.
    pub drained: u64,
    /// Windows the batchers closed and enqueued on the router.
    pub windows: u64,
    /// Windows the router tick observed.
    pub observed: u64,
}

/// What one tenant's lane did during a gated drain — the watchdog
/// signal the `stream::supervisor` scores for progress/no-progress.
#[derive(Debug, Clone, Copy)]
pub struct LaneOutcome {
    pub tenant: TenantId,
    /// Samples popped off the tenant queue this drain (0 when skipped).
    pub drained: u64,
    /// Samples released through the reorder buffer into the batcher.
    pub delivered: u64,
    /// Samples still queued + parked in the reorder buffer afterwards.
    pub resident_after: u64,
    /// Max sample time ever delivered for this tenant
    /// (`f64::NEG_INFINITY` before the first delivery).
    pub watermark: f64,
}

struct QueueState {
    buf: VecDeque<(u64, Sample)>,
    /// Next sequence number handed to a plain `submit`.
    seq_next: u64,
    /// Sequence numbers whose sample was shed (or rejected at close) —
    /// known-lost marks the drain feeds the reorder buffer so it never
    /// waits for them.
    lost: Vec<u64>,
    submitted: u64,
    accepted: u64,
    shed: u64,
    blocked: u64,
    peak: u64,
    // written back by the drain (mirrors of the reorder buffer)
    deduped: u64,
    gaps: u64,
    held: u64,
    closed_rejects: u64,
}

struct TenantQueue {
    state: Mutex<QueueState>,
    /// Signaled by the consumer after draining (and by `close`);
    /// blocked producers wait here.
    space: Condvar,
}

impl TenantQueue {
    fn new() -> Arc<TenantQueue> {
        Arc::new(TenantQueue {
            state: Mutex::new(QueueState {
                buf: VecDeque::new(),
                seq_next: 0,
                lost: Vec::new(),
                submitted: 0,
                accepted: 0,
                shed: 0,
                blocked: 0,
                peak: 0,
                deduped: 0,
                gaps: 0,
                held: 0,
                closed_rejects: 0,
            }),
            space: Condvar::new(),
        })
    }

    fn stats(&self) -> TenantIngestStats {
        let st = self.state.lock().unwrap();
        TenantIngestStats {
            submitted: st.submitted,
            accepted: st.accepted,
            shed: st.shed,
            resident: st.buf.len() as u64 + st.held,
            blocked: st.blocked,
            peak_resident: st.peak,
            deduped: st.deduped,
            gaps_skipped: st.gaps,
            closed_rejects: st.closed_rejects,
        }
    }
}

struct IngestShared {
    queue_cap: usize,
    policy: ShedPolicy,
    queues: RwLock<BTreeMap<TenantId, Arc<TenantQueue>>>,
    /// Samples resident across all queues — the consumer's one-atomic
    /// idle check.
    resident: AtomicU64,
    /// Set by `close`; submits turn into `Closed` rejects and blocked
    /// producers wake.
    closed: AtomicBool,
    /// Producers notify here on the empty→non-empty edge;
    /// [`IngestFrontEnd::wait_for_samples`] sleeps here.
    wake: Mutex<()>,
    wake_cv: Condvar,
}

/// Cheap, cloneable producer handle. Any number of threads can hold
/// clones and [`submit`](IngestHandle::submit) concurrently.
#[derive(Clone)]
pub struct IngestHandle {
    shared: Arc<IngestShared>,
}

impl IngestHandle {
    fn queue(&self, t: TenantId) -> Arc<TenantQueue> {
        if let Some(q) = self.shared.queues.read().unwrap().get(&t) {
            return Arc::clone(q);
        }
        let mut qs = self.shared.queues.write().unwrap();
        Arc::clone(qs.entry(t).or_insert_with(TenantQueue::new))
    }

    /// Submit one sample for tenant `t`. Never loses a sample silently:
    /// the returned outcome says what happened, and the per-tenant
    /// counters account for it either way. The sample's sequence number
    /// is assigned here, under the queue lock.
    pub fn submit(&self, t: TenantId, s: Sample) -> SubmitOutcome {
        self.submit_with(t, s, None)
    }

    /// Submit a sample whose sequence number was assigned upstream (by
    /// the transport — see `stream::fault::TransportLayer`). The same
    /// `seq` may arrive more than once (duplication) and out of order
    /// (delay); the drain-side reorder buffer restores exactly-once,
    /// in-order delivery to the batcher.
    pub fn submit_sequenced(
        &self,
        t: TenantId,
        seq: u64,
        s: Sample,
    ) -> SubmitOutcome {
        self.submit_with(t, s, Some(seq))
    }

    fn submit_with(
        &self,
        t: TenantId,
        s: Sample,
        seq: Option<u64>,
    ) -> SubmitOutcome {
        let q = self.queue(t);
        let cap = self.shared.queue_cap;
        let mut st = q.state.lock().unwrap();
        if self.shared.closed.load(Ordering::Acquire) {
            // a transport-assigned seq must still be written off, or a
            // draining flush would wait for a sample that never comes
            if let Some(seq) = seq {
                st.lost.push(seq);
                st.seq_next = st.seq_next.max(seq + 1);
            }
            st.submitted += 1;
            st.closed_rejects += 1;
            return SubmitOutcome::Closed;
        }
        let seq = match seq {
            Some(seq) => {
                st.seq_next = st.seq_next.max(seq + 1);
                seq
            }
            None => {
                let v = st.seq_next;
                st.seq_next += 1;
                v
            }
        };
        let outcome = if st.buf.len() < cap {
            st.buf.push_back((seq, s));
            SubmitOutcome::Accepted
        } else {
            match self.shared.policy {
                ShedPolicy::Block => {
                    st.blocked += 1;
                    loop {
                        if self.shared.closed.load(Ordering::Acquire) {
                            // woken by close, not by space: reject
                            // loudly instead of hanging forever
                            st.lost.push(seq);
                            st.submitted += 1;
                            st.closed_rejects += 1;
                            return SubmitOutcome::Closed;
                        }
                        if st.buf.len() < cap {
                            break;
                        }
                        st = q.space.wait(st).unwrap();
                    }
                    st.buf.push_back((seq, s));
                    SubmitOutcome::AcceptedAfterBlock
                }
                ShedPolicy::ShedOldest => {
                    if let Some((old_seq, _)) = st.buf.pop_front() {
                        st.lost.push(old_seq);
                    }
                    st.shed += 1;
                    st.buf.push_back((seq, s));
                    SubmitOutcome::ShedOldest
                }
                ShedPolicy::ShedNewest => {
                    st.lost.push(seq);
                    st.shed += 1;
                    SubmitOutcome::ShedNewest
                }
            }
        };
        // counted only once the sample's fate is decided (queued or
        // shed), under the same lock hold — so the conservation
        // invariant `accepted + shed + deduped + closed_rejects +
        // resident == submitted` is exact at every instant, even with a
        // producer parked mid-Block.
        st.submitted += 1;
        st.peak = st.peak.max(st.buf.len() as u64);
        drop(st);
        // global resident delta: +1 when a sample entered the queue
        // without evicting one. ShedOldest swaps (net 0), ShedNewest
        // adds nothing.
        if matches!(
            outcome,
            SubmitOutcome::Accepted | SubmitOutcome::AcceptedAfterBlock
        ) && self.shared.resident.fetch_add(1, Ordering::AcqRel) == 0
        {
            // empty→non-empty edge: wake the consumer. Taking the wake
            // mutex orders this notify against a consumer that just
            // re-checked `resident` and is about to sleep.
            let _g = self.shared.wake.lock().unwrap();
            self.shared.wake_cv.notify_all();
        }
        outcome
    }

    /// Accounting snapshot for one tenant (None if it never submitted).
    pub fn tenant_stats(&self, t: TenantId) -> Option<TenantIngestStats> {
        self.shared.queues.read().unwrap().get(&t).map(|q| q.stats())
    }

    /// Accounting snapshot for every tenant.
    pub fn stats(&self) -> BTreeMap<TenantId, TenantIngestStats> {
        let qs = self.shared.queues.read().unwrap();
        qs.iter().map(|(t, q)| (*t, q.stats())).collect()
    }

    /// Cross-tenant totals (peak_resident is the max single-tenant
    /// peak, not a sum).
    pub fn totals(&self) -> TenantIngestStats {
        let mut acc = TenantIngestStats::default();
        for st in self.stats().values() {
            acc.absorb(st);
        }
        acc
    }

    /// Samples currently queued across all tenants.
    pub fn resident(&self) -> u64 {
        self.shared.resident.load(Ordering::Acquire)
    }

    /// Whether the front-end has been closed.
    pub fn is_closed(&self) -> bool {
        self.shared.closed.load(Ordering::Acquire)
    }
}

/// Consumer-side dedup/reorder stage: releases samples to the batcher
/// in sequence order exactly once, no matter how the transport
/// duplicated, delayed, or dropped them. Fault-free it is pure
/// pass-through (sequences arrive contiguous; nothing is ever parked).
#[derive(Debug, Default)]
struct ReorderBuffer {
    /// Next sequence number owed to the batcher.
    next: u64,
    /// Out-of-order arrivals parked until their turn.
    held: BTreeMap<u64, Sample>,
    /// Known-lost sequence numbers (shed / rejected-at-close) — skipped
    /// without waiting when their turn comes.
    lost: BTreeSet<u64>,
    /// Drains survived with an open unknown gap at the head.
    gap_age: u32,
    /// Duplicate deliveries collapsed (cumulative).
    deduped: u64,
    /// Unknown sequence numbers written off (cumulative).
    gaps: u64,
}

impl ReorderBuffer {
    fn pending(&self) -> usize {
        self.held.len()
    }

    /// Record that `seq`'s sample will never arrive through the queue.
    fn mark_lost(&mut self, seq: u64, out: &mut Vec<Sample>) {
        if seq < self.next || self.held.contains_key(&seq) {
            return;
        }
        self.lost.insert(seq);
        if seq == self.next {
            self.release_ready(out);
        }
    }

    /// Offer one drained `(seq, sample)`; contiguous runs land in
    /// `out`, duplicates are collapsed, gaps park the sample.
    fn offer(&mut self, seq: u64, s: Sample, out: &mut Vec<Sample>) {
        if seq < self.next
            || self.held.contains_key(&seq)
            || self.lost.contains(&seq)
        {
            self.deduped += 1;
            return;
        }
        if seq == self.next {
            out.push(s);
            self.next += 1;
            self.release_ready(out);
        } else {
            self.held.insert(seq, s);
        }
    }

    /// Release the contiguous run now sitting at `next`.
    fn release_ready(&mut self, out: &mut Vec<Sample>) {
        loop {
            if let Some(s) = self.held.remove(&self.next) {
                out.push(s);
                self.next += 1;
            } else if self.lost.remove(&self.next) {
                self.next += 1;
            } else {
                break;
            }
        }
    }

    /// End-of-drain bookkeeping: age any unknown head gap and write it
    /// off once it outlives `patience` drains or parks more than `cap`
    /// samples behind it — the dropped/partitioned sample is never
    /// coming, and the parked ones must not starve the windows.
    fn end_drain(&mut self, patience: u32, cap: usize, out: &mut Vec<Sample>) {
        if self.held.is_empty() && self.lost.is_empty() {
            self.gap_age = 0;
            return;
        }
        self.gap_age += 1;
        if self.gap_age >= patience.max(1) || self.held.len() > cap.max(1) {
            self.skip_gap(out);
            self.gap_age = 0;
        }
    }

    /// Write off the unknown gap at the head and release what it was
    /// blocking.
    fn skip_gap(&mut self, out: &mut Vec<Sample>) {
        let lowest = match (self.held.keys().next(), self.lost.iter().next())
        {
            (Some(&a), Some(&b)) => a.min(b),
            (Some(&a), None) => a,
            (None, Some(&b)) => b,
            (None, None) => return,
        };
        self.gaps += lowest - self.next;
        self.next = lowest;
        self.release_ready(out);
    }

    /// Write off every outstanding gap and release everything parked —
    /// the reconcile/shutdown path.
    fn flush_all(&mut self, out: &mut Vec<Sample>) {
        while !(self.held.is_empty() && self.lost.is_empty()) {
            self.skip_gap(out);
        }
        self.gap_age = 0;
    }
}

/// One tenant's drain-and-batch work item for the executor fan-out.
struct Lane<'a> {
    tenant: TenantId,
    queue: Arc<TenantQueue>,
    agg: &'a mut WindowAggregator,
    buf: &'a mut ReorderBuffer,
    windows: Vec<ObservationWindow>,
    drained: u64,
    delivered: u64,
    resident_after: u64,
    watermark: f64,
}

/// The consumer side: owns the per-tenant batchers and drives
/// queue-drain → reorder/dedup → window-batch → router-enqueue → tick.
pub struct IngestFrontEnd {
    shared: Arc<IngestShared>,
    config: IngestConfig,
    batchers: BTreeMap<TenantId, WindowAggregator>,
    reorders: BTreeMap<TenantId, ReorderBuffer>,
    /// Max sample time ever delivered per tenant — the progress
    /// watermark the supervisor compares across tenants.
    delivered_until: BTreeMap<TenantId, f64>,
}

impl IngestFrontEnd {
    pub fn new(config: IngestConfig) -> IngestFrontEnd {
        IngestFrontEnd {
            shared: Arc::new(IngestShared {
                queue_cap: config.queue_cap.max(1),
                policy: config.policy,
                queues: RwLock::new(BTreeMap::new()),
                resident: AtomicU64::new(0),
                closed: AtomicBool::new(false),
                wake: Mutex::new(()),
                wake_cv: Condvar::new(),
            }),
            config,
            batchers: BTreeMap::new(),
            reorders: BTreeMap::new(),
            delivered_until: BTreeMap::new(),
        }
    }

    /// A producer handle (clone freely across threads).
    pub fn handle(&self) -> IngestHandle {
        IngestHandle { shared: Arc::clone(&self.shared) }
    }

    /// Every tenant that has ever submitted, in id order.
    pub fn tenant_ids(&self) -> Vec<TenantId> {
        self.shared.queues.read().unwrap().keys().copied().collect()
    }

    /// Samples accepted into batchers but not yet closed into a window
    /// (the partial tail of each tenant's current window).
    pub fn open_samples(&self) -> usize {
        self.batchers.values().map(|a| a.pending_samples()).sum()
    }

    /// Samples currently queued across all tenants.
    pub fn resident(&self) -> u64 {
        self.shared.resident.load(Ordering::Acquire)
    }

    /// Close the front-end: all further submits return
    /// [`SubmitOutcome::Closed`], and every producer parked in a
    /// [`ShedPolicy::Block`] wait wakes immediately with the same
    /// outcome. Draining/pumping still works, so a shutdown can close
    /// first and flush the backlog after.
    pub fn close(&self) {
        self.shared.closed.store(true, Ordering::Release);
        let qs = self.shared.queues.read().unwrap();
        for q in qs.values() {
            // take the queue lock so the store above cannot interleave
            // between a producer's closed-check and its wait()
            let _st = q.state.lock().unwrap();
            q.space.notify_all();
        }
    }

    /// Whether [`close`](IngestFrontEnd::close) has been called.
    pub fn is_closed(&self) -> bool {
        self.shared.closed.load(Ordering::Acquire)
    }

    /// Sleep until at least one sample is queued, or `timeout` passes.
    /// Returns whether samples are waiting. Never misses the producer
    /// edge-notify: the resident check is repeated under the wake
    /// mutex producers notify through.
    pub fn wait_for_samples(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        if self.resident() > 0 {
            return true;
        }
        let mut g = self.shared.wake.lock().unwrap();
        loop {
            if self.shared.resident.load(Ordering::Acquire) > 0 {
                return true;
            }
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            let (guard, _res) = self
                .shared
                .wake_cv
                .wait_timeout(g, deadline - now)
                .unwrap();
            g = guard;
        }
    }

    /// Drain every tenant queue into its batcher (fanned across the
    /// engine) and enqueue the closed windows on `router` — without
    /// ticking it. Each lane is drained FIFO by exactly one worker and
    /// windows are enqueued in tenant order on the calling thread, so
    /// the result is bit-identical to a sequential drain regardless of
    /// engine threads.
    pub fn drain_into(&mut self, router: &mut StreamRouter) -> PumpStats {
        self.drain_gated(router, &[]).0
    }

    /// [`drain_into`](IngestFrontEnd::drain_into) with a lane gate:
    /// tenants in `skip` are left untouched this pump (a wedged lane
    /// worker, or a supervisor backoff). Returns per-lane outcomes —
    /// including the skipped lanes, with `drained == 0` — for the
    /// supervisor's watchdogs.
    pub fn drain_gated(
        &mut self,
        router: &mut StreamRouter,
        skip: &[TenantId],
    ) -> (PumpStats, Vec<LaneOutcome>) {
        let snapshot: Vec<(TenantId, Arc<TenantQueue>)> = {
            let qs = self.shared.queues.read().unwrap();
            qs.iter().map(|(t, q)| (*t, Arc::clone(q))).collect()
        };
        let monitor = self.config.monitor.clone();
        for (t, _) in &snapshot {
            self.batchers
                .entry(*t)
                .or_insert_with(|| WindowAggregator::new(monitor.clone(), 0));
            self.reorders.entry(*t).or_insert_with(ReorderBuffer::default);
        }
        let queues: BTreeMap<TenantId, Arc<TenantQueue>> =
            snapshot.into_iter().collect();
        let mut skipped: Vec<LaneOutcome> = Vec::new();
        let mut bufs: BTreeMap<TenantId, &mut ReorderBuffer> =
            self.reorders.iter_mut().map(|(t, b)| (*t, b)).collect();
        let mut lanes: Vec<Lane> = self
            .batchers
            .iter_mut()
            .filter_map(|(t, agg)| {
                let q = queues.get(t)?;
                if skip.contains(t) {
                    skipped.push(LaneOutcome {
                        tenant: *t,
                        drained: 0,
                        delivered: 0,
                        resident_after: q.stats().resident,
                        watermark: f64::NEG_INFINITY,
                    });
                    return None;
                }
                let buf = bufs.remove(t)?;
                Some(Lane {
                    tenant: *t,
                    queue: Arc::clone(q),
                    agg,
                    buf,
                    windows: Vec::new(),
                    drained: 0,
                    delivered: 0,
                    resident_after: 0,
                    watermark: f64::NEG_INFINITY,
                })
            })
            .collect();
        let drain_max = self.config.drain_max;
        let patience = self.config.gap_patience;
        let reorder_cap = self.config.reorder_cap;
        let shared = &self.shared;
        // one work item = one tenant's drain+batch; costs are as skewed
        // as the traffic (that's the point of the work-stealing
        // executor), so every lane is its own stealable chunk
        let engine = self.config.engine.with_min_items(1);
        engine.for_rows(&mut lanes, 1, |_, chunk| {
            for lane in chunk.iter_mut() {
                let (popped, lost_marks): (Vec<(u64, Sample)>, Vec<u64>) = {
                    let mut st = lane.queue.state.lock().unwrap();
                    let n = if drain_max == 0 {
                        st.buf.len()
                    } else {
                        st.buf.len().min(drain_max)
                    };
                    (st.buf.drain(..n).collect(), std::mem::take(&mut st.lost))
                };
                if !popped.is_empty() {
                    // space freed: release blocked producers, then
                    // retire the residents globally
                    lane.queue.space.notify_all();
                    shared
                        .resident
                        .fetch_sub(popped.len() as u64, Ordering::AcqRel);
                    lane.drained = popped.len() as u64;
                }
                let before =
                    (lane.buf.deduped, lane.buf.gaps, lane.buf.pending());
                let mut out: Vec<Sample> = Vec::with_capacity(popped.len());
                for seq in &lost_marks {
                    lane.buf.mark_lost(*seq, &mut out);
                }
                for (seq, s) in popped {
                    lane.buf.offer(seq, s, &mut out);
                }
                lane.buf.end_drain(patience, reorder_cap, &mut out);
                lane.delivered = out.len() as u64;
                for s in out {
                    if s.time > lane.watermark {
                        lane.watermark = s.time;
                    }
                    if let Some(w) = lane.agg.push(s) {
                        lane.windows.push(w);
                    }
                }
                let after =
                    (lane.buf.deduped, lane.buf.gaps, lane.buf.pending());
                let mut st = lane.queue.state.lock().unwrap();
                if lane.drained > 0
                    || lane.delivered > 0
                    || !lost_marks.is_empty()
                    || before != after
                {
                    st.accepted += lane.delivered;
                    st.deduped = after.0;
                    st.gaps = after.1;
                    st.held = after.2 as u64;
                }
                lane.resident_after = st.buf.len() as u64 + st.held;
            }
        });
        let mut stats = PumpStats::default();
        let mut outcomes = skipped;
        for o in outcomes.iter_mut() {
            if let Some(wm) = self.delivered_until.get(&o.tenant) {
                o.watermark = *wm;
            }
        }
        for lane in &lanes {
            stats.drained += lane.drained;
            stats.windows += lane.windows.len() as u64;
            if !lane.windows.is_empty() {
                router.enqueue_windows(lane.tenant, &lane.windows);
            }
            let wm = self
                .delivered_until
                .entry(lane.tenant)
                .or_insert(f64::NEG_INFINITY);
            if lane.watermark > *wm {
                *wm = lane.watermark;
            }
            outcomes.push(LaneOutcome {
                tenant: lane.tenant,
                drained: lane.drained,
                delivered: lane.delivered,
                resident_after: lane.resident_after,
                watermark: *wm,
            });
        }
        drop(lanes);
        outcomes.sort_by_key(|o| o.tenant.0);
        (stats, outcomes)
    }

    /// Reconcile the transport: drain everything, then write off every
    /// outstanding sequence gap and release all parked samples into the
    /// batchers — the "link healed / run over" settlement that
    /// guarantees no lane stays wedged on a sample that will never
    /// arrive. Windows closed by the settlement are enqueued on
    /// `router` (not ticked).
    pub fn flush_transport(&mut self, router: &mut StreamRouter) -> PumpStats {
        let mut stats = self.drain_into(router);
        let monitor = self.config.monitor.clone();
        let queues: BTreeMap<TenantId, Arc<TenantQueue>> = {
            let qs = self.shared.queues.read().unwrap();
            qs.iter().map(|(t, q)| (*t, Arc::clone(q))).collect()
        };
        for (t, buf) in self.reorders.iter_mut() {
            if buf.held.is_empty() && buf.lost.is_empty() {
                continue;
            }
            let mut out: Vec<Sample> = Vec::new();
            buf.flush_all(&mut out);
            let agg = self
                .batchers
                .entry(*t)
                .or_insert_with(|| WindowAggregator::new(monitor.clone(), 0));
            let mut windows: Vec<ObservationWindow> = Vec::new();
            let delivered = out.len() as u64;
            for s in out {
                let wm =
                    self.delivered_until.entry(*t).or_insert(f64::NEG_INFINITY);
                if s.time > *wm {
                    *wm = s.time;
                }
                if let Some(w) = agg.push(s) {
                    windows.push(w);
                }
            }
            stats.windows += windows.len() as u64;
            if !windows.is_empty() {
                router.enqueue_windows(*t, &windows);
            }
            if let Some(q) = queues.get(t) {
                let mut st = q.state.lock().unwrap();
                st.accepted += delivered;
                st.deduped = buf.deduped;
                st.gaps = buf.gaps;
                st.held = buf.pending() as u64;
            }
        }
        stats
    }

    /// Delivery watermark for one tenant (max delivered sample time).
    pub fn watermark(&self, t: TenantId) -> Option<f64> {
        self.delivered_until.get(&t).copied()
    }

    /// One full pump: drain + batch + enqueue, then tick the router.
    pub fn pump(&mut self, router: &mut StreamRouter) -> PumpStats {
        let mut stats = self.drain_into(router);
        stats.observed = router.tick() as u64;
        stats
    }

    /// Event-driven pump: sleep until samples arrive (or `timeout`),
    /// then pump. `None` means the wait timed out with nothing queued.
    pub fn pump_when_ready(
        &mut self,
        router: &mut StreamRouter,
        timeout: Duration,
    ) -> Option<PumpStats> {
        if self.wait_for_samples(timeout) {
            Some(self.pump(router))
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::monitor::aggregate_samples;
    use crate::stream::router::RouterConfig;
    use crate::workloadgen::{tour_schedule, Generator};

    fn samples(seed: u64, classes: &[u32]) -> Vec<Sample> {
        let mut g = Generator::with_default_config(seed);
        g.generate(&tour_schedule(40, classes)).samples
    }

    fn front_end(cap: usize, policy: ShedPolicy) -> IngestFrontEnd {
        IngestFrontEnd::new(IngestConfig {
            queue_cap: cap,
            policy,
            monitor: MonitorConfig { window_size: 10 },
            ..Default::default()
        })
    }

    #[test]
    fn shed_oldest_keeps_newest_and_counts_evictions() {
        let fe = front_end(4, ShedPolicy::ShedOldest);
        let h = fe.handle();
        let t = TenantId(0);
        let ss = samples(1, &[0]);
        for (i, s) in ss.iter().take(10).enumerate() {
            let out = h.submit(t, s.clone());
            if i < 4 {
                assert_eq!(out, SubmitOutcome::Accepted);
            } else {
                assert_eq!(out, SubmitOutcome::ShedOldest);
            }
        }
        let st = h.tenant_stats(t).unwrap();
        assert_eq!(st.submitted, 10);
        assert_eq!(st.shed, 6);
        assert_eq!(st.resident, 4);
        assert_eq!(st.accepted, 0, "nothing drained yet");
        assert_eq!(st.accepted + st.shed + st.resident, st.submitted);
        assert_eq!(st.peak_resident, 4);
        assert_eq!(h.resident(), 4);
    }

    #[test]
    fn shed_newest_keeps_oldest_and_counts_rejections() {
        let fe = front_end(4, ShedPolicy::ShedNewest);
        let h = fe.handle();
        let t = TenantId(3);
        let ss = samples(2, &[1]);
        for (i, s) in ss.iter().take(10).enumerate() {
            let out = h.submit(t, s.clone());
            if i < 4 {
                assert_eq!(out, SubmitOutcome::Accepted);
            } else {
                assert_eq!(out, SubmitOutcome::ShedNewest);
            }
        }
        let st = h.tenant_stats(t).unwrap();
        assert_eq!(st.submitted, 10);
        assert_eq!(st.shed, 6);
        assert_eq!(st.resident, 4);
        assert_eq!(st.accepted + st.shed + st.resident, st.submitted);
    }

    #[test]
    fn pump_batches_windows_bit_identical_to_offline_aggregation() {
        let mcfg = MonitorConfig { window_size: 10 };
        let mut fe = front_end(1 << 16, ShedPolicy::Block);
        let h = fe.handle();
        let mut router = StreamRouter::new(RouterConfig {
            monitor: mcfg.clone(),
            ..Default::default()
        });
        let ss = samples(3, &[0, 2]);
        let t = TenantId(7);
        for s in &ss {
            assert_eq!(h.submit(t, s.clone()), SubmitOutcome::Accepted);
        }
        let st = fe.pump(&mut router);
        let expect = aggregate_samples(&ss, &mcfg);
        assert_eq!(st.drained, ss.len() as u64);
        assert_eq!(st.windows, expect.len() as u64);
        assert_eq!(st.observed, expect.len() as u64);
        assert_eq!(fe.open_samples(), ss.len() % 10);
        // the windows the router observed are bit-identical to offline
        // aggregation of the same sample stream
        let taken = router.take_observed();
        assert_eq!(taken.len(), 1);
        assert_eq!(taken[0].0, t);
        assert_eq!(taken[0].1, expect);
        // drained == accepted, conservation holds at quiesce
        let ts = h.tenant_stats(t).unwrap();
        assert_eq!(ts.accepted, ss.len() as u64);
        assert_eq!(ts.resident, 0);
        assert_eq!(ts.shed, 0);
        assert_eq!(ts.deduped, 0);
        assert_eq!(ts.gaps_skipped, 0);
    }

    #[test]
    fn wait_for_samples_times_out_empty_and_wakes_on_submit() {
        let fe = front_end(8, ShedPolicy::Block);
        assert!(!fe.wait_for_samples(Duration::from_millis(1)));
        let h = fe.handle();
        let s = samples(4, &[0])[0].clone();
        h.submit(TenantId(1), s);
        assert!(fe.wait_for_samples(Duration::from_millis(1)));
        // still true on a zero timeout once samples are resident
        assert!(fe.wait_for_samples(Duration::ZERO));
    }

    #[test]
    fn drain_max_smooths_a_burst_across_pumps() {
        let mcfg = MonitorConfig { window_size: 10 };
        let mut fe = IngestFrontEnd::new(IngestConfig {
            queue_cap: 1 << 16,
            policy: ShedPolicy::Block,
            monitor: mcfg.clone(),
            drain_max: 25,
            ..Default::default()
        });
        let h = fe.handle();
        let mut router = StreamRouter::new(RouterConfig {
            monitor: mcfg,
            ..Default::default()
        });
        let ss = samples(5, &[2]);
        assert!(ss.len() > 25);
        for s in &ss {
            h.submit(TenantId(0), s.clone());
        }
        let st1 = fe.pump(&mut router);
        assert_eq!(st1.drained, 25);
        let mut total = st1.drained;
        while fe.resident() > 0 {
            total += fe.pump(&mut router).drained;
        }
        assert_eq!(total, ss.len() as u64);
    }

    #[test]
    fn duplicate_and_reordered_seqs_collapse_to_inorder_exactly_once() {
        let mcfg = MonitorConfig { window_size: 10 };
        let mut fe = front_end(1 << 16, ShedPolicy::ShedOldest);
        let h = fe.handle();
        let mut router = StreamRouter::new(RouterConfig {
            monitor: mcfg.clone(),
            ..Default::default()
        });
        let ss = samples(6, &[0, 2]);
        let t = TenantId(2);
        // deliver every adjacent pair swapped, and duplicate every
        // sample at an even index divisible by 3
        let mut dups = 0u64;
        let mut i = 0usize;
        while i < ss.len() {
            if i + 1 < ss.len() {
                h.submit_sequenced(t, (i + 1) as u64, ss[i + 1].clone());
            }
            h.submit_sequenced(t, i as u64, ss[i].clone());
            if i % 3 == 0 {
                h.submit_sequenced(t, i as u64, ss[i].clone());
                dups += 1;
            }
            i += 2;
        }
        let st = fe.pump(&mut router);
        assert_eq!(st.drained as usize, ss.len() + dups as usize);
        // windows are bit-identical to clean in-order aggregation
        let expect = aggregate_samples(&ss, &mcfg);
        assert_eq!(st.windows, expect.len() as u64);
        let taken = router.take_observed();
        assert_eq!(taken[0].1, expect, "reorder buffer broke the stream");
        let ts = h.tenant_stats(t).unwrap();
        assert_eq!(ts.deduped, dups);
        assert_eq!(ts.gaps_skipped, 0);
        assert_eq!(
            ts.accepted + ts.shed + ts.deduped + ts.closed_rejects,
            ts.submitted - ts.resident
        );
    }

    #[test]
    fn transport_gap_is_written_off_after_patience_pumps() {
        let mcfg = MonitorConfig { window_size: 5 };
        let mut fe = IngestFrontEnd::new(IngestConfig {
            queue_cap: 1 << 16,
            policy: ShedPolicy::ShedOldest,
            monitor: mcfg.clone(),
            gap_patience: 2,
            ..Default::default()
        });
        let h = fe.handle();
        let mut router = StreamRouter::new(RouterConfig {
            monitor: mcfg,
            ..Default::default()
        });
        let ss = samples(7, &[1]);
        let t = TenantId(4);
        // seq 3 is dropped in transit: 0,1,2 then 4..12
        for (i, s) in ss.iter().take(13).enumerate() {
            if i == 3 {
                continue;
            }
            h.submit_sequenced(t, i as u64, s.clone());
        }
        let st1 = fe.pump(&mut router);
        // 0..=2 released; 4.. parked behind the gap
        assert_eq!(st1.drained, 12);
        let ts = h.tenant_stats(t).unwrap();
        assert_eq!(ts.accepted, 3);
        assert!(ts.resident > 0, "parked samples count as resident");
        // second pump: gap outlives patience, written off, rest flows
        let _ = fe.pump(&mut router);
        let ts = h.tenant_stats(t).unwrap();
        assert_eq!(ts.gaps_skipped, 1);
        assert_eq!(ts.accepted, 12);
        assert_eq!(ts.resident, 0);
    }

    #[test]
    fn close_wakes_blocked_producer_with_closed_outcome() {
        let fe = front_end(2, ShedPolicy::Block);
        let h = fe.handle();
        let t = TenantId(0);
        let ss = samples(8, &[0]);
        h.submit(t, ss[0].clone());
        h.submit(t, ss[1].clone());
        let h2 = fe.handle();
        let s2 = ss[2].clone();
        let blocked = std::thread::spawn(move || h2.submit(t, s2));
        // wait until the producer is parked in the Block wait
        while h.tenant_stats(t).unwrap().blocked == 0 {
            std::thread::yield_now();
        }
        fe.close();
        let out = blocked.join().expect("blocked producer never woke");
        assert_eq!(out, SubmitOutcome::Closed);
        // submits after close are rejected loudly too
        assert_eq!(h.submit(t, ss[3].clone()), SubmitOutcome::Closed);
        let st = h.tenant_stats(t).unwrap();
        assert_eq!(st.closed_rejects, 2);
        assert_eq!(
            st.accepted + st.shed + st.deduped + st.closed_rejects
                + st.resident,
            st.submitted
        );
        assert!(h.is_closed());
    }

    #[test]
    fn flush_transport_releases_parked_samples_and_clears_gaps() {
        let mcfg = MonitorConfig { window_size: 5 };
        let mut fe = IngestFrontEnd::new(IngestConfig {
            queue_cap: 1 << 16,
            policy: ShedPolicy::ShedOldest,
            monitor: mcfg.clone(),
            gap_patience: 1000, // never written off by patience
            ..Default::default()
        });
        let h = fe.handle();
        let mut router = StreamRouter::new(RouterConfig {
            monitor: mcfg,
            ..Default::default()
        });
        let ss = samples(9, &[2]);
        let t = TenantId(1);
        // seqs 0 and 5 never arrive
        for (i, s) in ss.iter().take(10).enumerate() {
            if i == 0 || i == 5 {
                continue;
            }
            h.submit_sequenced(t, i as u64, s.clone());
        }
        let _ = fe.pump(&mut router);
        let ts = h.tenant_stats(t).unwrap();
        assert_eq!(ts.accepted, 0, "everything parked behind seq 0");
        let _ = fe.flush_transport(&mut router);
        router.tick();
        let ts = h.tenant_stats(t).unwrap();
        assert_eq!(ts.gaps_skipped, 2);
        assert_eq!(ts.accepted, 8);
        assert_eq!(ts.resident, 0, "no lane left wedged after reconcile");
    }
}
