//! Event-driven ingest front-end: bounded per-tenant sample queues, an
//! off-caller-thread batcher, and explicit backpressure — the entry
//! point for "heavy traffic from millions of users".
//!
//! # Why not call `StreamRouter::ingest` directly?
//!
//! The router is a *consumer-side* structure: ingesting into it takes
//! `&mut self`, so every producer serializes on the owner of the
//! router, and a slow tick stalls the producers themselves. This module
//! splits the two roles:
//!
//! * **Producers** hold a cheap, cloneable [`IngestHandle`] and call
//!   [`IngestHandle::submit`] — one short per-tenant mutex hold, no
//!   aggregation, no router access. Any number of producer threads can
//!   submit concurrently.
//! * The **consumer** owns the [`IngestFrontEnd`] (and the router) and
//!   drives [`IngestFrontEnd::pump`]: drain every tenant queue, coalesce
//!   samples into `ObservationWindow`s through per-tenant
//!   [`WindowAggregator`]s (fanned across the engine's work-stealing
//!   executor — the same executor router ticks, offline cycles, and
//!   tuning probes run on), enqueue the windows on the router, and
//!   tick it.
//!
//! The front-end is **event-driven**: producers signal the consumer's
//! condvar on the empty→non-empty edge, so an idle consumer sleeps in
//! [`IngestFrontEnd::wait_for_samples`] instead of spinning, and a busy
//! one never pays more than one atomic check per pump.
//!
//! # Backpressure is explicit, shedding is never silent
//!
//! Every queue is bounded at `queue_cap`. What happens on overflow is
//! the [`ShedPolicy`] picked at construction:
//!
//! | policy | producer sees | queue keeps | counted in |
//! |--------|---------------|-------------|------------|
//! | [`ShedPolicy::Block`] | blocks until space | everything | `blocked` (waits), never sheds |
//! | [`ShedPolicy::ShedOldest`] | returns immediately | newest `queue_cap` | `shed` (the evicted oldest) |
//! | [`ShedPolicy::ShedNewest`] | returns immediately | oldest `queue_cap` | `shed` (the rejected newcomer) |
//!
//! Per tenant, at every quiesce point (queue drained):
//! `accepted + shed == submitted` — and at any instant
//! `accepted + shed + resident == submitted`, where `accepted` counts
//! samples handed to the batcher and `resident` counts samples still
//! queued. `tests/ingest.rs` pins the invariant under every policy and
//! under concurrent producers.
//!
//! Shedding decisions are **deterministic**: they are a pure function
//! of the queue state at submit time, so a seeded single-threaded
//! replay produces the identical outcome sequence (also pinned).

use super::router::StreamRouter;
use super::tenant::TenantId;
use crate::features::ObservationWindow;
use crate::linalg::engine::Engine;
use crate::monitor::{MonitorConfig, WindowAggregator};
use crate::workloadgen::Sample;
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::{Duration, Instant};

/// What a full per-tenant queue does with the next sample.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedPolicy {
    /// Block the producer until the consumer drains space. Lossless;
    /// couples producer latency to consumer health. A blocked producer
    /// relies on a live consumer — only use where one is guaranteed.
    Block,
    /// Evict the oldest queued sample to admit the new one (keep the
    /// freshest data — right for monitoring, where stale samples decay
    /// in value). The evicted sample is counted, never silently lost.
    ShedOldest,
    /// Reject the incoming sample (keep the oldest — right when windows
    /// must stay contiguous from their start). Counted, never silent.
    ShedNewest,
}

/// Front-end configuration.
#[derive(Debug, Clone)]
pub struct IngestConfig {
    /// Per-tenant queue bound (clamped to ≥ 1).
    pub queue_cap: usize,
    /// Overflow behaviour for every queue.
    pub policy: ShedPolicy,
    /// Window aggregation config for the batchers. Must match the
    /// router's monitor config for windows to be bit-identical to
    /// direct `StreamRouter::ingest` (the coordinator's
    /// `attach_ingest` enforces this).
    pub monitor: MonitorConfig,
    /// Max samples drained per tenant per pump (0 = drain everything).
    /// A bound smooths one bursty tenant's latency impact on the rest.
    pub drain_max: usize,
    /// Engine the batching fans out on — share the coordinator's so
    /// batching, ticks, and offline cycles use one executor.
    pub engine: Engine,
}

impl Default for IngestConfig {
    fn default() -> Self {
        IngestConfig {
            queue_cap: 1024,
            policy: ShedPolicy::Block,
            monitor: MonitorConfig::default(),
            drain_max: 0,
            engine: Engine::sequential(),
        }
    }
}

/// What happened to one submitted sample.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitOutcome {
    /// Queued with space to spare.
    Accepted,
    /// Queued after blocking for the consumer to drain space
    /// ([`ShedPolicy::Block`] only).
    AcceptedAfterBlock,
    /// Queued; the oldest resident sample was evicted and counted shed.
    ShedOldest,
    /// Rejected and counted shed; the queue is unchanged.
    ShedNewest,
}

/// Per-tenant accounting snapshot. Invariant (always):
/// `accepted + shed + resident == submitted`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TenantIngestStats {
    /// Samples ever submitted for this tenant.
    pub submitted: u64,
    /// Samples drained into the batcher (on their way to windows).
    pub accepted: u64,
    /// Samples shed by the overflow policy — every one counted here.
    pub shed: u64,
    /// Samples currently queued.
    pub resident: u64,
    /// Times a producer blocked on this queue ([`ShedPolicy::Block`]).
    pub blocked: u64,
    /// High-water mark of `resident`.
    pub peak_resident: u64,
}

impl TenantIngestStats {
    fn absorb(&mut self, o: &TenantIngestStats) {
        self.submitted += o.submitted;
        self.accepted += o.accepted;
        self.shed += o.shed;
        self.resident += o.resident;
        self.blocked += o.blocked;
        self.peak_resident = self.peak_resident.max(o.peak_resident);
    }
}

/// One pump's work. `observed` is what the router tick processed —
/// windows enqueued by *this* pump plus any backlog from earlier ones.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PumpStats {
    /// Samples drained out of the queues.
    pub drained: u64,
    /// Windows the batchers closed and enqueued on the router.
    pub windows: u64,
    /// Windows the router tick observed.
    pub observed: u64,
}

struct QueueState {
    buf: VecDeque<Sample>,
    submitted: u64,
    accepted: u64,
    shed: u64,
    blocked: u64,
    peak: u64,
}

struct TenantQueue {
    state: Mutex<QueueState>,
    /// Signaled by the consumer after draining; blocked producers wait
    /// here.
    space: Condvar,
}

impl TenantQueue {
    fn new() -> Arc<TenantQueue> {
        Arc::new(TenantQueue {
            state: Mutex::new(QueueState {
                buf: VecDeque::new(),
                submitted: 0,
                accepted: 0,
                shed: 0,
                blocked: 0,
                peak: 0,
            }),
            space: Condvar::new(),
        })
    }

    fn stats(&self) -> TenantIngestStats {
        let st = self.state.lock().unwrap();
        TenantIngestStats {
            submitted: st.submitted,
            accepted: st.accepted,
            shed: st.shed,
            resident: st.buf.len() as u64,
            blocked: st.blocked,
            peak_resident: st.peak,
        }
    }
}

struct IngestShared {
    queue_cap: usize,
    policy: ShedPolicy,
    queues: RwLock<BTreeMap<TenantId, Arc<TenantQueue>>>,
    /// Samples resident across all queues — the consumer's one-atomic
    /// idle check.
    resident: AtomicU64,
    /// Producers notify here on the empty→non-empty edge;
    /// [`IngestFrontEnd::wait_for_samples`] sleeps here.
    wake: Mutex<()>,
    wake_cv: Condvar,
}

/// Cheap, cloneable producer handle. Any number of threads can hold
/// clones and [`submit`](IngestHandle::submit) concurrently.
#[derive(Clone)]
pub struct IngestHandle {
    shared: Arc<IngestShared>,
}

impl IngestHandle {
    fn queue(&self, t: TenantId) -> Arc<TenantQueue> {
        if let Some(q) = self.shared.queues.read().unwrap().get(&t) {
            return Arc::clone(q);
        }
        let mut qs = self.shared.queues.write().unwrap();
        Arc::clone(qs.entry(t).or_insert_with(TenantQueue::new))
    }

    /// Submit one sample for tenant `t`. Never loses a sample silently:
    /// the returned outcome says what happened, and the per-tenant
    /// counters account for it either way.
    pub fn submit(&self, t: TenantId, s: Sample) -> SubmitOutcome {
        let q = self.queue(t);
        let cap = self.shared.queue_cap;
        let mut st = q.state.lock().unwrap();
        let outcome = if st.buf.len() < cap {
            st.buf.push_back(s);
            SubmitOutcome::Accepted
        } else {
            match self.shared.policy {
                ShedPolicy::Block => {
                    st.blocked += 1;
                    while st.buf.len() >= cap {
                        st = q.space.wait(st).unwrap();
                    }
                    st.buf.push_back(s);
                    SubmitOutcome::AcceptedAfterBlock
                }
                ShedPolicy::ShedOldest => {
                    st.buf.pop_front();
                    st.shed += 1;
                    st.buf.push_back(s);
                    SubmitOutcome::ShedOldest
                }
                ShedPolicy::ShedNewest => {
                    st.shed += 1;
                    SubmitOutcome::ShedNewest
                }
            }
        };
        // counted only once the sample's fate is decided (queued or
        // shed), under the same lock hold — so the conservation
        // invariant `accepted + shed + resident == submitted` is exact
        // at every instant, even with a producer parked mid-Block.
        st.submitted += 1;
        st.peak = st.peak.max(st.buf.len() as u64);
        drop(st);
        // global resident delta: +1 when a sample entered the queue
        // without evicting one. ShedOldest swaps (net 0), ShedNewest
        // adds nothing.
        if matches!(
            outcome,
            SubmitOutcome::Accepted | SubmitOutcome::AcceptedAfterBlock
        ) && self.shared.resident.fetch_add(1, Ordering::AcqRel) == 0
        {
            // empty→non-empty edge: wake the consumer. Taking the wake
            // mutex orders this notify against a consumer that just
            // re-checked `resident` and is about to sleep.
            let _g = self.shared.wake.lock().unwrap();
            self.shared.wake_cv.notify_all();
        }
        outcome
    }

    /// Accounting snapshot for one tenant (None if it never submitted).
    pub fn tenant_stats(&self, t: TenantId) -> Option<TenantIngestStats> {
        self.shared.queues.read().unwrap().get(&t).map(|q| q.stats())
    }

    /// Accounting snapshot for every tenant.
    pub fn stats(&self) -> BTreeMap<TenantId, TenantIngestStats> {
        let qs = self.shared.queues.read().unwrap();
        qs.iter().map(|(t, q)| (*t, q.stats())).collect()
    }

    /// Cross-tenant totals (peak_resident is the max single-tenant
    /// peak, not a sum).
    pub fn totals(&self) -> TenantIngestStats {
        let mut acc = TenantIngestStats::default();
        for st in self.stats().values() {
            acc.absorb(st);
        }
        acc
    }

    /// Samples currently queued across all tenants.
    pub fn resident(&self) -> u64 {
        self.shared.resident.load(Ordering::Acquire)
    }
}

/// One tenant's drain-and-batch work item for the executor fan-out.
struct Lane<'a> {
    tenant: TenantId,
    queue: Arc<TenantQueue>,
    agg: &'a mut WindowAggregator,
    windows: Vec<ObservationWindow>,
    drained: u64,
}

/// The consumer side: owns the per-tenant batchers and drives
/// queue-drain → window-batch → router-enqueue → tick.
pub struct IngestFrontEnd {
    shared: Arc<IngestShared>,
    config: IngestConfig,
    batchers: BTreeMap<TenantId, WindowAggregator>,
}

impl IngestFrontEnd {
    pub fn new(config: IngestConfig) -> IngestFrontEnd {
        IngestFrontEnd {
            shared: Arc::new(IngestShared {
                queue_cap: config.queue_cap.max(1),
                policy: config.policy,
                queues: RwLock::new(BTreeMap::new()),
                resident: AtomicU64::new(0),
                wake: Mutex::new(()),
                wake_cv: Condvar::new(),
            }),
            config,
            batchers: BTreeMap::new(),
        }
    }

    /// A producer handle (clone freely across threads).
    pub fn handle(&self) -> IngestHandle {
        IngestHandle { shared: Arc::clone(&self.shared) }
    }

    /// Every tenant that has ever submitted, in id order.
    pub fn tenant_ids(&self) -> Vec<TenantId> {
        self.shared.queues.read().unwrap().keys().copied().collect()
    }

    /// Samples accepted into batchers but not yet closed into a window
    /// (the partial tail of each tenant's current window).
    pub fn open_samples(&self) -> usize {
        self.batchers.values().map(|a| a.pending_samples()).sum()
    }

    /// Samples currently queued across all tenants.
    pub fn resident(&self) -> u64 {
        self.shared.resident.load(Ordering::Acquire)
    }

    /// Sleep until at least one sample is queued, or `timeout` passes.
    /// Returns whether samples are waiting. Never misses the producer
    /// edge-notify: the resident check is repeated under the wake
    /// mutex producers notify through.
    pub fn wait_for_samples(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        if self.resident() > 0 {
            return true;
        }
        let mut g = self.shared.wake.lock().unwrap();
        loop {
            if self.shared.resident.load(Ordering::Acquire) > 0 {
                return true;
            }
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            let (guard, _res) = self
                .shared
                .wake_cv
                .wait_timeout(g, deadline - now)
                .unwrap();
            g = guard;
        }
    }

    /// Drain every tenant queue into its batcher (fanned across the
    /// engine) and enqueue the closed windows on `router` — without
    /// ticking it. Each lane is drained FIFO by exactly one worker and
    /// windows are enqueued in tenant order on the calling thread, so
    /// the result is bit-identical to a sequential drain regardless of
    /// engine threads.
    pub fn drain_into(&mut self, router: &mut StreamRouter) -> PumpStats {
        let snapshot: Vec<(TenantId, Arc<TenantQueue>)> = {
            let qs = self.shared.queues.read().unwrap();
            qs.iter().map(|(t, q)| (*t, Arc::clone(q))).collect()
        };
        let monitor = self.config.monitor.clone();
        for (t, _) in &snapshot {
            self.batchers
                .entry(*t)
                .or_insert_with(|| WindowAggregator::new(monitor.clone(), 0));
        }
        let queues: BTreeMap<TenantId, Arc<TenantQueue>> =
            snapshot.into_iter().collect();
        let mut lanes: Vec<Lane> = self
            .batchers
            .iter_mut()
            .filter_map(|(t, agg)| {
                queues.get(t).map(|q| Lane {
                    tenant: *t,
                    queue: Arc::clone(q),
                    agg,
                    windows: Vec::new(),
                    drained: 0,
                })
            })
            .collect();
        let drain_max = self.config.drain_max;
        let shared = &self.shared;
        // one work item = one tenant's drain+batch; costs are as skewed
        // as the traffic (that's the point of the work-stealing
        // executor), so every lane is its own stealable chunk
        let engine = self.config.engine.with_min_items(1);
        engine.for_rows(&mut lanes, 1, |_, chunk| {
            for lane in chunk.iter_mut() {
                let drained: Vec<Sample> = {
                    let mut st = lane.queue.state.lock().unwrap();
                    let n = if drain_max == 0 {
                        st.buf.len()
                    } else {
                        st.buf.len().min(drain_max)
                    };
                    st.accepted += n as u64;
                    st.buf.drain(..n).collect()
                };
                if drained.is_empty() {
                    continue;
                }
                // space freed: release blocked producers, then retire
                // the residents globally
                lane.queue.space.notify_all();
                shared
                    .resident
                    .fetch_sub(drained.len() as u64, Ordering::AcqRel);
                lane.drained = drained.len() as u64;
                for s in drained {
                    if let Some(w) = lane.agg.push(s) {
                        lane.windows.push(w);
                    }
                }
            }
        });
        let mut stats = PumpStats::default();
        for lane in &lanes {
            stats.drained += lane.drained;
            stats.windows += lane.windows.len() as u64;
            if !lane.windows.is_empty() {
                router.enqueue_windows(lane.tenant, &lane.windows);
            }
        }
        stats
    }

    /// One full pump: drain + batch + enqueue, then tick the router.
    pub fn pump(&mut self, router: &mut StreamRouter) -> PumpStats {
        let mut stats = self.drain_into(router);
        stats.observed = router.tick() as u64;
        stats
    }

    /// Event-driven pump: sleep until samples arrive (or `timeout`),
    /// then pump. `None` means the wait timed out with nothing queued.
    pub fn pump_when_ready(
        &mut self,
        router: &mut StreamRouter,
        timeout: Duration,
    ) -> Option<PumpStats> {
        if self.wait_for_samples(timeout) {
            Some(self.pump(router))
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::monitor::aggregate_samples;
    use crate::stream::router::RouterConfig;
    use crate::workloadgen::{tour_schedule, Generator};

    fn samples(seed: u64, classes: &[u32]) -> Vec<Sample> {
        let mut g = Generator::with_default_config(seed);
        g.generate(&tour_schedule(40, classes)).samples
    }

    fn front_end(cap: usize, policy: ShedPolicy) -> IngestFrontEnd {
        IngestFrontEnd::new(IngestConfig {
            queue_cap: cap,
            policy,
            monitor: MonitorConfig { window_size: 10 },
            ..Default::default()
        })
    }

    #[test]
    fn shed_oldest_keeps_newest_and_counts_evictions() {
        let fe = front_end(4, ShedPolicy::ShedOldest);
        let h = fe.handle();
        let t = TenantId(0);
        let ss = samples(1, &[0]);
        for (i, s) in ss.iter().take(10).enumerate() {
            let out = h.submit(t, s.clone());
            if i < 4 {
                assert_eq!(out, SubmitOutcome::Accepted);
            } else {
                assert_eq!(out, SubmitOutcome::ShedOldest);
            }
        }
        let st = h.tenant_stats(t).unwrap();
        assert_eq!(st.submitted, 10);
        assert_eq!(st.shed, 6);
        assert_eq!(st.resident, 4);
        assert_eq!(st.accepted, 0, "nothing drained yet");
        assert_eq!(st.accepted + st.shed + st.resident, st.submitted);
        assert_eq!(st.peak_resident, 4);
        assert_eq!(h.resident(), 4);
    }

    #[test]
    fn shed_newest_keeps_oldest_and_counts_rejections() {
        let fe = front_end(4, ShedPolicy::ShedNewest);
        let h = fe.handle();
        let t = TenantId(3);
        let ss = samples(2, &[1]);
        for (i, s) in ss.iter().take(10).enumerate() {
            let out = h.submit(t, s.clone());
            if i < 4 {
                assert_eq!(out, SubmitOutcome::Accepted);
            } else {
                assert_eq!(out, SubmitOutcome::ShedNewest);
            }
        }
        let st = h.tenant_stats(t).unwrap();
        assert_eq!(st.submitted, 10);
        assert_eq!(st.shed, 6);
        assert_eq!(st.resident, 4);
        assert_eq!(st.accepted + st.shed + st.resident, st.submitted);
    }

    #[test]
    fn pump_batches_windows_bit_identical_to_offline_aggregation() {
        let mcfg = MonitorConfig { window_size: 10 };
        let mut fe = front_end(1 << 16, ShedPolicy::Block);
        let h = fe.handle();
        let mut router = StreamRouter::new(RouterConfig {
            monitor: mcfg.clone(),
            ..Default::default()
        });
        let ss = samples(3, &[0, 2]);
        let t = TenantId(7);
        for s in &ss {
            assert_eq!(h.submit(t, s.clone()), SubmitOutcome::Accepted);
        }
        let st = fe.pump(&mut router);
        let expect = aggregate_samples(&ss, &mcfg);
        assert_eq!(st.drained, ss.len() as u64);
        assert_eq!(st.windows, expect.len() as u64);
        assert_eq!(st.observed, expect.len() as u64);
        assert_eq!(fe.open_samples(), ss.len() % 10);
        // the windows the router observed are bit-identical to offline
        // aggregation of the same sample stream
        let taken = router.take_observed();
        assert_eq!(taken.len(), 1);
        assert_eq!(taken[0].0, t);
        assert_eq!(taken[0].1, expect);
        // drained == accepted, conservation holds at quiesce
        let ts = h.tenant_stats(t).unwrap();
        assert_eq!(ts.accepted, ss.len() as u64);
        assert_eq!(ts.resident, 0);
        assert_eq!(ts.shed, 0);
    }

    #[test]
    fn wait_for_samples_times_out_empty_and_wakes_on_submit() {
        let fe = front_end(8, ShedPolicy::Block);
        assert!(!fe.wait_for_samples(Duration::from_millis(1)));
        let h = fe.handle();
        let s = samples(4, &[0])[0].clone();
        h.submit(TenantId(1), s);
        assert!(fe.wait_for_samples(Duration::from_millis(1)));
        // still true on a zero timeout once samples are resident
        assert!(fe.wait_for_samples(Duration::ZERO));
    }

    #[test]
    fn drain_max_smooths_a_burst_across_pumps() {
        let mcfg = MonitorConfig { window_size: 10 };
        let mut fe = IngestFrontEnd::new(IngestConfig {
            queue_cap: 1 << 16,
            policy: ShedPolicy::Block,
            monitor: mcfg.clone(),
            drain_max: 25,
            ..Default::default()
        });
        let h = fe.handle();
        let mut router = StreamRouter::new(RouterConfig {
            monitor: mcfg,
            ..Default::default()
        });
        let ss = samples(5, &[2]);
        assert!(ss.len() > 25);
        for s in &ss {
            h.submit(TenantId(0), s.clone());
        }
        let st1 = fe.pump(&mut router);
        assert_eq!(st1.drained, 25);
        let mut total = st1.drained;
        while fe.resident() > 0 {
            total += fe.pump(&mut router).drained;
        }
        assert_eq!(total, ss.len() as u64);
    }
}
