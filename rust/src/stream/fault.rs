//! Deterministic transport fault injection for the ingest path.
//!
//! `simcluster::fault` chaos-tests the *executor* side of the loop;
//! this module chaos-tests the *transport* between tenant producers and
//! the tuner's ingest front-end: samples can be dropped, delayed and
//! reordered, duplicated, or cut off entirely by a per-tenant partition
//! with a heal time — and the consumer itself can misbehave (a stalled
//! pump, a wedged lane worker). The chaos lab
//! (`crate::chaoslab::transport`) drives runs through a
//! [`TransportFaultPlan`]; the supervision layer in
//! `stream::ingest`/`stream::supervisor` is what has to absorb it.
//!
//! The contract mirrors [`crate::simcluster::fault::FaultLayer`]
//! exactly: an inert plan (the default) draws **zero** random numbers
//! and perturbs nothing, so fault-free runs through a
//! [`TransportLayer`] stay bit-identical to submitting straight into
//! the [`IngestHandle`] — pinned by `inert_layer_is_neutral_and_drawless`.

use super::ingest::{IngestHandle, SubmitOutcome};
use super::tenant::TenantId;
use crate::util::rng::Rng;
use crate::workloadgen::Sample;
use std::collections::BTreeMap;

/// Lossy link: each sample is independently dropped in transit with
/// probability `prob`. Dropped samples leave a sequence gap the
/// consumer-side reorder buffer must eventually write off.
#[derive(Debug, Clone, Copy)]
pub struct SampleLoss {
    pub prob: f64,
}

/// Laggy link: each sample is independently held back with probability
/// `prob` and released after between 1 and `max_hold` subsequent sends
/// of the same tenant — genuine reordering, not just latency.
#[derive(Debug, Clone, Copy)]
pub struct SampleDelay {
    pub prob: f64,
    /// Max sends of the same tenant a held sample can be overtaken by
    /// (clamped to ≥ 1).
    pub max_hold: usize,
}

/// Duplicating link: each sample is independently delivered twice (same
/// sequence number) with probability `prob` — at-least-once transport,
/// which the dedup buffer must collapse back to exactly-once windows.
#[derive(Debug, Clone, Copy)]
pub struct SampleDup {
    pub prob: f64,
}

/// Full partition: every sample of `tenant` with
/// `from <= time < until` is lost in transit. Heals by itself at
/// `until` — the supervision layer must notice the silence (degraded
/// mode) and re-arm when traffic returns.
#[derive(Debug, Clone, Copy)]
pub struct Partition {
    pub tenant: TenantId,
    pub from: f64,
    pub until: f64,
}

/// Consumer-side burst stall: the whole pump is down for
/// `from <= now < until` — no queue drains at all, so backpressure
/// (and the shed policy) is what protects the producers.
#[derive(Debug, Clone, Copy)]
pub struct PumpStall {
    pub from: f64,
    pub until: f64,
}

/// Consumer-side wedged lane worker: `tenant`'s lane does not drain
/// for `from <= now < until` while every other lane keeps flowing —
/// the per-tenant watchdog + retry/backoff case.
#[derive(Debug, Clone, Copy)]
pub struct WedgedLane {
    pub tenant: TenantId,
    pub from: f64,
    pub until: f64,
}

/// A scripted description of what goes wrong on the ingest transport.
/// `Default` is completely inert: no faults, no RNG draws, no behavior
/// change.
#[derive(Debug, Clone, Default)]
pub struct TransportFaultPlan {
    pub loss: Option<SampleLoss>,
    pub delay: Option<SampleDelay>,
    pub duplication: Option<SampleDup>,
    pub partitions: Vec<Partition>,
    pub stalls: Vec<PumpStall>,
    pub wedges: Vec<WedgedLane>,
}

impl TransportFaultPlan {
    pub fn is_inert(&self) -> bool {
        self.loss.is_none()
            && self.delay.is_none()
            && self.duplication.is_none()
            && self.partitions.is_empty()
            && self.stalls.is_empty()
            && self.wedges.is_empty()
    }
}

/// What the transport layer actually did — the ground truth the chaos
/// scoreboard reconciles against the consumer-side counters
/// (`TenantIngestStats::deduped`, `gaps_skipped`): injected ≥ observed,
/// always.
#[derive(Debug, Clone, Copy, Default)]
pub struct TransportFaultReport {
    /// Samples dropped by the lossy link.
    pub samples_dropped: usize,
    /// Samples swallowed by an active partition.
    pub samples_partitioned: usize,
    /// Samples held back (and later released) by the laggy link.
    pub samples_delayed: usize,
    /// Extra deliveries injected by the duplicating link.
    pub samples_duplicated: usize,
    /// Times the pump gate reported the consumer stalled.
    pub pump_stalls: usize,
    /// Times a lane gate reported a tenant's lane wedged.
    pub lane_wedges: usize,
    /// Partitions that swallowed at least one sample and then healed
    /// (traffic seen at/after `until`).
    pub partitions_healed: usize,
}

/// Runtime state of a [`TransportFaultPlan`] between producers and an
/// [`IngestHandle`]: the seeded fault RNG, per-tenant sequence
/// counters (assigned *before* the faults, so drops leave gaps,
/// duplicates repeat a number, and delays scramble the order — exactly
/// what the consumer-side supervision has to untangle), and the
/// held-back sample buffer.
#[derive(Debug, Clone)]
pub struct TransportLayer {
    plan: TransportFaultPlan,
    rng: Rng,
    /// Next sequence number per tenant (pre-fault).
    seqs: BTreeMap<TenantId, u64>,
    /// Sends processed per tenant (the delay-release clock).
    sends: BTreeMap<TenantId, u64>,
    /// Held-back samples: (release at send count, seq, sample), kept in
    /// release order per tenant.
    held: BTreeMap<TenantId, Vec<(u64, u64, Sample)>>,
    /// Which partitions swallowed ≥ 1 sample / already healed.
    partition_hit: Vec<bool>,
    partition_done: Vec<bool>,
    pub report: TransportFaultReport,
}

impl TransportLayer {
    /// An inert layer: injects nothing, draws nothing — submitting
    /// through it is bit-identical to submitting directly.
    pub fn inert() -> TransportLayer {
        TransportLayer::new(TransportFaultPlan::default(), 0)
    }

    pub fn new(plan: TransportFaultPlan, seed: u64) -> TransportLayer {
        let n = plan.partitions.len();
        TransportLayer {
            plan,
            rng: Rng::new(seed ^ 0xBAD1_114C_FA17_0001),
            seqs: BTreeMap::new(),
            sends: BTreeMap::new(),
            held: BTreeMap::new(),
            partition_hit: vec![false; n],
            partition_done: vec![false; n],
            report: TransportFaultReport::default(),
        }
    }

    pub fn is_inert(&self) -> bool {
        self.plan.is_inert()
    }

    /// Sequence numbers assigned to tenant `t` so far — the
    /// producer-side ground truth of how many samples were *sent*,
    /// whatever the faults did to them afterwards.
    pub fn sent(&self, t: TenantId) -> u64 {
        self.seqs.get(&t).copied().unwrap_or(0)
    }

    /// Total samples sent across all tenants.
    pub fn sent_total(&self) -> u64 {
        self.seqs.values().sum()
    }

    /// Send one sample for tenant `t` through the (possibly faulty)
    /// transport into `handle`. Sequence numbers are assigned here,
    /// before any fault fires, so whatever arrives carries the
    /// producer-side ordering truth the dedup/reorder buffer needs.
    pub fn send(
        &mut self,
        handle: &IngestHandle,
        t: TenantId,
        s: Sample,
    ) -> Option<SubmitOutcome> {
        let seq = {
            let c = self.seqs.entry(t).or_insert(0);
            let seq = *c;
            *c += 1;
            seq
        };
        let send_idx = {
            let c = self.sends.entry(t).or_insert(0);
            *c += 1;
            *c
        };
        let mut outcome = None;
        if let Some(p) = self.partition_index(t, s.time) {
            // lost in transit: the consumer sees only silence
            self.partition_hit[p] = true;
            self.report.samples_partitioned += 1;
        } else if self
            .plan
            .loss
            .is_some_and(|f| self.rng.chance(f.prob))
        {
            self.report.samples_dropped += 1;
        } else {
            let dup = self
                .plan
                .duplication
                .is_some_and(|f| self.rng.chance(f.prob));
            let delayed = match self.plan.delay {
                Some(f) if self.rng.chance(f.prob) => {
                    let hold =
                        1 + self.rng.below(f.max_hold.max(1) as u64);
                    self.held.entry(t).or_default().push((
                        send_idx + hold,
                        seq,
                        s.clone(),
                    ));
                    self.report.samples_delayed += 1;
                    true
                }
                _ => false,
            };
            if !delayed {
                outcome = Some(handle.submit_sequenced(t, seq, s.clone()));
            }
            if dup {
                // the duplicate travels the fast path even when the
                // original was held back — duplication + reorder at once
                self.report.samples_duplicated += 1;
                let o = handle.submit_sequenced(t, seq, s);
                if outcome.is_none() {
                    outcome = Some(o);
                }
            }
        }
        self.release_due(handle, t, send_idx);
        outcome
    }

    /// Deliver every still-held sample (end of run / link flush), in
    /// (tenant, seq) order.
    pub fn flush(&mut self, handle: &IngestHandle) {
        let held = std::mem::take(&mut self.held);
        for (t, mut v) in held {
            v.sort_by_key(|(_, seq, _)| *seq);
            for (_, seq, s) in v {
                handle.submit_sequenced(t, seq, s);
            }
        }
    }

    /// Is the consumer pump down at sim time `now`? (No RNG; counts
    /// the stall events it reports.)
    pub fn pump_stalled(&mut self, now: f64) -> bool {
        let stalled = self
            .plan
            .stalls
            .iter()
            .any(|w| now >= w.from && now < w.until);
        if stalled {
            self.report.pump_stalls += 1;
        }
        stalled
    }

    /// Tenants whose lane worker is wedged at sim time `now`.
    pub fn wedged_tenants(&mut self, now: f64) -> Vec<TenantId> {
        let mut out: Vec<TenantId> = self
            .plan
            .wedges
            .iter()
            .filter(|w| now >= w.from && now < w.until)
            .map(|w| w.tenant)
            .collect();
        out.sort_by_key(|t| t.0);
        out.dedup();
        self.report.lane_wedges += out.len();
        out
    }

    /// Index of the partition swallowing tenant `t`'s sample at `time`,
    /// if any. Also scores heals: a partition that swallowed traffic
    /// counts healed the first time the tenant sends at/after `until`.
    fn partition_index(&mut self, t: TenantId, time: f64) -> Option<usize> {
        let mut hit = None;
        for (i, p) in self.plan.partitions.iter().enumerate() {
            if p.tenant != t {
                continue;
            }
            if time >= p.from && time < p.until {
                hit = Some(i);
            } else if time >= p.until
                && self.partition_hit[i]
                && !self.partition_done[i]
            {
                self.partition_done[i] = true;
                self.report.partitions_healed += 1;
            }
        }
        hit
    }

    /// Deliver held samples whose release clock has come.
    fn release_due(
        &mut self,
        handle: &IngestHandle,
        t: TenantId,
        send_idx: u64,
    ) {
        let Some(v) = self.held.get_mut(&t) else { return };
        let mut due: Vec<(u64, Sample)> = Vec::new();
        v.retain(|(release, seq, s)| {
            if *release <= send_idx {
                due.push((*seq, s.clone()));
                false
            } else {
                true
            }
        });
        if v.is_empty() {
            self.held.remove(&t);
        }
        due.sort_by_key(|(seq, _)| *seq);
        for (seq, s) in due {
            handle.submit_sequenced(t, seq, s);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::monitor::MonitorConfig;
    use crate::stream::ingest::{IngestConfig, IngestFrontEnd, ShedPolicy};
    use crate::workloadgen::TruthTag;

    fn mk(t: f64) -> Sample {
        Sample {
            time: t,
            features: [1.0; crate::features::NUM_FEATURES],
            truth: TruthTag::Steady(0),
        }
    }

    fn front_end() -> IngestFrontEnd {
        IngestFrontEnd::new(IngestConfig {
            queue_cap: 1 << 14,
            policy: ShedPolicy::ShedOldest,
            monitor: MonitorConfig { window_size: 10 },
            ..Default::default()
        })
    }

    #[test]
    fn inert_layer_is_neutral_and_drawless() {
        let fe = front_end();
        let h = fe.handle();
        let mut layer = TransportLayer::inert();
        let before = layer.rng.clone();
        for i in 0..20 {
            let out = layer.send(&h, TenantId(0), mk(i as f64));
            assert_eq!(out, Some(SubmitOutcome::Accepted));
        }
        assert!(!layer.pump_stalled(5.0));
        assert!(layer.wedged_tenants(5.0).is_empty());
        layer.flush(&h);
        // no RNG state advanced: fault-free runs stay bit-identical
        let mut a = before;
        assert_eq!(a.next_u64(), layer.rng.clone().next_u64());
        // every sample arrived, in order, exactly once
        let st = h.tenant_stats(TenantId(0)).unwrap();
        assert_eq!(st.submitted, 20);
        assert_eq!(st.resident, 20);
        let r = layer.report;
        assert_eq!(r.samples_dropped + r.samples_duplicated, 0);
        assert_eq!(r.samples_delayed + r.samples_partitioned, 0);
    }

    #[test]
    fn fault_draws_are_seed_deterministic() {
        let plan = TransportFaultPlan {
            loss: Some(SampleLoss { prob: 0.3 }),
            delay: Some(SampleDelay { prob: 0.3, max_hold: 3 }),
            duplication: Some(SampleDup { prob: 0.3 }),
            ..Default::default()
        };
        let run = |seed: u64| {
            let fe = front_end();
            let h = fe.handle();
            let mut layer = TransportLayer::new(plan.clone(), seed);
            for i in 0..60 {
                layer.send(&h, TenantId(1), mk(i as f64));
            }
            layer.flush(&h);
            let st = h.tenant_stats(TenantId(1)).unwrap();
            (st.submitted, layer.report.samples_dropped)
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8), "different seeds gave identical faults");
    }

    #[test]
    fn partition_swallows_window_and_scores_heal() {
        let plan = TransportFaultPlan {
            partitions: vec![Partition {
                tenant: TenantId(0),
                from: 10.0,
                until: 20.0,
            }],
            ..Default::default()
        };
        let fe = front_end();
        let h = fe.handle();
        let mut layer = TransportLayer::new(plan, 1);
        let before = layer.rng.clone();
        for i in 0..30 {
            layer.send(&h, TenantId(0), mk(i as f64));
            layer.send(&h, TenantId(1), mk(i as f64));
        }
        // partitions are time-scripted: still zero RNG draws
        let mut a = before;
        assert_eq!(a.next_u64(), layer.rng.clone().next_u64());
        assert_eq!(layer.report.samples_partitioned, 10);
        assert_eq!(layer.report.partitions_healed, 1);
        let st0 = h.tenant_stats(TenantId(0)).unwrap();
        let st1 = h.tenant_stats(TenantId(1)).unwrap();
        assert_eq!(st0.submitted, 20, "10 swallowed in transit");
        assert_eq!(st1.submitted, 30, "other tenant untouched");
    }

    #[test]
    fn consumer_gates_follow_their_windows() {
        let plan = TransportFaultPlan {
            stalls: vec![PumpStall { from: 5.0, until: 10.0 }],
            wedges: vec![WedgedLane {
                tenant: TenantId(2),
                from: 8.0,
                until: 12.0,
            }],
            ..Default::default()
        };
        let mut layer = TransportLayer::new(plan, 1);
        assert!(!layer.pump_stalled(4.0));
        assert!(layer.pump_stalled(5.0));
        assert!(!layer.pump_stalled(10.0));
        assert!(layer.wedged_tenants(7.0).is_empty());
        assert_eq!(layer.wedged_tenants(9.0), vec![TenantId(2)]);
        assert!(layer.wedged_tenants(12.0).is_empty());
        assert_eq!(layer.report.pump_stalls, 1);
        assert_eq!(layer.report.lane_wedges, 1);
    }

    #[test]
    fn delayed_samples_arrive_reordered_then_flush_completes() {
        let plan = TransportFaultPlan {
            delay: Some(SampleDelay { prob: 0.5, max_hold: 4 }),
            ..Default::default()
        };
        let fe = front_end();
        let h = fe.handle();
        let mut layer = TransportLayer::new(plan, 3);
        for i in 0..40 {
            layer.send(&h, TenantId(0), mk(i as f64));
        }
        assert!(layer.report.samples_delayed > 0, "delay never fired");
        layer.flush(&h);
        let st = h.tenant_stats(TenantId(0)).unwrap();
        assert_eq!(st.submitted, 40, "flush delivered every held sample");
    }
}
