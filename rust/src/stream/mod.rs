//! The multi-tenant stream layer: turns the single-stream MAPE-K loop
//! into a sharded service (paper §1/§6: KERMIT identifies and optimises
//! *complex multi-user workloads*; this layer is where "multi-user"
//! becomes a first-class runtime concept rather than a trace property).
//!
//! Topology (see docs/ARCHITECTURE.md for the full diagram):
//!
//! ```text
//!   producers ──▶ ingest::IngestFrontEnd ─▶ bounded per-tenant queues
//!   (any thread,   (event-driven batcher:     + explicit ShedPolicy
//!    IngestHandle)  samples → windows off the caller's thread)
//!                        │ pump()
//!                        ▼
//!   tenant streams ──▶ StreamRouter ──▶ one TenantShard per tenant
//!                        │                ├─ monitor::WindowAggregator
//!                        │                ├─ online::OnlinePipeline
//!                        │                └─ per-tenant ContextStream
//!                        └─ tick(): drains every shard's closed windows
//!                           through `linalg::Engine` — busy shards fan
//!                           out over the persistent worker pool when
//!                           the `TickDispatch` policy allows, one shard
//!                           per worker at a time, so the observe path
//!                           scales with tenant count while each shard's
//!                           state stays single-writer.
//! ```
//!
//! Because every shard is touched by exactly one worker per tick and
//! shards share no mutable state (the knowledge plane is behind its own
//! lock, contexts are per-tenant), parallel-over-tenants is race-free by
//! construction and **bit-identical** to replaying each tenant's trace
//! alone through a sequential [`crate::online::OnlinePipeline`] — pinned
//! by `tests/stream_equivalence.rs`.

pub mod fault;
pub mod ingest;
pub mod router;
pub mod supervisor;
pub mod tenant;

pub use fault::{TransportFaultPlan, TransportFaultReport, TransportLayer};
pub use ingest::{
    IngestConfig, IngestFrontEnd, IngestHandle, LaneOutcome, PumpStats,
    ShedPolicy, SubmitOutcome, TenantIngestStats,
};
pub use supervisor::{IngestSupervisor, SupervisorConfig, TenantHealth};
pub use router::{RouterConfig, StreamRouter, TenantShard, TickDispatch};
pub use tenant::{interleave_round_robin, TenantId, TenantSample};
