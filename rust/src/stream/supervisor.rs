//! Per-tenant ingest watchdogs and the degraded-mode state machine.
//!
//! The transport between tenants and the tuner can drop, delay,
//! duplicate, or completely partition traffic (`stream::fault`), and
//! the consumer itself can wedge. The loop's autonomic claim says no
//! human intervenes — so *something* has to notice a lane that stopped
//! making progress, stop wasting probes on it, and re-arm when it
//! heals. That something is the [`IngestSupervisor`].
//!
//! It is deliberately dumb and deterministic: it looks only at the
//! [`LaneOutcome`]s each gated drain produces (samples drained,
//! samples delivered, samples still resident, delivery watermark) and
//! counts pumps — no wall clock, no RNG. Runs without faults score
//! every lane healthy on every pump and never mutate a decision, so
//! attaching a supervisor to a clean run is behaviour-neutral by
//! construction (pinned in `chaoslab::transport`).
//!
//! # State machine (per tenant)
//!
//! ```text
//!            no-progress deadline / retry budget exhausted
//!   Healthy ────────────────────────────────────────────► Degraded
//!      ▲                                                     │
//!      │ `heal_confirm` consecutive                          │ first
//!      │ healthy pumps                                       │ healthy pump
//!      │                                                     ▼
//!      └─────────────────────────────────────────────── Healing
//! ```
//!
//! While a tenant is **Degraded** or **Healing** ("impaired"), the
//! tuning plane serves its last-known label with the safe fallback
//! config and suspends probes (`TuningPlane::decide`); the state is
//! surfaced in `MultiTenantReport::tenant_health`.
//!
//! A lane that drains nothing while samples sit resident is *retried
//! with exponential backoff*: the supervisor asks the pump to skip the
//! lane for `backoff_base << (failures-1)` pumps (capped) before the
//! next attempt, so a wedged lane worker is not hammered every pump.
//! `max_retries` consecutive failures demote the tenant to Degraded
//! (the retries keep going — Degraded is a *decision* mode, not a
//! stop). A lane that is silent (nothing resident, nothing delivered)
//! only degrades once its delivery watermark lags the most advanced
//! tenant by more than `silence_after` sim-seconds — the partition
//! case, where the queue looks idle because nothing gets through.

use super::ingest::LaneOutcome;
use super::tenant::TenantId;
use std::collections::BTreeMap;

/// Per-tenant ingest-path health.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TenantHealth {
    /// Lane makes progress (or is idle and current).
    Healthy,
    /// No-progress deadline or retry budget blown: decisions fall back
    /// to last-known label + safe config, probes are suspended.
    Degraded,
    /// Progress again after Degraded; confirming before re-arming.
    Healing,
}

/// Watchdog thresholds. Pump-count and sim-time based — never wall
/// clock — so supervised runs stay deterministic.
#[derive(Debug, Clone, Copy)]
pub struct SupervisorConfig {
    /// Max sim-seconds a silent tenant's delivery watermark may lag the
    /// most advanced tenant before it is declared partitioned. Default
    /// `f64::INFINITY` — **off** — because silence alone cannot be told
    /// apart from a tenant that legitimately went quiet; deployments
    /// with a known traffic cadence (the chaos scenarios) opt in with a
    /// finite deadline.
    pub silence_after: f64,
    /// Consecutive no-progress drains (with samples resident) before a
    /// tenant is demoted to Degraded.
    pub max_retries: u32,
    /// Backoff after the n-th consecutive failure is
    /// `backoff_base << (n-1)` pumps, capped at `backoff_cap`.
    pub backoff_base: u32,
    pub backoff_cap: u32,
    /// Consecutive healthy pumps a Healing tenant needs to re-arm.
    pub heal_confirm: u32,
}

impl Default for SupervisorConfig {
    fn default() -> Self {
        SupervisorConfig {
            silence_after: f64::INFINITY,
            max_retries: 6,
            backoff_base: 1,
            backoff_cap: 8,
            heal_confirm: 2,
        }
    }
}

#[derive(Debug, Clone, Copy)]
struct TenantWatch {
    health: TenantHealth,
    /// Consecutive no-progress drains.
    failures: u32,
    /// Pump index before which the lane should not be retried.
    next_attempt: u64,
    /// Consecutive healthy pumps while Healing.
    confirm: u32,
    last_watermark: f64,
    /// Has this tenant ever delivered a sample?
    seen: bool,
}

impl TenantWatch {
    fn new() -> TenantWatch {
        TenantWatch {
            health: TenantHealth::Healthy,
            failures: 0,
            next_attempt: 0,
            confirm: 0,
            last_watermark: f64::NEG_INFINITY,
            seen: false,
        }
    }
}

/// Watches [`LaneOutcome`]s, tracks per-tenant health, and schedules
/// retry backoffs. Owned by the coordinator; fed by every supervised
/// pump.
#[derive(Debug, Clone)]
pub struct IngestSupervisor {
    pub config: SupervisorConfig,
    /// Pumps observed (the backoff clock).
    pump: u64,
    watches: BTreeMap<TenantId, TenantWatch>,
    /// No-progress drains that triggered a scheduled retry.
    pub delivery_retries: u64,
    /// Healthy→Degraded transitions.
    pub degraded_events: u64,
    /// Healing→Healthy transitions (full recoveries).
    pub healed: u64,
}

impl IngestSupervisor {
    pub fn new(config: SupervisorConfig) -> IngestSupervisor {
        IngestSupervisor {
            config,
            pump: 0,
            watches: BTreeMap::new(),
            delivery_retries: 0,
            degraded_events: 0,
            healed: 0,
        }
    }

    /// Tenants whose retry backoff says "skip this pump".
    pub fn backed_off(&self) -> Vec<TenantId> {
        self.watches
            .iter()
            .filter(|(_, w)| w.failures > 0 && self.pump < w.next_attempt)
            .map(|(t, _)| *t)
            .collect()
    }

    /// Score one supervised pump's lane outcomes.
    pub fn observe(&mut self, outcomes: &[LaneOutcome]) {
        self.pump += 1;
        // the progress frontier: how far the healthiest lane has gotten
        let frontier = outcomes
            .iter()
            .map(|o| o.watermark)
            .fold(f64::NEG_INFINITY, f64::max);
        for o in outcomes {
            let w = self.watches.entry(o.tenant).or_insert_with(TenantWatch::new);
            if o.delivered > 0 {
                w.seen = true;
                w.last_watermark = o.watermark;
            }
            let seen = w.seen;
            if w.failures > 0 && self.pump <= w.next_attempt && o.drained == 0
            {
                // skipped by our own backoff gate: not evidence either way
                continue;
            }
            let lag = if o.watermark == f64::NEG_INFINITY {
                f64::INFINITY
            } else {
                frontier - o.watermark
            };
            let progressed = o.delivered > 0;
            let idle_and_current =
                o.resident_after == 0 && lag <= self.config.silence_after;
            if progressed || (!seen && o.resident_after == 0) {
                // progress — or a tenant that never sent anything yet
                self.score_healthy(o.tenant);
            } else if o.resident_after > 0 {
                // samples waiting, none delivered: the lane is stuck
                self.score_failure(o.tenant);
            } else if idle_and_current {
                self.score_healthy(o.tenant);
            } else {
                // silent and far behind the frontier: partitioned
                self.demote(o.tenant);
            }
        }
    }

    fn score_healthy(&mut self, t: TenantId) {
        let c = self.config;
        let w = self.watches.entry(t).or_insert_with(TenantWatch::new);
        w.failures = 0;
        w.next_attempt = 0;
        match w.health {
            TenantHealth::Healthy => {}
            TenantHealth::Degraded => {
                w.health = TenantHealth::Healing;
                w.confirm = 1;
            }
            TenantHealth::Healing => {
                w.confirm += 1;
                if w.confirm >= c.heal_confirm {
                    w.health = TenantHealth::Healthy;
                    w.confirm = 0;
                    self.healed += 1;
                }
            }
        }
    }

    fn score_failure(&mut self, t: TenantId) {
        let c = self.config;
        let w = self.watches.entry(t).or_insert_with(TenantWatch::new);
        w.failures += 1;
        let exp = w.failures.saturating_sub(1).min(31);
        let delay = c
            .backoff_base
            .saturating_mul(1u32.checked_shl(exp).unwrap_or(u32::MAX))
            .min(c.backoff_cap)
            .max(1);
        w.next_attempt = self.pump + delay as u64;
        self.delivery_retries += 1;
        if w.failures > c.max_retries {
            self.demote(t);
        } else if w.health == TenantHealth::Healing {
            // relapse while confirming
            w.health = TenantHealth::Degraded;
            w.confirm = 0;
        }
    }

    fn demote(&mut self, t: TenantId) {
        let w = self.watches.entry(t).or_insert_with(TenantWatch::new);
        if w.health != TenantHealth::Degraded {
            if w.health == TenantHealth::Healthy {
                self.degraded_events += 1;
            }
            w.health = TenantHealth::Degraded;
            w.confirm = 0;
        }
    }

    /// Current health for one tenant (Healthy if never watched).
    pub fn health(&self, t: TenantId) -> TenantHealth {
        self.watches.get(&t).map(|w| w.health).unwrap_or(TenantHealth::Healthy)
    }

    /// Degraded or Healing: decisions should use the safe degraded
    /// path and probes stay suspended.
    pub fn is_impaired(&self, t: TenantId) -> bool {
        matches!(
            self.health(t),
            TenantHealth::Degraded | TenantHealth::Healing
        )
    }

    /// Bridge supervisor health into a telemetry registry:
    /// transition counters plus instantaneous Degraded / Healing
    /// tenant-count gauges (what the `tenant_degraded` alert watches).
    pub fn export_metrics(&self, reg: &crate::obs::Registry) {
        reg.counter(
            "kermit_stream_delivery_retries_total",
            "No-progress drains that triggered a scheduled retry.",
            &[],
        )
        .set_total(self.delivery_retries);
        reg.counter(
            "kermit_stream_degraded_events_total",
            "Healthy-to-Degraded tenant transitions.",
            &[],
        )
        .set_total(self.degraded_events);
        reg.counter(
            "kermit_stream_healed_total",
            "Healing-to-Healthy tenant transitions (full recoveries).",
            &[],
        )
        .set_total(self.healed);
        let mut degraded = 0u64;
        let mut healing = 0u64;
        for (_, h) in self.healths() {
            match h {
                TenantHealth::Degraded => degraded += 1,
                TenantHealth::Healing => healing += 1,
                TenantHealth::Healthy => {}
            }
        }
        reg.gauge(
            "kermit_stream_tenants_degraded",
            "Tenants currently held in the Degraded state.",
            &[],
        )
        .set(degraded as f64);
        reg.gauge(
            "kermit_stream_tenants_healing",
            "Tenants currently held in the Healing state.",
            &[],
        )
        .set(healing as f64);
    }

    /// Every tenant currently not Healthy, in id order.
    pub fn impaired(&self) -> Vec<(TenantId, TenantHealth)> {
        self.watches
            .iter()
            .filter(|(_, w)| w.health != TenantHealth::Healthy)
            .map(|(t, w)| (*t, w.health))
            .collect()
    }

    /// Health of every watched tenant, in id order.
    pub fn healths(&self) -> Vec<(TenantId, TenantHealth)> {
        self.watches.iter().map(|(t, w)| (*t, w.health)).collect()
    }

    /// Clear all retry backoffs (reconcile: give every lane one more
    /// immediate chance).
    pub fn reset_backoffs(&mut self) {
        for w in self.watches.values_mut() {
            w.failures = 0;
            w.next_attempt = 0;
        }
    }

    /// Final settlement after a reconcile drain: any tenant still
    /// marked impaired whose backlog was flushed is re-armed. Call
    /// *after* `flush_transport` + a tick has emptied the lanes — the
    /// chaos scenarios assert no tenant stays degraded past this.
    pub fn settle(&mut self) {
        for w in self.watches.values_mut() {
            if w.health != TenantHealth::Healthy {
                w.health = TenantHealth::Healthy;
                w.confirm = 0;
                self.healed += 1;
            }
            w.failures = 0;
            w.next_attempt = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(
        t: u32,
        drained: u64,
        delivered: u64,
        resident_after: u64,
        watermark: f64,
    ) -> LaneOutcome {
        LaneOutcome {
            tenant: TenantId(t),
            drained,
            delivered,
            resident_after,
            watermark,
        }
    }

    #[test]
    fn healthy_traffic_never_changes_state() {
        let mut sup = IngestSupervisor::new(SupervisorConfig::default());
        for i in 0..50 {
            let tm = i as f64 * 10.0;
            sup.observe(&[
                outcome(0, 5, 5, 0, tm),
                outcome(1, 3, 3, 0, tm),
            ]);
        }
        assert_eq!(sup.health(TenantId(0)), TenantHealth::Healthy);
        assert_eq!(sup.health(TenantId(1)), TenantHealth::Healthy);
        assert_eq!(sup.delivery_retries, 0);
        assert_eq!(sup.degraded_events, 0);
        assert!(sup.backed_off().is_empty());
    }

    #[test]
    fn stuck_lane_backs_off_exponentially_then_degrades() {
        let cfg = SupervisorConfig {
            max_retries: 3,
            backoff_base: 1,
            backoff_cap: 4,
            ..Default::default()
        };
        let mut sup = IngestSupervisor::new(cfg);
        let mut pumps_to_degrade = 0;
        while sup.health(TenantId(0)) != TenantHealth::Degraded {
            // tenant 1 keeps flowing; tenant 0 has resident samples but
            // its lane delivers nothing (wedged worker) — pumps the
            // backoff gate skips are scored as no evidence
            sup.observe(&[
                outcome(0, 0, 0, 8, f64::NEG_INFINITY),
                outcome(1, 2, 2, 0, pumps_to_degrade as f64),
            ]);
            pumps_to_degrade += 1;
            assert!(pumps_to_degrade < 100, "never degraded");
        }
        // backoff gaps mean strictly more pumps than failures
        assert!(pumps_to_degrade > 4, "no backoff between retries");
        assert!(sup.delivery_retries >= 4);
        assert_eq!(sup.degraded_events, 1);
        assert!(sup.is_impaired(TenantId(0)));
        assert!(!sup.is_impaired(TenantId(1)));
    }

    #[test]
    fn silent_partitioned_tenant_degrades_then_heals_on_traffic() {
        let cfg = SupervisorConfig {
            silence_after: 50.0,
            heal_confirm: 2,
            ..Default::default()
        };
        let mut sup = IngestSupervisor::new(cfg);
        // both healthy first
        sup.observe(&[outcome(0, 2, 2, 0, 10.0), outcome(1, 2, 2, 0, 10.0)]);
        // tenant 0 goes silent (partition swallows its samples) while
        // tenant 1 advances past the silence threshold
        let mut tm = 10.0;
        while sup.health(TenantId(0)) == TenantHealth::Healthy {
            tm += 20.0;
            sup.observe(&[outcome(0, 0, 0, 0, 10.0), outcome(1, 2, 2, 0, tm)]);
            assert!(tm < 1e4, "silent tenant never degraded");
        }
        assert_eq!(sup.health(TenantId(0)), TenantHealth::Degraded);
        // partition heals: traffic flows again → Healing → Healthy
        sup.observe(&[outcome(0, 4, 4, 0, tm), outcome(1, 2, 2, 0, tm)]);
        assert_eq!(sup.health(TenantId(0)), TenantHealth::Healing);
        assert!(sup.is_impaired(TenantId(0)), "healing still impaired");
        sup.observe(&[outcome(0, 4, 4, 0, tm), outcome(1, 2, 2, 0, tm)]);
        assert_eq!(sup.health(TenantId(0)), TenantHealth::Healthy);
        assert_eq!(sup.healed, 1);
    }

    #[test]
    fn settle_rearms_every_tenant() {
        let cfg = SupervisorConfig {
            silence_after: 1.0,
            ..Default::default()
        };
        let mut sup = IngestSupervisor::new(cfg);
        sup.observe(&[outcome(0, 1, 1, 0, 5.0), outcome(1, 1, 1, 0, 5.0)]);
        for _ in 0..20 {
            sup.observe(&[
                outcome(0, 0, 0, 0, 5.0),
                outcome(1, 2, 2, 0, 500.0),
            ]);
        }
        assert!(sup.is_impaired(TenantId(0)));
        sup.settle();
        assert!(sup.impaired().is_empty());
        assert!(sup.backed_off().is_empty());
    }
}
