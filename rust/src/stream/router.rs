//! The `StreamRouter`: one MAPE-K pipeline shard per tenant, with the
//! per-tick observe pass dispatched across shards on the
//! `linalg::Engine` worker pool.
//!
//! # Determinism
//!
//! A shard is the *only* writer of its own state (aggregator, change
//! detector, classifier scratch, label history, context ring). A tick
//! hands each shard to exactly one worker, and within a shard the
//! pending windows are observed in arrival order — so for any engine
//! (1 thread or 64) every tenant's context sequence is bit-identical to
//! replaying that tenant's samples alone through a sequential
//! [`OnlinePipeline`]. `tests/stream_equivalence.rs` pins this.
//!
//! # Engine threshold
//!
//! One work item here is a whole shard's pending batch (tens of windows,
//! each a detector + classifier + predictor pass), not a 32-wide row —
//! far above the engine's default per-row spawn-amortization threshold.
//! The router therefore lowers `min_items` to the tenant count so a
//! 4-tenant tick already fans out (see [`Engine::with_min_items`]).

use super::tenant::{TenantId, TenantSample};
use crate::features::ObservationWindow;
use crate::linalg::engine::Engine;
use crate::monitor::{MonitorConfig, WindowAggregator};
use crate::online::classifier::WindowClassifier;
use crate::online::context::{ContextBus, ContextStream, WorkloadContext};
use crate::online::OnlinePipeline;
use crate::workloadgen::Sample;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

/// Router configuration.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    pub monitor: MonitorConfig,
    /// Ring capacity of every per-tenant context stream.
    pub context_cap: usize,
    /// Worker pool the per-tick observe pass fans out on. Sequential by
    /// default: plain constructions add no threading.
    pub engine: Engine,
    /// Per-shard cap on the context log and the observed-window backlog
    /// (the memory bound for long-running deployments: on overflow the
    /// oldest half is dropped, like the pipeline's history cap).
    /// Off-line consumers drain `take_observed` every tick — far below
    /// this — so the cap only bites router-only users and runaway logs.
    pub shard_log_cap: usize,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            monitor: MonitorConfig::default(),
            context_cap: 64,
            engine: Engine::sequential(),
            shard_log_cap: 65_536,
        }
    }
}

/// One tenant's slice of the on-line sub-system: aggregation, pipeline,
/// context stream, and the window/context logs the off-line analyser
/// and the equivalence tests read.
pub struct TenantShard {
    pub tenant: TenantId,
    agg: WindowAggregator,
    pub pipeline: OnlinePipeline,
    /// This tenant's context ring (shared with its plug-in readers via
    /// the router's [`ContextBus`]).
    pub context: Arc<Mutex<ContextStream>>,
    /// Closed windows awaiting the next tick's observe pass.
    pending: Vec<ObservationWindow>,
    /// Observed windows awaiting off-line collection — the analyze
    /// backlog feed of [`StreamRouter::take_observed`].
    observed: Vec<ObservationWindow>,
    /// Per-tenant context log, in observe order (capped at the router's
    /// `shard_log_cap`; oldest half dropped on overflow).
    pub contexts: Vec<WorkloadContext>,
    log_cap: usize,
}

impl TenantShard {
    fn new(
        tenant: TenantId,
        config: &RouterConfig,
        context: Arc<Mutex<ContextStream>>,
    ) -> TenantShard {
        TenantShard {
            tenant,
            agg: WindowAggregator::new(config.monitor.clone(), 0),
            pipeline: OnlinePipeline::new(context.clone()),
            context,
            pending: Vec::new(),
            observed: Vec::new(),
            contexts: Vec::new(),
            log_cap: config.shard_log_cap.max(2),
        }
    }

    /// Observe every pending window in arrival order; returns the count.
    fn observe_pending(&mut self) -> usize {
        let pending = std::mem::take(&mut self.pending);
        let n = pending.len();
        for w in pending {
            let ctx = self.pipeline.observe(&w);
            self.contexts.push(ctx);
            self.observed.push(w);
        }
        // memory bound for long-running shards: both logs drop their
        // oldest half past the cap (take_observed normally drains
        // `observed` every tick, far below it)
        cap_log(&mut self.contexts, self.log_cap);
        cap_log(&mut self.observed, self.log_cap);
        n
    }

    /// Closed-but-unobserved window count.
    pub fn pending_windows(&self) -> usize {
        self.pending.len()
    }

    /// Label sequence this shard has published (UNKNOWN included), for
    /// scoring and equivalence checks.
    pub fn label_log(&self) -> Vec<u32> {
        self.contexts.iter().map(|c| c.current_label).collect()
    }
}

/// Drop the oldest half of `log` once it exceeds `cap`.
fn cap_log<T>(log: &mut Vec<T>, cap: usize) {
    if log.len() > cap {
        let cut = log.len() - cap / 2;
        log.drain(..cut);
    }
}

/// The sharded multi-tenant front end of the on-line sub-system.
pub struct StreamRouter {
    pub config: RouterConfig,
    shards: BTreeMap<TenantId, TenantShard>,
    bus: ContextBus,
}

impl StreamRouter {
    pub fn new(config: RouterConfig) -> StreamRouter {
        let bus = ContextBus::new(config.context_cap);
        StreamRouter { config, shards: BTreeMap::new(), bus }
    }

    /// Ensure tenant `t` has a shard (idempotent) and return it.
    pub fn add_tenant(&mut self, t: TenantId) -> &mut TenantShard {
        if !self.shards.contains_key(&t) {
            let ctx = self.bus.stream(t);
            self.shards.insert(t, TenantShard::new(t, &self.config, ctx));
        }
        self.shards.get_mut(&t).unwrap()
    }

    /// Ingest a burst of one tenant's samples: windows close into the
    /// shard's pending queue; nothing is observed until [`tick`].
    ///
    /// [`tick`]: StreamRouter::tick
    pub fn ingest(&mut self, t: TenantId, samples: &[Sample]) {
        let shard = self.add_tenant(t);
        for s in samples {
            if let Some(w) = shard.agg.push(s.clone()) {
                shard.pending.push(w);
            }
        }
    }

    /// Ingest one tenant-tagged sample from a multiplexed stream.
    pub fn ingest_tagged(&mut self, ts: &TenantSample) {
        let shard = self.add_tenant(ts.tenant);
        if let Some(w) = shard.agg.push(ts.sample.clone()) {
            shard.pending.push(w);
        }
    }

    /// Enqueue pre-aggregated windows directly (off-line replay and the
    /// hot-path benches, which time the observe dispatch in isolation).
    pub fn enqueue_windows(&mut self, t: TenantId, ws: &[ObservationWindow]) {
        let shard = self.add_tenant(t);
        shard.pending.extend(ws.iter().cloned());
    }

    /// One router tick: drain every shard's pending windows through its
    /// pipeline, shards dispatched across the engine's workers (see the
    /// module docs for why this is race-free and bit-identical to the
    /// sequential replay). Returns the number of windows observed.
    pub fn tick(&mut self) -> usize {
        let engine = self
            .config
            .engine
            .with_min_items(self.shards.len().max(1));
        let mut shards: Vec<&mut TenantShard> =
            self.shards.values_mut().collect();
        let counts = engine.for_rows_map(&mut shards, 1, |_, chunk| {
            let mut n = 0usize;
            for shard in chunk.iter_mut() {
                n += shard.observe_pending();
            }
            n
        });
        counts.into_iter().sum()
    }

    /// Take every shard's observed-window backlog (cleared on return):
    /// the union feed for one amortized off-line analyze/train cycle.
    pub fn take_observed(&mut self) -> Vec<(TenantId, Vec<ObservationWindow>)> {
        self.shards
            .values_mut()
            .filter(|s| !s.observed.is_empty())
            .map(|s| (s.tenant, std::mem::take(&mut s.observed)))
            .collect()
    }

    /// Install a classifier on every shard (the off-line trainer calls
    /// this after each retrain: one shared model, N shards).
    pub fn install_classifiers<F>(&mut self, mut make: F)
    where
        F: FnMut(TenantId) -> Box<dyn WindowClassifier + Send>,
    {
        for (t, shard) in self.shards.iter_mut() {
            shard.pipeline.set_classifier(make(*t));
        }
    }

    pub fn shard(&self, t: TenantId) -> Option<&TenantShard> {
        self.shards.get(&t)
    }

    pub fn shard_mut(&mut self, t: TenantId) -> Option<&mut TenantShard> {
        self.shards.get_mut(&t)
    }

    pub fn tenants(&self) -> Vec<TenantId> {
        self.shards.keys().copied().collect()
    }

    pub fn n_tenants(&self) -> usize {
        self.shards.len()
    }

    /// The per-tenant context bus (plug-in readers take handles here).
    pub fn bus(&self) -> &ContextBus {
        &self.bus
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::monitor::aggregate_samples;
    use crate::workloadgen::{tour_schedule, Generator};

    fn trace_for(seed: u64, classes: &[u32]) -> crate::workloadgen::Trace {
        let mut g = Generator::with_default_config(seed);
        g.generate(&tour_schedule(60, classes))
    }

    #[test]
    fn router_windows_match_batch_aggregation_per_tenant() {
        let cfg = RouterConfig {
            monitor: MonitorConfig { window_size: 15 },
            ..Default::default()
        };
        let mut router = StreamRouter::new(cfg.clone());
        let traces = [trace_for(1, &[0, 2]), trace_for(2, &[4])];
        // interleave bursts that straddle window boundaries
        let mixed = super::super::tenant::interleave_round_robin(&traces, 7);
        for ts in &mixed {
            router.ingest_tagged(ts);
        }
        let n = router.tick();
        let want_total: usize =
            traces.iter().map(|t| t.len() / 15).sum();
        assert_eq!(n, want_total);
        for (k, tr) in traces.iter().enumerate() {
            let t = TenantId(k as u32);
            let batch =
                aggregate_samples(&tr.samples, &cfg.monitor);
            let shard = router.shard(t).unwrap();
            assert_eq!(shard.contexts.len(), batch.len(), "tenant {k}");
            for (c, w) in shard.contexts.iter().zip(&batch) {
                assert_eq!(c.window_index, w.index);
                assert_eq!(c.time, w.time);
            }
            // context ring saw the same tail
            assert_eq!(
                router.bus().latest(t).unwrap().window_index,
                batch.last().unwrap().index
            );
        }
    }

    #[test]
    fn tick_is_incremental_and_observed_backlog_drains_once() {
        let mut router = StreamRouter::new(RouterConfig {
            monitor: MonitorConfig { window_size: 10 },
            ..Default::default()
        });
        let tr = trace_for(3, &[1]);
        let half = tr.len() / 2;
        router.ingest(TenantId(0), &tr.samples[..half]);
        let n1 = router.tick();
        assert!(n1 > 0);
        assert_eq!(router.tick(), 0, "second tick with no new samples");
        router.ingest(TenantId(0), &tr.samples[half..]);
        let n2 = router.tick();
        assert_eq!(n1 + n2, tr.len() / 10);
        let taken = router.take_observed();
        assert_eq!(taken.len(), 1);
        assert_eq!(taken[0].1.len(), n1 + n2);
        assert!(router.take_observed().is_empty(), "backlog re-served");
        // contexts log everything ever observed
        assert_eq!(
            router.shard(TenantId(0)).unwrap().contexts.len(),
            n1 + n2
        );
    }

    #[test]
    fn shard_logs_are_bounded_by_the_cap() {
        let mut router = StreamRouter::new(RouterConfig {
            monitor: MonitorConfig { window_size: 10 },
            shard_log_cap: 16,
            ..Default::default()
        });
        let tr = trace_for(7, &[2]);
        let ws = aggregate_samples(
            &tr.samples,
            &MonitorConfig { window_size: 10 },
        );
        // a router-only user that never drains take_observed: both the
        // context log and the observed backlog must stay bounded
        for _ in 0..20 {
            router.enqueue_windows(TenantId(0), &ws);
            router.tick();
        }
        let shard = router.shard(TenantId(0)).unwrap();
        assert!(
            shard.contexts.len() <= 16 && shard.contexts.len() >= 8,
            "context log {} outside [8, 16]",
            shard.contexts.len()
        );
        let taken = router.take_observed();
        assert!(taken[0].1.len() <= 16, "observed {}", taken[0].1.len());
    }

    #[test]
    fn parallel_tick_contexts_bit_identical_to_sequential_router() {
        let traces: Vec<_> = (0..5)
            .map(|k| trace_for(10 + k, &[k as u32, (k as u32 + 3) % 6]))
            .collect();
        let run = |engine: Engine| -> Vec<Vec<WorkloadContext>> {
            let mut router = StreamRouter::new(RouterConfig {
                monitor: MonitorConfig { window_size: 12 },
                context_cap: 32,
                engine,
                ..Default::default()
            });
            let mixed =
                super::super::tenant::interleave_round_robin(&traces, 9);
            for (i, ts) in mixed.iter().enumerate() {
                router.ingest_tagged(ts);
                if i % 40 == 0 {
                    router.tick();
                }
            }
            router.tick();
            (0..traces.len())
                .map(|k| {
                    router
                        .shard(TenantId(k as u32))
                        .unwrap()
                        .contexts
                        .clone()
                })
                .collect()
        };
        let seq = run(Engine::sequential());
        for threads in [2, 4, 8] {
            let par = run(Engine::with_threads(threads));
            assert_eq!(seq, par, "threads {threads}");
        }
    }
}
