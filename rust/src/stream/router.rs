//! The `StreamRouter`: one MAPE-K pipeline shard per tenant, with the
//! per-tick observe pass dispatched across shards on the
//! `linalg::Engine` worker pool.
//!
//! # Determinism
//!
//! A shard is the *only* writer of its own state (aggregator, change
//! detector, classifier scratch, label history, context ring). A tick
//! hands each shard to exactly one worker, and within a shard the
//! pending windows are observed in arrival order — so for any engine
//! (1 thread or 64) every tenant's context sequence is bit-identical to
//! replaying that tenant's samples alone through a sequential
//! [`OnlinePipeline`]. `tests/stream_equivalence.rs` pins this.
//!
//! # Dispatch policy
//!
//! One work item here is a whole shard's pending batch (tens of windows,
//! each a detector + classifier + predictor pass), not a 32-wide row —
//! far above the engine's default per-row threshold, so the engine's
//! generic `min_items` heuristic is the wrong knob. The router instead
//! carries an explicit per-tick policy ([`TickDispatch`]): fan out
//! across the persistent pool only when at least `min_tenants` shards
//! actually have pending windows (idle shards are skipped entirely).
//! A 1-tenant router therefore **never** fans out — there is nothing to
//! overlap with, and the pool wakeup would be pure overhead (pinned by
//! a test).

use super::tenant::{TenantId, TenantSample};
use crate::features::ObservationWindow;
use crate::linalg::engine::Engine;
use crate::monitor::{MonitorConfig, WindowAggregator};
use crate::online::classifier::WindowClassifier;
use crate::online::context::{ContextBus, ContextStream, WorkloadContext};
use crate::obs::{ObserveMetrics, Registry};
use crate::online::OnlinePipeline;
use crate::workloadgen::Sample;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

/// When does a router tick fan shards out across the engine pool
/// instead of draining them inline on the calling thread?
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TickDispatch {
    /// Always drain shards inline, whatever the engine says.
    Sequential,
    /// Fan out when the engine is multi-threaded and at least
    /// `min_tenants` shards have pending windows this tick. Clamped to
    /// ≥ 2: a single busy shard is one indivisible work item, so
    /// dispatching it to the pool buys nothing and costs a wakeup.
    Parallel { min_tenants: usize },
}

impl Default for TickDispatch {
    fn default() -> Self {
        TickDispatch::Parallel { min_tenants: 2 }
    }
}

/// Router configuration.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    pub monitor: MonitorConfig,
    /// Ring capacity of every per-tenant context stream.
    pub context_cap: usize,
    /// Worker pool the per-tick observe pass fans out on. Sequential by
    /// default: plain constructions add no threading.
    pub engine: Engine,
    /// Explicit per-tick fan-out policy (see [`TickDispatch`]).
    pub dispatch: TickDispatch,
    /// Per-shard cap on the context log and the observed-window backlog
    /// (the memory bound for long-running deployments: on overflow the
    /// oldest half is dropped, like the pipeline's history cap).
    /// Off-line consumers drain `take_observed` every tick — far below
    /// this — so the cap only bites router-only users and runaway logs.
    pub shard_log_cap: usize,
    /// Per-shard cap on closed-but-unobserved *pending* windows (the
    /// queue [`StreamRouter::tick`] drains). Without it a stalled tick
    /// — a consumer that ingests but never ticks — grows pending
    /// without bound, the one shard buffer `shard_log_cap` did not
    /// cover. Overflow drops the oldest half (same policy as the logs)
    /// and counts every dropped window in the shard's
    /// `pending_dropped`, never silently.
    pub pending_cap: usize,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            monitor: MonitorConfig::default(),
            context_cap: 64,
            engine: Engine::sequential(),
            dispatch: TickDispatch::default(),
            shard_log_cap: 65_536,
            pending_cap: 65_536,
        }
    }
}

/// One tenant's slice of the on-line sub-system: aggregation, pipeline,
/// context stream, and the window/context logs the off-line analyser
/// and the equivalence tests read.
pub struct TenantShard {
    pub tenant: TenantId,
    agg: WindowAggregator,
    pub pipeline: OnlinePipeline,
    /// This tenant's context ring (shared with its plug-in readers via
    /// the router's [`ContextBus`]).
    pub context: Arc<Mutex<ContextStream>>,
    /// Closed windows awaiting the next tick's observe pass.
    pending: Vec<ObservationWindow>,
    /// Observed windows awaiting off-line collection — the analyze
    /// backlog feed of [`StreamRouter::take_observed`].
    observed: Vec<ObservationWindow>,
    /// Per-tenant context log, in observe order (capped at the router's
    /// `shard_log_cap`; oldest half dropped on overflow).
    pub contexts: Vec<WorkloadContext>,
    /// Monotone count of contexts ever published by this shard —
    /// unlike `contexts.len()` it is immune to the cap's truncation,
    /// so cursor-based consumers (the adaptive-cadence counters) never
    /// silently skip or double-count entries.
    pub contexts_published: u64,
    /// Monotone count of log entries (contexts + observed windows) the
    /// shard-log cap has dropped — the back-pressure observable: a
    /// nonzero value means the off-line consumer fell behind and the
    /// bounded logs shed telemetry to protect memory.
    pub windows_dropped: u64,
    /// Monotone count of *pending* (closed-but-unobserved) windows the
    /// pending cap has dropped — nonzero means the tick loop stalled
    /// while ingest kept running, and the shard shed its oldest
    /// backlog to protect memory. Kept separate from `windows_dropped`
    /// (log overflow) so the two failure modes stay distinguishable.
    pub pending_dropped: u64,
    log_cap: usize,
    pending_cap: usize,
}

impl TenantShard {
    fn new(
        tenant: TenantId,
        config: &RouterConfig,
        context: Arc<Mutex<ContextStream>>,
    ) -> TenantShard {
        TenantShard {
            tenant,
            agg: WindowAggregator::new(config.monitor.clone(), 0),
            pipeline: OnlinePipeline::new(context.clone()),
            context,
            pending: Vec::new(),
            observed: Vec::new(),
            contexts: Vec::new(),
            contexts_published: 0,
            windows_dropped: 0,
            pending_dropped: 0,
            log_cap: config.shard_log_cap.max(2),
            pending_cap: config.pending_cap.max(2),
        }
    }

    /// Observe every pending window in arrival order; returns the count.
    fn observe_pending(&mut self) -> usize {
        let pending = std::mem::take(&mut self.pending);
        let n = pending.len();
        for w in pending {
            let ctx = self.pipeline.observe(&w);
            self.contexts.push(ctx);
            self.contexts_published += 1;
            self.observed.push(w);
        }
        // memory bound for long-running shards: both logs drop their
        // oldest half past the cap (take_observed normally drains
        // `observed` every tick, far below it)
        self.windows_dropped +=
            cap_log(&mut self.contexts, self.log_cap) as u64;
        self.windows_dropped +=
            cap_log(&mut self.observed, self.log_cap) as u64;
        n
    }

    /// Closed-but-unobserved window count.
    pub fn pending_windows(&self) -> usize {
        self.pending.len()
    }

    /// Label sequence this shard has published (UNKNOWN included), for
    /// scoring and equivalence checks.
    pub fn label_log(&self) -> Vec<u32> {
        self.contexts.iter().map(|c| c.current_label).collect()
    }

    /// Most recent *known* label this shard published, if any — what a
    /// degraded tenant keeps being served while its ingest path is
    /// partitioned (the supervisor's stale-but-safe fallback).
    pub fn last_known_label(&self) -> Option<u32> {
        self.contexts
            .iter()
            .rev()
            .find(|c| c.is_known())
            .map(|c| c.current_label)
    }
}

/// Drop the oldest half of `log` once it exceeds `cap`; returns how
/// many entries were dropped.
fn cap_log<T>(log: &mut Vec<T>, cap: usize) -> usize {
    if log.len() > cap {
        let cut = log.len() - cap / 2;
        log.drain(..cut);
        cut
    } else {
        0
    }
}

/// The sharded multi-tenant front end of the on-line sub-system.
pub struct StreamRouter {
    pub config: RouterConfig,
    shards: BTreeMap<TenantId, TenantShard>,
    bus: ContextBus,
    /// When set, every shard's pipeline carries per-tenant
    /// [`ObserveMetrics`] registered here (shards added later are
    /// instrumented on creation).
    telemetry: Option<Registry>,
}

impl StreamRouter {
    pub fn new(config: RouterConfig) -> StreamRouter {
        let bus = ContextBus::new(config.context_cap);
        StreamRouter {
            config,
            shards: BTreeMap::new(),
            bus,
            telemetry: None,
        }
    }

    /// Instrument every pipeline shard (current and future) with
    /// per-tenant observe counters in `reg`. The handles are plain
    /// atomics, safe to bump from pool workers during a fanned-out
    /// tick; observing never changes what shards publish.
    pub fn enable_telemetry(&mut self, reg: &Registry) {
        for (t, shard) in self.shards.iter_mut() {
            shard
                .pipeline
                .set_observe_metrics(ObserveMetrics::register(reg, &t.0.to_string()));
        }
        self.telemetry = Some(reg.clone());
    }

    /// Ensure tenant `t` has a shard (idempotent) and return it.
    pub fn add_tenant(&mut self, t: TenantId) -> &mut TenantShard {
        if !self.shards.contains_key(&t) {
            let ctx = self.bus.stream(t);
            let mut shard = TenantShard::new(t, &self.config, ctx);
            if let Some(reg) = &self.telemetry {
                shard.pipeline.set_observe_metrics(ObserveMetrics::register(
                    reg,
                    &t.0.to_string(),
                ));
            }
            self.shards.insert(t, shard);
        }
        self.shards.get_mut(&t).unwrap()
    }

    /// Ingest a burst of one tenant's samples: windows close into the
    /// shard's pending queue; nothing is observed until [`tick`].
    ///
    /// [`tick`]: StreamRouter::tick
    pub fn ingest(&mut self, t: TenantId, samples: &[Sample]) {
        let shard = self.add_tenant(t);
        for s in samples {
            if let Some(w) = shard.agg.push(s.clone()) {
                shard.pending.push(w);
            }
        }
        shard.pending_dropped +=
            cap_log(&mut shard.pending, shard.pending_cap) as u64;
    }

    /// Ingest one tenant-tagged sample from a multiplexed stream.
    pub fn ingest_tagged(&mut self, ts: &TenantSample) {
        let shard = self.add_tenant(ts.tenant);
        if let Some(w) = shard.agg.push(ts.sample.clone()) {
            shard.pending.push(w);
            shard.pending_dropped +=
                cap_log(&mut shard.pending, shard.pending_cap) as u64;
        }
    }

    /// Enqueue pre-aggregated windows directly (off-line replay and the
    /// hot-path benches, which time the observe dispatch in isolation).
    pub fn enqueue_windows(&mut self, t: TenantId, ws: &[ObservationWindow]) {
        let shard = self.add_tenant(t);
        shard.pending.extend(ws.iter().cloned());
        shard.pending_dropped +=
            cap_log(&mut shard.pending, shard.pending_cap) as u64;
    }

    /// One router tick: drain every shard's pending windows through its
    /// pipeline. Shards with pending work are dispatched across the
    /// persistent engine pool when the [`TickDispatch`] policy says so,
    /// and drained inline otherwise (see the module docs for why the
    /// parallel path is race-free and bit-identical to the sequential
    /// replay). Returns the number of windows observed.
    pub fn tick(&mut self) -> usize {
        let busy =
            self.shards.values().filter(|s| !s.pending.is_empty()).count();
        if !self.fan_out_for(busy) {
            return self.shards.values_mut().map(|s| s.observe_pending()).sum();
        }
        // one chunk item = one busy shard's whole pending batch: heavy,
        // pointer-sized items, so no min-items heuristic or cache
        // alignment — dispatch each busy shard as its own work item
        let engine = self.config.engine.with_min_items(1);
        let mut shards: Vec<&mut TenantShard> = self
            .shards
            .values_mut()
            .filter(|s| !s.pending.is_empty())
            .collect();
        let counts = engine.for_rows_map(&mut shards, 1, |_, chunk| {
            let mut n = 0usize;
            for shard in chunk.iter_mut() {
                n += shard.observe_pending();
            }
            n
        });
        counts.into_iter().sum()
    }

    /// Would a tick right now fan out across the pool? (The explicit
    /// dispatch policy made observable so tests can pin it.)
    pub fn would_fan_out(&self) -> bool {
        let busy =
            self.shards.values().filter(|s| !s.pending.is_empty()).count();
        self.fan_out_for(busy)
    }

    fn fan_out_for(&self, busy_shards: usize) -> bool {
        match self.config.dispatch {
            TickDispatch::Sequential => false,
            TickDispatch::Parallel { min_tenants } => {
                self.config.engine.threads() > 1
                    && busy_shards >= min_tenants.max(2)
            }
        }
    }

    /// Take every shard's observed-window backlog (cleared on return):
    /// the union feed for one amortized off-line analyze/train cycle.
    pub fn take_observed(&mut self) -> Vec<(TenantId, Vec<ObservationWindow>)> {
        self.shards
            .values_mut()
            .filter(|s| !s.observed.is_empty())
            .map(|s| (s.tenant, std::mem::take(&mut s.observed)))
            .collect()
    }

    /// Install a classifier on every shard (the off-line trainer calls
    /// this after each retrain: one shared model, N shards).
    pub fn install_classifiers<F>(&mut self, mut make: F)
    where
        F: FnMut(TenantId) -> Box<dyn WindowClassifier + Send>,
    {
        for (t, shard) in self.shards.iter_mut() {
            shard.pipeline.set_classifier(make(*t));
        }
    }

    /// Install a transition classifier on every shard (paired with
    /// [`StreamRouter::install_classifiers`] after each retrain, so the
    /// multi-tenant pipelines name transition types on-line exactly
    /// like the single-tenant pipeline).
    pub fn install_transition_classifiers<F>(&mut self, mut make: F)
    where
        F: FnMut(TenantId) -> Box<dyn WindowClassifier + Send>,
    {
        for (t, shard) in self.shards.iter_mut() {
            shard.pipeline.set_transition_classifier(make(*t));
        }
    }

    pub fn shard(&self, t: TenantId) -> Option<&TenantShard> {
        self.shards.get(&t)
    }

    pub fn shard_mut(&mut self, t: TenantId) -> Option<&mut TenantShard> {
        self.shards.get_mut(&t)
    }

    pub fn tenants(&self) -> Vec<TenantId> {
        self.shards.keys().copied().collect()
    }

    pub fn n_tenants(&self) -> usize {
        self.shards.len()
    }

    /// The per-tenant context bus (plug-in readers take handles here).
    pub fn bus(&self) -> &ContextBus {
        &self.bus
    }

    /// Total log entries dropped by shard-log overflow across every
    /// shard — surfaced in `MultiTenantReport::windows_dropped` so
    /// silent telemetry shedding is visible cluster-wide.
    pub fn windows_dropped(&self) -> u64 {
        self.shards.values().map(|s| s.windows_dropped).sum()
    }

    /// Total *pending* windows dropped by the per-shard pending cap
    /// across every shard (stalled-tick back-pressure; see
    /// [`RouterConfig::pending_cap`]).
    pub fn pending_dropped(&self) -> u64 {
        self.shards.values().map(|s| s.pending_dropped).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::monitor::aggregate_samples;
    use crate::workloadgen::{tour_schedule, Generator};

    fn trace_for(seed: u64, classes: &[u32]) -> crate::workloadgen::Trace {
        let mut g = Generator::with_default_config(seed);
        g.generate(&tour_schedule(60, classes))
    }

    #[test]
    fn router_windows_match_batch_aggregation_per_tenant() {
        let cfg = RouterConfig {
            monitor: MonitorConfig { window_size: 15 },
            ..Default::default()
        };
        let mut router = StreamRouter::new(cfg.clone());
        let traces = [trace_for(1, &[0, 2]), trace_for(2, &[4])];
        // interleave bursts that straddle window boundaries
        let mixed = super::super::tenant::interleave_round_robin(&traces, 7);
        for ts in &mixed {
            router.ingest_tagged(ts);
        }
        let n = router.tick();
        let want_total: usize =
            traces.iter().map(|t| t.len() / 15).sum();
        assert_eq!(n, want_total);
        for (k, tr) in traces.iter().enumerate() {
            let t = TenantId(k as u32);
            let batch =
                aggregate_samples(&tr.samples, &cfg.monitor);
            let shard = router.shard(t).unwrap();
            assert_eq!(shard.contexts.len(), batch.len(), "tenant {k}");
            for (c, w) in shard.contexts.iter().zip(&batch) {
                assert_eq!(c.window_index, w.index);
                assert_eq!(c.time, w.time);
            }
            // context ring saw the same tail
            assert_eq!(
                router.bus().latest(t).unwrap().window_index,
                batch.last().unwrap().index
            );
        }
    }

    #[test]
    fn tick_is_incremental_and_observed_backlog_drains_once() {
        let mut router = StreamRouter::new(RouterConfig {
            monitor: MonitorConfig { window_size: 10 },
            ..Default::default()
        });
        let tr = trace_for(3, &[1]);
        let half = tr.len() / 2;
        router.ingest(TenantId(0), &tr.samples[..half]);
        let n1 = router.tick();
        assert!(n1 > 0);
        assert_eq!(router.tick(), 0, "second tick with no new samples");
        router.ingest(TenantId(0), &tr.samples[half..]);
        let n2 = router.tick();
        assert_eq!(n1 + n2, tr.len() / 10);
        let taken = router.take_observed();
        assert_eq!(taken.len(), 1);
        assert_eq!(taken[0].1.len(), n1 + n2);
        assert!(router.take_observed().is_empty(), "backlog re-served");
        // contexts log everything ever observed
        assert_eq!(
            router.shard(TenantId(0)).unwrap().contexts.len(),
            n1 + n2
        );
    }

    #[test]
    fn shard_logs_are_bounded_by_the_cap() {
        let mut router = StreamRouter::new(RouterConfig {
            monitor: MonitorConfig { window_size: 10 },
            shard_log_cap: 16,
            ..Default::default()
        });
        let tr = trace_for(7, &[2]);
        let ws = aggregate_samples(
            &tr.samples,
            &MonitorConfig { window_size: 10 },
        );
        // a router-only user that never drains take_observed: both the
        // context log and the observed backlog must stay bounded
        for _ in 0..20 {
            router.enqueue_windows(TenantId(0), &ws);
            router.tick();
        }
        let shard = router.shard(TenantId(0)).unwrap();
        assert!(
            shard.contexts.len() <= 16 && shard.contexts.len() >= 8,
            "context log {} outside [8, 16]",
            shard.contexts.len()
        );
        // the shedding is counted, not silent: every entry the cap
        // dropped (from both logs, which grow in lockstep here) shows
        // up in windows_dropped, reconcilable against the monotone
        // published counter
        let ctx_drops =
            shard.contexts_published - shard.contexts.len() as u64;
        assert!(ctx_drops > 0, "cap never bit");
        assert_eq!(shard.windows_dropped, 2 * ctx_drops);
        assert_eq!(router.windows_dropped(), 2 * ctx_drops);
        let taken = router.take_observed();
        assert!(taken[0].1.len() <= 16, "observed {}", taken[0].1.len());
    }

    #[test]
    fn pending_window_cap_bounds_a_stalled_tick() {
        // a producer that ingests while the tick loop is stalled: the
        // pending queue must stay bounded, every dropped window must be
        // counted, and the eventual tick must observe exactly the
        // survivors (the cap sheds on enqueue, never inside tick)
        let mut router = StreamRouter::new(RouterConfig {
            monitor: MonitorConfig { window_size: 10 },
            pending_cap: 8,
            ..Default::default()
        });
        let tr = trace_for(11, &[1]);
        let ws = aggregate_samples(
            &tr.samples,
            &MonitorConfig { window_size: 10 },
        );
        let mut submitted = 0u64;
        for _ in 0..10 {
            router.enqueue_windows(TenantId(0), &ws);
            submitted += ws.len() as u64;
        }
        let shard = router.shard(TenantId(0)).unwrap();
        assert!(
            shard.pending_windows() <= 8,
            "pending {} above cap",
            shard.pending_windows()
        );
        let dropped = shard.pending_dropped;
        assert!(dropped > 0, "cap never bit");
        assert_eq!(router.pending_dropped(), dropped);
        // log-overflow accounting stays untouched by pending shedding
        assert_eq!(router.windows_dropped(), 0);
        let observed = router.tick() as u64;
        assert_eq!(observed + dropped, submitted, "a window went missing");
        let shard = router.shard(TenantId(0)).unwrap();
        assert_eq!(shard.pending_dropped, dropped, "tick itself shed");
    }

    #[test]
    fn single_tenant_router_never_fans_out() {
        // the explicit dispatch policy replaces the old "min_items
        // lowered to tenant count" hack: one busy shard is one
        // indivisible work item, so even an 8-thread engine must not
        // dispatch it to the pool
        let cfg = RouterConfig {
            monitor: MonitorConfig { window_size: 10 },
            engine: Engine::with_threads(8),
            ..Default::default()
        };
        let mut router = StreamRouter::new(cfg);
        let tr = trace_for(5, &[1]);
        router.ingest(TenantId(0), &tr.samples);
        assert!(!router.would_fan_out(), "1 busy tenant fanned out");
        let n = router.tick();
        assert_eq!(n, tr.len() / 10, "inline tick observed everything");

        // min_tenants = 1 is clamped to 2 for the same reason
        let mut clamped = StreamRouter::new(RouterConfig {
            monitor: MonitorConfig { window_size: 10 },
            engine: Engine::with_threads(8),
            dispatch: TickDispatch::Parallel { min_tenants: 1 },
            ..Default::default()
        });
        clamped.ingest(TenantId(0), &tr.samples);
        assert!(!clamped.would_fan_out(), "min_tenants=1 not clamped");
    }

    #[test]
    fn dispatch_policy_gates_fan_out() {
        let mk = |engine: Engine, dispatch: TickDispatch| {
            StreamRouter::new(RouterConfig {
                monitor: MonitorConfig { window_size: 10 },
                engine,
                dispatch,
                ..Default::default()
            })
        };
        let traces: Vec<_> = (0..3).map(|k| trace_for(20 + k, &[2])).collect();
        let fill = |router: &mut StreamRouter, n: usize| {
            for (k, tr) in traces.iter().take(n).enumerate() {
                router.ingest(TenantId(k as u32), &tr.samples);
            }
        };

        // Sequential policy: never, whatever the engine
        let mut r = mk(Engine::with_threads(8), TickDispatch::Sequential);
        fill(&mut r, 3);
        assert!(!r.would_fan_out());

        // Parallel policy counts only shards with pending windows
        let mut r = mk(
            Engine::with_threads(8),
            TickDispatch::Parallel { min_tenants: 3 },
        );
        fill(&mut r, 2);
        r.add_tenant(TenantId(9)); // idle shard must not count
        assert!(!r.would_fan_out(), "2 busy < min_tenants=3");
        fill(&mut r, 3);
        assert!(r.would_fan_out(), "3 busy >= min_tenants=3");

        // a sequential engine never fans out regardless of policy
        let mut r = mk(
            Engine::sequential(),
            TickDispatch::Parallel { min_tenants: 2 },
        );
        fill(&mut r, 3);
        assert!(!r.would_fan_out());

        // after a tick drains everything the router is idle again
        let mut r = mk(
            Engine::with_threads(4),
            TickDispatch::Parallel { min_tenants: 2 },
        );
        fill(&mut r, 3);
        assert!(r.would_fan_out());
        r.tick();
        assert!(!r.would_fan_out(), "no pending work left");
    }

    #[test]
    fn concurrent_routers_share_the_pool_and_stay_exact() {
        // two routers ticking simultaneously from two caller threads:
        // both dispatch jobs into the same persistent pool, and each
        // must still produce exactly the solo sequential result
        let traces: Vec<_> = (0..4)
            .map(|k| trace_for(40 + k, &[k as u32 % 6, (k as u32 + 2) % 6]))
            .collect();
        let run = |engine: Engine| -> Vec<Vec<WorkloadContext>> {
            let mut router = StreamRouter::new(RouterConfig {
                monitor: MonitorConfig { window_size: 12 },
                engine,
                ..Default::default()
            });
            for (k, tr) in traces.iter().enumerate() {
                router.ingest(TenantId(k as u32), &tr.samples);
            }
            router.tick();
            (0..traces.len())
                .map(|k| router.shard(TenantId(k as u32)).unwrap().contexts.clone())
                .collect()
        };
        let want = run(Engine::sequential());
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..2)
                .map(|_| s.spawn(|| run(Engine::with_threads(4))))
                .collect();
            for h in handles {
                assert_eq!(h.join().unwrap(), want, "concurrent router diverged");
            }
        });
    }

    #[test]
    fn parallel_tick_contexts_bit_identical_to_sequential_router() {
        let traces: Vec<_> = (0..5)
            .map(|k| trace_for(10 + k, &[k as u32, (k as u32 + 3) % 6]))
            .collect();
        let run = |engine: Engine| -> Vec<Vec<WorkloadContext>> {
            let mut router = StreamRouter::new(RouterConfig {
                monitor: MonitorConfig { window_size: 12 },
                context_cap: 32,
                engine,
                ..Default::default()
            });
            let mixed =
                super::super::tenant::interleave_round_robin(&traces, 9);
            for (i, ts) in mixed.iter().enumerate() {
                router.ingest_tagged(ts);
                if i % 40 == 0 {
                    router.tick();
                }
            }
            router.tick();
            (0..traces.len())
                .map(|k| {
                    router
                        .shard(TenantId(k as u32))
                        .unwrap()
                        .contexts
                        .clone()
                })
                .collect()
        };
        let seq = run(Engine::sequential());
        for threads in [2, 4, 8] {
            let par = run(Engine::with_threads(threads));
            assert_eq!(seq, par, "threads {threads}");
        }
    }
}
