//! Workload class library: parametric signatures for the big-data job
//! archetypes the paper's evaluation draws on (HiBench-style Spark/Hadoop
//! benchmarks). Each archetype phase is one steady-state *workload* in
//! the paper's sense (§6.1); a job is a sequence of phases connected by
//! abrupt transitions (Figure 2 — e.g. the map->reduce transition).
//!
//! Signatures are per-feature (mean, std) pairs over the 16 counters in
//! `features::FEATURE_NAMES`. Values are in normalized utilisation units
//! (0..100 for percentages, MB/s-scaled for throughput counters) — the
//! algorithms only care about the statistical structure, not the units.

use crate::features::{FeatureVec, NUM_FEATURES};

/// A steady-state workload class: what DBSCAN should discover as one
/// cluster and the WorkloadClassifier should learn as one label.
#[derive(Debug, Clone)]
pub struct WorkloadClass {
    /// Human name (for reports only; KERMIT's own labels are generated
    /// integers, per paper §7.1).
    pub name: &'static str,
    /// Per-feature mean level.
    pub base: FeatureVec,
    /// Per-feature sample noise (std).
    pub noise: FeatureVec,
}

/// Index into [`catalog()`].
pub type ClassId = u32;

macro_rules! sig {
    ($name:expr; $($mean:expr, $std:expr);* $(;)?) => {{
        let base = [$($mean as f64),*];
        let noise = [$($std as f64),*];
        WorkloadClass { name: $name, base, noise }
    }};
}

/// The 10 pure workload classes (8 job archetypes, two of which have a
/// distinct second phase — the paper's map/reduce-style split).
///
/// Feature order: cpu_user, cpu_sys, cpu_iowait, mem_used, mem_cache,
/// disk_read, disk_write, net_rx, net_tx, ctx_switches, page_faults,
/// gc_time, task_queue, shuffle_bytes, hdfs_read, hdfs_write.
pub fn catalog() -> Vec<WorkloadClass> {
    vec![
        // 0: WordCount-style map phase — CPU-bound scan over HDFS
        sig!("wordcount_map";
            78, 6;  8, 2;  4, 1.5;  45, 5;  20, 4;  35, 8;  5, 2;
            6, 2;   6, 2;  30, 6;   8, 3;   6, 2;   55, 8;  2, 1;
            70, 9;  3, 1),
        // 1: WordCount-style reduce phase — light CPU, HDFS write-out
        sig!("wordcount_reduce";
            30, 5;  10, 3;  12, 3;  38, 4;  22, 4;  6, 2;   45, 8;
            18, 4;  18, 4;  22, 5;  6, 2;   4, 1.5; 18, 5;  25, 6;
            8, 3;   55, 8),
        // 2: TeraSort shuffle — network+disk dominated, heavy spill
        sig!("terasort_shuffle";
            40, 7;  18, 4;  28, 6;  70, 6;  35, 5;  55, 9;  60, 10;
            65, 9;  65, 9;  55, 8;  25, 6;  18, 5;  70, 9;  85, 8;
            30, 6;  30, 6),
        // 3: K-means iteration — memory-resident iterative compute
        sig!("kmeans_iter";
            85, 5;  6, 2;   2, 1;   80, 5;  12, 3;  8, 3;   3, 1.5;
            25, 5;  25, 5;  40, 7;  12, 4;  22, 5;  45, 7;  12, 4;
            10, 3;  2, 1),
        // 4: SQL join (Hive/TPC-DS-ish) — mixed scan + broadcast
        sig!("sql_join";
            55, 7;  14, 3;  15, 4;  60, 6;  40, 6;  45, 8;  20, 5;
            35, 7;  35, 7;  38, 6;  15, 4;  12, 4;  50, 8;  45, 8;
            50, 8;  12, 4),
        // 5: Streaming ingest — network-in + sequential disk write
        sig!("stream_ingest";
            18, 4;  16, 4;  10, 3;  30, 4;  45, 6;  4, 2;   70, 9;
            80, 7;  12, 3;  60, 9;  6, 2;   3, 1;   25, 6;  4, 2;
            2, 1;   65, 8),
        // 6: PageRank superstep — graph traversal, pointer-chasing
        sig!("pagerank_step";
            65, 7;  12, 3;  8, 3;   75, 6;  15, 4;  15, 4;  8, 3;
            45, 8;  45, 8;  75, 9;  45, 8;  28, 6;  60, 8;  35, 7;
            15, 4;  5, 2),
        // 7: Bayes training — moderate CPU + model broadcast
        sig!("bayes_train";
            60, 6;  8, 2;   6, 2;   55, 5;  25, 4;  25, 6;  10, 3;
            30, 6;  15, 4;  35, 6;  10, 3;  15, 4;  40, 7;  18, 5;
            35, 7;  8, 3),
        // 8: ETL transform — balanced disk in/out, sys-CPU heavy
        sig!("etl_transform";
            35, 6;  30, 5;  20, 5;  42, 5;  35, 5;  55, 8;  55, 8;
            12, 3;  12, 3;  45, 7;  18, 5;  8, 3;   35, 6;  10, 3;
            55, 8;  50, 8),
        // 9: Interactive OLAP burst — short hot scans from cache
        sig!("olap_burst";
            50, 9;  10, 3;  3, 1.5; 35, 5;  70, 7;  10, 4;  2, 1;
            20, 5;  20, 5;  30, 6;  5, 2;   5, 2;   30, 8;  8, 3;
            20, 6;  1, 0.5),
    ]
}

pub fn num_pure_classes() -> usize {
    catalog().len()
}

/// A (possibly hybrid) workload mix: pure class, or a weighted blend of
/// two pure classes — the multi-user scenario the ZSL synthesizer (paper
/// §7.2 step 7) anticipates without ever observing.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Mix {
    Pure(ClassId),
    /// Hybrid of two pure classes with blend weight w for the first
    /// (resource signatures superpose when two tenants share a cluster).
    Hybrid(ClassId, ClassId, f64),
}

impl Mix {
    /// Expected feature mean of the mix.
    pub fn mean(&self, cat: &[WorkloadClass]) -> FeatureVec {
        match *self {
            Mix::Pure(a) => cat[a as usize].base,
            Mix::Hybrid(a, b, w) => {
                let (ca, cb) = (&cat[a as usize], &cat[b as usize]);
                let mut out = [0.0; NUM_FEATURES];
                for i in 0..NUM_FEATURES {
                    out[i] = w * ca.base[i] + (1.0 - w) * cb.base[i];
                }
                out
            }
        }
    }

    /// Sample noise std of the mix (variances superpose).
    pub fn noise(&self, cat: &[WorkloadClass]) -> FeatureVec {
        match *self {
            Mix::Pure(a) => cat[a as usize].noise,
            Mix::Hybrid(a, b, w) => {
                let (ca, cb) = (&cat[a as usize], &cat[b as usize]);
                let mut out = [0.0; NUM_FEATURES];
                for i in 0..NUM_FEATURES {
                    let va = ca.noise[i] * ca.noise[i];
                    let vb = cb.noise[i] * cb.noise[i];
                    out[i] = (w * w * va + (1.0 - w) * (1.0 - w) * vb
                        + 0.25 * (va + vb))
                        .sqrt(); // extra cross-tenant interference term
                }
                out
            }
        }
    }

    /// Canonical ground-truth id: pure ids are 0..N; hybrid (a,b) with
    /// a<b maps to N + pair_index (weight ignored — the paper's hybrid
    /// classes are identified by their constituents).
    pub fn truth_id(&self, num_pure: usize) -> u32 {
        match *self {
            Mix::Pure(a) => a,
            Mix::Hybrid(a, b, _) => {
                let (lo, hi) = if a < b { (a, b) } else { (b, a) };
                let (lo, hi) = (lo as usize, hi as usize);
                // index of pair (lo,hi) in lexicographic enumeration
                let before: usize =
                    (0..lo).map(|i| num_pure - i - 1).sum();
                (num_pure + before + (hi - lo - 1)) as u32
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_is_well_formed() {
        let cat = catalog();
        assert_eq!(cat.len(), 10);
        for c in &cat {
            for i in 0..NUM_FEATURES {
                assert!(c.base[i] >= 0.0, "{} base[{}]", c.name, i);
                assert!(c.noise[i] > 0.0, "{} noise[{}]", c.name, i);
            }
        }
    }

    #[test]
    fn classes_are_mutually_distinct() {
        // pairwise L2 distance between base vectors must be large relative
        // to noise, otherwise discovery can't work even in principle
        let cat = catalog();
        for i in 0..cat.len() {
            for j in (i + 1)..cat.len() {
                let d: f64 = (0..NUM_FEATURES)
                    .map(|k| (cat[i].base[k] - cat[j].base[k]).powi(2))
                    .sum::<f64>()
                    .sqrt();
                assert!(d > 30.0, "{} vs {} too close: {}", cat[i].name,
                    cat[j].name, d);
            }
        }
    }

    #[test]
    fn hybrid_mean_is_blend() {
        let cat = catalog();
        let m = Mix::Hybrid(0, 1, 0.5);
        let mean = m.mean(&cat);
        for i in 0..NUM_FEATURES {
            let want = 0.5 * (cat[0].base[i] + cat[1].base[i]);
            assert!((mean[i] - want).abs() < 1e-12);
        }
    }

    #[test]
    fn truth_ids_unique() {
        let n = num_pure_classes();
        let mut ids = std::collections::HashSet::new();
        for a in 0..n as u32 {
            assert!(ids.insert(Mix::Pure(a).truth_id(n)));
        }
        for a in 0..n as u32 {
            for b in (a + 1)..n as u32 {
                assert!(
                    ids.insert(Mix::Hybrid(a, b, 0.5).truth_id(n)),
                    "dup id for ({a},{b})"
                );
            }
        }
        // order/weight independence
        assert_eq!(
            Mix::Hybrid(2, 5, 0.3).truth_id(n),
            Mix::Hybrid(5, 2, 0.9).truth_id(n)
        );
    }
}
