//! Trace generator: turns a schedule of workload mixes into a raw metric
//! stream with ground truth. Reproduces the statistical structure the
//! paper's algorithms depend on (§6.1, Figure 2): steady-state plateaus
//! connected by *abrupt, non-linear* transition ramps, recurring workload
//! types, hybrid multi-user mixes, and workload drift.

use super::archetypes::{catalog, Mix, WorkloadClass};
use super::trace::{Sample, Segment, Trace, TruthTag};
use crate::features::{FeatureVec, TenantId, NUM_FEATURES};
use crate::util::rng::Rng;

/// One scheduled steady-state period.
#[derive(Debug, Clone)]
pub struct ScheduleEntry {
    pub mix: Mix,
    /// Steady-state duration in samples.
    pub duration: usize,
}

/// Generator configuration.
#[derive(Debug, Clone)]
pub struct GenConfig {
    /// Samples per second of simulated time (agent scrape rate).
    pub sample_hz: f64,
    /// Transition ramp length in samples between consecutive entries.
    pub transition_len: usize,
    /// Sigmoid steepness of the ramp (higher = more abrupt; the paper
    /// stresses big-data transitions are abrupt and non-linear).
    pub ramp_steepness: f64,
    /// Extra noise multiplier inside transitions (phase churn).
    pub transition_noise: f64,
    /// Additive per-sample systematic drift applied to class means,
    /// units/sample, per class id. Empty = no drift.
    pub drift_per_sample: Vec<(u32, FeatureVec)>,
    /// Clamp features at zero (utilisations can't go negative).
    pub clamp_zero: bool,
}

impl Default for GenConfig {
    fn default() -> Self {
        GenConfig {
            sample_hz: 1.0,
            transition_len: 12,
            ramp_steepness: 10.0,
            transition_noise: 1.8,
            drift_per_sample: Vec::new(),
            clamp_zero: true,
        }
    }
}

/// The generator. Owns the class catalog and an RNG stream.
pub struct Generator {
    pub catalog: Vec<WorkloadClass>,
    pub config: GenConfig,
    rng: Rng,
    /// Samples generated so far (drives drift).
    clock: usize,
}

impl Generator {
    pub fn new(seed: u64, config: GenConfig) -> Generator {
        Generator { catalog: catalog(), config, rng: Rng::new(seed), clock: 0 }
    }

    pub fn with_default_config(seed: u64) -> Generator {
        Generator::new(seed, GenConfig::default())
    }

    /// Effective mean of `mix` at the current clock (drift applied).
    fn mean_at(&self, mix: Mix, clock: usize) -> FeatureVec {
        let mut m = mix.mean(&self.catalog);
        for (cid, rate) in &self.config.drift_per_sample {
            let applies = match mix {
                Mix::Pure(a) => a == *cid,
                Mix::Hybrid(a, b, _) => a == *cid || b == *cid,
            };
            if applies {
                for i in 0..NUM_FEATURES {
                    m[i] += rate[i] * clock as f64;
                }
            }
        }
        m
    }

    fn emit(&mut self, mean: &FeatureVec, noise: &FeatureVec, mult: f64,
            tag: TruthTag, out: &mut Trace) {
        let mut f = [0.0; NUM_FEATURES];
        for i in 0..NUM_FEATURES {
            f[i] = self.rng.normal_ms(mean[i], noise[i] * mult);
            if self.config.clamp_zero && f[i] < 0.0 {
                f[i] = 0.0;
            }
        }
        let time = self.clock as f64 / self.config.sample_hz;
        out.samples.push(Sample { time, features: f, truth: tag });
        self.clock += 1;
    }

    /// Generate a trace for `schedule`, inserting a sigmoid transition
    /// ramp between consecutive entries.
    pub fn generate(&mut self, schedule: &[ScheduleEntry]) -> Trace {
        let mut trace = Trace::default();
        let num_pure = self.catalog.len();
        for (k, entry) in schedule.iter().enumerate() {
            // transition ramp from previous entry
            if k > 0 {
                let prev = &schedule[k - 1];
                let from_id = prev.mix.truth_id(num_pure);
                let to_id = entry.mix.truth_id(num_pure);
                let start = trace.samples.len();
                let n = self.config.transition_len;
                for j in 0..n {
                    // sigmoid blend: abrupt mid-ramp switch
                    let x = (j as f64 + 0.5) / n as f64;
                    let s = 1.0
                        / (1.0
                            + (-self.config.ramp_steepness * (x - 0.5))
                                .exp());
                    let ma = self.mean_at(prev.mix, self.clock);
                    let mb = self.mean_at(entry.mix, self.clock);
                    let na = prev.mix.noise(&self.catalog);
                    let nb = entry.mix.noise(&self.catalog);
                    let mut mean = [0.0; NUM_FEATURES];
                    let mut noise = [0.0; NUM_FEATURES];
                    for i in 0..NUM_FEATURES {
                        mean[i] = (1.0 - s) * ma[i] + s * mb[i];
                        noise[i] = ((1.0 - s) * na[i] * na[i]
                            + s * nb[i] * nb[i])
                            .sqrt();
                    }
                    self.emit(
                        &mean,
                        &noise,
                        self.config.transition_noise,
                        TruthTag::Transition { from: from_id, to: to_id },
                        &mut trace,
                    );
                }
                trace.segments.push(Segment {
                    start,
                    end: trace.samples.len(),
                    tag: TruthTag::Transition { from: from_id, to: to_id },
                });
            }
            // steady state
            let id = entry.mix.truth_id(num_pure);
            let start = trace.samples.len();
            let noise = entry.mix.noise(&self.catalog);
            for _ in 0..entry.duration {
                let mean = self.mean_at(entry.mix, self.clock);
                self.emit(&mean, &noise, 1.0, TruthTag::Steady(id), &mut trace);
            }
            trace.segments.push(Segment {
                start,
                end: trace.samples.len(),
                tag: TruthTag::Steady(id),
            });
        }
        trace.check_invariants();
        trace
    }

    /// RNG access for schedule builders sharing the generator's stream.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

// ---------------------------------------------------------------------------
// Scenario builders (the workloads the paper's evaluation motivates)
// ---------------------------------------------------------------------------

/// Simple tour: every pure class once, fixed duration. The Fig 9/10
/// detection & discovery workload.
pub fn tour_schedule(duration: usize, classes: &[u32]) -> Vec<ScheduleEntry> {
    classes
        .iter()
        .map(|&c| ScheduleEntry { mix: Mix::Pure(c), duration })
        .collect()
}

/// A recurring "business day": a fixed rotation of jobs repeated `cycles`
/// times with small duration jitter — the repetitive real-world pattern
/// §6.4 argues KERMIT exploits (same workload recurs many times per day).
pub fn daily_schedule(
    rng: &mut Rng,
    cycles: usize,
    base_duration: usize,
    classes: &[u32],
) -> Vec<ScheduleEntry> {
    let mut out = Vec::new();
    for _ in 0..cycles {
        for &c in classes {
            let jitter = rng.range_f64(0.8, 1.2);
            out.push(ScheduleEntry {
                mix: Mix::Pure(c),
                duration: ((base_duration as f64) * jitter) as usize,
            });
        }
    }
    out
}

/// Random job arrivals drawn from `classes` (geometric-ish durations),
/// modelling an uncoordinated multi-tenant queue.
pub fn random_schedule(
    rng: &mut Rng,
    entries: usize,
    mean_duration: usize,
    classes: &[u32],
) -> Vec<ScheduleEntry> {
    let mut out = Vec::new();
    let mut prev: Option<u32> = None;
    for _ in 0..entries {
        // avoid immediate self-transition (no-op transitions)
        let mut c = *rng.choice(classes);
        while Some(c) == prev && classes.len() > 1 {
            c = *rng.choice(classes);
        }
        prev = Some(c);
        let d = ((mean_duration as f64)
            * (-rng.f64().max(1e-9).ln()).max(0.25).min(3.0))
            as usize;
        out.push(ScheduleEntry { mix: Mix::Pure(c), duration: d.max(8) });
    }
    out
}

/// Multi-user phase: alternates pure jobs with hybrid (two-tenant) mixes
/// drawn from `classes` — the unseen-hybrid workloads of the ZSL study [9].
pub fn multi_user_schedule(
    rng: &mut Rng,
    entries: usize,
    duration: usize,
    classes: &[u32],
    hybrid_fraction: f64,
) -> Vec<ScheduleEntry> {
    let mut out = Vec::new();
    for _ in 0..entries {
        let mix = if rng.chance(hybrid_fraction) && classes.len() >= 2 {
            let a = *rng.choice(classes);
            let mut b = *rng.choice(classes);
            while b == a {
                b = *rng.choice(classes);
            }
            Mix::Hybrid(a, b, rng.range_f64(0.35, 0.65))
        } else {
            Mix::Pure(*rng.choice(classes))
        };
        out.push(ScheduleEntry { mix, duration });
    }
    out
}

/// Per-tenant schedules for an interleaved multi-tenant run: tenant `k`
/// rotates through `classes` starting at offset `k` (so concurrent
/// tenants run *different* archetypes at any instant — the paper's
/// complex multi-user condition), with duration jitter and an
/// occasional two-tenant hybrid mix thrown in.
pub fn tenant_schedules(
    rng: &mut Rng,
    tenants: usize,
    entries: usize,
    duration: usize,
    classes: &[u32],
) -> Vec<Vec<ScheduleEntry>> {
    assert!(!classes.is_empty());
    (0..tenants)
        .map(|k| {
            let mut out = Vec::with_capacity(entries);
            for e in 0..entries {
                let c = classes[(k + e) % classes.len()];
                // hybrids need a partner class distinct from `c` — a
                // list like [3, 3] has none, so the resample below must
                // be gated on distinct values, not on list length
                let has_partner = classes.iter().any(|&x| x != c);
                let mix = if has_partner && rng.chance(0.2) {
                    let mut other = *rng.choice(classes);
                    while other == c {
                        other = *rng.choice(classes);
                    }
                    Mix::Hybrid(c, other, rng.range_f64(0.35, 0.65))
                } else {
                    Mix::Pure(c)
                };
                let jitter = rng.range_f64(0.8, 1.2);
                out.push(ScheduleEntry {
                    mix,
                    duration: ((duration as f64) * jitter) as usize,
                });
            }
            out
        })
        .collect()
}

/// Generate one trace per tenant with **phase-shifted drift**: every
/// tenant's copy of `drift_class` drifts on the same features, but
/// tenant `k`'s per-sample rate is scaled by `1 - k/tenants`, so the
/// tenants cross the off-line analyser's drift threshold ε at staggered
/// times (tenant 0 first, the last tenant barely at all) — the
/// staggered-drift scenario a shared knowledge plane must absorb
/// without tenants corrupting each other's entries. `drift_rate` may be
/// zero for a drift-free mix.
pub fn tenant_traces(
    seed: u64,
    tenants: usize,
    entries: usize,
    duration: usize,
    classes: &[u32],
    drift_class: u32,
    drift_rate: f64,
) -> Vec<Trace> {
    let mut sched_rng = Rng::new(seed ^ 0x7E4A_17);
    let schedules =
        tenant_schedules(&mut sched_rng, tenants, entries, duration, classes);
    schedules
        .into_iter()
        .enumerate()
        .map(|(k, schedule)| {
            let mut cfg = GenConfig::default();
            if drift_rate != 0.0 {
                let phase = k as f64 / tenants.max(1) as f64;
                let mut rate = [0.0; NUM_FEATURES];
                rate[0] = drift_rate * (1.0 - phase);
                rate[3] = drift_rate * (1.0 - phase);
                cfg.drift_per_sample = vec![(drift_class, rate)];
            }
            let mut g = Generator::new(seed + k as u64, cfg);
            g.generate(&schedule)
        })
        .collect()
}

/// Seedable Zipf sampler over `0..n`: rank `k` is drawn with
/// probability proportional to `1/(k+1)^s`. Built once (O(n) CDF
/// precompute), sampled in O(log n) — cheap enough to drive a
/// 10k-tenant popularity distribution inside a bench's timed loop.
#[derive(Debug, Clone)]
pub struct ZipfSampler {
    /// Normalized cumulative distribution; `cdf[k]` = P(rank ≤ k).
    cdf: Vec<f64>,
}

impl ZipfSampler {
    /// `n` ranks with exponent `s` (s = 0 is uniform; s ≈ 1 is the
    /// classic web-traffic tail). Panics if `n == 0`.
    pub fn new(n: usize, s: f64) -> ZipfSampler {
        assert!(n > 0, "ZipfSampler over an empty domain");
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 0..n {
            acc += 1.0 / ((k + 1) as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for c in &mut cdf {
            *c /= total;
        }
        ZipfSampler { cdf }
    }

    /// Draw one rank in `0..n`.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        self.cdf
            .partition_point(|&c| c <= u)
            .min(self.cdf.len() - 1)
    }
}

/// A heavy-tailed multi-tenant sample stream for ingest stress: tenant
/// popularity is Zipf(`zipf_s`) over `tenants`, arrivals are bursty
/// (geometric run lengths with mean `mean_burst`, capped at 8× to keep
/// the tail bounded), and each tenant cycles its class's template trace
/// at its own cursor — so two tenants of one class emit the same
/// *marginal* signal but interleave differently, like real co-tenants.
/// Tenant `t` runs class `classes[t % classes.len()]`. Deterministic
/// per seed.
pub fn heavy_tailed_stream(
    seed: u64,
    tenants: usize,
    events: usize,
    zipf_s: f64,
    mean_burst: usize,
    classes: &[u32],
) -> Vec<(TenantId, Sample)> {
    assert!(tenants > 0 && !classes.is_empty());
    let mean_burst = mean_burst.max(1);
    // one template trace per class, long enough to cycle without
    // obvious periodicity at window granularity
    let templates: Vec<Trace> = classes
        .iter()
        .map(|&c| {
            let mut g = Generator::with_default_config(
                seed ^ (0xC1A5 + c as u64),
            );
            g.generate(&[ScheduleEntry { mix: Mix::Pure(c), duration: 512 }])
        })
        .collect();
    let zipf = ZipfSampler::new(tenants, zipf_s);
    let mut rng = Rng::new(seed ^ 0xB0257);
    let mut cursors = vec![0usize; tenants];
    let mut out = Vec::with_capacity(events);
    let continue_p = 1.0 - 1.0 / mean_burst as f64;
    while out.len() < events {
        let t = zipf.sample(&mut rng);
        let template = &templates[t % classes.len()];
        // geometric burst from one tenant (bursty arrival process)
        let mut burst = 1;
        while burst < mean_burst * 8 && rng.chance(continue_p) {
            burst += 1;
        }
        for _ in 0..burst {
            if out.len() >= events {
                break;
            }
            let s = template.samples[cursors[t] % template.len()].clone();
            cursors[t] += 1;
            out.push((TenantId(t as u32), s));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats;

    #[test]
    fn generates_expected_length_and_segments() {
        let mut g = Generator::with_default_config(1);
        let sched = tour_schedule(100, &[0, 1, 2]);
        let t = g.generate(&sched);
        // 3 steady + 2 transitions
        assert_eq!(t.segments.len(), 5);
        assert_eq!(t.len(), 300 + 2 * g.config.transition_len);
        assert_eq!(t.steady_classes(), vec![0, 1, 2]);
        assert_eq!(t.num_transitions(), 2);
    }

    #[test]
    fn steady_means_match_signature() {
        let mut g = Generator::with_default_config(2);
        let t = g.generate(&[ScheduleEntry { mix: Mix::Pure(3), duration: 2000 }]);
        let cat = catalog();
        for i in 0..NUM_FEATURES {
            let xs: Vec<f64> =
                t.samples.iter().map(|s| s.features[i]).collect();
            let m = stats::mean(&xs);
            // clamping at zero biases low-mean features slightly upward
            assert!(
                (m - cat[3].base[i]).abs() < cat[3].noise[i] * 0.5 + 0.5,
                "feature {i}: {m} vs {}",
                cat[3].base[i]
            );
        }
    }

    #[test]
    fn transitions_are_monotone_blends() {
        let mut cfg = GenConfig::default();
        cfg.transition_len = 50;
        let mut g = Generator::new(3, cfg);
        // classes 0 (cpu 78) -> 1 (cpu 30): cpu_user should fall
        let t = g.generate(&tour_schedule(50, &[0, 1]));
        let trans: Vec<&Sample> = t
            .samples
            .iter()
            .filter(|s| s.truth.is_transition())
            .collect();
        assert_eq!(trans.len(), 50);
        let first10: f64 =
            trans[..10].iter().map(|s| s.features[0]).sum::<f64>() / 10.0;
        let last10: f64 = trans[40..].iter().map(|s| s.features[0]).sum::<f64>()
            / 10.0;
        assert!(first10 > last10 + 20.0, "{first10} -> {last10}");
    }

    #[test]
    fn drift_moves_class_mean() {
        let mut cfg = GenConfig::default();
        let mut rate = [0.0; NUM_FEATURES];
        rate[0] = 0.01; // +0.01/sample on cpu_user for class 0
        cfg.drift_per_sample = vec![(0, rate)];
        let mut g = Generator::new(4, cfg);
        let t = g.generate(&[ScheduleEntry { mix: Mix::Pure(0), duration: 4000 }]);
        let early: f64 = t.samples[..500]
            .iter()
            .map(|s| s.features[0])
            .sum::<f64>()
            / 500.0;
        let late: f64 = t.samples[3500..]
            .iter()
            .map(|s| s.features[0])
            .sum::<f64>()
            / 500.0;
        assert!(late - early > 25.0, "{early} -> {late}");
    }

    #[test]
    fn deterministic_per_seed() {
        let mk = || {
            let mut g = Generator::with_default_config(7);
            g.generate(&tour_schedule(20, &[0, 5]))
        };
        let (a, b) = (mk(), mk());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.samples.iter().zip(&b.samples) {
            assert_eq!(x.features, y.features);
        }
    }

    #[test]
    fn hybrid_schedule_produces_hybrid_truth_ids() {
        let mut rng = Rng::new(9);
        let sched = multi_user_schedule(&mut rng, 40, 30, &[0, 1, 2, 3], 0.5);
        let n_hybrid = sched
            .iter()
            .filter(|e| matches!(e.mix, Mix::Hybrid(..)))
            .count();
        assert!(n_hybrid > 5 && n_hybrid < 35, "{n_hybrid}");
        let mut g = Generator::with_default_config(10);
        let t = g.generate(&sched);
        let max_pure = num_pure_as_u32();
        assert!(t.steady_classes().iter().any(|&c| c >= max_pure));
    }

    fn num_pure_as_u32() -> u32 {
        catalog().len() as u32
    }

    #[test]
    fn tenant_schedules_stagger_archetypes() {
        let mut rng = Rng::new(12);
        let scheds = tenant_schedules(&mut rng, 4, 6, 50, &[0, 1, 2]);
        assert_eq!(scheds.len(), 4);
        for s in &scheds {
            assert_eq!(s.len(), 6);
            for e in s {
                assert!(e.duration >= 40 && e.duration <= 60);
            }
        }
        // at entry 0 the tenants start on rotated classes: whatever the
        // pure entries are, they can't all share one class
        let firsts: Vec<Option<u32>> = scheds
            .iter()
            .map(|s| match s[0].mix {
                Mix::Pure(c) => Some(c),
                Mix::Hybrid(..) => None,
            })
            .collect();
        let pure: Vec<u32> = firsts.iter().flatten().copied().collect();
        if pure.len() >= 2 {
            assert!(
                pure.windows(2).any(|p| p[0] != p[1]),
                "all tenants opened on {pure:?}"
            );
        }
    }

    #[test]
    fn tenant_traces_drift_is_phase_shifted() {
        // one long class-0 plateau per tenant; tenant 0 drifts at full
        // rate, the last tenant at 1/4 rate
        let traces = tenant_traces(7, 4, 1, 4000, &[0], 0, 0.01);
        assert_eq!(traces.len(), 4);
        // durations are jittered per tenant, so slice fractionally
        let late_mean = |t: &Trace| -> f64 {
            let from = t.len() - t.len() / 8;
            t.samples[from..]
                .iter()
                .map(|s| s.features[0])
                .sum::<f64>()
                / (t.len() - from) as f64
        };
        let early_mean = |t: &Trace| -> f64 {
            let to = t.len() / 8;
            t.samples[..to]
                .iter()
                .map(|s| s.features[0])
                .sum::<f64>()
                / to as f64
        };
        let drift0 = late_mean(&traces[0]) - early_mean(&traces[0]);
        let drift3 = late_mean(&traces[3]) - early_mean(&traces[3]);
        assert!(drift0 > 20.0, "tenant 0 drifted only {drift0}");
        assert!(
            drift3 < drift0 * 0.5,
            "phase shift lost: {drift3} vs {drift0}"
        );
    }

    #[test]
    fn tenant_traces_deterministic_and_distinct_per_tenant() {
        let a = tenant_traces(3, 3, 4, 60, &[0, 2, 5], 0, 0.0);
        let b = tenant_traces(3, 3, 4, 60, &[0, 2, 5], 0, 0.0);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.len(), y.len());
            for (sx, sy) in x.samples.iter().zip(&y.samples) {
                assert_eq!(sx.features, sy.features);
            }
        }
        // different tenants get different sample streams
        assert!(a[0]
            .samples
            .iter()
            .zip(&a[1].samples)
            .any(|(s0, s1)| s0.features != s1.features));
    }

    #[test]
    fn random_schedule_no_self_transitions() {
        let mut rng = Rng::new(11);
        let sched = random_schedule(&mut rng, 100, 30, &[0, 1, 2]);
        for pair in sched.windows(2) {
            assert_ne!(
                pair[0].mix, pair[1].mix,
                "self-transition in schedule"
            );
        }
    }

    #[test]
    fn zipf_sampler_is_head_skewed_and_in_range() {
        let zipf = ZipfSampler::new(100, 1.1);
        let mut rng = Rng::new(21);
        let mut counts = vec![0usize; 100];
        for _ in 0..20_000 {
            let k = zipf.sample(&mut rng);
            assert!(k < 100);
            counts[k] += 1;
        }
        // rank 0 dominates any deep-tail rank by a wide margin, and the
        // top decile carries most of the mass — the heavy-tail shape
        // the ingest stress relies on
        assert!(counts[0] > counts[50] * 5, "{} vs {}", counts[0], counts[50]);
        let head: usize = counts[..10].iter().sum();
        assert!(head > 10_000, "head mass only {head}/20000");
        // uniform corner: s = 0 must not collapse onto one rank
        let flat = ZipfSampler::new(10, 0.0);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[flat.sample(&mut rng)] = true;
        }
        assert!(seen.iter().all(|&s| s), "s=0 missed a rank");
    }

    #[test]
    fn heavy_tailed_stream_is_deterministic_and_heavy_tailed() {
        let a = heavy_tailed_stream(5, 50, 3000, 1.1, 4, &[0, 2, 5]);
        let b = heavy_tailed_stream(5, 50, 3000, 1.1, 4, &[0, 2, 5]);
        assert_eq!(a.len(), 3000);
        assert_eq!(a.len(), b.len());
        for ((ta, sa), (tb, sb)) in a.iter().zip(&b) {
            assert_eq!(ta, tb);
            assert_eq!(sa.features, sb.features);
        }
        let mut per_tenant = vec![0usize; 50];
        for (t, _) in &a {
            per_tenant[t.0 as usize] += 1;
        }
        let max = *per_tenant.iter().max().unwrap();
        let median = {
            let mut c = per_tenant.clone();
            c.sort_unstable();
            c[25]
        };
        assert!(
            max > median.max(1) * 4,
            "no skew: max {max}, median {median}"
        );
    }
}
