//! Workload generator substrate: parametric big-data workload classes,
//! trace synthesis with ground truth, and scenario builders.
//!
//! Stands in for the paper's physical Spark/Hadoop cluster running
//! HiBench-style benchmarks (see DESIGN.md §2 for the substitution
//! argument): the KERMIT algorithms only ever observe per-window feature
//! vectors, and this module reproduces their statistical structure —
//! steady plateaus, abrupt transitions, recurrence, hybrid tenancy,
//! drift.

pub mod archetypes;
pub mod generator;
pub mod trace;

pub use archetypes::{catalog, num_pure_classes, ClassId, Mix, WorkloadClass};
pub use generator::{
    daily_schedule, heavy_tailed_stream, multi_user_schedule,
    random_schedule, tenant_schedules, tenant_traces, tour_schedule,
    GenConfig, Generator, ScheduleEntry, ZipfSampler,
};
pub use trace::{Sample, Segment, Trace, TruthTag};
