//! Trace model: the raw metric stream a simulated cluster emits, plus
//! generator-side ground truth (which windows belong to which workload,
//! where the transitions are). Ground truth plays the role of the paper's
//! "human specialist interpretation of Hadoop/Spark logs" when scoring
//! Awt/Purity/accuracy — it is never visible to the KERMIT algorithms.

use crate::features::FeatureVec;

/// One raw metrics sample (per agent scrape tick).
#[derive(Debug, Clone)]
pub struct Sample {
    /// Simulated time in seconds.
    pub time: f64,
    pub features: FeatureVec,
    /// Ground truth: the workload class generating this sample, or None
    /// during a transition ramp.
    pub truth: TruthTag,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TruthTag {
    /// Steady-state processing of workload class `id`.
    Steady(u32),
    /// Inside a transition ramp between `from` and `to`.
    Transition { from: u32, to: u32 },
    /// Cluster idle (background noise only).
    Idle,
}

impl TruthTag {
    pub fn steady_id(&self) -> Option<u32> {
        match self {
            TruthTag::Steady(id) => Some(*id),
            _ => None,
        }
    }

    pub fn is_transition(&self) -> bool {
        matches!(self, TruthTag::Transition { .. })
    }
}

/// A generated trace: samples plus segment-level ground truth.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub samples: Vec<Sample>,
    pub segments: Vec<Segment>,
}

/// Ground-truth segment: [start, end) sample range of one steady state or
/// transition.
#[derive(Debug, Clone)]
pub struct Segment {
    pub start: usize,
    pub end: usize,
    pub tag: TruthTag,
}

impl Trace {
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Distinct steady-state class ids present, sorted.
    pub fn steady_classes(&self) -> Vec<u32> {
        let mut ids: Vec<u32> = self
            .segments
            .iter()
            .filter_map(|s| s.tag.steady_id())
            .collect();
        ids.sort();
        ids.dedup();
        ids
    }

    /// Number of transition segments.
    pub fn num_transitions(&self) -> usize {
        self.segments.iter().filter(|s| s.tag.is_transition()).count()
    }

    /// Sanity: segments tile the sample range exactly.
    pub fn check_invariants(&self) {
        let mut pos = 0;
        for s in &self.segments {
            assert_eq!(s.start, pos, "segment gap at {pos}");
            assert!(s.end > s.start, "empty segment at {pos}");
            pos = s.end;
        }
        assert_eq!(pos, self.samples.len(), "segments don't cover trace");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::zero_features;

    fn sample(t: f64, tag: TruthTag) -> Sample {
        Sample { time: t, features: zero_features(), truth: tag }
    }

    #[test]
    fn invariants_hold_for_tiled_segments() {
        let tr = Trace {
            samples: (0..10)
                .map(|i| sample(i as f64, TruthTag::Steady(0)))
                .collect(),
            segments: vec![
                Segment { start: 0, end: 6, tag: TruthTag::Steady(0) },
                Segment {
                    start: 6,
                    end: 10,
                    tag: TruthTag::Transition { from: 0, to: 1 },
                },
            ],
        };
        tr.check_invariants();
        assert_eq!(tr.steady_classes(), vec![0]);
        assert_eq!(tr.num_transitions(), 1);
    }

    #[test]
    #[should_panic(expected = "segments don't cover")]
    fn invariants_catch_gap() {
        let tr = Trace {
            samples: (0..10)
                .map(|i| sample(i as f64, TruthTag::Idle))
                .collect(),
            segments: vec![Segment {
                start: 0,
                end: 5,
                tag: TruthTag::Idle,
            }],
        };
        tr.check_invariants();
    }
}
