//! KERMIT Workload Monitor (KWmon) — the streaming engine of the on-line
//! sub-system (§6.3/§6.4): ingests raw agent metric messages, aggregates
//! them into observation windows `O_t` with feature vectors `F_t`, and
//! feeds the transformation zone + the on-line classification pipeline.
//!
//! Two modes, same aggregation logic (the paper's batch ChangeDetector
//! "logic … is exactly the same as in the real-time use case"):
//! * [`aggregate_trace`] — batch aggregation of a recorded trace;
//! * [`Monitor`] — a streaming thread consuming an mpsc channel of agent
//!   samples and emitting windows as they close.

pub mod agents;

use crate::features::{FeatureVec, ObservationWindow};
use crate::workloadgen::{Sample, Trace, TruthTag};
use std::sync::mpsc::{Receiver, Sender};

/// Monitor configuration.
#[derive(Debug, Clone)]
pub struct MonitorConfig {
    /// Samples aggregated per observation window.
    pub window_size: usize,
}

impl Default for MonitorConfig {
    fn default() -> Self {
        MonitorConfig { window_size: 30 }
    }
}

/// Majority ground-truth tag for a window (None if mixed/transition) —
/// scoring aid only.
fn window_truth(tags: &[TruthTag]) -> Option<u32> {
    let mut counts = std::collections::BTreeMap::new();
    for t in tags {
        if let TruthTag::Steady(id) = t {
            *counts.entry(*id).or_insert(0usize) += 1;
        }
    }
    let (best, n) = counts.into_iter().max_by_key(|&(_, n)| n)?;
    // a window dominated (>50%) by one steady class is labelled with it
    if n * 2 > tags.len() {
        Some(best)
    } else {
        None
    }
}

/// Whether the window overlaps a ground-truth transition (for the Fig 9
/// detection experiment).
pub fn window_has_transition(tags: &[TruthTag]) -> bool {
    tags.iter().any(|t| t.is_transition())
}

/// Batch aggregation: slice the trace into consecutive windows of
/// `window_size` samples (the trailing partial window is dropped, as a
/// real streaming aggregator would leave it open).
pub fn aggregate_trace(
    trace: &Trace,
    config: &MonitorConfig,
) -> Vec<ObservationWindow> {
    aggregate_samples(&trace.samples, config)
}

pub fn aggregate_samples(
    samples: &[Sample],
    config: &MonitorConfig,
) -> Vec<ObservationWindow> {
    let w = config.window_size;
    assert!(w >= 2, "window_size must be >= 2 for variance");
    samples
        .chunks_exact(w)
        .enumerate()
        .map(|(i, chunk)| {
            let feats: Vec<FeatureVec> =
                chunk.iter().map(|s| s.features).collect();
            let tags: Vec<TruthTag> = chunk.iter().map(|s| s.truth).collect();
            let mut ow = ObservationWindow::aggregate(
                i as u64,
                chunk.last().unwrap().time,
                &feats,
                window_truth(&tags),
            );
            // windows overlapping a generator transition keep truth=None
            if window_has_transition(&tags) && window_truth(&tags).is_none() {
                ow.truth = None;
            }
            ow
        })
        .collect()
}

/// Per-window transition ground truth for detection scoring: true when
/// the window's samples include a transition tag.
pub fn transition_truth(trace: &Trace, config: &MonitorConfig) -> Vec<bool> {
    trace
        .samples
        .chunks_exact(config.window_size)
        .map(|chunk| {
            chunk.iter().any(|s| s.truth.is_transition())
        })
        .collect()
}

/// Streaming monitor: consumes agent samples from a channel, emits
/// closed windows on another. Runs until the input channel closes.
pub struct Monitor;

impl Monitor {
    /// Spawn the aggregation thread. Window indices are monotone from
    /// `start_index`.
    pub fn spawn(
        rx: Receiver<Sample>,
        tx: Sender<ObservationWindow>,
        config: MonitorConfig,
        start_index: u64,
    ) -> std::thread::JoinHandle<()> {
        std::thread::spawn(move || {
            let mut buf: Vec<Sample> = Vec::with_capacity(config.window_size);
            let mut index = start_index;
            while let Ok(s) = rx.recv() {
                buf.push(s);
                if buf.len() == config.window_size {
                    let feats: Vec<FeatureVec> =
                        buf.iter().map(|s| s.features).collect();
                    let tags: Vec<TruthTag> =
                        buf.iter().map(|s| s.truth).collect();
                    let ow = ObservationWindow::aggregate(
                        index,
                        buf.last().unwrap().time,
                        &feats,
                        window_truth(&tags),
                    );
                    index += 1;
                    buf.clear();
                    if tx.send(ow).is_err() {
                        return; // downstream hung up
                    }
                }
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloadgen::{tour_schedule, Generator};
    use std::sync::mpsc::channel;

    #[test]
    fn batch_aggregation_window_count_and_truth() {
        let mut g = Generator::with_default_config(0);
        let t = g.generate(&tour_schedule(90, &[0, 1]));
        let cfg = MonitorConfig { window_size: 30 };
        let ws = aggregate_trace(&t, &cfg);
        assert_eq!(ws.len(), t.len() / 30);
        // early windows are pure class 0, late ones pure class 1
        assert_eq!(ws.first().unwrap().truth, Some(0));
        assert_eq!(ws.last().unwrap().truth, Some(1));
        // indices are consecutive
        for (i, w) in ws.iter().enumerate() {
            assert_eq!(w.index, i as u64);
        }
    }

    #[test]
    fn transition_truth_flags_ramp_windows() {
        let mut g = Generator::with_default_config(1);
        let t = g.generate(&tour_schedule(60, &[0, 2]));
        let cfg = MonitorConfig { window_size: 12 };
        let tt = transition_truth(&t, &cfg);
        assert!(tt.iter().any(|&b| b), "no transition window found");
        assert!(!tt[0], "first window must be steady");
    }

    #[test]
    fn streaming_matches_batch() {
        let mut g = Generator::with_default_config(2);
        let t = g.generate(&tour_schedule(64, &[3]));
        let cfg = MonitorConfig { window_size: 16 };
        let batch = aggregate_trace(&t, &cfg);

        let (tx_s, rx_s) = channel();
        let (tx_w, rx_w) = channel();
        let h = Monitor::spawn(rx_s, tx_w, cfg.clone(), 0);
        for s in &t.samples {
            tx_s.send(s.clone()).unwrap();
        }
        drop(tx_s);
        h.join().unwrap();
        let streamed: Vec<_> = rx_w.into_iter().collect();
        assert_eq!(streamed.len(), batch.len());
        for (a, b) in streamed.iter().zip(&batch) {
            assert_eq!(a.index, b.index);
            assert_eq!(a.mean, b.mean);
            assert_eq!(a.var, b.var);
        }
    }

    #[test]
    #[should_panic(expected = "window_size")]
    fn window_size_one_rejected() {
        let mut g = Generator::with_default_config(3);
        let t = g.generate(&tour_schedule(10, &[0]));
        aggregate_trace(&t, &MonitorConfig { window_size: 1 });
    }
}
