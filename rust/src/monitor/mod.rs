//! KERMIT Workload Monitor (KWmon) — the streaming engine of the on-line
//! sub-system (§6.3/§6.4): ingests raw agent metric messages, aggregates
//! them into observation windows `O_t` with feature vectors `F_t`, and
//! feeds the transformation zone + the on-line classification pipeline.
//!
//! Two modes, same aggregation logic (the paper's batch ChangeDetector
//! "logic … is exactly the same as in the real-time use case"):
//! * [`aggregate_trace`] — batch aggregation of a recorded trace;
//! * [`Monitor`] — a streaming thread consuming an mpsc channel of agent
//!   samples and emitting windows as they close.

pub mod agents;

use crate::features::{FeatureVec, ObservationWindow, TenantId};
use crate::workloadgen::{Sample, Trace, TruthTag};
use std::collections::BTreeMap;
use std::sync::mpsc::{Receiver, Sender};

/// Monitor configuration.
#[derive(Debug, Clone)]
pub struct MonitorConfig {
    /// Samples aggregated per observation window.
    pub window_size: usize,
}

impl Default for MonitorConfig {
    fn default() -> Self {
        MonitorConfig { window_size: 30 }
    }
}

/// Majority ground-truth tag for a window (None if mixed/transition) —
/// scoring aid only.
fn window_truth(tags: &[TruthTag]) -> Option<u32> {
    let mut counts = std::collections::BTreeMap::new();
    for t in tags {
        if let TruthTag::Steady(id) = t {
            *counts.entry(*id).or_insert(0usize) += 1;
        }
    }
    let (best, n) = counts.into_iter().max_by_key(|&(_, n)| n)?;
    // a window dominated (>50%) by one steady class is labelled with it
    if n * 2 > tags.len() {
        Some(best)
    } else {
        None
    }
}

/// Whether the window overlaps a ground-truth transition (for the Fig 9
/// detection experiment).
pub fn window_has_transition(tags: &[TruthTag]) -> bool {
    tags.iter().any(|t| t.is_transition())
}

/// Batch aggregation: slice the trace into consecutive windows of
/// `window_size` samples (the trailing partial window is dropped, as a
/// real streaming aggregator would leave it open).
pub fn aggregate_trace(
    trace: &Trace,
    config: &MonitorConfig,
) -> Vec<ObservationWindow> {
    aggregate_samples(&trace.samples, config)
}

pub fn aggregate_samples(
    samples: &[Sample],
    config: &MonitorConfig,
) -> Vec<ObservationWindow> {
    let w = config.window_size;
    assert!(w >= 2, "window_size must be >= 2 for variance");
    samples
        .chunks_exact(w)
        .enumerate()
        .map(|(i, chunk)| {
            let feats: Vec<FeatureVec> =
                chunk.iter().map(|s| s.features).collect();
            let tags: Vec<TruthTag> = chunk.iter().map(|s| s.truth).collect();
            let mut ow = ObservationWindow::aggregate(
                i as u64,
                chunk.last().unwrap().time,
                &feats,
                window_truth(&tags),
            );
            // windows overlapping a generator transition keep truth=None
            if window_has_transition(&tags) && window_truth(&tags).is_none() {
                ow.truth = None;
            }
            ow
        })
        .collect()
}

/// Per-window transition ground truth for detection scoring: true when
/// the window's samples include a transition tag.
pub fn transition_truth(trace: &Trace, config: &MonitorConfig) -> Vec<bool> {
    trace
        .samples
        .chunks_exact(config.window_size)
        .map(|chunk| {
            chunk.iter().any(|s| s.truth.is_transition())
        })
        .collect()
}

/// Incremental single-stream aggregator: push samples one at a time,
/// get the closed window back the moment the `window_size`-th sample
/// lands. Windows are **bit-identical** to [`aggregate_samples`] over
/// the same sample sequence (same mean/var arithmetic, same truth rule,
/// same trailing-partial-window-stays-open semantics) — this is the
/// synchronous core both the streaming [`Monitor`] thread and the
/// per-tenant stream shards are built on.
#[derive(Debug)]
pub struct WindowAggregator {
    config: MonitorConfig,
    buf: Vec<Sample>,
    index: u64,
}

impl WindowAggregator {
    pub fn new(config: MonitorConfig, start_index: u64) -> WindowAggregator {
        assert!(
            config.window_size >= 2,
            "window_size must be >= 2 for variance"
        );
        let cap = config.window_size;
        WindowAggregator { config, buf: Vec::with_capacity(cap), index: start_index }
    }

    /// Feed one sample; returns the closed window when this sample
    /// completes one.
    pub fn push(&mut self, s: Sample) -> Option<ObservationWindow> {
        self.buf.push(s);
        if self.buf.len() < self.config.window_size {
            return None;
        }
        let feats: Vec<FeatureVec> =
            self.buf.iter().map(|s| s.features).collect();
        let tags: Vec<TruthTag> = self.buf.iter().map(|s| s.truth).collect();
        let ow = ObservationWindow::aggregate(
            self.index,
            self.buf.last().unwrap().time,
            &feats,
            window_truth(&tags),
        );
        self.index += 1;
        self.buf.clear();
        Some(ow)
    }

    /// Samples buffered in the currently open window.
    pub fn pending_samples(&self) -> usize {
        self.buf.len()
    }

    /// Index the next closed window will carry.
    pub fn next_index(&self) -> u64 {
        self.index
    }
}

/// Per-tenant window aggregation: one [`WindowAggregator`] per tenant,
/// demultiplexing a tagged sample stream. Each tenant gets its own
/// monotone window index space starting at 0 — exactly what that
/// tenant's stream alone would have produced.
///
/// This is the standalone demux primitive (replay tooling, tests,
/// window-only consumers). The `stream::StreamRouter` intentionally
/// does **not** sit on top of it: each router shard embeds its own
/// [`WindowAggregator`] so aggregation state lives inside the shard
/// that the engine hands to a single worker per tick.
#[derive(Debug)]
pub struct TenantAggregator {
    config: MonitorConfig,
    shards: BTreeMap<TenantId, WindowAggregator>,
}

impl TenantAggregator {
    pub fn new(config: MonitorConfig) -> TenantAggregator {
        TenantAggregator { config, shards: BTreeMap::new() }
    }

    /// Route one tenant-tagged sample; returns the tenant's closed
    /// window when this sample completes one.
    pub fn push(
        &mut self,
        tenant: TenantId,
        s: Sample,
    ) -> Option<(TenantId, ObservationWindow)> {
        let agg = self
            .shards
            .entry(tenant)
            .or_insert_with(|| WindowAggregator::new(self.config.clone(), 0));
        agg.push(s).map(|w| (tenant, w))
    }

    /// Tenants seen so far, in id order.
    pub fn tenants(&self) -> Vec<TenantId> {
        self.shards.keys().copied().collect()
    }
}

/// Streaming monitor: consumes agent samples from a channel, emits
/// closed windows on another. Runs until the input channel closes.
pub struct Monitor;

impl Monitor {
    /// Spawn the aggregation thread. Window indices are monotone from
    /// `start_index`.
    pub fn spawn(
        rx: Receiver<Sample>,
        tx: Sender<ObservationWindow>,
        config: MonitorConfig,
        start_index: u64,
    ) -> std::thread::JoinHandle<()> {
        std::thread::spawn(move || {
            let mut agg = WindowAggregator::new(config, start_index);
            while let Ok(s) = rx.recv() {
                if let Some(ow) = agg.push(s) {
                    if tx.send(ow).is_err() {
                        return; // downstream hung up
                    }
                }
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloadgen::{tour_schedule, Generator};
    use std::sync::mpsc::channel;

    #[test]
    fn batch_aggregation_window_count_and_truth() {
        let mut g = Generator::with_default_config(0);
        let t = g.generate(&tour_schedule(90, &[0, 1]));
        let cfg = MonitorConfig { window_size: 30 };
        let ws = aggregate_trace(&t, &cfg);
        assert_eq!(ws.len(), t.len() / 30);
        // early windows are pure class 0, late ones pure class 1
        assert_eq!(ws.first().unwrap().truth, Some(0));
        assert_eq!(ws.last().unwrap().truth, Some(1));
        // indices are consecutive
        for (i, w) in ws.iter().enumerate() {
            assert_eq!(w.index, i as u64);
        }
    }

    #[test]
    fn transition_truth_flags_ramp_windows() {
        let mut g = Generator::with_default_config(1);
        let t = g.generate(&tour_schedule(60, &[0, 2]));
        let cfg = MonitorConfig { window_size: 12 };
        let tt = transition_truth(&t, &cfg);
        assert!(tt.iter().any(|&b| b), "no transition window found");
        assert!(!tt[0], "first window must be steady");
    }

    #[test]
    fn streaming_matches_batch() {
        let mut g = Generator::with_default_config(2);
        let t = g.generate(&tour_schedule(64, &[3]));
        let cfg = MonitorConfig { window_size: 16 };
        let batch = aggregate_trace(&t, &cfg);

        let (tx_s, rx_s) = channel();
        let (tx_w, rx_w) = channel();
        let h = Monitor::spawn(rx_s, tx_w, cfg.clone(), 0);
        for s in &t.samples {
            tx_s.send(s.clone()).unwrap();
        }
        drop(tx_s);
        h.join().unwrap();
        let streamed: Vec<_> = rx_w.into_iter().collect();
        assert_eq!(streamed.len(), batch.len());
        for (a, b) in streamed.iter().zip(&batch) {
            assert_eq!(a.index, b.index);
            assert_eq!(a.mean, b.mean);
            assert_eq!(a.var, b.var);
        }
    }

    #[test]
    fn incremental_aggregator_matches_batch() {
        let mut g = Generator::with_default_config(5);
        let t = g.generate(&tour_schedule(70, &[1, 4]));
        let cfg = MonitorConfig { window_size: 14 };
        let batch = aggregate_trace(&t, &cfg);
        let mut agg = WindowAggregator::new(cfg, 0);
        let streamed: Vec<_> =
            t.samples.iter().filter_map(|s| agg.push(s.clone())).collect();
        assert_eq!(streamed.len(), batch.len());
        for (a, b) in streamed.iter().zip(&batch) {
            assert_eq!(a.index, b.index);
            assert_eq!(a.mean, b.mean);
            assert_eq!(a.var, b.var);
            assert_eq!(a.truth, b.truth);
        }
        // the trailing partial window stays open
        assert_eq!(agg.pending_samples(), t.len() % 14);
    }

    #[test]
    fn tenant_aggregator_demultiplexes_independent_index_spaces() {
        use crate::features::TenantId;
        let mut g = Generator::with_default_config(6);
        let ta = g.generate(&tour_schedule(50, &[0]));
        let tb = g.generate(&tour_schedule(30, &[2]));
        let cfg = MonitorConfig { window_size: 10 };
        let mut agg = TenantAggregator::new(cfg.clone());
        let mut per_tenant: std::collections::BTreeMap<u32, Vec<_>> =
            Default::default();
        // interleave one sample at a time (worst-case multiplexing)
        let longest = ta.len().max(tb.len());
        for i in 0..longest {
            for (k, tr) in [&ta, &tb].iter().enumerate() {
                if let Some(s) = tr.samples.get(i) {
                    if let Some((t, w)) =
                        agg.push(TenantId(k as u32), s.clone())
                    {
                        per_tenant.entry(t.0).or_default().push(w);
                    }
                }
            }
        }
        assert_eq!(agg.tenants(), vec![TenantId(0), TenantId(1)]);
        for (k, tr) in [&ta, &tb].iter().enumerate() {
            let batch = aggregate_trace(tr, &cfg);
            let got = &per_tenant[&(k as u32)];
            assert_eq!(got.len(), batch.len(), "tenant {k}");
            for (a, b) in got.iter().zip(&batch) {
                assert_eq!(a.index, b.index);
                assert_eq!(a.mean, b.mean);
                assert_eq!(a.var, b.var);
            }
        }
    }

    #[test]
    #[should_panic(expected = "window_size")]
    fn window_size_one_rejected() {
        let mut g = Generator::with_default_config(3);
        let t = g.generate(&tour_schedule(10, &[0]));
        aggregate_trace(&t, &MonitorConfig { window_size: 1 });
    }
}
