//! KERMIT system-monitoring agents (KAgnt, Figure 4): one agent per
//! cluster node scrapes that node's counters and streams time-stamped
//! messages to the workload monitor, which merges per-timestamp across
//! agents into cluster-level samples (utilisations average, throughput
//! counters sum) before window aggregation.
//!
//! On the paper's cluster each agent appends to its own landing-zone
//! file; here each agent is a thread with an mpsc channel — same
//! topology, same merge semantics.

use crate::features::NUM_FEATURES;
use crate::workloadgen::Sample;
use std::sync::mpsc::{channel, Receiver, Sender};

/// Which features average across nodes (utilisation-like) vs sum
/// (throughput-like). Order matches `features::FEATURE_NAMES`.
pub const SUM_FEATURES: [bool; NUM_FEATURES] = [
    false, false, false, // cpu user/sys/iowait: average
    false, false, // mem used/cache: average
    true, true, // disk read/write: sum
    true, true, // net rx/tx: sum
    true, true, // ctx switches, page faults: sum
    false, // gc time: average
    true, // task queue: sum
    true, true, true, // shuffle, hdfs read/write: sum
];

/// A message from one agent: (node id, sample).
#[derive(Debug, Clone)]
pub struct AgentMessage {
    pub node: usize,
    pub sample: Sample,
}

/// Split a cluster-level sample into `n` plausible per-node shares (the
/// inverse of [`merge`], used by the simulated agents): sum-features are
/// divided across nodes, average-features are replicated with jitter.
pub fn split_sample(
    s: &Sample,
    n: usize,
    rng: &mut crate::util::rng::Rng,
) -> Vec<Sample> {
    assert!(n > 0);
    // random positive weights normalised to 1 for the sum features
    let mut w: Vec<f64> = (0..n).map(|_| rng.range_f64(0.5, 1.5)).collect();
    let total: f64 = w.iter().sum();
    for x in w.iter_mut() {
        *x /= total;
    }
    (0..n)
        .map(|k| {
            let mut f = [0.0; NUM_FEATURES];
            for i in 0..NUM_FEATURES {
                f[i] = if SUM_FEATURES[i] {
                    s.features[i] * w[k]
                } else {
                    (s.features[i] * rng.range_f64(0.92, 1.08)).max(0.0)
                };
            }
            Sample { time: s.time, features: f, truth: s.truth }
        })
        .collect()
}

/// Merge per-node samples of the same timestamp into one cluster-level
/// sample: sum-features add, average-features average.
pub fn merge(parts: &[Sample]) -> Sample {
    assert!(!parts.is_empty());
    let n = parts.len() as f64;
    let mut f = [0.0; NUM_FEATURES];
    for p in parts {
        for i in 0..NUM_FEATURES {
            f[i] += p.features[i];
        }
    }
    for i in 0..NUM_FEATURES {
        if !SUM_FEATURES[i] {
            f[i] /= n;
        }
    }
    Sample { time: parts[0].time, features: f, truth: parts[0].truth }
}

/// The agent fleet: spawns one thread per node, each forwarding its
/// share of the cluster metrics; a merger thread recombines messages by
/// timestamp and emits cluster samples in order.
pub struct AgentFleet;

impl AgentFleet {
    /// Spawn `n_nodes` agents consuming pre-split per-node streams, plus
    /// the merger. Returns the merged cluster-sample receiver.
    ///
    /// The merger assumes agents deliver in timestamp order per node
    /// (true of the scrape loop) and waits for all nodes per timestamp —
    /// the paper's monitor does the same via per-agent landing files.
    pub fn spawn(
        per_node: Vec<Receiver<Sample>>,
    ) -> (Receiver<Sample>, std::thread::JoinHandle<()>) {
        let (tx_msg, rx_msg) = channel::<AgentMessage>();
        let n_nodes = per_node.len();
        // one forwarder thread per agent
        for (node, rx) in per_node.into_iter().enumerate() {
            let tx = tx_msg.clone();
            std::thread::spawn(move || {
                while let Ok(sample) = rx.recv() {
                    if tx.send(AgentMessage { node, sample }).is_err() {
                        return;
                    }
                }
            });
        }
        drop(tx_msg);

        let (tx_out, rx_out) = channel::<Sample>();
        let merger = std::thread::spawn(move || {
            use std::collections::BTreeMap;
            // pending[timestamp bits] -> collected parts
            let mut pending: BTreeMap<u64, Vec<Sample>> = BTreeMap::new();
            while let Ok(msg) = rx_msg.recv() {
                let key = msg.sample.time.to_bits();
                let parts = pending.entry(key).or_default();
                parts.push(msg.sample);
                if parts.len() == n_nodes {
                    let parts = pending.remove(&key).unwrap();
                    if tx_out.send(merge(&parts)).is_err() {
                        return;
                    }
                }
            }
            // input closed: flush stragglers (partial scrapes) in order
            for (_, parts) in pending {
                let _ = tx_out.send(merge(&parts));
            }
        });
        (rx_out, merger)
    }

    /// Convenience: run a full trace through a simulated n-node fleet
    /// and return the merged samples (ordering preserved).
    pub fn replay_trace(
        samples: &[Sample],
        n_nodes: usize,
        seed: u64,
    ) -> Vec<Sample> {
        let mut rng = crate::util::rng::Rng::new(seed);
        let mut txs: Vec<Sender<Sample>> = Vec::new();
        let mut rxs: Vec<Receiver<Sample>> = Vec::new();
        for _ in 0..n_nodes {
            let (tx, rx) = channel();
            txs.push(tx);
            rxs.push(rx);
        }
        let (rx_out, merger) = AgentFleet::spawn(rxs);
        for s in samples {
            for (k, part) in
                split_sample(s, n_nodes, &mut rng).into_iter().enumerate()
            {
                txs[k].send(part).expect("agent channel closed");
            }
        }
        drop(txs);
        let out: Vec<Sample> = rx_out.into_iter().collect();
        merger.join().expect("merger panicked");
        out
    }
}

/// Simulate the loss of `dead` of `n` nodes from time `at`: the dead
/// node's sum-share disappears and the survivors' utilisations rise —
/// the paper's §6.2 partial-self-healing scenario, where node failure
/// "present[s] itself as the appearance of new workload types".
pub fn inject_node_failure(
    samples: &mut [Sample],
    at_time: f64,
    n_nodes: usize,
    dead: usize,
) {
    assert!(dead < n_nodes);
    let survivors = (n_nodes - dead) as f64 / n_nodes as f64;
    for s in samples.iter_mut().filter(|s| s.time >= at_time) {
        for i in 0..NUM_FEATURES {
            if SUM_FEATURES[i] {
                // lost capacity: cluster-wide throughput drops
                s.features[i] *= survivors;
            } else {
                // survivors run hotter
                s.features[i] =
                    (s.features[i] / survivors).min(100.0);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloadgen::TruthTag;
    use crate::util::rng::Rng;
    use crate::workloadgen::{tour_schedule, Generator};

    fn sample(t: f64, level: f64) -> Sample {
        Sample {
            time: t,
            features: [level; NUM_FEATURES],
            truth: TruthTag::Steady(0),
        }
    }

    #[test]
    fn split_then_merge_is_identity_for_sums() {
        let mut rng = Rng::new(0);
        let s = sample(1.0, 40.0);
        let parts = split_sample(&s, 4, &mut rng);
        let m = merge(&parts);
        for i in 0..NUM_FEATURES {
            if SUM_FEATURES[i] {
                assert!(
                    (m.features[i] - s.features[i]).abs() < 1e-9,
                    "sum feature {i}"
                );
            } else {
                // averages reconstruct within the jitter band
                assert!(
                    (m.features[i] - s.features[i]).abs()
                        < 0.1 * s.features[i],
                    "avg feature {i}: {} vs {}",
                    m.features[i],
                    s.features[i]
                );
            }
        }
    }

    #[test]
    fn fleet_replay_preserves_count_and_order() {
        let mut g = Generator::with_default_config(1);
        let trace = g.generate(&tour_schedule(60, &[0, 3]));
        let merged = AgentFleet::replay_trace(&trace.samples, 4, 2);
        assert_eq!(merged.len(), trace.samples.len());
        for (a, b) in merged.iter().zip(&trace.samples) {
            assert_eq!(a.time, b.time);
        }
    }

    #[test]
    fn fleet_merge_statistically_faithful() {
        // windows aggregated from fleet-merged samples should match the
        // original trace closely enough for classification
        use crate::monitor::{aggregate_samples, MonitorConfig};
        let mut g = Generator::with_default_config(3);
        let trace = g.generate(&tour_schedule(300, &[2]));
        let merged = AgentFleet::replay_trace(&trace.samples, 4, 4);
        let cfg = MonitorConfig { window_size: 30 };
        let wa = aggregate_samples(&trace.samples, &cfg);
        let wb = aggregate_samples(&merged, &cfg);
        for (a, b) in wa.iter().zip(&wb) {
            for i in 0..NUM_FEATURES {
                let tol = 0.12 * a.mean[i].abs() + 1.0;
                assert!(
                    (a.mean[i] - b.mean[i]).abs() < tol,
                    "window {} feature {i}: {} vs {}",
                    a.index,
                    a.mean[i],
                    b.mean[i]
                );
            }
        }
    }

    #[test]
    fn node_failure_shifts_signature() {
        let mut samples: Vec<Sample> =
            (0..100).map(|i| sample(i as f64, 40.0)).collect();
        inject_node_failure(&mut samples, 50.0, 4, 1);
        // before: untouched
        assert_eq!(samples[10].features[0], 40.0);
        // after: utilisations rise, throughputs fall
        assert!(samples[60].features[0] > 40.0); // cpu_user (avg)
        assert!(samples[60].features[5] < 40.0); // disk_read (sum)
    }

    #[test]
    fn merge_single_node_is_identity() {
        let s = sample(2.0, 17.0);
        let m = merge(&[s.clone()]);
        assert_eq!(m.features, s.features);
    }
}
