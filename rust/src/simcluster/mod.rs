//! Simulated big-data cluster substrate: YARN-like resource manager with
//! the plug-in interception point, the configuration-sensitive job
//! performance model, and a discrete-event engine that produces both job
//! logs and the agent metric stream.
//!
//! Stands in for the paper's physical Hadoop/Spark testbed (DESIGN.md §2).

pub mod config_space;
pub mod engine;
pub mod fault;
pub mod multi;
pub mod perfmodel;
pub mod rm;

pub use config_space::{default_config_index, ConfigIndex, TuningConfig};
pub use engine::{run_jobs, EngineConfig, JobRecord, JobSpec, SimResult};
pub use fault::{
    ChurnEvent, DriftStorm, FaultLayer, FaultPlan, FaultReport,
    NoisyNeighborFault, PreemptionFault, StragglerFault,
};
pub use multi::{
    FixedConfigTenants, MultiClusterEngine, MultiEngineConfig,
    MultiSimResult, TenantRmPlugin, TenantSimLog,
};
pub use perfmodel::{job_duration, profile_for, ClassProfile};
pub use rm::{
    Container, FixedConfigPlugin, NodeSpec, ResourceManager,
    ResourceRequest, RmError, RmPlugin,
};
