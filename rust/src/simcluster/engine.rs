//! Discrete-event cluster engine: runs a schedule of jobs through the
//! resource manager (with whatever plug-in is installed), produces the
//! job log (durations, configs) and the agent metric stream the KERMIT
//! monitor consumes.
//!
//! Jobs run back-to-back per the schedule (the paper's workloads are a
//! serial stream of analytic jobs; concurrency is modelled *inside* a
//! job via the hybrid classes, matching how the paper treats multi-user
//! load as hybrid workload types).

use super::config_space::TuningConfig;
use super::perfmodel::job_duration;
use super::rm::{ResourceRequest, RmPlugin};
use crate::util::rng::Rng;
use crate::workloadgen::{
    catalog, num_pure_classes, Mix, Sample, TruthTag, WorkloadClass,
};
use crate::features::NUM_FEATURES;

/// One job to run.
#[derive(Debug, Clone, Copy)]
pub struct JobSpec {
    pub mix: Mix,
}

/// Completed-job record.
#[derive(Debug, Clone)]
pub struct JobRecord {
    pub app_id: u64,
    pub truth_id: u32,
    pub config: TuningConfig,
    pub start: f64,
    pub duration: f64,
}

/// Full simulation output.
#[derive(Debug, Clone, Default)]
pub struct SimResult {
    pub jobs: Vec<JobRecord>,
    pub samples: Vec<Sample>,
    pub makespan: f64,
}

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Metric sample period (simulated seconds).
    pub sample_period: f64,
    /// Multiplicative lognormal-ish noise on job durations (0 = exact).
    pub duration_noise: f64,
    /// Idle gap between jobs (seconds).
    pub inter_job_gap: f64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            sample_period: 1.0,
            duration_noise: 0.03,
            inter_job_gap: 4.0,
        }
    }
}

/// Run `jobs` through the plug-in. Each job: RM calls the plug-in for a
/// config, the job runs for `perfmodel::job_duration` (plus noise),
/// metric samples with the class's signature are emitted for its whole
/// runtime, and the plug-in gets the completion callback.
pub fn run_jobs(
    jobs: &[JobSpec],
    plugin: &mut dyn RmPlugin,
    engine: &EngineConfig,
    seed: u64,
) -> SimResult {
    let mut rng = Rng::new(seed);
    let cat = catalog();
    let mut out = SimResult::default();
    let mut now = 0.0f64;
    let n_pure = num_pure_classes();

    for (k, job) in jobs.iter().enumerate() {
        let app_id = k as u64;
        let truth_id = job.mix.truth_id(n_pure);

        // idle gap before the job (background noise samples)
        let gap_end = now + engine.inter_job_gap;
        emit_idle(&mut out.samples, now, gap_end, engine.sample_period, &mut rng);
        now = gap_end;

        // RM responds to the resource request -> plug-in picks the config
        let req = ResourceRequest { app_id, time: now };
        let config = plugin.on_resource_request(&req);

        let base = job_duration(truth_id, &config);
        let noise = 1.0 + engine.duration_noise * rng.normal();
        let duration = base * noise.max(0.5);

        // metric emission for the job's runtime
        emit_job(
            &mut out.samples,
            &cat,
            job.mix,
            truth_id,
            now,
            now + duration,
            engine.sample_period,
            (true, true),
            &mut rng,
        );
        now += duration;

        plugin.on_app_complete(app_id, duration, now);
        out.jobs.push(JobRecord { app_id, truth_id, config, start: gap_end, duration });
    }
    out.makespan = now;
    out
}

/// Idle-gap emission (background noise floor) — shared with the
/// multi-tenant engine (`simcluster::multi`).
pub(crate) fn emit_idle(
    samples: &mut Vec<Sample>,
    from: f64,
    to: f64,
    period: f64,
    rng: &mut Rng,
) {
    let mut t = from;
    while t < to {
        let mut f = [0.0; NUM_FEATURES];
        for v in f.iter_mut() {
            *v = rng.range_f64(0.0, 2.0); // background noise floor
        }
        samples.push(Sample { time: t, features: f, truth: TruthTag::Idle });
        t += period;
    }
}

/// Job emission with transition-ramp marking — shared with the
/// multi-tenant engine (`simcluster::multi`). `ramps` = (ramp_in,
/// ramp_out): callers that split one job across several emission calls
/// (identification prefix, then body) ramp only at the *real* job
/// boundaries, so no spurious mid-job transition appears at the split.
#[allow(clippy::too_many_arguments)]
pub(crate) fn emit_job(
    samples: &mut Vec<Sample>,
    cat: &[WorkloadClass],
    mix: Mix,
    truth_id: u32,
    from: f64,
    to: f64,
    period: f64,
    ramps: (bool, bool),
    rng: &mut Rng,
) {
    let mean = mix.mean(cat);
    let noise = mix.noise(cat);
    let ramp = ((to - from) * 0.04).clamp(period, 8.0 * period);
    let mut t = from;
    while t < to {
        // short ramp in/out marks the job boundary as a transition
        let in_ramp = (ramps.0 && t - from < ramp)
            || (ramps.1 && to - t < ramp);
        let scale = if in_ramp { 1.8 } else { 1.0 };
        let mut f = [0.0; NUM_FEATURES];
        for i in 0..NUM_FEATURES {
            f[i] = rng.normal_ms(mean[i], noise[i] * scale).max(0.0);
        }
        let truth = if in_ramp {
            TruthTag::Transition { from: truth_id, to: truth_id }
        } else {
            TruthTag::Steady(truth_id)
        };
        samples.push(Sample { time: t, features: f, truth });
        t += period;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simcluster::config_space::default_config_index;
    use crate::simcluster::rm::FixedConfigPlugin;

    fn jobs(classes: &[u32]) -> Vec<JobSpec> {
        classes.iter().map(|&c| JobSpec { mix: Mix::Pure(c) }).collect()
    }

    #[test]
    fn runs_jobs_and_accumulates_makespan() {
        let mut plugin =
            FixedConfigPlugin(default_config_index().to_config());
        let r = run_jobs(
            &jobs(&[0, 1, 2]),
            &mut plugin,
            &EngineConfig::default(),
            42,
        );
        assert_eq!(r.jobs.len(), 3);
        assert!(r.makespan > 0.0);
        let sum: f64 = r.jobs.iter().map(|j| j.duration).sum();
        assert!(r.makespan >= sum);
        // samples cover the whole makespan
        let last = r.samples.last().unwrap().time;
        assert!(last > r.makespan - 2.0);
    }

    #[test]
    fn duration_tracks_perfmodel() {
        let cfg = default_config_index().to_config();
        let mut plugin = FixedConfigPlugin(cfg);
        let mut engine = EngineConfig::default();
        engine.duration_noise = 0.0;
        let r = run_jobs(&jobs(&[3]), &mut plugin, &engine, 1);
        let want = job_duration(3, &cfg);
        assert!((r.jobs[0].duration - want).abs() < 1e-9);
    }

    #[test]
    fn samples_carry_truth_tags() {
        let mut plugin =
            FixedConfigPlugin(default_config_index().to_config());
        let r = run_jobs(&jobs(&[5]), &mut plugin, &EngineConfig::default(), 2);
        assert!(r
            .samples
            .iter()
            .any(|s| s.truth == TruthTag::Steady(5)));
        assert!(r.samples.iter().any(|s| s.truth == TruthTag::Idle));
    }

    #[test]
    fn plugin_sees_every_request_and_completion() {
        struct Counting {
            cfg: TuningConfig,
            requests: usize,
            completions: usize,
        }
        impl RmPlugin for Counting {
            fn on_resource_request(
                &mut self,
                _req: &ResourceRequest,
            ) -> TuningConfig {
                self.requests += 1;
                self.cfg
            }
            fn on_app_complete(&mut self, _id: u64, _d: f64, _t: f64) {
                self.completions += 1;
            }
        }
        let mut p = Counting {
            cfg: default_config_index().to_config(),
            requests: 0,
            completions: 0,
        };
        run_jobs(&jobs(&[0, 1, 2, 3]), &mut p, &EngineConfig::default(), 3);
        assert_eq!(p.requests, 4);
        assert_eq!(p.completions, 4);
    }

    #[test]
    fn deterministic_given_seed() {
        let mk = || {
            let mut p = FixedConfigPlugin(default_config_index().to_config());
            run_jobs(&jobs(&[0, 4]), &mut p, &EngineConfig::default(), 9)
        };
        let (a, b) = (mk(), mk());
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.samples.len(), b.samples.len());
    }
}
