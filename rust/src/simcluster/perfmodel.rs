//! Configuration-sensitive job performance model: the response surface
//! the Explorer searches (paper §6.4, [16]).
//!
//! The surface is built from first-principles Spark/Hadoop cost effects,
//! per workload class:
//!
//! * **Wave quantisation** — tasks run in ⌈parallelism / slots⌉ waves;
//!   parallelism that doesn't divide the slot count wastes a partial wave.
//! * **Task overhead** — each task costs fixed scheduling/JVM time, so
//!   over-partitioning backfires (non-convexity #1).
//! * **GC/spill cliff** — when per-task memory drops below the class's
//!   working-set demand, time blows up super-linearly (the cliff real
//!   tuning guides warn about).
//! * **Shuffle spills** — shuffle-heavy classes degrade sharply when the
//!   shuffle buffer is small.
//! * **Compression trade-off** — compression accelerates I/O-bound
//!   classes and *penalises* CPU-bound ones (non-convexity #2, class-
//!   dependent optimum).
//! * **Cluster capacity** — executors that don't fit the cluster's
//!   cores/memory run in sequential allocation waves (interaction
//!   between num_executors, executor_cores and executor_mem).
//!
//! Different workload classes weight these effects differently, so each
//! class has a different optimal configuration — the property that makes
//! per-workload tuning (and therefore KERMIT) worthwhile.

use super::config_space::TuningConfig;
use crate::workloadgen::num_pure_classes;

/// Static cluster capacity (4 worker nodes).
pub const CLUSTER_CORES: u32 = 64;
pub const CLUSTER_MEM_MB: u32 = 98_304;

/// Resource-demand profile of a workload class.
#[derive(Debug, Clone, Copy)]
pub struct ClassProfile {
    /// Total compute work, core-seconds at unit speed.
    pub work: f64,
    /// Fraction of work that is CPU-bound (vs I/O-bound).
    pub cpu_frac: f64,
    /// Per-task working-set demand, MB.
    pub mem_demand_mb: f64,
    /// Shuffle volume per task, MB.
    pub shuffle_mb_per_task: f64,
    /// I/O volume factor (scales the I/O phase).
    pub io_gb: f64,
}

/// Profiles for the 10 pure classes in `workloadgen::catalog()` (same
/// order). Hybrids average their constituents.
pub fn class_profiles() -> Vec<ClassProfile> {
    vec![
        // 0 wordcount_map: cpu-heavy scan
        ClassProfile { work: 3200.0, cpu_frac: 0.85, mem_demand_mb: 900.0, shuffle_mb_per_task: 8.0, io_gb: 40.0 },
        // 1 wordcount_reduce: io write heavy
        ClassProfile { work: 1400.0, cpu_frac: 0.35, mem_demand_mb: 700.0, shuffle_mb_per_task: 24.0, io_gb: 55.0 },
        // 2 terasort_shuffle: shuffle monster
        ClassProfile { work: 4200.0, cpu_frac: 0.45, mem_demand_mb: 1600.0, shuffle_mb_per_task: 220.0, io_gb: 90.0 },
        // 3 kmeans_iter: memory-resident cpu
        ClassProfile { work: 3800.0, cpu_frac: 0.92, mem_demand_mb: 2600.0, shuffle_mb_per_task: 12.0, io_gb: 12.0 },
        // 4 sql_join: balanced, moderate shuffle
        ClassProfile { work: 2800.0, cpu_frac: 0.6, mem_demand_mb: 1400.0, shuffle_mb_per_task: 90.0, io_gb: 50.0 },
        // 5 stream_ingest: io-dominated
        ClassProfile { work: 1600.0, cpu_frac: 0.25, mem_demand_mb: 600.0, shuffle_mb_per_task: 4.0, io_gb: 75.0 },
        // 6 pagerank_step: memory + network
        ClassProfile { work: 3400.0, cpu_frac: 0.7, mem_demand_mb: 2200.0, shuffle_mb_per_task: 60.0, io_gb: 20.0 },
        // 7 bayes_train: cpu with broadcast
        ClassProfile { work: 2600.0, cpu_frac: 0.75, mem_demand_mb: 1200.0, shuffle_mb_per_task: 30.0, io_gb: 30.0 },
        // 8 etl_transform: io both ways
        ClassProfile { work: 2200.0, cpu_frac: 0.45, mem_demand_mb: 800.0, shuffle_mb_per_task: 16.0, io_gb: 65.0 },
        // 9 olap_burst: short cache-hot scans
        ClassProfile { work: 900.0, cpu_frac: 0.65, mem_demand_mb: 500.0, shuffle_mb_per_task: 10.0, io_gb: 15.0 },
    ]
}

/// Profile for a ground-truth class id (pure or hybrid, as produced by
/// `Mix::truth_id`). Hybrid profiles are the mean of their constituents
/// plus a 15% contention surcharge on work.
pub fn profile_for(truth_id: u32) -> ClassProfile {
    let profiles = class_profiles();
    let n = num_pure_classes() as u32;
    if truth_id < n {
        return profiles[truth_id as usize];
    }
    // decode hybrid pair from the lexicographic pair index
    let mut rest = (truth_id - n) as usize;
    let n = n as usize;
    let mut lo = 0usize;
    while rest >= n - lo - 1 {
        rest -= n - lo - 1;
        lo += 1;
    }
    let hi = lo + 1 + rest;
    let (a, b) = (profiles[lo], profiles[hi]);
    ClassProfile {
        work: 1.15 * 0.5 * (a.work + b.work) * 2.0, // both tenants' work
        cpu_frac: 0.5 * (a.cpu_frac + b.cpu_frac),
        mem_demand_mb: 0.5 * (a.mem_demand_mb + b.mem_demand_mb),
        shuffle_mb_per_task: 0.5
            * (a.shuffle_mb_per_task + b.shuffle_mb_per_task),
        io_gb: 0.5 * (a.io_gb + b.io_gb) * 2.0,
    }
}

/// Deterministic job duration (seconds) for class `truth_id` under
/// `config`. The measurement noise a real cluster adds is injected by
/// callers (`JobRunner`) so the model itself is exactly reproducible.
pub fn job_duration(truth_id: u32, config: &TuningConfig) -> f64 {
    let p = profile_for(truth_id);
    duration_for_profile(&p, config)
}

pub fn duration_for_profile(p: &ClassProfile, config: &TuningConfig) -> f64 {
    let cores_req = config.executor_cores * config.num_executors;
    let mem_req = config.executor_mem_mb * config.num_executors;

    // --- capacity waves: executors beyond the cluster run sequentially
    let core_waves = (cores_req as f64 / CLUSTER_CORES as f64).ceil().max(1.0);
    let mem_waves = (mem_req as f64 / CLUSTER_MEM_MB as f64).ceil().max(1.0);
    let alloc_waves = core_waves.max(mem_waves);
    // effective concurrent slots
    let slots = ((cores_req as f64) / alloc_waves).max(1.0);

    // --- task decomposition
    let tasks = config.parallelism.max(1) as f64;
    let task_waves = (tasks / slots).ceil();
    let work_per_task = p.work / tasks;

    // --- memory effects (per-task share of the executor heap)
    let mem_per_task =
        config.executor_mem_mb as f64 / config.executor_cores as f64;
    let mem_ratio = p.mem_demand_mb / mem_per_task;
    let gc_factor = if mem_ratio <= 0.8 {
        1.0
    } else if mem_ratio <= 1.0 {
        // approaching the cliff: mild GC pressure
        1.0 + 0.8 * (mem_ratio - 0.8) / 0.2 * 0.3
    } else if mem_ratio <= 2.0 {
        // over the cliff: heavy GC + spill
        1.24 + 2.8 * (mem_ratio - 1.0)
    } else {
        // thrash
        4.04 + 6.0 * (mem_ratio - 2.0)
    };

    // --- shuffle effects
    let shuffle_per_task = p.shuffle_mb_per_task * (256.0 / tasks).max(0.25);
    let spill_ratio = shuffle_per_task / config.shuffle_buffer_mb as f64;
    let shuffle_factor = if spill_ratio <= 1.0 {
        1.0
    } else {
        // each extra spill pass re-reads/writes the shuffle data
        1.0 + 0.55 * (spill_ratio - 1.0).min(6.0)
    };
    let shuffle_time = 0.012
        * p.shuffle_mb_per_task
        * tasks.min(256.0)
        * shuffle_factor
        / slots.sqrt();

    // --- compression trade-off
    let (io_comp, cpu_comp) = if config.compression {
        (0.62, 1.18)
    } else {
        (1.0, 1.0)
    };

    // --- cpu and io phases
    let cpu_time_per_task = work_per_task * p.cpu_frac * cpu_comp * gc_factor;
    let io_time_per_task = work_per_task * (1.0 - p.cpu_frac) * io_comp
        + p.io_gb * 1024.0 * io_comp / (tasks * 140.0); // 140 MB/s/task disk
    // fixed per-task overhead (scheduling + JVM)
    let overhead_per_task = 0.35;

    let per_task = cpu_time_per_task + io_time_per_task + overhead_per_task;
    let duration = task_waves * per_task * alloc_waves + shuffle_time;

    // small executors also pay a broadcast/setup cost per executor wave
    let setup = 2.0 * alloc_waves + 0.15 * config.num_executors as f64;
    duration + setup
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simcluster::config_space::{
        default_config_index, ConfigIndex,
    };

    fn best_and_worst(truth_id: u32) -> (f64, f64) {
        let mut best = f64::INFINITY;
        let mut worst = 0.0f64;
        for ci in ConfigIndex::enumerate_all() {
            let d = job_duration(truth_id, &ci.to_config());
            best = best.min(d);
            worst = worst.max(d);
        }
        (best, worst)
    }

    #[test]
    fn surface_has_meaningful_dynamic_range() {
        for class in [0u32, 2, 3, 5] {
            let (best, worst) = best_and_worst(class);
            assert!(
                worst / best > 4.0,
                "class {class}: best {best}, worst {worst}"
            );
            assert!(best > 10.0, "class {class} best {best} too small");
        }
    }

    #[test]
    fn default_config_is_mediocre() {
        // the vendor default should leave >=25% on the table for most
        // classes (the paper's premise that untuned clusters are slow)
        let dc = default_config_index().to_config();
        let mut losers = 0;
        for class in 0..num_pure_classes() as u32 {
            let (best, _) = best_and_worst(class);
            let d = job_duration(class, &dc);
            if d > 1.25 * best {
                losers += 1;
            }
        }
        assert!(losers >= 7, "only {losers} classes lose with default");
    }

    #[test]
    fn optima_differ_across_classes() {
        // per-class argmin configs must not all coincide — otherwise
        // per-workload tuning would be pointless
        let mut argmins = std::collections::HashSet::new();
        for class in 0..num_pure_classes() as u32 {
            let mut best = (f64::INFINITY, ConfigIndex([0; 6]));
            for ci in ConfigIndex::enumerate_all() {
                let d = job_duration(class, &ci.to_config());
                if d < best.0 {
                    best = (d, ci);
                }
            }
            argmins.insert(best.1 .0);
        }
        assert!(argmins.len() >= 3, "only {} distinct optima", argmins.len());
    }

    #[test]
    fn memory_cliff_exists() {
        // kmeans (class 3, 2600 MB demand): starving memory must blow up
        let starved = TuningConfig {
            executor_mem_mb: 1024,
            executor_cores: 4,
            num_executors: 8,
            shuffle_buffer_mb: 128,
            parallelism: 64,
            compression: false,
        };
        let fed = TuningConfig { executor_mem_mb: 12288, ..starved };
        let r = job_duration(3, &starved) / job_duration(3, &fed);
        assert!(r > 3.0, "cliff ratio {r}");
    }

    #[test]
    fn compression_helps_io_hurts_cpu() {
        let base = TuningConfig {
            executor_mem_mb: 8192,
            executor_cores: 4,
            num_executors: 12,
            shuffle_buffer_mb: 128,
            parallelism: 128,
            compression: false,
        };
        let comp = TuningConfig { compression: true, ..base };
        // stream_ingest (5) is io-bound: compression should help
        assert!(job_duration(5, &comp) < job_duration(5, &base));
        // kmeans (3) is cpu-bound: compression should hurt
        assert!(job_duration(3, &comp) > job_duration(3, &base));
    }

    #[test]
    fn oversubscription_pays_alloc_waves() {
        let fits = TuningConfig {
            executor_mem_mb: 4096,
            executor_cores: 4,
            num_executors: 16,
            shuffle_buffer_mb: 128,
            parallelism: 128,
            compression: false,
        }; // 64 cores, 64 GB: fits
        let over = TuningConfig { num_executors: 24, ..fits }; // 96 cores
        assert!(job_duration(0, &over) > job_duration(0, &fits));
    }

    #[test]
    fn hybrid_profile_is_heavier_than_parts() {
        let n = num_pure_classes() as u32;
        let hybrid_id = crate::workloadgen::Mix::Hybrid(0, 1, 0.5)
            .truth_id(num_pure_classes());
        let h = profile_for(hybrid_id);
        let a = profile_for(0);
        let b = profile_for(1);
        assert!(h.work > 0.5 * (a.work + b.work));
        assert!(hybrid_id >= n);
    }

    #[test]
    fn deterministic() {
        let c = default_config_index().to_config();
        assert_eq!(job_duration(2, &c), job_duration(2, &c));
    }
}
