//! The Spark/Hadoop tuning-parameter space KERMIT searches.
//!
//! Six parameters with discrete levels (the paper's Explorer operates on
//! YARN container memory/CPU and related knobs [16]; we model the
//! standard Spark tuning set). The full grid — the "exhaustive search"
//! oracle that defines 100% tuning efficiency — has
//! 6*6*6*6*6*2 = 15552 points, discretised as in real deployments.

/// One concrete configuration (a point in the search space).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TuningConfig {
    /// Executor heap, MB.
    pub executor_mem_mb: u32,
    /// Cores per executor.
    pub executor_cores: u32,
    /// Number of executors.
    pub num_executors: u32,
    /// Shuffle buffer per task, MB.
    pub shuffle_buffer_mb: u32,
    /// Default parallelism (partitions).
    pub parallelism: u32,
    /// I/O compression on/off.
    pub compression: bool,
}

/// Discrete levels per dimension.
pub const MEM_LEVELS: [u32; 6] = [1024, 2048, 4096, 6144, 8192, 12288];
pub const CORE_LEVELS: [u32; 6] = [1, 2, 3, 4, 5, 8];
pub const EXEC_LEVELS: [u32; 6] = [2, 4, 8, 12, 16, 24];
pub const SHUFFLE_LEVELS: [u32; 6] = [16, 32, 64, 128, 256, 512];
pub const PAR_LEVELS: [u32; 6] = [8, 16, 32, 64, 128, 256];
pub const COMPRESSION_LEVELS: [bool; 2] = [false, true];

/// Dimension count (for index-vector representations).
pub const NUM_DIMS: usize = 6;

/// A configuration as level indices — the representation the Explorer's
/// coordinate search walks.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ConfigIndex(pub [usize; NUM_DIMS]);

impl ConfigIndex {
    pub fn dims() -> [usize; NUM_DIMS] {
        [
            MEM_LEVELS.len(),
            CORE_LEVELS.len(),
            EXEC_LEVELS.len(),
            SHUFFLE_LEVELS.len(),
            PAR_LEVELS.len(),
            COMPRESSION_LEVELS.len(),
        ]
    }

    pub fn to_config(self) -> TuningConfig {
        let i = self.0;
        TuningConfig {
            executor_mem_mb: MEM_LEVELS[i[0]],
            executor_cores: CORE_LEVELS[i[1]],
            num_executors: EXEC_LEVELS[i[2]],
            shuffle_buffer_mb: SHUFFLE_LEVELS[i[3]],
            parallelism: PAR_LEVELS[i[4]],
            compression: COMPRESSION_LEVELS[i[5]],
        }
    }

    /// Neighbours at L1 distance 1 (one dimension stepped by ±1).
    pub fn neighbours(self) -> Vec<ConfigIndex> {
        let dims = Self::dims();
        let mut out = Vec::with_capacity(2 * NUM_DIMS);
        for d in 0..NUM_DIMS {
            if self.0[d] > 0 {
                let mut n = self;
                n.0[d] -= 1;
                out.push(n);
            }
            if self.0[d] + 1 < dims[d] {
                let mut n = self;
                n.0[d] += 1;
                out.push(n);
            }
        }
        out
    }

    /// Total number of grid points.
    pub fn grid_size() -> usize {
        Self::dims().iter().product()
    }

    /// Enumerate the entire grid (for the exhaustive-search oracle).
    pub fn enumerate_all() -> Vec<ConfigIndex> {
        let dims = Self::dims();
        let mut out = Vec::with_capacity(Self::grid_size());
        let mut idx = [0usize; NUM_DIMS];
        loop {
            out.push(ConfigIndex(idx));
            // odometer increment
            let mut d = NUM_DIMS;
            loop {
                if d == 0 {
                    return out;
                }
                d -= 1;
                idx[d] += 1;
                if idx[d] < dims[d] {
                    break;
                }
                idx[d] = 0;
            }
        }
    }

    /// Clamp an arbitrary index vector into the grid.
    pub fn clamped(mut self) -> ConfigIndex {
        let dims = Self::dims();
        for d in 0..NUM_DIMS {
            if self.0[d] >= dims[d] {
                self.0[d] = dims[d] - 1;
            }
        }
        self
    }
}

/// The vendor-default configuration (what an untuned deployment ships
/// with) — deliberately mediocre for most workloads, like the real
/// Spark/YARN defaults the paper tunes away from.
pub fn default_config_index() -> ConfigIndex {
    // 2048 MB, 1 core, 2 executors, 32 MB shuffle, 16 partitions, no comp
    ConfigIndex([1, 0, 0, 1, 1, 0])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_size_matches_product() {
        assert_eq!(ConfigIndex::grid_size(), 6 * 6 * 6 * 6 * 6 * 2);
        assert_eq!(
            ConfigIndex::enumerate_all().len(),
            ConfigIndex::grid_size()
        );
    }

    #[test]
    fn enumerate_has_no_duplicates() {
        let all = ConfigIndex::enumerate_all();
        let set: std::collections::HashSet<_> = all.iter().collect();
        assert_eq!(set.len(), all.len());
    }

    #[test]
    fn neighbours_interior_and_corner() {
        let interior = ConfigIndex([2, 2, 2, 2, 2, 0]);
        assert_eq!(interior.neighbours().len(), 2 * 5 + 1); // bool dim at 0: 1
        let corner = ConfigIndex([0, 0, 0, 0, 0, 0]);
        assert_eq!(corner.neighbours().len(), NUM_DIMS);
        for n in corner.neighbours() {
            let diff: usize = n
                .0
                .iter()
                .zip(&corner.0)
                .map(|(a, b)| a.abs_diff(*b))
                .sum();
            assert_eq!(diff, 1);
        }
    }

    #[test]
    fn to_config_maps_levels() {
        let c = ConfigIndex([0, 0, 0, 0, 0, 0]).to_config();
        assert_eq!(c.executor_mem_mb, 1024);
        assert!(!c.compression);
        let c = ConfigIndex([5, 5, 5, 5, 5, 1]).to_config();
        assert_eq!(c.executor_mem_mb, 12288);
        assert_eq!(c.parallelism, 256);
        assert!(c.compression);
    }

    #[test]
    fn clamp_works() {
        let c = ConfigIndex([99, 0, 0, 0, 0, 99]).clamped();
        assert_eq!(c.0[0], 5);
        assert_eq!(c.0[5], 1);
    }
}
