//! Multi-tenant cluster engine: ONE `ResourceManager` serving K
//! concurrent, interleaved job streams.
//!
//! The single-stream engine (`simcluster::engine::run_jobs`) models the
//! paper's serial benchmark runs; this module models the shared-cluster
//! deployment the multi-tenant MAPE-K loop actually targets: every
//! tenant owns a FIFO queue of [`JobSpec`]s, at most one job per tenant
//! runs at a time (a tenant's jobs are a serial analytic stream), and
//! jobs of *different* tenants run concurrently against the same
//! container pool.
//!
//! # Fair container allocation
//!
//! When a job starts, the RM responds to its resource request — the
//! plug-in interception point, per tenant — and the job asks for its
//! chosen config's executor fleet (`num_executors` containers of
//! `executor_cores` × `executor_mem_mb`). The RM grants what fits
//! (`allocate_up_to`); the job runs with the granted fleet, i.e. its
//! duration is computed from an *effective* config whose executor count
//! is the grant — contention on the shared cluster slows jobs down
//! exactly the way the perf model prices a smaller fleet. A job granted
//! nothing queues until a completion frees capacity; start attempts are
//! retried in round-robin rotated tenant order so no tenant starves.
//!
//! This means a *probe* measured under a degraded grant feeds the
//! contention-inflated duration back to the Explorer — exactly what a
//! real shared cluster does (a probe IS one execution under whatever
//! the RM granted). A search that converges under heavy contention can
//! therefore persist a contention-shaped optimum; as on the paper's
//! cluster, re-evaluation happens through the drift path (the optimum
//! is cleared when the workload is marked drifting), not by re-probing
//! a stored optimum.
//!
//! # Metric streams
//!
//! Each tenant emits its own tagged metric stream (idle gaps, an
//! identification prefix *before* the config decision — the lead-in the
//! single-tenant coordinator models — and the job body with transition
//! ramps), delivered incrementally through
//! [`TenantRmPlugin::on_samples`] so a monitor/identification stack can
//! run in lock-step with the simulation. Per-tenant RNG streams make
//! every tenant's timeline deterministic regardless of interleaving.

use super::config_space::TuningConfig;
use super::engine::{emit_idle, emit_job, EngineConfig, JobRecord, JobSpec};
use super::fault::{FaultLayer, FaultPlan, FaultReport};
use super::perfmodel::job_duration;
use super::rm::{ResourceManager, ResourceRequest};
use crate::features::TenantId;
use crate::util::rng::Rng;
use crate::workloadgen::{catalog, num_pure_classes, Sample};
use std::collections::{BTreeMap, VecDeque};

/// The per-tenant plug-in interception surface: what the RM calls as K
/// job streams run. One implementor fans these out to per-tenant
/// `KermitPlugin`s (`tuning::TuningPlane`); baselines use
/// [`FixedConfigTenants`].
pub trait TenantRmPlugin {
    /// Metric samples `tenant`'s agents emitted up to the current
    /// simulated time (idle gaps, identification prefixes, job bodies).
    fn on_samples(&mut self, _tenant: TenantId, _samples: &[Sample]) {}

    /// The RM responds to `tenant`'s resource request: pick the tuning
    /// configuration for this application's containers.
    fn on_resource_request(
        &mut self,
        tenant: TenantId,
        req: &ResourceRequest,
    ) -> TuningConfig;

    /// Completion feedback — the measured duration of the application
    /// (the feedback edge of the autonomic loop).
    fn on_app_complete(
        &mut self,
        _tenant: TenantId,
        _app_id: u64,
        _duration: f64,
        _now: f64,
    ) {
    }

    /// The RM granted `granted` containers to `tenant`'s application
    /// `app_id` — the fleet the job actually runs on, which contention
    /// may shrink below the ask. Lets the tuning plane judge measured
    /// durations in context (a degraded grant explains a slow job; a
    /// full grant does not).
    fn on_grant(&mut self, _tenant: TenantId, _app_id: u64, _granted: u32) {}

    /// The application died without completing (total container loss,
    /// tenant churn). The plug-in must write off any probe riding on
    /// this app so nothing waits forever for a measurement that will
    /// never arrive.
    fn on_app_fail(&mut self, _tenant: TenantId, _app_id: u64, _now: f64) {}
}

/// Every tenant under one fixed configuration (default / rule-of-thumb
/// baselines for the tuning-plane experiment).
pub struct FixedConfigTenants(pub TuningConfig);

impl TenantRmPlugin for FixedConfigTenants {
    fn on_resource_request(
        &mut self,
        _tenant: TenantId,
        _req: &ResourceRequest,
    ) -> TuningConfig {
        self.0
    }
}

/// Multi-tenant engine configuration.
#[derive(Debug, Clone)]
pub struct MultiEngineConfig {
    /// Shared knobs with the single-stream engine (sample period,
    /// duration noise, inter-job gap).
    pub engine: EngineConfig,
    /// Identification lead-in (seconds of the job's signature emitted
    /// before the config decision). Keep ≥ one observation window of
    /// samples or the decision always sees a stale/unknown context.
    pub prefix_secs: f64,
    /// Cap on metric samples emitted per job body (long jobs emit a
    /// truncated head — identification needs windows, not hours).
    pub max_job_samples: usize,
    /// Cap on idle samples emitted before a job (long queue waits are
    /// compressed; the noise floor carries no information).
    pub max_idle_samples: usize,
    /// Per-tenant start stagger (seconds): tenant k's first job arrives
    /// at `k * start_stagger`, so K tenants don't hit the RM in one
    /// thundering herd at t=0.
    pub start_stagger: f64,
}

impl Default for MultiEngineConfig {
    fn default() -> Self {
        MultiEngineConfig {
            engine: EngineConfig::default(),
            prefix_secs: 60.0,
            max_job_samples: 1200,
            max_idle_samples: 90,
            start_stagger: 7.0,
        }
    }
}

/// One tenant's simulation log.
#[derive(Debug, Clone, Default)]
pub struct TenantSimLog {
    pub jobs: Vec<JobRecord>,
    pub samples: Vec<Sample>,
}

/// Full multi-tenant simulation output.
#[derive(Debug, Clone, Default)]
pub struct MultiSimResult {
    pub per_tenant: BTreeMap<TenantId, TenantSimLog>,
    pub makespan: f64,
    /// Peak number of concurrently running jobs — must exceed 1 for the
    /// run to have actually exercised the shared cluster.
    pub peak_concurrency: usize,
    /// Jobs that had to wait for a completion before getting containers
    /// (the contention observable).
    pub waited_for_capacity: usize,
}

/// A job whose config is decided but whose containers are not granted
/// yet (the cluster was full at request time).
struct WaitingJob {
    app_id: u64,
    truth_id: u32,
    mix: crate::workloadgen::Mix,
    config: TuningConfig,
    decided_at: f64,
    waited: bool,
}

struct RunningJob {
    app_id: u64,
    truth_id: u32,
    mix: crate::workloadgen::Mix,
    config: TuningConfig,
    containers: Vec<u64>,
    start: f64,
    end: f64,
    /// Scheduled preemption event (fault layer), strictly inside
    /// `(start, end)`; cleared once it fires.
    preempt_at: Option<f64>,
}

struct TenantState {
    queue: VecDeque<JobSpec>,
    /// Earliest time the tenant's next job may start.
    ready_at: f64,
    /// End of this tenant's last emitted sample range.
    last_emit: f64,
    waiting: Option<WaitingJob>,
    running: Option<RunningJob>,
    rng: Rng,
}

/// The K-stream discrete-event engine.
pub struct MultiClusterEngine {
    pub config: MultiEngineConfig,
    rm: ResourceManager,
    tenants: BTreeMap<TenantId, TenantState>,
    next_app: u64,
    /// Round-robin rotation for start attempts (fairness tie-break).
    rotation: usize,
    seed: u64,
    /// Fault injection (inert by default: no draws, no perturbation).
    faults: FaultLayer,
}

impl MultiClusterEngine {
    pub fn new(
        rm: ResourceManager,
        config: MultiEngineConfig,
        seed: u64,
    ) -> MultiClusterEngine {
        MultiClusterEngine {
            config,
            rm,
            tenants: BTreeMap::new(),
            next_app: 0,
            rotation: 0,
            seed,
            faults: FaultLayer::inert(),
        }
    }

    /// Arm a fault plan for the next run. The fault RNG is forked off
    /// the engine seed, so the same seed + plan reproduce the same
    /// faults sample-for-sample.
    pub fn set_faults(&mut self, plan: FaultPlan) {
        self.faults = FaultLayer::new(plan, self.seed);
    }

    /// What the fault layer actually injected — ground truth for the
    /// chaos scoreboard.
    pub fn fault_report(&self) -> &FaultReport {
        &self.faults.report
    }

    /// Append jobs to tenant `t`'s queue (creating the tenant if new).
    pub fn push_jobs(&mut self, t: TenantId, jobs: &[JobSpec]) {
        let seed = self.seed;
        let stagger = self.config.start_stagger;
        let state = self.tenants.entry(t).or_insert_with(|| TenantState {
            queue: VecDeque::new(),
            ready_at: stagger * t.0 as f64,
            last_emit: stagger * t.0 as f64,
            waiting: None,
            running: None,
            rng: Rng::new(seed ^ (0x9E37_79B9_7F4A_7C15u64
                .wrapping_mul(t.0 as u64 + 1))),
        });
        state.queue.extend(jobs.iter().copied());
    }

    /// Append jobs that arrive at `arrival` (flash-crowd bursts): the
    /// tenant's stream opens no earlier than `arrival`. For an existing
    /// tenant the arrival can only push its next start later, never
    /// earlier.
    pub fn push_jobs_at(&mut self, t: TenantId, jobs: &[JobSpec], arrival: f64) {
        self.push_jobs(t, jobs);
        let state = self.tenants.get_mut(&t).unwrap();
        state.ready_at = state.ready_at.max(arrival);
        state.last_emit = state.last_emit.max(arrival);
    }

    /// Tenant ids in rotated round-robin order for this scheduling pass.
    fn rotated_ids(&self) -> Vec<TenantId> {
        let ids: Vec<TenantId> = self.tenants.keys().copied().collect();
        let k = ids.len();
        if k == 0 {
            return ids;
        }
        let r = self.rotation % k;
        ids[r..].iter().chain(ids[..r].iter()).copied().collect()
    }

    /// Run every queued job of every tenant to completion.
    pub fn run(&mut self, hub: &mut dyn TenantRmPlugin) -> MultiSimResult {
        let cat = catalog();
        let n_pure = num_pure_classes();
        let mut result = MultiSimResult::default();
        for &t in self.tenants.keys() {
            result.per_tenant.insert(t, TenantSimLog::default());
        }
        let mut now = 0.0f64;

        loop {
            // ---- churn phase: departing tenants tear down their streams
            for t in self.faults.due_churn(now) {
                self.churn_tenant(t, hub, now);
            }

            // ---- start phase: decide configs for idle, ready tenants
            for t in self.rotated_ids() {
                let state = self.tenants.get_mut(&t).unwrap();
                if state.running.is_some()
                    || state.waiting.is_some()
                    || state.queue.is_empty()
                    || state.ready_at > now + 1e-9
                {
                    continue;
                }
                let spec = state.queue.pop_front().unwrap();
                let truth_id = spec.mix.truth_id(n_pure);
                let log = result.per_tenant.get_mut(&t).unwrap();

                // idle-gap samples up to now (capped noise floor)
                let period = self.config.engine.sample_period;
                let idle_from = state
                    .last_emit
                    .max(now - self.config.max_idle_samples as f64 * period);
                if idle_from < now {
                    let mut buf = Vec::new();
                    emit_idle(&mut buf, idle_from, now, period, &mut state.rng);
                    hub.on_samples(t, &buf);
                    log.samples.extend(buf);
                }

                // identification prefix: the job's signature streams in
                // before the RM responds (the lead-in the plug-in's
                // context read depends on)
                let decision_time = now + self.config.prefix_secs;
                let mut prefix = Vec::new();
                // ramp in only: the body continues this job, so the
                // prefix/body split must not look like a transition
                emit_job(
                    &mut prefix,
                    &cat,
                    spec.mix,
                    truth_id,
                    now,
                    decision_time,
                    period,
                    (true, false),
                    &mut state.rng,
                );
                state.last_emit = decision_time;
                self.faults.transform_samples(t, &mut prefix);
                hub.on_samples(t, &prefix);
                result.per_tenant.get_mut(&t).unwrap().samples.extend(prefix);

                // plug-in interception point
                let app_id = self.next_app;
                self.next_app += 1;
                let req = ResourceRequest { app_id, time: decision_time };
                let config = hub.on_resource_request(t, &req);
                let state = self.tenants.get_mut(&t).unwrap();
                state.waiting = Some(WaitingJob {
                    app_id,
                    truth_id,
                    mix: spec.mix,
                    config,
                    decided_at: decision_time,
                    waited: false,
                });
                self.rotation += 1;
            }

            // ---- grant phase: give waiting jobs whatever fleet fits
            for t in self.rotated_ids() {
                self.try_grant(t, now, hub, &mut result);
            }

            // ---- next event
            let mut next = f64::INFINITY;
            for state in self.tenants.values() {
                if let Some(r) = &state.running {
                    next = next.min(r.end);
                    if let Some(p) = r.preempt_at {
                        next = next.min(p);
                    }
                }
                if state.running.is_none()
                    && state.waiting.is_none()
                    && !state.queue.is_empty()
                    && state.ready_at > now + 1e-9
                {
                    next = next.min(state.ready_at);
                }
            }
            if let Some(c) = self.faults.next_churn_at() {
                if c > now + 1e-9 {
                    next = next.min(c);
                }
            }
            if !next.is_finite() {
                break;
            }
            now = next;

            // ---- preemption phase: scheduled container losses fire
            let preempted: Vec<TenantId> = self
                .tenants
                .iter()
                .filter(|(_, s)| {
                    s.running
                        .as_ref()
                        .and_then(|r| r.preempt_at)
                        .map(|p| p <= now + 1e-9)
                        .unwrap_or(false)
                })
                .map(|(t, _)| *t)
                .collect();
            for t in preempted {
                self.preempt(t, hub, now);
            }

            // ---- completion phase
            let due: Vec<TenantId> = self
                .tenants
                .iter()
                .filter(|(_, s)| {
                    s.running
                        .as_ref()
                        .map(|r| r.end <= now + 1e-9)
                        .unwrap_or(false)
                })
                .map(|(t, _)| *t)
                .collect();
            for t in due {
                self.complete(t, hub, &cat, &mut result);
            }
        }

        result.makespan = result
            .per_tenant
            .values()
            .flat_map(|l| l.jobs.iter())
            .map(|j| j.start + j.duration)
            .fold(0.0, f64::max);
        result
    }

    /// Try to grant a waiting job its fleet; on success the job starts.
    fn try_grant(
        &mut self,
        t: TenantId,
        now: f64,
        hub: &mut dyn TenantRmPlugin,
        result: &mut MultiSimResult,
    ) {
        let state = self.tenants.get_mut(&t).unwrap();
        let Some(w) = state.waiting.take() else { return };
        let desired = w.config.num_executors.max(1);
        let mut granted = self.rm.allocate_up_to(
            desired,
            w.config.executor_cores,
            w.config.executor_mem_mb,
        );
        if granted.is_empty() && self.rm.live_containers() == 0 {
            // pathological shape on an empty cluster (a container bigger
            // than any node): run minimally degraded rather than
            // deadlock the stream. Size the fallback container to the
            // largest node so it fits *any* non-empty cluster; a silent
            // never-run job (leaking the plug-in's outstanding probe)
            // is worse than failing loudly here.
            let (cores, mem) = self
                .rm
                .nodes()
                .iter()
                .fold((0u32, 0u32), |(c, m), n| {
                    (c.max(n.cores), m.max(n.mem_mb))
                });
            let c = self
                .rm
                .allocate(1.min(cores), 1024.min(mem))
                .unwrap_or_else(|e| {
                    panic!(
                        "empty cluster cannot fit even a minimal \
                         container for app {}: {e}",
                        w.app_id
                    )
                });
            granted.push(c);
        }
        if granted.is_empty() {
            // cluster full: queue at the RM until a completion
            state.waiting = Some(WaitingJob { waited: true, ..w });
            return;
        }
        if w.waited {
            result.waited_for_capacity += 1;
        }
        hub.on_grant(t, w.app_id, granted.len() as u32);
        // the job runs with the granted fleet: contention prices itself
        // through the perf model's view of a smaller executor count —
        // and noisy-neighbor interference shrinks that view further
        // without releasing any container
        let eff_execs =
            self.faults.effective_executors(now, granted.len() as u32);
        let effective = TuningConfig {
            num_executors: eff_execs,
            ..w.config
        };
        let base = job_duration(w.truth_id, &effective)
            * self.faults.straggler_slowdown(granted.len());
        let noise =
            1.0 + self.config.engine.duration_noise * state.rng.normal();
        let duration = base * noise.max(0.5);
        let start = now.max(w.decided_at);
        let end = start + duration;
        state.running = Some(RunningJob {
            app_id: w.app_id,
            truth_id: w.truth_id,
            mix: w.mix,
            config: w.config,
            containers: granted.iter().map(|c| c.id).collect(),
            start,
            end,
            preempt_at: self.faults.schedule_preemption(start, end),
        });
        let running = self.tenants.values().filter(|s| s.running.is_some()).count();
        result.peak_concurrency = result.peak_concurrency.max(running);
    }

    /// A scheduled preemption fires: kill part of the job's fleet,
    /// release those containers, and ask the RM to re-grant
    /// replacements under whatever pressure the cluster is under *now*.
    /// Survivors finish the remaining work on the new fleet, paying the
    /// restart penalty; a total loss with nothing re-granted fails the
    /// job (requeued until the plan's budget runs out).
    fn preempt(&mut self, t: TenantId, hub: &mut dyn TenantRmPlugin, now: f64) {
        let state = self.tenants.get_mut(&t).unwrap();
        let r = state.running.as_mut().expect("no running job to preempt");
        r.preempt_at = None;
        let kill = self.faults.preempt_kill_count(r.containers.len());
        let killed: Vec<u64> =
            r.containers.split_off(r.containers.len() - kill);
        self.faults.report.preemptions += 1;
        self.faults.report.containers_preempted += killed.len();
        for id in &killed {
            self.rm.release(*id).expect("preempted container double-release");
        }
        let regrant = if self.faults.regrant_denied() {
            Vec::new()
        } else {
            self.rm.allocate_up_to(
                killed.len() as u32,
                r.config.executor_cores,
                r.config.executor_mem_mb,
            )
        };
        self.faults.report.regrants += regrant.len();
        r.containers.extend(regrant.iter().map(|c| c.id));
        if r.containers.is_empty() {
            // every container lost and the RM has nothing: the job dies
            let dead = state.running.take().unwrap();
            state.ready_at = now + self.config.engine.inter_job_gap;
            self.faults.report.jobs_failed += 1;
            if self.faults.allow_requeue(t) {
                state.queue.push_front(JobSpec { mix: dead.mix });
                self.faults.report.jobs_requeued += 1;
            } else {
                self.faults.report.jobs_dropped += 1;
            }
            hub.on_app_fail(t, dead.app_id, now);
            return;
        }
        // remaining work re-priced on the shrunken fleet
        let rem_frac = ((r.end - now) / (r.end - r.start)).clamp(0.0, 1.0);
        let effective = TuningConfig {
            num_executors: r.containers.len() as u32,
            ..r.config
        };
        let remaining = rem_frac
            * job_duration(r.truth_id, &effective)
            * self.faults.restart_penalty();
        r.end = now + remaining.max(1.0);
    }

    /// A churn event fires: the tenant disconnects. Its queue is
    /// dropped, its running job is killed (containers released, no
    /// record), and any decision-pending job fails so the tuning plane
    /// can write off the probe riding on it.
    fn churn_tenant(
        &mut self,
        t: TenantId,
        hub: &mut dyn TenantRmPlugin,
        now: f64,
    ) {
        let Some(state) = self.tenants.get_mut(&t) else { return };
        self.faults.report.jobs_dropped += state.queue.len();
        state.queue.clear();
        let waiting = state.waiting.take();
        let running = state.running.take();
        if let Some(w) = waiting {
            self.faults.report.jobs_failed += 1;
            hub.on_app_fail(t, w.app_id, now);
        }
        if let Some(r) = running {
            for id in &r.containers {
                self.rm.release(*id).expect("churned container double-release");
            }
            self.faults.report.jobs_failed += 1;
            hub.on_app_fail(t, r.app_id, now);
        }
    }

    /// Finish tenant `t`'s running job: release containers, emit the
    /// body metrics, fire the completion callback, record the job.
    fn complete(
        &mut self,
        t: TenantId,
        hub: &mut dyn TenantRmPlugin,
        cat: &[crate::workloadgen::WorkloadClass],
        result: &mut MultiSimResult,
    ) {
        let state = self.tenants.get_mut(&t).unwrap();
        let r = state.running.take().expect("no running job to complete");
        for id in &r.containers {
            self.rm.release(*id).expect("container double-release");
        }
        let period = self.config.engine.sample_period;
        let body_end = r
            .end
            .min(r.start + self.config.max_job_samples as f64 * period);
        let mut body = Vec::new();
        // ramp out only: the prefix already ramped this job in
        emit_job(
            &mut body,
            cat,
            r.mix,
            r.truth_id,
            r.start,
            body_end,
            period,
            (false, true),
            &mut state.rng,
        );
        state.last_emit = body_end;
        state.ready_at = r.end + self.config.engine.inter_job_gap;
        self.faults.transform_samples(t, &mut body);
        hub.on_samples(t, &body);
        let duration = r.end - r.start;
        hub.on_app_complete(t, r.app_id, duration, r.end);
        let log = result.per_tenant.get_mut(&t).unwrap();
        log.samples.extend(body);
        log.jobs.push(JobRecord {
            app_id: r.app_id,
            truth_id: r.truth_id,
            config: r.config,
            start: r.start,
            duration,
        });
    }

    /// RM accounting access (tests assert invariants after a run).
    pub fn rm(&self) -> &ResourceManager {
        &self.rm
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simcluster::config_space::{default_config_index, ConfigIndex};
    use crate::workloadgen::Mix;

    struct CountingHub {
        cfg: TuningConfig,
        requests: Vec<(TenantId, u64, f64)>,
        completions: Vec<(TenantId, u64, f64)>,
        fails: Vec<(TenantId, u64)>,
        grants: Vec<(u64, u32)>,
        samples: BTreeMap<TenantId, usize>,
    }

    impl CountingHub {
        fn new(cfg: TuningConfig) -> CountingHub {
            CountingHub {
                cfg,
                requests: Vec::new(),
                completions: Vec::new(),
                fails: Vec::new(),
                grants: Vec::new(),
                samples: BTreeMap::new(),
            }
        }
    }

    impl TenantRmPlugin for CountingHub {
        fn on_samples(&mut self, t: TenantId, samples: &[Sample]) {
            *self.samples.entry(t).or_insert(0) += samples.len();
        }
        fn on_resource_request(
            &mut self,
            t: TenantId,
            req: &ResourceRequest,
        ) -> TuningConfig {
            self.requests.push((t, req.app_id, req.time));
            self.cfg
        }
        fn on_app_complete(
            &mut self,
            t: TenantId,
            app_id: u64,
            duration: f64,
            _now: f64,
        ) {
            self.completions.push((t, app_id, duration));
        }
        fn on_grant(&mut self, _t: TenantId, app_id: u64, granted: u32) {
            self.grants.push((app_id, granted));
        }
        fn on_app_fail(&mut self, t: TenantId, app_id: u64, _now: f64) {
            self.fails.push((t, app_id));
        }
    }

    fn jobs(classes: &[u32]) -> Vec<JobSpec> {
        classes.iter().map(|&c| JobSpec { mix: Mix::Pure(c) }).collect()
    }

    fn engine_with(tenant_jobs: &[(u32, Vec<JobSpec>)]) -> MultiClusterEngine {
        let mut e = MultiClusterEngine::new(
            ResourceManager::default_cluster(),
            MultiEngineConfig::default(),
            42,
        );
        for (t, js) in tenant_jobs {
            e.push_jobs(TenantId(*t), js);
        }
        e
    }

    #[test]
    fn k_streams_run_concurrently_and_complete() {
        let per_tenant = jobs(&[0, 5, 3]);
        let mut e = engine_with(&[
            (0, per_tenant.clone()),
            (1, per_tenant.clone()),
            (2, per_tenant.clone()),
            (3, per_tenant.clone()),
        ]);
        let mut hub = CountingHub::new(default_config_index().to_config());
        let r = e.run(&mut hub);

        assert_eq!(hub.requests.len(), 12);
        assert_eq!(hub.completions.len(), 12);
        assert_eq!(r.per_tenant.len(), 4);
        for (t, log) in &r.per_tenant {
            assert_eq!(log.jobs.len(), 3, "{t}");
            assert!(*hub.samples.get(t).unwrap() > 0, "{t} got no samples");
            // per-tenant sample times are non-decreasing (a tenant's
            // stream is a single coherent timeline)
            assert!(
                log.samples.windows(2).all(|p| p[0].time <= p[1].time),
                "{t} stream went backwards"
            );
            // jobs are serial per tenant
            for pair in log.jobs.windows(2) {
                assert!(
                    pair[1].start >= pair[0].start + pair[0].duration - 1e-9,
                    "{t} overlapped its own jobs"
                );
            }
        }
        // different tenants overlapped on the shared cluster
        assert!(r.peak_concurrency >= 2, "never concurrent: {r:?}");
        // everything released
        assert_eq!(e.rm().live_containers(), 0);
        assert_eq!(e.rm().used_resources(), (0, 0));
        assert!(r.makespan > 0.0);
    }

    #[test]
    fn oversized_fleets_contend_and_wait_fairly() {
        // 24 executors x 5 cores = 120 cores on a 64-core cluster: every
        // job wants more than half the cluster, so streams must wait for
        // each other's completions — and still all finish
        let big = ConfigIndex([2, 4, 5, 3, 3, 0]).to_config();
        assert_eq!(big.num_executors, 24);
        let per_tenant = jobs(&[9, 9]);
        let mut e = engine_with(&[
            (0, per_tenant.clone()),
            (1, per_tenant.clone()),
            (2, per_tenant.clone()),
        ]);
        let mut hub = CountingHub::new(big);
        let r = e.run(&mut hub);
        assert_eq!(hub.completions.len(), 6);
        assert!(
            r.waited_for_capacity > 0,
            "nothing ever waited: {r:?}"
        );
        assert_eq!(e.rm().live_containers(), 0);
        // no tenant starved: every tenant finished both jobs
        for log in r.per_tenant.values() {
            assert_eq!(log.jobs.len(), 2);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let run = || {
            let mut e = engine_with(&[
                (0, jobs(&[0, 2])),
                (1, jobs(&[5, 3])),
            ]);
            let mut hub =
                CountingHub::new(default_config_index().to_config());
            let r = e.run(&mut hub);
            let durs: Vec<f64> = r
                .per_tenant
                .values()
                .flat_map(|l| l.jobs.iter().map(|j| j.duration))
                .collect();
            (r.makespan, durs)
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn contention_slows_jobs_versus_solo_run() {
        // same stream solo vs alongside three contending tenants asking
        // for large fleets: the shared-cluster copy must not be faster
        let big = ConfigIndex([2, 3, 5, 3, 3, 0]).to_config();
        let solo = {
            let mut e = engine_with(&[(0, jobs(&[2, 2]))]);
            let mut hub = CountingHub::new(big);
            let r = e.run(&mut hub);
            r.per_tenant[&TenantId(0)]
                .jobs
                .iter()
                .map(|j| j.duration)
                .sum::<f64>()
        };
        let contended = {
            let mut e = engine_with(&[
                (0, jobs(&[2, 2])),
                (1, jobs(&[2, 2])),
                (2, jobs(&[2, 2])),
                (3, jobs(&[2, 2])),
            ]);
            let mut hub = CountingHub::new(big);
            let r = e.run(&mut hub);
            r.per_tenant[&TenantId(0)]
                .jobs
                .iter()
                .map(|j| j.duration)
                .sum::<f64>()
        };
        assert!(
            contended >= solo * 0.95,
            "contended {contended} faster than solo {solo}"
        );
    }

    #[test]
    fn inert_fault_plan_changes_nothing() {
        // arming FaultPlan::default() must be a bit-identical no-op —
        // the fault layer draws no RNG when every fault is off
        let run = |armed: bool| {
            let mut e =
                engine_with(&[(0, jobs(&[0, 2])), (1, jobs(&[5, 3]))]);
            if armed {
                e.set_faults(crate::simcluster::FaultPlan::default());
            }
            let mut hub =
                CountingHub::new(default_config_index().to_config());
            let r = e.run(&mut hub);
            let durs: Vec<f64> = r
                .per_tenant
                .values()
                .flat_map(|l| l.jobs.iter().map(|j| j.duration))
                .collect();
            (r.makespan, durs)
        };
        assert_eq!(run(false), run(true));
    }

    #[test]
    fn stragglers_slow_the_run_deterministically() {
        use crate::simcluster::{FaultPlan, StragglerFault};
        let run = |plan: Option<FaultPlan>| {
            let mut e = engine_with(&[(0, jobs(&[2, 2])), (1, jobs(&[4, 4]))]);
            if let Some(p) = plan {
                e.set_faults(p);
            }
            let mut hub =
                CountingHub::new(default_config_index().to_config());
            let r = e.run(&mut hub);
            (r.makespan, e.fault_report().straggler_jobs)
        };
        let slow = FaultPlan {
            stragglers: Some(StragglerFault { prob: 0.9, slowdown: 3.0 }),
            ..Default::default()
        };
        let (base_makespan, _) = run(None);
        let (slow_makespan, straggled) = run(Some(slow.clone()));
        assert!(straggled > 0, "no job ever straggled at p=0.9");
        assert!(
            slow_makespan > base_makespan * 1.1,
            "stragglers didn't stretch the run: {slow_makespan} vs {base_makespan}"
        );
        assert_eq!(run(Some(slow.clone())), run(Some(slow)), "not deterministic");
    }

    #[test]
    fn preemption_refits_or_fails_jobs_and_frees_everything() {
        use crate::simcluster::{FaultPlan, PreemptionFault};
        let plan = FaultPlan {
            preemption: Some(PreemptionFault {
                prob: 1.0,
                kill_frac: 1.0,
                restart_penalty: 1.5,
                regrant_denied_prob: 0.6,
            }),
            max_requeues: 2,
            ..Default::default()
        };
        // big fleets on a small cluster: replacements are scarce, so
        // total-loss preemptions (kill_frac 1.0) can genuinely fail
        let big = ConfigIndex([2, 3, 5, 3, 3, 0]).to_config();
        let mut e = engine_with(&[
            (0, jobs(&[2, 2])),
            (1, jobs(&[2, 2])),
            (2, jobs(&[2, 2])),
        ]);
        e.set_faults(plan);
        let mut hub = CountingHub::new(big);
        let r = e.run(&mut hub);
        let rep = *e.fault_report();
        assert!(rep.preemptions > 0, "p=1.0 never preempted: {rep:?}");
        assert!(rep.containers_preempted >= rep.preemptions);
        // every decided app resolved exactly once: completed or failed
        assert_eq!(
            hub.completions.len() + hub.fails.len(),
            hub.requests.len(),
            "an app vanished without completion or failure: {rep:?}"
        );
        assert_eq!(rep.jobs_failed, hub.fails.len());
        // failures were either requeued or dropped, never lost silently
        assert_eq!(rep.jobs_failed, rep.jobs_requeued + rep.jobs_dropped);
        // the RM ends clean whatever the fault layer did
        assert_eq!(e.rm().live_containers(), 0);
        assert_eq!(e.rm().used_resources(), (0, 0));
        e.rm().check_invariants();
        // completed jobs never overlap within a tenant even after
        // preemption stretched their ends
        for log in r.per_tenant.values() {
            for pair in log.jobs.windows(2) {
                assert!(
                    pair[1].start >= pair[0].start + pair[0].duration - 1e-9
                );
            }
        }
    }

    #[test]
    fn churn_kills_the_tenant_stream_and_notifies() {
        use crate::simcluster::{ChurnEvent, FaultPlan};
        let plan = FaultPlan {
            churn: vec![ChurnEvent { tenant: TenantId(1), at: 100.0 }],
            ..Default::default()
        };
        let mut e = engine_with(&[
            (0, jobs(&[0, 2, 4])),
            (1, jobs(&[0, 2, 4])),
        ]);
        e.set_faults(plan);
        let mut hub = CountingHub::new(default_config_index().to_config());
        let r = e.run(&mut hub);
        let rep = *e.fault_report();
        assert_eq!(rep.tenants_churned, 1);
        // the surviving tenant finished everything
        assert_eq!(r.per_tenant[&TenantId(0)].jobs.len(), 3);
        // the churned tenant lost at least its in-flight job
        assert!(r.per_tenant[&TenantId(1)].jobs.len() < 3);
        assert!(
            hub.fails.iter().any(|(t, _)| *t == TenantId(1)),
            "no failure callback for the churned tenant's in-flight app"
        );
        assert_eq!(e.rm().live_containers(), 0, "churn leaked containers");
        e.rm().check_invariants();
    }

    #[test]
    fn flash_crowd_arrivals_start_no_earlier_than_staged() {
        let mut e = engine_with(&[(0, jobs(&[0, 2]))]);
        e.push_jobs_at(TenantId(7), &jobs(&[4, 4]), 500.0);
        let mut hub = CountingHub::new(default_config_index().to_config());
        let r = e.run(&mut hub);
        assert_eq!(r.per_tenant[&TenantId(7)].jobs.len(), 2);
        let first = &r.per_tenant[&TenantId(7)].jobs[0];
        assert!(
            first.start >= 500.0,
            "flash-crowd job started at {} before its arrival",
            first.start
        );
    }

    #[test]
    fn minimal_grant_fallback_serializes_without_deadlock() {
        // nodes too small for the asked container shape: every job runs
        // through the minimal-grant fallback, one tenant at a time, and
        // the K queued streams still all finish
        let tiny = ResourceManager::new(vec![
            crate::simcluster::NodeSpec { cores: 2, mem_mb: 2048 },
            crate::simcluster::NodeSpec { cores: 2, mem_mb: 2048 },
        ]);
        let cfg = ConfigIndex([2, 3, 4, 3, 3, 0]).to_config();
        assert!(cfg.executor_cores > 2, "ask must exceed any node");
        let mut e = MultiClusterEngine::new(
            tiny,
            MultiEngineConfig::default(),
            42,
        );
        for k in 0..3u32 {
            e.push_jobs(TenantId(k), &jobs(&[1, 1]));
        }
        let mut hub = CountingHub::new(cfg);
        let r = e.run(&mut hub);
        assert_eq!(hub.completions.len(), 6, "a stream deadlocked");
        // the fallback grants exactly one minimal container per job
        assert!(hub.grants.iter().all(|(_, g)| *g == 1));
        // with one job running at a time, later tenants stalled
        assert!(
            r.waited_for_capacity >= 2,
            "stalled grants unaccounted: {r:?}"
        );
        assert_eq!(e.rm().live_containers(), 0);
        e.rm().check_invariants();
    }

    #[test]
    fn waited_for_capacity_accounts_every_stalled_grant() {
        // one 16-core node and 4-core containers: exactly four fit, so
        // one tenant's fleet hogs the whole node and the other streams'
        // grants stall until completions free it
        let one_node = ResourceManager::new(vec![
            crate::simcluster::NodeSpec { cores: 16, mem_mb: 24_576 },
        ]);
        let big = ConfigIndex([2, 3, 4, 3, 3, 0]).to_config();
        let mut e = MultiClusterEngine::new(
            one_node,
            MultiEngineConfig::default(),
            42,
        );
        for k in 0..3u32 {
            e.push_jobs(TenantId(k), &jobs(&[2, 2]));
        }
        let mut hub = CountingHub::new(big);
        let r = e.run(&mut hub);
        assert_eq!(hub.completions.len(), 6);
        // every job whose start is later than its decision time was
        // stalled behind a full cluster — waited_for_capacity must
        // account each one (it may also count jobs re-granted within
        // their identification prefix, hence >=)
        let stalled: usize = r
            .per_tenant
            .values()
            .flat_map(|l| l.jobs.iter())
            .filter(|j| {
                let req = hub
                    .requests
                    .iter()
                    .find(|(_, id, _)| *id == j.app_id)
                    .map(|(_, _, time)| *time)
                    .unwrap();
                j.start > req + 1e-6
            })
            .count();
        assert!(stalled > 0, "contended run never stalled a start");
        assert!(
            r.waited_for_capacity >= stalled,
            "waited_for_capacity {} misses stalled grants {}",
            r.waited_for_capacity,
            stalled
        );
        assert!(r.waited_for_capacity <= hub.requests.len());
        assert_eq!(e.rm().live_containers(), 0);
    }

    #[test]
    fn decision_comes_after_prefix_and_before_body() {
        let mut e = engine_with(&[(0, jobs(&[4]))]);
        let mut hub = CountingHub::new(default_config_index().to_config());
        let r = e.run(&mut hub);
        let (_, _, req_time) = hub.requests[0];
        let job = &r.per_tenant[&TenantId(0)].jobs[0];
        // request fired exactly at the end of the identification prefix
        assert!((req_time - e.config.prefix_secs).abs() < 1e-9);
        // the job body starts at the decision, never before
        assert!(job.start >= req_time - 1e-9);
        // prefix samples precede the decision time
        let first = r.per_tenant[&TenantId(0)].samples.first().unwrap();
        assert!(first.time < req_time);
    }
}
