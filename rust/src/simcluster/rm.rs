//! YARN-like resource manager: nodes, container accounting, and the
//! plug-in interception point.
//!
//! The paper's integration model ([16], §6.4): "The KERMIT plug-in code
//! is called whenever the resource manager responds to a resource request
//! from an analytic framework" — the RM exposes exactly that hook here
//! via the [`RmPlugin`] trait. A no-op plugin reproduces an untuned
//! cluster; the KERMIT plug-in (in `online::plugin`) implements
//! Algorithm 1.

use super::config_space::TuningConfig;
use std::collections::BTreeMap;

/// One worker node's capacity.
#[derive(Debug, Clone, Copy)]
pub struct NodeSpec {
    pub cores: u32,
    pub mem_mb: u32,
}

/// A granted container.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Container {
    pub id: u64,
    pub node: usize,
    pub cores: u32,
    pub mem_mb: u32,
}

/// A resource request from an analytic framework (one job's executor
/// ask, shaped by the tuning config the plug-in selects).
#[derive(Debug, Clone, Copy)]
pub struct ResourceRequest {
    pub app_id: u64,
    /// Time of the request (simulated seconds).
    pub time: f64,
}

/// The plug-in hook: given the request, return the tuning configuration
/// the RM should apply to this application's containers.
pub trait RmPlugin {
    fn on_resource_request(&mut self, req: &ResourceRequest) -> TuningConfig;

    /// Called when the application completes with its measured duration —
    /// the feedback edge of the autonomic loop.
    fn on_app_complete(&mut self, _app_id: u64, _duration: f64, _time: f64) {}
}

/// A plug-in that always returns a fixed configuration (default-config
/// and rule-of-thumb baselines).
pub struct FixedConfigPlugin(pub TuningConfig);

impl RmPlugin for FixedConfigPlugin {
    fn on_resource_request(&mut self, _req: &ResourceRequest) -> TuningConfig {
        self.0
    }
}

#[derive(Debug, PartialEq)]
pub enum RmError {
    WontFit { cores: u32, mem_mb: u32 },
    UnknownContainer(u64),
}

impl std::fmt::Display for RmError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RmError::WontFit { cores, mem_mb } => write!(
                f,
                "no node can fit a container of {cores} cores / {mem_mb} MB"
            ),
            RmError::UnknownContainer(id) => {
                write!(f, "unknown container {id}")
            }
        }
    }
}

impl std::error::Error for RmError {}

/// Container-level accounting for a static set of nodes.
#[derive(Debug)]
pub struct ResourceManager {
    nodes: Vec<NodeSpec>,
    used: Vec<(u32, u32)>, // (cores, mem) in use per node
    live: BTreeMap<u64, Container>,
    next_id: u64,
}

impl ResourceManager {
    pub fn new(nodes: Vec<NodeSpec>) -> ResourceManager {
        let used = vec![(0, 0); nodes.len()];
        ResourceManager { nodes, used, live: BTreeMap::new(), next_id: 0 }
    }

    /// The 4-node cluster matching `perfmodel::CLUSTER_*`.
    pub fn default_cluster() -> ResourceManager {
        ResourceManager::new(vec![
            NodeSpec { cores: 16, mem_mb: 24_576 };
            4
        ])
    }

    /// The static node set (the multi-tenant engine's degraded-grant
    /// fallback sizes a minimal container from it).
    pub fn nodes(&self) -> &[NodeSpec] {
        &self.nodes
    }

    pub fn total_capacity(&self) -> (u32, u32) {
        self.nodes
            .iter()
            .fold((0, 0), |(c, m), n| (c + n.cores, m + n.mem_mb))
    }

    pub fn used_resources(&self) -> (u32, u32) {
        self.used
            .iter()
            .fold((0, 0), |(c, m), &(uc, um)| (c + uc, m + um))
    }

    /// Allocate one container with best-fit (most-loaded node that still
    /// fits, to reduce fragmentation).
    pub fn allocate(&mut self, cores: u32, mem_mb: u32) -> Result<Container, RmError> {
        let mut best: Option<(usize, u32)> = None; // (node, free_cores_after)
        for (i, node) in self.nodes.iter().enumerate() {
            let (uc, um) = self.used[i];
            if uc + cores <= node.cores && um + mem_mb <= node.mem_mb {
                let free_after = node.cores - uc - cores;
                if best.map(|(_, f)| free_after < f).unwrap_or(true) {
                    best = Some((i, free_after));
                }
            }
        }
        let (node, _) = best.ok_or(RmError::WontFit { cores, mem_mb })?;
        self.used[node].0 += cores;
        self.used[node].1 += mem_mb;
        let c = Container { id: self.next_id, node, cores, mem_mb };
        self.next_id += 1;
        self.live.insert(c.id, c);
        Ok(c)
    }

    /// Allocate as many of `count` identical containers as fit; returns
    /// the granted set (possibly shorter — the caller decides whether to
    /// run degraded or queue, as YARN apps do).
    pub fn allocate_up_to(
        &mut self,
        count: u32,
        cores: u32,
        mem_mb: u32,
    ) -> Vec<Container> {
        let mut out = Vec::new();
        for _ in 0..count {
            match self.allocate(cores, mem_mb) {
                Ok(c) => out.push(c),
                Err(_) => break,
            }
        }
        out
    }

    pub fn release(&mut self, id: u64) -> Result<(), RmError> {
        let c = self.live.remove(&id).ok_or(RmError::UnknownContainer(id))?;
        self.used[c.node].0 -= c.cores;
        self.used[c.node].1 -= c.mem_mb;
        Ok(())
    }

    pub fn live_containers(&self) -> usize {
        self.live.len()
    }

    /// Accounting invariant: per-node usage equals the sum of live
    /// containers and never exceeds capacity. Exercised by proptests.
    pub fn check_invariants(&self) {
        let mut per_node = vec![(0u32, 0u32); self.nodes.len()];
        for c in self.live.values() {
            per_node[c.node].0 += c.cores;
            per_node[c.node].1 += c.mem_mb;
        }
        for (i, node) in self.nodes.iter().enumerate() {
            assert_eq!(per_node[i], self.used[i], "node {i} usage mismatch");
            assert!(self.used[i].0 <= node.cores, "node {i} cores oversub");
            assert!(self.used[i].1 <= node.mem_mb, "node {i} mem oversub");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocate_and_release_roundtrip() {
        let mut rm = ResourceManager::default_cluster();
        let c = rm.allocate(4, 8192).unwrap();
        assert_eq!(rm.used_resources(), (4, 8192));
        rm.check_invariants();
        rm.release(c.id).unwrap();
        assert_eq!(rm.used_resources(), (0, 0));
        rm.check_invariants();
    }

    #[test]
    fn rejects_oversized_container() {
        let mut rm = ResourceManager::default_cluster();
        assert_eq!(
            rm.allocate(17, 1024),
            Err(RmError::WontFit { cores: 17, mem_mb: 1024 })
        );
        assert_eq!(
            rm.allocate(1, 99_999),
            Err(RmError::WontFit { cores: 1, mem_mb: 99_999 })
        );
    }

    #[test]
    fn fills_cluster_then_stops() {
        let mut rm = ResourceManager::default_cluster();
        // 16 containers of 4 cores = 64 cores: exactly fills
        let got = rm.allocate_up_to(20, 4, 4096);
        assert_eq!(got.len(), 16);
        rm.check_invariants();
        // all 64 cores are in use: nothing else fits
        assert!(rm.allocate(1, 1024).is_err());
    }

    #[test]
    fn cores_exhaustion_blocks() {
        let mut rm = ResourceManager::new(vec![NodeSpec { cores: 2, mem_mb: 4096 }]);
        rm.allocate(2, 1024).unwrap();
        assert!(rm.allocate(1, 1024).is_err());
    }

    #[test]
    fn double_release_errors() {
        let mut rm = ResourceManager::default_cluster();
        let c = rm.allocate(1, 1024).unwrap();
        rm.release(c.id).unwrap();
        assert_eq!(rm.release(c.id), Err(RmError::UnknownContainer(c.id)));
    }

    #[test]
    fn best_fit_packs_tight() {
        let mut rm = ResourceManager::new(vec![
            NodeSpec { cores: 8, mem_mb: 8192 },
            NodeSpec { cores: 8, mem_mb: 8192 },
        ]);
        let a = rm.allocate(6, 1024).unwrap();
        // next small container should pack onto the same node (best fit)
        let b = rm.allocate(2, 1024).unwrap();
        assert_eq!(a.node, b.node);
        rm.check_invariants();
    }
}
