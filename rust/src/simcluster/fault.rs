//! Deterministic fault injection for the multi-tenant simcluster.
//!
//! The chaos lab (`crate::chaoslab`) drives [`MultiClusterEngine`] runs
//! through a [`FaultPlan`]: a seeded, scripted description of everything
//! that can go wrong on a real shared cluster — straggler executors,
//! container preemption mid-job, noisy-neighbor interference, tenant
//! churn, coordinated drift storms. The engine consults a [`FaultLayer`]
//! (the plan plus its runtime state) at well-defined points of the
//! event loop; an inert plan (the default) draws no random numbers and
//! perturbs nothing, so fault-free runs stay bit-identical to the
//! pre-chaos engine.
//!
//! [`MultiClusterEngine`]: crate::simcluster::MultiClusterEngine

use crate::features::TenantId;
use crate::util::rng::Rng;

/// Straggler executors: each granted container independently runs slow
/// with probability `prob`; a job's duration stretches by the straggler
/// fraction of its fleet (tail latency is set by the slowest wave).
#[derive(Debug, Clone, Copy)]
pub struct StragglerFault {
    /// Per-container probability of being a straggler.
    pub prob: f64,
    /// Duration multiplier when the whole fleet straggles; a fleet with
    /// straggler fraction f runs `1 + f * (slowdown - 1)` times longer.
    pub slowdown: f64,
}

/// Container preemption mid-job: with probability `prob` per started
/// job, a preemption event fires strictly inside the job's runtime,
/// kills `kill_frac` of its containers, and asks the RM to re-grant
/// replacements. The job finishes its remaining work on whatever fleet
/// survives, paying `restart_penalty` on the remainder (lost shuffle
/// state, task re-launch). A job that loses every container and gets
/// nothing back from the RM fails outright.
#[derive(Debug, Clone, Copy)]
pub struct PreemptionFault {
    /// Per-job probability of one preemption event.
    pub prob: f64,
    /// Fraction of the job's containers killed (at least one).
    pub kill_frac: f64,
    /// Multiplier on the remaining work after a survived preemption.
    pub restart_penalty: f64,
    /// Probability the RM has *nothing* to re-grant — the preempting
    /// demand kept the freed capacity. (Freed containers would
    /// otherwise be handed straight back, and a total loss could never
    /// actually fail a job.)
    pub regrant_denied_prob: f64,
}

/// Noisy-neighbor interference: inside the `[from, until)` window,
/// co-located work steals an `intensity` fraction of every granted
/// fleet's effective capacity. Containers are still held (the RM
/// accounting is untouched); only the perf-model fleet shrinks.
#[derive(Debug, Clone, Copy)]
pub struct NoisyNeighborFault {
    pub from: f64,
    pub until: f64,
    /// Fraction of effective executors lost, in [0, 1).
    pub intensity: f64,
}

/// Tenant churn: at time `at` the tenant disconnects — its queue is
/// cleared, its running job is killed (containers released, no record),
/// and any decision-pending job fails so the tuning plane is told.
#[derive(Debug, Clone, Copy)]
pub struct ChurnEvent {
    pub tenant: TenantId,
    pub at: f64,
}

/// Coordinated drift storm: from `from + tenant_index * phase_shift`
/// onward, every tenant's job samples drift — feature values scale by
/// `1 + rate * seconds_into_storm` (capped) — so the classifiers see
/// the same workload slide away from its learned centroid on every
/// shard at once, phase-shifted like a rolling config push.
#[derive(Debug, Clone, Copy)]
pub struct DriftStorm {
    pub from: f64,
    /// Per-second multiplicative drift rate on the feature vector.
    pub rate: f64,
    /// Per-tenant onset delay (tenant k starts at `from + k * phase_shift`).
    pub phase_shift: f64,
}

/// A scripted description of what goes wrong during a run. `Default`
/// is completely inert: no faults, no RNG draws, no behavior change.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    pub stragglers: Option<StragglerFault>,
    pub preemption: Option<PreemptionFault>,
    pub noisy_neighbor: Option<NoisyNeighborFault>,
    pub churn: Vec<ChurnEvent>,
    pub drift_storm: Option<DriftStorm>,
    /// Per-tenant budget of job re-queues after a total-loss preemption
    /// failure; past it the job is dropped (and counted).
    pub max_requeues: u32,
}

impl FaultPlan {
    pub fn is_inert(&self) -> bool {
        self.stragglers.is_none()
            && self.preemption.is_none()
            && self.noisy_neighbor.is_none()
            && self.churn.is_empty()
            && self.drift_storm.is_none()
    }
}

/// What the fault layer actually did during a run — the ground truth
/// the chaos scoreboard diffs against plugin/plane-side observations.
#[derive(Debug, Clone, Copy, Default)]
pub struct FaultReport {
    /// Jobs whose fleet contained at least one straggler.
    pub straggler_jobs: usize,
    /// Jobs whose effective fleet was shrunk by interference.
    pub interference_jobs: usize,
    /// Preemption events that fired.
    pub preemptions: usize,
    /// Containers killed by preemption.
    pub containers_preempted: usize,
    /// Replacement containers the RM re-granted after preemption.
    pub regrants: usize,
    /// Jobs that failed outright (total container loss, nothing back).
    pub jobs_failed: usize,
    /// Failed jobs re-queued for another attempt.
    pub jobs_requeued: usize,
    /// Jobs dropped: requeue budget exhausted or churned away.
    pub jobs_dropped: usize,
    /// Churn events that fired.
    pub tenants_churned: usize,
    /// Samples perturbed by the drift storm.
    pub drifted_samples: usize,
}

/// Runtime state of a [`FaultPlan`] over one engine run: the seeded
/// fault RNG, the churn schedule cursor, and per-tenant requeue budgets.
#[derive(Debug, Clone)]
pub struct FaultLayer {
    plan: FaultPlan,
    rng: Rng,
    /// Churn events sorted by time; `churn_fired` marks consumed ones.
    churn: Vec<ChurnEvent>,
    churn_fired: Vec<bool>,
    requeues_used: std::collections::BTreeMap<TenantId, u32>,
    pub report: FaultReport,
}

impl FaultLayer {
    /// An inert layer: injects nothing, draws nothing.
    pub fn inert() -> FaultLayer {
        FaultLayer::new(FaultPlan::default(), 0)
    }

    pub fn new(plan: FaultPlan, seed: u64) -> FaultLayer {
        let mut churn = plan.churn.clone();
        churn.sort_by(|a, b| {
            a.at.partial_cmp(&b.at)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.tenant.0.cmp(&b.tenant.0))
        });
        let n = churn.len();
        FaultLayer {
            plan,
            rng: Rng::new(seed ^ 0xC4A0_51AB_FA17_0000),
            churn,
            churn_fired: vec![false; n],
            requeues_used: std::collections::BTreeMap::new(),
            report: FaultReport::default(),
        }
    }

    pub fn is_inert(&self) -> bool {
        self.plan.is_inert()
    }

    /// Duration multiplier from straggler containers in an `n`-container
    /// fleet. Draws one Bernoulli per container (deterministic in event
    /// order); 1.0 and no draws when the fault is off.
    pub fn straggler_slowdown(&mut self, n: usize) -> f64 {
        let Some(f) = self.plan.stragglers else { return 1.0 };
        let mut stragglers = 0usize;
        for _ in 0..n {
            if self.rng.chance(f.prob) {
                stragglers += 1;
            }
        }
        if stragglers == 0 || n == 0 {
            return 1.0;
        }
        self.report.straggler_jobs += 1;
        let frac = stragglers as f64 / n as f64;
        1.0 + frac * (f.slowdown - 1.0).max(0.0)
    }

    /// Effective executor count after noisy-neighbor interference at
    /// `now`: the perf model prices the shrunken fleet although the RM
    /// still holds every container.
    pub fn effective_executors(&mut self, now: f64, granted: u32) -> u32 {
        let Some(f) = self.plan.noisy_neighbor else { return granted };
        if now < f.from || now >= f.until || granted == 0 {
            return granted;
        }
        let stolen = (granted as f64 * f.intensity).ceil() as u32;
        let eff = granted.saturating_sub(stolen).max(1);
        if eff < granted {
            self.report.interference_jobs += 1;
        }
        eff
    }

    /// Schedule at most one preemption for a job spanning
    /// `[start, end)`, strictly inside its runtime. None when the fault
    /// is off or the draw misses.
    pub fn schedule_preemption(&mut self, start: f64, end: f64) -> Option<f64> {
        let f = self.plan.preemption?;
        if end <= start || !self.rng.chance(f.prob) {
            return None;
        }
        // strictly interior so the event fires before completion
        Some(start + self.rng.range_f64(0.15, 0.85) * (end - start))
    }

    /// How many of `n` containers a firing preemption kills (>= 1).
    pub fn preempt_kill_count(&self, n: usize) -> usize {
        let frac =
            self.plan.preemption.map(|f| f.kill_frac).unwrap_or(0.0);
        ((n as f64 * frac).round() as usize).clamp(1, n)
    }

    /// Does the preempting demand keep the freed capacity? One draw
    /// per firing preemption.
    pub fn regrant_denied(&mut self) -> bool {
        let Some(f) = self.plan.preemption else { return false };
        self.rng.chance(f.regrant_denied_prob)
    }

    pub fn restart_penalty(&self) -> f64 {
        self.plan
            .preemption
            .map(|f| f.restart_penalty.max(1.0))
            .unwrap_or(1.0)
    }

    /// Earliest unfired churn event time, if any.
    pub fn next_churn_at(&self) -> Option<f64> {
        self.churn
            .iter()
            .zip(&self.churn_fired)
            .find(|(_, fired)| !**fired)
            .map(|(e, _)| e.at)
    }

    /// Pop every churn event due at or before `now` (fires each once).
    pub fn due_churn(&mut self, now: f64) -> Vec<TenantId> {
        let mut due = Vec::new();
        for (i, e) in self.churn.iter().enumerate() {
            if !self.churn_fired[i] && e.at <= now + 1e-9 {
                self.churn_fired[i] = true;
                due.push(e.tenant);
            }
        }
        self.report.tenants_churned += due.len();
        due
    }

    /// May tenant `t` requeue one more failed job? Consumes budget.
    pub fn allow_requeue(&mut self, t: TenantId) -> bool {
        let used = self.requeues_used.entry(t).or_insert(0);
        if *used < self.plan.max_requeues {
            *used += 1;
            true
        } else {
            false
        }
    }

    /// Apply the drift storm to a tenant's emitted job samples in
    /// place. Features scale by `1 + rate * seconds_into_storm`, capped
    /// at 3x so the storm stays a drift, not an explosion.
    pub fn transform_samples(
        &mut self,
        t: TenantId,
        samples: &mut [crate::workloadgen::Sample],
    ) {
        let Some(f) = self.plan.drift_storm else { return };
        let onset = f.from + t.0 as f64 * f.phase_shift.max(0.0);
        for s in samples.iter_mut() {
            if s.time < onset {
                continue;
            }
            let scale =
                (1.0 + f.rate * (s.time - onset)).clamp(1.0, 3.0);
            if scale > 1.0 {
                for v in s.features.iter_mut() {
                    *v *= scale;
                }
                self.report.drifted_samples += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inert_layer_is_neutral_and_drawless() {
        let mut layer = FaultLayer::inert();
        let before = layer.rng.clone();
        assert_eq!(layer.straggler_slowdown(8), 1.0);
        assert_eq!(layer.effective_executors(100.0, 8), 8);
        assert_eq!(layer.schedule_preemption(0.0, 100.0), None);
        assert_eq!(layer.next_churn_at(), None);
        assert!(layer.due_churn(1e9).is_empty());
        // no RNG state advanced: fault-free runs stay bit-identical
        let mut a = before;
        assert_eq!(a.next_u64(), layer.rng.clone().next_u64());
        assert_eq!(layer.report.straggler_jobs, 0);
    }

    #[test]
    fn fault_draws_are_seed_deterministic() {
        let plan = FaultPlan {
            stragglers: Some(StragglerFault { prob: 0.3, slowdown: 3.0 }),
            preemption: Some(PreemptionFault {
                prob: 0.5,
                kill_frac: 0.5,
                restart_penalty: 1.2,
                regrant_denied_prob: 0.5,
            }),
            ..Default::default()
        };
        let run = |seed: u64| {
            let mut layer = FaultLayer::new(plan.clone(), seed);
            let slows: Vec<f64> =
                (0..10).map(|_| layer.straggler_slowdown(6)).collect();
            let preempts: Vec<Option<f64>> = (0..10)
                .map(|i| {
                    layer.schedule_preemption(i as f64 * 50.0, i as f64 * 50.0 + 40.0)
                })
                .collect();
            (slows, preempts)
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8), "different seeds gave identical faults");
    }

    #[test]
    fn noisy_neighbor_window_and_floor() {
        let plan = FaultPlan {
            noisy_neighbor: Some(NoisyNeighborFault {
                from: 100.0,
                until: 200.0,
                intensity: 0.5,
            }),
            ..Default::default()
        };
        let mut layer = FaultLayer::new(plan, 1);
        assert_eq!(layer.effective_executors(50.0, 8), 8, "before window");
        assert_eq!(layer.effective_executors(150.0, 8), 4, "inside window");
        assert_eq!(layer.effective_executors(150.0, 1), 1, "floor of one");
        assert_eq!(layer.effective_executors(250.0, 8), 8, "after window");
        assert_eq!(layer.report.interference_jobs, 1);
    }

    #[test]
    fn churn_fires_once_in_time_order() {
        let plan = FaultPlan {
            churn: vec![
                ChurnEvent { tenant: TenantId(2), at: 300.0 },
                ChurnEvent { tenant: TenantId(0), at: 100.0 },
            ],
            ..Default::default()
        };
        let mut layer = FaultLayer::new(plan, 1);
        assert_eq!(layer.next_churn_at(), Some(100.0));
        assert_eq!(layer.due_churn(150.0), vec![TenantId(0)]);
        assert_eq!(layer.next_churn_at(), Some(300.0));
        assert_eq!(layer.due_churn(400.0), vec![TenantId(2)]);
        assert!(layer.due_churn(500.0).is_empty(), "churn refired");
        assert_eq!(layer.report.tenants_churned, 2);
    }

    #[test]
    fn requeue_budget_is_per_tenant() {
        let plan = FaultPlan { max_requeues: 2, ..Default::default() };
        let mut layer = FaultLayer::new(plan, 1);
        assert!(layer.allow_requeue(TenantId(0)));
        assert!(layer.allow_requeue(TenantId(0)));
        assert!(!layer.allow_requeue(TenantId(0)), "budget exceeded");
        assert!(layer.allow_requeue(TenantId(1)), "budgets not shared");
    }

    #[test]
    fn drift_storm_is_phase_shifted_and_capped() {
        use crate::workloadgen::TruthTag;
        let plan = FaultPlan {
            drift_storm: Some(DriftStorm {
                from: 100.0,
                rate: 0.01,
                phase_shift: 50.0,
            }),
            ..Default::default()
        };
        let mut layer = FaultLayer::new(plan, 1);
        let mk = |t: f64| crate::workloadgen::Sample {
            time: t,
            features: [1.0; crate::features::NUM_FEATURES],
            truth: TruthTag::Steady(0),
        };
        let mut s = vec![mk(50.0), mk(150.0), mk(100_000.0)];
        layer.transform_samples(TenantId(1), &mut s);
        // tenant 1's onset is 100 + 50 = 150: first two untouched
        assert_eq!(s[0].features[0], 1.0);
        assert_eq!(s[1].features[0], 1.0);
        assert_eq!(s[2].features[0], 3.0, "cap at 3x");
        assert_eq!(layer.report.drifted_samples, 1);
    }
}
