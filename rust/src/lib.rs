//! # KERMIT — Autonomic Architecture for Big Data Performance Optimization
//!
//! Rust + JAX + Pallas reproduction of Genkin et al. (2023). The crate
//! implements the full MAPE-K autonomic loop: a simulated big-data
//! cluster substrate, the on-line monitoring / change-detection /
//! classification / prediction / tuning pipeline, and the off-line
//! discovery / characterization / training pipeline. ML inference for
//! the NN components executes AOT-compiled XLA artifacts via PJRT
//! (`runtime`); python is never on the request path.
//!
//! See DESIGN.md for the architecture map and EXPERIMENTS.md for the
//! reproduced results.

pub mod benchkit;
pub mod chaoslab;
pub mod clustering;
pub mod coordinator;
pub mod experiments;
pub mod explorer;
pub mod features;
pub mod knowledge;
pub mod linalg;
pub mod ml;
pub mod monitor;
pub mod obs;
pub mod offline;
pub mod online;
pub mod runtime;
pub mod stream;
pub mod testkit;
pub mod simcluster;
pub mod stats;
pub mod tuning;
pub mod util;
pub mod workloadgen;
