//! Workload feature space: observation windows `O_t`, feature vectors
//! `F_t`, analytic windows `A_t`, and the rate-of-change transform `A'_t`
//! used by the TransitionClassifier (paper §7.2 step 5).
//!
//! The feature vector width and ordering here MUST match
//! `python/compile/shapes.py::NUM_FEATURES` — the runtime asserts this
//! against the artifact manifest at startup.

/// Number of container performance counters per observation window.
pub const NUM_FEATURES: usize = 16;

/// Names of the 16 counters, in vector order. These mirror what the
/// KERMIT agents (KAgnt) would scrape from /proc + the resource manager
/// on a real cluster.
pub const FEATURE_NAMES: [&str; NUM_FEATURES] = [
    "cpu_user",
    "cpu_sys",
    "cpu_iowait",
    "mem_used",
    "mem_cache",
    "disk_read",
    "disk_write",
    "net_rx",
    "net_tx",
    "ctx_switches",
    "page_faults",
    "gc_time",
    "task_queue",
    "shuffle_bytes",
    "hdfs_read",
    "hdfs_write",
];

/// A point-in-time feature vector (one aggregated metrics sample).
pub type FeatureVec = [f64; NUM_FEATURES];

/// Identity of one tenant (one application / user whose metric stream
/// flows through its own pipeline shard). Defined here — the shared
/// vocabulary layer — so the monitor and the context stream can tag
/// per-tenant data without depending on the `stream` orchestration
/// layer above them (which re-exports this type). The id is opaque to
/// every algorithm; it only routes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct TenantId(pub u32);

impl std::fmt::Display for TenantId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "tenant-{}", self.0)
    }
}

pub fn zero_features() -> FeatureVec {
    [0.0; NUM_FEATURES]
}

/// Width of the analytic representation (window mean ++ window std).
pub const ANALYTIC_WIDTH: usize = 2 * NUM_FEATURES;

/// Fixed-width analytic feature vector — the widths are static, so the
/// on-line pipeline keeps these on the stack and re-fills them per
/// window instead of allocating a `Vec` per `observe` call.
pub type AnalyticVec = [f64; ANALYTIC_WIDTH];

pub fn zero_analytic() -> AnalyticVec {
    [0.0; ANALYTIC_WIDTH]
}

/// An observation window `O_t`: the aggregation of `samples` raw metric
/// samples over one monitoring interval, with per-feature mean and
/// variance. This is the unit every KERMIT algorithm operates on.
#[derive(Debug, Clone, PartialEq)]
pub struct ObservationWindow {
    /// Monotone window index assigned by the monitor.
    pub index: u64,
    /// Simulated wall-clock time (seconds) at window close.
    pub time: f64,
    /// Number of raw samples aggregated.
    pub samples: usize,
    /// Per-feature mean over the window.
    pub mean: FeatureVec,
    /// Per-feature population variance over the window.
    pub var: FeatureVec,
    /// Ground-truth workload id from the generator (None on a real
    /// cluster; used only for accuracy scoring, never by the algorithms).
    pub truth: Option<u32>,
}

impl ObservationWindow {
    /// Aggregate raw samples into a window. Panics on empty input.
    pub fn aggregate(
        index: u64,
        time: f64,
        samples: &[FeatureVec],
        truth: Option<u32>,
    ) -> ObservationWindow {
        assert!(!samples.is_empty(), "aggregate over empty window");
        let n = samples.len() as f64;
        let mut mean = zero_features();
        let mut var = zero_features();
        for s in samples {
            for (m, x) in mean.iter_mut().zip(s.iter()) {
                *m += x;
            }
        }
        for m in mean.iter_mut() {
            *m /= n;
        }
        for s in samples {
            for i in 0..NUM_FEATURES {
                let d = s[i] - mean[i];
                var[i] += d * d;
            }
        }
        for v in var.iter_mut() {
            *v /= n;
        }
        ObservationWindow { index, time, samples: samples.len(), mean, var, truth }
    }

    /// Write the analytic representation (mean ++ std) into `out`
    /// without allocating. `out.len()` must be [`ANALYTIC_WIDTH`].
    #[inline]
    pub fn write_analytic(&self, out: &mut [f64]) {
        assert_eq!(out.len(), ANALYTIC_WIDTH);
        out[..NUM_FEATURES].copy_from_slice(&self.mean);
        for i in 0..NUM_FEATURES {
            out[NUM_FEATURES + i] = self.var[i].sqrt();
        }
    }

    /// Fixed-array variant of [`ObservationWindow::write_analytic`] for
    /// the on-line hot path.
    #[inline]
    pub fn fill_analytic(&self, out: &mut AnalyticVec) {
        self.write_analytic(&mut out[..]);
    }
}

/// An analytic window `A_t`: the feature representation handed to the
/// classifiers. Currently the window mean concatenated with the window
/// std — richer than the raw mean, cheap to compute, and what [7]'s
/// container-pattern classification uses.
#[derive(Debug, Clone)]
pub struct AnalyticWindow {
    pub index: u64,
    pub features: Vec<f64>,
    pub truth: Option<u32>,
}

impl AnalyticWindow {
    pub fn from_observation(o: &ObservationWindow) -> AnalyticWindow {
        let mut features = vec![0.0; ANALYTIC_WIDTH];
        o.write_analytic(&mut features);
        AnalyticWindow { index: o.index, features, truth: o.truth }
    }

    pub fn width() -> usize {
        ANALYTIC_WIDTH
    }
}

/// Rate-of-change transform `{A_t} -> {A'_t}` (paper §7.2 step 5): the
/// TransitionClassifier sees deltas between consecutive analytic windows,
/// which makes transition *shapes* (e.g. map->reduce) comparable across
/// workloads with different absolute levels.
///
/// Output has length `input.len() - 1`; `A'_t = A_{t+1} - A_t`.
pub fn rate_of_change(windows: &[AnalyticWindow]) -> Vec<AnalyticWindow> {
    windows
        .windows(2)
        .map(|pair| AnalyticWindow {
            index: pair[1].index,
            features: pair[1]
                .features
                .iter()
                .zip(&pair[0].features)
                .map(|(b, a)| b - a)
                .collect(),
            truth: pair[1].truth,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fv(val: f64) -> FeatureVec {
        [val; NUM_FEATURES]
    }

    #[test]
    fn aggregate_mean_and_var() {
        let samples = vec![fv(1.0), fv(3.0)];
        let w = ObservationWindow::aggregate(0, 10.0, &samples, Some(7));
        assert_eq!(w.samples, 2);
        assert_eq!(w.truth, Some(7));
        for i in 0..NUM_FEATURES {
            assert!((w.mean[i] - 2.0).abs() < 1e-12);
            assert!((w.var[i] - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn aggregate_single_sample_zero_var() {
        let w = ObservationWindow::aggregate(1, 0.0, &[fv(5.0)], None);
        for i in 0..NUM_FEATURES {
            assert_eq!(w.var[i], 0.0);
            assert_eq!(w.mean[i], 5.0);
        }
    }

    #[test]
    fn analytic_window_concat_mean_std() {
        let samples = vec![fv(0.0), fv(2.0)];
        let o = ObservationWindow::aggregate(0, 0.0, &samples, None);
        let a = AnalyticWindow::from_observation(&o);
        assert_eq!(a.features.len(), AnalyticWindow::width());
        assert!((a.features[0] - 1.0).abs() < 1e-12); // mean
        assert!((a.features[NUM_FEATURES] - 1.0).abs() < 1e-12); // std
    }

    #[test]
    fn rate_of_change_deltas() {
        let mk = |idx, v: f64| AnalyticWindow {
            index: idx,
            features: vec![v, 2.0 * v],
            truth: None,
        };
        let rocs = rate_of_change(&[mk(0, 1.0), mk(1, 4.0), mk(2, 2.0)]);
        assert_eq!(rocs.len(), 2);
        assert_eq!(rocs[0].features, vec![3.0, 6.0]);
        assert_eq!(rocs[1].features, vec![-2.0, -4.0]);
        assert_eq!(rocs[1].index, 2);
    }

    #[test]
    fn fill_analytic_matches_analytic_window() {
        let samples = vec![fv(1.0), fv(3.0)];
        let o = ObservationWindow::aggregate(0, 0.0, &samples, None);
        let mut buf = zero_analytic();
        o.fill_analytic(&mut buf);
        let a = AnalyticWindow::from_observation(&o);
        assert_eq!(&buf[..], a.features.as_slice());
    }

    #[test]
    fn rate_of_change_empty_and_single() {
        assert!(rate_of_change(&[]).is_empty());
        let one = AnalyticWindow { index: 0, features: vec![1.0], truth: None };
        assert!(rate_of_change(&[one]).is_empty());
    }
}
