//! Hotpath baseline differ (ROADMAP: "record + diff hotpath
//! baselines"): compares the freshly written `BENCH_hotpath.json`
//! against the committed `BENCH_baseline.json` and fails loudly when a
//! stage regressed beyond the threshold *under a matching environment*
//! (`meta`: thread count + feature flags). On meta mismatch — or when
//! either file is missing — it skips cleanly: a 2-thread laptop run
//! must never fail CI against a 16-thread baseline.
//!
//! Usage:
//!   bench_diff [--baseline PATH] [--current PATH] [--threshold PCT]
//!
//! Exit codes: 0 = ok or skipped, 1 = regression, 2 = bad input.
//!
//! Workflow: run `cargo bench --bench hotpath` (writes
//! BENCH_hotpath.json), then `cargo run --bin bench_diff`; to accept
//! the current numbers as the new baseline, copy BENCH_hotpath.json to
//! BENCH_baseline.json and commit it.

use kermit::benchkit::{diff_baselines, BaselineDiff};
use kermit::util::json::Json;

fn load(path: &str) -> Option<Json> {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(_) => {
            println!("bench_diff: {path} not found — skipping (ok)");
            return None;
        }
    };
    match Json::parse(&text) {
        Ok(j) => Some(j),
        Err(e) => {
            eprintln!("bench_diff: {path} is not valid JSON: {e:?}");
            std::process::exit(2);
        }
    }
}

fn main() {
    let mut baseline = "BENCH_baseline.json".to_string();
    let mut current = "BENCH_hotpath.json".to_string();
    let mut threshold = 0.25f64;
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut i = 0;
    while i < args.len() {
        let need_value = |i: usize| {
            args.get(i + 1).cloned().unwrap_or_else(|| {
                eprintln!("bench_diff: {} needs a value", args[i]);
                std::process::exit(2);
            })
        };
        match args[i].as_str() {
            "--baseline" => baseline = need_value(i),
            "--current" => current = need_value(i),
            "--threshold" => {
                threshold = need_value(i).parse().unwrap_or_else(|_| {
                    eprintln!("bench_diff: bad --threshold");
                    std::process::exit(2);
                })
            }
            other => {
                eprintln!("bench_diff: unknown argument {other}");
                std::process::exit(2);
            }
        }
        i += 2;
    }

    let (Some(base), Some(cur)) = (load(&baseline), load(&current)) else {
        return; // missing file(s): skipped cleanly above
    };
    match diff_baselines(&base, &cur, threshold) {
        Ok(BaselineDiff::MetaMismatch { key, baseline, current }) => {
            println!(
                "bench_diff: meta mismatch on `{key}` \
                 (baseline {baseline:?} vs current {current:?}) — \
                 environments differ, comparison skipped (ok)"
            );
        }
        Ok(BaselineDiff::Compared { regressions, ok, unmatched }) => {
            println!(
                "bench_diff: {ok} stage(s) within {:.0}% of baseline, \
                 {unmatched} unmatched",
                threshold * 100.0
            );
            if regressions.is_empty() {
                println!("bench_diff: no regressions");
                return;
            }
            for r in &regressions {
                println!(
                    "  REGRESSION {}: {:.0} ns -> {:.0} ns ({:.2}x)",
                    r.stage, r.baseline_ns, r.current_ns, r.ratio
                );
            }
            eprintln!(
                "bench_diff: {} stage(s) regressed beyond {:.0}%",
                regressions.len(),
                threshold * 100.0
            );
            std::process::exit(1);
        }
        Err(e) => {
            eprintln!("bench_diff: malformed bench JSON: {e:?}");
            std::process::exit(2);
        }
    }
}
