//! Chaos outcome differ: compares the freshly written
//! `CHAOS_outcomes.json` (or `PERSIST_outcomes.json` — same shape)
//! against a committed baseline and fails loudly when any scenario's
//! deterministic snapshot drifted *under a matching sweep* (same
//! scenario names + seeds). On sweep mismatch — a `KERMIT_CHAOS_SEED`
//! override, a smoke run diffed against a full-scale baseline — or
//! when either file is missing, it skips cleanly, exactly like
//! `bench_diff`'s meta-mismatch contract.
//!
//! Usage:
//!   chaos_diff [--baseline PATH] [--current PATH]
//!
//! Exit codes: 0 = ok or skipped, 1 = drift, 2 = bad input.
//!
//! Workflow: run `cargo bench --bench chaos` (writes
//! CHAOS_outcomes.json), then `cargo run --bin chaos_diff`; to accept
//! the current behaviour as the new baseline, copy CHAOS_outcomes.json
//! to CHAOS_baseline.json and commit it.

use kermit::chaoslab::{diff_outcome_sets, OutcomeDiff};
use kermit::util::json::Json;

fn load(path: &str) -> Option<Json> {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(_) => {
            println!("chaos_diff: {path} not found — skipping (ok)");
            return None;
        }
    };
    match Json::parse(&text) {
        Ok(j) => Some(j),
        Err(e) => {
            eprintln!("chaos_diff: {path} is not valid JSON: {e:?}");
            std::process::exit(2);
        }
    }
}

fn main() {
    let mut baseline = "CHAOS_baseline.json".to_string();
    let mut current = "CHAOS_outcomes.json".to_string();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut i = 0;
    while i < args.len() {
        let need_value = |i: usize| {
            args.get(i + 1).cloned().unwrap_or_else(|| {
                eprintln!("chaos_diff: {} needs a value", args[i]);
                std::process::exit(2);
            })
        };
        match args[i].as_str() {
            "--baseline" => baseline = need_value(i),
            "--current" => current = need_value(i),
            other => {
                eprintln!("chaos_diff: unknown argument {other}");
                std::process::exit(2);
            }
        }
        i += 2;
    }

    let (Some(base), Some(cur)) = (load(&baseline), load(&current)) else {
        return; // missing file(s): skipped cleanly above
    };
    match diff_outcome_sets(&base, &cur) {
        Ok(OutcomeDiff::MetaMismatch { scenarios }) => {
            println!(
                "chaos_diff: sweep mismatch — scenario/seed sets \
                 differ, comparison skipped (ok)"
            );
            for (name, b, c) in &scenarios {
                let show = |s: u64| {
                    if s == u64::MAX {
                        "absent".to_string()
                    } else {
                        format!("seed {s}")
                    }
                };
                println!("  {name}: baseline {} vs current {}", show(*b), show(*c));
            }
        }
        Ok(OutcomeDiff::Compared { unchanged, drifted }) => {
            println!(
                "chaos_diff: {unchanged} scenario(s) byte-identical to \
                 baseline"
            );
            if drifted.is_empty() {
                println!("chaos_diff: no drift");
                return;
            }
            for (scenario, field, was, now) in &drifted {
                println!("  DRIFT {scenario}.{field}: {was} -> {now}");
            }
            eprintln!(
                "chaos_diff: {} field(s) drifted under a matching sweep",
                drifted.len()
            );
            std::process::exit(1);
        }
        Err(e) => {
            eprintln!("chaos_diff: malformed outcome JSON: {e:?}");
            std::process::exit(2);
        }
    }
}
