//! Figure 7: TransitionClassifier performance.
//!
//! The TransitionClassifier is a random forest over *rate-of-change*
//! features ([8]); the paper reports accuracy by transition type. We
//! generate traces with known transition points, extract ground-truth
//! transition windows, label them by (from, to) pair, and evaluate a
//! held-out split — plus the ablation the paper's design implies:
//! rate-of-change features vs raw analytic features.

use super::WINDOW;
use crate::util::rng::Rng as XRng;
use crate::workloadgen::{GenConfig, Generator, Mix, ScheduleEntry};
use crate::features::{rate_of_change, AnalyticWindow};
use crate::ml::forest::{ForestConfig, RandomForest};
use crate::ml::{accuracy, macro_f1, Classifier, Dataset};
use crate::monitor::{aggregate_trace, MonitorConfig};
use crate::util::rng::Rng;
use crate::workloadgen::{Trace, TruthTag};
use std::collections::BTreeMap;

/// Trace tailored for transition study: ramps of 1.5 windows so every
/// transition contributes multiple rate-of-change examples.
pub fn transition_trace(seed: u64, classes: &[u32], reps: usize) -> Trace {
    let mut rng = XRng::new(seed ^ 0xF16);
    let mut order: Vec<u32> = Vec::new();
    for _ in 0..reps {
        let mut c = classes.to_vec();
        rng.shuffle(&mut c);
        if let (Some(&last), Some(&first)) = (order.last(), c.first()) {
            if last == first {
                c.reverse();
            }
        }
        order.extend(c);
    }
    let schedule: Vec<ScheduleEntry> = order
        .iter()
        .map(|&c| ScheduleEntry { mix: Mix::Pure(c), duration: 70 })
        .collect();
    let mut cfg = GenConfig::default();
    cfg.transition_len = (WINDOW * 3) / 2;
    let mut g = Generator::new(seed, cfg);
    g.generate(&schedule)
}

#[derive(Debug, Clone)]
pub struct Fig7Result {
    pub n_transition_types: usize,
    pub accuracy_roc: f64,
    pub f1_roc: f64,
    /// Ablation: same classifier on raw (non-ROC) features.
    pub accuracy_raw: f64,
}

/// Extract transition-window datasets from a trace: (roc features, raw
/// features, labels). Labels are generated ids per (from, to) pair.
pub fn transition_data(trace: &Trace) -> (Dataset, Dataset) {
    let cfg = MonitorConfig { window_size: WINDOW };
    let windows = aggregate_trace(trace, &cfg);
    let analytic: Vec<AnalyticWindow> = windows
        .iter()
        .map(AnalyticWindow::from_observation)
        .collect();
    let rocs = rate_of_change(&analytic);

    // ground-truth (from,to) per window from the sample tags
    let mut registry: BTreeMap<(u32, u32), u32> = BTreeMap::new();
    let mut roc_set = Dataset::new();
    let mut raw_set = Dataset::new();
    for (i, chunk) in trace.samples.chunks_exact(WINDOW).enumerate() {
        let tags: Vec<&TruthTag> = chunk
            .iter()
            .map(|s| &s.truth)
            .filter(|t| t.is_transition())
            .collect();
        if tags.is_empty() || i == 0 {
            continue;
        }
        if let TruthTag::Transition { from, to } = tags[0] {
            if from == to {
                continue;
            }
            let next = registry.len() as u32;
            let id = *registry.entry((*from, *to)).or_insert(next);
            // roc[i-1] = analytic[i] - analytic[i-1]
            roc_set.push(&rocs[i - 1].features, id);
            raw_set.push(&analytic[i].features, id);
        }
    }
    (roc_set, raw_set)
}

pub fn run(seed: u64) -> Fig7Result {
    // many repeated transitions between 4 classes (12 directed types),
    // with ramps long enough to span multiple observation windows
    let classes: Vec<u32> = vec![0, 2, 5, 7];
    let trace = transition_trace(seed, &classes, 25);
    let (roc, raw) = transition_data(&trace);

    let mut rng = Rng::new(seed ^ 0x7);
    let (tr_roc, te_roc) = roc.split(&mut rng, 0.3);
    let f = RandomForest::fit(&tr_roc, ForestConfig::default(), &mut rng);
    let preds = f.predict_batch(te_roc.x());
    let acc_roc = accuracy(&te_roc.labels, &preds);
    let f1_roc = macro_f1(&te_roc.labels, &preds);

    let (tr_raw, te_raw) = raw.split(&mut rng, 0.3);
    let f2 = RandomForest::fit(&tr_raw, ForestConfig::default(), &mut rng);
    let preds2 = f2.predict_batch(te_raw.x());
    let acc_raw = accuracy(&te_raw.labels, &preds2);

    Fig7Result {
        n_transition_types: roc.classes().len(),
        accuracy_roc: acc_roc,
        f1_roc,
        accuracy_raw: acc_raw,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transition_classifier_learns_transition_types() {
        let r = run(3);
        assert!(r.n_transition_types >= 6, "{}", r.n_transition_types);
        assert!(r.accuracy_roc > 0.6, "roc accuracy {}", r.accuracy_roc);
    }

    #[test]
    fn transition_data_is_labelled_consistently() {
        let classes: Vec<u32> = vec![0, 2];
        let trace = transition_trace(5, &classes, 6);
        let (roc, raw) = transition_data(&trace);
        assert_eq!(roc.len(), raw.len());
        // only two transition directions exist
        assert!(roc.classes().len() <= 2);
        assert!(!roc.is_empty());
    }
}
