//! The tuning-efficiency experiment behind the paper's headline claim:
//! Explorer is ≥30% faster than rule-of-thumb tuning and reaches ≥92%
//! of the exhaustive-search optimum ("up to 92.5% tuning efficiency").
//!
//! Probes are measured with multiplicative noise (a real cluster never
//! returns the model-exact duration), and the found config is finally
//! scored on the *noise-free* surface — exactly how the paper evaluates
//! (wall-clock of the tuned run vs wall-clock of the best run).

use crate::explorer::baselines::{exhaustive, random_search, rule_of_thumb};
use crate::explorer::{Explorer, ExplorerConfig};
use crate::simcluster::config_space::{default_config_index, ConfigIndex};
use crate::simcluster::perfmodel::job_duration;
use crate::util::rng::Rng;
use crate::workloadgen::num_pure_classes;

#[derive(Debug, Clone)]
pub struct ExplorerRow {
    pub class: u32,
    pub default_s: f64,
    pub rot_s: f64,
    pub random_s: f64,
    pub explorer_s: f64,
    pub oracle_s: f64,
    pub explorer_probes: usize,
    /// oracle / explorer (the paper's "tuning efficiency").
    pub efficiency: f64,
    /// 1 - explorer/rot (the paper's "% faster than rule-of-thumb").
    pub vs_rot: f64,
}

pub fn run(seed: u64, noise: f64) -> Vec<ExplorerRow> {
    let mut rows = Vec::new();
    for class in 0..num_pure_classes() as u32 {
        let mut rng = Rng::new(seed ^ (class as u64) << 8);
        // noisy evaluator for the search...
        let mut noisy = |c: ConfigIndex| {
            let d = job_duration(class, &c.to_config());
            d * (1.0 + noise * rng.normal()).max(0.5)
        };
        let ex = Explorer::new(ExplorerConfig::default());
        let found = ex.global_search(&mut noisy);
        let mut rng2 = Rng::new(seed ^ 0xF00D);
        let mut noisy2 = |c: ConfigIndex| {
            let d = job_duration(class, &c.to_config());
            d * (1.0 + noise * rng2.normal()).max(0.5)
        };
        let rand = random_search(&mut noisy2, found.probes, &mut Rng::new(seed));

        // ...but final scoring on the exact surface
        let exact = |c: ConfigIndex| job_duration(class, &c.to_config());
        let mut exact_mut = exact;
        let oracle = exhaustive(&mut exact_mut);
        let explorer_s = exact(found.best);
        let random_s = exact(rand.best);
        let default_s = exact(default_config_index());
        let rot_s = exact(rule_of_thumb());
        rows.push(ExplorerRow {
            class,
            default_s,
            rot_s,
            random_s,
            explorer_s,
            oracle_s: oracle.best_duration,
            explorer_probes: found.probes,
            efficiency: oracle.best_duration / explorer_s,
            vs_rot: 1.0 - explorer_s / rot_s,
        });
    }
    rows
}

/// Aggregate the table the way the paper states its claims.
pub struct ExplorerSummary {
    pub mean_efficiency: f64,
    pub max_efficiency: f64,
    pub mean_vs_rot: f64,
    pub max_vs_rot: f64,
    pub mean_probes: f64,
}

pub fn summarize(rows: &[ExplorerRow]) -> ExplorerSummary {
    let n = rows.len() as f64;
    ExplorerSummary {
        mean_efficiency: rows.iter().map(|r| r.efficiency).sum::<f64>() / n,
        max_efficiency: rows
            .iter()
            .map(|r| r.efficiency)
            .fold(0.0, f64::max),
        mean_vs_rot: rows.iter().map(|r| r.vs_rot).sum::<f64>() / n,
        max_vs_rot: rows.iter().map(|r| r.vs_rot).fold(f64::MIN, f64::max),
        mean_probes: rows.iter().map(|r| r.explorer_probes as f64).sum::<f64>()
            / n,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reproduces_headline_claims() {
        let rows = run(0, 0.03);
        let s = summarize(&rows);
        // paper: up to 92.5% tuning efficiency — we require the mean to
        // clear it under 3% measurement noise
        assert!(s.mean_efficiency > 0.92, "mean eff {}", s.mean_efficiency);
        // paper: up to 30% faster than rule-of-thumb
        assert!(s.max_vs_rot > 0.30, "max vs rot {}", s.max_vs_rot);
        // probes stay tiny vs the 15552-point grid
        assert!(s.mean_probes < 200.0, "probes {}", s.mean_probes);
    }

    #[test]
    fn explorer_beats_random_at_equal_budget() {
        let rows = run(1, 0.03);
        let better = rows
            .iter()
            .filter(|r| r.explorer_s <= r.random_s + 1e-9)
            .count();
        assert!(
            better * 10 >= rows.len() * 7,
            "explorer beats random on only {better}/{} classes",
            rows.len()
        );
    }
}
