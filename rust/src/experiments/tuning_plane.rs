//! Tuning-plane experiment: K tenants with rotated/hybrid archetype
//! schedules run their job streams concurrently on one simulated
//! cluster, with the full per-tenant MAPE-K loop closed by
//! [`crate::tuning::TuningPlane`]. Scores the §6.4 economics at
//! multi-tenant scale:
//!
//! * **tuned-vs-default speedup** — makespan under the plane versus the
//!   same schedules under the vendor default config;
//! * **cross-tenant cache-hit rate** — how often a tenant reuses an
//!   optimum another tenant paid the search for;
//! * **probes saved** — probes paid by the shared plane versus K
//!   *independent* single-tenant loops over the same schedules (the
//!   amortization Tuneful-style recurring-workload tuning promises).

use crate::explorer::ExplorerConfig;
use crate::simcluster::multi::{
    FixedConfigTenants, MultiClusterEngine, MultiEngineConfig,
};
use crate::simcluster::rm::ResourceManager;
use crate::simcluster::{default_config_index, JobSpec};
use crate::stream::TenantId;
use crate::tuning::{TuningPlane, TuningPlaneConfig, TuningRunReport};
use crate::util::rng::Rng;
use crate::workloadgen::tenant_schedules;

/// Scores for one tuning-plane run.
#[derive(Debug, Clone, Default)]
pub struct TuningPlaneScore {
    pub tenants: usize,
    pub jobs_per_tenant: usize,
    pub tuned_makespan: f64,
    pub default_makespan: f64,
    /// default / tuned (>1 means the plane beat the untuned cluster).
    pub speedup: f64,
    pub cache_hit_ratio: f64,
    pub cross_tenant_hits: usize,
    pub searches_completed: usize,
    pub searches_abandoned: usize,
    /// Probes paid by the shared plane.
    pub probes_shared: usize,
    /// Probes paid by K independent single-tenant loops on the same
    /// schedules (no shared knowledge plane).
    pub probes_independent: usize,
    pub peak_concurrency: usize,
    pub workloads_known: usize,
    pub offline_runs: usize,
}

impl TuningPlaneScore {
    /// Probes saved per tenant by sharing the plane.
    pub fn probes_saved_per_tenant(&self) -> f64 {
        if self.tenants == 0 {
            return 0.0;
        }
        (self.probes_independent as f64 - self.probes_shared as f64)
            / self.tenants as f64
    }
}

/// Rotated/hybrid per-tenant job schedules (the archetype rotation of
/// `workloadgen::tenant_schedules`, as job streams instead of traces).
pub fn schedules(
    seed: u64,
    tenants: usize,
    jobs_per_tenant: usize,
    classes: &[u32],
) -> Vec<(TenantId, Vec<JobSpec>)> {
    let mut rng = Rng::new(seed ^ 0x51C0_FFEE);
    tenant_schedules(&mut rng, tenants, jobs_per_tenant, 1, classes)
        .into_iter()
        .enumerate()
        .map(|(k, entries)| {
            (
                TenantId(k as u32),
                entries
                    .into_iter()
                    .map(|e| JobSpec { mix: e.mix })
                    .collect(),
            )
        })
        .collect()
}

/// The experiment's plane configuration (shared with the chaos lab so
/// faulted and fault-free runs tune under identical knobs).
pub fn plane_config(seed: u64, budget: usize) -> TuningPlaneConfig {
    let mut cfg = TuningPlaneConfig::default();
    cfg.coordinator.seed = seed;
    cfg.coordinator.offline_interval_windows = 16;
    cfg.coordinator.engine.duration_noise = 0.01;
    // archetypes here are well separated; a small forest keeps the
    // experiment's many retrain cycles cheap without costing accuracy
    cfg.coordinator.training.forest.n_trees = 24;
    cfg.coordinator.training.forest.max_depth = 12;
    cfg.explorer = ExplorerConfig {
        global_budget: budget,
        local_budget: budget / 2 + 1,
        min_improvement: 0.002,
    };
    cfg
}

/// The experiment's simcluster configuration (shared with the chaos lab).
pub fn sim_config() -> MultiEngineConfig {
    let mut sim = MultiEngineConfig::default();
    sim.engine.duration_noise = 0.01;
    // identification needs windows, not hours: cap each job's emitted
    // body at ~20 observation windows
    sim.max_job_samples = 600;
    sim
}

/// One shared-plane run over `schedules`.
pub fn run_shared(
    seed: u64,
    schedules: &[(TenantId, Vec<JobSpec>)],
    budget: usize,
) -> TuningRunReport {
    let mut plane = TuningPlane::new(plane_config(seed, budget));
    plane.run_schedules(schedules, sim_config(), seed)
}

/// K independent single-tenant loops: each tenant gets its own plane
/// (own DB, own classifiers) and runs alone — the comparator for the
/// probes-saved metric. Returns total probes paid.
pub fn run_independent(
    seed: u64,
    schedules: &[(TenantId, Vec<JobSpec>)],
    budget: usize,
) -> usize {
    let mut probes = 0usize;
    for (t, jobs) in schedules {
        let mut plane = TuningPlane::new(plane_config(seed, budget));
        let solo = vec![(*t, jobs.clone())];
        let report = plane.run_schedules(&solo, sim_config(), seed);
        probes += report.probes_paid;
    }
    probes
}

/// The full experiment.
pub fn run(seed: u64, tenants: usize, jobs_per_tenant: usize) -> TuningPlaneScore {
    let classes = [0u32, 5];
    let budget = 18;
    let scheds = schedules(seed, tenants, jobs_per_tenant, &classes);

    // tuned: the closed multi-tenant loop
    let tuned = run_shared(seed, &scheds, budget);

    // default baseline: same schedules, same cluster, vendor default
    let default_makespan = {
        let mut engine = MultiClusterEngine::new(
            ResourceManager::default_cluster(),
            sim_config(),
            seed,
        );
        for (t, jobs) in &scheds {
            engine.push_jobs(*t, jobs);
        }
        let mut hub =
            FixedConfigTenants(default_config_index().to_config());
        engine.run(&mut hub).makespan
    };

    // independent loops comparator
    let probes_independent = run_independent(seed, &scheds, budget);

    TuningPlaneScore {
        tenants,
        jobs_per_tenant,
        tuned_makespan: tuned.makespan(),
        default_makespan,
        speedup: default_makespan / tuned.makespan().max(1e-9),
        cache_hit_ratio: tuned.cache_hit_ratio(),
        cross_tenant_hits: tuned.cross_tenant_hits,
        searches_completed: tuned.searches_completed,
        searches_abandoned: tuned.searches_abandoned,
        probes_shared: tuned.probes_paid,
        probes_independent,
        peak_concurrency: tuned.sim.peak_concurrency,
        workloads_known: tuned.multi.workloads_known,
        offline_runs: tuned.multi.offline_runs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tuning_plane_closes_the_loop_at_k4() {
        let s = run(11, 4, 16);
        assert_eq!(s.tenants, 4);
        // the loop learned something and tuned jobs
        assert!(s.workloads_known >= 1, "{s:?}");
        assert!(s.offline_runs >= 1, "{s:?}");
        assert!(s.searches_completed >= 1, "{s:?}");
        assert!(s.cache_hit_ratio > 0.0, "{s:?}");
        // the streams actually shared the cluster
        assert!(s.peak_concurrency >= 2, "{s:?}");
        // tuned beats the untuned default cluster
        assert!(s.speedup > 1.0, "{s:?}");
        // at least one tenant reused an optimum another tenant paid for
        assert!(s.cross_tenant_hits >= 1, "{s:?}");
        // sharing the knowledge plane pays fewer probes than K
        // independent loops — the amortization headline
        assert!(
            s.probes_shared < s.probes_independent,
            "no probes saved: {s:?}"
        );
    }
}
