//! Figure 6: workload-classification accuracy across ML algorithms.
//!
//! The paper compares the algorithms considered for the
//! WorkloadClassifier ([7]); random forest wins at ~90%+. We reproduce
//! the comparison over the same kind of data — labelled steady-state
//! analytic windows from the benchmark archetypes — with the native
//! implementations plus (optionally) the MLP artifact.

use super::{labelled_windows, multiclass_trace};
use crate::ml::forest::{ForestConfig, RandomForest};
use crate::ml::knn::Knn;
use crate::ml::logreg::{LogReg, LogRegConfig};
use crate::ml::naive_bayes::GaussianNb;
use crate::ml::tree::{DecisionTree, TreeConfig};
use crate::ml::{accuracy, macro_f1, Classifier, Dataset};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct Fig6Row {
    pub algorithm: &'static str,
    pub accuracy: f64,
    pub macro_f1: f64,
}

pub struct Fig6Data {
    pub train: Dataset,
    pub test: Dataset,
}

pub fn data(seed: u64) -> Fig6Data {
    // all 10 archetypes, several plateaus each
    let classes: Vec<u32> = (0..10).collect();
    let trace = multiclass_trace(seed, &classes, 150, 4);
    let d = labelled_windows(&trace);
    let mut rng = Rng::new(seed ^ 0x51);
    let (train, test) = d.split(&mut rng, 0.3);
    Fig6Data { train, test }
}

fn eval(c: &dyn Classifier, test: &Dataset) -> (f64, f64) {
    let preds = c.predict_batch(test.x());
    (accuracy(&test.labels, &preds), macro_f1(&test.labels, &preds))
}

/// Run the native-algorithm comparison. The MLP (artifact path) is
/// benchmarked separately in `benches/fig6_classifiers.rs` since it
/// needs the PJRT runtime.
pub fn run(data: &Fig6Data, seed: u64) -> Vec<Fig6Row> {
    let mut rng = Rng::new(seed ^ 0x6);
    let mut rows = Vec::new();

    let forest =
        RandomForest::fit(&data.train, ForestConfig::default(), &mut rng);
    let (a, f) = eval(&forest, &data.test);
    rows.push(Fig6Row { algorithm: "random_forest", accuracy: a, macro_f1: f });

    let tree =
        DecisionTree::fit(&data.train, TreeConfig::default(), &mut rng);
    let (a, f) = eval(&tree, &data.test);
    rows.push(Fig6Row { algorithm: "decision_tree", accuracy: a, macro_f1: f });

    let knn = Knn::fit(&data.train, 7);
    let (a, f) = eval(&knn, &data.test);
    rows.push(Fig6Row { algorithm: "knn", accuracy: a, macro_f1: f });

    let nb = GaussianNb::fit(&data.train);
    let (a, f) = eval(&nb, &data.test);
    rows.push(Fig6Row { algorithm: "naive_bayes", accuracy: a, macro_f1: f });

    let lr =
        LogReg::fit(&data.train, LogRegConfig::default(), &mut rng);
    let (a, f) = eval(&lr, &data.test);
    rows.push(Fig6Row { algorithm: "logistic_regression", accuracy: a, macro_f1: f });

    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forest_wins_and_exceeds_90pct() {
        let d = data(42);
        let rows = run(&d, 42);
        let rf = rows.iter().find(|r| r.algorithm == "random_forest").unwrap();
        assert!(rf.accuracy > 0.9, "rf accuracy {}", rf.accuracy);
        // the paper's headline: RF is the best of the compared set
        for r in &rows {
            assert!(
                rf.accuracy >= r.accuracy - 0.02,
                "{} ({}) beats rf ({})",
                r.algorithm,
                r.accuracy,
                rf.accuracy
            );
        }
    }
}
