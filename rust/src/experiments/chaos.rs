//! Chaos-lab experiment: run the standard fault-scenario sweep and
//! score every graceful-degradation guarantee against its fault-free
//! oracle. `benches/chaos.rs` prints the scoreboard and writes the
//! deterministic JSON snapshots CI archives; under `KERMIT_SMOKE=1` it
//! *asserts* every scenario passes (the blocking `rust-chaos-smoke`
//! job).

use crate::chaoslab::{
    persistence_scenarios, run_persistence_scenario, run_scenario,
    run_transport_scenario, standard_scenarios, transport_scenarios,
    RecoveryOutcome, ScenarioOutcome, TransportOutcome,
};

/// Run the full standard sweep (smoke scale or full scale).
pub fn run_all(smoke: bool) -> Vec<ScenarioOutcome> {
    standard_scenarios(smoke)
        .iter()
        .map(run_scenario)
        .collect()
}

/// Run the durable-knowledge-plane crash/recovery sweep
/// (`crash_restart`, `corrupt_snapshot`). `benches/persist.rs` prints
/// the scoreboard and writes `PERSIST_outcomes.json`; under
/// `KERMIT_SMOKE=1` it asserts every scenario passes (the blocking
/// `rust-persist-smoke` job).
pub fn run_persistence(smoke: bool) -> Vec<RecoveryOutcome> {
    persistence_scenarios(smoke)
        .iter()
        .map(run_persistence_scenario)
        .collect()
}

/// Run the transport-chaos sweep (`partition_heal`, `lossy_transport`,
/// `duplicate_storm`, `stalled_consumer`) — the ingest path under a
/// faulty link, scored against a fault-free oracle.
/// `benches/transport_chaos.rs` prints the scoreboard and writes
/// `TRANSPORT_outcomes.json`; under `KERMIT_SMOKE=1` it asserts every
/// scenario passes (the blocking `rust-transport-chaos` job).
pub fn run_transport(smoke: bool) -> Vec<TransportOutcome> {
    transport_scenarios(smoke)
        .iter()
        .map(run_transport_scenario)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chaoslab::ScenarioSpec;

    fn scenario(name: &str) -> ScenarioSpec {
        standard_scenarios(true)
            .into_iter()
            .find(|s| s.name == name)
            .unwrap()
    }

    #[test]
    fn straggler_scenario_is_deterministic_and_passes() {
        let spec = scenario("stragglers");
        let a = run_scenario(&spec);
        let b = run_scenario(&spec);
        // same seed → byte-identical JSON snapshot (the reproducibility
        // contract the CI artifact relies on)
        assert_eq!(a.to_json().encode(), b.to_json().encode());
        // the faults really fired, and the guarantees held anyway
        assert!(a.straggler_jobs > 0, "{a:?}");
        assert!(a.pass, "failures: {:?}", a.failures);
        assert_eq!(a.livelocked_sessions, 0);
        assert_eq!(a.pending_decisions, 0);
    }

    #[test]
    fn lossy_transport_scenario_holds_its_guarantees() {
        let spec = transport_scenarios(true)
            .into_iter()
            .find(|s| s.name == "lossy_transport")
            .unwrap();
        let a = run_transport_scenario(&spec);
        // the link really dropped traffic, gaps were written off, and
        // every guarantee held anyway
        assert!(a.samples_dropped > 0, "{a:?}");
        assert!(a.gaps_skipped > 0, "{a:?}");
        assert!(a.pass, "failures: {:?}", a.failures);
        assert_eq!(a.double_counted_windows, 0);
        assert_eq!(a.resident_after, 0);
        assert_eq!(a.degraded_final, 0);
        // same seed → byte-identical snapshot (the CI artifact contract)
        let b = run_transport_scenario(&spec);
        assert_eq!(a.to_json().encode(), b.to_json().encode());
    }

    #[test]
    fn poisoned_db_scenario_contains_the_poison() {
        let spec = scenario("poisoned_db");
        let o = run_scenario(&spec);
        // both knowledge-plane attacks were planted...
        assert!(o.db_poisoned >= 1, "{o:?}");
        assert!(o.db_corrupted >= 1, "{o:?}");
        // ...and contained: no served poison left trusted, no corrupt
        // entry surviving the audit, no wedged session
        assert_eq!(o.unquarantined_poison, 0, "{o:?}");
        assert!(o.pass, "failures: {:?}", o.failures);
        assert_eq!(o.livelocked_sessions, 0);
    }
}
