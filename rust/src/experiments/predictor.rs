//! Workload-type prediction accuracy (paper §8: "Predict workload type
//! with up to 96% accuracy" [8]) — the LSTM WorkloadPredictor against
//! the Markov and persistence baselines, on realistic recurring
//! schedules with noise.

use crate::online::predictor::{
    sequence_accuracy, LabelPredictor, LastValuePredictor, MarkovPredictor,
};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct PredictorRow {
    pub predictor: &'static str,
    pub horizon: usize,
    pub accuracy: f64,
}

/// A "business day" label sequence: a fixed rotation with occasional
/// ad-hoc jobs injected (noise fraction). This is the recurring pattern
/// §6.4 argues KERMIT exploits.
pub fn daily_label_sequence(
    seed: u64,
    len: usize,
    rotation: &[u32],
    noise_frac: f64,
    ad_hoc: &[u32],
) -> Vec<u32> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(len);
    let mut i = 0usize;
    while out.len() < len {
        if rng.chance(noise_frac) && !ad_hoc.is_empty() {
            out.push(*rng.choice(ad_hoc));
        } else {
            out.push(rotation[i % rotation.len()]);
            i += 1;
        }
    }
    out
}

/// Evaluate native predictors on train/test splits of the sequence.
/// (The LSTM artifact variant is evaluated in the bench, which has the
/// PJRT runtime; it implements the same `LabelPredictor` trait and is
/// scored by the same `sequence_accuracy`.)
pub fn run_native(seq_train: &[u32], seq_test: &[u32]) -> Vec<PredictorRow> {
    let mut rows = Vec::new();
    let markov = MarkovPredictor::fit(seq_train);
    for &h in &[1usize, 5, 10] {
        rows.push(PredictorRow {
            predictor: "markov",
            horizon: h,
            accuracy: sequence_accuracy(&markov, seq_test, h, 2),
        });
    }
    let lv = LastValuePredictor;
    for &h in &[1usize, 5, 10] {
        rows.push(PredictorRow {
            predictor: "last_value",
            horizon: h,
            accuracy: sequence_accuracy(&lv, seq_test, h, 2),
        });
    }
    rows
}

/// Score any predictor implementation on the standard scenario.
pub fn score_predictor(
    p: &dyn LabelPredictor,
    seq_test: &[u32],
) -> Vec<(usize, f64)> {
    [1usize, 5, 10]
        .iter()
        .map(|&h| (h, sequence_accuracy(p, seq_test, h, 2)))
        .collect()
}

/// Standard scenario: rotation of 5 job types, 6% ad-hoc noise.
pub fn standard_scenario(seed: u64) -> (Vec<u32>, Vec<u32>) {
    let rotation = [3u32, 0, 7, 5, 2];
    let ad_hoc = [8u32, 9];
    let train =
        daily_label_sequence(seed, 400, &rotation, 0.06, &ad_hoc);
    let test =
        daily_label_sequence(seed ^ 77, 200, &rotation, 0.06, &ad_hoc);
    (train, test)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markov_hits_90s_on_recurring_pattern() {
        let (train, test) = standard_scenario(5);
        let rows = run_native(&train, &test);
        let m1 = rows
            .iter()
            .find(|r| r.predictor == "markov" && r.horizon == 1)
            .unwrap();
        // with 6% injected noise the ceiling is ~94%; the paper's 96%
        // claim is "up to" — we require >85% here
        assert!(m1.accuracy > 0.85, "markov@1 {}", m1.accuracy);
        // markov beats persistence on a rotating pattern
        let lv1 = rows
            .iter()
            .find(|r| r.predictor == "last_value" && r.horizon == 1)
            .unwrap();
        assert!(m1.accuracy > lv1.accuracy + 0.3);
    }

    #[test]
    fn sequence_has_requested_noise() {
        let seq = daily_label_sequence(0, 1000, &[1, 2, 3], 0.1, &[9]);
        let noise = seq.iter().filter(|&&l| l == 9).count();
        assert!((50..200).contains(&noise), "{noise}");
    }
}
