//! Zero-shot classification of unseen hybrid workloads (paper §8 /
//! [9]: "classify them with up to 83% accuracy").
//!
//! Protocol: the classifier trains on *pure* workloads only. The
//! WorkloadSynthesizer anticipates hybrid classes from pairs of pure
//! characterizations and injects synthetic instances. At test time,
//! real hybrid traces (never observed in training) must be classified
//! as their anticipated hybrid class. The ablation removes synthesis —
//! without it, hybrids can only ever be misclassified.

use super::{labelled_windows, multiclass_trace, WINDOW};
use crate::knowledge::{Characterization, WorkloadDb};
use crate::ml::forest::{ForestConfig, RandomForest};
use crate::ml::{Classifier, Dataset};
use crate::monitor::{aggregate_trace, MonitorConfig};
use crate::offline::zsl::{synthesize, ZslConfig};
use crate::util::rng::Rng;
use crate::workloadgen::{Generator, Mix, ScheduleEntry};
use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct ZslResult {
    /// Accuracy naming unseen hybrids with synthesis enabled.
    pub zsl_accuracy: f64,
    /// Ablation: same protocol without the synthesizer (hybrids are
    /// unseen AND unanticipated; correct naming is impossible).
    pub ablation_accuracy: f64,
    pub n_hybrid_tests: usize,
    pub pure_accuracy: f64,
}

pub fn run(seed: u64) -> ZslResult {
    let pure_classes: Vec<u32> = vec![0, 2, 3, 5];
    // --- training data: pure classes only
    let trace = multiclass_trace(seed, &pure_classes, 150, 3);
    let pure_data = labelled_windows(&trace);

    // register pure workloads in a DB (as discovery would)
    let mut db = WorkloadDb::new();
    let mut truth_to_label: BTreeMap<u32, u32> = BTreeMap::new();
    for &c in &pure_classes {
        let idx: Vec<usize> = (0..pure_data.len())
            .filter(|&i| pure_data.labels[i] == c)
            .collect();
        let rows = pure_data.x().gather(&idx);
        let ch = Characterization::from_rows(&rows);
        let centroid = ch.mean_vector();
        let label = db.insert_new(ch, centroid, rows.n_rows(), false);
        truth_to_label.insert(c, label);
    }

    // training set in DB-label space
    let mut train = Dataset::new();
    for (r, t) in pure_data.iter() {
        train.push(r, truth_to_label[&t]);
    }

    // --- ZSL synthesis
    let mut rng = Rng::new(seed ^ 0x25);
    let synth = synthesize(&mut db, &ZslConfig::default(), &mut rng);
    let mut train_zsl = train.clone();
    train_zsl.extend_from(&synth.instances);
    // map (pure_label_a, pure_label_b) -> synthetic label
    let pair_label: BTreeMap<(u32, u32), u32> = synth
        .classes
        .iter()
        .map(|&(s, a, b)| ((a.min(b), a.max(b)), s))
        .collect();

    // --- test data: real hybrid traces (never trained on)
    let mut g = Generator::with_default_config(seed ^ 0x31);
    let mut schedule = Vec::new();
    let mut hrng = Rng::new(seed ^ 0x99);
    for i in 0..pure_classes.len() {
        for j in (i + 1)..pure_classes.len() {
            schedule.push(ScheduleEntry {
                mix: Mix::Hybrid(
                    pure_classes[i],
                    pure_classes[j],
                    hrng.range_f64(0.4, 0.6),
                ),
                duration: 150,
            });
        }
    }
    let htrace = g.generate(&schedule);
    let hwindows =
        aggregate_trace(&htrace, &MonitorConfig { window_size: WINDOW });

    // expected synthetic label per hybrid window, from generator truth
    let n_pure_total = crate::workloadgen::num_pure_classes();
    let mut tests: Vec<(Vec<f64>, u32)> = Vec::new();
    for w in &hwindows {
        if let Some(truth) = w.truth {
            // decode hybrid truth id back to the pure pair
            if truth >= n_pure_total as u32 {
                let (a, b) = decode_pair(truth, n_pure_total);
                let (la, lb) =
                    (truth_to_label[&a], truth_to_label[&b]);
                let key = (la.min(lb), la.max(lb));
                if let Some(&syn) = pair_label.get(&key) {
                    tests.push((
                        crate::features::AnalyticWindow::from_observation(w)
                            .features,
                        syn,
                    ));
                }
            }
        }
    }

    // --- classifiers
    let forest_zsl =
        RandomForest::fit(&train_zsl, ForestConfig::default(), &mut rng);
    let forest_abl =
        RandomForest::fit(&train, ForestConfig::default(), &mut rng);

    let hits_zsl = tests
        .iter()
        .filter(|(r, want)| forest_zsl.predict(r) == *want)
        .count();
    let hits_abl = tests
        .iter()
        .filter(|(r, want)| forest_abl.predict(r) == *want)
        .count();

    // sanity: pure accuracy with zsl training stays high
    let mut prng = Rng::new(seed ^ 0x42);
    let (ptr, pte) = {
        let mut d = Dataset::new();
        for (r, t) in pure_data.iter() {
            d.push(r, truth_to_label[&t]);
        }
        d.split(&mut prng, 0.3)
    };
    let _ = ptr;
    let ppred = forest_zsl.predict_batch(pte.x());
    let pure_accuracy = crate::ml::accuracy(&pte.labels, &ppred);

    ZslResult {
        zsl_accuracy: hits_zsl as f64 / tests.len().max(1) as f64,
        ablation_accuracy: hits_abl as f64 / tests.len().max(1) as f64,
        n_hybrid_tests: tests.len(),
        pure_accuracy,
    }
}

/// Inverse of `Mix::truth_id` for hybrids.
pub fn decode_pair(truth_id: u32, n_pure: usize) -> (u32, u32) {
    let mut rest = (truth_id as usize) - n_pure;
    let mut lo = 0usize;
    while rest >= n_pure - lo - 1 {
        rest -= n_pure - lo - 1;
        lo += 1;
    }
    (lo as u32, (lo + 1 + rest) as u32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloadgen::Mix;

    #[test]
    fn decode_pair_inverts_truth_id() {
        let n = crate::workloadgen::num_pure_classes();
        for a in 0..n as u32 {
            for b in (a + 1)..n as u32 {
                let id = Mix::Hybrid(a, b, 0.5).truth_id(n);
                assert_eq!(decode_pair(id, n), (a, b));
            }
        }
    }

    #[test]
    fn zsl_names_unseen_hybrids_ablation_cannot() {
        let r = run(3);
        assert!(r.n_hybrid_tests > 10);
        // paper: up to 83% on unseen hybrids
        assert!(r.zsl_accuracy > 0.6, "zsl accuracy {}", r.zsl_accuracy);
        // without synthesis the hybrid label doesn't exist in training:
        // accuracy is necessarily 0
        assert_eq!(r.ablation_accuracy, 0.0);
        assert!(r.pure_accuracy > 0.85, "pure {}", r.pure_accuracy);
    }
}
