//! Figure 10: workload-discovery quality across clustering algorithms
//! (Awt + Purity). DBSCAN — KERMIT's choice — vs k-means (elbow) and
//! average-linkage agglomerative.

use super::WINDOW;
use crate::clustering::{
    agglomerative::agglomerative, dbscan, kmeans::kmeans_elbow, metrics,
    DbscanConfig, DistanceProvider, NativeDistance,
};
use crate::features::{zero_analytic, ANALYTIC_WIDTH};
use crate::linalg::Matrix;
use crate::monitor::{aggregate_trace, MonitorConfig};
use crate::util::rng::Rng;
use crate::workloadgen::{random_schedule, Generator};

#[derive(Debug, Clone)]
pub struct Fig10Row {
    pub algorithm: &'static str,
    pub awt: f64,
    pub purity: f64,
    pub clusters_found: usize,
    pub true_classes: usize,
}

/// Steady-window rows (contiguous analytic matrix) + ground-truth
/// labels for a discovery scenario.
pub fn discovery_data(seed: u64, classes: &[u32]) -> (Matrix, Vec<u32>) {
    let mut srng = Rng::new(seed);
    let sched = random_schedule(&mut srng, 40, 240, classes);
    let mut g = Generator::with_default_config(seed ^ 0x10);
    let trace = g.generate(&sched);
    let windows =
        aggregate_trace(&trace, &MonitorConfig { window_size: WINDOW });
    let mut rows = Matrix::with_width(ANALYTIC_WIDTH);
    let mut truth = Vec::new();
    let mut buf = zero_analytic();
    for w in &windows {
        if let Some(t) = w.truth {
            w.fill_analytic(&mut buf);
            rows.push_row(&buf);
            truth.push(t);
        }
    }
    (rows, truth)
}

pub fn run_with_distance(
    seed: u64,
    dist: &dyn DistanceProvider,
) -> Vec<Fig10Row> {
    let classes: Vec<u32> = vec![0, 2, 3, 5, 7, 9];
    let (rows, truth) = discovery_data(seed, &classes);
    let true_classes = classes.len();
    let mut out = Vec::new();

    let db = dbscan(&rows, &DbscanConfig { eps: 10.0, min_pts: 4 }, dist);
    out.push(Fig10Row {
        algorithm: "dbscan",
        awt: metrics::awt(&truth, &db.labels),
        purity: metrics::purity(&truth, &db.labels),
        clusters_found: db.n_clusters,
        true_classes,
    });

    let mut rng = Rng::new(seed ^ 0x20);
    let km = kmeans_elbow(&rows, 12, 0.2, 100, &mut rng);
    out.push(Fig10Row {
        algorithm: "kmeans_elbow",
        awt: metrics::awt(&truth, &km.labels),
        purity: metrics::purity(&truth, &km.labels),
        clusters_found: km.centroids.n_rows(),
        true_classes,
    });

    let ag = agglomerative(&rows, 18.0, dist);
    out.push(Fig10Row {
        algorithm: "agglomerative",
        awt: metrics::awt(&truth, &ag.labels),
        purity: metrics::purity(&truth, &ag.labels),
        clusters_found: ag.n_clusters,
        true_classes,
    });
    out
}

pub fn run(seed: u64) -> Vec<Fig10Row> {
    run_with_distance(seed, &NativeDistance)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dbscan_discovers_workload_types_best() {
        let rows = run(17);
        let db = rows.iter().find(|r| r.algorithm == "dbscan").unwrap();
        // the paper's finding: DBSCAN identifies the workload types
        assert!(db.awt > 0.9, "dbscan awt {}", db.awt);
        assert!(db.purity > 0.85, "dbscan purity {}", db.purity);
        for r in &rows {
            assert!(
                db.awt >= r.awt - 0.05,
                "{} awt {} beats dbscan {}",
                r.algorithm,
                r.awt,
                db.awt
            );
        }
    }
}
