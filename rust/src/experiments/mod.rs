//! Reproduction harness: one module per paper figure/claim. Each
//! produces the data series the paper reports; `rust/benches/*` print
//! them (with timings) and EXPERIMENTS.md records paper-vs-measured.

pub mod chaos;
pub mod explorer_table;
pub mod fig10;
pub mod fig6;
pub mod fig7;
pub mod fig9;
pub mod multitenant;
pub mod predictor;
pub mod tuning_plane;
pub mod zsl;

use crate::features::AnalyticWindow;
use crate::ml::Dataset;
use crate::monitor::{aggregate_trace, MonitorConfig};
use crate::util::rng::Rng;
use crate::workloadgen::{Generator, ScheduleEntry, Trace};

/// Standard observation-window size used across experiments.
pub const WINDOW: usize = 30;

/// Generate a trace and aggregate it into a labelled analytic-window
/// dataset using generator ground truth (the "human specialist"
/// labelling of the paper's evaluation). Transition / mixed windows are
/// dropped, as the paper's classifier experiments use steady windows.
pub fn labelled_windows(trace: &Trace) -> Dataset {
    let windows =
        aggregate_trace(trace, &MonitorConfig { window_size: WINDOW });
    let mut d = Dataset::new();
    for w in &windows {
        if let Some(t) = w.truth {
            d.push(AnalyticWindow::from_observation(&w.clone()).features, t);
        }
    }
    d
}

/// A multi-class steady-state dataset: `reps` plateaus per class in
/// shuffled order (so each class contributes many separate segments).
pub fn multiclass_trace(
    seed: u64,
    classes: &[u32],
    duration: usize,
    reps: usize,
) -> Trace {
    let mut rng = Rng::new(seed ^ 0xABCD);
    let mut order: Vec<u32> = Vec::new();
    for _ in 0..reps {
        let mut c = classes.to_vec();
        rng.shuffle(&mut c);
        // avoid no-op transitions at rep boundaries
        if let (Some(&last), Some(&first)) = (order.last(), c.first()) {
            if last == first {
                c.reverse();
            }
        }
        order.extend(c);
    }
    let schedule: Vec<ScheduleEntry> = order
        .iter()
        .map(|&c| ScheduleEntry {
            mix: crate::workloadgen::Mix::Pure(c),
            duration,
        })
        .collect();
    let mut g = Generator::with_default_config(seed);
    g.generate(&schedule)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labelled_windows_have_all_classes() {
        let t = multiclass_trace(0, &[0, 1, 2], 120, 2);
        let d = labelled_windows(&t);
        assert_eq!(d.classes(), vec![0, 1, 2]);
        assert!(d.len() > 10);
    }
}
