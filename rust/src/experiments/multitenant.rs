//! Multi-tenant identification experiment (the paper's §1/§6 claim that
//! KERMIT handles *complex multi-user workloads* without explicit
//! training, scaled to N concurrent streams): K tenants with mixed,
//! phase-shifted archetype rotations stream through one `StreamRouter`
//! and one shared knowledge plane; we score how much of each tenant's
//! traffic ends up labelled, and whether the shared plane keeps label
//! assignments consistent *across* tenants (the same archetype must get
//! the same label no matter whose stream it arrives on).

use crate::coordinator::{CoordinatorConfig, MultiTenantCoordinator};
use crate::monitor::TenantAggregator;
use crate::online::UNKNOWN;
use crate::stream::{interleave_round_robin, TenantId};
use crate::workloadgen::tenant_traces;
use std::collections::BTreeMap;

/// Scores for one multi-tenant run.
#[derive(Debug, Clone, Default)]
pub struct MultiTenantScore {
    pub tenants: usize,
    pub windows_observed: usize,
    pub offline_runs: usize,
    pub workloads_known: usize,
    /// Fraction of observed windows published with a known label.
    pub known_fraction: f64,
    /// Of the windows with both a ground-truth class and a known label:
    /// the fraction whose (truth -> label) assignment agrees with the
    /// *global* majority assignment for that truth class, pooled over
    /// all tenants. 1.0 means every tenant names every archetype the
    /// same way — the shared-knowledge-plane property.
    pub cross_tenant_consistency: f64,
}

/// Run the experiment: `tenants` interleaved streams, mixed archetypes,
/// several amortized off-line cycles.
pub fn run(seed: u64, tenants: usize) -> MultiTenantScore {
    let mut cfg = CoordinatorConfig::default();
    cfg.offline_interval_windows = 10;
    cfg.seed = seed;
    let mut coord = MultiTenantCoordinator::new(cfg);
    let traces =
        tenant_traces(seed, tenants, 6, 150, &[0, 2, 5, 7], 0, 0.0);
    let report = coord.run_interleaved(&traces, 15, 100);

    // pool (truth, label) pairs over every tenant's observed windows:
    // replay the *same* interleaved stream through the monitor's
    // standalone demux (TenantAggregator) to recover per-tenant window
    // truths in shard observe order — shard contexts align 1:1
    let mut demux = TenantAggregator::new(coord.config.monitor.clone());
    let mut truths: BTreeMap<u32, Vec<Option<u32>>> = BTreeMap::new();
    for ts in interleave_round_robin(&traces, 15) {
        if let Some((t, w)) = demux.push(ts.tenant, ts.sample.clone()) {
            truths.entry(t.0).or_default().push(w.truth);
        }
    }
    let mut pairs: Vec<(u32, u32)> = Vec::new();
    for k in 0..traces.len() {
        let shard = coord.router().shard(TenantId(k as u32)).unwrap();
        let tenant_truths = &truths[&(k as u32)];
        for (truth, c) in tenant_truths.iter().zip(&shard.contexts) {
            if let (Some(truth), label) = (*truth, c.current_label) {
                if label != UNKNOWN {
                    pairs.push((truth, label));
                }
            }
        }
    }
    // majority label per truth class, then agreement with it
    let mut votes: BTreeMap<u32, BTreeMap<u32, usize>> = BTreeMap::new();
    for &(t, l) in &pairs {
        *votes.entry(t).or_default().entry(l).or_insert(0) += 1;
    }
    let majority: BTreeMap<u32, u32> = votes
        .iter()
        .map(|(t, ls)| {
            let (&best, _) =
                ls.iter().max_by_key(|&(_, &n)| n).unwrap();
            (*t, best)
        })
        .collect();
    let agree = pairs
        .iter()
        .filter(|&&(t, l)| majority.get(&t) == Some(&l))
        .count();
    let consistency = if pairs.is_empty() {
        0.0
    } else {
        agree as f64 / pairs.len() as f64
    };

    MultiTenantScore {
        tenants,
        windows_observed: report.windows_observed,
        offline_runs: report.offline_runs,
        workloads_known: report.workloads_known,
        known_fraction: report.known_fraction(),
        cross_tenant_consistency: consistency,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn multi_tenant_run_learns_and_stays_consistent_across_tenants() {
        let s = run(11, 4);
        assert_eq!(s.tenants, 4);
        assert!(s.windows_observed > 60, "{s:?}");
        assert!(s.offline_runs >= 2, "{s:?}");
        assert!(s.workloads_known >= 3, "{s:?}");
        assert!(s.known_fraction > 0.35, "{s:?}");
        assert!(s.cross_tenant_consistency > 0.85, "{s:?}");
    }
}
