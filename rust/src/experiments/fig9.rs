//! Figure 9: ChangeDetector performance (up to 99% detection accuracy).
//!
//! Traces with known transition points stream through the Welch-based
//! ChangeDetector; a window is a true positive when flagged and it (or
//! an immediate neighbour — boundary quantisation) overlaps a generator
//! transition. The sweep covers significance level α and window size,
//! the detector's two hyper-parameters.

use crate::monitor::{aggregate_trace, transition_truth, MonitorConfig};
use crate::online::change_detector::{ChangeDetector, ChangeDetectorConfig};
use crate::workloadgen::{random_schedule, Generator};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct Fig9Row {
    pub alpha: f64,
    pub window_size: usize,
    pub accuracy: f64,
    pub precision: f64,
    pub recall: f64,
}

/// Score detector flags vs ground truth with ±1-window tolerance on
/// both sides (a transition detected one window late is a detection,
/// matching how the paper scores against human log interpretation).
pub fn score(flags: &[bool], truth: &[bool]) -> (f64, f64, f64) {
    let n = flags.len();
    let near = |v: &[bool], i: usize| -> bool {
        let lo = i.saturating_sub(1);
        let hi = (i + 1).min(n - 1);
        (lo..=hi).any(|k| v[k])
    };
    let mut tp = 0usize;
    let mut fp = 0usize;
    let mut fn_ = 0usize;
    let mut tn = 0usize;
    for i in 0..n {
        match (flags[i], near(truth, i)) {
            (true, true) => tp += 1,
            (true, false) => fp += 1,
            (false, _) if truth[i] && !near(flags, i) => fn_ += 1,
            (false, _) if truth[i] => tp += 0, // caught by neighbour
            _ => tn += 1,
        }
    }
    let accuracy = (tp + tn) as f64 / (tp + tn + fp + fn_).max(1) as f64;
    let precision = if tp + fp > 0 { tp as f64 / (tp + fp) as f64 } else { 1.0 };
    let recall = if tp + fn_ > 0 { tp as f64 / (tp + fn_) as f64 } else { 1.0 };
    (accuracy, precision, recall)
}

pub fn run(seed: u64) -> Vec<Fig9Row> {
    let mut rows = Vec::new();
    for &window_size in &[15usize, 30, 60] {
        // one trace per window size (same schedule seed for fairness)
        let mut srng = Rng::new(seed);
        let sched = random_schedule(&mut srng, 60, 200, &[0, 2, 3, 5, 7]);
        let mut g = Generator::with_default_config(seed ^ 9);
        let trace = g.generate(&sched);
        let mcfg = MonitorConfig { window_size };
        let windows = aggregate_trace(&trace, &mcfg);
        let truth = transition_truth(&trace, &mcfg);
        for &alpha in &[1e-2, 1e-3, 1e-4, 1e-6] {
            let cfg = ChangeDetectorConfig {
                alpha,
                min_changed_features: 3,
            };
            let flags = ChangeDetector::batch(&windows, &cfg);
            let (accuracy, precision, recall) = score(&flags, &truth);
            rows.push(Fig9Row {
                alpha,
                window_size,
                accuracy,
                precision,
                recall,
            });
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn best_operating_point_is_highly_accurate() {
        let rows = run(11);
        let best = rows
            .iter()
            .map(|r| r.accuracy)
            .fold(0.0f64, f64::max);
        // the paper's claim: up to 99% detection accuracy
        assert!(best > 0.95, "best accuracy {best}");
    }

    #[test]
    fn score_tolerates_one_window_offset() {
        // flag one window after the truth: still a TP
        let truth = [false, true, false, false];
        let flags = [false, false, true, false];
        let (acc, p, r) = score(&flags, &truth);
        assert_eq!(p, 1.0);
        assert_eq!(r, 1.0);
        assert_eq!(acc, 1.0);
    }

    #[test]
    fn score_counts_misses_and_false_alarms() {
        let truth = [true, false, false, false, false];
        let flags = [false, false, false, true, false];
        let (_, p, r) = score(&flags, &truth);
        assert_eq!(p, 0.0);
        assert_eq!(r, 0.0);
    }
}
