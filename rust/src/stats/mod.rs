//! Statistical primitives: descriptive statistics, Welch's t-test, and
//! vector norms. These back the ChangeDetector (paper §7.2) and workload
//! characterization (paper §7.1).

/// Descriptive statistics for one feature over a set of samples — the
/// paper's "workload characterization" set: mean, std, min, max, p75, p90
/// (§7.1: "A full set of statistics, including the mean, the standard
/// deviation, the max, the min, the 90th percentile, and the 75th
/// percentile").
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p75: f64,
    pub p90: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of(empty)");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        let mut sorted: Vec<f64> = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p75: percentile_sorted(&sorted, 0.75),
            p90: percentile_sorted(&sorted, 0.90),
        }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice, q in [0,1].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

pub fn mean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance (matches the L1 window_stats kernel's convention).
pub fn variance(xs: &[f64]) -> f64 {
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Sample variance (n-1 denominator) — what Welch's t-test wants.
pub fn sample_variance(xs: &[f64]) -> f64 {
    assert!(xs.len() >= 2, "sample_variance needs n >= 2");
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
        / (xs.len() - 1) as f64
}

/// Euclidean (L2) distance between equal-length vectors.
pub fn l2_distance(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y) * (x - y))
        .sum::<f64>()
        .sqrt()
}

/// Result of a Welch two-sample t-test.
#[derive(Debug, Clone, Copy)]
pub struct WelchResult {
    pub t: f64,
    pub df: f64,
    /// Two-sided p-value.
    pub p: f64,
}

/// Welch's unequal-variance t-test from raw samples.
pub fn welch_t_test(a: &[f64], b: &[f64]) -> WelchResult {
    welch_t_test_from_moments(
        mean(a),
        sample_variance(a),
        a.len(),
        mean(b),
        sample_variance(b),
        b.len(),
    )
}

/// Welch's t-test from precomputed moments — this is the form the on-line
/// ChangeDetector uses, consuming the mean/var emitted by the
/// `welch_stats` artifact (L1 kernel) or the streaming aggregator.
pub fn welch_t_test_from_moments(
    mean_a: f64,
    var_a: f64,
    n_a: usize,
    mean_b: f64,
    var_b: f64,
    n_b: usize,
) -> WelchResult {
    assert!(n_a >= 2 && n_b >= 2);
    let sa = var_a / n_a as f64;
    let sb = var_b / n_b as f64;
    let denom = (sa + sb).sqrt();
    if denom == 0.0 {
        // identical constant samples: no evidence of change
        return WelchResult { t: 0.0, df: (n_a + n_b - 2) as f64, p: 1.0 };
    }
    let t = (mean_a - mean_b) / denom;
    // Welch–Satterthwaite degrees of freedom
    let df = (sa + sb) * (sa + sb)
        / (sa * sa / (n_a as f64 - 1.0) + sb * sb / (n_b as f64 - 1.0));
    let p = 2.0 * student_t_sf(t.abs(), df);
    WelchResult { t, df, p }
}

/// Survival function P(T > t) of Student's t with `df` degrees of freedom,
/// via the regularized incomplete beta function.
pub fn student_t_sf(t: f64, df: f64) -> f64 {
    if !t.is_finite() {
        return if t > 0.0 { 0.0 } else { 1.0 };
    }
    let x = df / (df + t * t);
    0.5 * incomplete_beta(0.5 * df, 0.5, x)
}

/// Regularized incomplete beta I_x(a, b) via the Lentz continued fraction.
pub fn incomplete_beta(a: f64, b: f64, x: f64) -> f64 {
    assert!(a > 0.0 && b > 0.0);
    if x <= 0.0 {
        return 0.0;
    }
    if x >= 1.0 {
        return 1.0;
    }
    let ln_front = ln_gamma(a + b) - ln_gamma(a) - ln_gamma(b)
        + a * x.ln()
        + b * (1.0 - x).ln();
    // use the symmetry relation for faster convergence
    if x < (a + 1.0) / (a + b + 2.0) {
        (ln_front.exp() * beta_cf(a, b, x)) / a
    } else {
        1.0 - incomplete_beta(b, a, 1.0 - x)
    }
}

/// Continued fraction for the incomplete beta (Numerical Recipes betacf).
fn beta_cf(a: f64, b: f64, x: f64) -> f64 {
    const MAX_ITER: usize = 300;
    const EPS: f64 = 1e-14;
    const FPMIN: f64 = 1e-300;
    let qab = a + b;
    let qap = a + 1.0;
    let qam = a - 1.0;
    let mut c = 1.0;
    let mut d = 1.0 - qab * x / qap;
    if d.abs() < FPMIN {
        d = FPMIN;
    }
    d = 1.0 / d;
    let mut h = d;
    for m in 1..=MAX_ITER {
        let m = m as f64;
        let m2 = 2.0 * m;
        // even step
        let aa = m * (b - m) * x / ((qam + m2) * (a + m2));
        d = 1.0 + aa * d;
        if d.abs() < FPMIN {
            d = FPMIN;
        }
        c = 1.0 + aa / c;
        if c.abs() < FPMIN {
            c = FPMIN;
        }
        d = 1.0 / d;
        h *= d * c;
        // odd step
        let aa = -(a + m) * (qab + m) * x / ((a + m2) * (qap + m2));
        d = 1.0 + aa * d;
        if d.abs() < FPMIN {
            d = FPMIN;
        }
        c = 1.0 + aa / c;
        if c.abs() < FPMIN {
            c = FPMIN;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < EPS {
            break;
        }
    }
    h
}

/// Lanczos log-gamma (g=7, n=9), |error| < 1e-13 over the positive reals.
pub fn ln_gamma(x: f64) -> f64 {
    const COEF: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1_259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_572e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // reflection formula
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut acc = COEF[0];
    for (i, &c) in COEF.iter().enumerate().skip(1) {
        acc += c / (x + i as f64);
    }
    let t = x + 7.5;
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t
        + acc.ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64, tol: f64) {
        assert!((a - b).abs() < tol, "{a} vs {b}");
    }

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        close(s.mean, 3.0, 1e-12);
        close(s.min, 1.0, 1e-12);
        close(s.max, 5.0, 1e-12);
        close(s.p75, 4.0, 1e-12);
        close(s.std, 2.0f64.sqrt(), 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        close(percentile_sorted(&xs, 0.5), 25.0, 1e-12);
        close(percentile_sorted(&xs, 0.0), 10.0, 1e-12);
        close(percentile_sorted(&xs, 1.0), 40.0, 1e-12);
    }

    #[test]
    fn ln_gamma_known_values() {
        close(ln_gamma(1.0), 0.0, 1e-10);
        close(ln_gamma(2.0), 0.0, 1e-10);
        close(ln_gamma(5.0), 24.0f64.ln(), 1e-10); // gamma(5)=4!
        close(ln_gamma(0.5), std::f64::consts::PI.sqrt().ln(), 1e-10);
    }

    #[test]
    fn incomplete_beta_bounds_and_symmetry() {
        close(incomplete_beta(2.0, 3.0, 0.0), 0.0, 1e-12);
        close(incomplete_beta(2.0, 3.0, 1.0), 1.0, 1e-12);
        // I_x(a,b) = 1 - I_{1-x}(b,a)
        let x = 0.37;
        close(
            incomplete_beta(2.5, 1.5, x),
            1.0 - incomplete_beta(1.5, 2.5, 1.0 - x),
            1e-10,
        );
        // I_x(1,1) = x (uniform)
        close(incomplete_beta(1.0, 1.0, 0.42), 0.42, 1e-10);
    }

    #[test]
    fn student_t_sf_reference_values() {
        // scipy.stats.t.sf reference values
        close(student_t_sf(0.0, 10.0), 0.5, 1e-10);
        close(student_t_sf(1.812461, 10.0), 0.05, 1e-4); // t_{0.95,10}
        close(student_t_sf(2.228139, 10.0), 0.025, 1e-4); // t_{0.975,10}
        close(student_t_sf(1.959964, 1e6), 0.025, 1e-4); // ~normal
    }

    #[test]
    fn welch_identical_samples_p_one() {
        let a = [5.0, 5.1, 4.9, 5.0, 5.05, 4.95];
        let r = welch_t_test(&a, &a);
        close(r.t, 0.0, 1e-12);
        close(r.p, 1.0, 1e-9);
    }

    #[test]
    fn welch_clearly_different_samples() {
        let a = [1.0, 1.1, 0.9, 1.05, 0.95, 1.0, 1.02, 0.98];
        let b = [9.0, 9.1, 8.9, 9.05, 8.95, 9.0, 9.02, 8.98];
        let r = welch_t_test(&a, &b);
        assert!(r.p < 1e-10, "p = {}", r.p);
        assert!(r.t < 0.0);
    }

    #[test]
    fn welch_matches_scipy_example() {
        // scipy.stats.ttest_ind(a, b, equal_var=False)
        // -> t = -2.828090, p = 0.008583 (verified against scipy 1.x)
        let a = [
            27.5, 21.0, 19.0, 23.6, 17.0, 17.9, 16.9, 20.1, 21.9, 22.6,
            23.1, 19.6, 19.0, 21.7, 21.4,
        ];
        let b = [
            27.1, 22.0, 20.8, 23.4, 23.4, 23.5, 25.8, 22.0, 24.8, 20.2,
            21.9, 22.1, 22.9, 30.3, 23.9,
        ];
        let r = welch_t_test(&a, &b);
        close(r.t, -2.828090, 1e-5);
        close(r.p, 0.008583, 1e-5);
    }

    #[test]
    fn welch_constant_equal_samples() {
        let a = [3.0; 5];
        let r = welch_t_test(&a, &a);
        close(r.p, 1.0, 1e-12);
    }

    #[test]
    fn l2_distance_known() {
        close(l2_distance(&[0.0, 0.0], &[3.0, 4.0]), 5.0, 1e-12);
        close(l2_distance(&[1.0], &[1.0]), 0.0, 1e-12);
    }

    #[test]
    fn moments_vs_raw_agree() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [2.0, 4.0, 6.0, 8.0];
        let r1 = welch_t_test(&a, &b);
        let r2 = welch_t_test_from_moments(
            mean(&a), sample_variance(&a), 4,
            mean(&b), sample_variance(&b), 4,
        );
        close(r1.t, r2.t, 1e-12);
        close(r1.p, r2.p, 1e-12);
    }
}
