//! Multi-tenant orchestration: N tenant pipeline shards behind a
//! [`StreamRouter`], one shared knowledge plane, and a **single
//! amortized off-line analyze/train cycle over the union of all
//! tenants' backlogs** — the paper's cross-workload learning (§6.4,
//! "KERMIT retains a long-term memory of workloads") applied across
//! users: a class discovered in tenant A's traffic is classified in
//! tenant B's stream without B ever contributing a training window.
//!
//! Contrast with [`super::Coordinator`], which drives one stream and
//! one plug-in through the full Algorithm 1 tuning loop: this
//! coordinator scales the *identification* side (monitor → analyze →
//! knowledge) to many concurrent streams. Tuning is layered on top by
//! [`crate::tuning::TuningPlane`], which owns one `KermitPlugin` per
//! tenant sharing `db` and reading its tenant's context stream.
//!
//! The off-line cycle is the consolidated
//! [`super::offline_cycle::OfflineCycle`] — the same store → gate →
//! ZSL → retrain → transition routine the single-tenant coordinator
//! runs, so a multi-tenant deployment anticipates hybrids and names
//! transitions exactly like a single-tenant one (this used to be
//! silently skipped; pinned by `tests/tuning_plane.rs`).
//!
//! # Cadence
//!
//! By default one cycle runs per `offline_interval_windows × K` union
//! windows (amortized). [`CadencePolicy::Adaptive`] additionally
//! triggers an early cycle when any tenant's recent UNKNOWN rate
//! crosses a threshold — a new tenant (or a drifted signature, which
//! also stops classifying and so shows up as UNKNOWN pressure) gets its
//! time-to-label cut without retraining for quiet tenants.

use super::offline_cycle::OfflineCycle;
use super::CoordinatorConfig;
use crate::clustering::{DistanceProvider, NativeDistance};
use crate::features::ObservationWindow;
use crate::knowledge::{shared_db, SharedWorkloadDb, WorkloadDb};
use crate::ml::forest::RandomForest;
use crate::obs::Registry;
use crate::online::classifier::{GatedForestClassifier, WindowClassifier};
use crate::online::{ForestWindowClassifier, PluginStats, UNKNOWN};
use crate::stream::{
    interleave_round_robin, IngestConfig, IngestFrontEnd, IngestHandle,
    IngestSupervisor, PumpStats, RouterConfig, StreamRouter,
    SupervisorConfig, TenantHealth, TenantId, TenantSample,
};
use crate::util::rng::Rng;
use crate::workloadgen::{Sample, Trace};
use std::collections::BTreeMap;

/// When does the amortized off-line cycle run?
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum CadencePolicy {
    /// One cycle per `offline_interval_windows × K` union windows.
    #[default]
    FixedUnion,
    /// The fixed union interval PLUS early triggers: any tenant with at
    /// least `min_windows` windows observed since the last cycle whose
    /// UNKNOWN fraction is ≥ `unknown_rate` forces a cycle now. High
    /// UNKNOWN pressure is both the new-tenant signal and the drift
    /// suspicion proxy (a drifted signature stops matching the
    /// classifier's gate and degrades to UNKNOWN).
    Adaptive { unknown_rate: f64, min_windows: usize },
}

/// Summary of one multi-tenant run.
#[derive(Debug, Clone, Default)]
pub struct MultiTenantReport {
    pub windows_observed: usize,
    pub offline_runs: usize,
    pub workloads_known: usize,
    /// Per tenant: (tenant, windows with a known label, total windows).
    pub per_tenant: Vec<(TenantId, usize, usize)>,
    /// Per-tenant Algorithm-1 decision statistics (choice-kind counts).
    /// Empty unless a tuning plane drove plug-ins during the run — the
    /// identification-only coordinator has no plug-ins to report on.
    pub tenant_stats: Vec<(TenantId, PluginStats)>,
    /// Telemetry windows dropped by shard-log overflow (bounded-memory
    /// back-pressure; durable counts survive the drop itself).
    pub windows_dropped: u64,
    /// Knowledge-plane entries quarantined by the integrity audit.
    pub db_quarantined: usize,
    /// Per tenant per known label: mean L2 residual between the
    /// tenant's observed window means and the label's stored
    /// characterization. The drift-vs-new-tenant discriminator: a
    /// *drifting* tenant keeps matching a label while its residual
    /// climbs; a *new* tenant publishes UNKNOWN (no residual at all) —
    /// so `CadencePolicy::Adaptive` consumers can tell the two apart
    /// instead of treating all UNKNOWN pressure the same.
    pub tenant_residuals: Vec<(TenantId, u32, f64)>,
    /// Ingest-path health per supervised tenant (empty without an
    /// attached front-end, or before the first supervised pump).
    pub tenant_health: Vec<(TenantId, TenantHealth)>,
    /// No-progress drains the ingest supervisor retried with backoff.
    pub delivery_retries: u64,
    /// Healthy→Degraded transitions the supervisor recorded.
    pub degraded_events: u64,
}

impl MultiTenantReport {
    /// Fraction of all observed windows that published a known label.
    pub fn known_fraction(&self) -> f64 {
        let (known, total) = self
            .per_tenant
            .iter()
            .fold((0usize, 0usize), |(k, t), &(_, wk, wt)| {
                (k + wk, t + wt)
            });
        crate::obs::ratio(known as f64, total as f64)
    }

    /// Cluster-wide cache-hit ratio: cache hits over all tenants'
    /// requests pooled (0 when no plug-in stats were recorded).
    pub fn cluster_cache_hit_ratio(&self) -> f64 {
        let (hits, reqs) = self
            .tenant_stats
            .iter()
            .fold((0usize, 0usize), |(h, r), (_, s)| {
                (h + s.cache_hits, r + s.requests)
            });
        crate::obs::ratio(hits as f64, reqs as f64)
    }
}

/// The assembled multi-tenant identification loop.
pub struct MultiTenantCoordinator {
    pub config: CoordinatorConfig,
    /// Shared knowledge plane — one DB for every tenant.
    pub db: SharedWorkloadDb,
    router: StreamRouter,
    /// Analyze backlogs, kept per tenant so each tenant's windows stay
    /// contiguous and in arrival order: the off-line cycle concatenates
    /// them tenant-major, so the batch ChangeDetector sees at most one
    /// artificial boundary per tenant per cycle (the same cost as a
    /// plateau switch) instead of a boundary at every drain interleave.
    backlogs: BTreeMap<TenantId, Vec<ObservationWindow>>,
    windows_since_offline: usize,
    /// The consolidated off-line cycle state (shared routine with the
    /// single-tenant coordinator).
    pub cycle: OfflineCycle,
    /// Off-line cadence policy (see [`CadencePolicy`]).
    pub cadence: CadencePolicy,
    /// Per-tenant (unknown, total) window counts since the last cycle —
    /// the adaptive-cadence pressure signal.
    since_offline: BTreeMap<TenantId, (usize, usize)>,
    /// Per-tenant cursor over `TenantShard::contexts_published` (how
    /// many of the shard's contexts the cadence counters have folded
    /// in — an absolute count, immune to the capped log's truncation).
    ctx_cursor: BTreeMap<TenantId, u64>,
    rng: Rng,
    dist: Box<dyn DistanceProvider>,
    /// The latest union-trained shared model. Kept so a tenant joining
    /// *between* off-line cycles gets the current classifier at shard
    /// creation — the "knowledge from tenant A immediately serves
    /// tenant B" contract must not wait for the next retrain.
    trained_forest: Option<RandomForest>,
    /// Ditto for the transition classifier.
    trained_transition: Option<RandomForest>,
    /// Off-line cycles executed — the amortization observable: with N
    /// tenants this grows once per `offline_interval_windows * N`
    /// windows (plus any adaptive early triggers), not once per tenant
    /// interval.
    pub offline_runs: usize,
    /// Entries the knowledge-plane integrity audit has quarantined.
    pub db_quarantined: usize,
    /// Optional event-driven ingest front-end (see
    /// [`MultiTenantCoordinator::attach_ingest`]). `None` means
    /// producers call [`MultiTenantCoordinator::ingest`] directly.
    ingest: Option<IngestFrontEnd>,
    /// Ingest-path watchdogs (only fed by the supervised pump paths, so
    /// coordinators without an attached front-end never consult it).
    pub supervisor: IngestSupervisor,
    /// Per tenant per label: (summed L2 residual, window count) of
    /// observed window means against the stored characterization.
    residuals: BTreeMap<TenantId, BTreeMap<u32, (f64, u64)>>,
    /// Telemetry registry, when enabled: the router's shards carry
    /// per-tenant observe counters and `run_offline` records
    /// wall-clock cycle durations here.
    telemetry: Option<Registry>,
}

impl MultiTenantCoordinator {
    pub fn new(config: CoordinatorConfig) -> MultiTenantCoordinator {
        Self::with_distance(config, Box::new(NativeDistance))
    }

    pub fn with_distance(
        config: CoordinatorConfig,
        dist: Box<dyn DistanceProvider>,
    ) -> MultiTenantCoordinator {
        // the default TickDispatch policy fans busy shards out across
        // the persistent pool from 2 tenants up; a 1-tenant deployment
        // drains inline (no wakeup for an indivisible work item)
        let router = StreamRouter::new(RouterConfig {
            monitor: config.monitor.clone(),
            context_cap: 64,
            engine: config.discovery.engine,
            ..Default::default()
        });
        let rng = Rng::new(config.seed);
        MultiTenantCoordinator {
            config,
            db: shared_db(),
            router,
            backlogs: BTreeMap::new(),
            windows_since_offline: 0,
            cycle: OfflineCycle::new(400, 5),
            cadence: CadencePolicy::default(),
            since_offline: BTreeMap::new(),
            ctx_cursor: BTreeMap::new(),
            rng,
            dist,
            trained_forest: None,
            trained_transition: None,
            offline_runs: 0,
            db_quarantined: 0,
            ingest: None,
            supervisor: IngestSupervisor::new(SupervisorConfig::default()),
            residuals: BTreeMap::new(),
            telemetry: None,
        }
    }

    /// Enable telemetry: instrument every pipeline shard (current and
    /// future) with per-tenant observe counters and record off-line
    /// cycle durations into `reg`. Telemetry never changes what the
    /// loop decides or publishes.
    pub fn enable_telemetry(&mut self, reg: &Registry) {
        self.router.enable_telemetry(reg);
        self.telemetry = Some(reg.clone());
    }

    /// Bridge the coordinator's loop-health counters into `reg`:
    /// off-line cycle count, knowledge-plane size, window drops, the
    /// supervisor's health states, per-tenant ingest stats (when a
    /// front-end is attached) and per-tenant per-label residual-drift
    /// gauges.
    pub fn export_metrics(&self, reg: &Registry) {
        reg.counter(
            "kermit_coordinator_offline_runs_total",
            "Consolidated off-line cycles executed.",
            &[],
        )
        .set_total(self.offline_runs as u64);
        reg.counter(
            "kermit_stream_windows_dropped_total",
            "Windows dropped by capped shard logs.",
            &[],
        )
        .set_total(self.router.windows_dropped());
        reg.gauge(
            "kermit_knowledge_workloads_known",
            "Workload classes currently held by the knowledge plane.",
            &[],
        )
        .set(self.db.read().unwrap().len() as f64);
        self.supervisor.export_metrics(reg);
        if let Some(h) = self.ingest_handle() {
            for (t, st) in h.stats() {
                st.export_metrics(reg, &t.0.to_string());
            }
        }
        for (t, by_label) in &self.residuals {
            let tenant = t.0.to_string();
            for (label, (sum, n)) in by_label {
                reg.gauge(
                    "kermit_coordinator_residual",
                    "Mean L2 residual of observed window means against \
                     the stored characterization.",
                    &[
                        ("tenant", tenant.as_str()),
                        ("label", label.to_string().as_str()),
                    ],
                )
                .set(sum / (*n).max(1) as f64);
            }
        }
    }

    /// Attach an event-driven ingest front-end and return a producer
    /// handle. The front-end's monitor config and engine are overridden
    /// with the coordinator's own, so (a) windows batched off-thread
    /// are bit-identical to direct [`MultiTenantCoordinator::ingest`]
    /// and (b) batching, router ticks, and offline cycles all share the
    /// one work-stealing executor instead of competing.
    pub fn attach_ingest(&mut self, mut config: IngestConfig) -> IngestHandle {
        config.monitor = self.config.monitor.clone();
        config.engine = self.router.config.engine;
        let fe = IngestFrontEnd::new(config);
        let handle = fe.handle();
        self.ingest = Some(fe);
        handle
    }

    /// A fresh producer handle for the attached front-end (`None` if
    /// [`MultiTenantCoordinator::attach_ingest`] was never called).
    pub fn ingest_handle(&self) -> Option<IngestHandle> {
        self.ingest.as_ref().map(|fe| fe.handle())
    }

    /// Drain the attached front-end's queues through the batchers into
    /// the router, then run one [`MultiTenantCoordinator::tick`] (so
    /// the offline cadence advances exactly as with direct ingest).
    /// Returns the pump stats plus the tick's observed-window count;
    /// `None` if no front-end is attached.
    pub fn pump_ingest(&mut self) -> Option<(PumpStats, usize)> {
        self.pump_ingest_supervised(&[])
    }

    /// [`pump_ingest`](MultiTenantCoordinator::pump_ingest) with the
    /// supervision layer in the loop: lanes in `wedged` (a consumer
    /// fault — see `stream::fault::WedgedLane`) and lanes the
    /// supervisor's retry backoff parked are skipped this pump, and
    /// every lane's outcome is scored by the per-tenant watchdogs.
    /// With no wedged lanes and a healthy run this is behaviour-
    /// identical to the plain pump (nothing is skipped, every lane
    /// scores healthy).
    pub fn pump_ingest_supervised(
        &mut self,
        wedged: &[TenantId],
    ) -> Option<(PumpStats, usize)> {
        let mut fe = self.ingest.take()?;
        // shards must exist (with the current shared model installed)
        // before their first windows land — same contract as ingest()
        for t in fe.tenant_ids() {
            self.ensure_tenant(t);
        }
        let mut skip: Vec<TenantId> = wedged.to_vec();
        for t in self.supervisor.backed_off() {
            if !skip.contains(&t) {
                skip.push(t);
            }
        }
        let (stats, lanes) = fe.drain_gated(&mut self.router, &skip);
        self.supervisor.observe(&lanes);
        self.ingest = Some(fe);
        let n = self.tick();
        Some((stats, n))
    }

    /// Transport reconcile: clear every retry backoff, drain the
    /// queues, write off all outstanding sequence gaps (releasing
    /// parked samples), tick, and re-arm every tenant the supervisor
    /// had demoted. After this no lane is wedged and no tenant stays
    /// degraded — the heal-time settlement the chaos scenarios assert.
    pub fn reconcile_ingest(&mut self) -> Option<(PumpStats, usize)> {
        let mut fe = self.ingest.take()?;
        for t in fe.tenant_ids() {
            self.ensure_tenant(t);
        }
        self.supervisor.reset_backoffs();
        let stats = fe.flush_transport(&mut self.router);
        self.ingest = Some(fe);
        let n = self.tick();
        self.supervisor.settle();
        Some((stats, n))
    }

    /// Is `t`'s ingest path impaired (Degraded or Healing)? Always
    /// false without an attached front-end — direct ingest has no
    /// transport to supervise.
    pub fn ingest_impaired(&self, t: TenantId) -> bool {
        self.ingest.is_some() && self.supervisor.is_impaired(t)
    }

    /// Most recent known label tenant `t` published (the stale-but-safe
    /// label served while the tenant's transport is impaired).
    pub fn last_known_label(&self, t: TenantId) -> Option<u32> {
        self.router.shard(t).and_then(|s| s.last_known_label())
    }

    pub fn router(&self) -> &StreamRouter {
        &self.router
    }

    pub fn router_mut(&mut self) -> &mut StreamRouter {
        &mut self.router
    }

    /// True once a retrain has produced a transition classifier (the
    /// consolidation observable: the old multi-tenant cycle never did).
    pub fn has_transition_model(&self) -> bool {
        self.trained_transition.is_some()
    }

    /// Snapshot of the current shared model as an installable
    /// classifier (None before the first retrain).
    fn shared_classifier(&self) -> Option<Box<dyn WindowClassifier + Send>> {
        let forest = self.trained_forest.as_ref()?;
        let db = self.db.read().unwrap();
        Some(Box::new(GatedForestClassifier::from_db(
            forest.clone(),
            &db,
            self.config.centroid_gate,
            self.config.min_confidence,
        )))
    }

    /// Ensure tenant `t` has a shard; a shard created after a retrain
    /// receives the current shared model (and transition classifier)
    /// immediately.
    pub fn ensure_tenant(&mut self, t: TenantId) {
        if self.router.shard(t).is_none() {
            let classifier = self.shared_classifier();
            let transition = self.trained_transition.clone();
            let conf = self.config.min_confidence;
            let shard = self.router.add_tenant(t);
            if let Some(c) = classifier {
                shard.pipeline.set_classifier(c);
            }
            if let Some(tf) = transition {
                shard.pipeline.set_transition_classifier(Box::new(
                    ForestWindowClassifier::new(tf, conf),
                ));
            }
        }
    }

    /// Buffer one tenant's samples (windows close in the shard; nothing
    /// observes until [`MultiTenantCoordinator::tick`]).
    pub fn ingest(&mut self, t: TenantId, samples: &[Sample]) {
        self.ensure_tenant(t);
        self.router.ingest(t, samples);
    }

    /// Buffer one tenant-tagged sample from a multiplexed stream.
    pub fn ingest_tagged(&mut self, ts: &TenantSample) {
        self.ensure_tenant(ts.tenant);
        self.router.ingest_tagged(ts);
    }

    /// One loop turn: observe every shard's pending windows (engine-
    /// parallel over tenants), fold the observed windows into the union
    /// backlog, and run the amortized off-line cycle when the union
    /// interval elapses — or earlier, when the adaptive cadence sees a
    /// tenant under UNKNOWN pressure. Returns windows observed this turn.
    pub fn tick(&mut self) -> usize {
        let n = self.router.tick();
        for (t, ws) in self.router.take_observed() {
            self.note_residuals(t, &ws);
            self.backlogs.entry(t).or_default().extend(ws);
        }
        self.update_cadence_counters();
        self.windows_since_offline += n;
        let interval = self.config.offline_interval_windows
            * self.router.n_tenants().max(1);
        if self.windows_since_offline >= interval || self.adaptive_due() {
            self.run_offline();
        }
        n
    }

    /// Accumulate per-label residual distances for one tenant's freshly
    /// observed windows: how far each window's feature mean sits from
    /// the stored characterization of the label the shard assigned it.
    /// Contexts and observed windows are published 1:1 in observe
    /// order, so the shard's context-log tail aligns with the window
    /// batch (truncated bursts just lose their oldest pairs).
    fn note_residuals(&mut self, t: TenantId, ws: &[ObservationWindow]) {
        let Some(shard) = self.router.shard(t) else { return };
        let ctxs = &shard.contexts;
        let k = ws.len().min(ctxs.len());
        if k == 0 {
            return;
        }
        let db = self.db.read().unwrap();
        let pairs =
            ctxs[ctxs.len() - k..].iter().zip(ws[ws.len() - k..].iter());
        let mut hits: Vec<(u32, f64)> = Vec::new();
        for (c, w) in pairs {
            if !c.is_known() {
                continue;
            }
            let Some(e) = db.get(c.current_label) else { continue };
            // compare over the window-mean features (a characterization
            // over analytic windows carries extra width; zip stops at
            // the shared prefix, which is exactly the means)
            let d = w
                .mean
                .iter()
                .zip(e.characterization.mean_vector().iter())
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                .sqrt();
            if d.is_finite() {
                hits.push((c.current_label, d));
            }
        }
        drop(db);
        for (label, d) in hits {
            let slot = self
                .residuals
                .entry(t)
                .or_default()
                .entry(label)
                .or_insert((0.0, 0));
            slot.0 += d;
            slot.1 += 1;
        }
    }

    /// Fold newly published contexts into the per-tenant UNKNOWN
    /// counters the adaptive cadence reads.
    fn update_cadence_counters(&mut self) {
        if !matches!(self.cadence, CadencePolicy::Adaptive { .. }) {
            return;
        }
        for t in self.router.tenants() {
            let shard = self.router.shard(t).unwrap();
            let published = shard.contexts_published;
            let seen = self.ctx_cursor.entry(t).or_insert(0);
            let fresh = (published - *seen) as usize;
            *seen = published;
            if fresh == 0 {
                continue;
            }
            // the capped log may have truncated part of an extreme
            // burst; whatever survived is the newest suffix
            let avail = shard.contexts.len();
            let visible = fresh.min(avail);
            let truncated = fresh - visible;
            let counts = self.since_offline.entry(t).or_insert((0, 0));
            // truncated contexts are uninspectable — count them toward
            // the total only, which can only *delay* a trigger, never
            // fire one spuriously
            counts.1 += truncated;
            for c in &shard.contexts[avail - visible..] {
                counts.1 += 1;
                if c.current_label == UNKNOWN {
                    counts.0 += 1;
                }
            }
        }
    }

    /// Would the adaptive cadence trigger a cycle right now?
    pub fn adaptive_due(&self) -> bool {
        match self.cadence {
            CadencePolicy::FixedUnion => false,
            CadencePolicy::Adaptive { unknown_rate, min_windows } => self
                .since_offline
                .values()
                .any(|&(unknown, total)| {
                    total >= min_windows.max(1)
                        && unknown as f64 / total as f64 >= unknown_rate
                }),
        }
    }

    /// The single amortized off-line cycle: the consolidated
    /// [`OfflineCycle::run`] over the union backlog (one discovery pass,
    /// one drift check, ZSL synthesis, one retrain + transition-forest
    /// fit), then the same shared models installed on every tenant
    /// shard. The DB write lock covers discovery + synthesis only — the
    /// expensive forest fits run lock-free so concurrent tenant plug-ins
    /// keep serving read-lock cache lookups throughout the cycle.
    /// Sweep the knowledge plane for structurally corrupt entries and
    /// quarantine them (see `WorkloadDb::audit_quarantine`). Returns the
    /// labels quarantined by this sweep.
    pub fn audit_knowledge(&mut self) -> Vec<u32> {
        let bad = self.db.write().unwrap().audit_quarantine();
        self.db_quarantined += bad.len();
        bad
    }

    /// Replace the shared knowledge plane's contents with a recovered
    /// (or imported) DB. Every holder of the shared `Arc` — plug-ins,
    /// shards, classifiers — sees the restored state at once; this is
    /// how a restarted deployment starts warm instead of relearning
    /// from job one.
    pub fn install_db(&mut self, db: WorkloadDb) {
        *self.db.write().unwrap() = db;
    }

    pub fn run_offline(&mut self) {
        // wall-clock only ever feeds the telemetry histogram — never a
        // decision, so determinism is untouched
        let cycle_start = self
            .telemetry
            .is_some()
            .then(std::time::Instant::now);
        self.windows_since_offline = 0;
        // integrity first: a corrupt entry (NaN centroid, off-grid
        // config) must not poison this cycle's matching or synthesis
        self.audit_knowledge();
        let total: usize = self.backlogs.values().map(|v| v.len()).sum();
        if total < 8 {
            // too little data to do anything: keep the adaptive-cadence
            // pressure counters so the trigger re-fires once the union
            // backlog is big enough, instead of making a pressured
            // tenant re-earn min_windows from scratch
            self.record_cycle_duration(cycle_start);
            return;
        }
        self.since_offline.clear();
        // concatenate tenant-major: each tenant's run stays contiguous
        let mut union: Vec<ObservationWindow> = Vec::with_capacity(total);
        for ws in self.backlogs.values() {
            union.extend(ws.iter().cloned());
        }
        let outcome = self.cycle.run(
            &union,
            &self.db,
            &self.config,
            &mut self.rng,
            self.dist.as_ref(),
        );
        self.offline_runs += 1;

        if let Some(models) = outcome.models {
            self.trained_forest = Some(models.forest.clone());
            if models.transition_forest.is_some() {
                // keep the previous transition model when this retrain
                // had too few transition types to fit one — existing
                // shards keep theirs (install below is skipped), so
                // late joiners must match them, not regress to None
                self.trained_transition = models.transition_forest.clone();
            }
            let gate = self.config.centroid_gate;
            let conf = self.config.min_confidence;
            // one shared model, N shards: every tenant classifies with
            // the union-trained forest gated by the shared DB centroids
            // (read lock only — centroids are not mutated here)
            let db = self.db.read().unwrap();
            self.router.install_classifiers(|_t| {
                Box::new(GatedForestClassifier::from_db(
                    models.forest.clone(),
                    &db,
                    gate,
                    conf,
                ))
            });
            if let Some(tforest) = &models.transition_forest {
                self.router.install_transition_classifiers(|_t| {
                    Box::new(ForestWindowClassifier::new(
                        tforest.clone(),
                        conf,
                    ))
                });
            }
        }

        // keep a characterization tail per tenant so recurring
        // workloads re-match next cycle, without unbounded growth
        let keep = self.config.offline_interval_windows * 2;
        for ws in self.backlogs.values_mut() {
            if ws.len() > keep {
                let cut = ws.len() - keep;
                ws.drain(..cut);
            }
        }
        self.record_cycle_duration(cycle_start);
    }

    fn record_cycle_duration(&self, start: Option<std::time::Instant>) {
        if let (Some(reg), Some(t0)) = (&self.telemetry, start) {
            reg.histogram(
                "kermit_coordinator_offline_cycle_seconds",
                "Wall-clock duration of off-line analyze/train cycles.",
                &[],
                &[0.001, 0.005, 0.01, 0.05, 0.1, 0.5, 1.0],
            )
            .observe(t0.elapsed().as_secs_f64());
        }
    }

    /// Drive interleaved per-tenant traces through the loop: trace `k`
    /// belongs to `TenantId(k)`, samples arrive in round-robin bursts of
    /// `burst`, and the router ticks every `tick_every` samples.
    pub fn run_interleaved(
        &mut self,
        traces: &[Trace],
        burst: usize,
        tick_every: usize,
    ) -> MultiTenantReport {
        assert!(tick_every > 0);
        let mixed = interleave_round_robin(traces, burst);
        let mut observed = 0usize;
        for (i, ts) in mixed.iter().enumerate() {
            self.ingest_tagged(ts);
            if (i + 1) % tick_every == 0 {
                observed += self.tick();
            }
        }
        observed += self.tick();
        self.report(observed)
    }

    /// Snapshot report over the shards' full context logs.
    pub fn report(&self, windows_observed: usize) -> MultiTenantReport {
        let per_tenant = self
            .router
            .tenants()
            .into_iter()
            .map(|t| {
                let log = self.router.shard(t).unwrap().label_log();
                let known =
                    log.iter().filter(|&&l| l != UNKNOWN).count();
                (t, known, log.len())
            })
            .collect();
        let tenant_residuals = self
            .residuals
            .iter()
            .flat_map(|(t, by_label)| {
                by_label.iter().map(|(label, (sum, n))| {
                    (*t, *label, sum / (*n).max(1) as f64)
                })
            })
            .collect();
        MultiTenantReport {
            windows_observed,
            offline_runs: self.offline_runs,
            workloads_known: self.db.read().unwrap().len(),
            per_tenant,
            tenant_stats: Vec::new(),
            windows_dropped: self.router.windows_dropped(),
            db_quarantined: self.db_quarantined,
            tenant_residuals,
            tenant_health: self.supervisor.healths(),
            delivery_retries: self.supervisor.delivery_retries,
            degraded_events: self.supervisor.degraded_events,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloadgen::{tour_schedule, Generator};

    fn trace(seed: u64, classes: &[u32], dur: usize) -> Trace {
        let mut g = Generator::with_default_config(seed);
        g.generate(&tour_schedule(dur, classes))
    }

    #[test]
    fn knowledge_discovered_from_tenant_a_serves_tenant_b() {
        let mut cfg = CoordinatorConfig::default();
        cfg.offline_interval_windows = 40; // manual off-line only
        let mut coord = MultiTenantCoordinator::new(cfg);
        let (a, b) = (TenantId(0), TenantId(1));

        // phase 1: only tenant A streams (classes 0 then 5)
        let ta = trace(1, &[0, 5], 240);
        coord.ingest(a, &ta.samples);
        coord.tick();
        coord.run_offline();
        assert_eq!(coord.offline_runs, 1);
        let known = coord.db.read().unwrap().len();
        assert!(known >= 2, "discovery found {known} classes");
        let a_log = coord.router().shard(a).unwrap().label_log();
        // A's shard itself classifies after the retrain installs — its
        // past windows were observed untrained, so look forward instead:
        // stream one more class-5 plateau through A
        let ta2 = trace(2, &[5], 150);
        coord.ingest(a, &ta2.samples);
        coord.tick();
        let a_log2 = coord.router().shard(a).unwrap().label_log();
        let a_label5 = *a_log2[a_log.len()..]
            .iter()
            .rev()
            .find(|&&l| l != UNKNOWN)
            .expect("tenant A never classified class 5");

        // phase 2: tenant B streams class 5 for the first time — no
        // off-line cycle in between, so any knowledge must have come
        // from A's traffic through the shared plane
        let offline_before = coord.offline_runs;
        let tb = trace(3, &[5], 150);
        coord.ingest(b, &tb.samples);
        coord.tick();
        assert_eq!(coord.offline_runs, offline_before, "B triggered offline");
        let b_known: Vec<u32> = coord
            .router()
            .shard(b)
            .unwrap()
            .label_log()
            .into_iter()
            .filter(|&l| l != UNKNOWN)
            .collect();
        assert!(!b_known.is_empty(), "tenant B classified nothing");
        assert!(
            b_known.iter().all(|&l| l == a_label5),
            "B labels {b_known:?} != A's class-5 label {a_label5}"
        );
    }

    #[test]
    fn offline_cycles_amortize_over_tenants() {
        let mut cfg = CoordinatorConfig::default();
        cfg.offline_interval_windows = 4;
        let mut coord = MultiTenantCoordinator::new(cfg);
        let traces: Vec<Trace> = (0..3)
            .map(|k| trace(10 + k, &[k as u32], 4 * 30))
            .collect();
        // 3 tenants x 4 windows each = 12 windows = exactly one union
        // interval (4 * 3) -> exactly one off-line cycle, not three
        let report = coord.run_interleaved(&traces, 30, 90);
        assert_eq!(report.windows_observed, 12);
        assert_eq!(report.offline_runs, 1, "cycles did not amortize");
        assert_eq!(report.per_tenant.len(), 3);
    }

    #[test]
    fn interleaved_multi_tenant_run_classifies_most_windows() {
        let mut cfg = CoordinatorConfig::default();
        cfg.offline_interval_windows = 8;
        let mut coord = MultiTenantCoordinator::new(cfg);
        // three tenants on distinct class rotations, long enough for
        // several amortized cycles
        let traces: Vec<Trace> = vec![
            trace(20, &[0, 3, 0, 3], 180),
            trace(21, &[3, 5, 3, 5], 180),
            trace(22, &[5, 0, 5, 0], 180),
        ];
        let report = coord.run_interleaved(&traces, 15, 120);
        assert!(report.offline_runs >= 2, "{report:?}");
        assert!(report.workloads_known >= 3, "{report:?}");
        // after warm-up the shared model serves every tenant
        assert!(
            report.known_fraction() > 0.4,
            "known fraction {:.2} ({report:?})",
            report.known_fraction()
        );
        // cross-tenant consistency: the shared model must name a fresh
        // class-3 plateau identically for every tenant — including
        // tenant 2, which never contributed a class-3 window (freeze
        // the off-line cadence so the model can't change mid-check)
        coord.config.offline_interval_windows = 1_000_000;
        let follow = trace(23, &[3], 150);
        let mut labels = Vec::new();
        for t in coord.router().tenants() {
            let before =
                coord.router().shard(t).unwrap().label_log().len();
            coord.ingest(t, &follow.samples);
            coord.tick();
            let log = coord.router().shard(t).unwrap().label_log();
            if let Some(&l) =
                log[before..].iter().rev().find(|&&l| l != UNKNOWN)
            {
                labels.push(l);
            }
        }
        assert!(labels.len() >= 2, "too few tenants classified: {labels:?}");
        assert!(
            labels.windows(2).all(|p| p[0] == p[1]),
            "tenants disagree on the same class: {labels:?}"
        );
    }

    #[test]
    fn multi_cycle_runs_zsl_and_trains_transitions() {
        // the consolidation pin at the unit level: one multi-tenant
        // off-line cycle must synthesize ZSL classes and (with >= 2
        // transition types in the backlog) train a transition model —
        // the two steps the pre-consolidation cycle silently skipped
        let mut cfg = CoordinatorConfig::default();
        cfg.offline_interval_windows = 1_000_000; // manual cycles only
        let mut coord = MultiTenantCoordinator::new(cfg);
        let t0 = trace(5, &[0, 5, 0, 5], 150);
        coord.ingest(TenantId(0), &t0.samples);
        coord.tick();
        coord.run_offline();
        assert_eq!(coord.offline_runs, 1);
        assert!(
            coord.db.read().unwrap().entries().any(|e| e.synthetic),
            "multi-tenant cycle did not synthesize ZSL classes"
        );
        assert!(
            coord.has_transition_model(),
            "multi-tenant cycle did not train a transition classifier"
        );
        // a late-joining tenant's fresh shard gets both models installed
        coord.ensure_tenant(TenantId(7));
        let shard = coord.router().shard(TenantId(7)).unwrap();
        assert_eq!(shard.pending_windows(), 0);
    }

    #[test]
    fn adaptive_cadence_triggers_early_for_unknown_pressure() {
        let mut cfg = CoordinatorConfig::default();
        // fixed interval far away: only the adaptive path can trigger
        cfg.offline_interval_windows = 1_000_000;
        let mut coord = MultiTenantCoordinator::new(cfg);
        coord.cadence =
            CadencePolicy::Adaptive { unknown_rate: 0.6, min_windows: 4 };

        // a brand-new tenant streams an undiscovered class: everything
        // is UNKNOWN, so the cycle must fire well before the fixed
        // interval
        let t0 = trace(30, &[0, 5], 240);
        coord.ingest(TenantId(0), &t0.samples);
        coord.tick();
        assert!(coord.offline_runs >= 1, "adaptive cadence never fired");
        let runs_after_learning = coord.offline_runs;

        // now a quiet phase: the same tenant replays a class the model
        // already knows — the UNKNOWN rate stays low, so no extra
        // cycles fire (quiet tenants don't pay retrains)
        let t1 = trace(31, &[5], 150);
        coord.ingest(TenantId(0), &t1.samples);
        coord.tick();
        let report = coord.report(0);
        let (_, known, total) = report.per_tenant[0];
        assert!(
            total > 0 && known > 0,
            "follow-up plateau never classified: {report:?}"
        );
        assert!(
            coord.offline_runs <= runs_after_learning + 1,
            "quiet tenant kept triggering cycles: {} -> {}",
            runs_after_learning,
            coord.offline_runs
        );
    }

    #[test]
    fn report_aggregates_tenant_stats() {
        let mut report = MultiTenantReport::default();
        assert_eq!(report.cluster_cache_hit_ratio(), 0.0);
        let mut a = PluginStats::default();
        a.requests = 10;
        a.cache_hits = 6;
        let mut b = PluginStats::default();
        b.requests = 10;
        b.cache_hits = 2;
        report.tenant_stats =
            vec![(TenantId(0), a), (TenantId(1), b)];
        assert!((report.cluster_cache_hit_ratio() - 0.4).abs() < 1e-12);
    }
}
