//! Multi-tenant orchestration: N tenant pipeline shards behind a
//! [`StreamRouter`], one shared knowledge plane, and a **single
//! amortized off-line analyze/train cycle over the union of all
//! tenants' backlogs** — the paper's cross-workload learning (§6.4,
//! "KERMIT retains a long-term memory of workloads") applied across
//! users: a class discovered in tenant A's traffic is classified in
//! tenant B's stream without B ever contributing a training window.
//!
//! Contrast with [`super::Coordinator`], which drives one stream and
//! one plug-in through the full Algorithm 1 tuning loop: this
//! coordinator scales the *identification* side (monitor → analyze →
//! knowledge) to many concurrent streams. Tuning stays per-tenant — a
//! plug-in instance per tenant can share `db` and read its tenant's
//! context stream from the router's bus.

use super::CoordinatorConfig;
use crate::clustering::{DistanceProvider, NativeDistance};
use crate::features::{zero_analytic, ObservationWindow};
use crate::knowledge::{shared_db, SharedWorkloadDb};
use crate::linalg::Matrix;
use crate::ml::forest::RandomForest;
use crate::ml::Dataset;
use crate::offline::{discover, ClusterOutcome};
use crate::online::classifier::{GatedForestClassifier, WindowClassifier};
use crate::online::UNKNOWN;
use crate::stream::{
    interleave_round_robin, RouterConfig, StreamRouter, TenantId,
    TenantSample,
};
use crate::util::rng::Rng;
use crate::workloadgen::{Sample, Trace};
use std::collections::BTreeMap;

/// Summary of one multi-tenant run.
#[derive(Debug, Clone, Default)]
pub struct MultiTenantReport {
    pub windows_observed: usize,
    pub offline_runs: usize,
    pub workloads_known: usize,
    /// Per tenant: (tenant, windows with a known label, total windows).
    pub per_tenant: Vec<(TenantId, usize, usize)>,
}

impl MultiTenantReport {
    /// Fraction of all observed windows that published a known label.
    pub fn known_fraction(&self) -> f64 {
        let (known, total) = self
            .per_tenant
            .iter()
            .fold((0usize, 0usize), |(k, t), &(_, wk, wt)| {
                (k + wk, t + wt)
            });
        if total == 0 {
            0.0
        } else {
            known as f64 / total as f64
        }
    }
}

/// The assembled multi-tenant identification loop.
pub struct MultiTenantCoordinator {
    pub config: CoordinatorConfig,
    /// Shared knowledge plane — one DB for every tenant.
    pub db: SharedWorkloadDb,
    router: StreamRouter,
    /// Analyze backlogs, kept per tenant so each tenant's windows stay
    /// contiguous and in arrival order: the off-line cycle concatenates
    /// them tenant-major, so the batch ChangeDetector sees at most one
    /// artificial boundary per tenant per cycle (the same cost as a
    /// plateau switch) instead of a boundary at every drain interleave.
    backlogs: BTreeMap<TenantId, Vec<ObservationWindow>>,
    windows_since_offline: usize,
    /// Cumulative per-label training store over the union stream.
    training_store: BTreeMap<u32, Matrix>,
    store_cap: usize,
    ticks_since_train: usize,
    /// Retrain cadence in off-line cycles (see `Coordinator::retrain_every`).
    pub retrain_every: usize,
    rng: Rng,
    dist: Box<dyn DistanceProvider>,
    /// The latest union-trained shared model. Kept so a tenant joining
    /// *between* off-line cycles gets the current classifier at shard
    /// creation — the "knowledge from tenant A immediately serves
    /// tenant B" contract must not wait for the next retrain.
    trained_forest: Option<RandomForest>,
    /// Off-line cycles executed — the amortization observable: with N
    /// tenants this grows once per `offline_interval_windows * N`
    /// windows, not once per tenant interval.
    pub offline_runs: usize,
}

impl MultiTenantCoordinator {
    pub fn new(config: CoordinatorConfig) -> MultiTenantCoordinator {
        Self::with_distance(config, Box::new(NativeDistance))
    }

    pub fn with_distance(
        config: CoordinatorConfig,
        dist: Box<dyn DistanceProvider>,
    ) -> MultiTenantCoordinator {
        // the default TickDispatch policy fans busy shards out across
        // the persistent pool from 2 tenants up; a 1-tenant deployment
        // drains inline (no wakeup for an indivisible work item)
        let router = StreamRouter::new(RouterConfig {
            monitor: config.monitor.clone(),
            context_cap: 64,
            engine: config.discovery.engine,
            ..Default::default()
        });
        let rng = Rng::new(config.seed);
        MultiTenantCoordinator {
            config,
            db: shared_db(),
            router,
            backlogs: BTreeMap::new(),
            windows_since_offline: 0,
            training_store: BTreeMap::new(),
            store_cap: 400,
            ticks_since_train: 0,
            retrain_every: 5,
            rng,
            dist,
            trained_forest: None,
            offline_runs: 0,
        }
    }

    pub fn router(&self) -> &StreamRouter {
        &self.router
    }

    pub fn router_mut(&mut self) -> &mut StreamRouter {
        &mut self.router
    }

    /// Snapshot of the current shared model as an installable
    /// classifier (None before the first retrain).
    fn shared_classifier(&self) -> Option<Box<dyn WindowClassifier + Send>> {
        let forest = self.trained_forest.as_ref()?;
        let db = self.db.read().unwrap();
        Some(Box::new(GatedForestClassifier::from_db(
            forest.clone(),
            &db,
            self.config.centroid_gate,
            self.config.min_confidence,
        )))
    }

    /// Ensure tenant `t` has a shard; a shard created after a retrain
    /// receives the current shared model immediately.
    pub fn ensure_tenant(&mut self, t: TenantId) {
        if self.router.shard(t).is_none() {
            let classifier = self.shared_classifier();
            let shard = self.router.add_tenant(t);
            if let Some(c) = classifier {
                shard.pipeline.set_classifier(c);
            }
        }
    }

    /// Buffer one tenant's samples (windows close in the shard; nothing
    /// observes until [`MultiTenantCoordinator::tick`]).
    pub fn ingest(&mut self, t: TenantId, samples: &[Sample]) {
        self.ensure_tenant(t);
        self.router.ingest(t, samples);
    }

    /// Buffer one tenant-tagged sample from a multiplexed stream.
    pub fn ingest_tagged(&mut self, ts: &TenantSample) {
        self.ensure_tenant(ts.tenant);
        self.router.ingest_tagged(ts);
    }

    /// One loop turn: observe every shard's pending windows (engine-
    /// parallel over tenants), fold the observed windows into the union
    /// backlog, and run the amortized off-line cycle when the union
    /// interval elapses. Returns windows observed this turn.
    pub fn tick(&mut self) -> usize {
        let n = self.router.tick();
        for (t, ws) in self.router.take_observed() {
            self.backlogs.entry(t).or_default().extend(ws);
        }
        self.windows_since_offline += n;
        let interval = self.config.offline_interval_windows
            * self.router.n_tenants().max(1);
        if self.windows_since_offline >= interval {
            self.run_offline();
        }
        n
    }

    /// The single amortized off-line cycle: Algorithm 2 over the union
    /// backlog (one discovery pass, one drift check, one DB write-lock
    /// hold), then one retrain installing the same shared model on every
    /// tenant shard.
    ///
    /// This mirrors `Coordinator::run_offline`'s store-accumulate /
    /// gate / retrain shape but deliberately omits ZSL synthesis and
    /// transition-classifier training for now (ROADMAP: per-tenant
    /// tuning plane names the consolidation of the two cycles).
    pub fn run_offline(&mut self) {
        self.windows_since_offline = 0;
        let total: usize = self.backlogs.values().map(|v| v.len()).sum();
        if total < 8 {
            return;
        }
        // concatenate tenant-major: each tenant's run stays contiguous
        let mut union: Vec<ObservationWindow> = Vec::with_capacity(total);
        for ws in self.backlogs.values() {
            union.extend(ws.iter().cloned());
        }
        // the write lock covers discovery only — the expensive retrain
        // below runs lock-free so concurrent tenant plug-ins keep
        // serving read-lock cache lookups throughout the cycle
        let report = {
            let mut db = self.db.write().unwrap();
            discover(
                &union,
                &mut db,
                &self.config.discovery,
                self.dist.as_ref(),
            )
        };
        self.offline_runs += 1;

        // cumulative per-label training store over the union stream
        let mut analytic_buf = zero_analytic();
        for (w, label) in union.iter().zip(&report.window_labels) {
            if let Some(l) = label {
                let rows = self.training_store.entry(*l).or_default();
                w.fill_analytic(&mut analytic_buf);
                rows.push_row(&analytic_buf);
                if rows.n_rows() > self.store_cap {
                    let excess = rows.n_rows() - self.store_cap;
                    rows.remove_first_rows(excess);
                }
            }
        }

        // retrain gating, as in the single-tenant coordinator: only on
        // label-set changes or the refresher interval
        self.ticks_since_train += 1;
        let label_set_changed = report
            .outcomes
            .iter()
            .any(|o| !matches!(o, ClusterOutcome::Matched { .. }));
        let must_train = label_set_changed
            || self.ticks_since_train >= self.retrain_every;

        if !self.training_store.is_empty() && must_train {
            self.ticks_since_train = 0;
            let mut data = Dataset::new();
            for (l, rows) in &self.training_store {
                for r in rows.iter_rows() {
                    data.push(r, *l);
                }
            }
            let forest = RandomForest::fit_with(
                &data,
                self.config.training.forest.clone(),
                &mut self.rng,
                self.config.discovery.engine,
            );
            self.trained_forest = Some(forest.clone());
            let gate = self.config.centroid_gate;
            let conf = self.config.min_confidence;
            // one shared model, N shards: every tenant classifies with
            // the union-trained forest gated by the shared DB centroids
            // (read lock only — centroids are not mutated here)
            let db = self.db.read().unwrap();
            self.router.install_classifiers(|_t| {
                Box::new(GatedForestClassifier::from_db(
                    forest.clone(),
                    &db,
                    gate,
                    conf,
                ))
            });
        }

        // keep a characterization tail per tenant so recurring
        // workloads re-match next cycle, without unbounded growth
        let keep = self.config.offline_interval_windows * 2;
        for ws in self.backlogs.values_mut() {
            if ws.len() > keep {
                let cut = ws.len() - keep;
                ws.drain(..cut);
            }
        }
    }

    /// Drive interleaved per-tenant traces through the loop: trace `k`
    /// belongs to `TenantId(k)`, samples arrive in round-robin bursts of
    /// `burst`, and the router ticks every `tick_every` samples.
    pub fn run_interleaved(
        &mut self,
        traces: &[Trace],
        burst: usize,
        tick_every: usize,
    ) -> MultiTenantReport {
        assert!(tick_every > 0);
        let mixed = interleave_round_robin(traces, burst);
        let mut observed = 0usize;
        for (i, ts) in mixed.iter().enumerate() {
            self.ingest_tagged(ts);
            if (i + 1) % tick_every == 0 {
                observed += self.tick();
            }
        }
        observed += self.tick();
        self.report(observed)
    }

    /// Snapshot report over the shards' full context logs.
    pub fn report(&self, windows_observed: usize) -> MultiTenantReport {
        let per_tenant = self
            .router
            .tenants()
            .into_iter()
            .map(|t| {
                let log = self.router.shard(t).unwrap().label_log();
                let known =
                    log.iter().filter(|&&l| l != UNKNOWN).count();
                (t, known, log.len())
            })
            .collect();
        MultiTenantReport {
            windows_observed,
            offline_runs: self.offline_runs,
            workloads_known: self.db.read().unwrap().len(),
            per_tenant,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloadgen::{tour_schedule, Generator};

    fn trace(seed: u64, classes: &[u32], dur: usize) -> Trace {
        let mut g = Generator::with_default_config(seed);
        g.generate(&tour_schedule(dur, classes))
    }

    #[test]
    fn knowledge_discovered_from_tenant_a_serves_tenant_b() {
        let mut cfg = CoordinatorConfig::default();
        cfg.offline_interval_windows = 40; // manual off-line only
        let mut coord = MultiTenantCoordinator::new(cfg);
        let (a, b) = (TenantId(0), TenantId(1));

        // phase 1: only tenant A streams (classes 0 then 5)
        let ta = trace(1, &[0, 5], 240);
        coord.ingest(a, &ta.samples);
        coord.tick();
        coord.run_offline();
        assert_eq!(coord.offline_runs, 1);
        let known = coord.db.read().unwrap().len();
        assert!(known >= 2, "discovery found {known} classes");
        let a_log = coord.router().shard(a).unwrap().label_log();
        // A's shard itself classifies after the retrain installs — its
        // past windows were observed untrained, so look forward instead:
        // stream one more class-5 plateau through A
        let ta2 = trace(2, &[5], 150);
        coord.ingest(a, &ta2.samples);
        coord.tick();
        let a_log2 = coord.router().shard(a).unwrap().label_log();
        let a_label5 = *a_log2[a_log.len()..]
            .iter()
            .rev()
            .find(|&&l| l != UNKNOWN)
            .expect("tenant A never classified class 5");

        // phase 2: tenant B streams class 5 for the first time — no
        // off-line cycle in between, so any knowledge must have come
        // from A's traffic through the shared plane
        let offline_before = coord.offline_runs;
        let tb = trace(3, &[5], 150);
        coord.ingest(b, &tb.samples);
        coord.tick();
        assert_eq!(coord.offline_runs, offline_before, "B triggered offline");
        let b_known: Vec<u32> = coord
            .router()
            .shard(b)
            .unwrap()
            .label_log()
            .into_iter()
            .filter(|&l| l != UNKNOWN)
            .collect();
        assert!(!b_known.is_empty(), "tenant B classified nothing");
        assert!(
            b_known.iter().all(|&l| l == a_label5),
            "B labels {b_known:?} != A's class-5 label {a_label5}"
        );
    }

    #[test]
    fn offline_cycles_amortize_over_tenants() {
        let mut cfg = CoordinatorConfig::default();
        cfg.offline_interval_windows = 4;
        let mut coord = MultiTenantCoordinator::new(cfg);
        let traces: Vec<Trace> = (0..3)
            .map(|k| trace(10 + k, &[k as u32], 4 * 30))
            .collect();
        // 3 tenants x 4 windows each = 12 windows = exactly one union
        // interval (4 * 3) -> exactly one off-line cycle, not three
        let report = coord.run_interleaved(&traces, 30, 90);
        assert_eq!(report.windows_observed, 12);
        assert_eq!(report.offline_runs, 1, "cycles did not amortize");
        assert_eq!(report.per_tenant.len(), 3);
    }

    #[test]
    fn interleaved_multi_tenant_run_classifies_most_windows() {
        let mut cfg = CoordinatorConfig::default();
        cfg.offline_interval_windows = 8;
        let mut coord = MultiTenantCoordinator::new(cfg);
        // three tenants on distinct class rotations, long enough for
        // several amortized cycles
        let traces: Vec<Trace> = vec![
            trace(20, &[0, 3, 0, 3], 180),
            trace(21, &[3, 5, 3, 5], 180),
            trace(22, &[5, 0, 5, 0], 180),
        ];
        let report = coord.run_interleaved(&traces, 15, 120);
        assert!(report.offline_runs >= 2, "{report:?}");
        assert!(report.workloads_known >= 3, "{report:?}");
        // after warm-up the shared model serves every tenant
        assert!(
            report.known_fraction() > 0.4,
            "known fraction {:.2} ({report:?})",
            report.known_fraction()
        );
        // cross-tenant consistency: the shared model must name a fresh
        // class-3 plateau identically for every tenant — including
        // tenant 2, which never contributed a class-3 window (freeze
        // the off-line cadence so the model can't change mid-check)
        coord.config.offline_interval_windows = 1_000_000;
        let follow = trace(23, &[3], 150);
        let mut labels = Vec::new();
        for t in coord.router().tenants() {
            let before =
                coord.router().shard(t).unwrap().label_log().len();
            coord.ingest(t, &follow.samples);
            coord.tick();
            let log = coord.router().shard(t).unwrap().label_log();
            if let Some(&l) =
                log[before..].iter().rev().find(|&&l| l != UNKNOWN)
            {
                labels.push(l);
            }
        }
        assert!(labels.len() >= 2, "too few tenants classified: {labels:?}");
        assert!(
            labels.windows(2).all(|p| p[0] == p[1]),
            "tenants disagree on the same class: {labels:?}"
        );
    }
}
