//! The KERMIT coordinator: wires the full MAPE-K loop (Figure 3) over
//! the simulated cluster and drives end-to-end scenarios.
//!
//! Monitor: job metric samples stream through KWmon into observation
//! windows. Analyze: the on-line pipeline (ChangeDetector → classifier →
//! predictor) publishes contexts; the off-line analyser periodically
//! runs Algorithm 2 + the training pipeline. Plan: the plug-in's
//! Algorithm 1 picks configurations (cache hit / local / global search).
//! Execute: the RM applies them to job containers. Knowledge: the
//! WorkloadDB persists everything.

pub mod multi;

pub mod offline_cycle;

pub mod report;

use crate::clustering::{DistanceProvider, NativeDistance};
use crate::features::ObservationWindow;
use crate::knowledge::{shared_db, SharedWorkloadDb};
use crate::monitor::{aggregate_samples, MonitorConfig};
use crate::offline::{DiscoveryConfig, TrainingConfig};
use crate::online::classifier::GatedForestClassifier;
use crate::online::{
    ChoiceKind, ContextStream, ForestWindowClassifier, KermitPlugin,
    OnlinePipeline, UNKNOWN,
};
use std::collections::BTreeMap;
use crate::simcluster::engine::EngineConfig;
use crate::simcluster::perfmodel::job_duration;
use crate::simcluster::JobSpec;
use crate::util::rng::Rng;
use crate::workloadgen::{catalog, num_pure_classes, Sample, TruthTag};
use crate::features::NUM_FEATURES;
pub use multi::{CadencePolicy, MultiTenantCoordinator, MultiTenantReport};
pub use offline_cycle::{CycleModels, CycleOutcome, OfflineCycle};
pub use report::{JobOutcome, RunReport};
use std::sync::{Arc, Mutex};

/// Coordinator configuration (the paper's hyper-parameters).
#[derive(Clone)]
pub struct CoordinatorConfig {
    pub monitor: MonitorConfig,
    pub discovery: DiscoveryConfig,
    pub training: TrainingConfig,
    pub engine: EngineConfig,
    /// Off-line analysis interval, in observation windows (the paper's
    /// `k` batch-length hyper-parameter).
    pub offline_interval_windows: usize,
    /// Windows of metric prefix emitted before the config decision (the
    /// identification lead-in).
    pub prefix_windows: usize,
    /// Forest soft-vote confidence gate.
    pub min_confidence: f64,
    /// Centroid-distance gate for the bootstrap classifier.
    pub centroid_gate: f64,
    pub seed: u64,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            monitor: MonitorConfig { window_size: 30 },
            discovery: DiscoveryConfig::default(),
            training: TrainingConfig::default(),
            engine: EngineConfig::default(),
            offline_interval_windows: 40,
            prefix_windows: 2,
            min_confidence: 0.65,
            centroid_gate: 20.0,
            seed: 1,
        }
    }
}

/// The assembled autonomic system.
pub struct Coordinator {
    pub config: CoordinatorConfig,
    /// The shared knowledge plane (read-mostly RwLock; multi-tenant
    /// deployments hand the same handle to every tenant's consumers).
    pub db: SharedWorkloadDb,
    pub context: Arc<Mutex<ContextStream>>,
    pub pipeline: OnlinePipeline,
    pub plugin: KermitPlugin,
    backlog: Vec<ObservationWindow>,
    windows_since_offline: usize,
    window_index: u64,
    rng: Rng,
    /// distance provider for discovery (native, or the PJRT artifact)
    dist: Box<dyn DistanceProvider>,
    /// The consolidated off-line analyze/train loop state (training
    /// stores, retrain gate, transition registry) — shared routine with
    /// the multi-tenant coordinator; see [`offline_cycle::OfflineCycle`].
    pub cycle: OfflineCycle,
    /// Active signature drift per ground-truth class (systematic mean
    /// shift applied to emitted metrics; see [`Coordinator::inject_drift`]).
    signature_shift: BTreeMap<u32, crate::features::FeatureVec>,
}

impl Coordinator {
    pub fn new(config: CoordinatorConfig) -> Coordinator {
        Self::with_distance(config, Box::new(NativeDistance))
    }

    /// "Artifact if available" construction (ROADMAP): route the
    /// discovery distance matrix through the PJRT `pairwise_dist`
    /// artifact when the runtime and artifacts are present, and degrade
    /// gracefully to the engine-parallel native provider otherwise —
    /// the caller no longer has to pick at build time. The fallback
    /// (and the off-line retraining) parallelise over
    /// `config.discovery.engine`, whose workers live in the process-
    /// wide persistent pool — repeated discovery cycles reuse them
    /// instead of re-spawning per call.
    pub fn with_best_distance(config: CoordinatorConfig) -> Coordinator {
        let dist = crate::runtime::nn::distance_provider(config.discovery.engine);
        Self::with_distance(config, dist)
    }

    /// Use a custom distance provider (e.g. `runtime::nn::ArtifactDistance`
    /// to route DBSCAN through the pallas kernel artifact).
    pub fn with_distance(
        config: CoordinatorConfig,
        dist: Box<dyn DistanceProvider>,
    ) -> Coordinator {
        let db = shared_db();
        let context = Arc::new(Mutex::new(ContextStream::new(64)));
        let pipeline = OnlinePipeline::new(context.clone());
        let plugin = KermitPlugin::new(db.clone(), context.clone());
        let rng = Rng::new(config.seed);
        Coordinator {
            config,
            db,
            context,
            pipeline,
            plugin,
            backlog: Vec::new(),
            windows_since_offline: 0,
            window_index: 0,
            rng,
            dist,
            cycle: OfflineCycle::new(400, 5),
            signature_shift: BTreeMap::new(),
        }
    }

    /// Inject workload drift: from now on, class `truth_id`'s emitted
    /// metric signature is shifted by `shift` (the paper's §6.1 workload
    /// drift, or §6.2's node-failure-as-drift scenario). The off-line
    /// analyser should detect it (Algorithm 2's ε test), mark the DB
    /// entry drifting, and the plug-in should re-optimise with a *local*
    /// search seeded at the last good configuration.
    pub fn inject_drift(
        &mut self,
        truth_id: u32,
        shift: crate::features::FeatureVec,
    ) {
        self.signature_shift.insert(truth_id, shift);
    }

    /// Stream raw samples through the monitor + on-line pipeline;
    /// returns the label of the final known context (UNKNOWN when no
    /// window classified). Public so external drivers (the tuning
    /// plane's parity tests, replay tools) can feed a recorded stream
    /// without going through `run_schedule`'s own sample synthesis.
    pub fn ingest(&mut self, samples: &[Sample]) -> u32 {
        let windows = aggregate_samples(samples, &self.config.monitor);
        let mut label = UNKNOWN;
        for mut w in windows {
            w.index = self.window_index;
            self.window_index += 1;
            let ctx = self.pipeline.observe(&w);
            if ctx.current_label != UNKNOWN {
                label = ctx.current_label;
            }
            self.backlog.push(w);
            self.windows_since_offline += 1;
        }
        if self.windows_since_offline >= self.config.offline_interval_windows
        {
            self.run_offline();
        }
        label
    }

    /// The off-line sub-system tick: the consolidated cycle (Algorithm 2
    /// discovery + drift, store accumulation, retrain gating, ZSL
    /// synthesis, classifier + transition-classifier training — see
    /// [`OfflineCycle::run`]) followed by model installation on this
    /// coordinator's single pipeline.
    pub fn run_offline(&mut self) {
        self.windows_since_offline = 0;
        if self.backlog.len() < 8 {
            return;
        }
        let outcome = self.cycle.run(
            &self.backlog,
            &self.db,
            &self.config,
            &mut self.rng,
            self.dist.as_ref(),
        );
        if let Some(models) = outcome.models {
            let classifier = {
                let db = self.db.read().unwrap();
                GatedForestClassifier::from_db(
                    models.forest,
                    &db,
                    self.config.centroid_gate,
                    self.config.min_confidence,
                )
            };
            self.pipeline.set_classifier(Box::new(classifier));
            if let Some(tforest) = models.transition_forest {
                self.pipeline.set_transition_classifier(Box::new(
                    ForestWindowClassifier::new(
                        tforest,
                        self.config.min_confidence,
                    ),
                ));
            }
        }
        // keep a characterization tail so recurring workloads re-match,
        // but don't regrow unboundedly
        let keep = self.config.offline_interval_windows * 2;
        if self.backlog.len() > keep {
            let cut = self.backlog.len() - keep;
            self.backlog.drain(..cut);
        }
    }

    /// Emit `n_windows` of metric samples for a job mix (same signature
    /// model as the cluster engine).
    fn emit_job_samples(
        &mut self,
        mix: crate::workloadgen::Mix,
        truth_id: u32,
        start_time: f64,
        n_windows: usize,
    ) -> Vec<Sample> {
        let cat = catalog();
        let mut mean = mix.mean(&cat);
        if let Some(shift) = self.signature_shift.get(&truth_id) {
            for (m, s) in mean.iter_mut().zip(shift.iter()) {
                *m = (*m + s).max(0.0);
            }
        }
        let noise = mix.noise(&cat);
        let n = n_windows * self.config.monitor.window_size;
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let mut f = [0.0; NUM_FEATURES];
            for k in 0..NUM_FEATURES {
                f[k] = self.rng.normal_ms(mean[k], noise[k]).max(0.0);
            }
            out.push(Sample {
                time: start_time + i as f64,
                features: f,
                truth: TruthTag::Steady(truth_id),
            });
        }
        out
    }

    /// Run a job schedule through the full autonomic loop. Each job:
    /// prefix windows stream in (identification lead-in), the plug-in
    /// picks the config (Algorithm 1), the job runs under it, its
    /// remaining metrics stream in, and the measured duration feeds the
    /// active search session if any.
    pub fn run_schedule(&mut self, jobs: &[JobSpec]) -> RunReport {
        let n_pure = num_pure_classes();
        let mut report = RunReport::default();
        let mut now = 0.0f64;
        let window_secs = self.config.monitor.window_size as f64;

        for (k, job) in jobs.iter().enumerate() {
            let truth_id = job.mix.truth_id(n_pure);

            // identification lead-in
            let prefix = self.emit_job_samples(
                job.mix,
                truth_id,
                now,
                self.config.prefix_windows,
            );
            let label = self.ingest(&prefix);
            now += self.config.prefix_windows as f64 * window_secs;

            // Algorithm 1 decision
            let (config_idx, choice) = self.plugin.choose_config_for_label(label);
            let base = job_duration(truth_id, &config_idx.to_config());
            let noise =
                1.0 + self.config.engine.duration_noise * self.rng.normal();
            let duration = base * noise.max(0.5);

            // job body metrics
            let body_windows =
                ((duration / window_secs).ceil() as usize).clamp(1, 40);
            let body =
                self.emit_job_samples(job.mix, truth_id, now, body_windows);
            self.ingest(&body);
            now += duration;

            // feedback edge
            self.plugin.record_measurement(label, duration);

            report.jobs.push(JobOutcome {
                index: k,
                truth_id,
                classified_label: label,
                choice,
                duration,
            });
            now += self.config.engine.inter_job_gap;
        }
        report.makespan = now;
        report.plugin_stats = self.plugin.stats.clone();
        report.workloads_known = self.db.read().unwrap().len();
        report
    }
}

/// Baseline runner: the same schedule under a fixed configuration
/// (vendor default or rule-of-thumb), for end-to-end comparisons.
pub fn run_fixed_config(
    jobs: &[JobSpec],
    config_idx: crate::simcluster::ConfigIndex,
    engine: &EngineConfig,
    seed: u64,
) -> RunReport {
    let n_pure = num_pure_classes();
    let mut rng = Rng::new(seed);
    let mut report = RunReport::default();
    let mut now = 0.0;
    for (k, job) in jobs.iter().enumerate() {
        let truth_id = job.mix.truth_id(n_pure);
        let base = job_duration(truth_id, &config_idx.to_config());
        let noise = 1.0 + engine.duration_noise * rng.normal();
        let duration = base * noise.max(0.5);
        now += duration + engine.inter_job_gap;
        report.jobs.push(JobOutcome {
            index: k,
            truth_id,
            classified_label: UNKNOWN,
            choice: ChoiceKind::Default,
            duration,
        });
    }
    report.makespan = now;
    report
}

/// Oracle runner: every job at its exhaustive-search optimum — the
/// "fastest possible tuning" bound.
pub fn run_oracle(
    jobs: &[JobSpec],
    engine: &EngineConfig,
    seed: u64,
) -> RunReport {
    use crate::simcluster::ConfigIndex;
    let n_pure = num_pure_classes();
    let mut rng = Rng::new(seed);
    let mut report = RunReport::default();
    let mut now = 0.0;
    // memoise per-class optima (the grid scan is expensive)
    let mut best: std::collections::BTreeMap<u32, f64> = Default::default();
    for (k, job) in jobs.iter().enumerate() {
        let truth_id = job.mix.truth_id(n_pure);
        let base = *best.entry(truth_id).or_insert_with(|| {
            ConfigIndex::enumerate_all()
                .into_iter()
                .map(|ci| job_duration(truth_id, &ci.to_config()))
                .fold(f64::INFINITY, f64::min)
        });
        let noise = 1.0 + engine.duration_noise * rng.normal();
        let duration = base * noise.max(0.5);
        now += duration + engine.inter_job_gap;
        report.jobs.push(JobOutcome {
            index: k,
            truth_id,
            classified_label: truth_id,
            choice: ChoiceKind::CacheHit,
            duration,
        });
    }
    report.makespan = now;
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::explorer::baselines::rule_of_thumb;
    use crate::linalg::engine::Engine;
    use crate::simcluster::default_config_index;
    use crate::workloadgen::Mix;

    fn recurring_jobs(classes: &[u32], cycles: usize) -> Vec<JobSpec> {
        let mut out = Vec::new();
        for _ in 0..cycles {
            for &c in classes {
                out.push(JobSpec { mix: Mix::Pure(c) });
            }
        }
        out
    }

    #[test]
    fn autonomic_loop_learns_and_caches() {
        let mut cfg = CoordinatorConfig::default();
        cfg.offline_interval_windows = 12;
        cfg.engine.duration_noise = 0.01;
        // tight probe budget so searches converge within the test run
        let mut coord = Coordinator::new(cfg);
        coord.plugin.explorer_config.global_budget = 25;
        let jobs = recurring_jobs(&[0, 5], 30);
        let report = coord.run_schedule(&jobs);

        // discovery must have found both workload classes
        assert!(report.workloads_known >= 2, "{}", report.workloads_known);
        // the plugin must eventually serve cache hits
        assert!(
            report.plugin_stats.cache_hits > 5,
            "stats: {:?}",
            report.plugin_stats
        );
        assert!(report.plugin_stats.searches_completed >= 1);
        // late jobs must be faster than early (default-config) ones
        let early: f64 = report.jobs[..4]
            .iter()
            .map(|j| j.duration)
            .sum::<f64>()
            / 4.0;
        let tail = &report.jobs[report.jobs.len() - 4..];
        let late: f64 =
            tail.iter().map(|j| j.duration).sum::<f64>() / 4.0;
        assert!(
            late < early,
            "late {late} not faster than early {early}"
        );
    }

    #[test]
    fn kermit_beats_default_on_recurring_day() {
        let mut cfg = CoordinatorConfig::default();
        cfg.offline_interval_windows = 12;
        cfg.engine.duration_noise = 0.01;
        let mut coord = Coordinator::new(cfg.clone());
        coord.plugin.explorer_config.global_budget = 25;
        let jobs = recurring_jobs(&[0, 3, 5], 25);
        let kermit = coord.run_schedule(&jobs);
        let default = run_fixed_config(
            &jobs,
            default_config_index(),
            &cfg.engine,
            7,
        );
        let rot =
            run_fixed_config(&jobs, rule_of_thumb(), &cfg.engine, 7);
        let oracle = run_oracle(&jobs, &cfg.engine, 7);
        assert!(
            kermit.makespan < default.makespan,
            "kermit {} vs default {}",
            kermit.makespan,
            default.makespan
        );
        // sanity ordering: oracle <= kermit
        assert!(oracle.makespan <= kermit.makespan * 1.01);
        // and the oracle is meaningfully better than rule-of-thumb
        assert!(oracle.makespan < rot.makespan);
    }

    #[test]
    fn best_distance_parallel_run_matches_native_sequential() {
        // without artifacts on disk, with_best_distance must degrade to
        // the native provider; with a parallel engine the whole run is
        // still bit-identical to the sequential Coordinator::new path
        let mut cfg = CoordinatorConfig::default();
        cfg.offline_interval_windows = 12;
        cfg.engine.duration_noise = 0.01;
        let jobs = recurring_jobs(&[0, 5], 8);

        let mut seq = Coordinator::new(cfg.clone());
        seq.plugin.explorer_config.global_budget = 25;
        let seq_report = seq.run_schedule(&jobs);

        cfg.discovery.engine = Engine::with_threads(4).with_min_items(1);
        let mut par = Coordinator::with_best_distance(cfg);
        par.plugin.explorer_config.global_budget = 25;
        let par_report = par.run_schedule(&jobs);

        if crate::runtime::Runtime::load(&crate::runtime::default_dir()).is_ok() {
            // artifact path live (f32 kernel): bitwise comparison does
            // not apply; the construction + run not panicking is the
            // degradation contract under test
            return;
        }
        assert_eq!(seq_report.makespan, par_report.makespan);
        assert_eq!(seq_report.workloads_known, par_report.workloads_known);
        for (a, b) in seq_report.jobs.iter().zip(&par_report.jobs) {
            assert_eq!(a.classified_label, b.classified_label);
            assert_eq!(a.duration, b.duration);
        }
    }

    #[test]
    fn fixed_config_report_well_formed() {
        let jobs = recurring_jobs(&[1], 3);
        let r = run_fixed_config(
            &jobs,
            default_config_index(),
            &EngineConfig::default(),
            0,
        );
        assert_eq!(r.jobs.len(), 3);
        assert!(r.makespan > 0.0);
    }
}
