//! Run reports: per-job outcomes and aggregate metrics for end-to-end
//! scenarios (consumed by examples, benches and the CLI).

use crate::online::{ChoiceKind, PluginStats};

#[derive(Debug, Clone)]
pub struct JobOutcome {
    pub index: usize,
    pub truth_id: u32,
    /// Label the on-line pipeline assigned at request time (UNKNOWN
    /// before discovery catches up).
    pub classified_label: u32,
    pub choice: ChoiceKind,
    pub duration: f64,
}

#[derive(Debug, Clone, Default)]
pub struct RunReport {
    pub jobs: Vec<JobOutcome>,
    pub makespan: f64,
    pub plugin_stats: PluginStats,
    pub workloads_known: usize,
}

impl RunReport {
    pub fn total_job_time(&self) -> f64 {
        self.jobs.iter().map(|j| j.duration).sum()
    }

    pub fn mean_duration(&self) -> f64 {
        if self.jobs.is_empty() {
            0.0
        } else {
            self.total_job_time() / self.jobs.len() as f64
        }
    }

    /// Mean duration over the last `n` jobs (steady-state performance
    /// after learning converges).
    pub fn tail_mean_duration(&self, n: usize) -> f64 {
        if self.jobs.is_empty() {
            return 0.0;
        }
        let k = n.min(self.jobs.len());
        let tail = &self.jobs[self.jobs.len() - k..];
        tail.iter().map(|j| j.duration).sum::<f64>() / k as f64
    }

    /// Fraction of jobs whose classified label was correct, judged by
    /// label-to-truth majority association (labels are arbitrary ids).
    pub fn classification_consistency(&self) -> f64 {
        use std::collections::BTreeMap;
        let mut assoc: BTreeMap<u32, BTreeMap<u32, usize>> = BTreeMap::new();
        for j in &self.jobs {
            if j.classified_label != crate::online::UNKNOWN {
                *assoc
                    .entry(j.classified_label)
                    .or_default()
                    .entry(j.truth_id)
                    .or_insert(0) += 1;
            }
        }
        let majority: BTreeMap<u32, u32> = assoc
            .iter()
            .map(|(&l, counts)| {
                (
                    l,
                    *counts.iter().max_by_key(|(_, &n)| n).unwrap().0,
                )
            })
            .collect();
        let known: Vec<&JobOutcome> = self
            .jobs
            .iter()
            .filter(|j| j.classified_label != crate::online::UNKNOWN)
            .collect();
        if known.is_empty() {
            return 0.0;
        }
        let ok = known
            .iter()
            .filter(|j| majority.get(&j.classified_label) == Some(&j.truth_id))
            .count();
        ok as f64 / known.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(i: usize, truth: u32, label: u32, d: f64) -> JobOutcome {
        JobOutcome {
            index: i,
            truth_id: truth,
            classified_label: label,
            choice: ChoiceKind::Default,
            duration: d,
        }
    }

    #[test]
    fn aggregates() {
        let r = RunReport {
            jobs: vec![job(0, 0, 0, 10.0), job(1, 1, 1, 20.0)],
            makespan: 35.0,
            ..Default::default()
        };
        assert_eq!(r.total_job_time(), 30.0);
        assert_eq!(r.mean_duration(), 15.0);
        assert_eq!(r.tail_mean_duration(1), 20.0);
    }

    #[test]
    fn consistency_with_relabeled_ids() {
        // labels 7/9 consistently map to truths 0/1: consistency = 1.0
        let r = RunReport {
            jobs: vec![
                job(0, 0, 7, 1.0),
                job(1, 1, 9, 1.0),
                job(2, 0, 7, 1.0),
                job(3, 1, 9, 1.0),
            ],
            ..Default::default()
        };
        assert_eq!(r.classification_consistency(), 1.0);
    }

    #[test]
    fn consistency_penalises_confusion() {
        let r = RunReport {
            jobs: vec![
                job(0, 0, 7, 1.0),
                job(1, 1, 7, 1.0),
                job(2, 0, 7, 1.0),
            ],
            ..Default::default()
        };
        // label 7 majority-maps to truth 0; 2 of 3 consistent
        assert!((r.classification_consistency() - 2.0 / 3.0).abs() < 1e-12);
    }
}
