//! The consolidated off-line analyze/train cycle — ONE routine for the
//! single-tenant [`super::Coordinator`] and the multi-tenant
//! [`super::MultiTenantCoordinator`].
//!
//! Before this module existed the multi-tenant coordinator re-derived
//! the store/gate/retrain shape of `Coordinator::run_offline` but
//! silently skipped ZSL synthesis and transition-classifier training —
//! so a multi-tenant deployment could never anticipate hybrid workloads
//! or name transitions on-line. Both coordinators now delegate to
//! [`OfflineCycle::run`], which performs the full §7 pipeline:
//!
//! 1. Algorithm 2 discovery + drift detection over the backlog (under
//!    the knowledge-plane **write** lock — the only slow write);
//! 2. cumulative per-label training-store accumulation (the analytics
//!    zone, capped per label);
//! 3. retrain gating (§Perf: refit only on label-set changes or every
//!    `retrain_every` ticks);
//! 4. transition training-set accumulation (rate-of-change rows, stable
//!    ids via the persistent registry);
//! 5. when the gate opens: ZSL synthesis (write lock again — fast) and
//!    the WorkloadClassifier + TransitionClassifier forest fits, both
//!    **lock-free** so tenant plug-ins keep serving cache lookups while
//!    the expensive training runs.
//!
//! The caller installs the returned models (one pipeline, or one model
//! cloned onto every tenant shard) — installation is the only part that
//! differs between the two deployment shapes.

use super::CoordinatorConfig;
use crate::clustering::DistanceProvider;
use crate::features::{zero_analytic, ObservationWindow};
use crate::knowledge::SharedWorkloadDb;
use crate::linalg::Matrix;
use crate::ml::forest::RandomForest;
use crate::ml::Dataset;
use crate::offline::zsl::synthesize;
use crate::offline::{discover, ClusterOutcome, DiscoveryReport};
use crate::util::rng::Rng;
use std::collections::{BTreeMap, BTreeSet};

/// Models produced by a cycle whose retrain gate opened.
pub struct CycleModels {
    /// The WorkloadClassifier forest (train set = cumulative store +
    /// ZSL synthetic instances when enabled).
    pub forest: RandomForest,
    /// The TransitionClassifier forest (None until two or more
    /// transition types have been observed).
    pub transition_forest: Option<RandomForest>,
}

/// What one off-line cycle did.
pub struct CycleOutcome {
    pub report: DiscoveryReport,
    /// `Some` when the retrain gate opened this cycle.
    pub models: Option<CycleModels>,
}

/// Persistent state of the off-line analyze/train loop (the parts that
/// must survive across cycles: stores, caps, gates, registries).
pub struct OfflineCycle {
    /// Cumulative training store (the analytics zone): per label, the
    /// labelled analytic windows accumulated across all discovery runs,
    /// in contiguous row storage. Without it, a forest retrained on just
    /// the latest batch would forget every class absent from that batch.
    training_store: BTreeMap<u32, Matrix>,
    /// Cap per label (memory bound; oldest dropped first).
    store_cap: usize,
    /// Off-line ticks since the classifier was last retrained.
    ticks_since_train: usize,
    /// §Perf optimisation: retrain only when discovery changes the label
    /// set (new/drifted labels) or every `retrain_every` ticks as a
    /// refresher — retraining on every tick dominated end-to-end
    /// wall-clock (see EXPERIMENTS.md §Perf iteration 1).
    pub retrain_every: usize,
    /// Transition-type label registry ((from, to) -> generated id),
    /// persistent across cycles so ids stay stable.
    transition_registry: BTreeMap<(u32, u32), u32>,
    /// Cumulative transition training examples: rate-of-change rows in
    /// contiguous storage, with the label per row alongside.
    transition_rows: Matrix,
    transition_row_labels: Vec<u32>,
}

impl OfflineCycle {
    pub fn new(store_cap: usize, retrain_every: usize) -> OfflineCycle {
        OfflineCycle {
            training_store: BTreeMap::new(),
            store_cap,
            ticks_since_train: 0,
            retrain_every,
            transition_registry: BTreeMap::new(),
            transition_rows: Matrix::new(),
            transition_row_labels: Vec::new(),
        }
    }

    /// Transition types registered so far (telemetry + tests).
    pub fn transition_types(&self) -> usize {
        self.transition_registry.len()
    }

    /// One full off-line cycle over `backlog`. The write lock is held
    /// for discovery and (when retraining) ZSL synthesis only; forest
    /// fits run lock-free.
    pub fn run(
        &mut self,
        backlog: &[ObservationWindow],
        db: &SharedWorkloadDb,
        config: &CoordinatorConfig,
        rng: &mut Rng,
        dist: &dyn DistanceProvider,
    ) -> CycleOutcome {
        let report = {
            let mut dbw = db.write().unwrap();
            discover(backlog, &mut dbw, &config.discovery, dist)
        };

        // accumulate the analytics-zone training store (fixed-width
        // analytic rows appended straight into contiguous storage)
        let mut analytic_buf = zero_analytic();
        for (w, label) in backlog.iter().zip(&report.window_labels) {
            if let Some(l) = label {
                let rows = self.training_store.entry(*l).or_default();
                w.fill_analytic(&mut analytic_buf);
                rows.push_row(&analytic_buf);
                if rows.n_rows() > self.store_cap {
                    let excess = rows.n_rows() - self.store_cap;
                    rows.remove_first_rows(excess);
                }
            }
        }

        // retrain gating (§Perf): skip the expensive forest refit when
        // nothing about the label set changed and the refresher interval
        // hasn't elapsed
        self.ticks_since_train += 1;
        let label_set_changed = report
            .outcomes
            .iter()
            .any(|o| !matches!(o, ClusterOutcome::Matched { .. }));
        let must_train = label_set_changed
            || self.ticks_since_train >= self.retrain_every;

        // accumulate transition training data (rate-of-change rows per
        // (from, to) pair — §7.2 steps 3-6)
        let tset = crate::offline::training::transition_training_set(
            backlog,
            &report,
            &mut self.transition_registry,
        );
        for (row, label) in tset.iter() {
            self.transition_rows.push_row(row);
            self.transition_row_labels.push(label);
        }
        if self.transition_rows.n_rows() > 4 * self.store_cap {
            let excess = self.transition_rows.n_rows() - 4 * self.store_cap;
            self.transition_rows.remove_first_rows(excess);
            self.transition_row_labels.drain(..excess);
        }

        let models = if !self.training_store.is_empty() && must_train {
            self.ticks_since_train = 0;
            // training set = cumulative store + ZSL synthetic instances
            let mut data = Dataset::new();
            for (l, rows) in &self.training_store {
                for r in rows.iter_rows() {
                    data.push(r, *l);
                }
            }
            if config.training.enable_zsl {
                let mut dbw = db.write().unwrap();
                let synth = synthesize(&mut dbw, &config.training.zsl, rng);
                data.extend_from(&synth.instances);
            }
            let forest = RandomForest::fit_with(
                &data,
                config.training.forest.clone(),
                rng,
                config.discovery.engine,
            );

            // TransitionClassifier: retrain alongside (needs >=2 types)
            let types: BTreeSet<u32> =
                self.transition_row_labels.iter().copied().collect();
            let transition_forest = if types.len() >= 2 {
                let mut td = Dataset::new();
                for (row, &label) in self
                    .transition_rows
                    .iter_rows()
                    .zip(&self.transition_row_labels)
                {
                    td.push(row, label);
                }
                Some(RandomForest::fit_with(
                    &td,
                    config.training.forest.clone(),
                    rng,
                    config.discovery.engine,
                ))
            } else {
                None
            };
            Some(CycleModels { forest, transition_forest })
        } else {
            None
        };

        CycleOutcome { report, models }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clustering::NativeDistance;
    use crate::knowledge::shared_db;
    use crate::monitor::{aggregate_trace, MonitorConfig};
    use crate::workloadgen::{tour_schedule, Generator};

    fn backlog(seed: u64, classes: &[u32]) -> Vec<ObservationWindow> {
        let mut g = Generator::with_default_config(seed);
        let t = g.generate(&tour_schedule(150, classes));
        aggregate_trace(&t, &MonitorConfig { window_size: 30 })
    }

    #[test]
    fn cycle_is_deterministic_given_seed() {
        let run = || {
            let db = shared_db();
            let mut cyc = OfflineCycle::new(400, 5);
            let mut rng = Rng::new(3);
            let cfg = CoordinatorConfig::default();
            let out = cyc.run(
                &backlog(1, &[0, 5, 0]),
                &db,
                &cfg,
                &mut rng,
                &NativeDistance,
            );
            let json = db.read().unwrap().to_json().encode_pretty();
            (out.report.window_labels.clone(), json)
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn first_cycle_trains_and_synthesizes() {
        let db = shared_db();
        let mut cyc = OfflineCycle::new(400, 5);
        let mut rng = Rng::new(3);
        let cfg = CoordinatorConfig::default();
        let out = cyc.run(
            &backlog(1, &[0, 5, 0, 5]),
            &db,
            &cfg,
            &mut rng,
            &NativeDistance,
        );
        // new labels -> gate opens on the first cycle
        let models = out.models.expect("first cycle must retrain");
        // ZSL ran: the DB holds a synthetic (anticipated) hybrid class
        assert!(db.read().unwrap().entries().any(|e| e.synthetic));
        // two transition directions (0->5, 5->0) -> transition forest
        assert!(cyc.transition_types() >= 2, "{}", cyc.transition_types());
        assert!(models.transition_forest.is_some());
    }

    #[test]
    fn retrain_gate_closes_on_unchanged_label_set() {
        let db = shared_db();
        let mut cyc = OfflineCycle::new(400, 5);
        let mut rng = Rng::new(3);
        let cfg = CoordinatorConfig::default();
        let b = backlog(1, &[0, 5, 0]);
        let first = cyc.run(&b, &db, &cfg, &mut rng, &NativeDistance);
        assert!(first.models.is_some());
        // the identical backlog again: every cluster re-matches its own
        // DB entry, and the refresher interval has not elapsed
        let second = cyc.run(&b, &db, &cfg, &mut rng, &NativeDistance);
        assert!(
            second.models.is_none(),
            "gate must hold on an unchanged label set"
        );
    }
}
