//! The persistent worker pool behind [`crate::linalg::engine::Engine`].
//!
//! PR 2's engine fanned every call out with `std::thread::scope`, which
//! pays a full spawn + join per call — fine for one 600-row discovery
//! pass, ruinous for the per-merge scans of agglomerative clustering and
//! the per-tick dispatch of `stream::StreamRouter` (thousands of small
//! calls). This module replaces that with **one process-wide pool of
//! long-lived workers parked on a condvar**:
//!
//! * Workers are started **lazily** on the first parallel dispatch and
//!   grown by the *shortfall* between a job's useful helper count (the
//!   smaller of the engine's `threads - 1` and the job's `chunks - 1`)
//!   and the workers not currently busy, capped at [`MAX_WORKERS`] —
//!   so one caller's back-to-back dispatches reuse the same parked
//!   workers while concurrent callers each provision their own. A
//!   program that only ever uses sequential engines never starts a
//!   thread.
//! * A call publishes one **job descriptor** — a lifetime-erased pointer
//!   to its chunk-runner closure plus an atomic chunk-claim counter and
//!   a completion latch — onto a FIFO queue and wakes the workers. The
//!   **calling thread claims chunks too**, so a job always makes
//!   progress even if every worker is busy with another caller's job
//!   (or the pool is shutting down), and the fast path for a 2-chunk
//!   job is "caller takes one, first awake worker takes the other".
//! * Chunk *contents* are fixed by the submitting `Engine` (contiguous
//!   index ranges); workers only race on **which** chunk they claim.
//!   Each chunk writes results into its own pre-allocated slot, and the
//!   caller reduces the slots in chunk order after [`Job::wait`], so
//!   execution order never leaks into results — the pool preserves the
//!   engine's bit-identical-to-sequential guarantee.
//! * A panic inside a chunk is caught on the worker, parked in the job,
//!   and **resumed on the caller** once the job has fully drained. The
//!   worker survives and the pool keeps serving subsequent calls (no
//!   poisoning — pinned by `tests/engine_equivalence.rs`).
//! * [`shutdown`] drains the pool (workers exit, the global handle
//!   resets); the next parallel dispatch re-initializes it. In-flight
//!   callers are never stranded: they drain their own jobs.
//!
//! # Why the lifetime erasure is sound
//!
//! A job's closure borrows the caller's stack (`thread::scope`-style,
//! no `'static` bound). The raw pointer in the descriptor erases that
//! lifetime, which is sound because (a) [`dispatch`] does not return
//! until every chunk has completed, so the borrow outlives every
//! dereference, and (b) a worker only dereferences the pointer for
//! chunk indices it claimed *below* `chunks`, and all claims happen
//! before the caller's completion latch releases.
//!
//! Memory visibility: the job travels caller → worker through the pool
//! mutex (queue push / queue pop), and results travel worker → caller
//! through the job's state mutex (chunk-done increment / completion
//! wait), so every side effect of a chunk happens-before the caller's
//! return from [`dispatch`].

use std::any::Any;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};

/// Hard cap on pool size: above this, extra requested helpers just
/// share the existing workers. Far beyond any sane `Engine::auto` and
/// merely a guard against `Engine::with_threads(huge)`.
pub const MAX_WORKERS: usize = 512;

/// Lifetime-erased chunk runner. Only dereferenced for claimed chunk
/// indices while the submitting caller is blocked in [`Job::wait`].
struct RunPtr(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointee is `Sync` (it is built from `&(dyn Fn + Sync)`)
// and the pointer is only dereferenced under the liveness protocol in
// the module docs.
unsafe impl Send for RunPtr {}
unsafe impl Sync for RunPtr {}

/// One dispatched call: closure pointer, chunk-claim counter, and the
/// completion latch the caller blocks on.
struct Job {
    run: RunPtr,
    chunks: usize,
    /// Next unclaimed chunk index (claims may exceed `chunks`; a claim
    /// `>= chunks` means "nothing left for you").
    next: AtomicUsize,
    state: Mutex<JobState>,
    done_cv: Condvar,
}

struct JobState {
    done: usize,
    /// First panic payload out of any chunk, re-raised on the caller.
    panic: Option<Box<dyn Any + Send>>,
}

impl Job {
    /// # Safety
    ///
    /// The caller must keep `run` alive (not return / not drop the
    /// closure) until [`Job::wait`] has returned.
    #[allow(clippy::transmutes_expressible_as_ptr_casts)]
    unsafe fn new(run: &(dyn Fn(usize) + Sync), chunks: usize) -> Arc<Job> {
        let run = RunPtr(std::mem::transmute::<
            &(dyn Fn(usize) + Sync),
            *const (dyn Fn(usize) + Sync + 'static),
        >(run));
        Arc::new(Job {
            run,
            chunks,
            next: AtomicUsize::new(0),
            state: Mutex::new(JobState { done: 0, panic: None }),
            done_cv: Condvar::new(),
        })
    }

    /// Claim and run chunks until none are left. Called by workers and
    /// by the submitting caller alike; panics in the closure are caught
    /// and parked so the claimer (possibly a pool worker) survives.
    fn help(&self) {
        loop {
            let ci = self.next.fetch_add(1, Ordering::Relaxed);
            if ci >= self.chunks {
                return;
            }
            // SAFETY: ci < chunks, so the caller is still blocked in
            // `wait` and the closure borrow is alive (module docs).
            let result = catch_unwind(AssertUnwindSafe(|| unsafe { (*self.run.0)(ci) }));
            let mut st = self.state.lock().unwrap();
            if let Err(payload) = result {
                if st.panic.is_none() {
                    st.panic = Some(payload);
                }
            }
            st.done += 1;
            if st.done == self.chunks {
                self.done_cv.notify_all();
            }
        }
    }

    /// Every chunk claimed (not necessarily finished)? Workers use this
    /// to drop drained jobs off the queue front.
    fn exhausted(&self) -> bool {
        self.next.load(Ordering::Relaxed) >= self.chunks
    }

    /// Block until every chunk has finished, then re-raise the first
    /// chunk panic (if any) on this thread.
    fn wait(&self) {
        let mut st = self.state.lock().unwrap();
        while st.done < self.chunks {
            st = self.done_cv.wait(st).unwrap();
        }
        let panic = st.panic.take();
        drop(st);
        if let Some(payload) = panic {
            resume_unwind(payload);
        }
    }
}

struct Pool {
    shared: Mutex<Shared>,
    /// Workers park here; [`shutdown`] also waits here for the worker
    /// count to reach zero.
    work_cv: Condvar,
}

struct Shared {
    queue: VecDeque<Arc<Job>>,
    workers: usize,
    /// Workers currently inside [`Job::help`]. `workers - busy` are
    /// available (parked, or in transit back to the queue check) —
    /// the growth heuristic in [`Pool::submit`] keys off this so
    /// concurrent callers each get their own helpers while
    /// back-to-back calls from one caller reuse the same workers.
    busy: usize,
    shutting_down: bool,
}

impl Pool {
    fn new() -> Arc<Pool> {
        Arc::new(Pool {
            shared: Mutex::new(Shared {
                queue: VecDeque::new(),
                workers: 0,
                busy: 0,
                shutting_down: false,
            }),
            work_cv: Condvar::new(),
        })
    }

    /// Queue `job`, growing the pool to however many workers the job
    /// can actually use (capped). On a pool already shutting down this
    /// is a no-op: the submitting caller drains the job itself via
    /// [`Job::help`].
    fn submit(self: &Arc<Pool>, job: Arc<Job>, helpers: usize) {
        let mut sh = self.shared.lock().unwrap();
        if sh.shutting_down {
            return;
        }
        // at most `chunks - 1` workers can usefully serve this job (the
        // caller claims chunks itself), and only the shortfall against
        // currently-available workers needs spawning: a 2-chunk job on
        // a 64-thread engine grows/wakes one worker, not 63;
        // back-to-back calls from one caller reuse the same workers;
        // and a second concurrent caller (whose rival's workers are all
        // `busy`) grows its own helpers instead of sharing an
        // under-provisioned pool.
        let useful = helpers.min(job.chunks.saturating_sub(1));
        let available = sh.workers - sh.busy;
        let mut grow = useful.saturating_sub(available);
        // `busy` can transiently over-count: a worker that just ran a
        // job's last chunk (caller already released) stays "busy" until
        // it re-acquires this mutex. The demand-justified cap below
        // (`busy + useful` total workers) bounds the resulting
        // over-spawn to that stale count, and extra workers park and
        // raise `available` for every later submit, so growth stops
        // instead of ratcheting.
        let cap = (sh.busy + useful).min(MAX_WORKERS);
        while grow > 0 && sh.workers < cap {
            let pool = Arc::clone(self);
            let spawned = std::thread::Builder::new()
                .name("kermit-engine".into())
                .spawn(move || worker_loop(&pool));
            match spawned {
                Ok(_) => {
                    sh.workers += 1;
                    grow -= 1;
                }
                // transient spawn failure (thread limit, OOM): degrade
                // to however many workers exist — the caller and the
                // surviving workers still drain every job, and a later
                // submit retries the growth. Panicking here would
                // poison the process-wide pool mutex forever.
                Err(_) => break,
            }
        }
        if sh.workers == 0 {
            // nothing could be spawned: don't queue — no worker exists
            // to ever pop the descriptor, and the caller drains every
            // chunk itself anyway.
            return;
        }
        // prune drained descriptors here too, not just in worker_loop:
        // with every worker pinned inside a long chunk, a caller
        // looping tiny self-drained dispatches would otherwise grow the
        // queue without bound. Retain (not front-only pruning) because
        // a long-running unexhausted front job would shield thousands
        // of dead descriptors queued behind it. An exhausted job is
        // always safe to drop: its submitter holds its own Arc and its
        // own claim loop.
        sh.queue.retain(|j| !j.exhausted());
        sh.queue.push_back(job);
        // wake only as many workers as can usefully claim a chunk.
        // Under-waking can't strand the job: busy workers re-check the
        // queue between jobs, and the caller always drains its own.
        for _ in 0..useful.min(sh.workers - sh.busy) {
            self.work_cv.notify_one();
        }
    }
}

fn worker_loop(pool: &Pool) {
    let mut sh = pool.shared.lock().unwrap();
    loop {
        if sh.shutting_down {
            sh.workers -= 1;
            // wake the shutdown waiter (and fellow workers) so the
            // count re-check runs
            pool.work_cv.notify_all();
            return;
        }
        // drop fully-claimed jobs off the front so later callers'
        // jobs become visible
        while sh.queue.front().is_some_and(|j| j.exhausted()) {
            sh.queue.pop_front();
        }
        match sh.queue.front().cloned() {
            Some(job) => {
                sh.busy += 1;
                drop(sh);
                job.help();
                sh = pool.shared.lock().unwrap();
                sh.busy -= 1;
            }
            None => sh = pool.work_cv.wait(sh).unwrap(),
        }
    }
}

/// The process-wide pool handle. `None` until the first parallel
/// dispatch (lazy start) and after [`shutdown`]. An `RwLock` (not a
/// `Mutex`) so the many-small-dispatches hot path only ever takes the
/// read lock once the pool exists; the write lock is limited to lazy
/// init and [`shutdown`]. (An `OnceLock` can't give the reset-on-
/// shutdown semantics.)
static GLOBAL: RwLock<Option<Arc<Pool>>> = RwLock::new(None);

fn handle() -> Arc<Pool> {
    if let Some(p) = GLOBAL.read().unwrap().as_ref() {
        return Arc::clone(p);
    }
    let mut g = GLOBAL.write().unwrap();
    Arc::clone(g.get_or_insert_with(Pool::new))
}

/// Run `run(ci)` for every chunk index in `0..chunks`, the calling
/// thread claiming chunks alongside up to `helpers` pool workers.
/// Blocks until every chunk has finished; the first panic out of any
/// chunk resumes on the caller after the job has fully drained (the
/// pool itself is never poisoned).
///
/// With `helpers == 0` or a single chunk the call runs entirely inline
/// — no queue traffic, no wakeups.
pub(crate) fn dispatch(chunks: usize, helpers: usize, run: &(dyn Fn(usize) + Sync)) {
    if chunks == 0 {
        return;
    }
    if helpers == 0 || chunks == 1 {
        for ci in 0..chunks {
            run(ci);
        }
        return;
    }
    // SAFETY: `job.wait()` below blocks this frame until every chunk
    // has completed, so `run` outlives every dereference of the erased
    // pointer.
    let job = unsafe { Job::new(run, chunks) };
    handle().submit(Arc::clone(&job), helpers);
    job.help();
    job.wait();
}

/// Tear the pool down: workers exit, the global handle resets, and the
/// next parallel dispatch lazily re-initializes a fresh pool. In-flight
/// jobs are drained by their submitting callers (which always hold a
/// claim loop of their own), so this never strands a caller — but it
/// does busy-drain through them, so prefer calling it at quiesce points
/// (process teardown, between test cases).
pub fn shutdown() {
    let pool = GLOBAL.write().unwrap().take();
    let Some(pool) = pool else { return };
    let mut sh = pool.shared.lock().unwrap();
    sh.shutting_down = true;
    pool.work_cv.notify_all();
    while sh.workers > 0 {
        sh = pool.work_cv.wait(sh).unwrap();
    }
}

/// Number of live pool workers (0 before the first parallel dispatch
/// and after [`shutdown`]). Exposed for tests and bench metadata.
pub fn worker_count() -> usize {
    GLOBAL
        .read()
        .unwrap()
        .as_ref()
        .map_or(0, |p| p.shared.lock().unwrap().workers)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn dispatch_runs_every_chunk_exactly_once() {
        let hits: Vec<AtomicU64> = (0..23).map(|_| AtomicU64::new(0)).collect();
        dispatch(23, 3, &|ci| {
            hits[ci].fetch_add(1, Ordering::Relaxed);
        });
        for (ci, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "chunk {ci}");
        }
    }

    #[test]
    fn zero_helpers_runs_inline() {
        // the inline path never touches the global pool (no equality
        // assertion on worker_count here: sibling tests grow the pool
        // concurrently)
        let count = AtomicU64::new(0);
        dispatch(5, 0, &|_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 5);
    }

    #[test]
    fn pool_workers_are_reused_across_calls() {
        for _ in 0..200 {
            let count = AtomicU64::new(0);
            dispatch(4, 2, &|_| {
                count.fetch_add(1, Ordering::Relaxed);
            });
            assert_eq!(count.load(Ordering::Relaxed), 4);
        }
        // lazily started, then persistent: the 200 calls share workers
        assert!(worker_count() >= 1, "no persistent worker left");
        assert!(worker_count() <= MAX_WORKERS);
    }
}
