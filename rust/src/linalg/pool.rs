//! The persistent work-stealing executor behind
//! [`crate::linalg::engine::Engine`].
//!
//! PR 2's engine fanned every call out with `std::thread::scope`, which
//! pays a full spawn + join per call. PR 4 replaced that with one
//! process-wide pool of long-lived workers pulling whole jobs off a
//! FIFO queue — but workers claimed *fixed* chunks from a shared
//! counter, so a job with skewed chunk costs left every worker that
//! drew a cheap chunk idle while one straggler finished. This module
//! evolves the pool into a **work-stealing executor**:
//!
//! * Every dispatched chunk becomes one [`Task`] pushed onto a global
//!   **injector** queue. Workers keep **per-worker deques**: they pop
//!   their own deque LIFO (hot caches), refill in batches from the
//!   injector FIFO, and when both are empty **steal the front half** of
//!   a random-start round-robin victim's deque — so a straggling
//!   worker's backlog is redistributed instead of waiting on it.
//! * Idle workers **park** on a condvar and are woken by submits; the
//!   pending-task gauge is re-checked under the same mutex that submits
//!   publish under, so a wakeup can never be lost.
//! * The executor keeps **self-metrics** ([`PoolStats`] via [`stats`]):
//!   jobs/tasks submitted, worker-executed vs caller-executed chunks,
//!   steal + stolen-task counts, park count, pruned (stale) tasks,
//!   pending-task gauge + peak, and spawn latency (submit → first
//!   worker-side pickup, mean + max).
//! * Workers are still started **lazily** and grown by the *shortfall*
//!   between a job's useful helper count and the workers not currently
//!   busy, capped at [`MAX_WORKERS`]. A program that only ever uses
//!   sequential engines never starts a thread.
//!
//! # Exactly-once, bit-identical
//!
//! The single source of truth for "who runs chunk `ci`" is a per-chunk
//! **claim flag** (`AtomicBool::swap`): the submitting caller linearly
//! scans and claims chunks itself (so a job always makes progress even
//! if every worker is busy or the pool is shutting down), workers claim
//! through the tasks they pop or steal, and whoever loses the swap
//! drops the chunk. A task whose chunk was already claimed is *stale*
//! and is pruned, never run. Chunk *contents* are fixed by the
//! submitting `Engine` (contiguous index ranges); each chunk writes
//! results into its own pre-allocated slot and the caller reduces the
//! slots in chunk order after [`Job::wait`] — so scheduling (including
//! stealing) never leaks into results, and the engine's
//! bit-identical-to-sequential guarantee survives unchanged.
//!
//! * A panic inside a chunk is caught on the worker, parked in the job,
//!   and **resumed on the caller** once the job has fully drained. The
//!   worker survives and the pool keeps serving subsequent calls (no
//!   poisoning — pinned by `tests/engine_equivalence.rs`).
//! * [`shutdown`] drains the pool (workers exit, the global handle
//!   resets); the next parallel dispatch re-initializes it. In-flight
//!   callers are never stranded: they drain their own jobs through the
//!   claim scan.
//!
//! # Why the lifetime erasure is sound
//!
//! A job's closure borrows the caller's stack (`thread::scope`-style,
//! no `'static` bound). The raw pointer in the job erases that
//! lifetime, which is sound because (a) [`dispatch`] does not return
//! until every chunk has completed, so the borrow outlives every
//! dereference, and (b) the closure is only dereferenced for chunk
//! indices whose claim flag was won, and every claim happens before the
//! caller's completion latch releases. A *stale* task outliving its job
//! (still sitting in a deque after the caller returned) holds an `Arc`
//! to the job, so the claim flags it consults stay alive — and its
//! claim always fails, so the erased pointer is never dereferenced.
//!
//! Memory visibility: results travel worker → caller through the job's
//! state mutex (chunk-done increment / completion wait), so every side
//! effect of a chunk happens-before the caller's return from
//! [`dispatch`].
//!
//! # Lock order
//!
//! `Pool::shared` < `Pool::injector` < `Pool::slots` < any `Slot::deque`
//! — every acquisition path follows this order (at most one deque is
//! ever locked at a time), so the executor cannot deadlock on its own
//! locks.

use std::any::Any;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::Instant;

/// Hard cap on pool size: above this, extra requested helpers just
/// share the existing workers. Far beyond any sane `Engine::auto` and
/// merely a guard against `Engine::with_threads(huge)`.
pub const MAX_WORKERS: usize = 512;

/// Most tasks a worker moves from the injector to its own deque in one
/// refill. Bounds the latency penalty a burst of tiny jobs pays when
/// one worker grabs a batch just before parked workers wake.
const REFILL_MAX: usize = 32;

/// Lifetime-erased chunk runner. Only dereferenced for claimed chunk
/// indices while the submitting caller is blocked in [`Job::wait`].
struct RunPtr(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointee is `Sync` (it is built from `&(dyn Fn + Sync)`)
// and the pointer is only dereferenced under the liveness protocol in
// the module docs.
unsafe impl Send for RunPtr {}
unsafe impl Sync for RunPtr {}

/// One dispatched call: closure pointer, per-chunk claim flags, and the
/// completion latch the caller blocks on.
struct Job {
    run: RunPtr,
    chunks: usize,
    /// Per-chunk claim flags — the single source of exactly-once truth.
    /// Caller scan and worker tasks both claim through these.
    claimed: Box<[AtomicBool]>,
    /// Set by the first *worker-side* claim; gates the spawn-latency
    /// sample so each job contributes at most one.
    started: AtomicBool,
    submitted: Instant,
    state: Mutex<JobState>,
    done_cv: Condvar,
}

struct JobState {
    done: usize,
    /// First panic payload out of any chunk, re-raised on the caller.
    panic: Option<Box<dyn Any + Send>>,
}

impl Job {
    /// # Safety
    ///
    /// The caller must keep `run` alive (not return / not drop the
    /// closure) until [`Job::wait`] has returned.
    #[allow(clippy::transmutes_expressible_as_ptr_casts)]
    unsafe fn new(run: &(dyn Fn(usize) + Sync), chunks: usize) -> Arc<Job> {
        let run = RunPtr(std::mem::transmute::<
            &(dyn Fn(usize) + Sync),
            *const (dyn Fn(usize) + Sync + 'static),
        >(run));
        Arc::new(Job {
            run,
            chunks,
            claimed: (0..chunks).map(|_| AtomicBool::new(false)).collect(),
            started: AtomicBool::new(false),
            submitted: Instant::now(),
            state: Mutex::new(JobState { done: 0, panic: None }),
            done_cv: Condvar::new(),
        })
    }

    /// Try to win chunk `ci`. Exactly one claimer ever sees `true`.
    fn claim(&self, ci: usize) -> bool {
        !self.claimed[ci].swap(true, Ordering::AcqRel)
    }

    /// Run a *claimed* chunk. Panics in the closure are caught and
    /// parked so the claimer (possibly a pool worker) survives.
    fn run_chunk(&self, ci: usize) {
        // SAFETY: the claim on ci succeeded, so the caller is still
        // blocked in `wait` and the closure borrow is alive (module
        // docs).
        let result = catch_unwind(AssertUnwindSafe(|| unsafe { (*self.run.0)(ci) }));
        let mut st = self.state.lock().unwrap();
        if let Err(payload) = result {
            if st.panic.is_none() {
                st.panic = Some(payload);
            }
        }
        st.done += 1;
        if st.done == self.chunks {
            self.done_cv.notify_all();
        }
    }

    /// The submitting caller's claim scan: linearly claim and run every
    /// chunk the workers haven't taken yet. Guarantees forward progress
    /// with zero live workers. Returns how many chunks this thread ran.
    fn help(&self) -> u64 {
        let mut ran = 0u64;
        for ci in 0..self.chunks {
            if self.claim(ci) {
                self.run_chunk(ci);
                ran += 1;
            }
        }
        ran
    }

    /// Block until every chunk has finished, then re-raise the first
    /// chunk panic (if any) on this thread.
    fn wait(&self) {
        let mut st = self.state.lock().unwrap();
        while st.done < self.chunks {
            st = self.done_cv.wait(st).unwrap();
        }
        let panic = st.panic.take();
        drop(st);
        if let Some(payload) = panic {
            resume_unwind(payload);
        }
    }
}

/// One schedulable unit: chunk `chunk` of `job`. Stale once anyone
/// else claims the chunk; stale tasks are pruned, never run.
struct Task {
    job: Arc<Job>,
    chunk: usize,
}

impl Task {
    fn dead(&self) -> bool {
        self.job.claimed[self.chunk].load(Ordering::Acquire)
    }

    /// Claim and run the chunk; a lost claim (caller or another task
    /// got there first) is counted as pruned.
    fn execute(&self, pool: &Pool) {
        if !self.job.claim(self.chunk) {
            pool.metrics.tasks_pruned.fetch_add(1, Ordering::Relaxed);
            return;
        }
        if !self.job.started.swap(true, Ordering::Relaxed) {
            let ns = self.job.submitted.elapsed().as_nanos() as u64;
            pool.metrics.spawn_lat_sum_ns.fetch_add(ns, Ordering::Relaxed);
            pool.metrics.spawn_lat_count.fetch_add(1, Ordering::Relaxed);
            pool.metrics.spawn_lat_max_ns.fetch_max(ns, Ordering::Relaxed);
            for (i, bound) in SPAWN_LATENCY_BOUNDS_NS.iter().enumerate() {
                if ns <= *bound {
                    pool.metrics.spawn_lat_buckets[i]
                        .fetch_add(1, Ordering::Relaxed);
                    break;
                }
            }
        }
        self.job.run_chunk(self.chunk);
        pool.metrics.tasks_executed.fetch_add(1, Ordering::Relaxed);
    }
}

/// One worker's deque. Owned LIFO pops, front-half FIFO steals.
#[derive(Default)]
struct Slot {
    deque: Mutex<VecDeque<Task>>,
}

/// Executor self-metrics. All counters are monotonic over the life of
/// the current pool (reset by [`shutdown`] + lazy re-init);
/// `pending_tasks` is a gauge counting tasks currently resident in the
/// injector or any deque — including stale tasks not yet pruned.
#[derive(Default)]
struct Metrics {
    jobs: AtomicU64,
    tasks_injected: AtomicU64,
    tasks_executed: AtomicU64,
    caller_chunks: AtomicU64,
    steals: AtomicU64,
    stolen_tasks: AtomicU64,
    parks: AtomicU64,
    tasks_pruned: AtomicU64,
    pending: AtomicU64,
    pending_peak: AtomicU64,
    spawn_lat_sum_ns: AtomicU64,
    spawn_lat_count: AtomicU64,
    spawn_lat_max_ns: AtomicU64,
    spawn_lat_buckets: [AtomicU64; SPAWN_LATENCY_BOUNDS_NS.len()],
}

/// Upper bounds (ns, inclusive) of the spawn-latency histogram
/// buckets: 1µs, 10µs, 100µs, 1ms, 10ms. Latencies beyond the last
/// bound land only in the implicit +Inf bucket (`spawn_latency_count`).
pub const SPAWN_LATENCY_BOUNDS_NS: [u64; 5] =
    [1_000, 10_000, 100_000, 1_000_000, 10_000_000];

/// A point-in-time snapshot of the executor's self-metrics. See
/// [`stats`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Live worker threads.
    pub workers: usize,
    /// Jobs submitted to the pool (inline dispatches excluded).
    pub jobs: u64,
    /// Chunk tasks pushed onto the injector.
    pub tasks_injected: u64,
    /// Chunks executed by workers (via popped or stolen tasks).
    pub tasks_executed: u64,
    /// Chunks executed by submitting callers through their claim scan.
    pub caller_chunks: u64,
    /// Successful steal operations (at least one live task moved).
    pub steals: u64,
    /// Live tasks moved by those steals (≥ `steals`).
    pub stolen_tasks: u64,
    /// Times a worker parked on the condvar.
    pub parks: u64,
    /// Stale tasks discarded without running (chunk already claimed).
    pub tasks_pruned: u64,
    /// Tasks currently resident in the injector or a worker deque
    /// (gauge; includes stale tasks not yet pruned).
    pub pending_tasks: u64,
    /// High-water mark of `pending_tasks`.
    pub pending_peak: u64,
    /// Mean submit → first-worker-pickup latency (0 if no job was ever
    /// picked up by a worker).
    pub spawn_latency_mean_ns: u64,
    /// Max submit → first-worker-pickup latency.
    pub spawn_latency_max_ns: u64,
    /// Jobs whose spawn latency was recorded (first worker pickup).
    pub spawn_latency_count: u64,
    /// Sum of recorded spawn latencies, ns.
    pub spawn_latency_sum_ns: u64,
    /// Non-cumulative spawn-latency bucket counts, one per
    /// [`SPAWN_LATENCY_BOUNDS_NS`] bound.
    pub spawn_latency_buckets: [u64; SPAWN_LATENCY_BOUNDS_NS.len()],
}

impl PoolStats {
    /// Counters accumulate for the life of the pool; this subtracts an
    /// epoch snapshot so benches and alert rules see per-interval
    /// values, not lifetime totals. Gauges (`workers`,
    /// `pending_tasks`, `pending_peak`, the latency mean/max) keep
    /// their current values. Saturating, so a pool restart between
    /// snapshots yields zeros rather than wrapping.
    pub fn delta_since(&self, epoch: &PoolStats) -> PoolStats {
        let mut buckets = [0u64; SPAWN_LATENCY_BOUNDS_NS.len()];
        for (i, out) in buckets.iter_mut().enumerate() {
            *out = self.spawn_latency_buckets[i]
                .saturating_sub(epoch.spawn_latency_buckets[i]);
        }
        let count =
            self.spawn_latency_count.saturating_sub(epoch.spawn_latency_count);
        let sum = self
            .spawn_latency_sum_ns
            .saturating_sub(epoch.spawn_latency_sum_ns);
        PoolStats {
            workers: self.workers,
            jobs: self.jobs.saturating_sub(epoch.jobs),
            tasks_injected: self
                .tasks_injected
                .saturating_sub(epoch.tasks_injected),
            tasks_executed: self
                .tasks_executed
                .saturating_sub(epoch.tasks_executed),
            caller_chunks: self
                .caller_chunks
                .saturating_sub(epoch.caller_chunks),
            steals: self.steals.saturating_sub(epoch.steals),
            stolen_tasks: self.stolen_tasks.saturating_sub(epoch.stolen_tasks),
            parks: self.parks.saturating_sub(epoch.parks),
            tasks_pruned: self.tasks_pruned.saturating_sub(epoch.tasks_pruned),
            pending_tasks: self.pending_tasks,
            pending_peak: self.pending_peak,
            spawn_latency_mean_ns: if count == 0 { 0 } else { sum / count },
            spawn_latency_max_ns: self.spawn_latency_max_ns,
            spawn_latency_count: count,
            spawn_latency_sum_ns: sum,
            spawn_latency_buckets: buckets,
        }
    }

    /// Bridge this snapshot into a telemetry registry under
    /// `kermit_pool_*`. Pool counters are process-global (every
    /// dispatcher in the process shares them), so this is a caller
    /// decision — `TuningPlane::scrape` deliberately does not export
    /// them, keeping chaos-scenario registries sim-deterministic.
    pub fn export_metrics(&self, reg: &crate::obs::Registry) {
        let c = |name: &str, help: &str, v: u64| {
            reg.counter(name, help, &[]).set_total(v);
        };
        c(
            "kermit_pool_jobs_total",
            "Jobs submitted to the work-stealing pool.",
            self.jobs,
        );
        c(
            "kermit_pool_tasks_injected_total",
            "Chunk tasks pushed onto the pool injector.",
            self.tasks_injected,
        );
        c(
            "kermit_pool_tasks_executed_total",
            "Chunks executed by pool workers.",
            self.tasks_executed,
        );
        c(
            "kermit_pool_caller_chunks_total",
            "Chunks executed inline by submitting callers.",
            self.caller_chunks,
        );
        c(
            "kermit_pool_steals_total",
            "Successful steal operations.",
            self.steals,
        );
        c(
            "kermit_pool_stolen_tasks_total",
            "Live tasks moved by steals.",
            self.stolen_tasks,
        );
        c(
            "kermit_pool_parks_total",
            "Times a worker parked on the condvar.",
            self.parks,
        );
        c(
            "kermit_pool_tasks_pruned_total",
            "Stale tasks discarded without running.",
            self.tasks_pruned,
        );
        reg.gauge(
            "kermit_pool_workers",
            "Live pool worker threads.",
            &[],
        )
        .set(self.workers as f64);
        reg.gauge(
            "kermit_pool_pending_tasks",
            "Tasks resident in the injector or a worker deque.",
            &[],
        )
        .set(self.pending_tasks as f64);
        reg.gauge(
            "kermit_pool_pending_peak",
            "High-water mark of pending tasks.",
            &[],
        )
        .set(self.pending_peak as f64);
        let bounds: Vec<f64> =
            SPAWN_LATENCY_BOUNDS_NS.iter().map(|b| *b as f64).collect();
        reg.histogram(
            "kermit_pool_spawn_latency_ns",
            "Submit to first-worker-pickup latency, ns.",
            &[],
            &bounds,
        )
        .set_totals(
            &self.spawn_latency_buckets,
            self.spawn_latency_count,
            self.spawn_latency_sum_ns as f64,
        );
    }
}

struct Pool {
    shared: Mutex<Shared>,
    /// Workers park here; [`shutdown`] also waits here for the worker
    /// count to reach zero.
    work_cv: Condvar,
    /// Global FIFO all submits push to; workers refill from it in
    /// batches.
    injector: Mutex<VecDeque<Task>>,
    /// One slot per spawned worker, in spawn order. Grows only (workers
    /// exit only at shutdown, which discards the whole pool).
    slots: RwLock<Vec<Arc<Slot>>>,
    /// Workers currently executing a task. The growth heuristic in
    /// [`Pool::submit`] keys off `workers - busy` so concurrent callers
    /// each get their own helpers while back-to-back calls from one
    /// caller reuse the same workers. May transiently over-count
    /// (bounded over-spawn, see `submit`).
    busy: AtomicUsize,
    /// Fast shutdown flag checked at the top of every worker iteration.
    stop: AtomicBool,
    metrics: Metrics,
}

struct Shared {
    workers: usize,
    /// Workers currently blocked in `work_cv.wait` — submit wakes at
    /// most this many.
    sleepers: usize,
    shutting_down: bool,
}

impl Pool {
    fn new() -> Arc<Pool> {
        Arc::new(Pool {
            shared: Mutex::new(Shared { workers: 0, sleepers: 0, shutting_down: false }),
            work_cv: Condvar::new(),
            injector: Mutex::new(VecDeque::new()),
            slots: RwLock::new(Vec::new()),
            busy: AtomicUsize::new(0),
            stop: AtomicBool::new(false),
            metrics: Metrics::default(),
        })
    }

    /// Push one task per chunk of `job` onto the injector, growing the
    /// pool to however many workers the job can actually use (capped)
    /// and waking that many sleepers. On a pool already shutting down
    /// this is a no-op: the submitting caller drains the job itself via
    /// [`Job::help`].
    fn submit(self: &Arc<Pool>, job: &Arc<Job>, helpers: usize) {
        let mut sh = self.shared.lock().unwrap();
        if sh.shutting_down {
            return;
        }
        // at most `chunks - 1` workers can usefully serve this job (the
        // caller claims chunks itself), and only the shortfall against
        // currently-available workers needs spawning: a 2-chunk job on
        // a 64-thread engine grows/wakes one worker, not 63;
        // back-to-back calls from one caller reuse the same workers;
        // and a second concurrent caller (whose rival's workers are all
        // busy) grows its own helpers instead of sharing an
        // under-provisioned pool.
        let useful = helpers.min(job.chunks.saturating_sub(1));
        let busy = self.busy.load(Ordering::Relaxed).min(sh.workers);
        let available = sh.workers - busy;
        let mut grow = useful.saturating_sub(available);
        // `busy` can transiently over-count: a worker that just ran a
        // job's last chunk (caller already released) stays "busy" until
        // its decrement lands. The demand-justified cap below
        // (`busy + useful` total workers) bounds the resulting
        // over-spawn to that stale count, and extra workers park and
        // raise `available` for every later submit, so growth stops
        // instead of ratcheting.
        let cap = (busy + useful).min(MAX_WORKERS);
        while grow > 0 && sh.workers < cap {
            let slot = Arc::new(Slot::default());
            let me = {
                let mut slots = self.slots.write().unwrap();
                slots.push(Arc::clone(&slot));
                slots.len() - 1
            };
            let pool = Arc::clone(self);
            let spawned = std::thread::Builder::new()
                .name("kermit-engine".into())
                .spawn(move || worker_loop(&pool, &slot, me));
            match spawned {
                Ok(_) => {
                    sh.workers += 1;
                    grow -= 1;
                }
                // transient spawn failure (thread limit, OOM): drop the
                // unused slot and degrade to however many workers exist
                // — the caller and the surviving workers still drain
                // every job, and a later submit retries the growth.
                // Panicking here would poison the process-wide pool
                // mutex forever.
                Err(_) => {
                    self.slots.write().unwrap().pop();
                    break;
                }
            }
        }
        if sh.workers == 0 {
            // nothing could be spawned: don't queue — no worker exists
            // to ever pop a task, and the caller drains every chunk
            // itself anyway.
            return;
        }
        {
            // prune stale tasks before pushing: with every worker
            // pinned inside a long chunk, a caller looping tiny
            // self-drained dispatches would otherwise grow the injector
            // without bound.
            let mut inj = self.injector.lock().unwrap();
            let mut pruned = 0u64;
            inj.retain(|t| {
                if t.dead() {
                    pruned += 1;
                    false
                } else {
                    true
                }
            });
            if pruned > 0 {
                self.metrics.tasks_pruned.fetch_add(pruned, Ordering::Relaxed);
                self.metrics.pending.fetch_sub(pruned, Ordering::Relaxed);
            }
            for ci in 0..job.chunks {
                inj.push_back(Task { job: Arc::clone(job), chunk: ci });
            }
            self.metrics.jobs.fetch_add(1, Ordering::Relaxed);
            self.metrics.tasks_injected.fetch_add(job.chunks as u64, Ordering::Relaxed);
            // publish the pending increment while still holding the
            // shared mutex: a worker deciding to park re-checks pending
            // under that mutex, so it either sees these tasks or is
            // counted in `sleepers` and woken below — no lost wakeup.
            let pending = self.metrics.pending.fetch_add(job.chunks as u64, Ordering::Relaxed)
                + job.chunks as u64;
            self.metrics.pending_peak.fetch_max(pending, Ordering::Relaxed);
        }
        // wake only as many sleepers as can usefully claim a chunk.
        // Under-waking can't strand the job: busy workers re-check the
        // queues between tasks, and the caller always drains its own.
        for _ in 0..useful.min(sh.sleepers) {
            self.work_cv.notify_one();
        }
    }

    /// Pop the newest task off this worker's own deque (LIFO — the
    /// data it most recently touched), discarding stale ones.
    fn pop_local(&self, slot: &Slot) -> Option<Task> {
        let mut dq = slot.deque.lock().unwrap();
        while let Some(t) = dq.pop_back() {
            self.metrics.pending.fetch_sub(1, Ordering::Relaxed);
            if t.dead() {
                self.metrics.tasks_pruned.fetch_add(1, Ordering::Relaxed);
                continue;
            }
            return Some(t);
        }
        None
    }

    /// Move a fair batch from the injector front into this worker's
    /// deque, returning the first live task to run now. Tasks moved to
    /// the deque stay "pending"; only the returned and the stale ones
    /// leave the gauge.
    fn refill(&self, slot: &Slot) -> Option<Task> {
        let nworkers = self.slots.read().unwrap().len().max(1);
        let grabbed: Vec<Task> = {
            let mut inj = self.injector.lock().unwrap();
            if inj.is_empty() {
                return None;
            }
            let take = inj.len().div_ceil(nworkers).clamp(1, REFILL_MAX).min(inj.len());
            inj.drain(..take).collect()
        };
        self.absorb(slot, grabbed)
    }

    /// Steal the front half (oldest — FIFO end) of a victim's deque,
    /// round-robin from `rr`. Returns the first live stolen task.
    fn steal(&self, slot: &Slot, me: usize, rr: &mut usize) -> Option<Task> {
        let slots = self.slots.read().unwrap();
        let n = slots.len();
        if n <= 1 {
            return None;
        }
        for k in 0..n {
            let v = (*rr + k) % n;
            if v == me {
                continue;
            }
            let grabbed: Vec<Task> = {
                let mut dq = slots[v].deque.lock().unwrap();
                if dq.is_empty() {
                    continue;
                }
                let take = dq.len().div_ceil(2);
                dq.drain(..take).collect()
            };
            *rr = (v + 1) % n;
            let live = grabbed.iter().filter(|t| !t.dead()).count() as u64;
            if live > 0 {
                self.metrics.steals.fetch_add(1, Ordering::Relaxed);
                self.metrics.stolen_tasks.fetch_add(live, Ordering::Relaxed);
            }
            if let Some(first) = self.absorb(slot, grabbed) {
                return Some(first);
            }
            // everything stolen was stale — keep scanning victims
        }
        None
    }

    /// File a batch of tasks grabbed from elsewhere: discard stale
    /// ones, keep the first live one out to run immediately, queue the
    /// rest on this worker's own deque.
    fn absorb(&self, slot: &Slot, grabbed: Vec<Task>) -> Option<Task> {
        let mut first = None;
        let mut dq = slot.deque.lock().unwrap();
        for t in grabbed {
            if t.dead() {
                self.metrics.pending.fetch_sub(1, Ordering::Relaxed);
                self.metrics.tasks_pruned.fetch_add(1, Ordering::Relaxed);
            } else if first.is_none() {
                self.metrics.pending.fetch_sub(1, Ordering::Relaxed);
                first = Some(t);
            } else {
                dq.push_back(t);
            }
        }
        first
    }

    /// Park until a submit signals new work. The pending gauge is
    /// re-checked under the shared mutex (where submits publish it), so
    /// the sleep can't miss a wakeup.
    fn park(&self) {
        let mut sh = self.shared.lock().unwrap();
        if sh.shutting_down || self.metrics.pending.load(Ordering::Relaxed) > 0 {
            return;
        }
        self.metrics.parks.fetch_add(1, Ordering::Relaxed);
        sh.sleepers += 1;
        sh = self.work_cv.wait(sh).unwrap();
        sh.sleepers -= 1;
        drop(sh);
    }
}

fn worker_loop(pool: &Arc<Pool>, slot: &Arc<Slot>, me: usize) {
    let mut rr = (me + 1) % MAX_WORKERS.max(1);
    loop {
        if pool.stop.load(Ordering::Acquire) {
            let mut sh = pool.shared.lock().unwrap();
            sh.workers -= 1;
            // wake the shutdown waiter (and fellow workers) so the
            // count re-check runs
            pool.work_cv.notify_all();
            return;
        }
        let task = pool
            .pop_local(slot)
            .or_else(|| pool.refill(slot))
            .or_else(|| pool.steal(slot, me, &mut rr));
        match task {
            Some(t) => {
                pool.busy.fetch_add(1, Ordering::Relaxed);
                t.execute(pool);
                pool.busy.fetch_sub(1, Ordering::Relaxed);
            }
            None => pool.park(),
        }
    }
}

/// The process-wide pool handle. `None` until the first parallel
/// dispatch (lazy start) and after [`shutdown`]. An `RwLock` (not a
/// `Mutex`) so the many-small-dispatches hot path only ever takes the
/// read lock once the pool exists; the write lock is limited to lazy
/// init and [`shutdown`]. (An `OnceLock` can't give the reset-on-
/// shutdown semantics.)
static GLOBAL: RwLock<Option<Arc<Pool>>> = RwLock::new(None);

fn handle() -> Arc<Pool> {
    if let Some(p) = GLOBAL.read().unwrap().as_ref() {
        return Arc::clone(p);
    }
    let mut g = GLOBAL.write().unwrap();
    Arc::clone(g.get_or_insert_with(Pool::new))
}

/// Run `run(ci)` for every chunk index in `0..chunks`, the calling
/// thread claiming chunks alongside up to `helpers` pool workers.
/// Blocks until every chunk has finished; the first panic out of any
/// chunk resumes on the caller after the job has fully drained (the
/// pool itself is never poisoned).
///
/// With `helpers == 0` or a single chunk the call runs entirely inline
/// — no queue traffic, no wakeups.
pub(crate) fn dispatch(chunks: usize, helpers: usize, run: &(dyn Fn(usize) + Sync)) {
    if chunks == 0 {
        return;
    }
    if helpers == 0 || chunks == 1 {
        for ci in 0..chunks {
            run(ci);
        }
        return;
    }
    // SAFETY: `job.wait()` below blocks this frame until every chunk
    // has completed, so `run` outlives every dereference of the erased
    // pointer.
    let job = unsafe { Job::new(run, chunks) };
    let pool = handle();
    pool.submit(&job, helpers);
    let mine = job.help();
    pool.metrics.caller_chunks.fetch_add(mine, Ordering::Relaxed);
    job.wait();
}

/// Tear the pool down: workers exit, the global handle resets, and the
/// next parallel dispatch lazily re-initializes a fresh pool (with
/// fresh metrics). In-flight jobs are drained by their submitting
/// callers (which always run their own claim scan), so this never
/// strands a caller — but it does busy-drain through them, so prefer
/// calling it at quiesce points (process teardown, between test cases).
pub fn shutdown() {
    let pool = GLOBAL.write().unwrap().take();
    let Some(pool) = pool else { return };
    pool.stop.store(true, Ordering::Release);
    let mut sh = pool.shared.lock().unwrap();
    sh.shutting_down = true;
    pool.work_cv.notify_all();
    while sh.workers > 0 {
        sh = pool.work_cv.wait(sh).unwrap();
    }
}

/// Number of live pool workers (0 before the first parallel dispatch
/// and after [`shutdown`]). Exposed for tests and bench metadata.
pub fn worker_count() -> usize {
    GLOBAL
        .read()
        .unwrap()
        .as_ref()
        .map_or(0, |p| p.shared.lock().unwrap().workers)
}

/// Snapshot the executor's self-metrics. All zeros before the first
/// parallel dispatch and after [`shutdown`]. Counters are process-
/// global: concurrent dispatchers all add to the same snapshot, so
/// consumers should diff two snapshots around the region they care
/// about rather than assert absolute values.
pub fn stats() -> PoolStats {
    let g = GLOBAL.read().unwrap();
    let Some(p) = g.as_ref() else {
        return PoolStats::default();
    };
    let m = &p.metrics;
    let count = m.spawn_lat_count.load(Ordering::Relaxed);
    let sum = m.spawn_lat_sum_ns.load(Ordering::Relaxed);
    let mut buckets = [0u64; SPAWN_LATENCY_BOUNDS_NS.len()];
    for (i, out) in buckets.iter_mut().enumerate() {
        *out = m.spawn_lat_buckets[i].load(Ordering::Relaxed);
    }
    PoolStats {
        workers: p.shared.lock().unwrap().workers,
        jobs: m.jobs.load(Ordering::Relaxed),
        tasks_injected: m.tasks_injected.load(Ordering::Relaxed),
        tasks_executed: m.tasks_executed.load(Ordering::Relaxed),
        caller_chunks: m.caller_chunks.load(Ordering::Relaxed),
        steals: m.steals.load(Ordering::Relaxed),
        stolen_tasks: m.stolen_tasks.load(Ordering::Relaxed),
        parks: m.parks.load(Ordering::Relaxed),
        tasks_pruned: m.tasks_pruned.load(Ordering::Relaxed),
        pending_tasks: m.pending.load(Ordering::Relaxed),
        pending_peak: m.pending_peak.load(Ordering::Relaxed),
        spawn_latency_mean_ns: if count == 0 { 0 } else { sum / count },
        spawn_latency_max_ns: m.spawn_lat_max_ns.load(Ordering::Relaxed),
        spawn_latency_count: count,
        spawn_latency_sum_ns: sum,
        spawn_latency_buckets: buckets,
    }
}

/// Epoch-diffing wrapper around [`stats`]: returns the counter deltas
/// since `epoch` and advances `epoch` to the current snapshot, so each
/// call yields the activity of the interval it closes. Start from
/// `PoolStats::default()` to make the first interval span the pool's
/// whole life.
pub fn stats_delta(epoch: &mut PoolStats) -> PoolStats {
    let now = stats();
    let delta = now.delta_since(epoch);
    *epoch = now;
    delta
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn delta_since_subtracts_counters_and_keeps_gauges() {
        let mut epoch = PoolStats {
            jobs: 10,
            steals: 4,
            spawn_latency_count: 2,
            spawn_latency_sum_ns: 2_000,
            spawn_latency_buckets: [2, 0, 0, 0, 0],
            ..PoolStats::default()
        };
        let now = PoolStats {
            workers: 3,
            jobs: 15,
            steals: 9,
            pending_tasks: 7,
            spawn_latency_count: 4,
            spawn_latency_sum_ns: 8_000,
            spawn_latency_buckets: [2, 2, 0, 0, 0],
            ..PoolStats::default()
        };
        let d = now.delta_since(&epoch);
        assert_eq!(d.jobs, 5);
        assert_eq!(d.steals, 5);
        assert_eq!(d.workers, 3, "gauge keeps current value");
        assert_eq!(d.pending_tasks, 7, "gauge keeps current value");
        assert_eq!(d.spawn_latency_count, 2);
        assert_eq!(d.spawn_latency_mean_ns, 3_000);
        assert_eq!(d.spawn_latency_buckets, [0, 2, 0, 0, 0]);
        // a restarted pool (counters below epoch) saturates to zero
        epoch.jobs = 100;
        assert_eq!(now.delta_since(&epoch).jobs, 0);
    }

    #[test]
    fn dispatch_runs_every_chunk_exactly_once() {
        let hits: Vec<AtomicU64> = (0..23).map(|_| AtomicU64::new(0)).collect();
        dispatch(23, 3, &|ci| {
            hits[ci].fetch_add(1, Ordering::Relaxed);
        });
        for (ci, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "chunk {ci}");
        }
    }

    #[test]
    fn zero_helpers_runs_inline() {
        // the inline path never touches the global pool (no equality
        // assertion on worker_count here: sibling tests grow the pool
        // concurrently)
        let count = AtomicU64::new(0);
        dispatch(5, 0, &|_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 5);
    }

    #[test]
    fn pool_workers_are_reused_across_calls() {
        for _ in 0..200 {
            let count = AtomicU64::new(0);
            dispatch(4, 2, &|_| {
                count.fetch_add(1, Ordering::Relaxed);
            });
            assert_eq!(count.load(Ordering::Relaxed), 4);
        }
        // lazily started, then persistent: the 200 calls share workers
        assert!(worker_count() >= 1, "no persistent worker left");
        assert!(worker_count() <= MAX_WORKERS);
    }

    #[test]
    fn skewed_chunks_run_exactly_once_and_metrics_stay_consistent() {
        // Heavy head chunk + cheap tail chunks: the worker that draws
        // chunk 0 builds a stealable backlog. Assertions stick to
        // invariants that hold under any interleaving (metrics are
        // process-global and sibling tests dispatch concurrently).
        for _ in 0..50 {
            let hits: Vec<AtomicU64> = (0..17).map(|_| AtomicU64::new(0)).collect();
            dispatch(17, 3, &|ci| {
                let spins: u64 = if ci == 0 { 20_000 } else { 50 };
                let mut acc = 0u64;
                for i in 0..spins {
                    acc = acc.wrapping_mul(31).wrapping_add(i);
                }
                std::hint::black_box(acc);
                hits[ci].fetch_add(1, Ordering::Relaxed);
            });
            for (ci, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "chunk {ci}");
            }
        }
        let st = stats();
        assert!(st.workers >= 1, "pool should be live after 50 parallel dispatches");
        assert!(st.jobs >= 50);
        assert!(st.tasks_injected >= st.jobs, "every job injects >= 1 task");
        assert!(
            st.stolen_tasks >= st.steals,
            "each counted steal moves >= 1 live task: {st:?}"
        );
        assert!(
            st.spawn_latency_max_ns >= st.spawn_latency_mean_ns,
            "max below mean: {st:?}"
        );
        assert!(st.pending_peak >= st.pending_tasks, "peak is a high-water mark: {st:?}");
    }
}
