//! The compute engine behind the clustering / classification hot paths:
//! explicit SIMD squared-distance kernels and a std-only **persistent
//! worker pool** for the embarrassingly-parallel row loops.
//!
//! # SIMD kernels and feature gates
//!
//! [`sq_dist`] is the dispatch point every distance computation in the
//! crate funnels through (via `linalg::sq_dist`). Four tiers, picked at
//! compile time by cargo feature and at **runtime** by CPU detection
//! (cached after the first call, scalar fallback everywhere):
//!
//! | build                  | kernel                | equivalence guarantee |
//! |------------------------|-----------------------|-----------------------|
//! | default                | [`sq_dist_scalar`], four-accumulator scalar | reference arithmetic |
//! | `--features simd`      | AVX f64x4, **no FMA** | **bit-identical** to scalar |
//! | `--features simd-fast` | AVX2 f64x4 **FMA**    | relative error ≤ [`SIMD_FAST_REL_TOL`]; labels unchanged on the golden fixtures |
//! | `--features simd-fast` + AVX-512 host | AVX-512 f64x8 FMA | same tolerance contract as AVX2 FMA |
//!
//! The plain-`simd` AVX kernel deliberately avoids fused multiply-add:
//! lane `i` of the vector accumulator performs exactly the operation
//! sequence of scalar accumulator `s[i]`, and the horizontal reduction
//! uses the same `(s0 + s1) + (s2 + s3)` order, so that tier is
//! **bit-identical** to the scalar path (pinned by a property test) and
//! every golden-equivalence guarantee holds regardless of build flavour.
//!
//! The `simd-fast` tier trades that bit identity for throughput: FMA
//! contracts `acc + d*d` into one correctly-rounded operation (the
//! *contracted* result is more accurate, not less — it skips the
//! intermediate rounding of `d*d`), and the AVX-512 path additionally
//! changes the accumulator width and reduction shape. Because
//! `sq_dist` is a sum of non-negative terms there is no cancellation,
//! so the relative error against the scalar kernel is bounded by the
//! usual `n·ε` accumulation bound — [`SIMD_FAST_REL_TOL`] documents the
//! shipped contract and the tolerance property tests in
//! `tests/engine_equivalence.rs` pin it, together with label-stability
//! tests showing the low-order distance bits never flip a clustering or
//! classification decision on the golden fixtures. Both fast kernels
//! remain **bitwise symmetric** (`sq_dist(a,b) == sq_dist(b,a)`), which
//! is what the parallel pairwise matrix relies on.
//!
//! On non-x86_64 targets every simd feature compiles to the scalar
//! kernel; the features are no-ops rather than build errors. The
//! AVX-512 intrinsics need Rust ≥ 1.89 (they stabilised there).
//!
//! # Persistent work-stealing executor
//!
//! [`Engine`] is a tiny `Copy` handle — thread count, sequential-
//! fallback threshold, chunk alignment — that callers pick **once at
//! construction** ([`Engine::sequential`], [`Engine::auto`],
//! [`Engine::with_threads`]) and thread through the clustering / ML /
//! discovery APIs. Parallel calls no longer spawn scoped threads; they
//! push one task per chunk onto the process-wide work-stealing executor
//! ([`crate::linalg::pool`]): lazily-started workers pop their own
//! deques LIFO, refill from a global injector, and steal from each
//! other when their local work runs dry, so skewed chunk costs (one
//! hot tenant shard among thousands of idle ones) redistribute instead
//! of serializing on a straggler. The calling thread always claims
//! chunks itself through the per-chunk claim flags, so every call makes
//! progress even under pool contention or shutdown, and a
//! 1000-small-call loop (per-merge agglomerative scans, per-tick router
//! dispatch) pays parking-lot wakeups instead of thread spawns — see
//! the `spawn_amortization` stage of `benches/hotpath.rs`. The executor
//! exports self-metrics (steals, parks, pending tasks, spawn latency)
//! via [`pool_stats`].
//!
//! Batches smaller than `min_items` (default [`MIN_PAR_ITEMS`]) run
//! sequentially on the calling thread: below roughly that many rows
//! even a pool wakeup exceeds the row work itself for the 32-wide
//! analytic rows these loops process. Callers whose items are
//! individually heavy (fitting one forest tree, draining one tenant
//! shard) lower it with [`Engine::with_min_items`].
//!
//! # Chunking and determinism
//!
//! Chunks are contiguous index ranges and results are reduced **in
//! chunk order**, so any per-row map is bit-identical to its sequential
//! run. Reductions that break ties by index (k-means empty-cluster
//! reseed, agglomerative closest-pair) keep sequential tie-breaking by
//! comparing chunk-local winners in chunk order — see
//! `clustering::kmeans` for the pattern; those reductions are written
//! to be chunk-boundary-invariant, which also makes them alignment-
//! invariant. [`Engine::with_chunk_align`] rounds chunk boundaries up
//! to a multiple of the given item count; pair it with
//! [`Engine::cache_align_for`] so boundaries land on cache-line-sized
//! multiples from the buffer start — adjacent workers then share at
//! most the one line straddling each boundary (none when the
//! allocation happens to be line-aligned; `Vec` guarantees only
//! element alignment), instead of a line per misplaced split.
//! Alignment changes *where* chunks split, never what is computed.
//! Work stealing operates strictly **at** chunk granularity — a steal
//! moves whole not-yet-claimed chunks between workers, never splits
//! one — and results land in per-chunk slots reduced in chunk order,
//! so which thread ran a chunk (stolen or not) never reaches the data
//! path and there is no scheduling nondeterminism to observe.

use super::pool;
pub use super::pool::{
    stats as pool_stats, stats_delta as pool_stats_delta, PoolStats,
    SPAWN_LATENCY_BOUNDS_NS,
};
use std::ops::Range;

/// Below this many items a parallel call runs sequentially (see the
/// module docs for the rationale).
pub const MIN_PAR_ITEMS: usize = 64;

/// Documented error contract of the `simd-fast` tier: for inputs up to
/// a few thousand features, `|sq_dist - sq_dist_scalar|` is bounded by
/// `SIMD_FAST_REL_TOL * sq_dist_scalar` (plus nothing — the sum has no
/// cancellation, so the bound is purely the `n·ε` accumulation term,
/// about `4e-13` at n = 4096 and far smaller for the 32-wide analytic
/// rows). The default and plain-`simd` tiers are exact (bit-identical),
/// not merely within this bound.
pub const SIMD_FAST_REL_TOL: f64 = 1e-12;

/// Cache-line size assumed by [`Engine::cache_align_for`].
pub const CACHE_LINE_BYTES: usize = 64;

/// Worker-pool engine handle. Cheap to copy; embed it in configs so
/// parallelism is picked once at construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Engine {
    threads: usize,
    min_items: usize,
    chunk_align: usize,
}

impl Engine {
    /// Single-threaded engine: every call runs on the calling thread.
    pub fn sequential() -> Engine {
        Engine { threads: 1, min_items: MIN_PAR_ITEMS, chunk_align: 1 }
    }

    /// Engine with an explicit worker count (clamped to at least 1).
    pub fn with_threads(threads: usize) -> Engine {
        Engine { threads: threads.max(1), min_items: MIN_PAR_ITEMS, chunk_align: 1 }
    }

    /// Engine sized to the host (`std::thread::available_parallelism`),
    /// overridable with the `KERMIT_THREADS` environment variable
    /// (clamped to ≥ 1; unparsable values fall back to the host size).
    /// The override is what makes CI benches and `bench_diff` runs
    /// reproducible across heterogeneous runners.
    pub fn auto() -> Engine {
        let host = || std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let threads = match std::env::var("KERMIT_THREADS") {
            Ok(v) => {
                v.trim().parse::<usize>().map(|n| n.max(1)).unwrap_or_else(|_| host())
            }
            Err(_) => host(),
        };
        Engine::with_threads(threads)
    }

    /// Override the sequential-fallback threshold (items per call below
    /// which the pool is not used). For loops whose items are
    /// individually expensive — fitting a tree, not scanning a row.
    pub fn with_min_items(mut self, min_items: usize) -> Engine {
        self.min_items = min_items.max(1);
        self
    }

    /// Round chunk boundaries up to a multiple of `items` items
    /// (clamped to ≥ 1; 1 = split anywhere, the default). Use
    /// [`Engine::cache_align_for`] to compute the item count that puts
    /// boundaries on cache-line multiples of the row stride. Alignment
    /// can reduce the number of chunks for tiny batches (the rounded
    /// chunk covers more items), so leave it at 1 for loops whose items
    /// are individually heavy.
    pub fn with_chunk_align(mut self, items: usize) -> Engine {
        self.chunk_align = items.max(1);
        self
    }

    /// Smallest item count whose byte span is a whole number of cache
    /// lines: with chunks aligned to this, adjacent workers share at
    /// most the single line straddling each chunk boundary (and none
    /// when the buffer base happens to be line-aligned — `Vec` only
    /// guarantees element alignment). `stride` is in elements of `T`
    /// per item (e.g. one n-wide matrix row ⇒
    /// `cache_align_for::<f64>(n)`). Always a power of two ≤ 64; 1
    /// when a single item already spans whole lines.
    pub fn cache_align_for<T>(stride: usize) -> usize {
        let bytes = std::mem::size_of::<T>().max(1) * stride.max(1);
        CACHE_LINE_BYTES / gcd(bytes, CACHE_LINE_BYTES)
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Configured chunk alignment, in items.
    pub fn chunk_align(&self) -> usize {
        self.chunk_align
    }

    /// Would a call over `items` items actually fan out?
    pub fn is_parallel_for(&self, items: usize) -> bool {
        self.threads > 1 && items >= self.min_items
    }

    /// Chunk length (in items) for a parallel call over `items`:
    /// an even `threads`-way split, rounded up to the chunk alignment.
    fn chunk_items(&self, items: usize) -> usize {
        let workers = self.threads.min(items);
        round_up(items.div_ceil(workers), self.chunk_align)
    }

    /// Parallel for over disjoint chunks of `out`, collecting one result
    /// per chunk **in chunk order**.
    ///
    /// `out` is split at multiples of `stride` (use `stride > 1` when
    /// each logical item spans several elements, e.g. one matrix row of
    /// `n` distances). `f` receives the first *item* index of its chunk
    /// and the chunk slice. Sequential below the engine threshold, in
    /// which case `f` runs once over the whole slice.
    pub fn for_rows_map<T, R, F>(&self, out: &mut [T], stride: usize, f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(usize, &mut [T]) -> R + Sync,
    {
        assert!(stride > 0, "stride must be positive");
        assert_eq!(out.len() % stride, 0, "slice length not a stride multiple");
        let items = out.len() / stride;
        if !self.is_parallel_for(items) {
            return vec![f(0, out)];
        }
        let chunk_items = self.chunk_items(items);
        let chunk_len = chunk_items * stride;
        let chunks = items.div_ceil(chunk_items);
        let total_len = out.len();
        let out_ptr = SendPtr(out.as_mut_ptr());
        self.dispatch_collect(chunks, |ci| {
            let start = ci * chunk_len;
            let len = chunk_len.min(total_len - start);
            // SAFETY: chunk `ci` exclusively owns out[start..start+len]
            // (chunk ranges are disjoint) and the borrow ends before
            // `out` is touched again — the pool blocks until every
            // chunk has completed.
            let chunk = unsafe {
                std::slice::from_raw_parts_mut(out_ptr.0.add(start), len)
            };
            f(ci * chunk_items, chunk)
        })
    }

    /// Parallel for over disjoint chunks of `out` (no per-chunk result).
    pub fn for_rows<T, F>(&self, out: &mut [T], stride: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        self.for_rows_map(out, stride, |start, chunk| f(start, chunk));
    }

    /// Fan a read-only computation over contiguous sub-ranges of `0..n`,
    /// collecting one result per chunk **in chunk order**. Sequential
    /// below the engine threshold (one call over the whole range).
    pub fn map_chunks<R, F>(&self, n: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(Range<usize>) -> R + Sync,
    {
        if !self.is_parallel_for(n) {
            return vec![f(0..n)];
        }
        let chunk = self.chunk_items(n);
        let chunks = n.div_ceil(chunk);
        self.dispatch_collect(chunks, |ci| {
            let start = ci * chunk;
            f(start..(start + chunk).min(n))
        })
    }

    /// Shared pool-dispatch scaffolding for the parallel paths: run
    /// `run(ci)` for every chunk index in `0..chunks` (the calling
    /// thread claiming chunks alongside up to `threads - 1` pool
    /// workers) and collect each chunk's result **in chunk order**.
    /// This is the one place the result-slot raw-pointer protocol
    /// lives; the public methods only contribute their chunk math.
    fn dispatch_collect<R, F>(&self, chunks: usize, run: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        let mut results: Vec<Option<R>> = Vec::with_capacity(chunks);
        results.resize_with(chunks, || None);
        {
            let res_ptr = SendPtr(results.as_mut_ptr());
            let task = |ci: usize| {
                let r = run(ci);
                // SAFETY: chunk `ci` exclusively owns results[ci], and
                // the write ends before `results` is read below — the
                // pool blocks until every chunk has completed (a chunk
                // panic also counts as completed, and unwinds on this
                // thread before the read).
                unsafe { *res_ptr.0.add(ci) = Some(r) };
            };
            pool::dispatch(chunks, self.threads - 1, &task);
        }
        results.into_iter().map(|r| r.expect("pool chunk skipped")).collect()
    }
}

impl Default for Engine {
    fn default() -> Engine {
        Engine::sequential()
    }
}

/// Raw-pointer wrapper so disjoint chunk writes can cross the pool's
/// closure boundary. Soundness rests on the chunk-disjointness argument
/// at each use site.
struct SendPtr<T>(*mut T);
unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

fn round_up(x: usize, align: usize) -> usize {
    debug_assert!(align >= 1);
    x.div_ceil(align) * align
}

fn gcd(mut a: usize, mut b: usize) -> usize {
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a.max(1)
}

// ---------------------------------------------------------------------------
// squared-distance kernels
// ---------------------------------------------------------------------------

/// Scalar squared euclidean distance: four independent accumulators so
/// the compiler can keep the loop in SIMD lanes even without the
/// explicit kernel. This is the reference arithmetic the AVX path must
/// match bit-for-bit (and the `simd-fast` tiers within
/// [`SIMD_FAST_REL_TOL`]).
#[inline]
pub fn sq_dist_scalar(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut ca = a.chunks_exact(4);
    let mut cb = b.chunks_exact(4);
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    for (x, y) in (&mut ca).zip(&mut cb) {
        let d0 = x[0] - y[0];
        let d1 = x[1] - y[1];
        let d2 = x[2] - y[2];
        let d3 = x[3] - y[3];
        s0 += d0 * d0;
        s1 += d1 * d1;
        s2 += d2 * d2;
        s3 += d3 * d3;
    }
    let mut sum = (s0 + s1) + (s2 + s3);
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        let d = x - y;
        sum += d * d;
    }
    sum
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod avx {
    use std::arch::x86_64::{
        _mm256_add_pd, _mm256_loadu_pd, _mm256_mul_pd, _mm256_setzero_pd,
        _mm256_storeu_pd, _mm256_sub_pd,
    };

    /// AVX f64x4 squared distance, bit-identical to
    /// [`super::sq_dist_scalar`]: lane `i` of `acc` runs exactly the
    /// scalar accumulator `s[i]`'s operation sequence (no FMA — fusing
    /// would change the rounding and break golden equivalence), and the
    /// horizontal reduction uses the same `(s0 + s1) + (s2 + s3)` order.
    ///
    /// # Safety
    ///
    /// The caller must have verified AVX support on the running CPU
    /// (see `tier::active`).
    #[target_feature(enable = "avx")]
    pub unsafe fn sq_dist(a: &[f64], b: &[f64]) -> f64 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let n4 = n / 4 * 4;
        let mut acc = _mm256_setzero_pd();
        let mut i = 0;
        while i < n4 {
            let x = _mm256_loadu_pd(a.as_ptr().add(i));
            let y = _mm256_loadu_pd(b.as_ptr().add(i));
            let d = _mm256_sub_pd(x, y);
            acc = _mm256_add_pd(acc, _mm256_mul_pd(d, d));
            i += 4;
        }
        let mut lanes = [0.0f64; 4];
        _mm256_storeu_pd(lanes.as_mut_ptr(), acc);
        let mut sum = (lanes[0] + lanes[1]) + (lanes[2] + lanes[3]);
        while i < n {
            let d = *a.get_unchecked(i) - *b.get_unchecked(i);
            sum += d * d;
            i += 1;
        }
        sum
    }
}

#[cfg(all(feature = "simd-fast", target_arch = "x86_64"))]
mod avx2_fma {
    use std::arch::x86_64::{
        _mm256_fmadd_pd, _mm256_loadu_pd, _mm256_setzero_pd, _mm256_storeu_pd,
        _mm256_sub_pd,
    };

    /// AVX2 f64x4 squared distance with fused multiply-add: `simd-fast`
    /// tier, within [`super::SIMD_FAST_REL_TOL`] of the scalar kernel
    /// (not bit-identical — the FMA skips the intermediate rounding of
    /// `d*d`). Bitwise symmetric in its arguments, which the parallel
    /// pairwise matrix relies on.
    ///
    /// # Safety
    ///
    /// The caller must have verified AVX2 + FMA support on the running
    /// CPU (see `tier::active`).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn sq_dist(a: &[f64], b: &[f64]) -> f64 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let n4 = n / 4 * 4;
        let mut acc = _mm256_setzero_pd();
        let mut i = 0;
        while i < n4 {
            let x = _mm256_loadu_pd(a.as_ptr().add(i));
            let y = _mm256_loadu_pd(b.as_ptr().add(i));
            let d = _mm256_sub_pd(x, y);
            acc = _mm256_fmadd_pd(d, d, acc);
            i += 4;
        }
        let mut lanes = [0.0f64; 4];
        _mm256_storeu_pd(lanes.as_mut_ptr(), acc);
        let mut sum = (lanes[0] + lanes[1]) + (lanes[2] + lanes[3]);
        while i < n {
            let d = *a.get_unchecked(i) - *b.get_unchecked(i);
            sum += d * d;
            i += 1;
        }
        sum
    }
}

#[cfg(all(feature = "simd-fast", target_arch = "x86_64"))]
mod avx512 {
    use std::arch::x86_64::{
        _mm512_fmadd_pd, _mm512_loadu_pd, _mm512_reduce_add_pd, _mm512_setzero_pd,
        _mm512_sub_pd,
    };

    /// AVX-512 f64x8 squared distance with fused multiply-add: the
    /// widest `simd-fast` tier, same tolerance contract as the AVX2 FMA
    /// kernel ([`super::SIMD_FAST_REL_TOL`]) and likewise bitwise
    /// symmetric. Needs Rust ≥ 1.89 (AVX-512 intrinsics stabilised
    /// there).
    ///
    /// # Safety
    ///
    /// The caller must have verified AVX-512F support on the running
    /// CPU (see `tier::active`).
    #[target_feature(enable = "avx512f")]
    pub unsafe fn sq_dist(a: &[f64], b: &[f64]) -> f64 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let n8 = n / 8 * 8;
        let mut acc = _mm512_setzero_pd();
        let mut i = 0;
        while i < n8 {
            let x = _mm512_loadu_pd(a.as_ptr().add(i));
            let y = _mm512_loadu_pd(b.as_ptr().add(i));
            let d = _mm512_sub_pd(x, y);
            acc = _mm512_fmadd_pd(d, d, acc);
            i += 8;
        }
        let mut sum = _mm512_reduce_add_pd(acc);
        while i < n {
            let d = *a.get_unchecked(i) - *b.get_unchecked(i);
            sum += d * d;
            i += 1;
        }
        sum
    }
}

/// Runtime kernel-tier detection, cached after the first call.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod tier {
    use std::sync::atomic::{AtomicU8, Ordering};

    pub const SCALAR: u8 = 1;
    pub const AVX: u8 = 2;
    #[cfg(feature = "simd-fast")]
    pub const AVX2_FMA: u8 = 3;
    #[cfg(feature = "simd-fast")]
    pub const AVX512_FMA: u8 = 4;

    static STATE: AtomicU8 = AtomicU8::new(0);

    /// The active kernel tier (0 is "not yet probed" and never
    /// returned).
    pub fn active() -> u8 {
        match STATE.load(Ordering::Relaxed) {
            0 => {
                let t = detect();
                STATE.store(t, Ordering::Relaxed);
                t
            }
            t => t,
        }
    }

    fn detect() -> u8 {
        #[cfg(feature = "simd-fast")]
        {
            if is_x86_feature_detected!("avx512f") {
                return AVX512_FMA;
            }
            if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
                return AVX2_FMA;
            }
        }
        if is_x86_feature_detected!("avx") {
            AVX
        } else {
            SCALAR
        }
    }
}

/// Squared euclidean distance — the dispatch point (`linalg::sq_dist`
/// forwards here). Picks the best compiled-in kernel the running CPU
/// supports: AVX-512 FMA / AVX2 FMA under `simd-fast`, the bit-exact
/// AVX kernel under plain `simd`, scalar otherwise. See the module docs
/// for the per-tier equivalence guarantees.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[inline]
pub fn sq_dist(a: &[f64], b: &[f64]) -> f64 {
    match tier::active() {
        // SAFETY: each arm's CPU features were verified by `tier::active`.
        #[cfg(feature = "simd-fast")]
        tier::AVX512_FMA => unsafe { avx512::sq_dist(a, b) },
        #[cfg(feature = "simd-fast")]
        tier::AVX2_FMA => unsafe { avx2_fma::sq_dist(a, b) },
        tier::AVX => unsafe { avx::sq_dist(a, b) },
        _ => sq_dist_scalar(a, b),
    }
}

/// Squared euclidean distance — the dispatch point (`linalg::sq_dist`
/// forwards here). This build has no explicit SIMD kernel compiled in;
/// the scalar kernel is the (auto-vectorising) implementation.
#[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
#[inline]
pub fn sq_dist(a: &[f64], b: &[f64]) -> f64 {
    sq_dist_scalar(a, b)
}

/// True when an explicit SIMD kernel is compiled in *and* the running
/// CPU supports it (benches record this into their JSON metadata).
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
pub fn simd_active() -> bool {
    tier::active() != tier::SCALAR
}

/// True when an explicit SIMD kernel is compiled in *and* the running
/// CPU supports it (benches record this into their JSON metadata).
#[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
pub fn simd_active() -> bool {
    false
}

/// Name of the kernel [`sq_dist`] actually dispatches to on this build
/// + host: `"scalar"`, `"avx"`, `"avx2-fma"`, or `"avx512-fma"`.
/// Benches record it so baseline diffs compare like with like.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
pub fn simd_tier() -> &'static str {
    match tier::active() {
        #[cfg(feature = "simd-fast")]
        tier::AVX512_FMA => "avx512-fma",
        #[cfg(feature = "simd-fast")]
        tier::AVX2_FMA => "avx2-fma",
        tier::AVX => "avx",
        _ => "scalar",
    }
}

/// Name of the kernel [`sq_dist`] actually dispatches to on this build
/// + host: always `"scalar"` without the `simd` feature on x86_64.
#[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
pub fn simd_tier() -> &'static str {
    "scalar"
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn for_rows_visits_every_item_once_with_correct_index() {
        for threads in [1, 2, 4, 7] {
            let engine = Engine::with_threads(threads).with_min_items(1);
            let mut out = vec![usize::MAX; 333];
            engine.for_rows(&mut out, 1, |start, chunk| {
                for (off, cell) in chunk.iter_mut().enumerate() {
                    assert_eq!(*cell, usize::MAX, "item visited twice");
                    *cell = start + off;
                }
            });
            let want: Vec<usize> = (0..333).collect();
            assert_eq!(out, want, "threads = {threads}");
        }
    }

    #[test]
    fn for_rows_respects_stride() {
        let engine = Engine::with_threads(3).with_min_items(1);
        let mut out = vec![0usize; 50 * 7];
        engine.for_rows(&mut out, 7, |first_item, chunk| {
            assert_eq!(chunk.len() % 7, 0, "chunk split mid-row");
            for (off, row) in chunk.chunks_mut(7).enumerate() {
                for cell in row.iter_mut() {
                    *cell = first_item + off;
                }
            }
        });
        for (i, row) in out.chunks(7).enumerate() {
            assert!(row.iter().all(|&v| v == i), "row {i}: {row:?}");
        }
    }

    #[test]
    fn for_rows_map_results_in_chunk_order() {
        let engine = Engine::with_threads(4).with_min_items(1);
        let mut out = vec![0u8; 100];
        let firsts = engine.for_rows_map(&mut out, 1, |start, _| start);
        let mut sorted = firsts.clone();
        sorted.sort_unstable();
        assert_eq!(firsts, sorted, "chunk results out of order");
        assert_eq!(firsts[0], 0);
    }

    #[test]
    fn map_chunks_partitions_range_in_order() {
        for (threads, n) in [(1, 10), (4, 100), (3, 64), (16, 65)] {
            let engine = Engine::with_threads(threads).with_min_items(1);
            let ranges = engine.map_chunks(n, |r| r);
            let mut next = 0;
            for r in &ranges {
                assert_eq!(r.start, next, "gap/overlap at {next}");
                assert!(r.end > r.start);
                next = r.end;
            }
            assert_eq!(next, n, "threads={threads} n={n}");
        }
    }

    #[test]
    fn chunk_alignment_rounds_boundaries_and_covers_everything() {
        for (threads, n, align) in [(4, 100, 8), (3, 65, 4), (8, 120, 16), (2, 7, 64)] {
            let engine =
                Engine::with_threads(threads).with_min_items(1).with_chunk_align(align);
            let ranges = engine.map_chunks(n, |r| r);
            let mut next = 0;
            for (ci, r) in ranges.iter().enumerate() {
                assert_eq!(r.start, next, "gap/overlap at {next}");
                assert_eq!(r.start % align, 0, "unaligned boundary {}", r.start);
                assert!(
                    r.len() % align == 0 || ci == ranges.len() - 1,
                    "non-final chunk {ci} unaligned: {r:?}"
                );
                next = r.end;
            }
            assert_eq!(next, n, "threads={threads} n={n} align={align}");
        }
    }

    #[test]
    fn cache_align_for_matches_item_sizes() {
        // 8-byte items: 8 per 64-byte line
        assert_eq!(Engine::cache_align_for::<f64>(1), 8);
        // a 32-wide f64 row is 256 bytes = 4 whole lines
        assert_eq!(Engine::cache_align_for::<f64>(32), 1);
        // 16-byte items: 4 per line
        assert_eq!(Engine::cache_align_for::<(i32, f64)>(1), 4);
        // a 5-wide f64 row (40 bytes): 8 rows = 5 lines
        assert_eq!(Engine::cache_align_for::<f64>(5), 8);
        assert_eq!(Engine::cache_align_for::<u8>(1), 64);
    }

    #[test]
    fn alignment_does_not_change_results() {
        let mut rng = Rng::new(5);
        let xs: Vec<f64> = (0..300).map(|_| rng.normal()).collect();
        let run = |engine: Engine| -> Vec<f64> {
            let mut out = vec![0.0f64; xs.len()];
            engine.for_rows(&mut out, 1, |start, chunk| {
                for (off, cell) in chunk.iter_mut().enumerate() {
                    *cell = xs[start + off] * 2.0 + 1.0;
                }
            });
            out
        };
        let plain = run(Engine::with_threads(4).with_min_items(1));
        for align in [2, 8, 64] {
            let aligned =
                run(Engine::with_threads(4).with_min_items(1).with_chunk_align(align));
            assert_eq!(plain, aligned, "align = {align}");
        }
    }

    #[test]
    fn empty_input_is_a_noop() {
        let engine = Engine::with_threads(4).with_min_items(1);
        let mut out: Vec<u32> = Vec::new();
        let results = engine.for_rows_map(&mut out, 1, |_, chunk| chunk.len());
        assert_eq!(results, vec![0]);
        assert_eq!(engine.map_chunks(0, |r| r.len()), vec![0]);
    }

    #[test]
    fn threshold_keeps_small_batches_sequential() {
        let engine = Engine::with_threads(8);
        assert!(!engine.is_parallel_for(MIN_PAR_ITEMS - 1));
        assert!(engine.is_parallel_for(MIN_PAR_ITEMS));
        assert!(!Engine::sequential().is_parallel_for(1 << 20));
        assert!(engine.with_min_items(1).is_parallel_for(2));
    }

    #[test]
    fn with_threads_clamps_to_one() {
        assert_eq!(Engine::with_threads(0).threads(), 1);
        assert_eq!(Engine::sequential().with_chunk_align(0).chunk_align(), 1);
    }

    // Engine::auto()'s KERMIT_THREADS handling is tested in
    // tests/engine_env.rs — a dedicated integration-test binary, so
    // its set_var never races another test's getenv (setenv vs getenv
    // across threads is UB on glibc, and several lib unit tests read
    // env vars, e.g. runtime artifact dirs).

    #[test]
    fn sq_dist_dispatch_matches_scalar_all_lengths() {
        // covering every remainder case of the 4- and 8-lane kernels.
        // Exact bits for the default and plain-simd tiers; the
        // simd-fast tiers are pinned to the documented tolerance
        // instead (and exactly when the fast kernels fall back).
        let mut rng = Rng::new(42);
        for n in 0..=64usize {
            let a: Vec<f64> = (0..n).map(|_| rng.range_f64(-100.0, 100.0)).collect();
            let b: Vec<f64> = (0..n).map(|_| rng.range_f64(-100.0, 100.0)).collect();
            let fast = sq_dist(&a, &b);
            let scalar = sq_dist_scalar(&a, &b);
            if cfg!(feature = "simd-fast") {
                assert!(
                    (fast - scalar).abs() <= SIMD_FAST_REL_TOL * scalar.max(f64::MIN_POSITIVE),
                    "n = {n}: {fast} vs {scalar}"
                );
            } else {
                assert_eq!(fast, scalar, "n = {n}");
            }
        }
    }

    #[test]
    fn sq_dist_is_symmetric_bitwise() {
        let mut rng = Rng::new(7);
        let a: Vec<f64> = (0..32).map(|_| rng.normal_ms(5.0, 3.0)).collect();
        let b: Vec<f64> = (0..32).map(|_| rng.normal_ms(1.0, 2.0)).collect();
        // exact symmetry is what lets the parallel pairwise matrix
        // compute both triangles independently yet stay bit-identical —
        // it holds for every tier (the FMA kernels square a sign-
        // flipped difference, which is sign-invariant)
        assert_eq!(sq_dist(&a, &b), sq_dist(&b, &a));
    }

    #[test]
    fn simd_tier_is_consistent_with_simd_active() {
        let tier = simd_tier();
        assert!(
            ["scalar", "avx", "avx2-fma", "avx512-fma"].contains(&tier),
            "unknown tier {tier}"
        );
        assert_eq!(simd_active(), tier != "scalar");
        if !cfg!(feature = "simd") {
            assert_eq!(tier, "scalar");
        }
        if !cfg!(feature = "simd-fast") {
            assert!(!tier.ends_with("fma"), "fma tier without simd-fast: {tier}");
        }
    }

    #[test]
    fn parallel_map_is_bit_identical_to_sequential() {
        let mut rng = Rng::new(3);
        let xs: Vec<f64> = (0..500).map(|_| rng.normal()).collect();
        let run = |engine: Engine| -> Vec<f64> {
            let mut out = vec![0.0f64; xs.len()];
            engine.for_rows(&mut out, 1, |start, chunk| {
                for (off, cell) in chunk.iter_mut().enumerate() {
                    let x = xs[start + off];
                    *cell = (x * 1.7).sin() + x * x;
                }
            });
            out
        };
        let seq = run(Engine::sequential());
        for threads in [2, 3, 8] {
            assert_eq!(
                seq,
                run(Engine::with_threads(threads).with_min_items(1)),
                "threads = {threads}"
            );
        }
    }

    #[test]
    #[should_panic(expected = "stride multiple")]
    fn stride_mismatch_panics() {
        let mut out = vec![0u8; 10];
        Engine::sequential().for_rows(&mut out, 3, |_, _| {});
    }
}
