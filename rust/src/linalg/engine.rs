//! The compute engine behind the clustering / classification hot paths:
//! an explicit SIMD squared-distance kernel and a std-only scoped-thread
//! worker pool for the embarrassingly-parallel row loops.
//!
//! # SIMD kernel and feature gates
//!
//! [`sq_dist`] is the dispatch point every distance computation in the
//! crate funnels through (via `linalg::sq_dist`). Three tiers:
//!
//! * **default build** — [`sq_dist_scalar`], the four-accumulator scalar
//!   kernel. It auto-vectorises well and keeps the build dependency- and
//!   `unsafe`-free.
//! * **`--features simd`, x86_64** — an explicit AVX f64x4 kernel
//!   (`std::arch` intrinsics, no external crates). Availability is
//!   checked *at runtime* via `is_x86_feature_detected!` and cached, so
//!   a `simd` binary still runs correctly on a pre-AVX host by falling
//!   back to the scalar kernel.
//! * **`--features simd`, non-x86_64** — compiles to the scalar kernel;
//!   the feature is a no-op rather than a build error.
//!
//! The AVX kernel deliberately avoids fused multiply-add: lane `i` of
//! the vector accumulator performs exactly the operation sequence of
//! scalar accumulator `s[i]`, and the horizontal reduction uses the same
//! `(s0 + s1) + (s2 + s3)` order, so the SIMD path is **bit-identical**
//! to the scalar path (pinned by a property test). That keeps every
//! golden-equivalence guarantee of the numeric core intact regardless of
//! build flavour.
//!
//! # Worker pool and threshold heuristics
//!
//! [`Engine`] is a tiny `Copy` handle — a thread count plus a
//! sequential-fallback threshold — that callers pick **once at
//! construction** ([`Engine::sequential`], [`Engine::auto`],
//! [`Engine::with_threads`]) and thread through the clustering / ML /
//! discovery APIs. Work is fanned out with `std::thread::scope` (no
//! external thread-pool dependency, no `'static` bounds), split into at
//! most `threads` contiguous, disjoint chunks.
//!
//! Batches smaller than `min_items` (default [`MIN_PAR_ITEMS`]) run
//! sequentially on the calling thread: below roughly that many rows the
//! scoped-spawn cost (~tens of µs) exceeds the row work itself for the
//! 32-wide analytic rows these loops process. Callers whose items are
//! individually heavy (e.g. fitting one forest tree) lower it with
//! [`Engine::with_min_items`].
//!
//! # Determinism
//!
//! Chunks are contiguous index ranges and results are reduced **in
//! chunk order**, so any per-row map is bit-identical to its sequential
//! run. Reductions that break ties by index (k-means empty-cluster
//! reseed, agglomerative closest-pair) keep sequential tie-breaking by
//! comparing chunk-local winners in chunk order — see
//! `clustering::kmeans` for the pattern. Nothing in this module uses
//! work stealing or atomics on the data path, so there is no scheduling
//! nondeterminism to begin with.

use std::ops::Range;

/// Below this many items a parallel call runs sequentially (see the
/// module docs for the rationale).
pub const MIN_PAR_ITEMS: usize = 64;

/// Scoped-thread worker pool handle. Cheap to copy; embed it in configs
/// so parallelism is picked once at construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Engine {
    threads: usize,
    min_items: usize,
}

impl Engine {
    /// Single-threaded engine: every call runs on the calling thread.
    pub fn sequential() -> Engine {
        Engine { threads: 1, min_items: MIN_PAR_ITEMS }
    }

    /// Engine with an explicit worker count (clamped to at least 1).
    pub fn with_threads(threads: usize) -> Engine {
        Engine { threads: threads.max(1), min_items: MIN_PAR_ITEMS }
    }

    /// Engine sized to the host (`std::thread::available_parallelism`).
    pub fn auto() -> Engine {
        let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Engine::with_threads(threads)
    }

    /// Override the sequential-fallback threshold (items per call below
    /// which no threads are spawned). For loops whose items are
    /// individually expensive — fitting a tree, not scanning a row.
    pub fn with_min_items(mut self, min_items: usize) -> Engine {
        self.min_items = min_items.max(1);
        self
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Would a call over `items` items actually fan out?
    pub fn is_parallel_for(&self, items: usize) -> bool {
        self.threads > 1 && items >= self.min_items
    }

    /// Parallel for over disjoint chunks of `out`, collecting one result
    /// per chunk **in chunk order**.
    ///
    /// `out` is split at multiples of `stride` (use `stride > 1` when
    /// each logical item spans several elements, e.g. one matrix row of
    /// `n` distances). `f` receives the first *item* index of its chunk
    /// and the chunk slice. Sequential below the engine threshold, in
    /// which case `f` runs once over the whole slice.
    pub fn for_rows_map<T, R, F>(&self, out: &mut [T], stride: usize, f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(usize, &mut [T]) -> R + Sync,
    {
        assert!(stride > 0, "stride must be positive");
        assert_eq!(out.len() % stride, 0, "slice length not a stride multiple");
        let items = out.len() / stride;
        if !self.is_parallel_for(items) {
            return vec![f(0, out)];
        }
        let workers = self.threads.min(items);
        let chunk_items = items.div_ceil(workers);
        let chunk_len = chunk_items * stride;
        std::thread::scope(|s| {
            let f = &f;
            let handles: Vec<_> = out
                .chunks_mut(chunk_len)
                .enumerate()
                .map(|(ci, chunk)| s.spawn(move || f(ci * chunk_items, chunk)))
                .collect();
            handles.into_iter().map(join_or_resume).collect()
        })
    }

    /// Parallel for over disjoint chunks of `out` (no per-chunk result).
    pub fn for_rows<T, F>(&self, out: &mut [T], stride: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        self.for_rows_map(out, stride, |start, chunk| f(start, chunk));
    }

    /// Fan a read-only computation over contiguous sub-ranges of `0..n`,
    /// collecting one result per chunk **in chunk order**. Sequential
    /// below the engine threshold (one call over the whole range).
    pub fn map_chunks<R, F>(&self, n: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(Range<usize>) -> R + Sync,
    {
        if !self.is_parallel_for(n) {
            return vec![f(0..n)];
        }
        let workers = self.threads.min(n);
        let chunk = n.div_ceil(workers);
        std::thread::scope(|s| {
            let f = &f;
            let handles: Vec<_> = (0..n)
                .step_by(chunk)
                .map(|start| {
                    let end = (start + chunk).min(n);
                    s.spawn(move || f(start..end))
                })
                .collect();
            handles.into_iter().map(join_or_resume).collect()
        })
    }
}

impl Default for Engine {
    fn default() -> Engine {
        Engine::sequential()
    }
}

fn join_or_resume<R>(h: std::thread::ScopedJoinHandle<'_, R>) -> R {
    match h.join() {
        Ok(r) => r,
        Err(payload) => std::panic::resume_unwind(payload),
    }
}

// ---------------------------------------------------------------------------
// squared-distance kernels
// ---------------------------------------------------------------------------

/// Scalar squared euclidean distance: four independent accumulators so
/// the compiler can keep the loop in SIMD lanes even without the
/// explicit kernel. This is the reference arithmetic the AVX path must
/// match bit-for-bit.
#[inline]
pub fn sq_dist_scalar(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut ca = a.chunks_exact(4);
    let mut cb = b.chunks_exact(4);
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    for (x, y) in (&mut ca).zip(&mut cb) {
        let d0 = x[0] - y[0];
        let d1 = x[1] - y[1];
        let d2 = x[2] - y[2];
        let d3 = x[3] - y[3];
        s0 += d0 * d0;
        s1 += d1 * d1;
        s2 += d2 * d2;
        s3 += d3 * d3;
    }
    let mut sum = (s0 + s1) + (s2 + s3);
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        let d = x - y;
        sum += d * d;
    }
    sum
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod avx {
    use std::arch::x86_64::{
        _mm256_add_pd, _mm256_loadu_pd, _mm256_mul_pd, _mm256_setzero_pd,
        _mm256_storeu_pd, _mm256_sub_pd,
    };

    /// AVX f64x4 squared distance, bit-identical to
    /// [`super::sq_dist_scalar`]: lane `i` of `acc` runs exactly the
    /// scalar accumulator `s[i]`'s operation sequence (no FMA — fusing
    /// would change the rounding and break golden equivalence), and the
    /// horizontal reduction uses the same `(s0 + s1) + (s2 + s3)` order.
    ///
    /// # Safety
    ///
    /// The caller must have verified AVX support on the running CPU
    /// (see `avx_active`).
    #[target_feature(enable = "avx")]
    pub unsafe fn sq_dist(a: &[f64], b: &[f64]) -> f64 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let n4 = n / 4 * 4;
        let mut acc = _mm256_setzero_pd();
        let mut i = 0;
        while i < n4 {
            let x = _mm256_loadu_pd(a.as_ptr().add(i));
            let y = _mm256_loadu_pd(b.as_ptr().add(i));
            let d = _mm256_sub_pd(x, y);
            acc = _mm256_add_pd(acc, _mm256_mul_pd(d, d));
            i += 4;
        }
        let mut lanes = [0.0f64; 4];
        _mm256_storeu_pd(lanes.as_mut_ptr(), acc);
        let mut sum = (lanes[0] + lanes[1]) + (lanes[2] + lanes[3]);
        while i < n {
            let d = *a.get_unchecked(i) - *b.get_unchecked(i);
            sum += d * d;
            i += 1;
        }
        sum
    }
}

/// Cached runtime AVX check: 0 = unknown, 1 = available, 2 = absent.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[inline]
fn avx_active() -> bool {
    use std::sync::atomic::{AtomicU8, Ordering};
    static STATE: AtomicU8 = AtomicU8::new(0);
    match STATE.load(Ordering::Relaxed) {
        1 => true,
        2 => false,
        _ => {
            let ok = is_x86_feature_detected!("avx");
            STATE.store(if ok { 1 } else { 2 }, Ordering::Relaxed);
            ok
        }
    }
}

/// Squared euclidean distance — the dispatch point (`linalg::sq_dist`
/// forwards here). Explicit AVX kernel when compiled with `--features
/// simd` on an x86_64 host that has AVX; scalar kernel otherwise. Both
/// paths produce bit-identical results.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[inline]
pub fn sq_dist(a: &[f64], b: &[f64]) -> f64 {
    if avx_active() {
        // SAFETY: AVX availability verified by `avx_active`.
        unsafe { avx::sq_dist(a, b) }
    } else {
        sq_dist_scalar(a, b)
    }
}

/// Squared euclidean distance — the dispatch point (`linalg::sq_dist`
/// forwards here). This build has no explicit SIMD kernel compiled in;
/// the scalar kernel is the (auto-vectorising) implementation.
#[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
#[inline]
pub fn sq_dist(a: &[f64], b: &[f64]) -> f64 {
    sq_dist_scalar(a, b)
}

/// True when the explicit SIMD kernel is compiled in *and* the running
/// CPU supports it (benches record this into their JSON metadata).
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
pub fn simd_active() -> bool {
    avx_active()
}

/// True when the explicit SIMD kernel is compiled in *and* the running
/// CPU supports it (benches record this into their JSON metadata).
#[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
pub fn simd_active() -> bool {
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn for_rows_visits_every_item_once_with_correct_index() {
        for threads in [1, 2, 4, 7] {
            let engine = Engine::with_threads(threads).with_min_items(1);
            let mut out = vec![usize::MAX; 333];
            engine.for_rows(&mut out, 1, |start, chunk| {
                for (off, cell) in chunk.iter_mut().enumerate() {
                    assert_eq!(*cell, usize::MAX, "item visited twice");
                    *cell = start + off;
                }
            });
            let want: Vec<usize> = (0..333).collect();
            assert_eq!(out, want, "threads = {threads}");
        }
    }

    #[test]
    fn for_rows_respects_stride() {
        let engine = Engine::with_threads(3).with_min_items(1);
        let mut out = vec![0usize; 50 * 7];
        engine.for_rows(&mut out, 7, |first_item, chunk| {
            assert_eq!(chunk.len() % 7, 0, "chunk split mid-row");
            for (off, row) in chunk.chunks_mut(7).enumerate() {
                for cell in row.iter_mut() {
                    *cell = first_item + off;
                }
            }
        });
        for (i, row) in out.chunks(7).enumerate() {
            assert!(row.iter().all(|&v| v == i), "row {i}: {row:?}");
        }
    }

    #[test]
    fn for_rows_map_results_in_chunk_order() {
        let engine = Engine::with_threads(4).with_min_items(1);
        let mut out = vec![0u8; 100];
        let firsts = engine.for_rows_map(&mut out, 1, |start, _| start);
        let mut sorted = firsts.clone();
        sorted.sort_unstable();
        assert_eq!(firsts, sorted, "chunk results out of order");
        assert_eq!(firsts[0], 0);
    }

    #[test]
    fn map_chunks_partitions_range_in_order() {
        for (threads, n) in [(1, 10), (4, 100), (3, 64), (16, 65)] {
            let engine = Engine::with_threads(threads).with_min_items(1);
            let ranges = engine.map_chunks(n, |r| r);
            let mut next = 0;
            for r in &ranges {
                assert_eq!(r.start, next, "gap/overlap at {next}");
                assert!(r.end > r.start);
                next = r.end;
            }
            assert_eq!(next, n, "threads={threads} n={n}");
        }
    }

    #[test]
    fn empty_input_is_a_noop() {
        let engine = Engine::with_threads(4).with_min_items(1);
        let mut out: Vec<u32> = Vec::new();
        let results = engine.for_rows_map(&mut out, 1, |_, chunk| chunk.len());
        assert_eq!(results, vec![0]);
        assert_eq!(engine.map_chunks(0, |r| r.len()), vec![0]);
    }

    #[test]
    fn threshold_keeps_small_batches_sequential() {
        let engine = Engine::with_threads(8);
        assert!(!engine.is_parallel_for(MIN_PAR_ITEMS - 1));
        assert!(engine.is_parallel_for(MIN_PAR_ITEMS));
        assert!(!Engine::sequential().is_parallel_for(1 << 20));
        assert!(engine.with_min_items(1).is_parallel_for(2));
    }

    #[test]
    fn with_threads_clamps_to_one() {
        assert_eq!(Engine::with_threads(0).threads(), 1);
        assert!(Engine::auto().threads() >= 1);
    }

    #[test]
    fn sq_dist_dispatch_matches_scalar_all_lengths() {
        // bit-identical across 0..=64, covering every remainder case of
        // the 4-lane kernel (exact equality, not a tolerance)
        let mut rng = Rng::new(42);
        for n in 0..=64usize {
            let a: Vec<f64> = (0..n).map(|_| rng.range_f64(-100.0, 100.0)).collect();
            let b: Vec<f64> = (0..n).map(|_| rng.range_f64(-100.0, 100.0)).collect();
            assert_eq!(sq_dist(&a, &b), sq_dist_scalar(&a, &b), "n = {n}");
        }
    }

    #[test]
    fn sq_dist_is_symmetric_bitwise() {
        let mut rng = Rng::new(7);
        let a: Vec<f64> = (0..32).map(|_| rng.normal_ms(5.0, 3.0)).collect();
        let b: Vec<f64> = (0..32).map(|_| rng.normal_ms(1.0, 2.0)).collect();
        // exact symmetry is what lets the parallel pairwise matrix
        // compute both triangles independently yet stay bit-identical
        assert_eq!(sq_dist(&a, &b), sq_dist(&b, &a));
    }

    #[test]
    fn parallel_map_is_bit_identical_to_sequential() {
        let mut rng = Rng::new(3);
        let xs: Vec<f64> = (0..500).map(|_| rng.normal()).collect();
        let run = |engine: Engine| -> Vec<f64> {
            let mut out = vec![0.0f64; xs.len()];
            engine.for_rows(&mut out, 1, |start, chunk| {
                for (off, cell) in chunk.iter_mut().enumerate() {
                    let x = xs[start + off];
                    *cell = (x * 1.7).sin() + x * x;
                }
            });
            out
        };
        let seq = run(Engine::sequential());
        for threads in [2, 3, 8] {
            assert_eq!(
                seq,
                run(Engine::with_threads(threads).with_min_items(1)),
                "threads = {threads}"
            );
        }
    }

    #[test]
    #[should_panic(expected = "stride multiple")]
    fn stride_mismatch_panics() {
        let mut out = vec![0u8; 10];
        Engine::sequential().for_rows(&mut out, 3, |_, _| {});
    }
}
