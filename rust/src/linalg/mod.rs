//! Contiguous numeric core: the [`Matrix`] row store and the cache-
//! friendly distance/accumulate kernels every clustering and ML path in
//! the crate runs on. The [`engine`] submodule supplies the compute
//! layer on top — the explicit SIMD `sq_dist` kernel tiers (behind the
//! `simd` / `simd-fast` cargo features) and the `Engine` handle whose
//! row-parallel hot paths fan out on the lazily-started persistent
//! worker pool in [`pool`].
//!
//! # Layout
//!
//! A `Matrix` is a dense row-major table: one flat `Vec<f64>` of
//! `rows * cols` values, row `i` occupying `data[i*cols .. (i+1)*cols]`.
//! Compared to the `Vec<Vec<f64>>` it replaced, rows are contiguous in
//! memory (one allocation instead of `n+1`, no pointer chase per row),
//! so scanning kernels like [`sq_dist`] stream linearly through cache
//! and auto-vectorise.
//!
//! # Aliasing rules
//!
//! Row accessors hand out plain slices: [`Matrix::row`] borrows the
//! whole matrix shared, [`Matrix::row_mut`] borrows it exclusively.
//! There is deliberately no cell-level interior mutability — callers
//! that need to read row `a` while writing row `b` should either copy
//! the source row into a scratch buffer first or restructure as a
//! gather + write (see `kmeans`'s `sums` buffer for the idiom).
//!
//! # Views vs owned
//!
//! * Pass `&Matrix` (or a `&[f64]` row view) through APIs; it is `Copy`
//!   -cheap and keeps the single allocation alive.
//! * Own a `Matrix` when the rows are a new value (a gathered cluster,
//!   a standardised copy of a dataset). [`Matrix::gather`] and
//!   [`Matrix::from_rows`] build those in one pass.
//! * A width of 0 on an empty matrix means "width not fixed yet": the
//!   first [`Matrix::push_row`] adopts the row's width. This lets
//!   growable containers (e.g. `ml::Dataset`) start empty without
//!   declaring a width up front.

pub mod engine;
pub mod pool;

/// Dense row-major matrix of `f64`. See the module docs for layout and
/// aliasing rules.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Matrix {
    data: Vec<f64>,
    rows: usize,
    cols: usize,
}

impl Matrix {
    /// Empty matrix with the width left unfixed (adopted on first push).
    pub fn new() -> Matrix {
        Matrix::default()
    }

    /// Empty matrix with a fixed width.
    pub fn with_width(cols: usize) -> Matrix {
        Matrix { data: Vec::new(), rows: 0, cols }
    }

    /// `rows x cols` matrix of zeros.
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { data: vec![0.0; rows * cols], rows, cols }
    }

    /// Adopt a flat row-major buffer. Panics unless `data.len()` is an
    /// exact multiple of `cols`.
    pub fn from_flat(data: Vec<f64>, cols: usize) -> Matrix {
        assert!(cols > 0, "from_flat needs cols > 0");
        assert_eq!(data.len() % cols, 0, "flat length not a multiple of width");
        let rows = data.len() / cols;
        Matrix { data, rows, cols }
    }

    /// Boundary shim: copy a `Vec<Vec<f64>>` row set into contiguous
    /// storage once. Panics on inconsistent widths.
    pub fn from_rows(rows: &[Vec<f64>]) -> Matrix {
        let mut m = Matrix::new();
        for r in rows {
            m.push_row(r);
        }
        m
    }

    pub fn n_rows(&self) -> usize {
        self.rows
    }

    pub fn n_cols(&self) -> usize {
        self.cols
    }

    pub fn is_empty(&self) -> bool {
        self.rows == 0
    }

    /// Append one row. An empty width-unfixed matrix adopts the row's
    /// width; otherwise the width must match.
    pub fn push_row(&mut self, row: &[f64]) {
        if self.rows == 0 && self.cols == 0 {
            self.cols = row.len();
        }
        assert_eq!(
            row.len(),
            self.cols,
            "inconsistent feature width: row {} vs matrix {}",
            row.len(),
            self.cols
        );
        self.data.extend_from_slice(row);
        self.rows += 1;
    }

    /// Append every row of `other` (width must match, or self empty).
    pub fn extend_rows(&mut self, other: &Matrix) {
        if other.rows == 0 {
            return;
        }
        if self.rows == 0 && self.cols == 0 {
            self.cols = other.cols;
        }
        assert_eq!(self.cols, other.cols, "inconsistent feature width");
        self.data.extend_from_slice(&other.data);
        self.rows += other.rows;
    }

    /// Drop the first `k` rows (FIFO trim for bounded stores).
    pub fn remove_first_rows(&mut self, k: usize) {
        let k = k.min(self.rows);
        self.data.drain(..k * self.cols);
        self.rows -= k;
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        debug_assert!(i < self.rows);
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        debug_assert!(i < self.rows);
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Iterate rows as slices, in order.
    pub fn iter_rows(&self) -> impl Iterator<Item = &[f64]> + '_ {
        (0..self.rows).map(move |i| self.row(i))
    }

    /// New matrix holding the selected rows, in `idx` order.
    pub fn gather(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix {
            data: Vec::with_capacity(idx.len() * self.cols),
            rows: 0,
            cols: self.cols,
        };
        for &i in idx {
            out.data.extend_from_slice(self.row(i));
            out.rows += 1;
        }
        out
    }

    /// The whole storage, row-major.
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }
}

/// Squared euclidean distance between two equal-length slices.
///
/// On contiguous `Matrix` rows this is the hot kernel of k-means
/// assign, DBSCAN's distance matrix, kNN, and the centroid gates.
/// Dispatches through [`engine::sq_dist`]: the best explicit SIMD
/// kernel compiled in (`simd` = bit-exact AVX, `simd-fast` = FMA
/// AVX2/AVX-512 within a documented tolerance) that the running CPU
/// supports, otherwise the four-accumulator scalar kernel. See the
/// `engine` module docs for the per-tier equivalence guarantees.
#[inline]
pub fn sq_dist(a: &[f64], b: &[f64]) -> f64 {
    engine::sq_dist(a, b)
}

/// Fused accumulate: `acc[i] += x[i]` — k-means update without a
/// temporary.
#[inline]
pub fn add_assign(acc: &mut [f64], x: &[f64]) {
    debug_assert_eq!(acc.len(), x.len());
    for (a, v) in acc.iter_mut().zip(x) {
        *a += v;
    }
}

/// Index and squared distance of the row of `m` nearest to `x`.
/// Ties keep the first (lowest index). Panics on an empty matrix.
#[inline]
pub fn nearest_row(m: &Matrix, x: &[f64]) -> (usize, f64) {
    assert!(!m.is_empty(), "nearest_row on empty matrix");
    let mut best = 0usize;
    let mut best_d = f64::INFINITY;
    for (i, r) in m.iter_rows().enumerate() {
        let d = sq_dist(r, x);
        if d < best_d {
            best_d = d;
            best = i;
        }
    }
    (best, best_d)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_row_adopts_width_and_checks_it() {
        let mut m = Matrix::new();
        assert_eq!(m.n_cols(), 0);
        m.push_row(&[1.0, 2.0, 3.0]);
        assert_eq!((m.n_rows(), m.n_cols()), (1, 3));
        m.push_row(&[4.0, 5.0, 6.0]);
        assert_eq!(m.row(1), &[4.0, 5.0, 6.0]);
    }

    #[test]
    #[should_panic(expected = "inconsistent feature width")]
    fn push_row_width_mismatch_panics() {
        let mut m = Matrix::new();
        m.push_row(&[1.0, 2.0]);
        m.push_row(&[1.0]);
    }

    #[test]
    fn from_rows_roundtrips() {
        let rows = vec![vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]];
        let m = Matrix::from_rows(&rows);
        assert_eq!(m.n_rows(), 3);
        for (got, want) in m.iter_rows().zip(&rows) {
            assert_eq!(got, want.as_slice());
        }
        assert_eq!(m.as_slice(), &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn gather_selects_in_order() {
        let m = Matrix::from_flat(vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0], 2);
        let g = m.gather(&[2, 0]);
        assert_eq!(g.row(0), &[4.0, 5.0]);
        assert_eq!(g.row(1), &[0.0, 1.0]);
    }

    #[test]
    fn remove_first_rows_trims_fifo() {
        let mut m = Matrix::from_flat(vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0], 2);
        m.remove_first_rows(2);
        assert_eq!(m.n_rows(), 1);
        assert_eq!(m.row(0), &[4.0, 5.0]);
        m.remove_first_rows(5); // over-trim clamps
        assert!(m.is_empty());
    }

    #[test]
    fn sq_dist_matches_naive_all_lengths() {
        // exercise remainder handling at every length 0..=9
        for n in 0..=9usize {
            let a: Vec<f64> = (0..n).map(|i| i as f64 * 1.25).collect();
            let b: Vec<f64> = (0..n).map(|i| 10.0 - i as f64).collect();
            let naive: f64 =
                a.iter().zip(&b).map(|(x, y)| (x - y) * (x - y)).sum();
            assert!((sq_dist(&a, &b) - naive).abs() < 1e-9, "n={n}");
        }
    }

    #[test]
    fn nearest_row_finds_closest_first_on_tie() {
        let m = Matrix::from_flat(vec![0.0, 0.0, 5.0, 5.0, 0.0, 0.0], 2);
        let (i, d) = nearest_row(&m, &[0.1, 0.0]);
        assert_eq!(i, 0); // ties broken by first index
        assert!((d - 0.01).abs() < 1e-12);
    }

    #[test]
    fn add_assign_accumulates() {
        let mut acc = vec![1.0, 2.0];
        add_assign(&mut acc, &[0.5, 0.5]);
        assert_eq!(acc, vec![1.5, 2.5]);
    }

    #[test]
    fn extend_rows_appends() {
        let mut a = Matrix::new();
        let b = Matrix::from_flat(vec![1.0, 2.0, 3.0, 4.0], 2);
        a.extend_rows(&b);
        a.extend_rows(&b);
        assert_eq!(a.n_rows(), 4);
        assert_eq!(a.row(3), &[3.0, 4.0]);
    }
}
