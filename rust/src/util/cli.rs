//! Tiny command-line parser (no clap in the offline crate set).
//!
//! Supports `kermit <subcommand> [--flag] [--key value] [positional...]`.
//! Unknown flags are errors; `--help` is handled by the caller via
//! [`Args::help_requested`].

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    bools: Vec<String>,
}

#[derive(Debug)]
pub enum CliError {
    MissingValue(String),
    BadValue(String, String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::MissingValue(k) => {
                write!(f, "missing value for --{k}")
            }
            CliError::BadValue(k, v) => {
                write!(f, "invalid value for --{k}: {v}")
            }
        }
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse raw args (excluding argv[0]). `value_keys` lists flags that
    /// take a value; everything else starting with `--` is boolean.
    pub fn parse<I: IntoIterator<Item = String>>(
        raw: I,
        value_keys: &[&str],
    ) -> Result<Args, CliError> {
        let mut it = raw.into_iter().peekable();
        let mut out = Args {
            subcommand: None,
            positional: Vec::new(),
            flags: BTreeMap::new(),
            bools: Vec::new(),
        };
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                // --key=value form
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if value_keys.contains(&name) {
                    let v = it
                        .next()
                        .ok_or_else(|| CliError::MissingValue(name.into()))?;
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.bools.push(name.to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env(value_keys: &[&str]) -> Result<Args, CliError> {
        Args::parse(std::env::args().skip(1), value_keys)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.bools.iter().any(|b| b == name)
    }

    pub fn help_requested(&self) -> bool {
        self.flag("help") || self.subcommand.as_deref() == Some("help")
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| CliError::BadValue(name.into(), s.into())),
        }
    }

    pub fn get_usize(
        &self,
        name: &str,
        default: usize,
    ) -> Result<usize, CliError> {
        Ok(self.get_u64(name, default as u64)? as usize)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| CliError::BadValue(name.into(), s.into())),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str], keys: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()), keys).unwrap()
    }

    #[test]
    fn parses_subcommand_flags_positional() {
        let a = args(
            &["run", "--seed", "42", "--verbose", "trace.json"],
            &["seed"],
        );
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.get_u64("seed", 0).unwrap(), 42);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["trace.json"]);
    }

    #[test]
    fn key_equals_value() {
        let a = args(&["bench", "--eps=0.75"], &[]);
        assert_eq!(a.get_f64("eps", 0.0).unwrap(), 0.75);
    }

    #[test]
    fn missing_value_is_error() {
        let e = Args::parse(
            ["run".to_string(), "--seed".to_string()],
            &["seed"],
        );
        assert!(e.is_err());
    }

    #[test]
    fn bad_value_is_error() {
        let a = args(&["run", "--seed", "abc"], &["seed"]);
        assert!(a.get_u64("seed", 0).is_err());
    }

    #[test]
    fn defaults() {
        let a = args(&["run"], &[]);
        assert_eq!(a.get_or("out", "/tmp/x"), "/tmp/x");
        assert_eq!(a.get_f64("eps", 1.5).unwrap(), 1.5);
        assert!(!a.flag("verbose"));
    }
}
