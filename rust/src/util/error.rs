//! Crate-local error type (the offline crate set has no anyhow /
//! thiserror): a message-carrying error with `From` impls for the
//! error types that cross module boundaries, so `?` composes through
//! the CLI, persistence, and runtime layers without external crates.
//!
//! Errors carry an [`ErrorKind`] so callers can branch on the failure
//! class (an I/O failure on a bench JSON write degrades the run; a
//! persistence-envelope failure triggers generation fallback) without
//! string-matching messages.

use std::fmt;

/// Coarse failure class. `Display` stays message-only so existing
/// call sites and tests keep their output; the kind is for branching.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorKind {
    /// Filesystem / OS I/O failure (full disk, missing path, EPERM).
    Io,
    /// Durable-knowledge-plane envelope failure: bad magic, checksum
    /// mismatch, unsupported version, undecodable payload.
    Persist,
    /// Text / structure parsing failure (JSON, CLI).
    Parse,
    /// Anything else.
    Other,
}

/// A boxed-free error: a message plus a coarse [`ErrorKind`].
/// Construct with [`Error::msg`] / [`Error::io`] / [`Error::persist`]
/// or via the `From` impls.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Error {
    kind: ErrorKind,
    msg: String,
}

impl Error {
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { kind: ErrorKind::Other, msg: m.to_string() }
    }

    pub fn io(m: impl fmt::Display) -> Error {
        Error { kind: ErrorKind::Io, msg: m.to_string() }
    }

    pub fn persist(m: impl fmt::Display) -> Error {
        Error { kind: ErrorKind::Persist, msg: m.to_string() }
    }

    pub fn parse(m: impl fmt::Display) -> Error {
        Error { kind: ErrorKind::Parse, msg: m.to_string() }
    }

    pub fn kind(&self) -> ErrorKind {
        self.kind
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(s: String) -> Error {
        Error { kind: ErrorKind::Other, msg: s }
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Error {
        Error { kind: ErrorKind::Other, msg: s.to_string() }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::io(e)
    }
}

impl From<crate::util::json::JsonError> for Error {
    fn from(e: crate::util::json::JsonError) -> Error {
        Error::parse(e)
    }
}

impl From<crate::util::cli::CliError> for Error {
    fn from(e: crate::util::cli::CliError) -> Error {
        Error::parse(e)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn displays_message() {
        let e = Error::msg(format!("bad thing {}", 7));
        assert_eq!(e.to_string(), "bad thing 7");
        assert_eq!(e.kind(), ErrorKind::Other);
    }

    #[test]
    fn question_mark_composes_io() {
        fn inner() -> Result<()> {
            let _ = std::fs::read_to_string("/definitely/not/a/path/xyz")?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(e.kind(), ErrorKind::Io);
    }

    #[test]
    fn kinds_route_through_from_impls() {
        let p: Error = crate::util::json::Json::parse("{").unwrap_err().into();
        assert_eq!(p.kind(), ErrorKind::Parse);
        let s: Error = "plain".into();
        assert_eq!(s.kind(), ErrorKind::Other);
        assert_eq!(Error::persist("torn").kind(), ErrorKind::Persist);
        assert_eq!(Error::io("disk full").kind(), ErrorKind::Io);
    }
}
