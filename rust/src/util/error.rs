//! Crate-local error type (the offline crate set has no anyhow /
//! thiserror): a message-carrying error with `From` impls for the
//! error types that cross module boundaries, so `?` composes through
//! the CLI, persistence, and runtime layers without external crates.

use std::fmt;

/// A boxed-free, message-only error. Construct with [`Error::msg`] or
/// via the `From` impls.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(s: String) -> Error {
        Error { msg: s }
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Error {
        Error { msg: s.to_string() }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(e)
    }
}

impl From<crate::util::json::JsonError> for Error {
    fn from(e: crate::util::json::JsonError) -> Error {
        Error::msg(e)
    }
}

impl From<crate::util::cli::CliError> for Error {
    fn from(e: crate::util::cli::CliError) -> Error {
        Error::msg(e)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn displays_message() {
        let e = Error::msg(format!("bad thing {}", 7));
        assert_eq!(e.to_string(), "bad thing 7");
    }

    #[test]
    fn question_mark_composes_io() {
        fn inner() -> Result<()> {
            let _ = std::fs::read_to_string("/definitely/not/a/path/xyz")?;
            Ok(())
        }
        assert!(inner().is_err());
    }
}
