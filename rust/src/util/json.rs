//! Minimal JSON codec (the offline crate set has no serde).
//!
//! Used for: the artifact manifest written by `python/compile/aot.py`,
//! WorkloadDB persistence, knowledge-base zones, and config files.
//! Supports the full JSON grammar minus exotic number forms; numbers are
//! f64 (adequate for all KERMIT payloads, which the python side also
//! emits as doubles / small ints).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Object keys are kept sorted (BTreeMap) so encoded
/// output is deterministic — important for content-hash comparisons in
/// tests.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub enum JsonError {
    Eof(usize),
    Unexpected(char, usize),
    BadNumber(usize),
    BadEscape(char, usize),
    BadUnicode(usize),
    Trailing(usize),
    Type(&'static str, &'static str),
    MissingKey(String),
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JsonError::Eof(p) => {
                write!(f, "unexpected end of input at byte {p}")
            }
            JsonError::Unexpected(c, p) => {
                write!(f, "unexpected character '{c}' at byte {p}")
            }
            JsonError::BadNumber(p) => write!(f, "invalid number at byte {p}"),
            JsonError::BadEscape(c, p) => {
                write!(f, "invalid escape '\\{c}' at byte {p}")
            }
            JsonError::BadUnicode(p) => {
                write!(f, "invalid unicode escape at byte {p}")
            }
            JsonError::Trailing(p) => {
                write!(f, "trailing garbage at byte {p}")
            }
            JsonError::Type(want, got) => {
                write!(f, "expected {want}, found {got}")
            }
            JsonError::MissingKey(k) => write!(f, "missing key '{k}'"),
        }
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- constructors -------------------------------------------------
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, value: Json) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), value);
        } else {
            panic!("set() on non-object");
        }
        self
    }

    pub fn from_f64_slice(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    // ---- typed accessors ----------------------------------------------
    fn kind(&self) -> &'static str {
        match self {
            Json::Null => "null",
            Json::Bool(_) => "bool",
            Json::Num(_) => "number",
            Json::Str(_) => "string",
            Json::Arr(_) => "array",
            Json::Obj(_) => "object",
        }
    }

    pub fn as_f64(&self) -> Result<f64, JsonError> {
        match self {
            Json::Num(x) => Ok(*x),
            other => Err(JsonError::Type("number", other.kind())),
        }
    }

    pub fn as_usize(&self) -> Result<usize, JsonError> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_i64(&self) -> Result<i64, JsonError> {
        Ok(self.as_f64()? as i64)
    }

    pub fn as_bool(&self) -> Result<bool, JsonError> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => Err(JsonError::Type("bool", other.kind())),
        }
    }

    pub fn as_str(&self) -> Result<&str, JsonError> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(JsonError::Type("string", other.kind())),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json], JsonError> {
        match self {
            Json::Arr(v) => Ok(v),
            other => Err(JsonError::Type("array", other.kind())),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>, JsonError> {
        match self {
            Json::Obj(m) => Ok(m),
            other => Err(JsonError::Type("object", other.kind())),
        }
    }

    /// Object field lookup; errors with the key name for diagnostics.
    pub fn get(&self, key: &str) -> Result<&Json, JsonError> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| JsonError::MissingKey(key.to_string()))
    }

    pub fn get_opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn f64s(&self) -> Result<Vec<f64>, JsonError> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    // ---- encoding -------------------------------------------------------
    pub fn encode(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Pretty-printed with 2-space indent (for human-inspected DB files).
    pub fn encode_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(*x, out),
            Json::Str(s) => write_str(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&"  ".repeat(indent + 1));
                    x.write_pretty(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&"  ".repeat(indent + 1));
                    write_str(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
                out.push('}');
            }
            other => other.write(out),
        }
    }

    // ---- decoding -------------------------------------------------------
    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: input.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(JsonError::Trailing(p.i));
        }
        Ok(v)
    }
}

fn write_num(x: f64, out: &mut String) {
    if x.is_finite() {
        if x == x.trunc() && x.abs() < 1e15 {
            // integral values without the trailing ".0" (python-compatible)
            out.push_str(&format!("{}", x as i64));
        } else {
            out.push_str(&format!("{x}"));
        }
    } else {
        // JSON has no inf/nan; encode as null like python's json with
        // allow_nan=False would reject — we choose null + caller beware.
        out.push_str("null");
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8, JsonError> {
        self.b.get(self.i).copied().ok_or(JsonError::Eof(self.i))
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        let got = self.peek()?;
        if got != c {
            return Err(JsonError::Unexpected(got as char, self.i));
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(JsonError::Unexpected(self.peek()? as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek()? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(JsonError::Unexpected(c as char, self.i)),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                c => return Err(JsonError::Unexpected(c as char, self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            out.insert(key, self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                c => return Err(JsonError::Unexpected(c as char, self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            // surrogate pair handling
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                self.expect(b'\\')?;
                                self.expect(b'u')?;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err(JsonError::BadUnicode(self.i));
                                }
                                let c = 0x10000
                                    + ((cp - 0xD800) << 10)
                                    + (lo - 0xDC00);
                                char::from_u32(c)
                                    .ok_or(JsonError::BadUnicode(self.i))?
                            } else {
                                char::from_u32(cp)
                                    .ok_or(JsonError::BadUnicode(self.i))?
                            };
                            out.push(ch);
                        }
                        other => {
                            return Err(JsonError::BadEscape(
                                other as char,
                                self.i,
                            ))
                        }
                    }
                }
                c if c < 0x80 => out.push(c as char),
                _ => {
                    // multi-byte utf-8: re-decode from the byte slice
                    let start = self.i - 1;
                    let s = std::str::from_utf8(&self.b[start..])
                        .map_err(|_| JsonError::BadUnicode(start))?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.i = start + ch.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.i + 4 > self.b.len() {
            return Err(JsonError::Eof(self.i));
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| JsonError::BadUnicode(self.i))?;
        let v = u32::from_str_radix(s, 16)
            .map_err(|_| JsonError::BadUnicode(self.i))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| JsonError::BadNumber(start))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError::BadNumber(start))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.encode())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1.5", "\"hi\""] {
            let v = Json::parse(src).unwrap();
            assert_eq!(Json::parse(&v.encode()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(
            r#"{"a": [1, 2, {"b": null}], "c": "x\ny", "d": 1e3}"#,
        )
        .unwrap();
        assert_eq!(v.get("d").unwrap().as_f64().unwrap(), 1000.0);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
        // and raw multi-byte round trips
        let w = Json::Str("héllo 😀".into());
        assert_eq!(Json::parse(&w.encode()).unwrap(), w);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn integral_numbers_without_point() {
        assert_eq!(Json::Num(5.0).encode(), "5");
        assert_eq!(Json::Num(5.25).encode(), "5.25");
    }

    #[test]
    fn deterministic_object_order() {
        let mut a = Json::obj();
        a.set("z", Json::Num(1.0)).set("a", Json::Num(2.0));
        assert_eq!(a.encode(), r#"{"a":2,"z":1}"#);
    }

    #[test]
    fn pretty_parses_back() {
        let mut a = Json::obj();
        a.set("xs", Json::from_f64_slice(&[1.0, 2.0]))
            .set("name", Json::Str("w".into()));
        let p = a.encode_pretty();
        assert_eq!(Json::parse(&p).unwrap(), a);
    }

    #[test]
    fn parses_python_manifest_style() {
        let src = r#"{
  "artifacts": {
    "lstm_fwd": {"file": "lstm_fwd.hlo.txt", "inputs": [{"dtype": "float32", "shape": [32, 256]}]}
  },
  "constants": {"num_features": 16}
}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(
            v.get("constants").unwrap().get("num_features").unwrap()
                .as_usize().unwrap(),
            16
        );
    }
}
