//! Deterministic RNG for seeded experiments.
//!
//! The offline crate set has no `rand`; this is a small, well-tested
//! SplitMix64 + xoshiro256** stack. Every stochastic component in KERMIT
//! (workload generator, forest bootstrap, k-means init, …) takes one of
//! these explicitly so benches are reproducible run-to-run.

/// xoshiro256** seeded via SplitMix64. Passes BigCrush per the authors;
/// plenty for simulation work.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed the generator. Any u64 is fine, including 0.
    pub fn new(seed: u64) -> Self {
        // SplitMix64 to spread the seed across the state.
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent child stream (for per-component seeding).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA076_1D64_78BD_642F))
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [0, n). Uses Lemire's rejection-free-ish method
    /// with a widening multiply; bias is negligible for n << 2^64 but we
    /// reject to be exact.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let threshold = n.wrapping_neg() % n;
        loop {
            let r = self.next_u64();
            let (hi, lo) = {
                let wide = (r as u128) * (n as u128);
                ((wide >> 64) as u64, wide as u64)
            };
            if lo >= threshold {
                return hi;
            }
        }
    }

    /// Uniform integer in [lo, hi).
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Standard normal via Box-Muller (cached spare not kept: simplicity
    /// beats the extra multiply here).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-12 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with explicit mean / std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a uniformly random element.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from 0..n (k <= n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.range_usize(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            let v = r.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(17);
        let s = r.sample_indices(20, 10);
        assert_eq!(s.len(), 10);
        let mut d = s.clone();
        d.sort();
        d.dedup();
        assert_eq!(d.len(), 10);
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
