//! Foundation utilities built from scratch (the offline crate set has no
//! serde / clap / rand / anyhow): deterministic RNG, JSON codec, CLI
//! parsing, and the crate-local error type.

pub mod cli;
pub mod error;
pub mod json;
pub mod rng;
