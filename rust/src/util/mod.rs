//! Foundation utilities built from scratch (the offline crate set has no
//! serde / clap / rand): deterministic RNG, JSON codec, CLI parsing.

pub mod cli;
pub mod json;
pub mod rng;
