//! WorkloadPredictor: forecasts future workload labels from the recent
//! label sequence (paper §7.2: "based on an LSTM neural network").
//!
//! Implementations:
//! * [`MarkovPredictor`] — first-order transition-count model: the
//!   cheap native baseline.
//! * [`LastValuePredictor`] — naive persistence baseline.
//! * `runtime::nn::LstmPredictor` — the paper's LSTM, executed through
//!   the AOT-compiled PJRT artifact (see `runtime::nn`); it implements
//!   this same trait so the pipeline can swap them.
//!
//! The t+5 / t+10 horizons required by the context object come from
//! rolling the 1-step prediction forward.

/// Common interface for label-sequence predictors.
pub trait LabelPredictor {
    /// Predict the label at `horizon` windows after the end of `history`
    /// (horizon >= 1). Implementations may return None when they have
    /// insufficient signal.
    fn predict(&self, history: &[u32], horizon: usize) -> Option<u32>;
}

/// Persistence baseline: tomorrow looks like today.
pub struct LastValuePredictor;

impl LabelPredictor for LastValuePredictor {
    fn predict(&self, history: &[u32], _horizon: usize) -> Option<u32> {
        history.last().copied()
    }
}

/// First-order Markov chain over labels with add-one smoothing, fitted
/// on a label sequence. Rolls forward for multi-step horizons.
#[derive(Debug, Default)]
pub struct MarkovPredictor {
    counts: std::collections::BTreeMap<(u32, u32), usize>,
    states: std::collections::BTreeSet<u32>,
}

impl MarkovPredictor {
    pub fn new() -> MarkovPredictor {
        MarkovPredictor::default()
    }

    pub fn fit(seq: &[u32]) -> MarkovPredictor {
        let mut m = MarkovPredictor::new();
        m.update(seq);
        m
    }

    /// Incremental training on an additional observed sequence.
    pub fn update(&mut self, seq: &[u32]) {
        for pair in seq.windows(2) {
            *self.counts.entry((pair[0], pair[1])).or_insert(0) += 1;
            self.states.insert(pair[0]);
            self.states.insert(pair[1]);
        }
        if let Some(&last) = seq.last() {
            self.states.insert(last);
        }
    }

    fn next_of(&self, s: u32) -> Option<u32> {
        self.states
            .iter()
            .map(|&t| (t, *self.counts.get(&(s, t)).unwrap_or(&0)))
            .max_by_key(|&(_, n)| n)
            .filter(|&(_, n)| n > 0)
            .map(|(t, _)| t)
    }
}

impl LabelPredictor for MarkovPredictor {
    fn predict(&self, history: &[u32], horizon: usize) -> Option<u32> {
        let mut cur = *history.last()?;
        for _ in 0..horizon.max(1) {
            match self.next_of(cur) {
                Some(n) => cur = n,
                None => return Some(cur), // unseen state: persist
            }
        }
        Some(cur)
    }
}

/// Evaluation helper: walk a label sequence, predicting each position
/// from its prefix at the given horizon; returns accuracy. Used by the
/// predictor bench for every implementation.
pub fn sequence_accuracy(
    predictor: &dyn LabelPredictor,
    seq: &[u32],
    horizon: usize,
    warmup: usize,
) -> f64 {
    let mut hits = 0usize;
    let mut total = 0usize;
    for t in warmup..seq.len().saturating_sub(horizon) {
        if let Some(p) = predictor.predict(&seq[..=t], horizon) {
            total += 1;
            if p == seq[t + horizon] {
                hits += 1;
            }
        }
    }
    if total == 0 {
        0.0
    } else {
        hits as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markov_learns_cycle() {
        let seq: Vec<u32> =
            (0..60).map(|i| [1u32, 2, 3][i % 3]).collect();
        let m = MarkovPredictor::fit(&seq);
        assert_eq!(m.predict(&[1], 1), Some(2));
        assert_eq!(m.predict(&[2], 1), Some(3));
        assert_eq!(m.predict(&[3], 1), Some(1));
        // multi-step rolls forward
        assert_eq!(m.predict(&[1], 3), Some(1));
        assert_eq!(m.predict(&[1], 2), Some(3));
    }

    #[test]
    fn markov_perfect_on_deterministic_sequence() {
        let seq: Vec<u32> = (0..90).map(|i| [5u32, 7, 9][i % 3]).collect();
        let m = MarkovPredictor::fit(&seq);
        assert_eq!(sequence_accuracy(&m, &seq, 1, 3), 1.0);
        assert_eq!(sequence_accuracy(&m, &seq, 5, 3), 1.0);
    }

    #[test]
    fn last_value_fails_on_alternation() {
        let seq: Vec<u32> = (0..40).map(|i| (i % 2) as u32).collect();
        let lv = LastValuePredictor;
        assert_eq!(sequence_accuracy(&lv, &seq, 1, 2), 0.0);
        let m = MarkovPredictor::fit(&seq);
        assert_eq!(sequence_accuracy(&m, &seq, 1, 2), 1.0);
    }

    #[test]
    fn unseen_state_persists() {
        let m = MarkovPredictor::fit(&[1, 2, 1, 2]);
        assert_eq!(m.predict(&[99], 1), Some(99));
    }

    #[test]
    fn empty_history_none() {
        let m = MarkovPredictor::fit(&[1, 2]);
        assert_eq!(m.predict(&[], 1), None);
        assert_eq!(LastValuePredictor.predict(&[], 1), None);
    }

    #[test]
    fn incremental_update_extends_model() {
        let mut m = MarkovPredictor::fit(&[1, 2]);
        assert_eq!(m.predict(&[2], 1), Some(2)); // unseen from 2: persist
        m.update(&[2, 3, 2, 3]);
        assert_eq!(m.predict(&[2], 1), Some(3));
    }
}
