//! The KERMIT plug-in — Algorithm 1 (paper §6.4).
//!
//! Called when the resource manager responds to a resource request, it:
//! 1. reads the latest workload context `C_t` and checks it is in sync
//!    (falls back to the default configuration on staleness);
//! 2. if the current label is UNKNOWN, uses the default configuration
//!    until off-line discovery catches up;
//! 3. if WorkloadDB holds an optimal configuration for the label,
//!    reuses it — the cache hit that makes recurring workloads fast;
//! 4. if the workload is drifting, advances a *local* Explorer search
//!    seeded at the last good configuration;
//! 5. otherwise advances a *global* Explorer search.
//!
//! Searches are [`SearchSession`]s: each probe is one real execution of
//! the workload, so tuning overhead is paid in the job stream exactly as
//! on a live cluster.

use crate::explorer::session::{SearchSession, SessionStep};
use crate::explorer::ExplorerConfig;
use crate::knowledge::SharedWorkloadDb;
use crate::online::context::{ContextStream, UNKNOWN};
use crate::simcluster::config_space::{default_config_index, ConfigIndex};
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

/// Why the plug-in chose the configuration it chose (telemetry + tests).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChoiceKind {
    /// Context stale or label unknown: vendor default.
    Default,
    /// Optimal config found in WorkloadDB (the recurring-workload win).
    CacheHit,
    /// Probe of an ongoing global search.
    GlobalProbe,
    /// Probe of an ongoing local (drift) search.
    LocalProbe,
}

/// Plug-in statistics (reported by benches, the CLI, and per tenant in
/// `MultiTenantReport::tenant_stats`).
#[derive(Debug, Clone, Default)]
pub struct PluginStats {
    pub requests: usize,
    pub defaults: usize,
    pub cache_hits: usize,
    pub global_probes: usize,
    pub local_probes: usize,
    pub searches_completed: usize,
    /// Searches abandoned because another plug-in sharing the knowledge
    /// plane persisted an optimum for the same label first (the
    /// cross-tenant search dedup — probes this tenant did NOT pay).
    pub searches_abandoned: usize,
    /// Searches written off without a trusted optimum: step cap or
    /// failed-measurement streak tripped, or every probe died.
    pub searches_failed: usize,
    /// Probe measurements that came back failed (job died / timed out).
    pub probes_failed: usize,
    /// Requests served the safe fallback because the label was inside a
    /// failure-backoff window.
    pub backoffs: usize,
}

impl PluginStats {
    /// Cache hits as a fraction of all requests (0 when idle) — the
    /// recurring-workload economics observable.
    pub fn cache_hit_ratio(&self) -> f64 {
        crate::obs::ratio(self.cache_hits as f64, self.requests as f64)
    }

    /// Probes actually paid (global + local).
    pub fn probes_paid(&self) -> usize {
        self.global_probes + self.local_probes
    }

    /// Count for one choice kind.
    pub fn count(&self, kind: ChoiceKind) -> usize {
        match kind {
            ChoiceKind::Default => self.defaults,
            ChoiceKind::CacheHit => self.cache_hits,
            ChoiceKind::GlobalProbe => self.global_probes,
            ChoiceKind::LocalProbe => self.local_probes,
        }
    }

    /// Bridge this tenant's plug-in counters into a telemetry registry
    /// under `kermit_plugin_*{tenant=...}`.
    pub fn export_metrics(&self, reg: &crate::obs::Registry, tenant: &str) {
        let labels = [("tenant", tenant)];
        let c = |name: &str, help: &str, v: usize| {
            reg.counter(name, help, &labels).set_total(v as u64);
        };
        c(
            "kermit_plugin_requests_total",
            "Resource requests the plug-in served.",
            self.requests,
        );
        c(
            "kermit_plugin_defaults_total",
            "Requests served the vendor-default configuration.",
            self.defaults,
        );
        c(
            "kermit_plugin_cache_hits_total",
            "Requests served a WorkloadDB optimum (cache hit).",
            self.cache_hits,
        );
        c(
            "kermit_plugin_global_probes_total",
            "Probes paid to global Explorer searches.",
            self.global_probes,
        );
        c(
            "kermit_plugin_local_probes_total",
            "Probes paid to local (drift) Explorer searches.",
            self.local_probes,
        );
        c(
            "kermit_plugin_searches_completed_total",
            "Search sessions that converged to an optimum.",
            self.searches_completed,
        );
        c(
            "kermit_plugin_searches_abandoned_total",
            "Searches abandoned to the cross-tenant dedup.",
            self.searches_abandoned,
        );
        c(
            "kermit_plugin_searches_failed_total",
            "Searches written off without a trusted optimum.",
            self.searches_failed,
        );
        c(
            "kermit_plugin_probes_failed_total",
            "Probe measurements that came back failed.",
            self.probes_failed,
        );
        c(
            "kermit_plugin_backoffs_total",
            "Requests served the safe fallback inside a backoff window.",
            self.backoffs,
        );
    }
}

/// How the plug-in degrades when probes keep dying (fault hardening).
/// Defaults are generous enough that healthy runs never hit them.
#[derive(Debug, Clone)]
pub struct ResiliencePolicy {
    /// Bound on total probes per search session (0 = uncapped; the
    /// Explorer's own budget then bounds the session).
    pub session_step_cap: usize,
    /// Consecutive failed measurements before a session abandons.
    pub max_failed_streak: usize,
    /// Requests to skip (serving the safe fallback) after a probe
    /// failure; doubles per consecutive failure up to `backoff_cap`.
    pub backoff_base: usize,
    pub backoff_cap: usize,
}

impl Default for ResiliencePolicy {
    fn default() -> Self {
        ResiliencePolicy {
            session_step_cap: 0,
            max_failed_streak: 6,
            backoff_base: 2,
            backoff_cap: 16,
        }
    }
}

enum SessionKind {
    Global,
    Local,
}

pub struct KermitPlugin {
    /// The shared knowledge plane (read-mostly: Algorithm 1 takes the
    /// read lock for cache lookups, the write lock only to persist a
    /// converged optimum — so N tenant plug-ins look up concurrently).
    pub db: SharedWorkloadDb,
    pub context: Arc<Mutex<ContextStream>>,
    pub explorer_config: ExplorerConfig,
    /// Maximum age (seconds) of the latest context before it is
    /// considered out-of-sync (Algorithm 1's error path).
    pub max_context_age: f64,
    pub resilience: ResiliencePolicy,
    default_config: ConfigIndex,
    sessions: BTreeMap<u32, (SessionKind, SearchSession)>,
    /// The label whose probe is outstanding, if any.
    outstanding: Option<u32>,
    /// Per-label remaining backoff window (requests to serve the safe
    /// fallback before probing the label again).
    backoff: BTreeMap<u32, usize>,
    /// Per-label consecutive probe-failure count (escalates backoff).
    fail_count: BTreeMap<u32, u32>,
    pub stats: PluginStats,
}

impl KermitPlugin {
    pub fn new(
        db: SharedWorkloadDb,
        context: Arc<Mutex<ContextStream>>,
    ) -> KermitPlugin {
        KermitPlugin {
            db,
            context,
            explorer_config: ExplorerConfig::default(),
            max_context_age: 120.0,
            resilience: ResiliencePolicy::default(),
            default_config: default_config_index(),
            sessions: BTreeMap::new(),
            outstanding: None,
            backoff: BTreeMap::new(),
            fail_count: BTreeMap::new(),
            stats: PluginStats::default(),
        }
    }

    /// The label Algorithm 1 would act on at `now`: the latest context
    /// when it is in sync (within `max_context_age`) and known, UNKNOWN
    /// otherwise. Exposed so callers that must correlate the decision
    /// with its later measurement (the tuning plane's completion edge)
    /// resolve the label exactly once.
    pub fn current_label(&self, now: f64) -> u32 {
        let ctx = self.context.lock().unwrap();
        match ctx.latest() {
            Some(c)
                if (now - c.time).abs() <= self.max_context_age
                    && c.is_known() =>
            {
                c.current_label
            }
            _ => UNKNOWN,
        }
    }

    /// Algorithm 1, for the workload labelled by the current context.
    /// `now` is the request time (for the staleness check).
    pub fn choose_config(&mut self, now: f64) -> (ConfigIndex, ChoiceKind) {
        let label = self.current_label(now);
        self.choose_config_for_label(label)
    }

    /// Algorithm 1 body once the label is known (the coordinator may
    /// resolve the label itself from the job's first windows).
    pub fn choose_config_for_label(
        &mut self,
        label: u32,
    ) -> (ConfigIndex, ChoiceKind) {
        self.stats.requests += 1;
        if label == UNKNOWN {
            self.stats.defaults += 1;
            return (self.default_config, ChoiceKind::Default);
        }
        // a probe is still unresolved (its job has neither completed
        // nor failed yet — possible only when a fault interleaved the
        // streams): never advance or create sessions on top of it,
        // serve the safe fallback until the plane resolves the probe
        if self.outstanding.is_some() && self.outstanding != Some(label) {
            return self.safe_fallback(label);
        }
        // a label inside its failure-backoff window is not probed:
        // repeated dying measurements must not burn the whole budget
        if let Some(rem) = self.backoff.get_mut(&label) {
            if *rem > 0 {
                *rem -= 1;
                self.stats.backoffs += 1;
                return self.safe_fallback(label);
            }
            self.backoff.remove(&label);
        }
        // an existing session for this label takes priority — unless a
        // *different* plug-in sharing the knowledge plane persisted an
        // optimum for it while our search was in flight (the optimal
        // flag can only have been set externally: our own convergence
        // removes the session before setting it). Abandoning the local
        // session is the cross-tenant search dedup: the remaining probe
        // budget is pure waste once a converged optimum exists.
        if self.sessions.contains_key(&label) {
            if self.outstanding != Some(label) {
                let stored = {
                    let db = self.db.read().unwrap();
                    db.get(label)
                        .filter(|e| e.optimal_config_found && !e.quarantined)
                        .and_then(|e| e.config)
                };
                if let Some(cfg) = stored {
                    self.sessions.remove(&label);
                    self.stats.searches_abandoned += 1;
                    self.stats.cache_hits += 1;
                    return (cfg, ChoiceKind::CacheHit);
                }
            }
            return self.advance_session(label);
        }
        let (known, optimal, drifting, stored) = {
            let db = self.db.read().unwrap();
            match db.get(label) {
                // a quarantined entry is known but its stored optimum
                // is untrusted: force a fresh global search — never
                // serve the poisoned config, never seed a local search
                // from it
                Some(e) if e.quarantined => (true, false, false, None),
                Some(e) => {
                    (true, e.optimal_config_found, e.is_drifting, e.config)
                }
                None => (false, false, false, None),
            }
        };
        if !known {
            // classified label that discovery hasn't persisted yet
            self.stats.defaults += 1;
            return (self.default_config, ChoiceKind::Default);
        }
        if optimal {
            self.stats.cache_hits += 1;
            return (stored.expect("optimal flag without config"), ChoiceKind::CacheHit);
        }
        // start the right kind of session
        let (kind, mut session) = match (drifting, stored) {
            (true, Some(start)) => (
                SessionKind::Local,
                SearchSession::local(self.explorer_config.clone(), start),
            ),
            _ => (
                SessionKind::Global,
                SearchSession::global(self.explorer_config.clone()),
            ),
        };
        if self.resilience.session_step_cap > 0 {
            session.set_step_cap(self.resilience.session_step_cap);
        }
        session.set_max_failed_streak(self.resilience.max_failed_streak);
        self.sessions.insert(label, (kind, session));
        self.advance_session(label)
    }

    /// Decision path for a tenant whose *ingest transport* is impaired
    /// (partitioned / wedged — see `stream::supervisor`): serve the
    /// stale-but-safe choice for the last-known label without opening
    /// sessions, advancing probes, or touching backoff state. Re-arming
    /// is the caller's job once the supervisor scores the tenant
    /// healthy again.
    pub fn degraded_choice(&mut self, label: u32) -> (ConfigIndex, ChoiceKind) {
        self.stats.requests += 1;
        if label == UNKNOWN {
            self.stats.defaults += 1;
            return (self.default_config, ChoiceKind::Default);
        }
        self.safe_fallback(label)
    }

    /// The degraded-mode choice: a stored, trusted optimum if one
    /// exists (e.g. a peer converged while this label is backing off),
    /// else the vendor default.
    fn safe_fallback(&mut self, label: u32) -> (ConfigIndex, ChoiceKind) {
        let stored = {
            let db = self.db.read().unwrap();
            db.get(label)
                .filter(|e| e.optimal_config_found && !e.quarantined)
                .and_then(|e| e.config)
        };
        self.stats.defaults += 1;
        (stored.unwrap_or(self.default_config), ChoiceKind::Default)
    }

    /// Escalate the per-label failure backoff window.
    fn note_failure(&mut self, label: u32) {
        let c = self.fail_count.entry(label).or_insert(0);
        *c += 1;
        let skip = self
            .resilience
            .backoff_base
            .saturating_mul(1usize << (*c - 1).min(8) as usize)
            .min(self.resilience.backoff_cap);
        if skip > 0 {
            self.backoff.insert(label, skip);
        }
    }

    fn advance_session(&mut self, label: u32) -> (ConfigIndex, ChoiceKind) {
        assert!(
            self.outstanding.is_none(),
            "previous probe not yet measured"
        );
        let (kind, session) = self.sessions.get_mut(&label).unwrap();
        match session.next() {
            SessionStep::Probe(c) => {
                let choice = match kind {
                    SessionKind::Global => {
                        self.stats.global_probes += 1;
                        ChoiceKind::GlobalProbe
                    }
                    SessionKind::Local => {
                        self.stats.local_probes += 1;
                        ChoiceKind::LocalProbe
                    }
                };
                self.outstanding = Some(label);
                (c, choice)
            }
            SessionStep::Done(r) if r.best_duration.is_finite() => {
                // search converged: persist and serve the optimum
                self.sessions.remove(&label);
                self.fail_count.remove(&label);
                self.stats.searches_completed += 1;
                self.stats.cache_hits += 1;
                self.db.write().unwrap().set_optimal_measured(
                    label,
                    r.best,
                    r.best_duration,
                );
                (r.best, ChoiceKind::CacheHit)
            }
            SessionStep::Done(_) | SessionStep::Abandoned(_) => {
                // the search died (every probe failed) or abandoned
                // itself (step cap / failure streak): nothing trusted
                // was learned — never persist a garbage optimum
                // cluster-wide, open a backoff window instead
                self.sessions.remove(&label);
                self.stats.searches_failed += 1;
                self.note_failure(label);
                self.stats.defaults += 1;
                (self.default_config, ChoiceKind::Default)
            }
        }
    }

    /// Feed back the measured duration of the last probe for `label`.
    /// No-op when no search is outstanding (cache hits / defaults). A
    /// non-finite duration counts as a failed probe and escalates the
    /// label's backoff.
    pub fn record_measurement(&mut self, label: u32, duration: f64) {
        if self.outstanding == Some(label) {
            if let Some((_, session)) = self.sessions.get_mut(&label) {
                session.report(duration);
            }
            self.outstanding = None;
            if duration.is_finite() {
                self.fail_count.remove(&label);
            } else {
                self.stats.probes_failed += 1;
                self.note_failure(label);
            }
        }
    }

    /// Write off the outstanding probe for `label`: its job died or the
    /// decision timed out, and no measurement will ever arrive. The
    /// session is fed a failure (driving its abandon guard) and the
    /// label backs off. The per-tenant decide path can then never wedge
    /// on a measurement that is not coming.
    pub fn fail_probe(&mut self, label: u32) {
        if self.outstanding == Some(label) {
            self.record_measurement(label, f64::INFINITY);
        }
    }

    /// True while a search for `label` is in progress.
    pub fn searching(&self, label: u32) -> bool {
        self.sessions.contains_key(&label)
    }

    /// The label whose probe measurement is still pending, if any.
    /// After a run fully drains, a `Some` here is a wedged session —
    /// the chaos lab's livelock observable.
    pub fn outstanding_label(&self) -> Option<u32> {
        self.outstanding
    }

    /// Number of search sessions currently open.
    pub fn open_sessions(&self) -> usize {
        self.sessions.len()
    }

    /// Is the label inside its failure-backoff window?
    pub fn in_backoff(&self, label: u32) -> bool {
        self.backoff.get(&label).map(|r| *r > 0).unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::knowledge::Characterization;
    use crate::online::context::WorkloadContext;
    use crate::simcluster::perfmodel::job_duration;

    fn setup() -> (SharedWorkloadDb, Arc<Mutex<ContextStream>>, u32) {
        let mut db = crate::knowledge::WorkloadDb::new();
        let rows: Vec<Vec<f64>> = vec![vec![1.0; 4], vec![1.1; 4]];
        let label = db.insert_new(
            Characterization::from_vec_rows(&rows),
            vec![1.05; 4],
            2,
            false,
        );
        (
            Arc::new(std::sync::RwLock::new(db)),
            Arc::new(Mutex::new(ContextStream::new(16))),
            label,
        )
    }

    fn publish(ctx: &Arc<Mutex<ContextStream>>, label: u32, t: f64) {
        ctx.lock().unwrap().publish(WorkloadContext {
            window_index: 0,
            time: t,
            current_label: label,
            pred_1: label,
            pred_5: label,
            pred_10: label,
        });
    }

    #[test]
    fn unknown_label_gets_default() {
        let (db, ctx, _) = setup();
        let mut p = KermitPlugin::new(db, ctx);
        let (c, kind) = p.choose_config_for_label(UNKNOWN);
        assert_eq!(kind, ChoiceKind::Default);
        assert_eq!(c, default_config_index());
    }

    #[test]
    fn stale_context_gets_default() {
        let (db, ctx, label) = setup();
        publish(&ctx, label, 0.0);
        let mut p = KermitPlugin::new(db, ctx);
        p.max_context_age = 10.0;
        let (_, kind) = p.choose_config(1000.0); // far in the future
        assert_eq!(kind, ChoiceKind::Default);
    }

    #[test]
    fn full_search_lifecycle_converges_to_cache_hits() {
        let (db, ctx, label) = setup();
        publish(&ctx, label, 0.0);
        let mut p = KermitPlugin::new(db.clone(), ctx);
        // drive the search: every request is a probe until convergence
        let mut probes = 0;
        loop {
            let (c, kind) = p.choose_config_for_label(label);
            match kind {
                ChoiceKind::GlobalProbe => {
                    probes += 1;
                    assert!(probes < 1000, "search never converged");
                    p.record_measurement(label, job_duration(2, &c.to_config()));
                }
                ChoiceKind::CacheHit => break,
                other => panic!("unexpected choice {other:?}"),
            }
        }
        assert!(probes > 5);
        assert!(db.read().unwrap().get(label).unwrap().optimal_config_found);
        // subsequent requests are pure cache hits with the same config
        let (c1, k1) = p.choose_config_for_label(label);
        let (c2, k2) = p.choose_config_for_label(label);
        assert_eq!((k1, k2), (ChoiceKind::CacheHit, ChoiceKind::CacheHit));
        assert_eq!(c1, c2);
        assert_eq!(p.stats.searches_completed, 1);
    }

    #[test]
    fn drift_triggers_local_search_from_stored_config() {
        let (db, ctx, label) = setup();
        // converge a global search first
        let mut p = KermitPlugin::new(db.clone(), ctx);
        loop {
            let (c, kind) = p.choose_config_for_label(label);
            match kind {
                ChoiceKind::GlobalProbe => {
                    p.record_measurement(label, job_duration(3, &c.to_config()))
                }
                ChoiceKind::CacheHit => break,
                other => panic!("unexpected {other:?}"),
            }
        }
        // now mark drift (keeps config, clears optimal flag)
        {
            let mut dbl = db.write().unwrap();
            let rows: Vec<Vec<f64>> = vec![vec![2.0; 4], vec![2.1; 4]];
            dbl.mark_drifting(
                label,
                Characterization::from_vec_rows(&rows),
                vec![2.05; 4],
                2,
            );
        }
        let (_, kind) = p.choose_config_for_label(label);
        assert_eq!(kind, ChoiceKind::LocalProbe);
        assert!(p.stats.local_probes >= 1);
    }

    #[test]
    fn label_not_in_db_gets_default() {
        let (db, ctx, _) = setup();
        let mut p = KermitPlugin::new(db, ctx);
        let (_, kind) = p.choose_config_for_label(999);
        assert_eq!(kind, ChoiceKind::Default);
    }

    #[test]
    fn stats_helpers_aggregate() {
        let mut s = PluginStats::default();
        assert_eq!(s.cache_hit_ratio(), 0.0);
        s.requests = 8;
        s.cache_hits = 2;
        s.global_probes = 5;
        s.local_probes = 1;
        assert!((s.cache_hit_ratio() - 0.25).abs() < 1e-12);
        assert_eq!(s.probes_paid(), 6);
        assert_eq!(s.count(ChoiceKind::CacheHit), 2);
        assert_eq!(s.count(ChoiceKind::GlobalProbe), 5);
    }

    #[test]
    fn failed_probes_open_backoff_then_recovery_converges() {
        let (db, ctx, label) = setup();
        let mut p = KermitPlugin::new(db.clone(), ctx);
        p.resilience.max_failed_streak = 2;
        p.resilience.backoff_base = 2;
        p.resilience.backoff_cap = 4;

        // first probe dies: the label must enter a backoff window and
        // the next requests get the safe fallback, not a probe
        let (_, k) = p.choose_config_for_label(label);
        assert_eq!(k, ChoiceKind::GlobalProbe);
        p.record_measurement(label, f64::INFINITY);
        assert_eq!(p.stats.probes_failed, 1);
        assert!(p.in_backoff(label));
        for _ in 0..2 {
            let (_, k) = p.choose_config_for_label(label);
            assert_eq!(k, ChoiceKind::Default);
        }
        assert_eq!(p.stats.backoffs, 2);
        assert!(!p.in_backoff(label), "window must drain");

        // window drained: probing resumes, and finite measurements
        // drive the (still open) session to a normal convergence
        let mut guard = 0;
        loop {
            let (c, k) = p.choose_config_for_label(label);
            match k {
                ChoiceKind::GlobalProbe => {
                    guard += 1;
                    assert!(guard < 1000, "never converged");
                    p.record_measurement(
                        label,
                        job_duration(2, &c.to_config()),
                    );
                }
                ChoiceKind::CacheHit => break,
                other => panic!("unexpected {other:?}"),
            }
        }
        assert_eq!(p.stats.searches_completed, 1);
        assert_eq!(p.outstanding_label(), None);
        assert_eq!(p.open_sessions(), 0);
        let e = db.read().unwrap().get(label).cloned().unwrap();
        assert!(e.optimal_config_found);
        assert!(e.best_duration.is_some(), "measured optimum recorded");
    }

    #[test]
    fn abandoned_session_never_persists_an_optimum() {
        let (db, ctx, label) = setup();
        let mut p = KermitPlugin::new(db.clone(), ctx);
        p.resilience.max_failed_streak = 2;
        // every probe dies until the session abandons; the request that
        // observes the abandonment degrades to the default
        let mut requests = 0;
        loop {
            requests += 1;
            assert!(requests < 100, "abandon guard never tripped");
            let (_, k) = p.choose_config_for_label(label);
            match k {
                ChoiceKind::GlobalProbe => {
                    p.record_measurement(label, f64::INFINITY)
                }
                ChoiceKind::Default => {
                    if p.stats.searches_failed > 0 {
                        break;
                    }
                    // backoff-window fallback: keep going
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        assert_eq!(p.stats.searches_failed, 1);
        assert_eq!(p.open_sessions(), 0, "failed session must close");
        assert_eq!(p.outstanding_label(), None, "no wedged probe");
        assert!(
            !db.read().unwrap().get(label).unwrap().optimal_config_found,
            "a failed search persisted a garbage optimum"
        );
    }

    #[test]
    fn quarantined_entry_forces_fresh_global_search() {
        let (db, ctx, label) = setup();
        let mut p = KermitPlugin::new(db.clone(), ctx);
        // converge once, then poison-quarantine the label
        loop {
            let (c, k) = p.choose_config_for_label(label);
            match k {
                ChoiceKind::GlobalProbe => {
                    p.record_measurement(label, job_duration(2, &c.to_config()))
                }
                ChoiceKind::CacheHit => break,
                other => panic!("unexpected {other:?}"),
            }
        }
        db.write().unwrap().quarantine(label);
        // the poisoned optimum is never served — a fresh global search
        // starts instead, and its convergence lifts the quarantine
        let (c0, k0) = p.choose_config_for_label(label);
        assert_eq!(k0, ChoiceKind::GlobalProbe, "served a poisoned optimum");
        p.record_measurement(label, job_duration(2, &c0.to_config()));
        let mut guard = 0;
        loop {
            let (c, k) = p.choose_config_for_label(label);
            match k {
                ChoiceKind::GlobalProbe => {
                    guard += 1;
                    assert!(guard < 2000, "re-search never converged");
                    p.record_measurement(
                        label,
                        job_duration(2, &c.to_config()),
                    );
                }
                ChoiceKind::CacheHit => break,
                other => panic!("unexpected {other:?}"),
            }
        }
        let e = db.read().unwrap().get(label).cloned().unwrap();
        assert!(!e.quarantined && e.optimal_config_found);
    }

    #[test]
    fn concurrent_search_abandoned_once_peer_stores_optimum() {
        // two plug-ins (two tenants) share the knowledge plane and both
        // start a global search for the same label; A converges first
        // and persists the optimum; B's next request must abandon its
        // own session and serve A's optimum — zero further probes paid
        let (db, ctx_a, label) = setup();
        let ctx_b = Arc::new(Mutex::new(ContextStream::new(16)));
        let mut a = KermitPlugin::new(db.clone(), ctx_a);
        let mut b = KermitPlugin::new(db.clone(), ctx_b);

        // B starts searching (one probe in flight, then measured)
        let (cb, kb) = b.choose_config_for_label(label);
        assert_eq!(kb, ChoiceKind::GlobalProbe);
        b.record_measurement(label, job_duration(2, &cb.to_config()));
        assert!(b.searching(label));

        // A searches to convergence
        let stored = loop {
            let (c, kind) = a.choose_config_for_label(label);
            match kind {
                ChoiceKind::GlobalProbe => {
                    a.record_measurement(label, job_duration(2, &c.to_config()))
                }
                ChoiceKind::CacheHit => break c,
                other => panic!("unexpected {other:?}"),
            }
        };
        assert!(db.read().unwrap().get(label).unwrap().optimal_config_found);

        // B's next request: abandon, cache-hit A's config
        let probes_before = b.stats.probes_paid();
        let (cb2, kb2) = b.choose_config_for_label(label);
        assert_eq!(kb2, ChoiceKind::CacheHit);
        assert_eq!(cb2, stored);
        assert!(!b.searching(label), "B's session not abandoned");
        assert_eq!(b.stats.searches_abandoned, 1);
        assert_eq!(b.stats.probes_paid(), probes_before);
        // and B keeps cache-hitting (no new session)
        let (_, kb3) = b.choose_config_for_label(label);
        assert_eq!(kb3, ChoiceKind::CacheHit);
        assert_eq!(b.stats.searches_abandoned, 1);
    }
}
