//! The on-line classification pipeline: ChangeDetector →
//! WorkloadClassifier → WorkloadPredictor → context stream (Figure 3's
//! "Workload Classification, Prediction and Optimization" sub-system).
//!
//! One `observe` call per closed observation window. Transition windows
//! (flagged by the ChangeDetector) are not classified — they publish the
//! previous steady label as UNKNOWN-safe context exactly like the paper:
//! classification addresses steady states, transitions are a separate
//! class family handled by the TransitionClassifier off-line.

use super::change_detector::{ChangeDetector, ChangeDetectorConfig};
use super::classifier::{UnknownClassifier, WindowClassifier};
use super::context::{ContextStream, WorkloadContext, UNKNOWN};
use super::predictor::{LabelPredictor, MarkovPredictor};
use crate::features::{zero_analytic, AnalyticVec, ObservationWindow, ANALYTIC_WIDTH};
use crate::obs::ObserveMetrics;
use std::sync::{Arc, Mutex};

/// The trait objects are `+ Send` so a whole pipeline can move to (or
/// be borrowed by) a stream-router worker thread: the multi-tenant
/// `stream` layer fans pipeline shards out over the `linalg::Engine`
/// pool. Every native classifier/predictor is plain owned data, so the
/// bound costs nothing.
pub struct OnlinePipeline {
    detector: ChangeDetector,
    classifier: Box<dyn WindowClassifier + Send>,
    /// TransitionClassifier (random forest over rate-of-change features,
    /// trained off-line): names the transition *type* while a change is
    /// in progress (Figure 3's on-line pipeline).
    transition_classifier: Option<Box<dyn WindowClassifier + Send>>,
    predictor: Box<dyn LabelPredictor + Send>,
    /// Steady-state label history (feeds the predictor).
    history: Vec<u32>,
    /// Markov model kept warm online regardless of the active predictor
    /// (it is also the fallback when the LSTM has no signal).
    markov: MarkovPredictor,
    /// Fixed-width scratch buffers: the analytic widths are static, so
    /// the steady-state `observe` path never allocates (§Perf) — the
    /// current / previous analytic vectors and the rate-of-change vector
    /// are filled in place each window.
    cur_analytic: AnalyticVec,
    prev_analytic: AnalyticVec,
    roc_scratch: AnalyticVec,
    has_prev: bool,
    /// Transition types named on-line: (type id, window index).
    pub transition_log: Vec<(u32, u64)>,
    pub context: Arc<Mutex<ContextStream>>,
    /// cap on history length (memory bound)
    max_history: usize,
    /// Telemetry handles (None when the plane runs uninstrumented;
    /// each hit is a single relaxed atomic increment).
    obs: Option<ObserveMetrics>,
}

impl OnlinePipeline {
    pub fn new(context: Arc<Mutex<ContextStream>>) -> OnlinePipeline {
        OnlinePipeline {
            detector: ChangeDetector::new(ChangeDetectorConfig::default()),
            classifier: Box::new(UnknownClassifier),
            transition_classifier: None,
            predictor: Box::new(MarkovPredictor::new()),
            history: Vec::new(),
            markov: MarkovPredictor::new(),
            cur_analytic: zero_analytic(),
            prev_analytic: zero_analytic(),
            roc_scratch: zero_analytic(),
            has_prev: false,
            transition_log: Vec::new(),
            context,
            max_history: 4096,
            obs: None,
        }
    }

    /// Install telemetry counters for the observe path (windows /
    /// UNKNOWN / transition tallies). Counting never affects what the
    /// pipeline publishes.
    pub fn set_observe_metrics(&mut self, m: ObserveMetrics) {
        self.obs = Some(m);
    }

    /// Install a trained TransitionClassifier (rate-of-change features).
    pub fn set_transition_classifier(
        &mut self,
        c: Box<dyn WindowClassifier + Send>,
    ) {
        self.transition_classifier = Some(c);
    }

    /// Swap in a trained classifier (after off-line training).
    pub fn set_classifier(&mut self, c: Box<dyn WindowClassifier + Send>) {
        self.classifier = c;
    }

    /// Swap in a trained predictor (e.g. the LSTM artifact wrapper).
    pub fn set_predictor(&mut self, p: Box<dyn LabelPredictor + Send>) {
        self.predictor = p;
    }

    /// Override the label-history cap (memory bound per pipeline shard;
    /// when exceeded the oldest half is drained). Clamped to >= 2 so the
    /// Markov update always has a pair to learn from.
    pub fn set_max_history(&mut self, cap: usize) {
        self.max_history = cap.max(2);
    }

    pub fn max_history(&self) -> usize {
        self.max_history
    }

    pub fn history(&self) -> &[u32] {
        &self.history
    }

    fn predict(&self, horizon: usize) -> u32 {
        self.predictor
            .predict(&self.history, horizon)
            .or_else(|| self.markov.predict(&self.history, horizon))
            .unwrap_or(UNKNOWN)
    }

    /// Process one closed window; classify, predict, publish and return
    /// the context. Steady-state calls allocate nothing in the pipeline
    /// itself: the analytic and rate-of-change vectors are written into
    /// fixed scratch buffers, and the classifiers the coordinator
    /// installs (centroid / gated-forest) tally on the stack too.
    pub fn observe(&mut self, w: &ObservationWindow) -> WorkloadContext {
        let changed = self.detector.observe(w);
        w.fill_analytic(&mut self.cur_analytic);
        let label = if changed {
            // transition in progress: the steady-state classifier stays
            // silent; the TransitionClassifier names the transition type
            // from the rate-of-change features instead
            if self.has_prev {
                if let Some(tc) = &self.transition_classifier {
                    for i in 0..ANALYTIC_WIDTH {
                        self.roc_scratch[i] =
                            self.cur_analytic[i] - self.prev_analytic[i];
                    }
                    let t = tc.classify(&self.roc_scratch);
                    if t != UNKNOWN {
                        self.transition_log.push((t, w.index));
                    }
                }
            }
            UNKNOWN
        } else {
            self.classifier.classify(&self.cur_analytic)
        };
        if let Some(m) = &self.obs {
            m.windows.inc();
            if changed {
                m.transitions.inc();
            }
            if label == UNKNOWN {
                m.unknown.inc();
            }
        }
        self.prev_analytic = self.cur_analytic;
        self.has_prev = true;
        if label != UNKNOWN
            && self.history.last().copied() != Some(label)
        {
            self.history.push(label);
            if self.history.len() > self.max_history {
                self.history.drain(..self.max_history / 2);
            }
            // keep the online Markov model warm
            let n = self.history.len();
            if n >= 2 {
                self.markov.update(&self.history[n - 2..]);
            }
        }
        let ctx = WorkloadContext {
            window_index: w.index,
            time: w.time,
            current_label: label,
            pred_1: self.predict(1),
            pred_5: self.predict(5),
            pred_10: self.predict(10),
        };
        self.context.lock().unwrap().publish(ctx);
        ctx
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::NUM_FEATURES;
    use crate::online::classifier::CentroidClassifier;
    use crate::knowledge::{Characterization, WorkloadDb};

    fn window(level: f64, idx: u64) -> ObservationWindow {
        ObservationWindow {
            index: idx,
            time: idx as f64 * 30.0,
            samples: 30,
            mean: [level; NUM_FEATURES],
            var: [1.0; NUM_FEATURES],
            truth: None,
        }
    }

    fn db_with_two_centroids() -> WorkloadDb {
        let mut db = WorkloadDb::new();
        // analytic width = 2 * NUM_FEATURES (mean + std)
        let mk = |level: f64| -> Vec<Vec<f64>> {
            let mut a = vec![level; 2 * NUM_FEATURES];
            let mut b = vec![level + 0.1; 2 * NUM_FEATURES];
            for i in NUM_FEATURES..2 * NUM_FEATURES {
                a[i] = 1.0;
                b[i] = 1.0;
            }
            vec![a, b]
        };
        for level in [5.0, 50.0] {
            let rows = mk(level);
            let c = Characterization::from_vec_rows(&rows);
            let centroid = c.mean_vector();
            db.insert_new(c, centroid, 2, false);
        }
        db
    }

    #[test]
    fn pipeline_publishes_unknown_before_training() {
        let ctx = Arc::new(Mutex::new(ContextStream::new(8)));
        let mut p = OnlinePipeline::new(ctx.clone());
        let c = p.observe(&window(5.0, 0));
        assert_eq!(c.current_label, UNKNOWN);
        assert_eq!(ctx.lock().unwrap().len(), 1);
    }

    #[test]
    fn classifies_and_predicts_recurring_pattern() {
        let ctx = Arc::new(Mutex::new(ContextStream::new(64)));
        let mut p = OnlinePipeline::new(ctx);
        let db = db_with_two_centroids();
        p.set_classifier(Box::new(CentroidClassifier::from_db(&db, 20.0)));

        // alternate 5.0 / 50.0 plateaus (3 windows each); use fresh
        // detector tolerance: consecutive same-level windows are steady
        let mut idx = 0u64;
        let mut last = WorkloadContext::unknown(0, 0.0);
        for _ in 0..6 {
            for level in [5.0, 50.0] {
                for _ in 0..3 {
                    last = p.observe(&window(level, idx));
                    idx += 1;
                }
            }
        }
        // after the pattern repeats, prediction should be informative
        assert_ne!(last.current_label, UNKNOWN);
        assert_ne!(last.pred_1, UNKNOWN);
        // history alternates 0,1,0,1...
        let h = p.history();
        assert!(h.len() >= 4);
        for pair in h.windows(2) {
            assert_ne!(pair[0], pair[1]);
        }
    }

    #[test]
    fn transition_windows_not_classified() {
        let ctx = Arc::new(Mutex::new(ContextStream::new(8)));
        let mut p = OnlinePipeline::new(ctx);
        let db = db_with_two_centroids();
        p.set_classifier(Box::new(CentroidClassifier::from_db(&db, 20.0)));
        p.observe(&window(5.0, 0));
        // abrupt jump: change detector fires, label must be UNKNOWN
        let c = p.observe(&window(50.0, 1));
        assert_eq!(c.current_label, UNKNOWN);
        // settled: next window classifies
        let c = p.observe(&window(50.0, 2));
        assert_ne!(c.current_label, UNKNOWN);
    }

    #[test]
    fn transition_classifier_names_transitions_online() {
        use crate::ml::forest::{ForestConfig, RandomForest};
        use crate::ml::Dataset;
        use crate::online::classifier::ForestWindowClassifier;
        use crate::util::rng::Rng;
        // train a transition forest on two ROC directions: up vs down
        let mut d = Dataset::new();
        let mut rng = Rng::new(0);
        for _ in 0..60 {
            let up: Vec<f64> = (0..2 * NUM_FEATURES)
                .map(|i| if i < NUM_FEATURES { 45.0 + rng.normal() } else { rng.normal() })
                .collect();
            let down: Vec<f64> = up.iter().map(|x| -x).collect();
            d.push(up, 100);
            d.push(down, 200);
        }
        let f = RandomForest::fit(&d, ForestConfig::default(), &mut rng);

        let ctx = Arc::new(Mutex::new(ContextStream::new(8)));
        let mut p = OnlinePipeline::new(ctx);
        p.set_transition_classifier(Box::new(ForestWindowClassifier::new(
            f, 0.5,
        )));
        p.observe(&window(5.0, 0));
        p.observe(&window(50.0, 1)); // upward jump
        p.observe(&window(50.0, 2));
        p.observe(&window(5.0, 3)); // downward jump
        assert_eq!(
            p.transition_log,
            vec![(100, 1), (200, 3)],
            "log: {:?}",
            p.transition_log
        );
    }

    #[test]
    fn history_cap_drains_oldest_half_and_keeps_a_suffix() {
        let ctx = Arc::new(Mutex::new(ContextStream::new(8)));
        let mut p = OnlinePipeline::new(ctx);
        let db = db_with_two_centroids();
        p.set_classifier(Box::new(CentroidClassifier::from_db(&db, 20.0)));
        p.set_max_history(8);
        assert_eq!(p.max_history(), 8);

        // alternate plateaus so every plateau appends one label; track
        // the full dedup label sequence the unbounded history would hold
        let mut full: Vec<u32> = Vec::new();
        let mut idx = 0u64;
        for _ in 0..14 {
            for level in [5.0, 50.0] {
                for _ in 0..3 {
                    let c = p.observe(&window(level, idx));
                    idx += 1;
                    if c.current_label != UNKNOWN
                        && full.last().copied() != Some(c.current_label)
                    {
                        full.push(c.current_label);
                    }
                    // the drain runs inside observe: the cap holds on
                    // every return, not just eventually
                    assert!(
                        p.history().len() <= 8,
                        "history grew past cap: {}",
                        p.history().len()
                    );
                }
            }
        }
        // 14 cycles x 2 plateaus pushed far more labels than the cap
        assert!(full.len() > 16, "only {} labels", full.len());
        // what survives is exactly a suffix of the full sequence
        assert!(
            full.ends_with(p.history()),
            "history {:?} not a suffix of {:?}",
            p.history(),
            full
        );
        // and the alternation structure survived the drains
        for pair in p.history().windows(2) {
            assert_ne!(pair[0], pair[1]);
        }
        // predictor still has signal after draining
        p.observe(&window(5.0, idx));
        assert!(p.history().len() >= 2);
    }

    #[test]
    fn history_dedups_consecutive_labels() {
        let ctx = Arc::new(Mutex::new(ContextStream::new(8)));
        let mut p = OnlinePipeline::new(ctx);
        let db = db_with_two_centroids();
        p.set_classifier(Box::new(CentroidClassifier::from_db(&db, 20.0)));
        for i in 0..5 {
            p.observe(&window(5.0, i));
        }
        assert_eq!(p.history().len(), 1);
    }
}
