//! On-line classifier drivers: uniform interface over the trained
//! WorkloadClassifier / TransitionClassifier variants so the pipeline
//! and plug-in don't care which backend is active.
//!
//! * [`ForestWindowClassifier`] — the paper's random forest (§7.2),
//!   native rust (`ml::forest`), with a confidence gate: low-confidence
//!   windows classify as UNKNOWN rather than guessing (the plug-in then
//!   uses the default configuration, the paper's safe fallback).
//! * [`CentroidClassifier`] — nearest-centroid against WorkloadDB
//!   characterizations with a distance gate: the bootstrap classifier
//!   available as soon as discovery has run once, before forest
//!   training.
//! * `runtime::nn::MlpClassifier` implements the same trait through the
//!   PJRT artifact (see `runtime::nn`).
//!
//! All centroid tables live in contiguous `Matrix` storage. The
//! classifiers the coordinator installs on-line ([`CentroidClassifier`],
//! [`GatedForestClassifier`]) perform no heap allocation in `classify`
//! (this is the per-window hot loop); [`ForestWindowClassifier`] keeps
//! the seed's soft-vote semantics and allocates per call.

use super::context::UNKNOWN;
use crate::knowledge::WorkloadDb;
use crate::linalg::engine::Engine;
use crate::linalg::{nearest_row, sq_dist, Matrix};
use crate::ml::forest::RandomForest;

/// A window-level workload classifier.
pub trait WindowClassifier {
    /// Classify an analytic-window feature vector; UNKNOWN when not
    /// confident.
    fn classify(&self, features: &[f64]) -> u32;
}

/// Random-forest driver with a soft-vote confidence threshold.
/// (The soft vote allocates per call; [`GatedForestClassifier`] is the
/// allocation-free hard-vote hot path the coordinator installs.)
pub struct ForestWindowClassifier {
    pub forest: RandomForest,
    /// Minimum winning-class vote share; below it -> UNKNOWN.
    pub min_confidence: f64,
}

impl ForestWindowClassifier {
    pub fn new(forest: RandomForest, min_confidence: f64) -> Self {
        ForestWindowClassifier { forest, min_confidence }
    }
}

impl WindowClassifier for ForestWindowClassifier {
    fn classify(&self, features: &[f64]) -> u32 {
        let votes = self.forest.vote(features);
        match votes
            .into_iter()
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        {
            Some((label, share)) if share >= self.min_confidence => label,
            _ => UNKNOWN,
        }
    }
}

/// Nearest-centroid against the WorkloadDB (bootstrap classifier).
pub struct CentroidClassifier {
    labels: Vec<u32>,
    /// One centroid per row, aligned with `labels`.
    centroids: Matrix,
    /// Maximum accepted distance; beyond it -> UNKNOWN.
    pub max_distance: f64,
}

impl CentroidClassifier {
    /// Snapshot the real (non-synthetic) workload centroids from the DB.
    pub fn from_db(db: &WorkloadDb, max_distance: f64) -> CentroidClassifier {
        let mut labels = Vec::new();
        let mut centroids = Matrix::new();
        for e in db.entries().filter(|e| !e.synthetic) {
            labels.push(e.label);
            centroids.push_row(&e.centroid);
        }
        CentroidClassifier { labels, centroids, max_distance }
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }
}

impl WindowClassifier for CentroidClassifier {
    fn classify(&self, features: &[f64]) -> u32 {
        if self.labels.is_empty() {
            return UNKNOWN;
        }
        let (best, best_d2) = nearest_row(&self.centroids, features);
        if best_d2 <= self.max_distance * self.max_distance {
            self.labels[best]
        } else {
            UNKNOWN
        }
    }
}

/// Random forest with a centroid distance gate: the forest proposes a
/// label, and the proposal is accepted only if the window is actually
/// near that workload's centroid. This guards against the forest's
/// blind spot — it is *always* confident on out-of-distribution inputs
/// when few classes exist (a one-class forest votes 100% for that class
/// on anything), which would poison the plug-in's search sessions with
/// wrong-workload measurements.
pub struct GatedForestClassifier {
    pub forest: RandomForest,
    /// Labels the gate knows, aligned with `centroids` rows. Labels
    /// absent here (e.g. ZSL synthetic classes) are accepted ungated.
    labels: Vec<u32>,
    centroids: Matrix,
    pub max_distance: f64,
    pub min_confidence: f64,
}

impl GatedForestClassifier {
    pub fn new(
        forest: RandomForest,
        centroids: impl IntoIterator<Item = (u32, Vec<f64>)>,
        max_distance: f64,
        min_confidence: f64,
    ) -> GatedForestClassifier {
        let mut labels = Vec::new();
        let mut table = Matrix::new();
        for (l, c) in centroids {
            labels.push(l);
            table.push_row(&c);
        }
        GatedForestClassifier {
            forest,
            labels,
            centroids: table,
            max_distance,
            min_confidence,
        }
    }

    /// Gate with centroids of all non-synthetic DB entries.
    pub fn from_db(
        forest: RandomForest,
        db: &WorkloadDb,
        max_distance: f64,
        min_confidence: f64,
    ) -> GatedForestClassifier {
        Self::new(
            forest,
            db.entries()
                .filter(|e| !e.synthetic)
                .map(|e| (e.label, e.centroid.clone())),
            max_distance,
            min_confidence,
        )
    }
}

impl WindowClassifier for GatedForestClassifier {
    fn classify(&self, features: &[f64]) -> u32 {
        // hard vote: the on-line hot path (§Perf iteration 2)
        let (label, share) = self.forest.vote_hard(features);
        if share < self.min_confidence {
            return UNKNOWN;
        }
        if let Some(pos) = self.labels.iter().position(|&l| l == label) {
            let d2 = sq_dist(self.centroids.row(pos), features);
            if d2 > self.max_distance * self.max_distance {
                return UNKNOWN;
            }
        }
        label
    }
}

/// Always-unknown classifier (pipeline state before any discovery).
pub struct UnknownClassifier;

impl WindowClassifier for UnknownClassifier {
    fn classify(&self, _features: &[f64]) -> u32 {
        UNKNOWN
    }
}

/// Batch helper used by benches: classify every row, keeping UNKNOWN.
pub fn classify_all(c: &dyn WindowClassifier, rows: &Matrix) -> Vec<u32> {
    rows.iter_rows().map(|r| c.classify(r)).collect()
}

/// Engine-parallel [`classify_all`]: windows are independent, so rows
/// fan out over the engine's worker pool and the labels come back
/// identical to the sequential helper.
pub fn classify_all_with(
    engine: Engine,
    c: &(dyn WindowClassifier + Sync),
    rows: &Matrix,
) -> Vec<u32> {
    let mut out = vec![0u32; rows.n_rows()];
    engine.for_rows(&mut out, 1, |start, chunk| {
        for (off, cell) in chunk.iter_mut().enumerate() {
            *cell = c.classify(rows.row(start + off));
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::knowledge::Characterization;
    use crate::ml::forest::ForestConfig;
    use crate::ml::Dataset;
    use crate::util::rng::Rng;

    fn blob_dataset(rng: &mut Rng) -> Dataset {
        let mut d = Dataset::new();
        for _ in 0..120 {
            d.push(vec![rng.normal_ms(0.0, 0.5), rng.normal_ms(0.0, 0.5)], 0);
            d.push(vec![rng.normal_ms(8.0, 0.5), rng.normal_ms(8.0, 0.5)], 1);
        }
        d
    }

    #[test]
    fn forest_confident_on_train_region_unknown_far_away() {
        let mut rng = Rng::new(0);
        let d = blob_dataset(&mut rng);
        let f = RandomForest::fit(&d, ForestConfig::default(), &mut rng);
        let c = ForestWindowClassifier::new(f, 0.7);
        assert_eq!(c.classify(&[0.1, -0.1]), 0);
        assert_eq!(c.classify(&[8.2, 7.9]), 1);
        // a point between blobs gets mixed votes -> UNKNOWN at 0.7 gate
        // (forests can still be confident off-distribution, so only
        // assert the in-distribution behaviour strictly)
    }

    #[test]
    fn centroid_classifier_with_gate() {
        let mut db = WorkloadDb::new();
        let rows0: Vec<Vec<f64>> = vec![vec![0.0, 0.0], vec![0.2, 0.1]];
        let rows1: Vec<Vec<f64>> = vec![vec![10.0, 10.0], vec![10.1, 9.9]];
        let l0 = db.insert_new(
            Characterization::from_vec_rows(&rows0),
            vec![0.1, 0.05],
            2,
            false,
        );
        let l1 = db.insert_new(
            Characterization::from_vec_rows(&rows1),
            vec![10.05, 9.95],
            2,
            false,
        );
        let c = CentroidClassifier::from_db(&db, 3.0);
        assert_eq!(c.classify(&[0.0, 0.2]), l0);
        assert_eq!(c.classify(&[9.8, 10.2]), l1);
        assert_eq!(c.classify(&[5.0, 5.0]), UNKNOWN); // between, gated
    }

    #[test]
    fn centroid_skips_synthetic_entries() {
        let mut db = WorkloadDb::new();
        db.insert_new(
            Characterization::from_vec_rows(&[vec![0.0], vec![0.1]]),
            vec![0.05],
            2,
            true, // synthetic
        );
        let c = CentroidClassifier::from_db(&db, 100.0);
        assert!(c.is_empty());
        assert_eq!(c.classify(&[0.0]), UNKNOWN);
    }

    #[test]
    fn classify_all_maps_rows() {
        let mut db = WorkloadDb::new();
        db.insert_new(
            Characterization::from_vec_rows(&[vec![0.0], vec![0.2]]),
            vec![0.1],
            2,
            false,
        );
        let c = CentroidClassifier::from_db(&db, 1.0);
        let rows = crate::linalg::Matrix::from_rows(&[
            vec![0.0],
            vec![50.0],
        ]);
        let out = classify_all(&c, &rows);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0], 0);
        assert_eq!(out[1], UNKNOWN);
    }

    #[test]
    fn unknown_classifier_is_unknown() {
        assert_eq!(UnknownClassifier.classify(&[1.0, 2.0]), UNKNOWN);
    }

    #[test]
    fn classify_all_with_matches_sequential() {
        let mut db = WorkloadDb::new();
        db.insert_new(
            Characterization::from_vec_rows(&[vec![0.0], vec![0.2]]),
            vec![0.1],
            2,
            false,
        );
        let c = CentroidClassifier::from_db(&db, 1.0);
        let mut rows = crate::linalg::Matrix::with_width(1);
        for i in 0..120 {
            rows.push_row(&[(i % 7) as f64]);
        }
        let seq = classify_all(&c, &rows);
        for threads in [2, 4] {
            let engine = Engine::with_threads(threads).with_min_items(1);
            let par = classify_all_with(engine, &c, &rows);
            assert_eq!(seq, par, "threads {threads}");
        }
    }
}
