//! The KERMIT on-line sub-system: real-time change detection, workload
//! classification, prediction, the context stream, and the resource-
//! manager plug-in implementing Algorithm 1.

pub mod change_detector;
pub mod classifier;
pub mod context;
pub mod pipeline;
pub mod plugin;
pub mod predictor;

pub use change_detector::{ChangeDetector, ChangeDetectorConfig};
pub use classifier::{
    CentroidClassifier, ForestWindowClassifier, UnknownClassifier,
    WindowClassifier,
};
pub use context::{ContextBus, ContextStream, WorkloadContext, UNKNOWN};
pub use pipeline::OnlinePipeline;
pub use plugin::{ChoiceKind, KermitPlugin, PluginStats, ResiliencePolicy};
pub use predictor::{
    sequence_accuracy, LabelPredictor, LastValuePredictor, MarkovPredictor,
};
