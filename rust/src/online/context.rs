//! Workload context objects `C_t` (paper §6.4): what the monitor-side
//! pipeline publishes and the plug-in consumes on every resource
//! request.

/// Label value for windows the pipeline cannot yet classify.
pub const UNKNOWN: u32 = u32::MAX;

/// The context at observation window `t` — exactly the four items §6.4
/// lists, plus the window index/time used for the plug-in's staleness
/// check.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WorkloadContext {
    pub window_index: u64,
    pub time: f64,
    /// Workload label for the current observation window.
    pub current_label: u32,
    /// Predicted label at horizon t+1.
    pub pred_1: u32,
    /// Predicted label at horizon t+5.
    pub pred_5: u32,
    /// Predicted label at horizon t+10.
    pub pred_10: u32,
}

impl WorkloadContext {
    pub fn unknown(window_index: u64, time: f64) -> WorkloadContext {
        WorkloadContext {
            window_index,
            time,
            current_label: UNKNOWN,
            pred_1: UNKNOWN,
            pred_5: UNKNOWN,
            pred_10: UNKNOWN,
        }
    }

    pub fn is_known(&self) -> bool {
        self.current_label != UNKNOWN
    }
}

/// The context stream `{C_t}`: a bounded in-memory ring the plug-in
/// reads the latest element of. (On the paper's cluster this is a
/// streaming file; a ring buffer models the same read-latest semantics.)
#[derive(Debug)]
pub struct ContextStream {
    buf: std::collections::VecDeque<WorkloadContext>,
    cap: usize,
}

impl ContextStream {
    pub fn new(cap: usize) -> ContextStream {
        assert!(cap > 0);
        ContextStream { buf: std::collections::VecDeque::new(), cap }
    }

    pub fn publish(&mut self, c: WorkloadContext) {
        if self.buf.len() == self.cap {
            self.buf.pop_front();
        }
        self.buf.push_back(c);
    }

    pub fn latest(&self) -> Option<&WorkloadContext> {
        self.buf.back()
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = &WorkloadContext> {
        self.buf.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_keeps_latest() {
        let mut s = ContextStream::new(3);
        for i in 0..5u64 {
            s.publish(WorkloadContext::unknown(i, i as f64));
        }
        assert_eq!(s.len(), 3);
        assert_eq!(s.latest().unwrap().window_index, 4);
        let idx: Vec<u64> = s.iter().map(|c| c.window_index).collect();
        assert_eq!(idx, vec![2, 3, 4]);
    }

    #[test]
    fn unknown_context() {
        let c = WorkloadContext::unknown(0, 0.0);
        assert!(!c.is_known());
        assert_eq!(c.pred_10, UNKNOWN);
    }
}
