//! Workload context objects `C_t` (paper §6.4): what the monitor-side
//! pipeline publishes and the plug-in consumes on every resource
//! request.

/// Label value for windows the pipeline cannot yet classify.
pub const UNKNOWN: u32 = u32::MAX;

/// The context at observation window `t` — exactly the four items §6.4
/// lists, plus the window index/time used for the plug-in's staleness
/// check.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WorkloadContext {
    pub window_index: u64,
    pub time: f64,
    /// Workload label for the current observation window.
    pub current_label: u32,
    /// Predicted label at horizon t+1.
    pub pred_1: u32,
    /// Predicted label at horizon t+5.
    pub pred_5: u32,
    /// Predicted label at horizon t+10.
    pub pred_10: u32,
}

impl WorkloadContext {
    pub fn unknown(window_index: u64, time: f64) -> WorkloadContext {
        WorkloadContext {
            window_index,
            time,
            current_label: UNKNOWN,
            pred_1: UNKNOWN,
            pred_5: UNKNOWN,
            pred_10: UNKNOWN,
        }
    }

    pub fn is_known(&self) -> bool {
        self.current_label != UNKNOWN
    }
}

/// The context stream `{C_t}`: a bounded in-memory ring the plug-in
/// reads the latest element of. (On the paper's cluster this is a
/// streaming file; a ring buffer models the same read-latest semantics.)
#[derive(Debug)]
pub struct ContextStream {
    buf: std::collections::VecDeque<WorkloadContext>,
    cap: usize,
}

impl ContextStream {
    pub fn new(cap: usize) -> ContextStream {
        assert!(cap > 0);
        ContextStream { buf: std::collections::VecDeque::new(), cap }
    }

    pub fn publish(&mut self, c: WorkloadContext) {
        if self.buf.len() == self.cap {
            self.buf.pop_front();
        }
        self.buf.push_back(c);
    }

    pub fn latest(&self) -> Option<&WorkloadContext> {
        self.buf.back()
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = &WorkloadContext> {
        self.buf.iter()
    }
}

/// A bus of per-tenant context streams: one ring (and one lock) per
/// tenant, so N pipeline shards publishing concurrently never contend on
/// a shared `Mutex` — the single-stream `Arc<Mutex<ContextStream>>`
/// would otherwise serialize the multi-tenant observe path. Handles are
/// cheap `Arc` clones; the plug-in serving tenant `t` holds `stream(t)`
/// and sees only that tenant's contexts.
#[derive(Debug)]
pub struct ContextBus {
    streams: std::collections::BTreeMap<
        crate::features::TenantId,
        std::sync::Arc<std::sync::Mutex<ContextStream>>,
    >,
    cap: usize,
}

impl ContextBus {
    /// `cap` is the ring capacity of every per-tenant stream.
    pub fn new(cap: usize) -> ContextBus {
        assert!(cap > 0);
        ContextBus { streams: Default::default(), cap }
    }

    /// Get (creating on first use) tenant `t`'s stream handle.
    pub fn stream(
        &mut self,
        t: crate::features::TenantId,
    ) -> std::sync::Arc<std::sync::Mutex<ContextStream>> {
        let cap = self.cap;
        self.streams
            .entry(t)
            .or_insert_with(|| {
                std::sync::Arc::new(std::sync::Mutex::new(
                    ContextStream::new(cap),
                ))
            })
            .clone()
    }

    /// Tenant `t`'s stream, if it has published before.
    pub fn get(
        &self,
        t: crate::features::TenantId,
    ) -> Option<std::sync::Arc<std::sync::Mutex<ContextStream>>> {
        self.streams.get(&t).cloned()
    }

    /// Latest context for tenant `t` (a copy — the lock is held only for
    /// the read).
    pub fn latest(
        &self,
        t: crate::features::TenantId,
    ) -> Option<WorkloadContext> {
        self.streams
            .get(&t)
            .and_then(|s| s.lock().unwrap().latest().copied())
    }

    /// Tenants with a stream, in id order.
    pub fn tenants(&self) -> Vec<crate::features::TenantId> {
        self.streams.keys().copied().collect()
    }

    pub fn len(&self) -> usize {
        self.streams.len()
    }

    pub fn is_empty(&self) -> bool {
        self.streams.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_keeps_latest() {
        let mut s = ContextStream::new(3);
        for i in 0..5u64 {
            s.publish(WorkloadContext::unknown(i, i as f64));
        }
        assert_eq!(s.len(), 3);
        assert_eq!(s.latest().unwrap().window_index, 4);
        let idx: Vec<u64> = s.iter().map(|c| c.window_index).collect();
        assert_eq!(idx, vec![2, 3, 4]);
    }

    #[test]
    fn unknown_context() {
        let c = WorkloadContext::unknown(0, 0.0);
        assert!(!c.is_known());
        assert_eq!(c.pred_10, UNKNOWN);
    }

    #[test]
    fn concurrent_publishers_one_stream_stays_bounded_and_ordered() {
        use std::sync::{Arc, Mutex};
        let cap = 32;
        let stream = Arc::new(Mutex::new(ContextStream::new(cap)));
        let writers = 8;
        let per_writer = 200u64;
        std::thread::scope(|s| {
            for w in 0..writers {
                let stream = stream.clone();
                s.spawn(move || {
                    for i in 0..per_writer {
                        stream.lock().unwrap().publish(
                            WorkloadContext::unknown(
                                w * per_writer + i,
                                i as f64,
                            ),
                        );
                    }
                });
            }
        });
        let st = stream.lock().unwrap();
        // ring is full, never over capacity
        assert_eq!(st.len(), cap);
        // every element is one of the published contexts
        for c in st.iter() {
            assert!(c.window_index < writers * per_writer);
        }
        // each writer's surviving contexts appear in its publish order
        for w in 0..writers {
            let idx: Vec<u64> = st
                .iter()
                .map(|c| c.window_index)
                .filter(|&i| i / per_writer == w)
                .collect();
            assert!(
                idx.windows(2).all(|p| p[0] < p[1]),
                "writer {w} out of order: {idx:?}"
            );
        }
    }

    #[test]
    fn bus_isolates_tenants_under_concurrent_publishers() {
        use crate::features::TenantId;
        let mut bus = ContextBus::new(16);
        let writers = 6u32;
        let handles: Vec<_> =
            (0..writers).map(|t| bus.stream(TenantId(t))).collect();
        // same handle back on re-request (create-or-get semantics)
        assert_eq!(bus.len(), writers as usize);
        assert!(std::sync::Arc::ptr_eq(
            &handles[0],
            &bus.stream(TenantId(0))
        ));
        std::thread::scope(|s| {
            for (t, h) in handles.iter().enumerate() {
                let h = h.clone();
                s.spawn(move || {
                    for i in 0..300u64 {
                        let mut c = WorkloadContext::unknown(i, i as f64);
                        c.current_label = t as u32;
                        h.lock().unwrap().publish(c);
                    }
                });
            }
        });
        for t in 0..writers {
            let stream = bus.get(TenantId(t)).unwrap();
            {
                let st = stream.lock().unwrap();
                assert_eq!(st.len(), 16, "tenant {t}");
                // no cross-tenant bleed: every context carries its
                // tenant's label, in publish order
                let idx: Vec<u64> =
                    st.iter().map(|c| c.window_index).collect();
                assert!(st.iter().all(|c| c.current_label == t));
                assert!(idx.windows(2).all(|p| p[0] + 1 == p[1]));
            } // guard drops: bus.latest re-locks this same stream
            assert_eq!(
                bus.latest(TenantId(t)).unwrap().window_index,
                299
            );
        }
        assert!(bus.latest(TenantId(99)).is_none());
        assert_eq!(bus.tenants().len(), writers as usize);
    }
}
